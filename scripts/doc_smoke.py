#!/usr/bin/env python3
"""Execute the documentation's runnable command blocks.

Fenced code blocks in README.md and docs/*.md whose info string is
``bash doc-smoke`` are contracts, not prose: this script extracts each
one and runs it with ``bash -euo pipefail`` so CI fails the moment a
documented command sequence rots (renamed flag, removed subcommand,
changed default).

Blocks run in a throwaway working directory (so relative cache/output
dirs like ``.plans-docs`` never pollute the checkout) with the repo's
``src/`` prepended to ``PYTHONPATH`` (no-op when the package is
pip-installed, as in CI).

Usage:
    python scripts/doc_smoke.py            # run every block
    python scripts/doc_smoke.py --list     # show blocks without running
"""

from __future__ import annotations

import argparse
import os
import re
import subprocess
import sys
import tempfile
import time
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent
MARKER = "doc-smoke"
FENCE_RE = re.compile(
    r"^```bash[ \t]+doc-smoke[ \t]*\n(.*?)^```[ \t]*$",
    re.MULTILINE | re.DOTALL,
)


def doc_files() -> list[Path]:
    files = [REPO / "README.md"]
    files.extend(sorted((REPO / "docs").glob("*.md")))
    return [f for f in files if f.is_file()]


def extract_blocks() -> list[tuple[Path, str]]:
    blocks = []
    for f in doc_files():
        for m in FENCE_RE.finditer(f.read_text()):
            blocks.append((f, m.group(1)))
    return blocks


def run_block(path: Path, script: str, workdir: str) -> float:
    env = dict(os.environ)
    env["PYTHONPATH"] = str(REPO / "src") + os.pathsep + env.get("PYTHONPATH", "")
    t0 = time.monotonic()
    subprocess.run(
        ["bash", "-euo", "pipefail", "-c", script],
        cwd=workdir,
        env=env,
        check=True,
    )
    return time.monotonic() - t0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--list", action="store_true",
                    help="print the extracted blocks and exit")
    args = ap.parse_args(argv)

    blocks = extract_blocks()
    if not blocks:
        print(f"doc-smoke: no ```bash {MARKER} blocks found", file=sys.stderr)
        return 1

    if args.list:
        for path, script in blocks:
            print(f"--- {path.relative_to(REPO)} ---")
            print(script, end="")
        return 0

    failed = 0
    with tempfile.TemporaryDirectory(prefix="doc-smoke-") as workdir:
        for i, (path, script) in enumerate(blocks, 1):
            rel = path.relative_to(REPO)
            print(f"[doc-smoke {i}/{len(blocks)}] {rel}", flush=True)
            for line in script.rstrip().splitlines():
                print(f"    {line}")
            try:
                dt = run_block(path, script, workdir)
            except subprocess.CalledProcessError as e:
                print(f"[doc-smoke {i}/{len(blocks)}] FAILED "
                      f"(exit {e.returncode}): {rel}", file=sys.stderr)
                failed += 1
            else:
                print(f"[doc-smoke {i}/{len(blocks)}] ok ({dt:.1f}s)",
                      flush=True)
    if failed:
        print(f"doc-smoke: {failed}/{len(blocks)} block(s) failed",
              file=sys.stderr)
        return 1
    print(f"doc-smoke: {len(blocks)}/{len(blocks)} block(s) green")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
