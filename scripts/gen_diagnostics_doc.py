#!/usr/bin/env python
"""Generate docs/DIAGNOSTICS.md from the verifier's diagnostic table.

The TAGxxx codes in ``repro.verify.diagnostics.CODES`` are API — tests,
CI gates and the mutation self-test match on them — so their reference
page is generated, never hand-edited. Regenerate after touching CODES:

    PYTHONPATH=src python scripts/gen_diagnostics_doc.py

CI runs the sync check and fails when the committed page drifts from
the table in code:

    PYTHONPATH=src python scripts/gen_diagnostics_doc.py --check
"""
from __future__ import annotations

import argparse
import os
import sys

HEADER = """\
# Verifier diagnostic codes (TAGxxx)

<!-- GENERATED FILE — do not edit by hand.
     Regenerate with: PYTHONPATH=src python scripts/gen_diagnostics_doc.py -->

Every finding the static plan verifier (`repro.verify`) emits carries a
stable `TAGxxx` code. Codes are API: they never change meaning once
shipped, so alert routing, CI gates and the mutation self-test can
match on them. This page is generated from
`repro.verify.diagnostics.CODES`.

Severity semantics:

* **error** — the deployment is unsound: it deadlocks, races, OOMs or
  references devices/links that cannot serve it. `PlannerService`
  refuses to cache such a plan; execution preflight refuses to run it.
* **warn** — legal but suspicious; the plan runs, the diagnostic ships
  with it.
* **info** — lint-grade observations.

See [verification.md](verification.md) for the analyses that emit
these codes and where they are wired.
"""

# code-prefix -> section title, in rendering order
SECTIONS = [
    ("TAG0", "Plan / input structure"),
    ("TAG1", "Happens-before analysis"),
    ("TAG2", "Memory-budget prover"),
    ("TAG3", "Collective matching"),
    ("TAG4", "Placement feasibility"),
]


def render() -> str:
    from repro.verify.diagnostics import CODES

    lines = [HEADER]
    for prefix, title in SECTIONS:
        rows = sorted((c, sev, t) for c, (sev, t) in CODES.items()
                      if c.startswith(prefix))
        if not rows:
            continue
        lines.append(f"\n## {title}\n")
        lines.append("| Code | Severity | Meaning |")
        lines.append("|------|----------|---------|")
        for code, sev, text in rows:
            lines.append(f"| `{code}` | {sev} | {text} |")
    orphans = sorted(c for c in CODES
                     if not any(c.startswith(p) for p, _ in SECTIONS))
    if orphans:
        lines.append("\n## Other\n")
        lines.append("| Code | Severity | Meaning |")
        lines.append("|------|----------|---------|")
        for code in orphans:
            sev, text = CODES[code]
            lines.append(f"| `{code}` | {sev} | {text} |")
    lines.append("")
    return "\n".join(lines)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--check", action="store_true",
                    help="fail if docs/DIAGNOSTICS.md is out of sync "
                         "with repro.verify.diagnostics.CODES")
    ap.add_argument("--out", default=os.path.join(
        os.path.dirname(__file__), "..", "docs", "DIAGNOSTICS.md"))
    args = ap.parse_args(argv)
    want = render()
    out = os.path.normpath(args.out)
    if args.check:
        have = open(out).read() if os.path.exists(out) else ""
        if have != want:
            print(f"{out} is out of sync with "
                  f"repro.verify.diagnostics.CODES — regenerate with:\n"
                  f"  PYTHONPATH=src python scripts/gen_diagnostics_doc.py")
            return 1
        print(f"{out}: in sync ({want.count('TAG')} code mentions)")
        return 0
    os.makedirs(os.path.dirname(out), exist_ok=True)
    with open(out, "w") as f:
        f.write(want)
    print(f"wrote {out}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
