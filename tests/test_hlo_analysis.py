"""HLO analyzer: flops agreement with XLA cost_analysis on loop-free
modules; trip-count multiplication on scanned modules; collective byte
extraction."""
import jax
import jax.numpy as jnp

from repro.core.hlo_analysis import analyze_hlo, xla_cost_analysis


def _compile(fn, *args):
    return jax.jit(fn).lower(*args).compile()


def test_flops_match_cost_analysis_loop_free():
    def f(a, b):
        return jnp.tanh(a @ b) @ b
    a = jnp.ones((64, 128), jnp.float32)
    b = jnp.ones((128, 128), jnp.float32)
    c = _compile(f, a, b)
    stats = analyze_hlo(c.as_text())
    xla = xla_cost_analysis(c)["flops"]
    # dot flops dominate; agree within 20%
    assert abs(stats.flops - xla) / xla < 0.2


def test_while_trip_count_scaling():
    def f(x, w):
        def body(h, _):
            return jnp.tanh(h @ w), None
        h, _ = jax.lax.scan(body, x, None, length=16)
        return h.sum()
    x = jnp.ones((32, 64), jnp.float32)
    w = jnp.ones((64, 64), jnp.float32)
    c = _compile(f, x, w)
    stats = analyze_hlo(c.as_text())
    assert 16 in stats.while_trips
    per_iter = 2 * 32 * 64 * 64
    assert stats.flops >= 16 * per_iter * 0.9
    xla = xla_cost_analysis(c)["flops"]    # counts the body once
    assert stats.flops > 4 * xla


def test_nested_scan_multiplies():
    def f(x, w):
        def inner(h, _):
            return jnp.tanh(h @ w), None

        def outer(h, _):
            h, _ = jax.lax.scan(inner, h, None, length=4)
            return h, None
        h, _ = jax.lax.scan(outer, x, None, length=3)
        return h.sum()
    x = jnp.ones((8, 32), jnp.float32)
    w = jnp.ones((32, 32), jnp.float32)
    stats = analyze_hlo(_compile(f, x, w).as_text())
    per_iter = 2 * 8 * 32 * 32
    assert stats.flops >= 12 * per_iter * 0.9


def test_bytes_nonzero_and_scale_with_size():
    def f(a):
        return a * 2.0 + 1.0
    small = analyze_hlo(_compile(f, jnp.ones((128,), jnp.float32)).as_text())
    big = analyze_hlo(_compile(
        f, jnp.ones((128 * 1024,), jnp.float32)).as_text())
    assert big.bytes_accessed > 100 * small.bytes_accessed > 0
