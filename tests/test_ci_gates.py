"""CI benchmark-regression gate (benchmarks/check_regression.py).

The gate must catch an injected regression — step time / bubble fraction
creeping past the tolerance, a boolean acceptance flag flipping, or a
benchmark silently disappearing — and must pass an unchanged or
improved run.
"""
import copy
import json
import os

from benchmarks.check_regression import (
    METRICS, Violation, check_files, compare, lookup, main)

BASELINE = {
    "1f1b": {"step_time_s": 2.0, "bubble_frac": 0.50},
    "zb": {"step_time_s": 1.9},
    "pipeline_speedup_vs_dp": 2.3,
    "schedule_quality": {
        "1f1b": {"bubble_frac": 0.52},
        "interleaved": {"bubble_frac": 0.47},
        "zb": {"bubble_frac": 0.46},
        "zb_lower_bubble": True,
        "interleaved_lower_bubble": True,
    },
    "mcts": {"aware_step_time_s": 0.16,
             "variants": {"zb": {"step_time_s": 2.78}},
             "fifo_schedule_blind": True,
             "aware_pick_is_best": True},
}


def test_unchanged_run_passes():
    assert compare("BENCH_pipeline.json", BASELINE,
                   copy.deepcopy(BASELINE)) == []


def test_improvement_passes():
    fresh = copy.deepcopy(BASELINE)
    fresh["1f1b"]["step_time_s"] = 1.5          # faster
    fresh["pipeline_speedup_vs_dp"] = 3.0       # higher
    assert compare("BENCH_pipeline.json", BASELINE, fresh) == []


def test_injected_step_time_regression_caught():
    fresh = copy.deepcopy(BASELINE)
    fresh["1f1b"]["step_time_s"] = 2.0 * 1.11   # > 10% tolerance
    vs = compare("BENCH_pipeline.json", BASELINE, fresh)
    assert len(vs) == 1 and vs[0].path == "1f1b.step_time_s"
    # within tolerance is allowed
    fresh["1f1b"]["step_time_s"] = 2.0 * 1.09
    assert compare("BENCH_pipeline.json", BASELINE, fresh) == []


def test_injected_bubble_and_bool_regressions_caught():
    fresh = copy.deepcopy(BASELINE)
    fresh["schedule_quality"]["zb"]["bubble_frac"] = 0.60
    fresh["schedule_quality"]["zb_lower_bubble"] = False
    fresh["mcts"]["aware_pick_is_best"] = False
    paths = {v.path for v in
             compare("BENCH_pipeline.json", BASELINE, fresh)}
    assert paths == {"schedule_quality.zb.bubble_frac",
                     "schedule_quality.zb_lower_bubble",
                     "mcts.aware_pick_is_best"}


def test_higher_is_better_direction():
    fresh = copy.deepcopy(BASELINE)
    fresh["pipeline_speedup_vs_dp"] = 2.3 * 0.85     # fell > 10%
    vs = compare("BENCH_pipeline.json", BASELINE, fresh)
    assert [v.path for v in vs] == ["pipeline_speedup_vs_dp"]


def test_missing_fresh_metric_is_violation():
    fresh = copy.deepcopy(BASELINE)
    del fresh["mcts"]
    paths = {v.path for v in
             compare("BENCH_pipeline.json", BASELINE, fresh)}
    assert "mcts.aware_step_time_s" in paths


def test_metric_added_after_baseline_skipped():
    base = copy.deepcopy(BASELINE)
    del base["zb"]                       # baseline predates the metric
    assert compare("BENCH_pipeline.json", base,
                   copy.deepcopy(BASELINE)) == []


def test_check_files_and_cli(tmp_path):
    bdir, fdir = tmp_path / "base", tmp_path / "fresh"
    bdir.mkdir()
    fdir.mkdir()
    spec = {"BENCH_pipeline.json": METRICS["BENCH_pipeline.json"]}
    (bdir / "BENCH_pipeline.json").write_text(json.dumps(BASELINE))
    bad = copy.deepcopy(BASELINE)
    bad["mcts"]["aware_step_time_s"] = 99.0
    (fdir / "BENCH_pipeline.json").write_text(json.dumps(bad))
    vs, _ = check_files(str(bdir), str(fdir), spec)
    assert len(vs) == 1 and isinstance(vs[0], Violation)
    # missing fresh file = violation; missing baseline = note only
    os.remove(fdir / "BENCH_pipeline.json")
    vs, _ = check_files(str(bdir), str(fdir), spec)
    assert vs and vs[0].kind == "presence"
    os.remove(bdir / "BENCH_pipeline.json")
    (fdir / "BENCH_pipeline.json").write_text(json.dumps(BASELINE))
    vs, notes = check_files(str(bdir), str(fdir), spec)
    assert vs == [] and any("no committed baseline" in n for n in notes)
    # CLI exit codes against the real metric table
    (bdir / "BENCH_pipeline.json").write_text(json.dumps(BASELINE))
    assert main(["--baseline-dir", str(bdir),
                 "--fresh-dir", str(fdir)]) == 0
    (fdir / "BENCH_pipeline.json").write_text(json.dumps(bad))
    assert main(["--baseline-dir", str(bdir),
                 "--fresh-dir", str(fdir)]) == 1


def test_lookup_list_paths():
    doc = {"transfer": [{"halved": True}, {"halved": False}]}
    assert lookup(doc, "transfer.0.halved") is True
    assert lookup(doc, "transfer.1.halved") is False


def test_real_committed_baselines_parse():
    """Every gated metric path resolves in the committed baselines (so
    the CI gate can never silently no-op)."""
    results = os.path.join(os.path.dirname(__file__), "..", "results")
    for fname, metrics in METRICS.items():
        path = os.path.join(results, fname)
        assert os.path.exists(path), fname
        with open(path) as f:
            doc = json.load(f)
        for mpath, kind, _ in metrics:
            val = lookup(doc, mpath)
            if kind == "true":
                assert val, (fname, mpath)
