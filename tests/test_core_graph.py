"""Graph export, splittability, partitioner properties (incl. hypothesis
on random DAGs)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.graph import CompGraph, OpNode, Split, group_graph
from repro.core.jax_export import trace_training_graph
from repro.core.partition import cut_bytes, partition
from repro.core.zoo import build


@pytest.fixture(scope="module")
def bert_graph():
    loss_fn, params, batch = build("bert_small")
    return trace_training_graph(loss_fn, params, batch, "bert_small") \
        .simplify()


def test_trace_marks_gradients_and_params(bert_graph):
    g = bert_graph
    n_param = sum(1 for n in g.nodes.values() if n.is_param)
    n_grad = sum(1 for n in g.nodes.values() if n.is_grad_producer)
    n_apply = sum(1 for n in g.nodes.values() if n.is_apply_grad)
    assert n_param == n_apply  # one optimizer op per parameter
    assert n_grad > 0
    # total grad bytes == total param bytes
    pb = sum(n.param_bytes for n in g.nodes.values())
    gb = sum(n.grad_bytes for n in g.nodes.values())
    assert abs(pb - gb) / pb < 1e-6


def test_splittability_categories(bert_graph):
    g = bert_graph
    cats = {s: 0 for s in Split}
    for n in g.nodes.values():
        cats[n.split] += 1
    # forward ops carry the batch dim; gradient contractions drop it
    assert cats[Split.CONCAT] > 0
    assert cats[Split.SUM] > 0
    assert cats[Split.OTHER] > 0
    # every gradient producer is where batch is contracted or OTHER
    for n in g.nodes.values():
        if n.is_apply_grad:
            assert n.split == Split.OTHER


def test_scan_flops_scaling():
    """Scan bodies must be multiplied by trip count in the trace."""
    import jax
    import jax.numpy as jnp

    def loss_long(p, b):
        def body(x, _):
            return jnp.tanh(x @ p["w"]), None
        x, _ = jax.lax.scan(body, b["x"], None, length=8)
        return jnp.sum(x)

    def loss_short(p, b):
        def body(x, _):
            return jnp.tanh(x @ p["w"]), None
        x, _ = jax.lax.scan(body, b["x"], None, length=2)
        return jnp.sum(x)

    w = jnp.ones((16, 16))
    batch = {"x": jnp.ones((4, 16))}
    g8 = trace_training_graph(loss_long, {"w": w}, batch)
    g2 = trace_training_graph(loss_short, {"w": w}, batch)
    assert g8.total_flops() > 3 * g2.total_flops()


def test_partition_respects_group_count_and_balance(bert_graph):
    for n_groups in (10, 30, 60):
        asn = partition(bert_graph, n_groups)
        assert max(asn.values()) + 1 <= n_groups
        gg = group_graph(bert_graph, asn)
        flops = [g.flops for g in gg.groups]
        # capacity: no group above balance * average (loose factor 3 for
        # indivisible single ops)
        assert max(flops) <= 3.0 * sum(flops) / len(flops) + max(
            n.flops for n in bert_graph.nodes.values())


def test_partition_group_graph_is_acyclic(bert_graph):
    asn = partition(bert_graph, 40)
    # every edge must go from group i to group j with i <= j after
    # topological renumbering... acyclicity is the real requirement:
    gg = group_graph(bert_graph, asn)
    n = gg.n
    adj = {i: set() for i in range(n)}
    for (a, b) in gg.edges:
        adj[a].add(b)
    # DFS cycle check
    state = [0] * n

    def dfs(u):
        state[u] = 1
        for v in adj[u]:
            if state[v] == 1:
                return False
            if state[v] == 0 and not dfs(v):
                return False
        state[u] = 2
        return True

    assert all(dfs(u) for u in range(n) if state[u] == 0)


@st.composite
def random_dag(draw):
    n = draw(st.integers(5, 40))
    edges = []
    for j in range(1, n):
        for i in range(j):
            if draw(st.booleans()) and len(edges) < 4 * n:
                edges.append((i, j))
    return n, edges


@given(random_dag(), st.integers(2, 8))
@settings(max_examples=25, deadline=None)
def test_partition_acyclic_on_random_dags(dag, n_groups):
    n, edges = dag
    g = CompGraph()
    rng = np.random.default_rng(42)
    for i in range(n):
        g.add_node(OpNode(op_id=i, name=f"op{i}", op_type="dot_general",
                          flops=float(rng.uniform(1, 100))))
    for (a, b) in edges:
        g.add_edge(a, b, float(rng.uniform(1, 1e6)))
    asn = partition(g, n_groups)
    assert set(asn) == set(range(n))
    # group-level acyclicity via topological numbering property
    gg = group_graph(g, asn)
    state = [0] * gg.n
    adj = {i: set() for i in range(gg.n)}
    for (a, b) in gg.edges:
        adj[a].add(b)

    def dfs(u):
        state[u] = 1
        ok = True
        for v in adj[u]:
            if state[v] == 1:
                return False
            if state[v] == 0:
                ok = ok and dfs(v)
        state[u] = 2
        return ok

    assert all(dfs(u) for u in range(gg.n) if state[u] == 0)


def test_refinement_does_not_increase_cut(bert_graph):
    """Partition cut should beat naive contiguous chunking."""
    order = bert_graph.topo_order()
    n_groups = 20
    weights = {i: max(bert_graph.nodes[i].flops, 1.0) for i in bert_graph.nodes}
    total = sum(weights.values())
    target = total / n_groups
    naive, gid, acc = {}, 0, 0.0
    for op in order:
        naive[op] = gid
        acc += weights[op]
        if acc >= target * (gid + 1) and gid < n_groups - 1:
            gid += 1
    refined = partition(bert_graph, n_groups)
    assert cut_bytes(bert_graph, refined) <= cut_bytes(bert_graph, naive)
