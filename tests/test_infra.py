"""Optimizer, schedules, data pipeline, checkpointing, profiler
regressions."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.core.profiler import (
    LinearBatchModel, SegmentedLinear, allreduce_time, profile_matmul_batches)
from repro.data import SyntheticDataset
from repro.optim.adam import AdamW, clip_by_global_norm, global_norm
from repro.optim.schedule import cosine_schedule, linear_warmup


def test_adamw_first_step_matches_reference():
    opt = AdamW(lr=1e-2, weight_decay=0.0)
    params = {"w": jnp.asarray([1.0, -2.0, 3.0])}
    grads = {"w": jnp.asarray([0.1, -0.2, 0.3])}
    state = opt.init(params)
    newp, _ = opt.update(params, state, grads, 0)
    # bias-corrected first Adam step == -lr * sign-ish g/|g|
    expected = params["w"] - 1e-2 * grads["w"] / (
        jnp.abs(grads["w"]) + 1e-8)
    np.testing.assert_allclose(np.asarray(newp["w"]),
                               np.asarray(expected), rtol=1e-4)


def test_adamw_convergence_quadratic():
    opt = AdamW(lr=0.05, weight_decay=0.0)
    params = {"w": jnp.asarray([5.0, -3.0])}
    state = opt.init(params)
    for step in range(200):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(params, state, grads, step)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.1


def test_weight_decay_only_on_matrices():
    opt = AdamW(lr=1e-2, weight_decay=0.5)
    params = {"w": jnp.ones((2, 2)), "b": jnp.ones((2,))}
    grads = jax.tree.map(jnp.zeros_like, params)
    state = opt.init(params)
    newp, _ = opt.update(params, state, grads, 0)
    assert float(jnp.max(jnp.abs(newp["w"] - 1.0))) > 1e-4  # decayed
    np.testing.assert_allclose(np.asarray(newp["b"]), 1.0)  # exempt


def test_clip_by_global_norm():
    g = {"a": jnp.asarray([3.0, 4.0])}
    clipped, n = clip_by_global_norm(g, 1.0)
    assert abs(float(n) - 5.0) < 1e-6
    assert abs(float(global_norm(clipped)) - 1.0) < 1e-5


def test_schedules():
    assert float(linear_warmup(0, 100, 1.0)) < 0.02
    assert abs(float(cosine_schedule(100, 100, 1000, 1.0)) - 1.0) < 0.01
    end = float(cosine_schedule(1000, 100, 1000, 1.0))
    assert end < 0.2


def test_synthetic_data_deterministic_and_learnable():
    ds = SyntheticDataset(vocab_size=64, seq_len=32, batch_size=4, seed=7)
    b1, b2 = ds.batch(3), ds.batch(3)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    # bigram structure: successor prediction accuracy well above chance
    toks, labels = b1["tokens"], b1["labels"]
    hits = (ds._succ[toks] == labels).mean()
    assert hits > 0.5


def test_checkpoint_roundtrip_bf16(tmp_path):
    tree = {"a": {"w": jnp.asarray([[1.5, -2.25]], jnp.bfloat16)},
            "step": jnp.asarray(7, jnp.int32)}
    d = str(tmp_path / "ckpt")
    save_checkpoint(d, 3, tree)
    assert latest_step(d) == 3
    step, loaded = load_checkpoint(d)
    assert step == 3
    assert loaded["a"]["w"].dtype == jnp.bfloat16
    np.testing.assert_array_equal(np.asarray(loaded["a"]["w"], np.float32),
                                  np.asarray(tree["a"]["w"], np.float32))


def test_linear_batch_model_fit():
    m = LinearBatchModel.fit([1, 2, 4, 8], [1.1, 2.0, 4.2, 8.1])
    assert abs(m(16) - 16.2) < 1.5


def test_measured_matmul_time_linear_in_batch():
    """Paper §4.1.2: op time ~ linear in batch size (measured on host)."""
    batches = [8, 16, 32, 64]
    m = profile_matmul_batches(batches, dim=128)
    pred = m(128)
    meas = profile_matmul_batches([128], dim=128)(128)
    assert 0.2 * meas < pred < 5 * meas   # loose: CPU timing noise


def test_segmented_linear_interpolates():
    s = SegmentedLinear.fit([1e3, 1e6, 1e9], [1e-5, 1e-3, 1.0])
    assert 1e-5 <= s(1e4) <= 1e-3
    assert s(2e9) > 1.0


def test_allreduce_ring_formula():
    t2 = allreduce_time(1e9, 2, 1e9, 0)
    t8 = allreduce_time(1e9, 8, 1e9, 0)
    assert abs(t2 - 1.0) < 1e-6            # 2*(1/2)*1e9/1e9
    assert abs(t8 - 2 * 7 / 8) < 1e-6
