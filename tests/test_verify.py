"""Static plan verifier tests (repro.verify).

Clean baselines for every schedule family, the mutation self-test's
100%-catch soundness gate (ISSUE acceptance criterion), per-mutation
diagnostic-code contracts, the device-free engine/launcher preflight,
the ``repro-plan verify --selftest`` CLI gate, and the PlannerService
caching policy: an error-carrying plan is never cached, ``reject`` mode
raises, ``warn`` mode attaches the verdict to the response and the
stored record.
"""
import json
import os
import subprocess
import sys

import pytest

from repro.core.device import testbed as make_testbed
from repro.core.graph import CompGraph, OpNode, group_graph
from repro.exec.schedule import SCHEDULES, make_schedule
from repro.service.planner import PlannerService
from repro.verify import (
    CODES, MUTATIONS, PlanVerificationError, Report, Severity,
    make_context, run_selftest, verify_preflight, verify_schedule)
from repro.verify.mutate import verify_context

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _chain_gg(n_ops: int = 12, n_groups: int = 6):
    g = CompGraph(name="chain")
    for i in range(n_ops):
        g.add_node(OpNode(i, f"op{i}", "dot_general",
                          flops=1e9 * (1 + i % 3), bytes_out=1e6,
                          param_bytes=4e5, grad_bytes=4e5,
                          is_grad_producer=True))
        if i:
            g.add_edge(i - 1, i, 1e6)
    assign = {i: i * n_groups // n_ops for i in range(n_ops)}
    return group_graph(g, assign)


@pytest.fixture(scope="module")
def gg():
    return _chain_gg()


@pytest.fixture(scope="module")
def topo():
    return make_testbed()


# ------------------------------------------------------- clean baselines

@pytest.mark.parametrize("sched", SCHEDULES)
def test_clean_baseline_verifies_clean(sched):
    """The synthetic self-test deployment must produce a clean verdict
    under the full four-analysis pass, for every schedule family."""
    rep = verify_context(make_context(sched))
    assert rep.verdict == "clean", rep.format()


@pytest.mark.parametrize("n_stages,n_micro",
                         [(2, 4), (3, 6), (4, 8), (6, 12)])
@pytest.mark.parametrize("sched", SCHEDULES)
def test_generated_schedules_verify_clean(sched, n_stages, n_micro):
    """Every schedule the generators emit passes happens-before
    verification with zero diagnostics."""
    V = 2 if sched == "interleaved" else 1
    order = make_schedule(sched, n_stages, n_micro, n_chunks=V)
    rep = verify_schedule(order, n_stages, n_micro, n_chunks=V)
    assert rep.ok, rep.format()
    assert not rep.diagnostics


def test_memory_proof_is_engine_aware():
    """The memory prover accounts per engine: the eager engine follows
    the schedule's peak stash (1F1B drains as it goes) while the scan
    engine stashes all n_micro inputs per hosted chunk plus an
    n_micro-deep boundary double-buffer — so a plan can prove clean for
    eager and OOM for scan on the same devices."""
    from repro.exec.schedule import peak_stash
    from repro.exec.stages import StagePlan, StageSpec
    from repro.verify import verify_stage_plan
    from repro.verify.memory import analyze_memory, engine_peak_stash

    topo = make_testbed()
    M = 8
    # two 1080Ti pairs: 2 x 11 GB = 22 GB per stage. act/mb = 2 GB:
    # eager 1F1B stashes [2, 1] mbs -> fits; scan stashes M*V + M = 16
    # -> 32 GB -> OOM.
    plan = StagePlan(
        stages=[StageSpec(i, 1 + i, [i], flops=1e9, param_bytes=1e5,
                          grad_bytes=1e5, out_bytes=16e9,
                          n_devices=2, gpu_type="1080Ti")
                for i in range(2)],
        placement=(1, 2), n_micro=M)
    order = make_schedule("1f1b", 2, M)

    assert engine_peak_stash(order, M, "eager") == peak_stash(order)
    assert engine_peak_stash(order, M, "scan") == [M + M, M + M]
    with pytest.raises(ValueError, match="engine"):
        engine_peak_stash(order, M, "tpu")

    assert analyze_memory(plan, topo, order, M).ok
    rep = analyze_memory(plan, topo, order, M, engine="scan")
    assert not rep.ok
    assert {d.code for d in rep.errors()} == {"TAG201"}

    # threads through the orchestrator entry point too
    assert verify_stage_plan(plan, topo, schedule="1f1b").ok
    assert not verify_stage_plan(plan, topo, schedule="1f1b",
                                 engine="scan").ok

    # interleaved chunks multiply the scan stash: M * V + M
    order_v = make_schedule("interleaved", 2, M, n_chunks=2)
    assert engine_peak_stash(order_v, M, "scan") == [M * 2 + M] * 2


# --------------------------------------------------- mutation self-test

def test_selftest_catches_every_injected_violation():
    """Acceptance criterion: the verifier catches 100% of the mutator's
    injected violations across all four schedule families."""
    res = run_selftest()
    assert res["clean_baselines_ok"] is True
    assert res["missed"] == []
    assert res["caught"] == res["mutations_run"] >= 40
    assert res["ok"] is True


@pytest.mark.parametrize("mut", MUTATIONS, ids=lambda m: m.name)
def test_mutation_flags_its_designated_codes(mut):
    """Each mutation is caught with exactly the codes it designates, on
    every schedule family it applies to."""
    applied = 0
    for sched in SCHEDULES:
        ctx = make_context(sched)
        if not mut.apply(ctx):
            continue
        applied += 1
        rep = verify_context(ctx)
        assert rep.has(*mut.expect), \
            (sched, mut.name, sorted(rep.codes()))
        assert not rep.ok
    assert applied > 0


def test_mutation_expected_codes_are_error_severity():
    """Mutations inject unsound deployments, so every designated code
    must carry error severity in the frozen code table."""
    for mut in MUTATIONS:
        for code in mut.expect:
            assert CODES[code][0] is Severity.ERROR, (mut.name, code)


# ------------------------------------------------------ diagnostics API

def test_report_api_and_verification_error():
    rep = Report()
    assert rep.ok and rep.verdict == "clean"
    rep.add("TAG202", "pressure", stage=1)
    assert rep.ok and rep.verdict == "warn"
    d = rep.add("TAG201", "over budget", stage=1, mb=3)
    assert d.severity is Severity.ERROR
    assert not rep.ok and rep.verdict == "error"
    assert rep.has("TAG201", "TAG202") and not rep.has("TAG101")
    s = rep.summary()
    assert (s["errors"], s["warnings"], s["infos"]) == (1, 1, 0)
    assert s["codes"] == ["TAG201", "TAG202"]
    assert "TAG201" in rep.format() and "stage 1" in rep.format()
    err = PlanVerificationError(rep, context="unit test")
    assert "unit test" in str(err) and "TAG201" in str(err)
    assert err.report is rep


# ------------------------------------------------------------ preflight

def test_preflight_clean_then_corrupt_schedule():
    ctx = make_context("1f1b")
    rep = verify_preflight(ctx.plan, ctx.order, ctx.n_micro,
                           n_chunks=ctx.n_chunks,
                           device_counts=[2, 2, 2, 2])
    assert rep.ok, rep.format()
    # drop one backward: coverage hole + unmatched boundary traffic
    evs = ctx.order[2]
    del evs[next(i for i, e in enumerate(evs) if e.kind == "B")]
    rep2 = verify_preflight(ctx.plan, ctx.order, ctx.n_micro,
                            n_chunks=ctx.n_chunks)
    assert not rep2.ok
    assert rep2.has("TAG104")


def test_preflight_device_counts_override_plan():
    """The engine passes the device-set sizes the run will actually
    use; they override the plan's recorded counts."""
    ctx = make_context("1f1b")
    ctx.plan.stages[0].sync = "sfb"
    rep = verify_preflight(ctx.plan, ctx.order, ctx.n_micro,
                           device_counts=[1, 2, 2, 2])
    assert rep.has("TAG302")          # SFB cannot run on one device
    rep2 = verify_preflight(ctx.plan, ctx.order, ctx.n_micro,
                            device_counts=[4, 2, 2, 2])
    assert rep2.ok, rep2.format()


# ------------------------------------------- planner service integration

def _error_report():
    rep = Report()
    rep.add("TAG201", "injected by test: plan must not be cached")
    return rep


def test_planner_never_caches_error_plan(gg, topo, monkeypatch):
    """Acceptance criterion: PlannerService refuses to cache a plan
    carrying an error-severity diagnostic (even in warn mode)."""
    import repro.service.planner as planner_mod
    monkeypatch.setattr(planner_mod, "verify_deployment",
                        lambda *a, **k: _error_report())
    svc = PlannerService(verify="warn")
    resp = svc.plan_graph(gg, topo, iterations=4)
    assert resp.verify["verdict"] == "error"
    assert "TAG201" in resp.verify["codes"]
    assert len(svc.store) == 0            # never cached
    st = svc.stats()
    assert st["verify_error"] == 1 and st["verify_clean"] == 0


def test_planner_reject_mode_raises(gg, topo, monkeypatch):
    import repro.service.planner as planner_mod
    monkeypatch.setattr(planner_mod, "verify_deployment",
                        lambda *a, **k: _error_report())
    svc = PlannerService(verify="reject")
    with pytest.raises(PlanVerificationError) as ei:
        svc.plan_graph(gg, topo, iterations=4)
    assert "TAG201" in str(ei.value)
    assert len(svc.store) == 0


def test_planner_warn_mode_caches_clean_plan_with_verdict(gg, topo):
    """Acceptance criterion: the plan the current search produces for a
    real topology verifies with zero errors, gets cached with its
    verdict in PlanRecord.meta, and a cache hit replays the verdict."""
    svc = PlannerService(verify="warn")
    resp = svc.plan_graph(gg, topo, iterations=8)
    assert resp.verify is not None
    assert resp.verify["errors"] == 0
    assert resp.verify["verdict"] in ("clean", "warn")
    assert len(svc.store) == 1
    rec = svc.store.get(resp.graph_fp, resp.topo_fp)
    assert rec.meta["verify"] == resp.verify
    resp2 = svc.plan_graph(gg, topo, iterations=8)
    assert resp2.source == "hit"
    assert resp2.verify == resp.verify
    st = svc.stats()
    assert st["verify_clean"] + st["verify_warn"] == 1   # hit skips verify
    assert "planner_verify_total" in svc.metrics.to_prometheus()
    assert "planner_verify_seconds" in svc.metrics.to_prometheus()


def test_planner_verify_off_skips_verification(gg, topo):
    svc = PlannerService(verify="off")
    resp = svc.plan_graph(gg, topo, iterations=4)
    assert resp.verify is None
    assert len(svc.store) == 1            # off: cached without a verdict
    assert svc.stats()["verify_clean"] == 0


def test_planner_rejects_bad_verify_mode():
    with pytest.raises(ValueError):
        PlannerService(verify="strict")


# ------------------------------------------------------------------ CLI

def test_cli_verify_selftest_gate():
    """``repro-plan verify --selftest`` is the CI soundness gate: exit 0
    with ok=true JSON."""
    out = subprocess.run(
        [sys.executable, "-m", "repro.service.cli", "verify",
         "--selftest"],
        capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC), timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    res = json.loads(out.stdout)
    assert res["ok"] is True and res["missed"] == []
