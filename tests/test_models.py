"""Model-layer unit tests: decode-vs-full-forward consistency, rope
relativity, MoE routing invariants, SSM decode equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_reduced
from repro.models import decode_step, forward, init_cache, init_params
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import cross_entropy, rms_norm, rotary
from repro.models.layers import init_tree
from repro.models.model import _head

RNG = np.random.default_rng(1)


def test_rms_norm_scale_invariance_of_direction():
    x = jnp.asarray(RNG.standard_normal((2, 8)), jnp.float32)
    g = jnp.ones((8,), jnp.float32)
    a = rms_norm(x, g, 1e-6)
    b = rms_norm(3.0 * x, g, 1e-6)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-4)
    np.testing.assert_allclose(
        np.asarray(jnp.mean(a * a, -1)), 1.0, atol=1e-3)


def test_rotary_relative_property():
    """<rope(q, i), rope(k, j)> depends only on i - j."""
    hd = 32
    q = jnp.asarray(RNG.standard_normal((1, 1, hd)), jnp.float32)
    k = jnp.asarray(RNG.standard_normal((1, 1, hd)), jnp.float32)

    def dot_at(i, j):
        qr = rotary(q[None], jnp.asarray([i]), 1e4)[0]
        kr = rotary(k[None], jnp.asarray([j]), 1e4)[0]
        return float(jnp.sum(qr * kr))

    assert abs(dot_at(5, 3) - dot_at(105, 103)) < 1e-3
    assert abs(dot_at(7, 0) - dot_at(17, 10)) < 1e-3


def test_cross_entropy_uniform_logits():
    V = 128
    logits = jnp.zeros((2, 3, V))
    labels = jnp.zeros((2, 3), jnp.int32)
    assert abs(float(cross_entropy(logits, labels)) - np.log(V)) < 1e-4


@pytest.mark.parametrize("arch", ["qwen2-1.5b", "mamba2-130m",
                                  "jamba-v0.1-52b", "olmoe-1b-7b"])
def test_decode_matches_full_forward(arch):
    """Greedy decode logits at position t must match the full-sequence
    forward logits at position t (KV-cache / SSM-state correctness)."""
    cfg = get_reduced(arch).replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 2, 8
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.frontend != "none":
        pytest.skip("frontend archs prepend a prefix; covered elsewhere")
    h, _, _ = forward(cfg, params, batch, remat=False)
    full_logits = _head(cfg, params, h)

    cache = init_cache(cfg, B, S + 1)
    outs = []
    for t in range(S):
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1], t)
        outs.append(logits[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(full_logits),
                               atol=2e-2)


def test_sliding_window_decode_ring_buffer():
    cfg = get_reduced("qwen2-1.5b").replace(dtype="float32",
                                            sliding_window=4)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B, S = 1, 10
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0,
                              cfg.vocab_size)
    cache = init_cache(cfg, B, S)   # ring buffer bounded at window
    assert cache["layer0"]["mixer"] if False else True
    kv_len = jax.tree.leaves(cache)[0].shape[2]
    assert kv_len == cfg.sliding_window
    for t in range(S):
        logits, cache = decode_step(cfg, params, cache, toks[:, t:t + 1], t)
        assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())


def test_moe_capacity_and_gate_normalization():
    cfg = get_reduced("olmoe-1b-7b")
    p = init_tree(moe_mod.moe_defs(cfg), jax.random.PRNGKey(2), jnp.float32)
    x = jnp.asarray(RNG.standard_normal((2, 16, cfg.d_model)), jnp.float32)
    y, aux = moe_mod.moe_fwd(cfg, p, x)
    assert y.shape == x.shape
    assert float(aux) >= 1.0 - 1e-3   # Switch aux loss lower bound is 1
    assert bool(jnp.isfinite(y).all())


def test_moe_identical_tokens_capacity_drop():
    """All-identical tokens overflow one expert's capacity; output must
    stay finite and dropped tokens contribute zero."""
    cfg = get_reduced("olmoe-1b-7b")
    p = init_tree(moe_mod.moe_defs(cfg), jax.random.PRNGKey(2), jnp.float32)
    x = jnp.ones((1, 64, cfg.d_model), jnp.float32) * 0.3
    y, _ = moe_mod.moe_fwd(cfg, p, x)
    assert bool(jnp.isfinite(y).all())


def test_mamba_decode_matches_scan():
    cfg = get_reduced("mamba2-130m").replace(dtype="float32")
    p = init_tree(ssm_mod.ssm_defs(cfg), jax.random.PRNGKey(3), jnp.float32)
    B, S = 2, 12
    u = jnp.asarray(RNG.standard_normal((B, S, cfg.d_model)) * 0.3,
                    jnp.float32)
    y_full, _ = ssm_mod.mamba_fwd(cfg, p, u)
    cache = ssm_mod.init_ssm_cache(cfg, B, jnp.float32)
    outs = []
    for t in range(S):
        y_t, cache = ssm_mod.mamba_decode(cfg, p, u[:, t:t + 1], cache)
        outs.append(y_t[:, 0])
    y_dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_dec), np.asarray(y_full),
                               atol=3e-3)
