"""Pipeline execution engine tests (repro.exec).

Fast in-process coverage of the stage partitioner, the schedule event
lists and their invariants, and the predicted-vs-executed timeline
agreement; subprocess tests (forced 4-device CPU) prove loss/gradient
parity of the REAL pipelined train step against the single-device
reference across GPipe and 1F1B, and across the per-stage AR/PS/SFB
gradient-sync modes.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.device import testbed as make_testbed
from repro.core.graph import CompGraph, OpNode, group_graph
from repro.core.strategy import Action, Option, Strategy
from repro.exec import (
    build_stage_plan, execute_pipeline, flatten_schedule, make_schedule,
    max_feasible_micro, peak_stash, simulate_schedule, validate_schedule)
from repro.exec.stages import PipelineInfeasible, StagePlan, StageSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _chain_gg(n_ops: int = 12, n_groups: int = 6):
    g = CompGraph(name="chain")
    for i in range(n_ops):
        g.add_node(OpNode(i, f"op{i}", "dot_general",
                          flops=1e9 * (1 + i % 3), bytes_out=1e6,
                          param_bytes=4e5, grad_bytes=4e5,
                          is_grad_producer=True))
        if i:
            g.add_edge(i - 1, i, 1e6)
    assign = {i: i * n_groups // n_ops for i in range(n_ops)}
    return group_graph(g, assign)


def _pipe_strategy(gg, placement, sync_opt=Option.PS):
    return Strategy([
        Action(placement, Option.PIPE) if i % 2 == 0
        else Action(placement, sync_opt) for i in range(gg.n)])


# ------------------------------------------------------ stage partitioner

def test_stage_plan_cuts_at_pipe_boundaries():
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    assert plan is not None and plan.n_stages == 3
    assert plan.placement == (0, 1, 5)          # partial placement kept
    # every group on exactly one stage, contiguous topological spans
    seen = [g for s in plan.stages for g in s.op_group_ids]
    assert sorted(seen) == list(range(gg.n))
    flat = [g for s in plan.stages for g in sorted(s.op_group_ids)]
    assert flat == sorted(flat)
    # the ILP's sync decisions reach the stages (stage 1 holds only the
    # PIPE-action group, which casts no sync vote -> allreduce default)
    assert [s.sync for s in plan.stages] == ["ps", "allreduce", "ps"]
    assert [s.gpu_type for s in plan.stages] == ["V100", "1080Ti", "P100"]
    # V100 group (4 fast GPUs) gets the largest flops share
    assert plan.stages[0].flops == max(s.flops for s in plan.stages)


def test_stage_plan_none_without_multi_group_pipe():
    gg = _chain_gg()
    topo = make_testbed()
    dp = Strategy([Action((0, 1), Option.AR)] * gg.n)
    assert build_stage_plan(gg, dp, topo) is None
    single = Strategy([Action((0,), Option.PIPE)] * gg.n)
    assert build_stage_plan(gg, single, topo) is None
    assert not dp.has_pipeline() and not single.has_pipeline()


def test_stage_plan_device_assignment_infeasible():
    gg = _chain_gg()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), make_testbed())
    sets = plan.assign_local_devices(list(range(8)))
    assert len(sets) == 3 and sum(len(s) for s in sets) == 8
    assert all(len(s) >= 1 for s in sets)
    with pytest.raises(PipelineInfeasible):
        plan.assign_local_devices([0, 1])       # 2 devices < 3 stages


def test_stage_plan_roundtrip():
    gg = _chain_gg()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1)), make_testbed())
    plan2 = StagePlan.from_dict(plan.to_dict())
    assert plan2.placement == plan.placement
    assert [s.to_dict() for s in plan2.stages] == \
        [s.to_dict() for s in plan.stages]


# ------------------------------------------------------------- schedules

@pytest.mark.parametrize("name", ["gpipe", "1f1b"])
@pytest.mark.parametrize("S,M", [(2, 4), (3, 5), (4, 2), (4, 8)])
def test_schedules_validate(name, S, M):
    order = make_schedule(name, S, M)
    validate_schedule(order, S, M)
    flat = flatten_schedule(order, S, M)
    assert len(flat) == 2 * S * M


def test_schedule_stash_bounds():
    S, M = 4, 8
    assert peak_stash(make_schedule("gpipe", S, M)) == [M] * S
    assert peak_stash(make_schedule("1f1b", S, M)) == \
        [min(S - s, M) for s in range(S)]


def test_memory_capped_microbatching_favors_1f1b():
    """GPipe stashes every microbatch; under a fixed per-stage activation
    budget 1F1B sustains strictly deeper microbatching."""
    gg = _chain_gg()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), make_testbed())
    kw = dict(mb_act_bytes=1e6, mem_budget=6e6)
    m_gpipe = max_feasible_micro(plan, "gpipe", **kw)
    m_1f1b = max_feasible_micro(plan, "1f1b", **kw)
    assert m_gpipe == 6
    assert m_1f1b > m_gpipe


def test_timeline_respects_dependencies():
    """No stage executes a microbatch before its predecessor produced it
    (and backwards mirror it); per-stage execution never overlaps."""
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    for name in ("gpipe", "1f1b"):
        order = make_schedule(name, plan.n_stages, plan.n_micro)
        tl = simulate_schedule(plan, topo, order)
        for m in range(plan.n_micro):
            for s in range(1, plan.n_stages):
                assert tl.finish_of("F", s, m) > tl.finish_of("F", s - 1, m)
            for s in range(plan.n_stages - 1):
                assert tl.finish_of("B", s, m) > tl.finish_of("B", s + 1, m)
        per_stage = {}
        for e in tl.events:
            if e.kind in ("F", "B"):
                per_stage.setdefault(e.stage, []).append((e.start, e.finish))
        for evs in per_stage.values():
            evs.sort()
            for (s0, f0), (s1, f1) in zip(evs, evs[1:]):
                assert s1 >= f0 - 1e-12          # serial per stage
        assert 0.0 < tl.bubble_fraction() < 1.0


def test_bubble_decreases_with_microbatching():
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    bubbles = []
    for m in (2, 8):
        plan.n_micro = m
        tl = simulate_schedule(plan, topo, make_schedule(
            "1f1b", plan.n_stages, m))
        bubbles.append(tl.bubble_fraction())
    assert bubbles[1] < bubbles[0]


# -------------------------------------------- replay + simulator agreement

def test_replay_matches_predicted_timeline():
    """The plan->execution cross-check: the predicted schedule timeline
    and the replay-executed one agree event-for-event at zero noise."""
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    for name in ("gpipe", "1f1b"):
        rec, executed = execute_pipeline(plan, topo, schedule=name)
        predicted = simulate_schedule(
            plan, topo, make_schedule(name, plan.n_stages, plan.n_micro))
        assert abs(executed.makespan - predicted.makespan) < 1e-12
        assert len(executed.events) == len(predicted.events)
        for a, b in zip(executed.events, predicted.events):
            assert (a.kind, a.stage, a.mb) == (b.kind, b.stage, b.mb)
            assert abs(a.start - b.start) < 1e-12
            assert abs(a.finish - b.finish) < 1e-12
        assert rec.meta["bubble_frac"] == pytest.approx(
            predicted.bubble_fraction())


def test_replay_telemetry_samples():
    from repro.runtime.telemetry import MeasurementStore
    from repro.runtime.calibration import fit_profile
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    store = MeasurementStore()
    for step in range(6):
        execute_pipeline(plan, topo, schedule="1f1b", step=step,
                         store=store, graph_fp="g1", topo_fp="t1")
    recs = store.records(graph_fp="g1")
    assert len(recs) == 6
    assert all(c.get("pair") for r in recs for c in r.collectives)
    prof = fit_profile(recs, topo, min_pair_samples=4)
    assert prof.pairs, "per-pair tier should fit the boundary links"
    t2 = prof.apply(topo)
    assert t2.pair_eff                          # feeds Topology.bw()


# -------------------------------------------------- real execution parity

def test_pipeline_parity_vs_single_device():
    """A >= 2-stage strategy executes end-to-end on a CPU mesh with loss
    and gradients allclose to the single-device reference under both
    GPipe and 1F1B, with per-stage telemetry recorded."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import init_params, loss_fn
        from repro.exec import PipelineRunner, split_model
        from repro.exec.stages import StagePlan, StageSpec
        from repro.runtime.telemetry import MeasurementStore

        cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        ref_loss, _ = jax.jit(
            lambda p, b: loss_fn(cfg, p, b, remat=False))(params, batch)
        ref_grads = jax.jit(jax.grad(
            lambda p, b: loss_fn(cfg, p, b, remat=False)[0]))(params, batch)

        def maxerr(a, b):
            return max(float(jnp.max(jnp.abs(x - y))) for x, y in
                       zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        devs = jax.devices()
        hi = cfg.num_periods // 2
        for sched in ("gpipe", "1f1b"):
            plan = StagePlan(
                stages=[StageSpec(i, i, [i], flops=1e9, param_bytes=0,
                                  grad_bytes=0, out_bytes=1e5,
                                  n_devices=1, gpu_type="V100")
                        for i in range(2)],
                placement=(0, 1), n_micro=4)
            store = MeasurementStore()
            sp, fns, keys, tied = split_model(cfg, params, 2)
            runner = PipelineRunner(
                fns, plan, [[devs[0]], [devs[1]]], schedule=sched,
                n_micro=4, mb_keys=keys, tied_ref=tied, store=store)
            grads, stats = runner.step(runner.place_params(sp), batch,
                                       record=True)
            assert abs(stats.loss - float(ref_loss)) < 1e-4, sched
            errs = [
                maxerr(grads[0]["embed"], ref_grads["embed"]),
                maxerr(grads[0]["blocks"], jax.tree.map(
                    lambda a: a[:hi], ref_grads["blocks"])),
                maxerr(grads[1]["blocks"], jax.tree.map(
                    lambda a: a[hi:], ref_grads["blocks"])),
                maxerr(grads[1]["final_norm"], ref_grads["final_norm"]),
            ]
            assert max(errs) < 1e-4, (sched, errs)
            rec = store.records()[-1]
            assert rec.meta["schedule"] == sched
            stages = {(c["stage"], c["kind"]) for c in rec.compute}
            assert {(0, "F"), (0, "B"), (1, "F"), (1, "B")} <= stages
            # GPipe stashes every microbatch; 1F1B drains as it goes
            assert stats.peak_stash == (8 if sched == "gpipe" else 3)
        print("PARITY_OK")
    """)
    assert "PARITY_OK" in out


def test_pipeline_stage_dp_sync_modes():
    """Per-stage data parallelism: each stage spans 2 devices and syncs
    its parameter gradients via AR / PS / SFB — all allclose to the
    single-device reference (the §4.2.3 decisions on the real engine)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import init_params, loss_fn
        from repro.exec import PipelineRunner, split_model
        from repro.exec.stages import StagePlan, StageSpec

        cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        ref_grads = jax.jit(jax.grad(
            lambda p, b: loss_fn(cfg, p, b, remat=False)[0]))(params, batch)

        def maxerr(a, b):
            return max(float(jnp.max(jnp.abs(x - y))) for x, y in
                       zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        devs = jax.devices()
        hi = cfg.num_periods // 2
        for sync in ("allreduce", "ps", "sfb"):
            plan = StagePlan(
                stages=[StageSpec(i, i, [i], flops=1e9, param_bytes=0,
                                  grad_bytes=0, out_bytes=1e5, sync=sync,
                                  n_devices=2, gpu_type="V100")
                        for i in range(2)],
                placement=(0, 1), n_micro=2)
            sp, fns, keys, tied = split_model(cfg, params, 2)
            runner = PipelineRunner(
                fns, plan, [devs[:2], devs[2:]], schedule="1f1b",
                n_micro=2, mb_keys=keys, tied_ref=tied)
            grads, stats = runner.step(runner.place_params(sp), batch)
            errs = [
                maxerr(grads[0]["embed"], ref_grads["embed"]),
                maxerr(grads[0]["blocks"], jax.tree.map(
                    lambda a: a[:hi], ref_grads["blocks"])),
                maxerr(grads[1]["blocks"], jax.tree.map(
                    lambda a: a[hi:], ref_grads["blocks"])),
            ]
            assert max(errs) < 1e-4, (sync, errs)
        print("SYNC_OK")
    """)
    assert "SYNC_OK" in out


def test_single_stage_split_matches_reference():
    """Degenerate 1-stage split: the composed stage fn must apply the
    decoder blocks exactly once (regression: blocks ran twice)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.exec import split_model
    from repro.models import init_params, loss_fn

    cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}
    ref, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b, remat=False))(
        params, batch)
    sp, fns, keys, tied = split_model(cfg, params, 1)
    assert tied is None
    loss, _ = fns[0](sp[0], None, batch)
    assert abs(float(loss) - float(ref)) < 1e-5


# ------------------------------------------------------- launcher routing

def test_train_launcher_pipeline_fallback(capsys, monkeypatch):
    """--tag-search PIPE strategies are never silently degraded: on a
    too-small host the launcher logs an explicit fallback warning."""
    from repro.core.plan import ExecutionPlan
    from repro.launch import mesh as mesh_mod
    from repro.launch.train import resolve_pipeline
    # pin the visible device count (the suite may run under a forced
    # multi-device XLA_FLAGS)
    monkeypatch.setattr(
        mesh_mod, "stage_device_sets",
        lambda sp, devices=None: sp.assign_local_devices([object()]))
    plan = ExecutionPlan(
        rules=None, grad_sync={}, zero1=False,
        summary={"options": {"PIPE": 3}},
        stage_plan=StagePlan(
            stages=[StageSpec(i, i, [i], 1e9, 0, 0, 1e5)
                    for i in range(3)],
            placement=(0, 1, 2), n_micro=4))
    assert resolve_pipeline(plan, "auto") is None     # 1 CPU < 3 stages
    out = capsys.readouterr().out
    assert "WARNING" in out and "fallback" in out
    assert resolve_pipeline(plan, "off") is None
    out = capsys.readouterr().out
    assert "off" in out
    no_spine = ExecutionPlan(rules=None, grad_sync={}, zero1=False,
                             summary={"options": {"PIPE": 1}},
                             stage_plan=None)
    assert resolve_pipeline(no_spine, "auto") is None
    assert "single-mesh" in capsys.readouterr().out


def test_lower_strategy_attaches_stage_plan():
    from repro.core.plan import lower_strategy

    class _M:
        axis_names = ("data",)
        shape = {"data": 1}
    gg = _chain_gg()
    topo = make_testbed()
    plan = lower_strategy(_pipe_strategy(gg, (0, 1)), gg, topo, _M())
    assert plan.is_pipelined and plan.stage_plan.n_stages == 2
    assert plan.summary["n_stages"] == 2
    dp = Strategy([Action((0, 1), Option.AR)] * gg.n)
    plan2 = lower_strategy(dp, gg, topo, _M())
    assert not plan2.is_pipelined and plan2.summary["n_stages"] == 0
