"""Pipeline execution engine tests (repro.exec).

Fast in-process coverage of the stage partitioner, the schedule event
lists and their invariants, and the predicted-vs-executed timeline
agreement; subprocess tests (forced 4-device CPU) prove loss/gradient
parity of the REAL pipelined train step against the single-device
reference across GPipe and 1F1B, and across the per-stage AR/PS/SFB
gradient-sync modes.
"""
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.device import testbed as make_testbed
from repro.core.graph import CompGraph, OpNode, group_graph
from repro.core.strategy import Action, Option, Strategy
from repro.exec import (
    build_stage_plan, execute_pipeline, flatten_schedule, make_schedule,
    max_feasible_micro, peak_stash, simulate_schedule, validate_schedule)
from repro.exec.stages import PipelineInfeasible, StagePlan, StageSpec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _chain_gg(n_ops: int = 12, n_groups: int = 6):
    g = CompGraph(name="chain")
    for i in range(n_ops):
        g.add_node(OpNode(i, f"op{i}", "dot_general",
                          flops=1e9 * (1 + i % 3), bytes_out=1e6,
                          param_bytes=4e5, grad_bytes=4e5,
                          is_grad_producer=True))
        if i:
            g.add_edge(i - 1, i, 1e6)
    assign = {i: i * n_groups // n_ops for i in range(n_ops)}
    return group_graph(g, assign)


def _pipe_strategy(gg, placement, sync_opt=Option.PS):
    return Strategy([
        Action(placement, Option.PIPE) if i % 2 == 0
        else Action(placement, sync_opt) for i in range(gg.n)])


# ------------------------------------------------------ stage partitioner

def test_stage_plan_cuts_at_pipe_boundaries():
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    assert plan is not None and plan.n_stages == 3
    assert plan.placement == (0, 1, 5)          # partial placement kept
    # every group on exactly one stage, contiguous topological spans
    seen = [g for s in plan.stages for g in s.op_group_ids]
    assert sorted(seen) == list(range(gg.n))
    flat = [g for s in plan.stages for g in sorted(s.op_group_ids)]
    assert flat == sorted(flat)
    # the ILP's sync decisions reach the stages (stage 1 holds only the
    # PIPE-action group, which casts no sync vote -> allreduce default)
    assert [s.sync for s in plan.stages] == ["ps", "allreduce", "ps"]
    assert [s.gpu_type for s in plan.stages] == ["V100", "1080Ti", "P100"]
    # V100 group (4 fast GPUs) gets the largest flops share
    assert plan.stages[0].flops == max(s.flops for s in plan.stages)


def test_stage_plan_none_without_multi_group_pipe():
    gg = _chain_gg()
    topo = make_testbed()
    dp = Strategy([Action((0, 1), Option.AR)] * gg.n)
    assert build_stage_plan(gg, dp, topo) is None
    single = Strategy([Action((0,), Option.PIPE)] * gg.n)
    assert build_stage_plan(gg, single, topo) is None
    assert not dp.has_pipeline() and not single.has_pipeline()


def test_stage_plan_device_assignment_infeasible():
    gg = _chain_gg()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), make_testbed())
    sets = plan.assign_local_devices(list(range(8)))
    assert len(sets) == 3 and sum(len(s) for s in sets) == 8
    assert all(len(s) >= 1 for s in sets)
    with pytest.raises(PipelineInfeasible):
        plan.assign_local_devices([0, 1])       # 2 devices < 3 stages


def test_stage_plan_roundtrip():
    gg = _chain_gg()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1)), make_testbed())
    plan2 = StagePlan.from_dict(plan.to_dict())
    assert plan2.placement == plan.placement
    assert [s.to_dict() for s in plan2.stages] == \
        [s.to_dict() for s in plan.stages]


# ------------------------------------------------------------- schedules

@pytest.mark.parametrize("name", ["gpipe", "1f1b"])
@pytest.mark.parametrize("S,M", [(2, 4), (3, 5), (4, 2), (4, 8)])
def test_schedules_validate(name, S, M):
    order = make_schedule(name, S, M)
    validate_schedule(order, S, M)
    flat = flatten_schedule(order, S, M)
    assert len(flat) == 2 * S * M


@pytest.mark.parametrize("S,M,V", [(2, 2, 2), (2, 4, 2), (3, 6, 2),
                                   (4, 8, 2), (4, 8, 3), (6, 12, 2)])
def test_interleaved_validates(S, M, V):
    order = make_schedule("interleaved", S, M, n_chunks=V)
    validate_schedule(order, S, M)
    flat = flatten_schedule(order, S, M)
    assert len(flat) == 2 * S * M * V          # F+B per virtual microbatch
    # every stage hosts every chunk
    for evs in order:
        assert {e.chunk for e in evs} == set(range(V))


@pytest.mark.parametrize("S,M", [(2, 4), (3, 5), (4, 2), (4, 8), (6, 12)])
def test_zb_validates(S, M):
    order = make_schedule("zb", S, M)
    validate_schedule(order, S, M)
    flat = flatten_schedule(order, S, M)
    assert len(flat) == 3 * S * M              # F + B + W per microbatch


def test_interleaved_rejects_bad_micro():
    with pytest.raises(ValueError):
        make_schedule("interleaved", 4, 6)     # 6 % 4 != 0
    with pytest.raises(ValueError):
        make_schedule("interleaved", 2, 4, n_chunks=1)


def test_interleaved_chunk_ordering():
    """Forwards walk chunks 0..V-1 in microbatch groups of S; backwards
    walk them V-1..0 (the Megatron issue order)."""
    S, M, V = 3, 6, 2
    order = make_schedule("interleaved", S, M, n_chunks=V)
    for evs in order:
        fwd_chunks = [e.chunk for e in evs if e.kind == "F"]
        bwd_chunks = [e.chunk for e in evs if e.kind == "B"]
        # per microbatch group of S, the chunk id is constant and cycles
        groups_f = [fwd_chunks[i:i + S] for i in range(0, len(fwd_chunks), S)]
        assert all(len(set(g)) == 1 for g in groups_f)
        assert [g[0] for g in groups_f][:V] == list(range(V))
        groups_b = [bwd_chunks[i:i + S] for i in range(0, len(bwd_chunks), S)]
        assert all(len(set(g)) == 1 for g in groups_b)
        assert [g[0] for g in groups_b][:V] == list(range(V - 1, -1, -1))


def test_zb_w_after_b_and_stash():
    """W-after-B invariant, and zero-bubble keeps exactly 1F1B's
    activation stash (W releases the stash before the next F acquires)."""
    S, M = 4, 8
    order = make_schedule("zb", S, M)
    for evs in order:
        done_b = set()
        for e in evs:
            if e.kind == "B":
                done_b.add(e.mb)
            elif e.kind == "W":
                assert e.mb in done_b
    assert peak_stash(order) == peak_stash(make_schedule("1f1b", S, M))
    # a W issued before its B must be rejected
    from repro.exec.schedule import Event
    bad = [[Event("F", 0, 0), Event("W", 0, 0), Event("B", 0, 0)]]
    with pytest.raises(ValueError):
        validate_schedule(bad, 1, 1)


def test_schedule_stash_bounds():
    S, M = 4, 8
    assert peak_stash(make_schedule("gpipe", S, M)) == [M] * S
    assert peak_stash(make_schedule("1f1b", S, M)) == \
        [min(S - s, M) for s in range(S)]


def test_memory_capped_microbatching_favors_1f1b():
    """GPipe stashes every microbatch; under a fixed per-stage activation
    budget 1F1B sustains strictly deeper microbatching."""
    gg = _chain_gg()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), make_testbed())
    kw = dict(mb_act_bytes=1e6, mem_budget=6e6)
    m_gpipe = max_feasible_micro(plan, "gpipe", **kw)
    m_1f1b = max_feasible_micro(plan, "1f1b", **kw)
    assert m_gpipe == 6
    assert m_1f1b > m_gpipe


def test_timeline_respects_dependencies():
    """No stage executes a microbatch before its predecessor produced it
    (and backwards mirror it); per-stage execution never overlaps."""
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    for name in ("gpipe", "1f1b"):
        order = make_schedule(name, plan.n_stages, plan.n_micro)
        tl = simulate_schedule(plan, topo, order)
        for m in range(plan.n_micro):
            for s in range(1, plan.n_stages):
                assert tl.finish_of("F", s, m) > tl.finish_of("F", s - 1, m)
            for s in range(plan.n_stages - 1):
                assert tl.finish_of("B", s, m) > tl.finish_of("B", s + 1, m)
        per_stage = {}
        for e in tl.events:
            if e.kind in ("F", "B"):
                per_stage.setdefault(e.stage, []).append((e.start, e.finish))
        for evs in per_stage.values():
            evs.sort()
            for (_s0, f0), (s1, _f1) in zip(evs, evs[1:],
                                            strict=False):
                assert s1 >= f0 - 1e-12          # serial per stage
        assert 0.0 < tl.bubble_fraction() < 1.0


def _uniform_plan(S=4, M=8, out_bytes=0.0):
    """Hand-built equal-stage plan: compute-dominated when out_bytes=0."""
    return StagePlan(
        stages=[StageSpec(i, i % 3, [i], flops=4e9, param_bytes=1e5,
                          grad_bytes=1e5, out_bytes=out_bytes,
                          n_devices=1, gpu_type="V100")
                for i in range(S)],
        placement=tuple(i % 3 for i in range(S)), n_micro=M)


def test_interleaved_timeline_deps():
    """Virtual-stage dependency correctness: F(u) finishes after F(u-1)
    — including the chunk wrap from the last physical stage back to the
    first — and B(u) after B(u+1)."""
    topo = make_testbed()
    plan = _uniform_plan(S=3, M=6, out_bytes=1e6)
    V = 2
    order = make_schedule("interleaved", plan.n_stages, 6, n_chunks=V)
    tl = simulate_schedule(plan, topo, order)
    S = plan.n_stages
    for m in range(6):
        for u in range(1, S * V):
            assert tl.finish_of("F", u % S, m, u // S) > \
                tl.finish_of("F", (u - 1) % S, m, (u - 1) // S)
        for u in range(S * V - 1):
            assert tl.finish_of("B", u % S, m, u // S) > \
                tl.finish_of("B", (u + 1) % S, m, (u + 1) // S)


def test_zb_timeline_w_after_b():
    """On the timeline, W(s, m) runs after its B(s, m), and the B chain
    is NOT delayed by downstream W's (B(s, m) only needs B(s+1, m))."""
    topo = make_testbed()
    plan = _uniform_plan(S=4, M=8)
    tl = simulate_schedule(plan, topo, make_schedule("zb", 4, 8))
    for m in range(8):
        for s in range(4):
            assert tl.finish_of("W", s, m) > tl.finish_of("B", s, m)
        for s in range(3):
            assert tl.finish_of("B", s, m) > tl.finish_of("B", s + 1, m)


def test_new_schedules_beat_1f1b_bubble_when_compute_bound():
    """The headline property: on a compute-dominated pipeline, both the
    zero-bubble split and interleaved virtual stages strictly shrink the
    warm-up/drain bubble of plain 1F1B."""
    topo = make_testbed()
    plan = _uniform_plan(S=4, M=8)
    bubbles = {}
    for name in ("1f1b", "interleaved", "zb"):
        tl = simulate_schedule(plan, topo, make_schedule(name, 4, 8))
        bubbles[name] = tl.bubble_fraction()
    assert bubbles["zb"] < bubbles["1f1b"]
    assert bubbles["interleaved"] < bubbles["1f1b"]


def test_schedule_step_cost_memory_cap():
    """schedule_step_cost: depth is memory-capped per stage; parameter
    overflow is infeasible; interleaved only offers multiples of S."""
    from repro.exec import schedule_step_cost
    topo = make_testbed()
    plan = _uniform_plan(S=4, M=8, out_bytes=8e6)
    c1 = schedule_step_cost(plan, topo, "1f1b", global_micro=8)
    assert c1 is not None and c1["n_micro"] == 8 and c1["flushes"] == 1
    # a tight per-stage budget caps the depth and charges flushes
    act = [1e6] * 4
    c2 = schedule_step_cost(plan, topo, "gpipe", global_micro=8,
                            mb_act_bytes=act, mem_budget=[3e6] * 4)
    assert c2 is not None and c2["n_micro"] == 3 and c2["flushes"] == 3
    ci = schedule_step_cost(plan, topo, "interleaved", global_micro=8,
                            mb_act_bytes=act, mem_budget=[1e12] * 4)
    assert ci is not None and ci["n_micro"] % plan.n_stages == 0
    # parameters alone overflowing the group memory -> infeasible
    big = _uniform_plan(S=4, M=8)
    for st in big.stages:
        st.param_bytes = 1e13
    assert schedule_step_cost(big, topo, "1f1b", global_micro=8) is None


def test_mcts_schedule_aware_pipe_costing():
    """Schedule-aware MCTS costs pipelined strategies with the schedule
    timeline (memoized per partition+schedule) instead of the FIFO
    task-graph model, and ranks schedule variants differently."""
    from repro.core.mcts import MCTS
    from repro.exec import schedule_step_cost
    gg = _chain_gg()
    topo = make_testbed()
    strat = _pipe_strategy(gg, (0, 1, 5))
    m = MCTS(gg, topo, schedule_aware=True)
    r, res = m._evaluate(strat)
    assert len(m._pipe_cache) == 1
    plan = build_stage_plan(gg, strat, topo, n_micro=m.pipe_global_micro)
    cost = schedule_step_cost(plan, topo, plan.schedule,
                              global_micro=m.pipe_global_micro)
    assert r == pytest.approx(m.baseline_time / cost["step_time_s"])
    assert res is not None and res.makespan == \
        pytest.approx(cost["step_time_s"])
    # memoization: same partition+schedule -> no new entry
    m._evaluate(strat)
    assert len(m._pipe_cache) == 1
    # a different schedule choice lands in a different cache entry with a
    # different reward
    strat_zb = Strategy([
        Action(a.placement, a.option, schedule="zb")
        if a.option == Option.PIPE else a for a in strat.actions])
    r_zb, _ = m._evaluate(strat_zb)
    assert len(m._pipe_cache) == 2
    assert r_zb != pytest.approx(r)
    # the FIFO ablation ignores the pipeline timeline entirely
    m_fifo = MCTS(gg, topo, schedule_aware=False)
    r_fifo, _ = m_fifo._evaluate(strat)
    assert not m_fifo._pipe_cache
    assert r_fifo != pytest.approx(r)
    # a warm-seeded search tracks its best pipelined playout separately
    # from the overall winner (the seed must use candidate placements —
    # here the full spine — for the seed playout to apply)
    spine = tuple(range(topo.m))
    seed_strat = Strategy([
        Action(spine, Option.PIPE, schedule="zb") if i % 2 == 0
        else Action(spine, Option.PS) for i in range(gg.n)])
    sr = MCTS(gg, topo, schedule_aware=True,
              prior_strategy=seed_strat).search(6)
    assert sr.best_pipelined is not None
    assert sr.best_pipelined.has_pipeline()
    assert sr.best_pipelined_reward <= sr.best_reward + 1e-12
    # legacy prior (schedule="" PIPE, as stored by pre-schedule plans):
    # normalized to 1f1b so the warm seed still applies instead of
    # silently degrading to a cold search
    legacy = Strategy([
        Action(spine, Option.PIPE) if i % 2 == 0
        else Action(spine, Option.PS) for i in range(gg.n)])
    m_legacy = MCTS(gg, topo, schedule_aware=True, prior_strategy=legacy)
    assert all(a.schedule == "1f1b" for a in
               m_legacy.prior_strategy.actions
               if a.option == Option.PIPE)
    sr2 = m_legacy.search(3)
    assert sr2.best_pipelined is not None   # seed playout applied


def test_action_schedule_serialization():
    """PIPE actions carry a schedule; legacy dicts (no schedule key)
    still load, and legacy canonical JSON is byte-identical."""
    a = Action((0, 1), Option.PIPE, schedule="zb")
    assert Action.from_dict(a.to_dict()) == a
    legacy = {"placement": [0, 1], "option": "PIPE"}
    la = Action.from_dict(legacy)
    assert la.schedule == "" and la.to_dict() == legacy
    s = Strategy([a, la])
    assert Strategy.from_dict(s.to_dict()).actions == s.actions


def test_stage_plan_votes_schedule():
    gg = _chain_gg()
    topo = make_testbed()
    acts = []
    for i in range(gg.n):
        if i % 2 == 0:
            acts.append(Action((0, 1, 5), Option.PIPE, schedule="zb"))
        else:
            acts.append(Action((0, 1, 5), Option.PS))
    plan = build_stage_plan(gg, Strategy(acts), topo)
    assert plan.schedule == "zb"
    plan2 = StagePlan.from_dict(plan.to_dict())
    assert plan2.schedule == "zb"
    # legacy strategies (no schedule on PIPE) default to 1f1b
    legacy = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    assert legacy.schedule == "1f1b"


def test_bubble_decreases_with_microbatching():
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    bubbles = []
    for m in (2, 8):
        plan.n_micro = m
        tl = simulate_schedule(plan, topo, make_schedule(
            "1f1b", plan.n_stages, m))
        bubbles.append(tl.bubble_fraction())
    assert bubbles[1] < bubbles[0]


# ------------------------------------------------- overlap-aware timeline

def _xfer_link(plan, e):
    return (plan.stages[e.src].device_group,
            plan.stages[e.stage].device_group)


def test_overlap_modes_order_makespans():
    """The three overlap models order as expected on a transfer-heavy
    pipeline: "full" (streamed double-buffered boundaries) beats "link"
    (legacy: transfers overlap compute, serialize per link) beats
    "none" (eager-faithful: transfers block the destination row)."""
    topo = make_testbed()
    plan = _uniform_plan(S=3, M=6, out_bytes=5e8)
    order = make_schedule("gpipe", 3, 6)
    tls = {m: simulate_schedule(plan, topo, order, overlap=m)
           for m in ("none", "link", "full")}
    assert tls["full"].makespan < tls["link"].makespan
    assert tls["link"].makespan < tls["none"].makespan
    for m, tl in tls.items():
        assert tl.meta["overlap"] == m
    with pytest.raises(ValueError, match="overlap"):
        simulate_schedule(plan, topo, order, overlap="bogus")


def test_overlap_transfers_overlap_compute_on_distinct_resources():
    """Under "link"/"full" a boundary transfer may run while its
    destination stage computes something else (distinct resources);
    under "none" the destination row is occupied by the transfer."""
    topo = make_testbed()
    plan = _uniform_plan(S=3, M=6, out_bytes=5e8)
    order = make_schedule("gpipe", 3, 6)

    def overlaps(tl):
        comp = [e for e in tl.events if e.kind != "X"]
        n = 0
        for x in (e for e in tl.events if e.kind == "X"):
            for c in comp:
                if c.stage == x.stage and c.start < x.finish - 1e-15 \
                        and x.start < c.finish - 1e-15:
                    n += 1
        return n
    assert overlaps(simulate_schedule(plan, topo, order,
                                      overlap="link")) > 0
    assert overlaps(simulate_schedule(plan, topo, order,
                                      overlap="full")) > 0
    assert overlaps(simulate_schedule(plan, topo, order,
                                      overlap="none")) == 0


def test_overlap_shared_link_still_serializes():
    """Every overlap mode keeps transfers on the SAME directed link
    serialized — streaming amortizes latency, it does not parallelize
    the wire."""
    topo = make_testbed()
    plan = _uniform_plan(S=3, M=6, out_bytes=5e8)
    order = make_schedule("gpipe", 3, 6)
    for mode in ("none", "link", "full"):
        tl = simulate_schedule(plan, topo, order, overlap=mode)
        by_link: dict = {}
        for e in tl.events:
            if e.kind == "X":
                by_link.setdefault(_xfer_link(plan, e), []).append(e)
        assert by_link, "plan should cross device groups"
        for evs in by_link.values():
            evs.sort(key=lambda e: e.start)
            for a, b in zip(evs, evs[1:]):
                assert b.start >= a.finish - 1e-12, (mode, a, b)


def test_overlap_full_streams_latency():
    """"full" only drops the wire latency on back-to-back transfers:
    every streamed transfer is shorter than a cold one by exactly the
    topology latency, and the first transfer on each link stays cold."""
    topo = make_testbed()
    plan = _uniform_plan(S=3, M=6, out_bytes=5e8)
    order = make_schedule("gpipe", 3, 6)
    cold = {}
    for e in simulate_schedule(plan, topo, order,
                               overlap="link").events:
        if e.kind == "X":
            cold.setdefault((_xfer_link(plan, e), e.nbytes), e.dur)
    streamed = 0
    firsts: dict = {}
    for e in sorted((e for e in simulate_schedule(
            plan, topo, order, overlap="full").events if e.kind == "X"),
            key=lambda e: e.start):
        link = _xfer_link(plan, e)
        base = cold[(link, e.nbytes)]
        if link not in firsts:
            firsts[link] = e
            assert e.dur == pytest.approx(base)
        elif e.dur < base:
            assert e.dur == pytest.approx(base - topo.latency)
            streamed += 1
    assert streamed > 0


def test_schedule_step_cost_defaults_to_full_overlap():
    """The search-facing cost model prices pipelines under the scan
    engine's streaming overlap by default; the legacy model stays
    available via overlap="link" and is never cheaper."""
    from repro.exec import schedule_step_cost
    topo = make_testbed()
    plan = _uniform_plan(S=3, M=6, out_bytes=5e8)
    c_def = schedule_step_cost(plan, topo, "gpipe", global_micro=6)
    c_full = schedule_step_cost(plan, topo, "gpipe", global_micro=6,
                                overlap="full")
    c_link = schedule_step_cost(plan, topo, "gpipe", global_micro=6,
                                overlap="link")
    assert c_def["step_time_s"] == pytest.approx(c_full["step_time_s"])
    assert c_full["step_time_s"] < c_link["step_time_s"]
    assert c_def["timeline"].meta["overlap"] == "full"


# -------------------------------------------- replay + simulator agreement

@pytest.mark.parametrize("name", ["gpipe", "1f1b", "interleaved", "zb"])
def test_replay_matches_predicted_timeline(name):
    """The plan->execution cross-check: the predicted schedule timeline
    and the replay-executed one agree event-for-event at zero noise —
    for the interleaved and zero-bubble schedules too."""
    import copy
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    if name == "interleaved":               # needs n_micro % n_stages == 0
        plan = copy.deepcopy(plan)
        plan.n_micro = 2 * plan.n_stages
    rec, executed = execute_pipeline(plan, topo, schedule=name)
    predicted = simulate_schedule(
        plan, topo, make_schedule(name, plan.n_stages, plan.n_micro))
    assert abs(executed.makespan - predicted.makespan) < 1e-12
    assert len(executed.events) == len(predicted.events)
    for a, b in zip(executed.events, predicted.events, strict=True):
        assert (a.kind, a.stage, a.mb, a.chunk) == \
            (b.kind, b.stage, b.mb, b.chunk)
        assert abs(a.start - b.start) < 1e-12
        assert abs(a.finish - b.finish) < 1e-12
    assert rec.meta["bubble_frac"] == pytest.approx(
        predicted.bubble_fraction())


def test_replay_telemetry_samples():
    from repro.runtime.telemetry import MeasurementStore
    from repro.runtime.calibration import fit_profile
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    store = MeasurementStore()
    for step in range(6):
        execute_pipeline(plan, topo, schedule="1f1b", step=step,
                         store=store, graph_fp="g1", topo_fp="t1")
    recs = store.records(graph_fp="g1")
    assert len(recs) == 6
    assert all(c.get("pair") for r in recs for c in r.collectives)
    prof = fit_profile(recs, topo, min_pair_samples=4)
    assert prof.pairs, "per-pair tier should fit the boundary links"
    t2 = prof.apply(topo)
    assert t2.pair_eff                          # feeds Topology.bw()


# -------------------------------------------------- real execution parity

def test_pipeline_parity_vs_single_device():
    """A >= 2-stage strategy executes end-to-end on a CPU mesh with loss
    and gradients allclose to the single-device reference under both
    GPipe and 1F1B, with per-stage telemetry recorded."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import init_params, loss_fn
        from repro.exec import PipelineRunner, split_model
        from repro.exec.stages import StagePlan, StageSpec
        from repro.runtime.telemetry import MeasurementStore

        cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        ref_loss, _ = jax.jit(
            lambda p, b: loss_fn(cfg, p, b, remat=False))(params, batch)
        ref_grads = jax.jit(jax.grad(
            lambda p, b: loss_fn(cfg, p, b, remat=False)[0]))(params, batch)

        def maxerr(a, b):
            return max(float(jnp.max(jnp.abs(x - y))) for x, y in
                       zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        devs = jax.devices()
        hi = cfg.num_periods // 2
        for sched in ("gpipe", "1f1b"):
            plan = StagePlan(
                stages=[StageSpec(i, i, [i], flops=1e9, param_bytes=0,
                                  grad_bytes=0, out_bytes=1e5,
                                  n_devices=1, gpu_type="V100")
                        for i in range(2)],
                placement=(0, 1), n_micro=4)
            store = MeasurementStore()
            sp, fns, keys, tied = split_model(cfg, params, 2)
            runner = PipelineRunner(
                fns, plan, [[devs[0]], [devs[1]]], schedule=sched,
                n_micro=4, mb_keys=keys, tied_ref=tied, store=store)
            grads, stats = runner.step(runner.place_params(sp), batch,
                                       record=True)
            assert abs(stats.loss - float(ref_loss)) < 1e-4, sched
            errs = [
                maxerr(grads[0]["embed"], ref_grads["embed"]),
                maxerr(grads[0]["blocks"], jax.tree.map(
                    lambda a: a[:hi], ref_grads["blocks"])),
                maxerr(grads[1]["blocks"], jax.tree.map(
                    lambda a: a[hi:], ref_grads["blocks"])),
                maxerr(grads[1]["final_norm"], ref_grads["final_norm"]),
            ]
            assert max(errs) < 1e-4, (sched, errs)
            rec = store.records()[-1]
            assert rec.meta["schedule"] == sched
            stages = {(c["stage"], c["kind"]) for c in rec.compute}
            assert {(0, "F"), (0, "B"), (1, "F"), (1, "B")} <= stages
            # GPipe stashes every microbatch; 1F1B drains as it goes
            assert stats.peak_stash == (8 if sched == "gpipe" else 3)
        print("PARITY_OK")
    """)
    assert "PARITY_OK" in out


def test_pipeline_stage_dp_sync_modes():
    """Per-stage data parallelism: each stage spans 2 devices and syncs
    its parameter gradients via AR / PS / SFB — all allclose to the
    single-device reference (the §4.2.3 decisions on the real engine)."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import init_params, loss_fn
        from repro.exec import PipelineRunner, split_model
        from repro.exec.stages import StagePlan, StageSpec

        cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        ref_grads = jax.jit(jax.grad(
            lambda p, b: loss_fn(cfg, p, b, remat=False)[0]))(params, batch)

        def maxerr(a, b):
            return max(float(jnp.max(jnp.abs(x - y))) for x, y in
                       zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        devs = jax.devices()
        hi = cfg.num_periods // 2
        for sync in ("allreduce", "ps", "sfb"):
            plan = StagePlan(
                stages=[StageSpec(i, i, [i], flops=1e9, param_bytes=0,
                                  grad_bytes=0, out_bytes=1e5, sync=sync,
                                  n_devices=2, gpu_type="V100")
                        for i in range(2)],
                placement=(0, 1), n_micro=2)
            sp, fns, keys, tied = split_model(cfg, params, 2)
            runner = PipelineRunner(
                fns, plan, [devs[:2], devs[2:]], schedule="1f1b",
                n_micro=2, mb_keys=keys, tied_ref=tied)
            grads, stats = runner.step(runner.place_params(sp), batch)
            errs = [
                maxerr(grads[0]["embed"], ref_grads["embed"]),
                maxerr(grads[0]["blocks"], jax.tree.map(
                    lambda a: a[:hi], ref_grads["blocks"])),
                maxerr(grads[1]["blocks"], jax.tree.map(
                    lambda a: a[hi:], ref_grads["blocks"])),
            ]
            assert max(errs) < 1e-4, (sync, errs)
        print("SYNC_OK")
    """)
    assert "SYNC_OK" in out


def test_pipeline_parity_new_schedules():
    """Interleaved-1F1B (2 stages x 2 virtual chunks) and zero-bubble
    (split B/W backward) execute end-to-end with loss and gradients
    allclose to the single-device reference — including ZB under 2-way
    stage data parallelism with AR/PS/SFB sync."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import init_params, loss_fn
        from repro.exec import PipelineRunner, split_model
        from repro.exec.stages import StagePlan, StageSpec

        cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        ref_loss, _ = jax.jit(
            lambda p, b: loss_fn(cfg, p, b, remat=False))(params, batch)
        ref_grads = jax.jit(jax.grad(
            lambda p, b: loss_fn(cfg, p, b, remat=False)[0]))(params, batch)

        def maxerr(a, b):
            return max(float(jnp.max(jnp.abs(x - y))) for x, y in
                       zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        def plan2(n_micro, sync="allreduce", n_devices=1):
            return StagePlan(
                stages=[StageSpec(i, i, [i], flops=1e9, param_bytes=0,
                                  grad_bytes=0, out_bytes=1e5, sync=sync,
                                  n_devices=n_devices, gpu_type="V100")
                        for i in range(2)],
                placement=(0, 1), n_micro=n_micro)

        devs = jax.devices()
        P = cfg.num_periods

        # --- zero-bubble, single-device stages
        sp, fns, keys, tied = split_model(cfg, params, 2)
        runner = PipelineRunner(fns, plan2(4), [[devs[0]], [devs[1]]],
                                schedule="zb", n_micro=4, mb_keys=keys,
                                tied_ref=tied)
        grads, stats = runner.step(runner.place_params(sp), batch)
        hi = P // 2
        errs = [maxerr(grads[0]["embed"], ref_grads["embed"]),
                maxerr(grads[0]["blocks"], jax.tree.map(
                    lambda a: a[:hi], ref_grads["blocks"])),
                maxerr(grads[1]["blocks"], jax.tree.map(
                    lambda a: a[hi:], ref_grads["blocks"])),
                maxerr(grads[1]["final_norm"], ref_grads["final_norm"])]
        assert abs(stats.loss - float(ref_loss)) < 1e-4, stats.loss
        assert max(errs) < 1e-4, ("zb", errs)
        # zb keeps 1F1B's stash (W releases before the next F acquires)
        assert stats.peak_stash == 3, stats.peak_stash

        # --- interleaved: 2 physical stages x 2 chunks = 4 virtual
        plan = plan2(4)
        splits = plan.layer_splits(P, n_chunks=2)
        sp, fns, keys, tied = split_model(cfg, params, 4, splits=splits)
        runner = PipelineRunner(fns, plan, [[devs[0]], [devs[1]]],
                                schedule="interleaved", n_micro=4,
                                n_chunks=2, mb_keys=keys, tied_ref=tied)
        grads, stats = runner.step(runner.place_params(sp), batch)
        errs = [maxerr(grads[0]["embed"], ref_grads["embed"]),
                maxerr(grads[3]["final_norm"], ref_grads["final_norm"])]
        for u, (lo, hiu) in enumerate(splits):
            if lo < hiu:
                errs.append(maxerr(grads[u]["blocks"], jax.tree.map(
                    lambda a: a[lo:hiu], ref_grads["blocks"])))
        assert abs(stats.loss - float(ref_loss)) < 1e-4, stats.loss
        assert max(errs) < 1e-4, ("interleaved", errs)

        # --- zb with 2-way stage DP per sync mode
        for sync in ("allreduce", "ps", "sfb"):
            sp, fns, keys, tied = split_model(cfg, params, 2)
            runner = PipelineRunner(
                fns, plan2(2, sync=sync, n_devices=2),
                [devs[:2], devs[2:]], schedule="zb", n_micro=2,
                mb_keys=keys, tied_ref=tied)
            grads, stats = runner.step(runner.place_params(sp), batch)
            errs = [maxerr(grads[0]["embed"], ref_grads["embed"]),
                    maxerr(grads[0]["blocks"], jax.tree.map(
                        lambda a: a[:hi], ref_grads["blocks"])),
                    maxerr(grads[1]["blocks"], jax.tree.map(
                        lambda a: a[hi:], ref_grads["blocks"]))]
            assert max(errs) < 1e-4, (sync, errs)
        print("NEW_SCHED_PARITY_OK")
    """)
    assert "NEW_SCHED_PARITY_OK" in out


def test_scan_engine_matches_eager():
    """The compiled scan engine (CompiledPipelineRunner) produces loss
    and gradients allclose to the single-device reference for ALL four
    schedule families, with O(U) recorded scan-program events instead of
    the eager engine's O(U * n_micro) — including a 2-way stage-DP SFB
    spot check where the sync collectives run inside the scan."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.models import init_params, loss_fn
        from repro.exec import (CompiledPipelineRunner, PipelineRunner,
                                split_model)
        from repro.exec.stages import StagePlan, StageSpec

        cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((8, 16), jnp.int32),
                 "labels": jnp.ones((8, 16), jnp.int32)}
        ref_loss, _ = jax.jit(
            lambda p, b: loss_fn(cfg, p, b, remat=False))(params, batch)
        ref_grads = jax.jit(jax.grad(
            lambda p, b: loss_fn(cfg, p, b, remat=False)[0]))(params, batch)

        def maxerr(a, b):
            return max(float(jnp.max(jnp.abs(x - y))) for x, y in
                       zip(jax.tree.leaves(a), jax.tree.leaves(b)))

        def plan2(n_micro, sync="allreduce", n_devices=1):
            return StagePlan(
                stages=[StageSpec(i, i, [i], flops=1e9, param_bytes=0,
                                  grad_bytes=0, out_bytes=1e5, sync=sync,
                                  n_devices=n_devices, gpu_type="V100")
                        for i in range(2)],
                placement=(0, 1), n_micro=n_micro)

        devs = jax.devices()
        P = cfg.num_periods
        hi = P // 2
        M = 4
        for sched in ("gpipe", "1f1b", "interleaved", "zb"):
            nc = 2 if sched == "interleaved" else 1
            plan = plan2(M)
            splits = plan.layer_splits(P, n_chunks=nc) if nc > 1 else None
            sp, fns, keys, tied = split_model(cfg, params, 2 * nc,
                                              splits=splits)
            runner = CompiledPipelineRunner(
                fns, plan, [[devs[0]], [devs[1]]], schedule=sched,
                n_micro=M, n_chunks=nc, mb_keys=keys, tied_ref=tied)
            grads, stats = runner.step(runner.place_params(sp), batch,
                                       record=True)
            assert abs(stats.loss - float(ref_loss)) < 1e-4, \\
                (sched, stats.loss)
            errs = [maxerr(grads[0]["embed"], ref_grads["embed"]),
                    maxerr(grads[2 * nc - 1]["final_norm"],
                           ref_grads["final_norm"])]
            if nc == 1:
                errs += [maxerr(grads[0]["blocks"], jax.tree.map(
                             lambda a: a[:hi], ref_grads["blocks"])),
                         maxerr(grads[1]["blocks"], jax.tree.map(
                             lambda a: a[hi:], ref_grads["blocks"]))]
            else:
                for u, (lo, hiu) in enumerate(splits):
                    if lo < hiu:
                        errs.append(maxerr(grads[u]["blocks"],
                            jax.tree.map(lambda a: a[lo:hiu],
                                         ref_grads["blocks"])))
            assert max(errs) < 1e-4, (sched, errs)
            # one event per scan program, mb=-1: U fwd + U bwd
            # (+ U wgrad for zb), vs the eager engine's U*M + U*M
            U = 2 * nc
            want = U * (3 if sched == "zb" else 2)
            assert len(stats.events) == want, (sched, stats.events)
            assert all(e[2] == -1 for e in stats.events), stats.events
            # scan engine is GPipe-like in memory whatever the schedule
            assert stats.peak_stash == U * M, stats.peak_stash

        # eager engine on the same plan records per-microbatch events
        sp, fns, keys, tied = split_model(cfg, params, 2)
        eager = PipelineRunner(fns, plan2(M), [[devs[0]], [devs[1]]],
                               schedule="1f1b", n_micro=M, mb_keys=keys,
                               tied_ref=tied)
        _, est = eager.step(eager.place_params(sp), batch, record=True)
        assert len(est.events) == 2 * 2 * M, len(est.events)

        # 2-way stage DP: sync collectives run inside the scan
        sp, fns, keys, tied = split_model(cfg, params, 2)
        runner = CompiledPipelineRunner(
            fns, plan2(2, sync="sfb", n_devices=2),
            [devs[:2], devs[2:]], schedule="1f1b", n_micro=2,
            mb_keys=keys, tied_ref=tied)
        grads, stats = runner.step(runner.place_params(sp), batch)
        errs = [maxerr(grads[0]["embed"], ref_grads["embed"]),
                maxerr(grads[0]["blocks"], jax.tree.map(
                    lambda a: a[:hi], ref_grads["blocks"])),
                maxerr(grads[1]["blocks"], jax.tree.map(
                    lambda a: a[hi:], ref_grads["blocks"]))]
        assert max(errs) < 1e-4, ("sfb", errs)
        print("SCAN_ENGINE_OK")
    """)
    assert "SCAN_ENGINE_OK" in out


def test_stack_microbatches_shape_guard():
    """stack_microbatches reshapes [B, ...] -> [M, B/M, ...] and rejects
    batch sizes not divisible by n_micro."""
    import numpy as np
    from repro.exec import stack_microbatches
    batch = {"tokens": np.ones((8, 16), np.int32)}
    out = stack_microbatches(batch, 4)
    assert out["tokens"].shape == (4, 2, 16)
    with pytest.raises(ValueError, match="n_micro"):
        stack_microbatches(batch, 3)


def test_pipeline_kill_and_resume_parity():
    """Checkpoint resume for pipelined training: a run killed after 2
    steps and resumed from its per-stage checkpoint produces exactly the
    same losses and final checkpoint as an uninterrupted run."""
    out = _run_subprocess("""
        import argparse, os, tempfile
        import numpy as np
        import jax
        from repro.checkpoint import load_checkpoint
        from repro.configs import get_reduced
        from repro.exec.stages import StagePlan, StageSpec
        from repro.launch.train import run_pipeline

        cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
        plan = StagePlan(
            stages=[StageSpec(i, i, [i], flops=1e9, param_bytes=0,
                              grad_bytes=0, out_bytes=1e5, n_devices=2,
                              gpu_type="V100") for i in range(2)],
            placement=(0, 1), n_micro=4, schedule="zb")

        def mkargs(**kw):
            d = dict(arch="qwen2-1.5b", batch=8, seq=16, lr=1e-3, seed=0,
                     steps=4, log_every=10, ckpt_dir="", ckpt_every=2,
                     resume=False, pipeline="auto", n_micro=4, n_chunks=2,
                     telemetry_dir="")
            d.update(kw)
            return argparse.Namespace(**d)

        tmp = tempfile.mkdtemp()
        d1, d2 = os.path.join(tmp, "a"), os.path.join(tmp, "b")
        full = run_pipeline(mkargs(ckpt_dir=d1), cfg, plan)
        run_pipeline(mkargs(ckpt_dir=d2, steps=2), cfg, plan)  # "killed"
        resumed = run_pipeline(mkargs(ckpt_dir=d2, resume=True), cfg, plan)
        assert np.allclose(full[2:], resumed, atol=1e-6), (full, resumed)
        s1, t1 = load_checkpoint(d1)
        s2, t2 = load_checkpoint(d2)
        assert s1 == s2 == 4
        for a, b in zip(jax.tree.leaves(t1), jax.tree.leaves(t2)):
            assert np.allclose(np.asarray(a), np.asarray(b), atol=1e-6)
        # a single-mesh checkpoint must be rejected by the pipeline path
        print("RESUME_PARITY_OK")
    """)
    assert "RESUME_PARITY_OK" in out


def test_single_stage_split_matches_reference():
    """Degenerate 1-stage split: the composed stage fn must apply the
    decoder blocks exactly once (regression: blocks ran twice)."""
    import jax
    import jax.numpy as jnp
    from repro.configs import get_reduced
    from repro.exec import split_model
    from repro.models import init_params, loss_fn

    cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((2, 8), jnp.int32),
             "labels": jnp.ones((2, 8), jnp.int32)}
    ref, _ = jax.jit(lambda p, b: loss_fn(cfg, p, b, remat=False))(
        params, batch)
    sp, fns, keys, tied = split_model(cfg, params, 1)
    assert tied is None
    loss, _ = fns[0](sp[0], None, batch)
    assert abs(float(loss) - float(ref)) < 1e-5


# ------------------------------------------------------- launcher routing

def test_train_launcher_pipeline_fallback(capsys, monkeypatch):
    """--tag-search PIPE strategies are never silently degraded: on a
    too-small host the launcher logs an explicit fallback warning."""
    from repro.core.plan import ExecutionPlan
    from repro.launch import mesh as mesh_mod
    from repro.launch.train import resolve_pipeline
    # pin the visible device count (the suite may run under a forced
    # multi-device XLA_FLAGS)
    monkeypatch.setattr(
        mesh_mod, "stage_device_sets",
        lambda sp, devices=None: sp.assign_local_devices([object()]))
    plan = ExecutionPlan(
        rules=None, grad_sync={}, zero1=False,
        summary={"options": {"PIPE": 3}},
        stage_plan=StagePlan(
            stages=[StageSpec(i, i, [i], 1e9, 0, 0, 1e5)
                    for i in range(3)],
            placement=(0, 1, 2), n_micro=4))
    assert resolve_pipeline(plan, "auto") is None     # 1 CPU < 3 stages
    out = capsys.readouterr().out
    assert "WARNING" in out and "fallback" in out
    assert resolve_pipeline(plan, "off") is None
    out = capsys.readouterr().out
    assert "off" in out
    no_spine = ExecutionPlan(rules=None, grad_sync={}, zero1=False,
                             summary={"options": {"PIPE": 1}},
                             stage_plan=None)
    assert resolve_pipeline(no_spine, "auto") is None
    assert "single-mesh" in capsys.readouterr().out


def test_lower_strategy_attaches_stage_plan():
    from repro.core.plan import lower_strategy

    class _M:
        axis_names = ("data",)
        shape = {"data": 1}
    gg = _chain_gg()
    topo = make_testbed()
    plan = lower_strategy(_pipe_strategy(gg, (0, 1)), gg, topo, _M())
    assert plan.is_pipelined and plan.stage_plan.n_stages == 2
    assert plan.summary["n_stages"] == 2
    dp = Strategy([Action((0, 1), Option.AR)] * gg.n)
    plan2 = lower_strategy(dp, gg, topo, _M())
    assert not plan2.is_pipelined and plan2.summary["n_stages"] == 0
