"""Planner service: fingerprint stability, plan-store round trips, and the
cache hit / warm-start contracts (ISSUE acceptance criteria)."""
import copy
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro.core.device import DeviceGroup, Topology, _full_inter
from repro.core.device import testbed as make_testbed
from repro.core.graph import group_graph
from repro.core.jax_export import trace_training_graph
from repro.core.partition import partition
from repro.core.sfb import GroupSFB
from repro.core.strategy import Action, Option, Strategy
from repro.core.zoo import build
from repro.service import (
    PlannerService, PlanStore, adapt_strategy, fingerprint_grouped,
    fingerprint_topology, topology_structure_fingerprint)
from repro.service.planner import PlanRequest
from repro.service.store import SCHEMA_VERSION, PlanRecord

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


@pytest.fixture(scope="module")
def gg():
    loss_fn, params, batch = build("bert_small")
    g = trace_training_graph(loss_fn, params, batch, "bert").simplify()
    return group_graph(g, partition(g, 12))


@pytest.fixture(scope="module")
def topo():
    return make_testbed()


def _perturbed(topo, scale=0.9):
    t2 = copy.deepcopy(topo)
    t2.inter_bw = topo.inter_bw * scale
    return t2


# ------------------------------------------------------------ fingerprints

def test_fingerprint_deterministic_within_process(gg, topo):
    assert fingerprint_grouped(gg) == fingerprint_grouped(gg)
    assert fingerprint_topology(topo) == fingerprint_topology(topo)


def test_fingerprint_stable_across_processes(topo):
    """Same topology hashed in a fresh interpreter -> same hex digest
    (no dependence on PYTHONHASHSEED / object identity)."""
    code = textwrap.dedent("""
        from repro.core.device import testbed
        from repro.service import fingerprint_topology
        print(fingerprint_topology(testbed()))
    """)
    out = subprocess.run(
        [sys.executable, "-c", code], capture_output=True, text=True,
        env=dict(os.environ, PYTHONPATH=SRC, PYTHONHASHSEED="12345"),
        timeout=120)
    assert out.returncode == 0, out.stderr[-2000:]
    assert out.stdout.strip() == fingerprint_topology(topo)


def test_fingerprint_sensitive_to_perturbation(gg, topo):
    t2 = _perturbed(topo)
    assert fingerprint_topology(t2) != fingerprint_topology(topo)
    # bandwidth-blind structure fp is unchanged -> warm-start donor match
    assert topology_structure_fingerprint(t2) \
        == topology_structure_fingerprint(topo)
    # device-spec change flips both
    t3 = copy.deepcopy(topo)
    t3.groups[0].num_gpus += 1
    assert topology_structure_fingerprint(t3) \
        != topology_structure_fingerprint(topo)


def test_graph_fingerprint_ignores_name(gg):
    g2 = copy.deepcopy(gg)
    g2.base.name = "renamed"
    assert fingerprint_grouped(g2) == fingerprint_grouped(gg)


# -------------------------------------------------------------- plan store

def _dummy_record(graph_fp="g" * 64, topo_fp="t" * 64, time=1.0):
    strat = Strategy([Action((0,), Option.AR), None])
    return PlanRecord(
        graph_fp=graph_fp, topo_fp=topo_fp, topo_struct_fp="s" * 64,
        n_groups=2, topo_m=1, strategy=strat.to_dict(),
        sfb_plans={"0": GroupSFB(1.0, 2.0, 3.0, ["dot"]).to_dict()},
        time=time, baseline_time=2.0, meta={"seed": 0})


def test_store_memory_lru_eviction():
    store = PlanStore(capacity=2)
    for i in range(3):
        store.put(_dummy_record(graph_fp=f"g{i}" + "0" * 62))
    assert len(store) == 2
    assert store.get("g0" + "0" * 62, "t" * 64) is None


def test_store_disk_roundtrip(tmp_path):
    store = PlanStore(path=str(tmp_path))
    rec = _dummy_record()
    store.put(rec)
    # fresh store (new process equivalent) reloads from disk
    store2 = PlanStore(path=str(tmp_path))
    got = store2.get(rec.graph_fp, rec.topo_fp)
    assert got is not None
    assert got.strategy_obj().canonical_json() \
        == rec.strategy_obj().canonical_json()
    sfb = got.sfb_objs()[0]
    assert (sfb.extra_flops, sfb.bcast_bytes, sfb.saved_sync_bytes,
            sfb.dup_op_types) == (1.0, 2.0, 3.0, ["dot"])
    assert store2.evict(graph_fp=rec.graph_fp[:16]) == 1
    assert PlanStore(path=str(tmp_path)).get(rec.graph_fp, rec.topo_fp) \
        is None


def test_store_disk_eviction_age_and_quota(tmp_path):
    """Disk-tier budgets (ISSUE satellite): age budget drops old records,
    per-topology quotas keep only the newest N per topo_fp."""
    store = PlanStore(path=str(tmp_path))
    now = 1_000_000.0
    for i in range(4):
        rec = _dummy_record(graph_fp=f"g{i}" + "0" * 62,
                            topo_fp=("tA" if i < 3 else "tB") + "0" * 62)
        store.put(rec)
        fn = tmp_path / (rec.graph_fp[:24] + "-" + rec.topo_fp[:24]
                         + ".json")
        os.utime(fn, (now - 100 * (4 - i), now - 100 * (4 - i)))
    # age budget: only the two newest (age 200, 100) survive 250s
    assert store.evict_expired(max_age_s=250, now=now) == 2
    assert len(store) == 2
    # per-topology quota: tA still has one record, tB one -> quota 1 keeps
    # both; rebuild to test quota trimming
    store2 = PlanStore(path=str(tmp_path))
    for i in range(4, 7):
        rec = _dummy_record(graph_fp=f"g{i}" + "0" * 62,
                            topo_fp="tA" + "0" * 62)
        store2.put(rec)
        fn = tmp_path / (rec.graph_fp[:24] + "-" + rec.topo_fp[:24]
                         + ".json")
        os.utime(fn, (now + i, now + i))
    evicted = store2.evict_expired(per_topo_quota=1, now=now + 10)
    assert evicted >= 2
    # the newest tA record (g6) survives
    assert store2.get("g6" + "0" * 62, "tA" + "0" * 62) is not None
    assert store2.get("g4" + "0" * 62, "tA" + "0" * 62) is None


def test_store_disk_eviction_size_budget(tmp_path):
    store = PlanStore(path=str(tmp_path))
    now = 1_000_000.0
    for i in range(3):
        rec = _dummy_record(graph_fp=f"g{i}" + "0" * 62)
        rec.topo_fp = f"t{i}" + "0" * 62
        store.put(rec)
        fn = tmp_path / (rec.graph_fp[:24] + "-" + rec.topo_fp[:24]
                         + ".json")
        os.utime(fn, (now + i, now + i))
    one = os.path.getsize(next(tmp_path.glob("*.json")))
    # budget for ~1.5 records: oldest evicted first, newest kept
    assert store.evict_expired(max_bytes=int(1.5 * one), now=now + 10) == 2
    assert store.get("g2" + "0" * 62, "t2" + "0" * 62) is not None


def test_store_constructor_budgets_enforced_on_put(tmp_path):
    store = PlanStore(path=str(tmp_path), per_topo_quota=2)
    for i in range(4):
        store.put(_dummy_record(graph_fp=f"g{i}" + "0" * 62))
    assert len(store) <= 2


def test_store_budgets_cover_other_processes_records(tmp_path):
    """Budget enforcement rescans the directory under the lock, so
    records written by OTHER store instances (processes) sharing the
    cache are counted and evictable."""
    writer_a = PlanStore(path=str(tmp_path))
    writer_b = PlanStore(path=str(tmp_path))          # scanned when empty
    for i in range(3):
        writer_a.put(_dummy_record(graph_fp=f"ga{i}" + "0" * 60))
    # b never saw a's records in its index, but quota enforcement must
    for i in range(2):
        writer_b.put(_dummy_record(graph_fp=f"gb{i}" + "0" * 60))
    assert writer_b.evict_expired(per_topo_quota=1) == 4
    assert len(PlanStore(path=str(tmp_path))) == 1
    # evict --all from a stale instance also clears foreign records
    writer_c = PlanStore(path=str(tmp_path))
    writer_a.put(_dummy_record(graph_fp="gz" + "0" * 62))
    assert writer_c.evict(all=True) == 2          # survivor + foreign gz
    assert len(PlanStore(path=str(tmp_path))) == 0


def test_store_concurrent_writers_share_disk_tier(tmp_path):
    """fcntl-locked disk tier (ISSUE satellite): concurrent writers from
    several threads, plus a second store instance ("another process")
    reading records it never wrote."""
    import threading
    stores = [PlanStore(path=str(tmp_path)) for _ in range(3)]

    def hammer(s, base):
        for i in range(10):
            s.put(_dummy_record(graph_fp=f"g{base}_{i}" + "0" * 56))

    threads = [threading.Thread(target=hammer, args=(s, k))
               for k, s in enumerate(stores)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert os.path.exists(tmp_path / ".lock")
    # a store that scanned before the writes still sees fresh records
    # (get() falls through to the filesystem on a mem+index miss)
    fresh = PlanStore(path=str(tmp_path))
    assert len(fresh) == 30
    assert stores[0].get("g2_9" + "0" * 56, "t" * 64) is not None


def test_store_rejects_stale_schema(tmp_path):
    store = PlanStore(path=str(tmp_path))
    store.put(_dummy_record())
    # pick the record, not e.g. the store's .lock file
    fn = next(f for f in os.listdir(tmp_path) if f.endswith(".json"))
    d = json.load(open(tmp_path / fn))
    d["version"] = SCHEMA_VERSION + 1
    json.dump(d, open(tmp_path / fn, "w"))
    assert len(PlanStore(path=str(tmp_path))) == 0


def test_strategy_serialization_roundtrip():
    strat = Strategy([Action((0, 2), Option.PS), None,
                      Action((1,), Option.PIPE)])
    back = Strategy.from_dict(strat.to_dict())
    assert back.canonical_json() == strat.canonical_json()
    assert back.actions[0] == strat.actions[0]
    assert back.actions[1] is None


# ------------------------------------------------------- warm-start pieces

def test_adapt_strategy_clips_to_new_topology():
    prior = Strategy([Action((0, 5), Option.AR), Action((6,), Option.PS)])
    small = Topology([DeviceGroup(0, "V100", 2, intra_bw=1e9)],
                     _full_inter(1, 0))
    got = adapt_strategy(prior, 3, small)
    assert got.actions[0] == Action((0,), Option.AR)
    assert got.actions[1] is None          # placement vanished entirely
    assert got.actions[2] is None          # prior never decided group 2


# ----------------------------------------------------- service end-to-end

def test_hit_is_byte_identical_and_runs_no_mcts(gg, topo, tmp_path):
    svc = PlannerService(cache_dir=str(tmp_path))
    r1 = svc.plan_graph(gg, topo, iterations=10, seed=0)
    r2 = svc.plan_graph(gg, topo, iterations=10, seed=0)
    assert r1.source == "cold" and r2.source == "hit"
    assert r2.iterations_run == 0
    assert r2.strategy.canonical_json() == r1.strategy.canonical_json()
    # across a "restart": a fresh service on the same disk tier still hits
    r3 = PlannerService(cache_dir=str(tmp_path)).plan_graph(
        gg, topo, iterations=10, seed=0)
    assert r3.source == "hit" and r3.iterations_run == 0
    assert r3.strategy.canonical_json() == r1.strategy.canonical_json()


def test_warm_start_fewer_iters_no_worse_makespan(gg, topo):
    """ISSUE acceptance: warm-started search on a perturbed topology
    completes in strictly fewer MCTS playouts than a cold search at
    equal-or-better simulated makespan."""
    topo_p = _perturbed(topo)
    budget = 25
    cold = PlannerService().plan_graph(gg, topo_p, iterations=budget, seed=0)
    assert cold.iterations_run == budget

    svc = PlannerService()
    svc.plan_graph(gg, topo, iterations=budget, seed=0)       # seed cache
    warm = svc.plan_graph(gg, topo_p, iterations=budget, seed=0,
                          stop_reward=cold.best_reward)
    assert warm.source == "warm"
    assert warm.iterations_run < cold.iterations_run
    assert warm.time <= cold.time * (1 + 1e-9)
    assert svc.stats()["warm"] == 1


def test_bigger_budget_not_shadowed_by_small_cached_plan(gg, topo):
    """A record cached under a tiny budget must not be served as a hit to a
    larger-budget request — it seeds a warm re-search instead."""
    svc = PlannerService()
    svc.plan_graph(gg, topo, iterations=2, seed=0)
    big = svc.plan_graph(gg, topo, iterations=6, seed=0)
    assert big.source == "warm" and big.iterations_run > 0
    # equal-budget repeat of the bigger request is again a plain hit
    again = svc.plan_graph(gg, topo, iterations=6, seed=0)
    assert again.source == "hit" and again.iterations_run == 0


def test_plan_many_dedups_within_batch(gg, topo):
    svc = PlannerService()
    reqs = [PlanRequest(gg, topo, iterations=8) for _ in range(3)]
    out = svc.plan_many(reqs)
    assert [r.source for r in out] == ["cold", "hit", "hit"]
    assert svc.stats()["batch_dedup"] == 2
    assert out[1].strategy.canonical_json() \
        == out[0].strategy.canonical_json()
