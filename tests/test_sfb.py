"""SFB ILP solver: exactness vs brute force (hypothesis), batch-size
regime behaviour, and the end-to-end post-pass on VGG (FC layers are the
paper's canonical SFB win)."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.device import two_1080ti
from repro.core.graph import group_graph
from repro.core.jax_export import trace_training_graph
from repro.core.partition import partition
from repro.core.sfb import SFBProblem, solve, solve_brute
from repro.core.strategy import Strategy, data_parallel_all
from repro.core.tag import sfb_post_pass
from repro.core.zoo import build


@st.composite
def random_problem(draw):
    n = draw(st.integers(2, 9))
    rng = np.random.default_rng(draw(st.integers(0, 1 << 30)))
    edges = []
    for j in range(1, n):
        for i in range(j):
            if rng.random() < 0.45:
                edges.append((i, j, float(rng.uniform(1e4, 1e8))))
    return SFBProblem(
        ops=list(range(n)), edges=edges,
        times={o: float(rng.uniform(1e-6, 1e-3)) for o in range(n)},
        g=n - 1, l=n, grad_bytes=float(rng.uniform(1e5, 1e9)),
        D=int(rng.integers(2, 9)), tau=float(rng.uniform(1e9, 1e10)))


@given(random_problem())
@settings(max_examples=60, deadline=None)
def test_branch_and_bound_matches_brute_force(prob):
    a, b = solve(prob), solve_brute(prob)
    assert abs(a.objective - b.objective) <= 1e-9 * max(1.0,
                                                        abs(b.objective))


def test_sfb_wins_small_batch_loses_large_batch():
    """Dense layer dW = x^T dy with realistic producer costs: SFB helps at
    B=4 (paper §5.6 regime) and is rejected at B=4096."""
    H1 = H2 = 1024
    D, tau, speed = 2, 1.25e9, 5e12

    def make(B):
        # 0: upstream producer of x (batch-sized output), 1: of dy,
        # 2: matmul producing dW
        edges = [(0, 2, B * H1 * 4), (1, 2, B * H2 * 4)]
        times = {0: 2 * B * H1 * H1 / speed, 1: 2 * B * H2 * H2 / speed,
                 2: 2 * B * H1 * H2 / speed}
        return SFBProblem([0, 1, 2], edges, times, g=2, l=3,
                          grad_bytes=H1 * H2 * 4, D=D, tau=tau)

    small = solve(make(4))
    big = solve(make(4096))
    assert small.beneficial
    assert small.alpha[2] == 1
    assert not big.beneficial


def test_post_pass_finds_fc_gradients_on_vgg():
    loss_fn, params, batch = build("vgg19", batch=4)
    g = trace_training_graph(loss_fn, params, batch, "vgg19").simplify()
    gg = group_graph(g, partition(g, 30))
    topo = two_1080ti()
    strat = Strategy([data_parallel_all(topo)] * gg.n)
    plans = sfb_post_pass(gg, strat, topo)
    assert plans, "SFB must trigger on VGG FC layers at batch 4"
    saved = sum(p.saved_sync_bytes for p in plans.values())
    assert saved > 50e6   # the FC gradients are hundreds of MB
    types = [t for p in plans.values() for t in p.dup_op_types]
    assert "dot_general" in types  # paper Table 6's top op


def test_sfb_improves_simulated_time_on_vgg_small_batch():
    from repro.core.compiler import compile_strategy
    from repro.core.simulator import simulate
    loss_fn, params, batch = build("vgg19", batch=4)
    g = trace_training_graph(loss_fn, params, batch, "vgg19").simplify()
    gg = group_graph(g, partition(g, 30))
    topo = two_1080ti()
    strat = Strategy([data_parallel_all(topo)] * gg.n)
    t0 = simulate(compile_strategy(gg, strat, topo), topo).makespan
    plans = sfb_post_pass(gg, strat, topo)
    t1 = simulate(compile_strategy(gg, strat, topo, sfb_plans=plans),
                  topo).makespan
    assert t1 < t0
