"""End-to-end behaviour tests: training converges on the synthetic bigram
task; serving generates; TAG's full pipeline produces a deployable plan."""
import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.device import tpu_pods
from repro.core.plan import lower_strategy
from repro.core.tag import optimize
from repro.launch.serve import generate
from repro.launch.train import main as train_main
from repro.models import init_params, loss_fn
from repro.parallel.sharding import AxisRules


def test_training_loss_decreases_e2e():
    losses = train_main(["--arch", "qwen2-1.5b", "--smoke", "--steps", "12",
                         "--batch", "8", "--seq", "64",
                         "--log-every", "100"])
    assert losses[-1] < losses[0] - 0.5


def test_checkpoint_resume_continues(tmp_path):
    d = str(tmp_path / "ck")
    train_main(["--arch", "qwen2-1.5b", "--smoke", "--steps", "4",
                "--batch", "4", "--seq", "32", "--ckpt-dir", d,
                "--ckpt-every", "4", "--log-every", "100"])
    losses = train_main(["--arch", "qwen2-1.5b", "--smoke", "--steps", "8",
                         "--batch", "4", "--seq", "32", "--ckpt-dir", d,
                         "--resume", "--log-every", "100"])
    assert len(losses) == 4   # resumed from step 4


def test_serving_generates_tokens():
    cfg = get_reduced("jamba-v0.1-52b")
    params = init_params(cfg, jax.random.PRNGKey(0))
    prompts = jnp.ones((2, 4), jnp.int32)
    out = generate(cfg, params, prompts, 6, AxisRules())
    assert out.shape == (2, 6)
    assert bool((out >= 0).all()) and bool((out < cfg.vocab_size).all())


def test_tag_full_pipeline_on_reduced_arch():
    """Trace one of the ASSIGNED architectures (reduced) through TAG and
    lower the strategy to an execution plan."""
    cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jnp.ones((4, 16), jnp.int32),
             "labels": jnp.ones((4, 16), jnp.int32)}
    topo = tpu_pods()
    res = optimize(lambda p, b: loss_fn(cfg, p, b, remat=False)[0],
                   params, batch, topo, name="qwen2", iterations=12,
                   n_groups=16, seed=0)
    assert res.search.best_reward >= 1.0 - 1e-9
    assert res.strategy.complete()

    class _Mesh:
        axis_names = ("pod", "data", "model")
        shape = {"pod": 2, "data": 16, "model": 16}
    plan = lower_strategy(res.strategy, res.gg, topo, _Mesh())
    assert plan.rules.rules["batch"] in (("pod", "data"), ("data",))
    assert set(plan.grad_sync.values()) <= {"allreduce", "ps", "sfb"}
