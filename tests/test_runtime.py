"""Runtime feedback subsystem: telemetry round trips, calibration fitting
recovering known ground-truth parameters, drift detection, and the
drift-triggered invalidate -> recalibrate -> replan loop (ISSUE
acceptance criteria)."""
import copy

import numpy as np
import pytest

from repro.core.compiler import TaskGraph, compile_strategy
from repro.core.device import testbed as make_testbed
from repro.core.features import featurize
from repro.core.graph import group_graph
from repro.core.jax_export import trace_training_graph
from repro.core.partition import partition
from repro.core.profiler import (
    OP_OVERHEAD, fit_comm, fit_utilization, transfer_time)
from repro.core.simulator import simulate
from repro.core.zoo import build
from repro.runtime import (
    DriftDetector, MeasurementStore, StepRecord, StepTimer, execute_plan,
    fit_profile, observed_sim_result)
from repro.runtime.calibration import CalibrationProfile
from repro.service import PlannerService


@pytest.fixture(scope="module")
def gg():
    loss_fn, params, batch = build("bert_small")
    g = trace_training_graph(loss_fn, params, batch, "bert").simplify()
    return group_graph(g, partition(g, 10))


@pytest.fixture(scope="module")
def topo():
    return make_testbed()


def _true_cluster(topo, util_scale=0.5, cross_scale=0.25, lat_scale=3.0):
    t2 = copy.deepcopy(topo)
    for g in t2.groups:
        g.flops *= util_scale
    t2.coll_eff_cross *= cross_scale
    t2.p2p_eff *= 0.8
    t2.latency *= lat_scale
    return t2


def _toy_taskgraph(topo):
    """Hand-built TaskGraph exercising every task kind (no tracing).
    Each link class gets >= 2 samples of distinct size so the joint
    (eff, alpha) regressions are full-rank."""
    tg = TaskGraph()
    for d in range(6):
        tg.add(kind="compute", group=0, device=d, flops=1e9 * (d + 1))
    tg.add(kind="xfer", group=0, src=0, dst=5, nbytes=3e6, deps=[0])
    tg.add(kind="xfer", group=0, src=1, dst=4, nbytes=9e6, deps=[1])
    tg.add(kind="allreduce", group=0, nbytes=8e6,
           devices=tuple(range(4)), deps=[6])         # intra (V100 group)
    tg.add(kind="allreduce", group=0, nbytes=24e6,
           devices=(0, 1, 2), deps=[7])               # intra, other size
    tg.add(kind="allreduce", group=0, nbytes=2e6,
           devices=(0, 4, 5), deps=[8])               # cross machines
    tg.add(kind="ps", group=0, nbytes=6e6,
           devices=(0, 1, 4, 5), deps=[9])            # cross, other size
    return tg


# ---------------------------------------------------- fitting primitives

def test_fit_utilization_recovers_ground_truth():
    peak, true_u = 10e12, 0.37
    flops = np.array([1e9, 5e9, 2e10, 8e10])
    times = OP_OVERHEAD + flops / (peak * true_u)
    assert fit_utilization(flops, times, peak) == pytest.approx(true_u)


def test_fit_comm_recovers_ground_truth():
    b_nom, true_eff, true_alpha = 12.5e9, 0.15, 2e-4
    sizes = np.array([1e6, 4e6, 1.6e7, 6.4e7])
    n_dev = np.array([4, 8, 4, 16])
    s = 2 * (n_dev - 1) / n_dev * sizes / b_nom
    m = 2.0 * n_dev
    t = s / true_eff + m * true_alpha
    fit = fit_comm(s, m, t)
    assert fit.eff == pytest.approx(true_eff)
    assert fit.alpha == pytest.approx(true_alpha)


def test_fit_comm_single_sample_falls_back_to_prior_latency():
    fit = fit_comm([1e-3], [2.0], [1e-2], prior_alpha=50e-6)
    assert fit.alpha == 50e-6
    # eff absorbs the residual: model reproduces the observed time
    assert 1e-3 / fit.eff + 2.0 * fit.alpha == pytest.approx(1e-2)


def test_degenerate_fits_return_none_not_peak_speed():
    """Samples with no signal must NOT calibrate the model toward peak
    speed — the caller keeps its nominal prior instead."""
    # all op times at/below the launch overhead: no compute signal
    assert fit_utilization([1e9, 2e9], [OP_OVERHEAD, OP_OVERHEAD],
                           10e12) is None
    # observed comm times below even the latency term: no bandwidth signal
    assert fit_comm([1e-3, 2e-3], [2.0, 2.0], [1e-5, 1e-5],
                    prior_alpha=50e-6) is None
    # fit_profile skips the degenerate samples and keeps nominal values
    from repro.runtime.calibration import fit_profile as _fp
    t = make_testbed()
    rec = StepRecord(compute=[{"gpu_type": "V100", "flops": 1e9,
                               "time": OP_OVERHEAD}],
                     collectives=[{"kind": "allreduce", "nbytes": 1e6,
                                   "n_dev": 4, "nominal_bw": 12.5e9,
                                   "link": "cross", "time": 1e-9}])
    prof = _fp([rec], t)
    assert prof.util == {} and prof.links == {}


# ------------------------------------------------ executor + calibration

def test_calibration_recovers_perturbed_cluster(topo):
    """Synthetic measurements from a known-slower cluster recover the
    ground-truth utilization and link parameters (ISSUE satellite)."""
    true = _true_cluster(topo)
    tg = _toy_taskgraph(topo)
    recs = [execute_plan(tg, true, nominal_topo=topo, step=i)
            for i in range(2)]
    profile = fit_profile(recs, topo)

    # per-type utilization: prior util x slowdown, exactly
    from repro.core.device import GPU_PEAKS
    for t, u in profile.util.items():
        assert u == pytest.approx(GPU_PEAKS[t]["util"] * 0.5, rel=1e-6)
    # cross-collective efficiency and latency recovered jointly
    assert profile.links["cross"].eff == pytest.approx(
        true.coll_eff_cross, rel=1e-6)
    assert profile.links["cross"].alpha == pytest.approx(
        true.latency, rel=1e-6)

    # calibrated simulation matches the observed cluster exactly
    obs = simulate(tg, true).makespan
    calib = simulate(tg, topo, profile=profile).makespan
    assert calib == pytest.approx(obs, rel=1e-9)
    # explicit-apply path is identical to the profile= kwarg
    assert simulate(tg, profile.apply(topo)).makespan \
        == pytest.approx(calib, rel=1e-12)


def test_calibration_closes_error_2x(topo):
    true = _true_cluster(topo)
    tg = _toy_taskgraph(topo)
    recs = [execute_plan(tg, true, nominal_topo=topo, step=i,
                         noise=0.01, seed=i) for i in range(6)]
    obs = float(np.median([r.wall_time for r in recs]))
    err_before = abs(simulate(tg, topo).makespan - obs) / obs
    profile = fit_profile(recs, topo)
    err_after = abs(simulate(tg, topo, profile=profile).makespan
                    - obs) / obs
    assert err_before >= 2.0 * err_after


def test_profile_serialization_roundtrip(tmp_path, topo):
    true = _true_cluster(topo)
    tg = _toy_taskgraph(topo)
    profile = fit_profile([execute_plan(tg, true, nominal_topo=topo)],
                          topo)
    p = tmp_path / "profile.json"
    profile.save(str(p))
    back = CalibrationProfile.load(str(p))
    assert back.util == profile.util
    assert back.latency == profile.latency
    assert {k: v.to_dict() for k, v in back.links.items()} \
        == {k: v.to_dict() for k, v in profile.links.items()}
    # bad schema rejected
    with pytest.raises(ValueError):
        CalibrationProfile.from_dict({"version": 99})


def test_uniform_profile_scales_makespan_exactly(topo):
    from repro.runtime import uniform_profile
    tg = _toy_taskgraph(topo)
    base = simulate(tg, topo).makespan
    half = simulate(tg, topo, profile=uniform_profile(topo, 0.5)).makespan
    # near-exact: only the fixed per-op launch overhead doesn't scale
    assert half == pytest.approx(2.0 * base, rel=1e-2)


def test_observe_time_only_falls_back_to_uniform_calibration(gg, topo):
    """A bare observed step time (no samples) still calibrates: the
    uniform-slowdown profile makes the simulator match the observation."""
    svc = PlannerService(drift_threshold=0.25)
    resp = svc.plan_graph(gg, topo, iterations=6, seed=0)
    res = svc.observe(gg, topo, resp.time * 2.0, iterations=6)
    assert res.kind == "replanned"
    assert res.profile.meta.get("uniform_scale") == pytest.approx(0.5)
    # near-exact: per-op launch overhead doesn't scale
    assert res.stale_time == pytest.approx(resp.time * 2.0, rel=1e-3)


# ------------------------------------------------------------- telemetry

def test_measurement_store_jsonl_roundtrip(tmp_path):
    store = MeasurementStore(str(tmp_path))
    for i in range(3):
        store.append(StepRecord(graph_fp="g1", topo_fp=f"t{i % 2}",
                                step=i, wall_time=0.1 * (i + 1)))
    # fresh handle (new process equivalent) reads everything back
    store2 = MeasurementStore(str(tmp_path))
    assert len(store2) == 3
    assert [r.step for r in store2.records(topo_fp="t0")] == [0, 2]
    assert [r.step for r in store2.records(limit=1)] == [2]
    assert store2.records()[0].wall_time == pytest.approx(0.1)


def test_step_timer_records_wall_times():
    store = MeasurementStore()
    timer = StepTimer(store, graph_fp="g", topo_fp="t",
                      meta={"launcher": "test"})
    fn = timer.wrap(lambda x: x + 1)
    assert fn(1) == 2 and fn(2) == 3
    assert len(store) == 2
    recs = store.records()
    assert all(r.wall_time > 0 for r in recs)
    assert recs[1].step == 1 and recs[0].meta["launcher"] == "test"
    assert timer.summary()["steps"] == 2


def test_observed_sim_result_aggregates(topo):
    recs = [StepRecord(wall_time=w, device_busy={"0": 0.5 * w},
                       link_busy={"0-1": 0.25 * w})
            for w in (1.0, 2.0, 3.0)]
    res = observed_sim_result(recs, topo)
    assert res.makespan == 2.0                      # median
    assert res.device_busy[0] == pytest.approx(1.0)  # mean busy
    assert res.link_idle_frac(0, 1) == pytest.approx(1 - 0.5 / 2.0)
    with pytest.raises(ValueError):
        observed_sim_result([], topo)


def test_featurize_uses_observed_feedback(gg, topo):
    from repro.core.strategy import data_parallel_all, Strategy
    strat = Strategy([data_parallel_all(topo)] * gg.n)
    res = simulate(compile_strategy(gg, strat, topo), topo)
    W = res.makespan * 3.0
    observed = observed_sim_result(
        [StepRecord(wall_time=W, device_busy={"0": 0.1 * W})], topo)
    het_sim = featurize(gg, topo, strat, res, 0)
    het_obs = featurize(gg, topo, strat, res, 0, observed=observed)
    # device idle % comes from the measured busy attribution
    assert het_obs.dev_x[0, 5] != pytest.approx(float(het_sim.dev_x[0, 5]))
    # wall-time-only observation (no attribution) must NOT overlay a
    # fabricated 100%-idle constant — simulated signals are kept
    bare = observed_sim_result([StepRecord(wall_time=W)], topo)
    het_bare = featurize(gg, topo, strat, res, 0, observed=bare)
    np.testing.assert_allclose(het_bare.dev_x[:, 5], het_sim.dev_x[:, 5])
    np.testing.assert_allclose(het_bare.dd_e[:, :, 1],
                               het_sim.dd_e[:, :, 1])
    # signals telemetry cannot attribute stay per-candidate from the
    # simulator: group makespan/idle features and peak-memory fractions
    np.testing.assert_allclose(het_obs.op_x[:, 7], het_sim.op_x[:, 7])
    np.testing.assert_allclose(het_obs.op_x[:, 8], het_sim.op_x[:, 8])
    np.testing.assert_allclose(het_obs.dev_x[:, 4], het_sim.dev_x[:, 4])
    assert het_obs.op_x[:, 7].max() > 0


# ------------------------------------------------------------------ drift

def test_drift_detector_thresholds():
    det = DriftDetector(threshold=0.25, alpha=0.5, min_samples=1)
    ok = det.update("g", "t", 1.0, 1.1)
    assert not ok.drifted and ok.drift == pytest.approx(0.1)
    bad = det.update("g", "t", 1.0, 2.1)       # ewma = 1.6
    assert bad.drifted and bad.ewma == pytest.approx(1.6)
    det.reset("g", "t")
    assert det.update("g", "t", 1.0, 1.1).n_obs == 1


def test_drift_detector_min_samples_damps_single_spike():
    det = DriftDetector(threshold=0.25, min_samples=2)
    assert not det.update("g", "t", 1.0, 5.0).drifted   # one spike
    assert det.update("g", "t", 1.0, 5.0).drifted       # sustained


# ------------------------------------- observe -> invalidate -> replan

def test_observe_below_threshold_keeps_plan(gg, topo):
    svc = PlannerService(drift_threshold=0.25)
    resp = svc.plan_graph(gg, topo, iterations=6, seed=0)
    res = svc.observe(gg, topo, resp.time * 1.1)
    assert res.kind == "ok" and not res.report.drifted
    assert svc.store.get(resp.graph_fp, resp.topo_fp) is not None
    assert svc.stats()["replans"] == 0


def test_observe_without_plan_is_noop(gg, topo):
    svc = PlannerService()
    res = svc.observe(gg, topo, 1.0)
    assert res.kind == "no_plan"
    assert len(svc.measurements) == 1          # telemetry still logged


def test_observe_drift_evicts_and_replans(gg, topo):
    """ISSUE acceptance: a drifted observation round-trips through
    observe() -> invalidate -> warm re-search under the recalibrated
    model, to a plan no worse than the stale one re-scored there."""
    svc = PlannerService(drift_threshold=0.25)
    resp = svc.plan_graph(gg, topo, iterations=6, seed=0)

    true = _true_cluster(topo)
    tg = compile_strategy(gg, resp.strategy, topo,
                          sfb_plans=resp.sfb_plans)
    rec = execute_plan(tg, true, nominal_topo=topo)
    assert rec.wall_time > resp.time * 1.25    # scenario sanity

    res = svc.observe(gg, topo, rec, iterations=6)
    assert res.kind == "replanned" and res.report.drifted
    # stale record replaced IN PLACE: the refreshed plan (searched under
    # the calibrated model) is stored under the nominal deployment key,
    # so the next launch hits it and the next observation joins it
    assert res.response.graph_fp == resp.graph_fp
    assert res.response.topo_fp == resp.topo_fp
    refreshed = svc.store.get(resp.graph_fp, resp.topo_fp)
    assert refreshed is not None
    assert refreshed.time == pytest.approx(res.response.time)
    assert refreshed.time != pytest.approx(resp.time)
    assert res.response.source == "warm"
    # a follow-up observation consistent with the refreshed plan's
    # calibrated expectation is below threshold -> plan kept
    follow = svc.observe(gg, topo, res.response.time * 1.02, iterations=6)
    assert follow.kind == "ok"
    # replanned plan no worse than the stale plan under the calibrated
    # cost model, and the calibrated model tracks the observation
    assert res.response.time <= res.stale_time * (1 + 1e-9)
    assert res.improved
    calib = res.profile.apply(topo)
    assert simulate(tg, calib).makespan \
        == pytest.approx(rec.wall_time, rel=1e-6)
    assert svc.stats()["replans"] == 1 and svc.stats()["observations"] == 2


# ------------------------------------------- per-link-pair calibration

def _pair_records(topo, true_eff: dict, n: int = 10):
    from repro.core.profiler import transfer_time
    recs = []
    for i in range(n):
        colls = []
        for (gi, gj), eff in true_eff.items():
            nb = 1e6 * (1 + i % 3)
            colls.append({
                "kind": "xfer", "nbytes": nb, "n_dev": 2,
                "nominal_bw": topo.nominal_bw(gi, gj),
                "link": "p2p", "pair": f"{gi}-{gj}",
                "time": transfer_time(
                    nb, topo.nominal_bw(gi, gj) * eff, topo.latency)})
        recs.append(StepRecord(collectives=colls, step=i))
    return recs


def test_per_pair_calibration_fits_each_link(topo):
    """ISSUE satellite: once a (gi, gj) pair crosses the sample
    threshold it gets its own alpha/beta fit; ``apply`` routes it into
    ``Topology.bw`` while other pairs keep the class fallback."""
    true_eff = {(0, 1): 0.2, (0, 2): 0.45}
    recs = _pair_records(topo, true_eff)
    prof = fit_profile(recs, topo, min_pair_samples=8)
    assert set(prof.pairs) == {"0-1", "0-2"}
    for (gi, gj), eff in true_eff.items():
        assert prof.pairs[f"{gi}-{gj}"].eff == pytest.approx(eff, rel=1e-6)
    t2 = prof.apply(topo)
    assert t2.bw(0, 1) == pytest.approx(
        topo.nominal_bw(0, 1) * 0.2, rel=1e-6)
    assert t2.bw(0, 2) == pytest.approx(
        topo.nominal_bw(0, 2) * 0.45, rel=1e-6)
    # unobserved pair keeps the class-level efficiency
    assert t2.bw(1, 2) == pytest.approx(
        topo.nominal_bw(1, 2) * t2.p2p_eff, rel=1e-6)


def test_per_pair_calibration_falls_back_when_sparse(topo):
    """Below the volume threshold the pair tier stays empty and the
    per-link-class fit carries the signal (the pre-existing behavior)."""
    recs = _pair_records(topo, {(0, 1): 0.2}, n=5)
    prof = fit_profile(recs, topo, min_pair_samples=8)
    assert prof.pairs == {}
    assert prof.links["p2p"].eff == pytest.approx(0.2, rel=1e-6)
    assert prof.meta["pair_samples"] == {"0-1": 5}


def test_pair_profile_serialization_roundtrip(tmp_path, topo):
    recs = _pair_records(topo, {(0, 1): 0.3})
    prof = fit_profile(recs, topo, min_pair_samples=4)
    p = str(tmp_path / "prof.json")
    prof.save(p)
    prof2 = CalibrationProfile.load(p)
    assert set(prof2.pairs) == set(prof.pairs)
    assert prof2.pairs["0-1"].eff == pytest.approx(
        prof.pairs["0-1"].eff, rel=1e-12)
    assert prof2.apply(make_testbed()).pair_eff


def test_executor_records_pair_keys(topo, gg):
    """The TaskGraph replay executor tags p2p samples with the pair key
    the per-pair tier consumes."""
    from repro.core.strategy import Action, Option, Strategy
    strat = Strategy([Action((0, 1), Option.PS)] * gg.n)
    tg = compile_strategy(gg, strat, topo)
    rec = execute_plan(tg, topo)
    xfers = [c for c in rec.collectives if c["kind"] == "xfer"]
    assert xfers and all("pair" in c for c in xfers)
