"""Pallas kernel validation: shape/dtype sweeps against the pure-jnp
oracles in kernels/ref.py (interpret mode executes the kernel bodies on
CPU)."""
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import gqa_flash_attention, mamba_ssd
from repro.kernels.ref import ref_attention, ref_ssd
from repro.kernels.ssd_scan import ssd_scan

RNG = np.random.default_rng(0)


def _rand(shape, dtype):
    return jnp.asarray(RNG.standard_normal(shape), dtype)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 2e-5),
                                        (jnp.bfloat16, 3e-2)])
@pytest.mark.parametrize("S,hd,bq,bk", [
    (128, 64, 64, 64),
    (256, 64, 128, 64),
    (256, 32, 64, 128),
    (128, 128, 128, 128),
])
def test_flash_attention_causal(S, hd, bq, bk, dtype, atol):
    B, H = 2, 2
    q, k, v = (_rand((B, H, S, hd), dtype) for _ in range(3))
    o = flash_attention(q, k, v, causal=True, block_q=bq, block_k=bk)
    r = ref_attention(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(o, np.float32),
                               np.asarray(r, np.float32), atol=atol)


@pytest.mark.parametrize("window", [32, 64, 100])
def test_flash_attention_sliding_window(window):
    B, H, S, hd = 1, 2, 256, 32
    q, k, v = (_rand((B, H, S, hd), jnp.float32) for _ in range(3))
    o = flash_attention(q, k, v, causal=True, window=window,
                        block_q=64, block_k=64)
    r = ref_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_flash_attention_noncausal():
    B, H, S, hd = 1, 1, 128, 64
    q, k, v = (_rand((B, H, S, hd), jnp.float32) for _ in range(3))
    o = flash_attention(q, k, v, causal=False, block_q=64, block_k=64)
    r = ref_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


def test_gqa_wrapper_matches_model_attention():
    B, S, H, KV, hd = 2, 128, 4, 2, 32
    q = _rand((B, S, H, hd), jnp.float32)
    k = _rand((B, S, KV, hd), jnp.float32)
    v = _rand((B, S, KV, hd), jnp.float32)
    o = gqa_flash_attention(q, k, v, block_q=64, block_k=64)
    # reference: expand kv then full attention
    G = H // KV
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    r = ref_attention(q.transpose(0, 2, 1, 3), kh, vh).transpose(0, 2, 1, 3)
    np.testing.assert_allclose(np.asarray(o), np.asarray(r), atol=2e-5)


@pytest.mark.parametrize("dtype,atol", [(jnp.float32, 1e-4),
                                        (jnp.bfloat16, 1e-1)])
@pytest.mark.parametrize("S,nh,hd,ds,chunk", [
    (128, 2, 32, 16, 64),
    (256, 4, 64, 32, 128),
    (192, 1, 16, 8, 64),
])
def test_ssd_scan_vs_naive_recurrence(S, nh, hd, ds, chunk, dtype, atol):
    Bb = 2
    x = _rand((Bb, S, nh, hd), dtype)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (Bb, S, nh)), dtype)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    Bm = _rand((Bb, S, nh, ds), dtype)
    Cm = _rand((Bb, S, nh, ds), dtype)
    y = ssd_scan(x, dt, A, Bm, Cm, chunk=chunk)
    yr, _ = ref_ssd(x, dt, A, Bm, Cm)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32), atol=atol)


def test_ssd_kernel_matches_model_chunked_path():
    from repro.models.ssm import ssd_chunked
    Bb, S, nh, hd, ds = 1, 128, 2, 32, 16
    x = _rand((Bb, S, nh, hd), jnp.float32)
    dt = jnp.asarray(RNG.uniform(0.01, 0.2, (Bb, S, nh)), jnp.float32)
    A = jnp.asarray(-RNG.uniform(0.5, 2.0, (nh,)), jnp.float32)
    Bm = _rand((Bb, S, nh, ds), jnp.float32)
    Cm = _rand((Bb, S, nh, ds), jnp.float32)
    y = mamba_ssd(x, dt, A, Bm, Cm, chunk=64)
    y2, _ = ssd_chunked(x, dt, A, Bm, Cm, 64)
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), atol=1e-4)
