"""Live observability plane tests (repro.obs.collector / repro.obs.server
/ repro.runtime.feedback.RecalibrationLoop).

Cross-process span spool + incremental collector merge (including the
two-subprocess skewed-monotonic-clock alignment test), the served
/metrics / /healthz / /plans / /traces endpoints, the strict Prometheus
text-exposition parser, tracer drop-counter export, the `repro-plan
metrics --url/--watch` paths, the unattended recalibration loop, and the
end-to-end acceptance run: a planner request and a pipelined training
job in separate processes feeding one spool + telemetry dir, with the
serving process detecting drift and replanning with no manual observe.
"""
import json
import os
import subprocess
import sys
import textwrap
import time
import urllib.error
import urllib.request

import pytest

from repro.core.device import testbed as make_testbed
from repro.core.graph import CompGraph, OpNode, group_graph
from repro.core.strategy import Action, Option, Strategy
from repro.exec.schedule import make_schedule, simulate_schedule
from repro.exec.stages import build_stage_plan
from repro.obs import (
    MetricsRegistry, ObsServer, RunHealthAnalyzer, SpoolWriter,
    TraceCollector, Tracer, escape_label_value, export_tracer_metrics,
    parse_prometheus_text, set_tracer, shard_path, validate_chrome_trace)
from repro.runtime.feedback import RecalibrationLoop
from repro.runtime.telemetry import MeasurementStore, StepRecord
from repro.service.fingerprint import (
    fingerprint_grouped_cached, fingerprint_topology)
from repro.service.planner import PlannerService

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-3000:]
    return out.stdout


def _chain_gg(n_ops: int = 12, n_groups: int = 6, edge_bytes: float = 1e6):
    g = CompGraph(name="chain")
    for i in range(n_ops):
        g.add_node(OpNode(i, f"op{i}", "dot_general",
                          flops=1e9 * (1 + i % 3), bytes_out=edge_bytes,
                          param_bytes=4e5, grad_bytes=4e5,
                          is_grad_producer=True))
        if i:
            g.add_edge(i - 1, i, edge_bytes)
    assign = {i: i * n_groups // n_ops for i in range(n_ops)}
    return group_graph(g, assign)


_CHAIN_GG_SRC = '''
def _chain_gg(n_ops=12, n_groups=6, edge_bytes=1e6):
    from repro.core.graph import CompGraph, OpNode, group_graph
    g = CompGraph(name="chain")
    for i in range(n_ops):
        g.add_node(OpNode(i, f"op{i}", "dot_general",
                          flops=1e9 * (1 + i % 3), bytes_out=edge_bytes,
                          param_bytes=4e5, grad_bytes=4e5,
                          is_grad_producer=True))
        if i:
            g.add_edge(i - 1, i, edge_bytes)
    return group_graph(g, {i: i * n_groups // n_ops for i in range(n_ops)})
'''


def _get(url: str, timeout: float = 30.0) -> bytes:
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.read()


# ------------------------------------------------------- spool + collector

def test_spool_shard_naming_and_anchor_guard(tmp_path):
    spool = str(tmp_path)
    w = SpoolWriter(spool, run_id="r/1", name="train worker", pid=42)
    assert w.path == shard_path(spool, "r/1", "train worker", 42)
    assert os.path.basename(w.path) == "r_1--train_worker-42.jsonl"
    # a second writer on the same (run_id, name, pid) must NOT write a
    # second anchor line into the existing shard
    SpoolWriter(spool, run_id="r/1", name="train worker", pid=42,
                anchor=(999.0, 999.0))
    lines = [json.loads(s) for s in
             open(w.path).read().splitlines() if s.strip()]
    assert [r["type"] for r in lines] == ["anchor"]
    assert lines[0]["wall"] != 999.0


def test_collector_incremental_poll_truncation_and_bad_lines(tmp_path):
    spool = str(tmp_path)
    w = SpoolWriter(spool, run_id="run", name="p", anchor=(100.0, 0.0))
    w.emit_track(0, "main")
    w.emit_span("a", 1.0, 2.0)
    c = TraceCollector(spool)
    assert c.poll() == 3                       # anchor + track + span
    assert c.poll() == 0                       # nothing new
    w.emit_span("b", 2.0, 3.0)
    # a torn (incomplete) trailing line stays buffered until completed
    with open(w.path, "a") as f:
        f.write('{"type": "span", "name": "torn"')
    assert c.poll() == 1                       # only the complete "b"
    with open(w.path, "a") as f:
        f.write(', "t0": 3.0, "t1": 4.0, "tid": 0, "cat": "s"}\n')
        f.write("not json at all\n")
        f.write('{"type": "mystery", "x": 1}\n')
    assert c.poll() == 1                       # completed "torn" span only
    assert c.counts() == {"shards": 1, "spans": 3, "bad_lines": 2,
                          "runs": 1}
    # truncation resets the cursor and replays the shard from scratch
    with open(w.path, "w") as f:
        f.write(json.dumps({"type": "anchor", "run_id": "run",
                            "process": "p", "pid": w.pid,
                            "wall": 100.0, "mono": 0.0}) + "\n")
        f.write(json.dumps({"type": "span", "name": "fresh", "cat": "s",
                            "tid": 0, "t0": 0.5, "t1": 0.75,
                            "args": {}}) + "\n")
    assert c.poll() == 2
    assert c.counts()["spans"] == 1
    assert [s["name"] for sh in c.shards("run") for s in sh.spans] \
        == ["fresh"]


def test_collector_skew_alignment_deterministic(tmp_path):
    """Two shards whose monotonic clocks disagree by 1000s but whose
    wall clocks are 0.5s apart merge in true wall order."""
    spool = str(tmp_path)
    a = SpoolWriter(spool, run_id="r", name="procA", pid=11,
                    anchor=(100.0, 0.0))
    b = SpoolWriter(spool, run_id="r", name="procB", pid=22,
                    anchor=(100.5, 1000.0))
    a.emit_track(0, "stage 0")
    a.emit_span("early", 0.0, 0.1, tid=0)
    b.emit_span("late", 1000.0, 1000.2, tid=0)  # wall 100.5: 0.5s later
    c = TraceCollector(spool)
    c.poll()
    doc = c.chrome("r")
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert [e["name"] for e in spans] == ["early", "late"]
    assert spans[0]["ts"] == 0.0
    assert spans[1]["ts"] == pytest.approx(0.5e6)      # µs, wall-aligned
    metas = {(e["name"], e["pid"]): e["args"]["name"]
             for e in doc["traceEvents"] if e["ph"] == "M"}
    assert metas[("process_name", 0)] == "procA (pid 11)"
    assert metas[("process_name", 1)] == "procB (pid 22)"
    assert metas[("thread_name", 0)] == "stage 0"      # named track
    assert metas[("thread_name", 1)] == "track 0"      # default name


def test_two_subprocess_skewed_shards_merge(tmp_path):
    """Satellite: shards written by two real OS processes with injected
    skewed monotonic clocks merge into one schema-valid Chrome trace
    with correct cross-process ordering and pid/tid metadata."""
    spool = str(tmp_path / "spool")
    writer = """
        import sys
        from repro.obs.collector import SpoolWriter
        spool, name, pid, wall, mono = sys.argv[1:6]
        w = SpoolWriter(spool, run_id="e2e", name=name, pid=int(pid),
                        anchor=(float(wall), float(mono)))
        w.emit_track(0, name + " work")
        for i in range(3):
            t0 = float(mono) + 0.1 * i
            w.emit_span(f"{name}-{i}", t0, t0 + 0.05, tid=0, cat="smoke")
        print("WROTE", w.path)
    """
    env = dict(os.environ, PYTHONPATH=SRC)
    procs = [subprocess.Popen(
        [sys.executable, "-c", textwrap.dedent(writer), spool,
         name, pid, wall, mono],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=env)
        for name, pid, wall, mono in (
            ("alpha", "101", "5000.0", "0.0"),
            # beta's monotonic clock is 7000s AHEAD, but its events start
            # 0.05s of wall time after alpha's i=0 span
            ("beta", "202", "5000.05", "7000.0"))]
    for p in procs:
        out, err = p.communicate(timeout=120)
        assert p.returncode == 0, err[-2000:]
        assert "WROTE" in out
    c = TraceCollector(spool)
    assert c.poll() == 2 * (1 + 1 + 3)
    doc = c.chrome("e2e")
    validate_chrome_trace(doc)
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    # true wall order interleaves the two processes despite the skew
    assert [e["name"] for e in spans] == [
        "alpha-0", "beta-0", "alpha-1", "beta-1", "alpha-2", "beta-2"]
    assert spans[1]["ts"] == pytest.approx(0.05e6, abs=1.0)
    by_pid = {e["args"]["name"]: e["pid"] for e in doc["traceEvents"]
              if e["ph"] == "M" and e["name"] == "process_name"}
    assert by_pid == {"alpha (pid 101)": 0, "beta (pid 202)": 1}
    thread_names = {(e["pid"], e["args"]["name"])
                    for e in doc["traceEvents"]
                    if e["ph"] == "M" and e["name"] == "thread_name"}
    assert thread_names == {(0, "alpha work"), (1, "beta work")}


def test_emit_tracer_incremental(tmp_path):
    tr = Tracer(enabled=True)
    with tr.span("s1", cat="c"):
        pass
    w = SpoolWriter(str(tmp_path), run_id="t", name="tr")
    assert w.emit_tracer(tr) == 1
    assert w.emit_tracer(tr) == 0              # nothing new
    with tr.span("s2", cat="c"):
        pass
    assert w.emit_tracer(tr) == 1
    c = TraceCollector(str(tmp_path))
    c.poll()
    names = [s["name"] for sh in c.shards("t") for s in sh.spans]
    assert names == ["s1", "s2"]


# ------------------------------------------------- spool shard retention

def test_spool_gc_never_deletes_undrained_shard(tmp_path):
    """Satellite invariant: GC deletes a shard file only after the
    collector has consumed every byte of it — a torn trailing line
    means undrained, so the file survives any retention budget."""
    spool = str(tmp_path)
    a = SpoolWriter(spool, run_id="r", name="drained", pid=1,
                    anchor=(100.0, 0.0))
    a.emit_span("done-a", 0.0, 1.0)
    b = SpoolWriter(spool, run_id="r", name="torn", pid=2,
                    anchor=(100.0, 0.0))
    b.emit_span("done-b", 0.0, 1.0)
    with open(b.path, "a") as f:
        f.write('{"type": "span", "name": "tail"')     # torn: no newline
    c = TraceCollector(spool)
    c.poll()
    old = time.time() - 3600
    for p in (a.path, b.path):
        os.utime(p, (old, old))
    res = c.gc(max_age_s=60)
    assert res["deleted"] == 1
    assert not os.path.exists(a.path)                  # drained: deleted
    assert os.path.exists(b.path)                      # undrained: kept
    # even the harshest budgets never touch an undrained shard
    res = c.gc(max_age_s=0, max_bytes=0)
    assert res["deleted"] == 0 and os.path.exists(b.path)
    # collected spans keep rendering after their shard file is gone
    assert c.counts()["spans"] == 2
    names = sorted(s["name"] for sh in c.shards("r") for s in sh.spans)
    assert names == ["done-a", "done-b"]
    # completing the torn line drains the shard and makes it deletable
    with open(b.path, "a") as f:
        f.write(', "t0": 1.0, "t1": 2.0, "tid": 0, "cat": "s"}\n')
    os.utime(b.path, (old, old))
    res = c.gc(max_age_s=60)
    assert res["deleted"] == 1 and not os.path.exists(b.path)
    assert c.counts()["spans"] == 3                    # "tail" collected


def test_spool_gc_byte_budget_drops_oldest_first(tmp_path):
    spool = str(tmp_path)
    writers = []
    now = time.time()
    for i in range(3):
        w = SpoolWriter(spool, run_id="r", name=f"p{i}", pid=10 + i,
                        anchor=(100.0, 0.0))
        w.emit_span(f"s{i}", 0.0, 1.0)
        writers.append(w)
    c = TraceCollector(spool)
    c.poll()
    for i, w in enumerate(writers):                    # p0 is the oldest
        t = now - 1000 + i * 100
        os.utime(w.path, (t, t))
    sizes = {w.path: os.path.getsize(w.path) for w in writers}
    budget = sizes[writers[1].path] + sizes[writers[2].path]
    res = c.gc(max_bytes=budget)
    assert res["deleted"] == 1
    assert res["bytes_freed"] == sizes[writers[0].path]
    assert not os.path.exists(writers[0].path)
    assert os.path.exists(writers[1].path)
    assert os.path.exists(writers[2].path)
    res = c.gc(max_bytes=0)
    assert res["deleted"] == 2
    # the merged trace still renders all three processes from memory
    doc = c.chrome("r")
    spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert sorted(e["name"] for e in spans) == ["s0", "s1", "s2"]


def test_served_metrics_runs_spool_gc(tmp_path):
    """serve-metrics retention wiring: a /metrics scrape GCs drained
    shards past the budget and exports the reclamation counters."""
    spool = str(tmp_path)
    w = SpoolWriter(spool, run_id="r", name="p", pid=3,
                    anchor=(100.0, 0.0))
    w.emit_span("a", 0.0, 1.0)
    c = TraceCollector(spool)
    c.poll()
    old = time.time() - 100
    os.utime(w.path, (old, old))
    size = os.path.getsize(w.path)
    with ObsServer(collector=c, spool_max_age_s=1.0) as srv:
        body = _get(srv.url + "/metrics").decode()
    assert not os.path.exists(w.path)
    fams = parse_prometheus_text(body)
    assert fams["collector_spool_gc_deleted_total"]["samples"][0][2] \
        == 1.0
    assert fams["collector_spool_gc_bytes_total"]["samples"][0][2] \
        == float(size)


# --------------------------------------------- prometheus text exposition

def test_prometheus_label_escaping_roundtrip():
    reg = MetricsRegistry()
    weird = 'we"ird\\x\nnewline'
    reg.counter("odd_total", 'help with \\ and\nnewline').inc(3, tag=weird)
    text = reg.to_prometheus()
    fams = parse_prometheus_text(text)
    assert fams["odd_total"]["kind"] == "counter"
    [(name, labels, value)] = fams["odd_total"]["samples"]
    assert labels == {"tag": weird}            # exact round-trip
    assert value == 3.0
    assert escape_label_value(weird) == 'we\\"ird\\\\x\\nnewline'


def test_prometheus_parser_histogram_folding_and_infinities():
    text = textwrap.dedent("""\
        # HELP lat_seconds latency
        # TYPE lat_seconds histogram
        lat_seconds_bucket{le="0.1"} 1
        lat_seconds_bucket{le="+Inf"} 2
        lat_seconds_sum 0.3
        lat_seconds_count 2
        # TYPE bare untyped
        bare 4
    """)
    fams = parse_prometheus_text(text)
    assert fams["lat_seconds"]["kind"] == "histogram"
    names = {s[0] for s in fams["lat_seconds"]["samples"]}
    assert names == {"lat_seconds_bucket", "lat_seconds_sum",
                     "lat_seconds_count"}
    inf_sample = [s for s in fams["lat_seconds"]["samples"]
                  if s[1].get("le") == "+Inf"]
    assert inf_sample and inf_sample[0][2] == 2.0


@pytest.mark.parametrize("bad", [
    "# TYPE x flavor\nx 1\n",                  # unknown TYPE
    "metric{9bad=\"v\"} 1\n",                  # invalid label name
    "metric{a=\"v} 1\n",                       # unterminated quote
    "metric{a=\"v\\\"} 1\n",                   # dangling escape
    "metric oops\n",                           # non-numeric value
    "# TYPE x counter\n# TYPE x gauge\nx 1\n",  # duplicate TYPE
    "# TYPE h histogram\nh_sum 1\nh_count 1\n",  # histogram w/o buckets
    "9metric 1\n",                             # invalid metric name
])
def test_prometheus_parser_rejects(bad):
    with pytest.raises(ValueError):
        parse_prometheus_text(bad)


def test_tracer_dropped_exported_as_counter():
    reg = MetricsRegistry()
    tr = Tracer(enabled=True, max_spans=2)
    for i in range(5):
        with tr.span(f"s{i}"):
            pass
    assert tr.dropped == 3
    c = export_tracer_metrics(reg, tr)
    assert c.value() == 3.0
    export_tracer_metrics(reg, tr)             # idempotent: no delta
    assert c.value() == 3.0
    with tr.span("s5"):
        pass
    export_tracer_metrics(reg, tr)
    assert c.value() == 4.0
    fams = parse_prometheus_text(reg.to_prometheus())
    assert fams["tracer_dropped_spans_total"]["kind"] == "counter"
    assert fams["tracer_buffered_spans"]["samples"][0][2] == 2.0


# ------------------------------------------------------------ HTTP server

def test_obs_server_endpoints(tmp_path):
    spool_dir = str(tmp_path / "spool")
    svc = PlannerService(cache_dir=str(tmp_path / "plans"))
    spool = SpoolWriter(spool_dir, run_id="srv", name="test")
    spool.emit_span("hello", 1.0, 2.0, tid=0)
    with ObsServer(service=svc, collector=TraceCollector(spool_dir),
                   spool=spool) as server:
        text = _get(server.url + "/metrics").decode()
        fams = parse_prometheus_text(text)
        assert "planner_requests_total" in fams
        assert "planner_store_size" in fams
        assert "collector_spool_shards" in fams
        assert "tracer_dropped_spans_total" in fams

        health = json.loads(_get(server.url + "/healthz"))
        assert health["status"] == "ok"
        assert health["collector"]["spans"] >= 1
        assert health["requests"] >= 1

        plans = json.loads(_get(server.url + "/plans"))
        assert plans["store_size"] == 0

        runs = json.loads(_get(server.url + "/traces"))
        assert "srv" in runs["runs"]
        doc = json.loads(_get(server.url + "/traces/srv"))
        validate_chrome_trace(doc)
        assert any(e["ph"] == "X" and e["name"] == "hello"
                   for e in doc["traceEvents"])

        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(server.url + "/traces/nope")
        assert ei.value.code == 404
        assert "srv" in json.loads(ei.value.read())["runs"]
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(server.url + "/bogus")
        assert ei.value.code == 404
        index = json.loads(_get(server.url + "/"))
        assert "/metrics" in index["endpoints"]
    # port released after stop(): a request must now fail to connect
    with pytest.raises(OSError):
        _get(server.url + "/healthz", timeout=2)


def test_cli_metrics_url_and_watch(tmp_path, capsys):
    from repro.service.cli import main
    svc = PlannerService(cache_dir=str(tmp_path / "plans"))
    with ObsServer(service=svc) as server:
        rc = main(["metrics", "--url", server.url])
        assert rc == 0
        out = capsys.readouterr().out
        assert "# TYPE planner_store_size gauge" in out
        parse_prometheus_text(out)
        rc = main(["metrics", "--url", server.url, "--format", "json"])
        assert rc == 0
        assert "store_size" in json.loads(capsys.readouterr().out)
    rc = main(["metrics", "--cache-dir", str(tmp_path / "plans"),
               "--watch", "0.01", "--watch-count", "3"])
    assert rc == 0
    dumps = capsys.readouterr().out.count("planner_store_size 0")
    assert dumps == 3


# ----------------------------------------------------- recalibration loop

def test_recalibration_loop_poll_once_detects_drift(tmp_path):
    tele = str(tmp_path / "telemetry")
    svc = PlannerService(cache_dir=str(tmp_path / "plans"),
                         telemetry_dir=tele)
    gg, topo = _chain_gg(), make_testbed()
    res = svc.plan_graph(gg, topo, iterations=8, seed=0)
    loop = RecalibrationLoop(svc, interval_s=60.0, iterations=8)
    key = loop.watch(gg, topo)

    # an EXTERNAL writer appends a drifted step to the shared telemetry
    # dir — 3x the planned time, far past the 0.25 drift threshold
    ext = MeasurementStore(tele)
    ext.append(StepRecord(graph_fp=key[0], topo_fp=key[1], step=0,
                          wall_time=res.time * 3.0))
    before = len(svc.measurements.records())
    assert [r.kind for r in loop.poll_once()] == ["replanned"]
    # append=False: the polled record must not be written back
    assert len(svc.measurements.records()) == before
    assert loop.poll_once() == []              # read_new cursor advanced
    # unwatched fingerprints are counted, not observed
    ext.append(StepRecord(graph_fp="other", topo_fp="other",
                          wall_time=1.0))
    assert loop.poll_once() == []
    st = loop.stats()
    assert st["records"]["replanned"] == 1
    assert st["records"]["unwatched"] == 1
    assert st["polls"] == 3 and not st["running"]
    # calibration gauges published from the refit profile
    fams = parse_prometheus_text(svc.metrics.to_prometheus())
    assert "recalib_records_total" in fams
    assert "calibration_utilization" in fams


def test_recalibration_background_thread(tmp_path):
    tele = str(tmp_path / "telemetry")
    svc = PlannerService(cache_dir=str(tmp_path / "plans"),
                         telemetry_dir=tele)
    gg, topo = _chain_gg(), make_testbed()
    res = svc.plan_graph(gg, topo, iterations=8, seed=0)
    loop = RecalibrationLoop(svc, interval_s=0.05, iterations=8)
    key = loop.watch(gg, topo)
    loop.start()
    try:
        assert loop.running
        MeasurementStore(tele).append(StepRecord(
            graph_fp=key[0], topo_fp=key[1], wall_time=res.time * 3.0))
        deadline = time.time() + 30
        while time.time() < deadline:
            if loop.stats()["records"].get("replanned", 0) >= 1:
                break
            time.sleep(0.05)
        assert loop.stats()["records"]["replanned"] >= 1
    finally:
        loop.stop()
    assert not loop.running


# ---------------------------------------------------- streaming /traces

def _get_with_headers(url: str, timeout: float = 30.0):
    with urllib.request.urlopen(url, timeout=timeout) as r:
        return r.read(), dict(r.headers)


def test_trace_streaming_past_size_threshold(tmp_path):
    """Satellite: past ``trace_stream_events`` merged spans the server
    streams /traces/<run_id> chunked (no Content-Length buffered body);
    the streamed document is byte-for-byte JSON-equal to the buffered
    one, and small runs keep the buffered path."""
    spool_dir = str(tmp_path)
    big = SpoolWriter(spool_dir, run_id="big", name="p",
                      anchor=(100.0, 0.0))
    for i in range(40):
        big.emit_span(f"s{i}", 0.1 * i, 0.1 * i + 0.05, tid=0)
    small = SpoolWriter(spool_dir, run_id="small", name="p",
                        anchor=(100.0, 0.0))
    small.emit_span("only", 0.0, 1.0, tid=0)
    collector = TraceCollector(spool_dir)
    with ObsServer(collector=collector, trace_stream_events=10) as srv:
        body, headers = _get_with_headers(srv.url + "/traces/big")
        assert headers.get("Transfer-Encoding") == "chunked"
        assert "Content-Length" not in headers
        doc = json.loads(body)
        validate_chrome_trace(doc)
        assert doc == collector.chrome("big")
        assert sum(1 for e in doc["traceEvents"]
                   if e["ph"] == "X") == 40
        body, headers = _get_with_headers(srv.url + "/traces/small")
        assert "Content-Length" in headers
        assert headers.get("Transfer-Encoding") != "chunked"
        validate_chrome_trace(json.loads(body))
        # an unknown run 404s regardless of the threshold
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/traces/nope")
        assert ei.value.code == 404


def test_collector_chrome_stream_matches_chrome(tmp_path):
    w = SpoolWriter(str(tmp_path), run_id="r", name="p",
                    anchor=(100.0, 0.0))
    for i in range(7):
        w.emit_span(f"s{i}", float(i), i + 0.5, tid=0)
    c = TraceCollector(str(tmp_path))
    c.poll()
    assert c.span_count("r") == 7
    assert c.span_count() == 7                     # all runs
    streamed = "".join(c.chrome_stream("r", chunk_events=3))
    assert json.loads(streamed) == c.chrome("r")
    with pytest.raises(KeyError):                  # eager, not mid-stream
        c.chrome_stream("missing")


# ------------------------------------------- served verify diagnostics

def test_served_plans_verify_detail(tmp_path):
    svc = PlannerService(cache_dir=str(tmp_path / "plans"))
    gg, topo = _chain_gg(), make_testbed()
    resp = svc.plan_graph(gg, topo, iterations=8, seed=0)
    with ObsServer(service=svc) as srv:
        plans = json.loads(_get(srv.url + "/plans"))
        [entry] = plans["plans"]
        assert entry["graph_fp"] == resp.graph_fp
        assert entry["verify"] == resp.verify
        assert isinstance(entry["verify_diagnostics"], list)
        detail = json.loads(
            _get(srv.url + f"/plans/{resp.graph_fp[:16]}/verify"))
        [match] = detail["matches"]
        assert match["graph_fp"] == resp.graph_fp
        assert match["verify_diagnostics"] == entry["verify_diagnostics"]
        # the combined <graph24>-<topo24> store-file form matches too
        combined = f"{resp.graph_fp[:24]}-{resp.topo_fp[:24]}"
        detail = json.loads(_get(srv.url + f"/plans/{combined}/verify"))
        assert len(detail["matches"]) == 1
        with pytest.raises(urllib.error.HTTPError) as ei:
            _get(srv.url + "/plans/zzzznothing/verify")
        assert ei.value.code == 404
        assert json.loads(ei.value.read())["plans"]


# ------------------------------------------------------------- end-to-end

def test_live_obs_e2e_cross_process(tmp_path):
    """Acceptance: a planner request and a pipelined training job run in
    SEPARATE processes against one spool + telemetry + plan-cache dir.
    The serving process exposes live planner/calibration/span-drop
    series on /metrics, merges both processes' events into one aligned
    /traces document, and its recalibration loop — fed only by
    ``read_new()`` polling — detects the injected drift and replans
    without any manual ``observe`` call."""
    cache = str(tmp_path / "plans")
    tele = str(tmp_path / "telemetry")
    spool_dir = str(tmp_path / "spool")

    # process 1: plans via a PlannerService against the shared cache and
    # spools its tracer spans
    _run_subprocess(_CHAIN_GG_SRC + textwrap.dedent(f"""
        from repro.obs import SpoolWriter, get_tracer
        from repro.core.device import testbed
        from repro.service.planner import PlannerService

        get_tracer().enable()
        svc = PlannerService(cache_dir={cache!r})
        res = svc.plan_graph(_chain_gg(), testbed(), iterations=8, seed=0)
        w = SpoolWriter({spool_dir!r}, run_id="e2e", name="planner")
        assert w.emit_tracer(get_tracer()) > 0
        print("PLANNED", res.time)
    """))

    # process 2: executes the planned pipeline (replay engine), streams
    # its stage events into the same spool, and appends a DRIFTED step
    # record (3x the planned time) to the shared telemetry dir
    _run_subprocess(_CHAIN_GG_SRC + textwrap.dedent(f"""
        from repro.core.device import testbed
        from repro.core.strategy import Action, Option, Strategy
        from repro.exec.replay import execute_pipeline
        from repro.exec.stages import build_stage_plan
        from repro.obs import SpoolWriter
        from repro.runtime.telemetry import MeasurementStore
        from repro.service.planner import PlannerService
        from repro.service.fingerprint import (
            fingerprint_grouped_cached, fingerprint_topology)

        gg, topo = _chain_gg(), testbed()
        svc = PlannerService(cache_dir={cache!r})
        res = svc.plan_graph(gg, topo, iterations=8, seed=0)
        assert svc.stats()["hits"] >= 1        # read process 1's plan
        strat = Strategy([Action((0, 1, 5), Option.PIPE) if i % 2 == 0
                          else Action((0, 1, 5), Option.PS)
                          for i in range(gg.n)])
        plan = build_stage_plan(gg, strat, topo, n_micro=8)
        spool = SpoolWriter({spool_dir!r}, run_id="e2e", name="train")
        rec, _ = execute_pipeline(
            plan, topo, schedule="1f1b", step=0, spool=spool,
            graph_fp=fingerprint_grouped_cached(gg),
            topo_fp=fingerprint_topology(topo))
        rec.wall_time = res.time * 3.0         # inject drift
        MeasurementStore({tele!r}).append(rec)
        print("TRAINED")
    """))

    # serving process: same cache (plan visible via the store's disk
    # fallthrough), same telemetry dir, recalibration poller + server
    svc = PlannerService(cache_dir=cache, telemetry_dir=tele)
    gg, topo = _chain_gg(), make_testbed()
    tr = Tracer(enabled=True, max_spans=1)
    set_tracer(tr)
    try:
        with tr.span("a"):
            pass
        with tr.span("b"):                     # overflow -> dropped > 0
            pass
        loop = RecalibrationLoop(svc, interval_s=0.1, iterations=8)
        loop.watch(gg, topo)
        with ObsServer(service=svc, collector=TraceCollector(spool_dir),
                       recalib=loop) as server:
            deadline = time.time() + 60
            fams = {}
            while time.time() < deadline:
                fams = parse_prometheus_text(
                    _get(server.url + "/metrics").decode())
                obs = {s[1].get("outcome"): s[2] for s in
                       fams.get("planner_observations_total",
                                {"samples": []})["samples"]}
                if obs.get("replanned", 0) >= 1:
                    break
                time.sleep(0.1)
            assert obs.get("replanned", 0) >= 1, dict(fams)

            # live planner + recalibration + calibration + drop series
            assert "planner_requests_total" in fams
            assert "planner_drift_ratio" in fams
            assert "recalib_records_total" in fams
            assert "calibration_utilization" in fams
            assert fams["tracer_dropped_spans_total"]["samples"][0][2] \
                >= 1.0
            assert fams["collector_spool_shards"]["samples"][0][2] == 2.0

            doc = json.loads(_get(server.url + "/traces/e2e"))
            validate_chrome_trace(doc)
            procs = {e["args"]["name"] for e in doc["traceEvents"]
                     if e["ph"] == "M" and e["name"] == "process_name"}
            assert len(procs) == 2 and \
                {p.split(" ")[0] for p in procs} == {"planner", "train"}
            spans = [e for e in doc["traceEvents"] if e["ph"] == "X"]
            by_proc = {}
            for e in spans:
                by_proc.setdefault(e["args"]["process"], []).append(e)
            assert by_proc.keys() == {"planner", "train"}
            assert all(e["ts"] >= 0 for e in spans)
            ts = [e["ts"] for e in spans]
            assert ts == sorted(ts)            # aligned, merged order
            # pipeline events carry their schedule-position names
            assert any(e["name"].startswith("F0.") for e in
                       by_proc["train"])
        assert loop.stats()["records"]["replanned"] >= 1
        assert not loop.running                # server.stop stopped it
    finally:
        set_tracer(Tracer())


def test_health_e2e_cross_process_straggler(tmp_path):
    """Acceptance: a training process executes two pipelined workloads
    against a TRUE topology whose stage-1 -> stage-2 link for runA runs
    at 1/3 bandwidth, appending step records to a shared telemetry dir.
    The serving process — holding only the NOMINAL predicted timelines —
    must attribute runA's dominant residual to that exact edge on
    /runs/runA/health, surface a firing page on /alerts, leave runB
    quiet, and have its recalibration loop drain runA's watched key
    before runB's."""
    cache = str(tmp_path / "plans")
    tele = str(tmp_path / "telemetry")
    topo = make_testbed()
    ggA = _chain_gg(12, 6, edge_bytes=4e6)
    ggB = _chain_gg(10, 5, edge_bytes=4e6)

    def _pipeline(gg):
        strat = Strategy([Action((0, 1, 5), Option.PIPE) if i % 2 == 0
                          else Action((0, 1, 5), Option.PS)
                          for i in range(gg.n)])
        plan = build_stage_plan(gg, strat, topo, n_micro=8)
        tl = simulate_schedule(plan, topo, make_schedule(
            "1f1b", plan.n_stages, plan.n_micro))
        return plan, tl

    _, tlA = _pipeline(ggA)
    _, tlB = _pipeline(ggB)

    # training process: rebuilds the same deterministic plans, slows the
    # stage1->2 forward link for runA only, interleaves 6 steps of each
    _run_subprocess(_CHAIN_GG_SRC + textwrap.dedent(f"""
        import copy
        from repro.core.device import testbed
        from repro.core.strategy import Action, Option, Strategy
        from repro.exec.replay import execute_pipeline
        from repro.exec.stages import build_stage_plan
        from repro.runtime.telemetry import MeasurementStore
        from repro.service.fingerprint import (
            fingerprint_grouped_cached, fingerprint_topology)

        topo = testbed()
        store = MeasurementStore({tele!r})
        jobs = []
        for rid, gg in (("runA", _chain_gg(12, 6, edge_bytes=4e6)),
                        ("runB", _chain_gg(10, 5, edge_bytes=4e6))):
            strat = Strategy([Action((0, 1, 5), Option.PIPE) if i % 2 == 0
                              else Action((0, 1, 5), Option.PS)
                              for i in range(gg.n)])
            plan = build_stage_plan(gg, strat, topo, n_micro=8)
            true = topo
            if rid == "runA":
                true = copy.deepcopy(topo)
                g1 = plan.stages[1].device_group
                g2 = plan.stages[2].device_group
                true.inter_bw[g1, g2] /= 3.0   # directional straggler
            jobs.append((rid, gg, plan, true))
        for step in range(6):
            for rid, gg, plan, true in jobs:
                rec, _ = execute_pipeline(
                    plan, true, schedule="1f1b", step=step,
                    graph_fp=fingerprint_grouped_cached(gg),
                    topo_fp=fingerprint_topology(topo),
                    meta={{"run_id": rid}})
                store.append(rec)
        print("TRAINED")
    """))

    # serving process: nominal timelines, tight SLO for runA, slack for
    # runB; the analyzer rides its own cursor over the telemetry dir
    svc = PlannerService(cache_dir=cache, telemetry_dir=tele)
    keyA = (fingerprint_grouped_cached(ggA), fingerprint_topology(topo))
    keyB = (fingerprint_grouped_cached(ggB), fingerprint_topology(topo))
    analyzer = RunHealthAnalyzer(MeasurementStore(tele))
    analyzer.watch("runA", timeline=tlA, slo_s=tlA.makespan * 1.05,
                   graph_fp=keyA[0], topo_fp=keyA[1])
    analyzer.watch("runB", timeline=tlB, slo_s=tlB.makespan * 1.5,
                   graph_fp=keyB[0], topo_fp=keyB[1])
    loop = RecalibrationLoop(svc, interval_s=0.1, iterations=8,
                             health=analyzer)
    loop.watch(ggA, topo)
    loop.watch(ggB, topo)
    loop.poll_once()

    with ObsServer(service=svc, health=analyzer) as srv:
        runs = json.loads(_get(srv.url + "/runs"))["runs"]
        assert [r["run_id"] for r in runs] == ["runA", "runB"]

        h = json.loads(_get(srv.url + "/runs/runA/health"))
        assert h["mode"] == "predicted"
        assert h["step_ratio"] > 1.05
        assert h["dominant"]["cause"] == "link"
        assert h["dominant"]["key"] == "1->2"   # the slowed edge, named
        assert [s["key"] for s in h["stragglers"]] == ["1->2"]
        assert {(a["rule"], a["state"]) for a in h["alerts"]} == {
            ("slo_fast_burn", "firing"), ("slo_slow_burn", "firing")}

        hb = json.loads(_get(srv.url + "/runs/runB/health"))
        assert hb["step_ratio"] == pytest.approx(1.0, abs=0.05)
        assert hb["stragglers"] == []
        assert all(a["state"] == "ok" for a in hb["alerts"])

        alerts = json.loads(_get(srv.url + "/alerts"))["alerts"]
        assert alerts[0]["run_id"] == "runA"
        assert alerts[0]["severity"] == "page"
        assert alerts[0]["state"] == "firing"

        # the health series ride the scrape
        fams = parse_prometheus_text(_get(srv.url + "/metrics").decode())
        ratios = {s[1]["run"]: s[2]
                  for s in fams["run_health_step_ratio"]["samples"]}
        assert ratios["runA"] > 1.05
        assert ratios["runB"] == pytest.approx(1.0, abs=0.05)

    # the drifted workload was drained before the healthy one
    order = loop.stats()["last_order"]
    assert order[0] == [keyA[0][:12], keyA[1][:12]]
    assert order[1] == [keyB[0][:12], keyB[1][:12]]
