"""Per-architecture smoke tests (deliverable f): a REDUCED variant of each
assigned architecture runs one forward + one train step on CPU; output
shapes and finiteness asserted. Full configs are exercised only via the
dry-run (ShapeDtypeStructs, no allocation)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import ARCH_IDS, config_for_shape, get_config, get_reduced
from repro.launch import steps as steps_mod
from repro.models import decode_step, init_cache, init_params
from repro.optim.adam import AdamW
from repro.parallel.sharding import AxisRules


def _batch(cfg, B=2, S=16, key=0):
    ks = jax.random.split(jax.random.PRNGKey(key), 3)
    b = {"tokens": jax.random.randint(ks[0], (B, S), 0, cfg.vocab_size),
         "labels": jax.random.randint(ks[1], (B, S), 0, cfg.vocab_size)}
    if cfg.frontend != "none":
        b["prefix"] = jax.random.normal(
            ks[2], (B, cfg.frontend_tokens, cfg.d_model), jnp.bfloat16)
    return b


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_forward_and_train_step(arch):
    cfg = get_reduced(arch)
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    assert cfg.num_experts <= 4
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = _batch(cfg)
    opt = AdamW(lr=1e-3)
    opt_state = opt.init(params)
    step = jax.jit(steps_mod.make_train_step(cfg, opt, AxisRules()))
    new_params, new_opt, metrics = step(
        params, opt_state, jnp.asarray(0, jnp.int32), batch)
    assert jnp.isfinite(metrics["loss"])
    # params must change
    delta = jax.tree.leaves(jax.tree.map(
        lambda a, b: float(jnp.max(jnp.abs(a.astype(jnp.float32)
                                           - b.astype(jnp.float32)))),
        params, new_params))
    assert max(delta) > 0


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_smoke_decode_step(arch):
    cfg = get_reduced(arch)
    params = init_params(cfg, jax.random.PRNGKey(0))
    B = 2
    cache = init_cache(cfg, B, 32)
    tok = jnp.ones((B, 1), jnp.int32)
    logits, cache2 = jax.jit(
        lambda p, c, t: decode_step(cfg, p, c, t, 3))(params, cache, tok)
    assert logits.shape == (B, 1, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    # cache tree structure preserved
    assert jax.tree.structure(cache) == jax.tree.structure(cache2)


@pytest.mark.parametrize("arch", ARCH_IDS)
def test_full_config_matches_assignment(arch):
    cfg = get_config(arch)
    spec = {
        "mamba2-130m": dict(num_layers=24, d_model=768, d_ff=0,
                            vocab_size=50280, ssm_state=128),
        "yi-6b": dict(num_layers=32, d_model=4096, num_heads=32,
                      num_kv_heads=4, d_ff=11008, vocab_size=64000),
        "olmoe-1b-7b": dict(num_layers=16, d_model=2048, num_heads=16,
                            num_kv_heads=16, d_ff=1024, vocab_size=50304,
                            num_experts=64, experts_per_token=8),
        "musicgen-large": dict(num_layers=48, d_model=2048, num_heads=32,
                               num_kv_heads=32, d_ff=8192, vocab_size=2048),
        "qwen2-1.5b": dict(num_layers=28, d_model=1536, num_heads=12,
                           num_kv_heads=2, d_ff=8960, vocab_size=151936,
                           qkv_bias=True),
        "deepseek-7b": dict(num_layers=30, d_model=4096, num_heads=32,
                            num_kv_heads=32, d_ff=11008, vocab_size=102400),
        "kimi-k2-1t-a32b": dict(num_layers=61, d_model=7168, num_heads=64,
                                num_kv_heads=8, d_ff=2048,
                                vocab_size=163840, num_experts=384,
                                experts_per_token=8),
        "jamba-v0.1-52b": dict(num_layers=32, d_model=4096, num_heads=32,
                               num_kv_heads=8, d_ff=14336, vocab_size=65536,
                               num_experts=16, experts_per_token=2),
        "internvl2-26b": dict(num_layers=48, d_model=6144, num_heads=48,
                              num_kv_heads=8, d_ff=16384, vocab_size=92553),
        "minitron-4b": dict(num_layers=32, d_model=3072, num_heads=24,
                            num_kv_heads=8, d_ff=9216, vocab_size=256000),
    }[arch]
    for k, v in spec.items():
        assert getattr(cfg, k) == v, (arch, k, getattr(cfg, k), v)
    assert cfg.source  # citation present


def test_long_context_variant_is_subquadratic():
    # dense archs get a sliding window for long_500k; SSM/hybrid unchanged
    assert config_for_shape("yi-6b", "long_500k").sliding_window == 8192
    assert config_for_shape("mamba2-130m", "long_500k").sliding_window == 0
    assert config_for_shape("yi-6b", "train_4k").sliding_window == 0


def test_param_counts_sane():
    # yi-6b ~6B, kimi ~1T total / ~32B active
    assert 5e9 < get_config("yi-6b").param_count() < 8e9
    assert 0.8e12 < get_config("kimi-k2-1t-a32b").param_count() < 1.3e12
    assert 15e9 < get_config("kimi-k2-1t-a32b").param_count(
        active_only=True) < 40e9
    assert 3e9 < get_config("minitron-4b").param_count() < 6e9
