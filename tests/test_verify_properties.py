"""Hypothesis property tests for the static plan verifier (satellite).

Two properties, each over randomly drawn deployment sizes:

  * every *valid* schedule the four generators emit verifies clean —
    no false positives at any (schedule, n_stages, n_micro, n_chunks)
    in range;
  * every mutator-injected violation class is flagged with its
    designated ``TAGxxx`` code at any size — no false negatives.

Gated on hypothesis being installed (it is in the ``test`` extra and
the CI environment; the tier-1 local run skips cleanly without it).
"""
import pytest

pytest.importorskip("hypothesis")

from hypothesis import given, settings
from hypothesis import strategies as st

from repro.exec.schedule import SCHEDULES, make_schedule
from repro.verify import MUTATIONS, make_context, verify_schedule
from repro.verify.mutate import verify_context


@st.composite
def schedule_params(draw):
    """A (schedule, n_stages, n_micro, n_chunks) tuple every generator
    accepts (interleaved needs S >= 2, V >= 2, M % S == 0)."""
    sched = draw(st.sampled_from(SCHEDULES))
    n_stages = draw(st.integers(min_value=2, max_value=6))
    if sched == "interleaved":
        n_micro = n_stages * draw(st.integers(min_value=1, max_value=4))
        n_chunks = draw(st.integers(min_value=2, max_value=3))
    else:
        n_micro = draw(st.integers(min_value=1, max_value=16))
        n_chunks = 1
    return sched, n_stages, n_micro, n_chunks


@settings(max_examples=80, deadline=None)
@given(params=schedule_params())
def test_random_valid_schedules_verify_clean(params):
    sched, S, M, V = params
    order = make_schedule(sched, S, M, n_chunks=V)
    rep = verify_schedule(order, S, M, n_chunks=V)
    assert rep.ok, rep.format()
    assert not rep.diagnostics


@settings(max_examples=120, deadline=None)
@given(mut=st.sampled_from(MUTATIONS),
       sched=st.sampled_from(SCHEDULES),
       n_stages=st.integers(min_value=3, max_value=6),
       mult=st.integers(min_value=1, max_value=3))
def test_every_mutation_class_is_flagged(mut, sched, n_stages, mult):
    # n_micro a multiple of n_stages keeps interleaved in-range while
    # exercising the other families at the same sizes
    n_micro = n_stages * mult
    ctx = make_context(sched, n_stages=n_stages, n_micro=n_micro)
    if not mut.apply(ctx):
        return                       # not applicable to this family
    rep = verify_context(ctx)
    assert rep.has(*mut.expect), \
        (mut.name, sched, n_stages, n_micro, sorted(rep.codes()))
