"""Discrete-event simulator properties (paper §4.3.2): lower bounds,
monotonicity, memory accounting, OOM feasibility — incl. hypothesis
property tests on random strategies."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.core.compiler import compile_strategy
from repro.core.device import DeviceGroup, Topology, _full_inter
from repro.core.device import testbed as make_testbed
from repro.core.graph import group_graph
from repro.core.jax_export import trace_training_graph
from repro.core.partition import partition
from repro.core.profiler import OP_OVERHEAD, compute_time
from repro.core.simulator import simulate
from repro.core.strategy import (
    Action, Option, Strategy, candidate_actions, data_parallel_all)
from repro.core.zoo import build


@pytest.fixture(scope="module")
def gg():
    loss_fn, params, batch = build("bert_small")
    g = trace_training_graph(loss_fn, params, batch, "bert").simplify()
    return group_graph(g, partition(g, 20))


@pytest.fixture(scope="module")
def topo():
    return make_testbed()


def test_makespan_at_least_compute_lower_bound(gg, topo):
    strat = Strategy([data_parallel_all(topo)] * gg.n)
    res = simulate(compile_strategy(gg, strat, topo), topo)
    total_flops = sum(g.flops for g in gg.groups)
    agg_speed = sum(dg.flops * dg.num_gpus for dg in topo.groups)
    assert res.makespan >= total_flops / agg_speed
    assert res.feasible


def test_single_fast_device_beats_single_slow_device(gg, topo):
    fast = Strategy([Action((0,), Option.MP)] * gg.n)   # V100 group
    slow = Strategy([Action((5,), Option.MP)] * gg.n)   # P100 group
    t_fast = simulate(compile_strategy(gg, fast, topo), topo).makespan
    t_slow = simulate(compile_strategy(gg, slow, topo), topo).makespan
    assert t_fast < t_slow


def test_homogeneous_dp_scales_down_compute(gg):
    gbps = 1e9 / 8
    one = Topology([DeviceGroup(0, "V100", 1, intra_bw=300 * gbps)],
                   _full_inter(1, 0), name="one")
    four = Topology([DeviceGroup(0, "V100", 4, intra_bw=300 * gbps)],
                    _full_inter(1, 0), name="four")
    s1 = Strategy([data_parallel_all(one)] * gg.n)
    s4 = Strategy([data_parallel_all(four)] * gg.n)
    t1 = simulate(compile_strategy(gg, s1, one), one).makespan
    t4 = simulate(compile_strategy(gg, s4, four), four).makespan
    assert t4 < t1  # DP on 4 devices beats 1 device for a compute-heavy net


def test_memory_accounting_positive_and_oom_flag(gg, topo):
    strat = Strategy([data_parallel_all(topo)] * gg.n)
    res = simulate(compile_strategy(gg, strat, topo), topo)
    assert all(v >= 0 for v in res.peak_mem.values())
    # shrink memory capacity -> infeasible
    tiny = Topology(
        [DeviceGroup(g.group_id, g.gpu_type, g.num_gpus, g.intra_bw,
                     mem_bytes=1e6) for g in topo.groups],
        topo.inter_bw, name="tiny")
    res2 = simulate(compile_strategy(gg, strat, tiny), tiny)
    assert not res2.feasible


def test_duplicate_option_no_sync_but_full_compute(gg, topo):
    dup = Strategy([Action((0,), Option.DUP)] * gg.n)
    tg = compile_strategy(gg, dup, topo)
    assert not any(t.kind in ("allreduce", "ps") for t in tg.tasks)
    # every replica computes the full batch
    for gid, reps in tg.replicas.items():
        for r in reps:
            assert abs(tg.tasks[r.task].flops - gg.groups[gid].flops) < 1e-6


def test_slower_interconnect_never_faster(gg):
    gbps = 1e9 / 8
    def mk(bw):
        groups = [DeviceGroup(0, "V100", 2, intra_bw=300 * gbps),
                  DeviceGroup(1, "P100", 2, intra_bw=64 * gbps)]
        return Topology(groups, _full_inter(2, bw), name=f"bw{bw}")
    fastnet, slownet = mk(100 * gbps), mk(1 * gbps)
    strat = Strategy([data_parallel_all(fastnet)] * gg.n)
    t_fast = simulate(compile_strategy(gg, strat, fastnet), fastnet).makespan
    t_slow = simulate(compile_strategy(gg, strat, slownet), slownet).makespan
    assert t_slow >= t_fast


@given(st.integers(0, 10_000))
@settings(max_examples=20, deadline=None)
def test_random_strategies_simulate_clean(gg, topo, seed):
    """Any complete strategy must simulate: positive makespan, all tasks
    scheduled, non-negative busy times (no deadlock on any action mix)."""
    rng = np.random.default_rng(seed)
    actions = []
    for gid in range(gg.n):
        cands = candidate_actions(topo, has_grad=gg.groups[gid].has_grad)
        actions.append(cands[int(rng.integers(len(cands)))])
    res = simulate(compile_strategy(gg, Strategy(actions), topo), topo)
    assert res.makespan > 0
    assert all(b >= 0 for b in res.device_busy.values())
    assert all(f >= s for s, f in zip(res.task_start, res.task_finish,
                                      strict=True))


def test_compute_time_linear_in_flops():
    t1 = compute_time(1e9, 1e12)
    t2 = compute_time(2e9, 1e12)
    assert abs((t2 - OP_OVERHEAD) - 2 * (t1 - OP_OVERHEAD)) < 1e-12


def test_pipeline_option_beats_mp_by_overlap(gg, topo):
    """Beyond-paper (paper §6 future work): the PIPE option overlaps MP
    stages across micro-batches — must be faster than sequential MP and
    conserve total compute."""
    mp = Strategy([Action((0,), Option.MP)] * gg.n)
    pipe = Strategy([Action((0,), Option.PIPE)] * gg.n)
    tg_mp = compile_strategy(gg, mp, topo)
    tg_pipe = compile_strategy(gg, pipe, topo)
    f_mp = sum(t.flops for t in tg_mp.tasks)
    f_pipe = sum(t.flops for t in tg_pipe.tasks)
    assert abs(f_mp - f_pipe) / f_mp < 1e-6      # compute conserved
    t_mp = simulate(tg_mp, topo).makespan
    t_pipe = simulate(tg_pipe, topo).makespan
    assert t_pipe < t_mp
