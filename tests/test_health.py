"""Run-health analyzer + burn-rate alerting tests (repro.obs.health /
repro.obs.alerts) and their recalibration-loop wiring
(repro.runtime.feedback: priority ordering, backlog shedding, drift
cause annotation).

The straggler scenario mirrors the acceptance criterion: a pipelined
workload executes on a TRUE topology whose stage-1 -> stage-2 link runs
at 1/3 bandwidth while the analyzer holds the NOMINAL predicted
timeline — the dominant residual must name that link, the flagged
straggler must survive hysteresis, the SLO page must fire, and the
recalibration loop must replan the afflicted workload before the
healthy one.
"""
import copy
import json
import types

import pytest

from repro.core.device import testbed as make_testbed
from repro.core.graph import CompGraph, OpNode, group_graph
from repro.core.strategy import Action, Option, Strategy
from repro.exec.replay import execute_pipeline
from repro.exec.schedule import make_schedule, simulate_schedule
from repro.exec.stages import build_stage_plan
from repro.obs.alerts import (
    AlertEvaluator, AlertRule, SLOTracker, default_rules, load_rules,
    parse_rules)
from repro.obs.health import RunHealthAnalyzer
from repro.obs.metrics import MetricsRegistry, parse_prometheus_text
from repro.runtime.feedback import RecalibrationLoop
from repro.runtime.telemetry import MeasurementStore, StepRecord
from repro.service.planner import PlannerService


def _chain_gg(n_ops=12, n_groups=6, edge_bytes=4e6):
    g = CompGraph(name=f"chain{n_ops}")
    for i in range(n_ops):
        g.add_node(OpNode(i, f"op{i}", "dot_general",
                          flops=1e9 * (1 + i % 3), bytes_out=edge_bytes,
                          param_bytes=4e5, grad_bytes=4e5,
                          is_grad_producer=True))
        if i:
            g.add_edge(i - 1, i, edge_bytes)
    return group_graph(g, {i: i * n_groups // n_ops for i in range(n_ops)})


def _pipeline(gg, topo, n_micro=8):
    strat = Strategy([Action((0, 1, 5), Option.PIPE) if i % 2 == 0
                      else Action((0, 1, 5), Option.PS)
                      for i in range(gg.n)])
    plan = build_stage_plan(gg, strat, topo, n_micro=n_micro)
    assert plan is not None and plan.n_stages >= 3
    tl = simulate_schedule(plan, topo, make_schedule(
        "1f1b", plan.n_stages, plan.n_micro))
    return plan, tl


def _slowed(topo, plan, factor=3.0):
    """A TRUE topology with the stage1->stage2 forward link slowed."""
    true = copy.deepcopy(topo)
    g1 = plan.stages[1].device_group
    g2 = plan.stages[2].device_group
    true.inter_bw[g1, g2] /= factor
    return true


def _rec(run_id, step, wall, stages=None, pairs=None, ts=None):
    """Synthetic sample-based StepRecord (no meta['events'])."""
    compute = [{"stage": s, "time": t, "gpu_type": "V100", "flops": 1e9}
               for s, t in (stages or {}).items()]
    colls = [{"pair": p, "time": t, "kind": "xfer", "nbytes": 1,
              "n_dev": 2, "nominal_bw": 1e9, "link": "p2p"}
             for p, t in (pairs or {}).items()]
    return StepRecord(step=step, wall_time=wall, compute=compute,
                      collectives=colls, meta={"run_id": run_id},
                      ts=ts if ts is not None else 1000.0 + step)


# ------------------------------------------------------------ alert rules

def test_alert_rule_validation():
    with pytest.raises(ValueError):
        AlertRule("x", "sev", 1.0, 100.0, 10.0)        # bad severity
    with pytest.raises(ValueError):
        AlertRule("x", "page", 0.0, 100.0, 10.0)       # burn <= 0
    with pytest.raises(ValueError):
        AlertRule("x", "page", 1.0, 10.0, 100.0)       # short > long
    r = AlertRule("x", "warn", 3.0, 100.0, 10.0)
    assert AlertRule.from_dict(r.to_dict()) == r


def test_parse_rules_schema(tmp_path):
    rules = parse_rules(json.dumps([r.to_dict() for r in default_rules()]))
    assert [r.name for r in rules] == ["slo_fast_burn", "slo_slow_burn"]
    with pytest.raises(ValueError):
        parse_rules("not json")
    with pytest.raises(ValueError):
        parse_rules("[]")                              # empty list
    with pytest.raises(ValueError):
        parse_rules('{"name": "x"}')                   # not a list
    with pytest.raises(ValueError):
        parse_rules('[{"name": "x"}]')                 # missing fields
    dup = [default_rules()[0].to_dict()] * 2
    with pytest.raises(ValueError):
        parse_rules(json.dumps(dup))
    p = tmp_path / "rules.json"
    p.write_text(json.dumps([
        {"name": "solo", "severity": "page", "burn_rate": 2.0,
         "long_window_s": 60.0, "short_window_s": 30.0}]))
    [rule] = load_rules(str(p))
    assert rule.name == "solo" and rule.burn_rate == 2.0


# ------------------------------------------------------------ SLO tracker

def test_slo_tracker_window_edges():
    tr = SLOTracker(1.0, objective=0.9, horizon_s=100.0)
    assert tr.budget == pytest.approx(0.1)
    assert tr.observe(0.0, 2.0) is True                # bad
    assert tr.observe(10.0, 0.5) is False              # good
    assert tr.observe(20.0, 2.0) is True               # bad
    # full window: 2 bad of 3
    assert tr.bad_fraction(100.0, now=20.0) == pytest.approx(2 / 3)
    # window (0, 20]: the ts=0 sample sits exactly ON the lower edge
    # and is excluded — half-open window semantics
    assert tr.bad_fraction(20.0, now=20.0) == pytest.approx(1 / 2)
    # a window holding no samples burns 0 (no data is not an incident)
    assert tr.bad_fraction(5.0, now=200.0) == 0.0
    assert tr.burn_rate(100.0, now=20.0) == pytest.approx((2 / 3) / 0.1)
    # horizon pruning: samples older than horizon_s drop off the buffer
    tr.observe(120.0, 0.5)
    assert tr.to_dict()["buffered"] == 2               # ts=0,10 pruned
    assert tr.total == 4 and tr.bad == 2               # lifetime kept
    # window (20, 120]: only the good ts=120 sample (ts=20 on the edge)
    assert tr.to_dict(now=120.0, windows=[100.0])["burn"]["100"] == 0.0
    with pytest.raises(ValueError):
        SLOTracker(0.0)
    with pytest.raises(ValueError):
        SLOTracker(1.0, objective=1.0)


def test_alert_evaluator_two_window_semantics():
    rule = AlertRule("r", "page", 5.0, long_window_s=100.0,
                     short_window_s=10.0)
    ev = AlertEvaluator([rule])
    assert ev.horizon_s == 100.0
    tr = SLOTracker(1.0, objective=0.9, horizon_s=100.0)
    # sustained violations: both windows burn at 1/0.1 = 10 >= 5
    for i in range(10):
        tr.observe(float(i), 2.0)
        ev.evaluate(tr, float(i))
    [st] = ev.firing()
    assert st.rule.name == "r" and st.transitions == 1
    # recovery: good steps drain the SHORT window below the threshold
    # while the long window still remembers the incident
    cleared_at = None
    for i in range(10, 22):
        tr.observe(float(i), 0.5)
        if ev.evaluate(tr, float(i)) and cleared_at is None:
            cleared_at = float(i)
            # at the instant of clearing, the long window is still hot:
            # recovery is decided by the short window alone
            assert st.burn_long >= rule.burn_rate
            assert st.burn_short < rule.burn_rate
    assert ev.firing() == [] and st.state == "ok"
    assert cleared_at is not None and st.since == cleared_at
    assert st.transitions == 2                     # cleared once, stays ok
    # long-window-only burn never fires (persistence without recency)
    ev2 = AlertEvaluator([rule])
    assert ev2.evaluate(tr, 21.0) == []
    assert ev2.firing() == []


# -------------------------------------------------- residual attribution

def _fake_timeline(stage_dur, link_dur, makespan, bubble=0.25):
    """Timeline stand-in: events carry (kind, stage, src, dur)."""
    events = [types.SimpleNamespace(kind="F", stage=s, src=-1, dur=d)
              for s, d in stage_dur.items()]
    events += [types.SimpleNamespace(kind="X", stage=dst, src=src, dur=d)
               for (src, dst), d in link_dur.items()]
    return types.SimpleNamespace(events=events, makespan=makespan,
                                 bubble_fraction=lambda: bubble)


def test_residual_attribution_math():
    an = RunHealthAnalyzer(ewma_alpha=1.0)         # no smoothing: exact
    tl = _fake_timeline({0: 0.30, 1: 0.30}, {(0, 1): 0.10},
                        makespan=0.80)
    an.watch("r", timeline=tl, sync_time=0.05)
    # executed: stage 1 twice as slow, link on plan, wall grew by the
    # stage residual plus 0.02s of unattributed sync
    an.ingest(_rec("r", 0, wall=1.17,
                   stages={0: 0.30, 1: 0.60}, pairs={"0-1": 0.10}))
    h = an.health("r")
    assert h["mode"] == "predicted"
    assert h["predicted_step_s"] == pytest.approx(0.85)   # makespan+sync
    assert h["stages"]["1"]["ratio"] == pytest.approx(2.0)
    assert h["stages"]["0"]["ratio"] == pytest.approx(1.0)
    assert h["links"]["0->1"]["ratio"] == pytest.approx(1.0)
    att = h["attribution"]
    assert att["compute_s"] == pytest.approx(0.30)
    assert att["transfer_s"] == pytest.approx(0.0)
    assert att["sync_other_s"] == pytest.approx(0.02)
    assert h["dominant"] == {"cause": "stage", "key": "1",
                             "residual_s": pytest.approx(0.30)}
    assert h["step_ratio"] == pytest.approx(1.17 / 0.85)
    assert h["bubble"]["predicted"] == pytest.approx(0.25)


def test_self_baselined_mode_anchors_first_step():
    an = RunHealthAnalyzer(ewma_alpha=1.0)
    an.ingest(_rec("solo", 0, wall=1.0, stages={0: 0.4}))
    an.ingest(_rec("solo", 1, wall=2.0, stages={0: 0.8}))
    h = an.health("solo")
    assert h["mode"] == "self_baselined"
    assert h["predicted_step_s"] == pytest.approx(1.0)    # first step
    assert h["step_ratio"] == pytest.approx(2.0)
    assert h["stages"]["0"]["ratio"] == pytest.approx(2.0)


def test_run_id_resolution():
    an = RunHealthAnalyzer()
    an.ingest(_rec("named", 0, 1.0))
    r = StepRecord(graph_fp="g" * 20, topo_fp="t" * 20, wall_time=1.0,
                   ts=1.0)
    an.ingest(r)
    an.ingest(StepRecord(wall_time=1.0, ts=1.0))
    assert an.run_ids() == ["default", "gggggggggggg:tttttttttttt",
                            "named"]


def test_lru_eviction_retires_metric_series():
    reg = MetricsRegistry()
    an = RunHealthAnalyzer(registry=reg, max_runs=2)
    for i in range(3):
        an.ingest(_rec(f"r{i}", 0, 1.0, ts=float(i + 1)))
        an.export_metrics()
    assert an.run_ids() == ["r1", "r2"]                # r0 evicted (LRU)
    fams = parse_prometheus_text(reg.to_prometheus())
    labels = {s[1]["run"]
              for s in fams["run_health_step_ratio"]["samples"]}
    assert labels == {"r1", "r2"}                      # r0 series removed


# --------------------------------------------------- straggler hysteresis

def test_straggler_hysteresis_up_and_down():
    an = RunHealthAnalyzer(ewma_alpha=1.0, straggler_ratio=1.3,
                           hysteresis_up=2, hysteresis_down=2)
    base = {0: 0.1, 1: 0.1, 2: 0.1}

    def flagged():
        return [s["key"] for s in an.health("r")["stragglers"]]

    an.ingest(_rec("r", 0, 0.3, stages=base))          # baseline anchor
    # one noisy step must NOT flag (hysteresis_up=2)
    an.ingest(_rec("r", 1, 0.4, stages={**base, 1: 0.2}))
    assert flagged() == []
    # second consecutive slow step flags stage 1
    an.ingest(_rec("r", 2, 0.4, stages={**base, 1: 0.2}))
    assert flagged() == ["1"]
    assert an.health("r")["stragglers"][0]["since_step"] == 2
    # one recovered step must NOT clear (hysteresis_down=2)
    an.ingest(_rec("r", 3, 0.3, stages=base))
    assert flagged() == ["1"]
    an.ingest(_rec("r", 4, 0.3, stages=base))
    assert flagged() == []


def test_uniform_slowdown_is_drift_not_straggler():
    an = RunHealthAnalyzer(ewma_alpha=1.0)
    base = {0: 0.1, 1: 0.1, 2: 0.1}
    an.ingest(_rec("r", 0, 0.3, stages=base))
    for step in range(1, 4):                           # ALL stages 2x
        an.ingest(_rec("r", step, 0.6,
                       stages={s: 0.2 for s in base}))
    h = an.health("r")
    assert h["stragglers"] == []                       # median-normalized
    assert h["step_ratio"] == pytest.approx(2.0)       # ...but drifted


# --------------------------------------------- replay straggler scenario

def test_replay_straggler_names_slowed_link_and_pages():
    topo = make_testbed()
    gg = _chain_gg()
    plan, nominal_tl = _pipeline(gg, topo)
    true_topo = _slowed(topo, plan, factor=3.0)

    an = RunHealthAnalyzer(slo_s=nominal_tl.makespan * 1.05)
    an.watch("runA", timeline=nominal_tl,
             graph_fp="G" * 40, topo_fp="T" * 40)
    for step in range(8):
        rec, _ = execute_pipeline(plan, true_topo, schedule="1f1b",
                                  step=step, meta={"run_id": "runA"})
        rec.ts = 1000.0 + 10.0 * step                  # inside 5m window
        an.ingest(rec)

    h = an.health("runA")
    assert h["mode"] == "predicted"
    assert h["step_ratio"] > 1.05
    # dominant residual names the slowed stage1->stage2 edge
    assert h["dominant"]["cause"] == "link"
    assert h["dominant"]["key"] == "1->2"
    assert h["dominant"]["residual_s"] > 0
    # the straggler ranking agrees and survived hysteresis
    assert [s["key"] for s in h["stragglers"]] == ["1->2"]
    assert h["links"]["1->2"]["ratio"] > 1.5
    assert h["links"]["0->1"]["ratio"] == pytest.approx(1.0, abs=0.05)
    # every perturbed step violated the SLO: both burn-rate rules fire
    assert {(a["rule"], a["state"]) for a in h["alerts"]} == {
        ("slo_fast_burn", "firing"), ("slo_slow_burn", "firing")}
    alerts = an.alerts()
    assert alerts[0]["severity"] == "page"             # pages sort first
    assert alerts[0]["state"] == "firing"
    # replan wiring: the watched key scores its deviation, the cause is
    # the attributed link
    key = ("G" * 40, "T" * 40)
    assert an.replan_priority()[key] == pytest.approx(
        h["step_ratio"] - 1.0)
    cause = an.attributed_cause(*key)
    assert cause["cause"] == "link" and cause["key"] == "1->2"
    assert cause["run_id"] == "runA"


def test_healthy_replay_run_stays_quiet():
    topo = make_testbed()
    gg = _chain_gg()
    plan, tl = _pipeline(gg, topo)
    an = RunHealthAnalyzer(slo_s=tl.makespan * 1.05)
    an.watch("ok", timeline=tl)
    for step in range(6):
        rec, _ = execute_pipeline(plan, topo, schedule="1f1b", step=step,
                                  meta={"run_id": "ok"})
        rec.ts = 1000.0 + 10.0 * step
        an.ingest(rec)
    h = an.health("ok")
    assert h["step_ratio"] == pytest.approx(1.0, abs=0.02)
    assert h["stragglers"] == []
    assert all(a["state"] == "ok" for a in h["alerts"])
    # executed bubble tracks the predicted one on a faithful replay
    assert h["bubble"]["executed"] == pytest.approx(
        h["bubble"]["predicted"], abs=0.05)


# ----------------------------------------------- analyzer metrics export

def test_export_metrics_parses_and_counts():
    reg = MetricsRegistry()
    an = RunHealthAnalyzer(registry=reg, slo_s=0.5, ewma_alpha=1.0)
    an.ingest(_rec("m", 0, 1.0, stages={0: 0.2}, pairs={"0-1": 0.1}))
    an.ingest(_rec("m", 1, 1.0, stages={0: 0.2}, pairs={"0-1": 0.1}))
    an.export_metrics()
    fams = parse_prometheus_text(reg.to_prometheus())
    for name in ("run_health_runs", "run_health_step_ratio",
                 "run_health_stage_ratio", "run_health_link_ratio",
                 "run_health_stragglers", "run_health_slo_burn",
                 "run_health_alert_firing", "run_health_records_total",
                 "alert_transitions_total"):
        assert name in fams, name
    assert fams["run_health_runs"]["samples"][0][2] == 1.0
    # every step violated the 0.5s target -> transition counted
    [(_, labels, v)] = [
        s for s in fams["alert_transitions_total"]["samples"]
        if s[1]["rule"] == "slo_fast_burn"]
    assert labels["to"] == "firing" and v == 1.0
    st = an.stats()
    assert st["records"] == 2 and st["ingest_us_per_event"] > 0.0


# --------------------------------------- recalibration loop integration

def test_recalib_priority_order_and_cause_annotation(tmp_path):
    """Two watched workloads drift in the same poll; the one the health
    analyzer scores worse replans FIRST and its refreshed plan record
    carries the attributed cause."""
    tele = str(tmp_path / "telemetry")
    svc = PlannerService(cache_dir=str(tmp_path / "plans"),
                         telemetry_dir=tele)
    topo = make_testbed()
    gg_bad, gg_ok = _chain_gg(12, 6), _chain_gg(10, 5)
    r_bad = svc.plan_graph(gg_bad, topo, iterations=8, seed=0)
    r_ok = svc.plan_graph(gg_ok, topo, iterations=8, seed=0)

    an = RunHealthAnalyzer()                       # feed-only, rides poll
    loop = RecalibrationLoop(svc, interval_s=60.0, iterations=8,
                             health=an)
    key_bad = loop.watch(gg_bad, topo)
    key_ok = loop.watch(gg_ok, topo)
    # health scores come from run step ratios: register each plan's
    # simulated time as the predicted step so the deviation is measured
    # against the plan, not self-baselined against the first bad step
    an.watch("bad", graph_fp=key_bad[0], topo_fp=key_bad[1],
             timeline=_fake_timeline({}, {}, makespan=r_bad.time))
    an.watch("ok", graph_fp=key_ok[0], topo_fp=key_ok[1],
             timeline=_fake_timeline({}, {}, makespan=r_ok.time))

    ext = MeasurementStore(tele)
    # interleave arrival order: ok first, then bad — priority must
    # reorder so 'bad' (4x deviation) drains before 'ok' (3x)
    for step in range(2):
        ext.append(StepRecord(graph_fp=key_ok[0], topo_fp=key_ok[1],
                              step=step, wall_time=r_ok.time * 3.0,
                              meta={"run_id": "ok"}))
        ext.append(StepRecord(graph_fp=key_bad[0], topo_fp=key_bad[1],
                              step=step, wall_time=r_bad.time * 4.0,
                              meta={"run_id": "bad"}))

    results = loop.poll_once()
    st = loop.stats()
    assert st["last_order"] == [[key_bad[0][:12], key_bad[1][:12]],
                                [key_ok[0][:12], key_ok[1][:12]]]
    kinds = [r.kind for r in results]
    assert "replanned" in kinds
    first_replan = next(r for r in results if r.kind == "replanned")
    assert first_replan.report.graph_fp == key_bad[0]  # worst key first
    assert first_replan.report.cause is not None
    assert first_replan.report.cause["run_id"] == "bad"
    assert "cause" in first_replan.report.to_dict()
    # the refreshed plan record persists the attribution
    rec = svc.store.get(*key_bad)
    assert rec is not None
    assert rec.meta["drift_cause"]["run_id"] == "bad"


def test_recalib_backlog_shedding(tmp_path):
    """A flooded telemetry dir: per-key shedding keeps only the newest
    max_per_key records, counts the shed ones, and still processes the
    newest signal."""
    tele = str(tmp_path / "telemetry")
    svc = PlannerService(cache_dir=str(tmp_path / "plans"),
                         telemetry_dir=tele)
    gg, topo = _chain_gg(), make_testbed()
    res = svc.plan_graph(gg, topo, iterations=8, seed=0)
    loop = RecalibrationLoop(svc, interval_s=60.0, iterations=8,
                             max_per_key=4, health=RunHealthAnalyzer())
    key = loop.watch(gg, topo)
    ext = MeasurementStore(tele)
    for step in range(20):                         # flood: 20 >> 4
        ext.append(StepRecord(graph_fp=key[0], topo_fp=key[1], step=step,
                              wall_time=res.time * 1.01))
    results = loop.poll_once()
    assert len(results) == 4                       # newest 4 processed
    st = loop.stats()
    assert st["backlog_depth"] == 20.0
    assert st["shed_total"] == 16.0
    assert st["records"]["shed"] == 16.0
    fams = parse_prometheus_text(svc.metrics.to_prometheus())
    assert fams["recalib_backlog_shed_total"]["samples"][0][2] == 16.0
    assert fams["recalib_backlog_depth"]["samples"][0][2] == 20.0
    # the loop's feed-only analyzer saw the records it rode along
    assert loop.health.records_total == 20


# ------------------------------------------------------------------- CLI

def test_health_cli_local_mode(tmp_path, capsys):
    from repro.service.cli import main
    tele = str(tmp_path / "telemetry")
    store = MeasurementStore(tele)
    for step in range(3):
        store.append(_rec("cli-run", step, 0.2, stages={0: 0.1},
                          ts=100.0 + step))
    rc = main(["health", "--telemetry-dir", tele, "--slo-ms", "100"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    assert out["ingested"] == 3
    assert [r["run_id"] for r in out["runs"]] == ["cli-run"]
    h = out["health"]["cli-run"]
    assert h["mode"] == "self_baselined" and h["steps"] == 3
    # 0.2s steps vs a 0.1s target: the page rule fires
    assert any(a["rule"] == "slo_fast_burn" and a["state"] == "firing"
               for a in out["alerts"])
    rc = main(["health", "--telemetry-dir", tele, "--run-id", "nope"])
    assert rc == 1
    assert "unknown run" in json.loads(capsys.readouterr().out)["error"]
