"""Sharding rules + multi-device runtime tests. Multi-device cases run in
subprocesses so XLA's forced host device count never leaks into other
tests."""
import os
import subprocess
import sys
import textwrap

from jax.sharding import PartitionSpec as P

from repro.parallel.sharding import AxisRules, axis_rules, logical_spec

SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _run_subprocess(code: str) -> str:
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH=SRC)
    out = subprocess.run([sys.executable, "-c", textwrap.dedent(code)],
                         capture_output=True, text=True, env=env,
                         timeout=560)
    assert out.returncode == 0, out.stderr[-2000:]
    return out.stdout


class _FakeMesh:
    axis_names = ("data", "model")
    shape = {"data": 4, "model": 2}


def test_logical_spec_divisibility_fallback():
    rules = AxisRules(mesh=_FakeMesh(),
                      rules={"batch": ("data",), "mlp": "model"})
    with axis_rules(rules):
        assert logical_spec(("batch", "mlp"), shape=(8, 6)) == P(("data",), "model")
        # 7 not divisible by 4 -> replicate that dim
        assert logical_spec(("batch", "mlp"), shape=(7, 6)) == P(None, "model")


def test_rules_ignore_missing_mesh_axes():
    class OneD:
        axis_names = ("data",)
        shape = {"data": 4}
    rules = AxisRules(mesh=OneD(), rules={"batch": ("data",),
                                          "mlp": "model"})
    with axis_rules(rules):
        assert logical_spec(("batch", "mlp"), shape=(8, 8)) == P(("data",))


def test_sfb_dense_sync_modes_equivalent_multidevice():
    out = _run_subprocess("""
        import jax, jax.numpy as jnp, numpy as np
        from repro.launch import mesh as mesh_mod
        from repro.parallel.sfb_dense import dp_mlp_loss
        mesh = mesh_mod.make_mesh((4,), ("data",))
        rng = np.random.default_rng(0)
        widths = [16, 32, 8]
        params = [jnp.asarray(rng.standard_normal((a, b)) * 0.1, jnp.float32)
                  for a, b in zip(widths[:-1], widths[1:])]
        x = jnp.asarray(rng.standard_normal((8, 16)), jnp.float32)
        y = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
        def ref_loss(params, x, y):
            h = x
            for i, w in enumerate(params):
                h = h @ w
                if i < len(params) - 1:
                    h = jax.nn.relu(h)
            return jnp.mean((h - y) ** 2)
        ref = jax.grad(ref_loss)(params, x, y)
        for sync in ("allreduce", "ps", "sfb"):
            g = jax.jit(jax.grad(dp_mlp_loss(mesh, "data", sync, widths)))(
                params, x, y)
            err = max(float(jnp.max(jnp.abs(a - b)))
                      for a, b in zip(g, ref))
            assert err < 1e-5, (sync, err)
        print("EQUIV_OK")
    """)
    assert "EQUIV_OK" in out


def test_sharded_train_step_matches_single_device():
    """The same reduced model must produce the same loss on a 4-device
    (data, model) mesh as on one device."""
    out = _run_subprocess("""
        import jax, jax.numpy as jnp
        from repro.configs import get_reduced
        from repro.launch import mesh as mesh_mod, steps as steps_mod
        from repro.models import init_params, loss_fn
        from repro.parallel.sharding import AxisRules, axis_rules
        cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
        params = init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((4, 16), jnp.int32),
                 "labels": jnp.ones((4, 16), jnp.int32)}
        l_single, _ = jax.jit(
            lambda p, b: loss_fn(cfg, p, b, remat=False))(params, batch)
        mesh = mesh_mod.make_mesh((2, 2), ("data", "model"))
        rules = steps_mod.baseline_rules(mesh)
        def sharded(p, b):
            with axis_rules(rules):
                return loss_fn(cfg, p, b, remat=False)
        l_mesh, _ = jax.jit(sharded)(params, batch)
        err = abs(float(l_single) - float(l_mesh))
        assert err < 1e-3, err
        print("SHARD_OK", float(l_single), float(l_mesh))
    """)
    assert "SHARD_OK" in out


def test_dryrun_cli_small_mesh():
    """The dry-run CLI path end-to-end on a subprocess-sized mesh."""
    out = _run_subprocess("""
        from repro.launch import mesh as mesh_mod
        from repro.launch.dryrun import lower_one
        mesh = mesh_mod.make_mesh((2, 2), ("data", "model"))
        r = lower_one("olmoe-1b-7b", "decode_32k", mesh)
        assert r["roofline"]["compute_s"] >= 0
        assert r["memory"]["temp_bytes"] > 0
        print("DRYRUN_OK", r["dominant"])
    """)
    assert "DRYRUN_OK" in out
