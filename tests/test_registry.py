"""Policy registry + cross-model transfer tier + this PR's regression
tests (SFB cache content-keying, embedding memoization, adapt_strategy
degeneracy)."""
import copy

import numpy as np
import pytest

from repro.core import tag as tag_mod
from repro.core.device import DeviceGroup, Topology, _full_inter
from repro.core.device import testbed as make_testbed
from repro.core.graph import group_graph
from repro.core.hetgnn import GNNConfig, policy_logits, policy_probs
from repro.core.jax_export import trace_training_graph
from repro.core.mcts import MCTS
from repro.core.partition import partition
from repro.core.strategy import Action, Option, Strategy, candidate_actions
from repro.core.trainer import init_trainer, make_policy, train_step
from repro.core.zoo import build
from repro.service import (
    PlannerService, PlanStore, PolicyRegistry, adapt_strategy, find_prior,
    fingerprint_grouped_cached, structural_distance, structural_features)
from repro.service.fingerprint import STRUCT_F, STRUCT_SCALARS
from repro.service.store import PlanRecord


@pytest.fixture(scope="module")
def traced():
    loss_fn, params, batch = build("bert_small")
    return trace_training_graph(loss_fn, params, batch, "bert").simplify()


@pytest.fixture(scope="module")
def gg(traced):
    return group_graph(traced, partition(traced, 12))


@pytest.fixture(scope="module")
def gg_alt(traced):
    """Same model, different grouping: a distinct graph fingerprint with
    near-zero structural distance (cross-model transfer stand-in)."""
    return group_graph(traced, partition(traced, 10))


@pytest.fixture(scope="module")
def topo():
    return make_testbed()


def _perturbed(topo, scale=0.9):
    t2 = copy.deepcopy(topo)
    t2.inter_bw = topo.inter_bw * scale
    return t2


def _vec(scalars=1.0, bucket=None, weight=0.9):
    v = [scalars] * STRUCT_SCALARS + [0.0] * (STRUCT_F - STRUCT_SCALARS)
    if bucket is not None:
        v[STRUCT_SCALARS + bucket] = weight
    return v


# ---------------------------------------------------- structural features

def test_structural_features_shape_and_determinism(gg):
    f1, f2 = structural_features(gg), structural_features(gg)
    assert len(f1) == STRUCT_F
    assert f1 == f2
    assert structural_distance(f1, f2) < 1e-9


def test_structural_distance_separates_families(gg, gg_alt):
    """Regrouping the same model is structurally near; disjoint op-type
    histograms are far; malformed vectors are infinitely far."""
    fa, fb = structural_features(gg), structural_features(gg_alt)
    assert structural_distance(fa, fb) < 0.05
    assert structural_distance(_vec(bucket=0), _vec(bucket=5)) > 0.25
    assert structural_distance(fa, []) == float("inf")
    assert structural_distance(fa, fb[:-1]) == float("inf")


# ------------------------------------------------------- policy registry

def test_registry_roundtrip_identical_logits(gg, topo, tmp_path):
    """ISSUE acceptance: train -> save -> load -> identical policy_logits."""
    from repro.core.features import featurize
    state = init_trainer(seed=0)
    sr = MCTS(gg, topo, seed=0, record_threshold=4).search(8)
    assert sr.visit_records
    train_step(state, sr.visit_records)

    reg = PolicyRegistry(str(tmp_path))
    reg.save("rt", state.cfg, state.params,
             corpus=[fingerprint_grouped_cached(gg)],
             corpus_features=[structural_features(gg)],
             meta={"models": ["bert_small"]})
    rec, params = reg.load("rt")
    assert rec.gnn_config() == state.cfg
    assert rec.meta["models"] == ["bert_small"]

    het = featurize(gg, topo, Strategy.empty(gg.n), None, 0)
    actions = candidate_actions(topo, has_grad=True)
    l1 = np.asarray(policy_logits(state.cfg, state.params, het, 0, actions))
    l2 = np.asarray(policy_logits(rec.gnn_config(), params, het, 0, actions))
    assert np.array_equal(l1, l2)


def test_registry_selection_tiers(tmp_path):
    """Pin > exact corpus fingerprint > structural NN > newest."""
    reg = PolicyRegistry(str(tmp_path))
    cfg = GNNConfig()
    dummy = {"w": np.zeros(2, np.float32)}
    reg.save("pa", cfg, dummy, corpus=["fpA"],
             corpus_features=[_vec(bucket=0)], created=1.0)
    reg.save("pb", cfg, dummy, corpus=["fpB"],
             corpus_features=[_vec(bucket=5)], created=2.0)
    assert {r.name for r in reg.records()} == {"pa", "pb"}

    assert reg.select().name == "pb"                      # newest
    assert reg.select(graph_fp="fpA").name == "pa"        # exact corpus
    near_a = _vec(bucket=0, weight=0.8)
    assert reg.select(graph_fp="zz",
                      graph_features=near_a).name == "pa"  # structural NN
    reg.set_default("pb")
    assert reg.select(graph_fp="fpA").name == "pb"        # pin wins
    assert reg.default_name() == "pb"

    assert reg.remove("pb")
    assert reg.select(graph_fp="zz").name == "pa"
    with pytest.raises(ValueError):
        reg.save("../evil", cfg, dummy)
    with pytest.raises(ValueError):
        reg.save("default", cfg, dummy)   # reserved: the pin file's name


def test_registry_resolve_reloads_after_reregistration(tmp_path):
    """A long-lived service must not serve stale params after the same
    checkpoint name is re-registered (e.g. by another process)."""
    reg = PolicyRegistry(str(tmp_path))
    cfg = GNNConfig()
    reg.save("p", cfg, {"w": np.ones(2, np.float32)}, created=1.0)
    _, pol1 = reg.resolve()
    _, pol1_again = reg.resolve()
    assert pol1_again is pol1                      # cached while unchanged
    # another process re-registers the name: reg's in-process cache is
    # NOT popped by reg.save(), only the created stamp reveals the change
    PolicyRegistry(str(tmp_path)).save(
        "p", cfg, {"w": np.zeros(2, np.float32)}, created=2.0)
    _, pol2 = reg.resolve()
    assert pol2 is not pol1                        # rebuilt from new npz
    assert float(np.asarray(pol2.params["w"]).sum()) == 0.0


def test_store_feature_entries_no_lru_promotion(tmp_path):
    """The structural donor scan must not churn the memory LRU."""
    store = PlanStore(path=str(tmp_path), capacity=2)
    strat = Strategy([Action((0,), Option.AR)])
    for i in range(3):
        store.put(PlanRecord(
            graph_fp=f"g{i}" + "0" * 62, topo_fp=f"t{i}" + "0" * 62,
            topo_struct_fp="s" * 64, n_groups=1, topo_m=1,
            strategy=strat.to_dict(), sfb_plans={}, time=1.0,
            baseline_time=2.0, graph_features=_vec(bucket=i)))
    assert len(store._mem) == 2 and len(store) == 3
    mem_before = list(store._mem)
    entries = store.feature_entries()
    assert len(entries) == 3                       # disk tier included
    assert list(store._mem) == mem_before          # untouched LRU
    # repeat scans serve disk entries from the (file, mtime) memo
    assert store._feat_cache
    assert len(store.feature_entries()) == 3
    # a rewrite bumps mtime and refreshes the memoized features
    import os as _os
    victim = next(k for k in store._disk if k not in store._mem)
    rec = store.get(*victim)
    rec.graph_features = _vec(bucket=7)
    store._mem.clear()                             # force disk path
    store.put(rec)
    store._mem.clear()
    fn = store._disk[victim]
    bumped = _os.stat(str(tmp_path / fn)).st_mtime + 10
    _os.utime(str(tmp_path / fn), (bumped, bumped))   # defeat coarse mtime
    feats = dict((k, f) for k, f, _ in store.feature_entries())
    assert feats[victim] == _vec(bucket=7)


def test_planner_service_uses_registered_policy(gg, topo, tmp_path):
    state = init_trainer(seed=0)
    PolicyRegistry(str(tmp_path / "policies")).save(
        "p0", state.cfg, state.params,
        corpus=[fingerprint_grouped_cached(gg)],
        corpus_features=[structural_features(gg)])

    svc = PlannerService(cache_dir=str(tmp_path))   # registry auto-attached
    resp = svc.plan_graph(gg, topo, iterations=4, seed=0)
    assert resp.source == "cold" and resp.policy == "p0"
    assert svc.stats()["policy_guided"] == 1
    # a cache hit serves the stored plan without re-running the policy
    again = svc.plan_graph(gg, topo, iterations=4, seed=0)
    assert again.source == "hit" and again.policy is None
    # the record remembers which checkpoint guided its search
    rec = svc.store.get(resp.graph_fp, resp.topo_fp)
    assert rec.meta["policy"] == "p0"


def test_planner_service_without_registry_unguided(gg, topo):
    svc = PlannerService()                          # no cache_dir: no registry
    resp = svc.plan_graph(gg, topo, iterations=3, seed=0)
    assert resp.policy is None
    assert svc.stats()["policy_guided"] == 0


# --------------------------------------------- structural warm-start tier

def test_find_prior_structural_tier():
    store = PlanStore()
    strat = Strategy([Action((0,), Option.AR)])
    rec = PlanRecord(
        graph_fp="g" * 64, topo_fp="t" * 64, topo_struct_fp="s" * 64,
        n_groups=1, topo_m=1, strategy=strat.to_dict(), sfb_plans={},
        time=1.0, baseline_time=2.0, graph_features=_vec(bucket=0))
    store.put(rec)
    # unseen graph AND topology, near features -> structural donor
    kind, got = find_prior(store, "x" * 64, "y" * 64, None,
                           graph_features=_vec(bucket=0, weight=0.8))
    assert kind == "warm_struct" and got.graph_fp == rec.graph_fp
    # far features -> miss
    kind, got = find_prior(store, "x" * 64, "y" * 64, None,
                           graph_features=_vec(bucket=5))
    assert kind == "miss" and got is None
    # records without features are never structural donors
    rec2 = copy.deepcopy(rec)
    rec2.graph_features = []
    store2 = PlanStore()
    store2.put(rec2)
    kind, _ = find_prior(store2, "x" * 64, "y" * 64, None,
                         graph_features=_vec(bucket=0))
    assert kind == "miss"


def test_find_prior_warm_graph_guarded_by_structure():
    """A same-topology donor is still a different graph: cross-family
    donors (distance > bound) must not seed the search; featureless
    legacy records keep the accept-any behaviour."""
    strat = Strategy([Action((0,), Option.AR)])
    rec = PlanRecord(
        graph_fp="g" * 64, topo_fp="t" * 64, topo_struct_fp="s" * 64,
        n_groups=1, topo_m=1, strategy=strat.to_dict(), sfb_plans={},
        time=1.0, baseline_time=2.0, graph_features=_vec(bucket=0))
    store = PlanStore()
    store.put(rec)
    near, far = _vec(bucket=0, weight=0.8), _vec(bucket=5)
    kind, _ = find_prior(store, "x" * 64, "t" * 64, None,
                         graph_features=near)
    assert kind == "warm_graph"
    kind, got = find_prior(store, "x" * 64, "t" * 64, None,
                           graph_features=far)
    assert kind == "miss" and got is None
    legacy = copy.deepcopy(rec)
    legacy.graph_features = []
    store2 = PlanStore()
    store2.put(legacy)
    kind, _ = find_prior(store2, "x" * 64, "t" * 64, None,
                         graph_features=far)
    assert kind == "warm_graph"


def test_planner_struct_warmstart_end_to_end(gg, gg_alt, topo):
    """An unseen (graph, topology) pair seeds from the structurally
    nearest cached plan instead of searching cold."""
    svc = PlannerService()
    svc.plan_graph(gg, topo, iterations=5, seed=0)
    resp = svc.plan_graph(gg_alt, _perturbed(topo), iterations=5, seed=0)
    assert resp.source == "warm"
    assert svc.stats()["warm"] == 1 and svc.stats()["cold"] == 1


# ------------------------------------------------ adapt_strategy degeneracy

def test_adapt_strategy_degenerates_sync_on_single_device():
    one = Topology([DeviceGroup(0, "V100", 1, intra_bw=1e9)],
                   _full_inter(1, 0))
    prior = Strategy([Action((0, 2), Option.PS),    # clipped -> 1 device
                      Action((0,), Option.AR),      # unclipped AR@1: legal
                      Action((0, 1), Option.AR),    # clipped -> 1 device
                      Action((0,), Option.MP),      # nothing to split
                      Action((0,), Option.PS)])     # PS needs >1 device
    got = adapt_strategy(prior, 5, one)
    assert got.actions[0] is None
    assert got.actions[1] == Action((0,), Option.AR)
    assert got.actions[2] is None
    assert got.actions[3] is None
    assert got.actions[4] is None


def test_adapt_strategy_keeps_multi_gpu_single_group():
    two = Topology([DeviceGroup(0, "V100", 2, intra_bw=1e9)],
                   _full_inter(1, 0))
    got = adapt_strategy(Strategy([Action((0, 3), Option.PS)]), 1, two)
    assert got.actions[0] == Action((0,), Option.PS)   # 2 devices: legal


# ------------------------------------------------------- SFB cache keying

def test_sfb_cache_content_keyed_and_id_poison_ignored(gg, topo):
    """Regression (ISSUE satellite): the cache must never serve another
    graph's plans through a recycled ``id()``. Keys are content
    fingerprints; a poisoned id-style entry (what the old cache used) is
    unreachable."""
    tag_mod._SFB_CACHE.clear()
    strat = tag_mod.dp_baseline(gg, topo)
    plans = tag_mod.sfb_post_pass(gg, strat, topo)
    assert plans and tag_mod._SFB_CACHE
    fp = fingerprint_grouped_cached(gg)
    assert all(k[0] == fp for k in tag_mod._SFB_CACHE)

    bogus = object()
    for key in list(tag_mod._SFB_CACHE):
        tag_mod._SFB_CACHE[(id(gg),) + key[1:]] = bogus
    plans2 = tag_mod.sfb_post_pass(gg, strat, topo)
    assert all(p is not bogus for p in plans2.values())
    assert plans2.keys() == plans.keys()
    tag_mod._SFB_CACHE.clear()


def test_sfb_cache_distinct_graphs_distinct_keys(gg, gg_alt, topo):
    tag_mod._SFB_CACHE.clear()
    strat = tag_mod.dp_baseline(gg, topo)
    tag_mod.sfb_post_pass(gg, strat, topo)
    keys_gg = set(tag_mod._SFB_CACHE)
    tag_mod.sfb_post_pass(gg_alt, tag_mod.dp_baseline(gg_alt, topo), topo)
    keys_alt = set(tag_mod._SFB_CACHE) - keys_gg
    assert keys_gg and keys_alt
    assert not ({k[0] for k in keys_gg} & {k[0] for k in keys_alt})
    tag_mod._SFB_CACHE.clear()


def test_sfb_cache_bounded(gg, topo, monkeypatch):
    tag_mod._SFB_CACHE.clear()
    monkeypatch.setattr(tag_mod, "SFB_CACHE_MAX_ENTRIES", 2)
    tag_mod.sfb_post_pass(gg, tag_mod.dp_baseline(gg, topo), topo)
    assert len(tag_mod._SFB_CACHE) <= 2
    tag_mod._SFB_CACHE.clear()


# ------------------------------------------------- embedding memoization

def test_cached_policy_matches_exact_policy(gg, topo):
    from repro.core.features import featurize
    state = init_trainer(seed=0)
    het = featurize(gg, topo, Strategy.empty(gg.n), None, 0)
    actions = candidate_actions(topo, has_grad=True)
    cached = make_policy(state.cfg, state.params)
    exact = np.asarray(policy_probs(state.cfg, state.params, het, 0,
                                    actions))
    assert np.allclose(np.asarray(cached(het, 0, actions)), exact,
                       atol=1e-6)
    assert (cached.hits, cached.misses) == (0, 1)
    cached(het, 3, actions)                       # same het, new group
    assert (cached.hits, cached.misses) == (1, 1)


def test_mcts_runs_one_forward_per_episode_with_cached_policy(gg, topo):
    state = init_trainer(seed=0)
    pol = make_policy(state.cfg, state.params)
    assert pol.cache_embeddings
    sr = MCTS(gg, topo, policy=pol, seed=0).search(10)
    assert pol.misses == 1                        # one gnn_forward total
    assert pol.hits >= 5                          # decoder-only expansions
    assert sr.best_reward >= 1.0 - 1e-9
    # exact (uncached) policies keep the per-vertex featurization path
    legacy = make_policy(state.cfg, state.params, cache_embeddings=False)
    assert not getattr(legacy, "cache_embeddings", False)


# ------------------------------------------------- eviction budgets

def _mk_ckpts(path, names, **budget):
    import os
    reg = PolicyRegistry(str(path), **budget)
    for i, n in enumerate(names):
        reg.save(n, GNNConfig(), {"w": np.ones(4, np.float32)},
                 created=100.0 + i)
        t = 100.0 + i
        os.utime(os.path.join(str(path), f"{n}.json"), (t, t))
    return reg


def test_registry_max_count_budget(tmp_path):
    """Constructor-enforced count quota, mirroring the plan store's
    disk-tier budgets: newest checkpoints win on every save."""
    reg = _mk_ckpts(tmp_path, ["a", "b", "c"], max_count=2)
    assert sorted(r.name for r in reg.records()) == ["b", "c"]


def test_registry_max_age_budget(tmp_path):
    import time as _time
    reg = _mk_ckpts(tmp_path, ["old", "new"])
    n = reg.evict_expired(max_age_s=0.5,
                          now=_time.time() + 100.0)
    assert n == 2 and len(reg) == 0


def test_registry_max_bytes_budget(tmp_path):
    import os
    reg = _mk_ckpts(tmp_path, ["a", "b", "c"])
    per = sum(os.stat(os.path.join(str(tmp_path), f"a{ext}")).st_size
              for ext in (".json", ".npz"))
    n = reg.evict_expired(max_bytes=2 * per + per // 2)
    assert n == 1                      # oldest ("a") evicted
    assert sorted(r.name for r in reg.records()) == ["b", "c"]


def test_registry_budget_never_evicts_pinned_default(tmp_path):
    reg = _mk_ckpts(tmp_path, ["a", "b", "c"])
    reg.set_default("a")               # oldest, would otherwise be evicted
    n = reg.evict_expired(max_count=1)
    assert n == 2
    assert [r.name for r in reg.records()] == ["a"]
    assert reg.default_name() == "a"


def test_registry_cli_policy_evict(tmp_path):
    from repro.service.cli import main as cli_main
    _mk_ckpts(tmp_path / "policies", ["a", "b", "c"])
    rc = cli_main(["policy", "evict", "--cache-dir", str(tmp_path),
                   "--max-count", "1"])
    assert rc == 0
    reg = PolicyRegistry(str(tmp_path / "policies"))
    assert [r.name for r in reg.records()] == ["c"]


def test_registry_budget_evicts_orphaned_meta(tmp_path):
    """A checkpoint whose npz vanished stays budget-visible so eviction
    can clean up the orphan instead of ignoring it forever."""
    import os
    reg = _mk_ckpts(tmp_path, ["orphan", "whole"])   # orphan is older
    os.remove(os.path.join(str(tmp_path), "orphan.npz"))
    assert [r.name for r in reg.records()] == ["whole"]   # unservable
    n = reg.evict_expired(max_count=1)
    assert n == 1
    assert not os.path.exists(os.path.join(str(tmp_path), "orphan.json"))
