"""MCTS + GNN policy tests: search improves over the DP baseline on a
heterogeneous topology; GNN priors sharpen toward MCTS visit counts."""
import jax
import numpy as np
import pytest

from repro.core.device import testbed as make_testbed
from repro.core.features import featurize
from repro.core.graph import group_graph
from repro.core.hetgnn import GNNConfig, init_gnn, policy_probs
from repro.core.jax_export import trace_training_graph
from repro.core.mcts import MCTS
from repro.core.partition import partition
from repro.core.strategy import candidate_actions
from repro.core.tag import optimize
from repro.core.trainer import init_trainer, train_step
from repro.core.zoo import build


@pytest.fixture(scope="module")
def gg():
    loss_fn, params, batch = build("vgg19")
    g = trace_training_graph(loss_fn, params, batch, "vgg").simplify()
    return group_graph(g, partition(g, 20))


@pytest.fixture(scope="module")
def topo():
    return make_testbed()


def test_mcts_never_worse_than_baseline(gg, topo):
    sr = MCTS(gg, topo, seed=0).search(20)
    assert sr.best_reward >= 1.0 - 1e-9   # DP itself is in the space
    assert len(sr.rewards) == 20


def test_tag_optimize_beats_dp_with_sfb(gg, topo):
    res = optimize(None, None, None, topo, gg=gg, iterations=25, seed=0)
    assert res.speedup > 1.0
    stats = res.strategy_stats(topo)
    assert abs(stats["ps_frac"] + stats["ar_frac"] + stats["dup_frac"]
               - 1.0) < 1e-6 or stats["ar_frac"] >= 0


def test_candidate_actions_cover_dp_and_options(topo):
    acts = candidate_actions(topo, has_grad=True)
    placements = {a.placement for a in acts}
    assert tuple(range(topo.m)) in placements       # DP-all present
    assert any(len(p) == 1 for p in placements)     # single group present
    opts = {a.option for a in acts}
    assert len(opts) >= 3


def test_gnn_policy_valid_distribution(gg, topo):
    cfg = GNNConfig()
    params = init_gnn(cfg, jax.random.PRNGKey(0))
    from repro.core.strategy import Strategy
    strat = Strategy.empty(gg.n)
    het = featurize(gg, topo, strat, None, gg.sorted_by_cost()[0])
    actions = candidate_actions(topo, has_grad=True)
    probs = np.asarray(policy_probs(cfg, params, het, 0, actions))
    assert probs.shape == (len(actions),)
    assert abs(probs.sum() - 1.0) < 1e-4
    assert (probs >= 0).all()


def test_gnn_train_step_reduces_loss(gg, topo):
    state = init_trainer(seed=0, lr=3e-3)
    sr = MCTS(gg, topo, seed=0, record_threshold=4).search(14)
    assert sr.visit_records
    l0 = train_step(state, sr.visit_records)
    for _ in range(10):
        l1 = train_step(state, sr.visit_records)
    assert l1 < l0  # fits the (fixed) visit distribution


def test_runtime_feedback_features_present(gg, topo):
    """Paper §5.5: part-3 features come from the simulator."""
    from repro.core.compiler import compile_strategy
    from repro.core.simulator import simulate
    from repro.core.tag import dp_baseline
    strat = dp_baseline(gg, topo)
    res = simulate(compile_strategy(gg, strat, topo), topo)
    het = featurize(gg, topo, strat, res, 0)
    assert het.op_x[:, 7].max() > 0          # makespans populated
    assert het.dev_x[:, 5].max() > 0         # idle fractions populated
    het0 = featurize(gg, topo, strat, None, 0)
    assert het0.op_x[:, 7].max() == 0
