"""Observability layer tests (repro.obs).

Chrome-trace schema validation of the predicted + executed exports for
all four pipeline schedules, the predicted-vs-executed diff report,
span nesting / thread safety / disabled-default, metrics-registry
semantics (counter/gauge/histogram, Prometheus text), the XLA-profiler
hook's graceful fallback + trace parsing, the measurement store's
incremental readers, and the per-op-type calibration buckets.
"""
import gzip
import json
import threading

import pytest

from repro.core.device import testbed as make_testbed
from repro.core.graph import CompGraph, OpNode, group_graph
from repro.core.strategy import Action, Option, Strategy
from repro.exec import (
    build_stage_plan, execute_pipeline, make_schedule, simulate_schedule)
from repro.obs import (
    MetricsRegistry, Tracer, chrome_trace, diff_report, executed_events_of,
    executed_trace_events, format_diff, get_tracer, set_tracer,
    timeline_trace_events, validate_chrome_trace, write_chrome_trace,
    xla_profiler as xp)

SCHEDULES = ("gpipe", "1f1b", "interleaved", "zb")


def _chain_gg(n_ops: int = 12, n_groups: int = 6):
    g = CompGraph(name="chain")
    for i in range(n_ops):
        g.add_node(OpNode(i, f"op{i}", "dot_general",
                          flops=1e9 * (1 + i % 3), bytes_out=1e6,
                          param_bytes=4e5, grad_bytes=4e5,
                          is_grad_producer=True))
        if i:
            g.add_edge(i - 1, i, 1e6)
    assign = {i: i * n_groups // n_ops for i in range(n_ops)}
    return group_graph(g, assign)


def _pipe_strategy(gg, placement):
    return Strategy([
        Action(placement, Option.PIPE) if i % 2 == 0
        else Action(placement, Option.PS) for i in range(gg.n)])


def _plan(name):
    import copy
    gg = _chain_gg()
    topo = make_testbed()
    plan = build_stage_plan(gg, _pipe_strategy(gg, (0, 1, 5)), topo)
    if name == "interleaved":           # needs n_micro % n_stages == 0
        plan = copy.deepcopy(plan)
        plan.n_micro = 2 * plan.n_stages
    return plan, topo


# ------------------------------------------------------------ trace export

@pytest.mark.parametrize("name", SCHEDULES)
def test_trace_export_schema_all_schedules(name, tmp_path):
    """Predicted + executed exports validate against the trace-event
    schema for every schedule, with both pid tracks, per-stage thread
    metadata, and one complete event per timeline event."""
    plan, topo = _plan(name)
    predicted = simulate_schedule(
        plan, topo, make_schedule(name, plan.n_stages, plan.n_micro))
    rec, _ = execute_pipeline(plan, topo, schedule=name)

    events = timeline_trace_events(predicted, pid=0) \
        + executed_trace_events(rec, pid=1, n_stages=plan.n_stages)
    path = write_chrome_trace(str(tmp_path / f"trace_{name}.json"), events,
                              schedule=name)
    with open(path) as f:
        doc = json.load(f)
    validate_chrome_trace(doc)
    assert doc["otherData"]["schedule"] == name

    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    metas = [e for e in doc["traceEvents"] if e["ph"] == "M"]
    # both sides rendered: one complete event per predicted timeline
    # event, and the executed stream mirrors it at noise 0
    assert len([e for e in xs if e["pid"] == 0]) == len(predicted.events)
    assert len([e for e in xs if e["pid"] == 1]) == \
        len(rec.meta["events"])
    assert {e["args"]["name"] for e in metas
            if e["name"] == "process_name"} == {"predicted", "executed"}
    stage_names = {e["args"]["name"] for e in metas
                   if e["name"] == "thread_name" and e["pid"] == 0}
    assert {f"stage {s}" for s in range(plan.n_stages)} <= stage_names
    # compute events on stage tracks, transfers shifted past them
    for e in xs:
        kind = e["args"]["kind"]
        if kind == "transfer":
            assert e["tid"] >= plan.n_stages
            assert e["name"].startswith("X")
        else:
            assert e["tid"] == e["args"]["stage"] < plan.n_stages
    if name == "zb":
        assert any(e["name"].startswith("W") for e in xs)
    if name == "interleaved":
        assert any("c1" in e["name"] for e in xs)


def test_trace_event_names_and_colors():
    plan, topo = _plan("1f1b")
    tl = simulate_schedule(
        plan, topo, make_schedule("1f1b", plan.n_stages, plan.n_micro))
    events = timeline_trace_events(tl)
    xs = [e for e in events if e["ph"] == "X"]
    assert {e["cname"] for e in xs} <= {"good", "bad", "yellow", "grey"}
    f0 = next(e for e in xs if e["args"]["kind"] == "forward"
              and e["args"]["stage"] == 0 and e["args"]["mb"] == 0)
    assert f0["name"] == "F0.0" and f0["cname"] == "good"
    assert f0["ts"] >= 0 and f0["dur"] > 0
    x = next(e for e in xs if e["args"]["kind"] == "transfer")
    assert "->" in x["name"] and x["args"]["nbytes"] > 0


def test_executed_events_of_normalizes_all_shapes():
    dicts = [{"kind": "F", "stage": 0, "mb": 1, "start": 0.5,
              "finish": 0.75}]
    norm = executed_events_of(dicts)
    assert norm == [{"kind": "F", "stage": 0, "mb": 1, "chunk": 0,
                     "src": -1, "start": 0.5, "finish": 0.75}]

    class FakeStats:                    # engine StepStats 6-tuples
        events = [("B", 2, 3, 0.25, 1, 1.0)]
    norm = executed_events_of(FakeStats())
    assert norm[0] == {"kind": "B", "stage": 2, "mb": 3, "chunk": 1,
                       "src": -1, "start": 1.0, "finish": 1.25}

    class FakeRecord:                   # StepRecord meta["events"]
        meta = {"events": dicts}
    assert executed_events_of(FakeRecord()) == executed_events_of(dicts)


def test_write_chrome_trace_gzip_and_validation(tmp_path):
    events = [{"name": "a", "ph": "X", "ts": 1.0, "dur": 2.0,
               "pid": 0, "tid": 0}]
    path = write_chrome_trace(str(tmp_path / "t.json.gz"), events)
    with gzip.open(path, "rt") as f:
        doc = json.load(f)
    assert doc["traceEvents"][0]["name"] == "a"

    with pytest.raises(ValueError):
        validate_chrome_trace({"events": []})          # no traceEvents
    with pytest.raises(ValueError):
        validate_chrome_trace(chrome_trace(
            [{"name": "a", "ph": "X", "pid": 0, "tid": 0}]))   # no ts
    with pytest.raises(ValueError):
        validate_chrome_trace(chrome_trace(
            [{"name": "a", "ph": "X", "ts": 0.0, "dur": -1.0,
              "pid": 0, "tid": 0}]))                   # negative dur
    with pytest.raises(ValueError):
        validate_chrome_trace(chrome_trace([{"ph": "X", "ts": 0.0}]))
    # metadata events need no ts
    validate_chrome_trace(chrome_trace(
        [{"name": "process_name", "ph": "M", "pid": 0, "tid": 0,
          "args": {"name": "p"}}]))


# ------------------------------------------------------------- diff report

def test_diff_report_exact_match_at_zero_noise():
    plan, topo = _plan("1f1b")
    predicted = simulate_schedule(
        plan, topo, make_schedule("1f1b", plan.n_stages, plan.n_micro))
    rec, _ = execute_pipeline(plan, topo, schedule="1f1b")
    rep = diff_report(predicted, rec, executed_wall=rec.wall_time)
    assert rep["events_matched"] == rep["events_predicted"] \
        == rep["events_executed"] == len(predicted.events)
    assert rep["unmatched"] == []
    assert abs(rep["attribution"]["compute_s"]) < 1e-9
    assert abs(rep["attribution"]["transfer_s"]) < 1e-9
    # replay wall time includes the post-flush gradient sync the bare
    # timeline does not predict -> lands in sync/other
    assert rep["step_error_s"] == pytest.approx(
        rep["attribution"]["sync_other_s"], abs=1e-9)
    assert rep["step_error_s"] >= 0
    txt = format_diff(rep)
    assert "attribution:" in txt and "matched" in txt


def test_diff_report_attributes_noise():
    plan, topo = _plan("1f1b")
    predicted = simulate_schedule(
        plan, topo, make_schedule("1f1b", plan.n_stages, plan.n_micro))
    rec, _ = execute_pipeline(plan, topo, schedule="1f1b", noise=0.3,
                              seed=7)
    rep = diff_report(predicted, rec, executed_wall=rec.wall_time)
    assert rep["events_matched"] == len(predicted.events)
    a = rep["attribution"]
    assert abs(a["compute_s"]) > 0 and abs(a["transfer_s"]) > 0
    assert rep["step_error_s"] == pytest.approx(
        a["compute_s"] + a["transfer_s"] + a["sync_other_s"])
    assert rep["worst_events"]
    assert abs(rep["worst_events"][0]["delta_s"]) >= \
        abs(rep["worst_events"][-1]["delta_s"])
    by_kind = rep["by_kind"]
    assert set(by_kind) >= {"F", "B", "X"}
    for agg in by_kind.values():
        assert agg["delta_s"] == pytest.approx(
            agg["executed_s"] - agg["predicted_s"])


def test_diff_report_flags_unmatched_events():
    plan, topo = _plan("1f1b")
    predicted = simulate_schedule(
        plan, topo, make_schedule("1f1b", plan.n_stages, plan.n_micro))
    executed = [{"kind": "F", "stage": 0, "mb": 99, "start": 0.0,
                 "finish": 1.0}]
    rep = diff_report(predicted, executed)
    assert rep["events_matched"] == 0
    assert len(rep["unmatched"]) == len(predicted.events) + 1


# ------------------------------------------------------------------ spans

def test_tracer_disabled_by_default_and_noop():
    tr = Tracer()
    assert not tr.enabled
    ctx = tr.span("x")
    assert ctx is tr.span("y")          # shared no-op context manager
    with ctx:
        pass
    assert len(tr) == 0


def test_tracer_nesting_and_summary():
    tr = Tracer(enabled=True)
    with tr.span("plan", cat="planner", model="m"):
        with tr.span("search", cat="planner"):
            with tr.span("playout", cat="mcts", iter=0):
                pass
        with tr.span("store_put", cat="planner"):
            pass
    spans = {s.name: s for s in tr.spans()}
    assert spans["plan"].depth == 0
    assert spans["search"].depth == 1 and spans["store_put"].depth == 1
    assert spans["playout"].depth == 2
    assert spans["playout"].args == {"iter": 0}
    # children finish before (and inside) their parent
    assert spans["plan"].start <= spans["playout"].start
    assert spans["playout"].end <= spans["plan"].end
    assert spans["plan"].dur >= 0
    summ = tr.summary()
    assert summ["planner/plan"]["count"] == 1
    assert summ["mcts/playout"]["total_s"] >= 0
    tr.clear()
    assert len(tr) == 0


def test_tracer_thread_safety():
    tr = Tracer(enabled=True)
    gate = threading.Barrier(4)         # all threads alive concurrently
    # (thread idents — and so tids — can be reused otherwise)

    def worker(k):
        gate.wait()
        for i in range(50):
            with tr.span("outer", cat="t", k=k):
                with tr.span("inner", cat="t"):
                    pass

    threads = [threading.Thread(target=worker, args=(k,)) for k in range(4)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    spans = tr.spans()
    assert len(spans) == 4 * 50 * 2
    assert {s.tid for s in spans} == set(range(4))   # dense per-thread ids
    for s in spans:                     # nesting is per thread
        assert s.depth == (0 if s.name == "outer" else 1)


def test_tracer_max_spans_drops():
    tr = Tracer(enabled=True, max_spans=3)
    for _ in range(5):
        with tr.span("s"):
            pass
    assert len(tr) == 3 and tr.dropped == 2


def test_tracer_to_chrome_roundtrip():
    tr = Tracer(enabled=True)
    with tr.span("plan", cat="planner"):
        with tr.span("search", cat="planner"):
            pass
    events = tr.to_chrome(process_name="test")
    doc = validate_chrome_trace(chrome_trace(events))
    xs = [e for e in doc["traceEvents"] if e["ph"] == "X"]
    assert {e["name"] for e in xs} == {"plan", "search"}
    assert all(e["args"]["depth"] in (0, 1) for e in xs)
    assert any(e["name"] == "process_name" and e["args"]["name"] == "test"
               for e in doc["traceEvents"])


def test_global_tracer_swap():
    assert not get_tracer().enabled     # instrumentation is opt-in
    tr = Tracer(enabled=True)
    old = set_tracer(tr)
    try:
        assert get_tracer() is tr
    finally:
        assert set_tracer(old) is tr
    assert get_tracer() is old


# ---------------------------------------------------------------- metrics

def test_counter_semantics():
    reg = MetricsRegistry()
    c = reg.counter("requests_total", "reqs")
    c.inc(source="hit")
    c.inc(2.0, source="hit")
    c.inc(source="cold")
    assert c.value(source="hit") == 3.0
    assert c.value(source="cold") == 1.0
    assert c.value(source="nope") == 0.0
    with pytest.raises(ValueError):
        c.inc(-1.0)
    assert reg.counter("requests_total") is c    # get-or-create


def test_gauge_semantics():
    g = MetricsRegistry().gauge("store_size")
    g.set(5)
    g.set(3)
    assert g.value() == 3.0
    g.inc()
    assert g.value() == 4.0
    g.set(0.5, shard="a")
    assert g.value(shard="a") == 0.5 and g.value() == 4.0


def test_histogram_buckets_and_snapshot():
    h = MetricsRegistry().histogram("lat", buckets=(0.1, 1.0, 10.0))
    for v in (0.05, 0.5, 0.5, 5.0, 50.0):
        h.observe(v)
    snap = h.snapshot()
    assert snap["count"] == 5 and snap["sum"] == pytest.approx(56.05)
    assert snap["min"] == 0.05 and snap["max"] == 50.0
    # cumulative per-bucket counts, +Inf catches everything
    assert snap["buckets"] == {"0.1": 1, "1.0": 3, "10.0": 4, "+Inf": 5}
    assert h.snapshot(other="label") == {"count": 0, "sum": 0.0}


def test_registry_kind_conflict_and_dumps():
    reg = MetricsRegistry()
    reg.counter("x", "a counter").inc()
    with pytest.raises(ValueError):
        reg.gauge("x")
    reg.gauge("g").set(1.5, role="planner")
    reg.histogram("h", buckets=(1.0,)).observe(0.5)

    d = reg.to_dict()
    assert d["x"]["kind"] == "counter" and d["x"]["series"][""] == 1.0
    assert d["g"]["series"]['{role="planner"}'] == 1.5
    assert d["h"]["series"][""]["count"] == 1
    json.dumps(d)                       # JSON-able end to end

    text = reg.to_prometheus()
    assert "# TYPE x counter" in text and "# HELP x a counter" in text
    assert 'g{role="planner"} 1.5' in text
    assert 'h_bucket{le="1.0"} 1' in text
    assert 'h_bucket{le="+Inf"} 1' in text
    assert "h_sum 0.5" in text and "h_count 1" in text


# ----------------------------------------------- planner spans + metrics

def test_planner_emits_spans_and_metrics():
    from repro.service.planner import PlannerService
    gg = _chain_gg()
    topo = make_testbed()
    svc = PlannerService(use_registry=False, warm_start=False)
    old = set_tracer(Tracer(enabled=True))
    try:
        svc.plan_graph(gg, topo, iterations=3)       # cold
        svc.plan_graph(gg, topo, iterations=3)       # hit
        names = {(s.cat, s.name) for s in get_tracer().spans()}
    finally:
        set_tracer(old)
    for want in (("planner", "plan"), ("planner", "fingerprint"),
                 ("planner", "store_lookup"), ("planner", "search"),
                 ("mcts", "playout"), ("mcts", "evaluate"),
                 ("mcts", "simulate")):
        assert want in names, want

    m = svc.stats()["metrics"]
    req = m["planner_requests_total"]["series"]
    assert req['{source="cold"}'] == 1.0
    assert req['{source="hit"}'] == 1.0
    lat = m["planner_plan_seconds"]["series"]
    assert lat['{source="cold"}']["count"] == 1
    assert m["planner_playouts"]["series"]['{source="cold"}'][
        "count"] == 1
    assert m["planner_store_size"]["series"][""] >= 1.0
    assert "planner_requests_total" in svc.metrics.to_prometheus()


# ------------------------------------------------------- xla profiler hook

def test_classify_op():
    assert xp.classify_op("all-reduce.3") == "allreduce"
    assert xp.classify_op("AllReduceStart") == "allreduce"
    assert xp.classify_op("reduce-scatter.1") == "allreduce"
    assert xp.classify_op("all-gather.7") == "allreduce"
    assert xp.classify_op("collective-permute.2") == "xfer"
    assert xp.classify_op("copy-start.1") == "xfer"
    assert xp.classify_op("dot_general.5") is None
    assert xp.classify_op("fusion.12") is None


def test_parse_trace_collectives(tmp_path):
    doc = {"traceEvents": [
        {"name": "all-reduce.1", "ph": "X", "ts": 0, "dur": 2000.0,
         "pid": 0, "tid": 0, "args": {"bytes_accessed": 4096}},
        {"name": "collective-permute.9", "ph": "X", "ts": 10, "dur": 500.0,
         "pid": 0, "tid": 0, "args": {}},
        {"name": "dot_general.2", "ph": "X", "ts": 20, "dur": 9000.0,
         "pid": 0, "tid": 0},
        {"name": "all-reduce.zero", "ph": "X", "ts": 30, "dur": 0.0,
         "pid": 0, "tid": 0},
        {"name": "thread_name", "ph": "M", "pid": 0, "tid": 0},
    ]}
    path = tmp_path / "perfetto_trace.json.gz"
    with gzip.open(path, "wt") as f:
        json.dump(doc, f)
    samples = xp.parse_trace_collectives(
        str(path), nominal_bw=1e9, n_dev=4, link="cross", pair="0-1")
    assert len(samples) == 2            # non-collective + zero-dur skipped
    ar = samples[0]
    assert ar["kind"] == "allreduce" and ar["nbytes"] == 4096.0
    assert ar["time"] == pytest.approx(2e-3)     # dur is microseconds
    assert ar["n_dev"] == 4 and ar["link"] == "cross"
    assert ar["pair"] == "0-1" and ar["nominal_bw"] == 1e9
    assert samples[1]["kind"] == "xfer" and samples[1]["nbytes"] == 0.0


def test_profile_step_unavailable_fallback(monkeypatch, tmp_path):
    monkeypatch.setattr(xp, "profiler_available", lambda: False)
    out, samples, meta = xp.profile_step(
        lambda a, b: a + b, 2, 3, log_dir=str(tmp_path))
    assert out == 5 and samples == []
    assert meta == {"profiler": "unavailable"}


def test_profile_step_no_trace(monkeypatch, tmp_path):
    monkeypatch.setattr(xp, "find_trace_files", lambda d: [])

    class FakeCtx:
        def __init__(self, *a, **k):
            pass

        def __enter__(self):
            return self

        def __exit__(self, *exc):
            return False

    import jax
    monkeypatch.setattr(jax.profiler, "trace", FakeCtx)
    out, samples, meta = xp.profile_step(lambda: 7, log_dir=str(tmp_path))
    assert out == 7 and samples == []
    assert meta["profiler"] == "no_trace"


def test_attach_collectives():
    from repro.runtime.telemetry import StepRecord
    rec = StepRecord(collectives=[{"kind": "xfer"}])
    out = xp.attach_collectives(
        rec, [{"kind": "allreduce"}], {"profiler": "ok"})
    assert out is rec and len(rec.collectives) == 2
    assert rec.meta["xla_profiler"]["profiler"] == "ok"


# --------------------------------------------- measurement store readers

def _rec(step, fp="g"):
    from repro.runtime.telemetry import StepRecord
    return StepRecord(graph_fp=fp, topo_fp="t", step=step,
                      wall_time=0.1 * (step + 1))


def test_store_tail_reads_newest_first_ordered(tmp_path):
    from repro.runtime.telemetry import MeasurementStore
    store = MeasurementStore(str(tmp_path))
    for i in range(20):
        store.append(_rec(i, fp="g" if i % 2 == 0 else "other"))
    out = store.tail(3)
    assert [r.step for r in out] == [17, 18, 19]     # oldest first
    out = store.tail(3, graph_fp="g")                # filtered tail
    assert [r.step for r in out] == [14, 16, 18]
    # tiny blocks force the backwards multi-block path
    out = store.tail(5, block_size=64)
    assert [r.step for r in out] == list(range(15, 20))
    assert store.records(limit=3)[-1].step == 19     # delegates to tail
    assert store.tail(0) == []


def test_store_read_new_incremental(tmp_path):
    from repro.runtime.telemetry import MeasurementStore
    store = MeasurementStore(str(tmp_path))
    for i in range(3):
        store.append(_rec(i))
    assert [r.step for r in store.read_new()] == [0, 1, 2]
    assert store.read_new() == []                    # cursor advanced
    store.append(_rec(3))
    store.append(_rec(4, fp="other"))
    assert [r.step for r in store.read_new(graph_fp="g")] == [3]
    assert store.read_new() == []


def test_store_read_new_torn_line_and_truncation(tmp_path):
    from repro.runtime.telemetry import MeasurementStore
    store = MeasurementStore(str(tmp_path))
    store.append(_rec(0))
    assert len(store.read_new()) == 1
    # a torn in-flight append stays buffered until its newline lands
    with open(store.path, "a") as f:
        f.write('{"graph_fp": "g", "step": 1')
    assert store.read_new() == []
    with open(store.path, "a") as f:
        f.write(', "wall_time": 0.5}\n')
    assert [r.step for r in store.read_new()] == [1]
    # rotation/truncation resets the cursor and replays from the start
    with open(store.path, "w") as f:
        f.write("")
    store.append(_rec(9))
    assert [r.step for r in store.read_new()] == [9]


def test_store_memory_mode_readers():
    from repro.runtime.telemetry import MeasurementStore
    store = MeasurementStore()
    for i in range(5):
        store.append(_rec(i))
    assert [r.step for r in store.tail(2)] == [3, 4]
    assert len(store.read_new()) == 5
    assert store.read_new() == []
    store.append(_rec(5))
    assert [r.step for r in store.read_new()] == [5]


# ------------------------------------------- per-op calibration buckets

def test_fit_profile_per_op_buckets():
    from repro.runtime.calibration import fit_profile, profile_metrics
    from repro.runtime.telemetry import StepRecord
    from repro.core.device import peak_flops
    topo = make_testbed()
    peak = peak_flops("V100")
    records = []
    for k in range(4):
        records.append(StepRecord(compute=[
            # forward runs at 50% utilization, backward at 25%
            {"gpu_type": "V100", "op": "F", "flops": 1e12,
             "time": 1e12 / (0.5 * peak)},
            {"gpu_type": "V100", "op": "B", "flops": 2e12,
             "time": 2e12 / (0.25 * peak)},
            {"gpu_type": "V100", "kind": "W", "flops": 1e12,
             "time": 1e12 / (0.5 * peak)},        # falls back to "kind"
            {"gpu_type": "NOPE", "op": "F", "flops": 1e12, "time": 1.0},
        ]))
    prof = fit_profile(records, topo)
    assert set(prof.util_by_op) == {"V100/F", "V100/B", "V100/W"}
    assert prof.util_by_op["V100/F"] == pytest.approx(0.5, rel=1e-3)
    assert prof.util_by_op["V100/B"] == pytest.approx(0.25, rel=1e-3)
    assert prof.meta["op_samples"]["V100/F"] == 4
    # pooled per-device fit still present and between the two buckets
    assert 0.25 < prof.util["V100"] < 0.5

    # roundtrip keeps the buckets
    from repro.runtime.calibration import CalibrationProfile
    prof2 = CalibrationProfile.from_dict(prof.to_dict())
    assert prof2.util_by_op == prof.util_by_op

    reg = profile_metrics(prof)
    d = reg.to_dict()
    by_op = d["calibration_utilization_by_op"]["series"]
    assert by_op['{gpu_type="V100",op="F"}'] == pytest.approx(
        0.5, rel=1e-3)
    assert d["calibration_records"]["series"][""] == 4.0
    assert "calibration_utilization_by_op" in reg.to_prometheus()


def test_replay_samples_feed_op_buckets():
    """End-to-end: replay-executed pipeline telemetry carries per-event
    kinds that land in the per-op utilization tier."""
    from repro.runtime.calibration import fit_profile
    from repro.runtime.telemetry import MeasurementStore
    plan, topo = _plan("zb")
    store = MeasurementStore()
    for step in range(3):
        execute_pipeline(plan, topo, schedule="zb", step=step, store=store)
    prof = fit_profile(store.records(), topo)
    ops = {k.split("/", 1)[1] for k in prof.util_by_op}
    assert {"F", "B", "W"} <= ops


# ----------------------------------------------------------- CLI metrics

def test_cli_metrics_smoke(tmp_path, capsys):
    from repro.service.cli import main
    rc = main(["metrics", "--cache-dir", str(tmp_path / "plans"),
               "--format", "json"])
    assert rc == 0
    out = json.loads(capsys.readouterr().out)
    m = out["stats"]["metrics"]
    assert m["planner_store_size"]["series"][""] == 0.0
    rc = main(["metrics", "--cache-dir", str(tmp_path / "plans")])
    assert rc == 0
    assert "planner_store_size 0" in capsys.readouterr().out
