"""Beyond-paper extensions + regression tests for the perf-loop fixes:
MoE combine variants, the sharding-rules trace fingerprint, PIPE actions,
and in-place scatter accounting in the HLO analyzer."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # property tests need hypothesis
from hypothesis import given, settings, strategies as st

from repro.configs import get_reduced
from repro.models import moe as moe_mod
from repro.models.layers import init_tree


@given(st.integers(0, 1 << 16), st.sampled_from([1.0, 1.25, 8.0]))
@settings(max_examples=10, deadline=None)
def test_moe_scatter_combine_equals_gather_combine(seed, cf):
    """The §Perf o5 reformulation must be numerically identical."""
    cfg = get_reduced("olmoe-1b-7b").replace(capacity_factor=cf)
    p = init_tree(moe_mod.moe_defs(cfg), jax.random.PRNGKey(1), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(seed), (2, 8, cfg.d_model),
                          jnp.float32)
    y1, a1 = moe_mod.moe_fwd(cfg, p, x)
    y2, a2 = moe_mod.moe_fwd(cfg.replace(moe_combine="scatter"), p, x)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-5)
    assert abs(float(a1) - float(a2)) < 1e-6


def test_moe_scatter_combine_grads_match():
    cfg = get_reduced("olmoe-1b-7b")
    p = init_tree(moe_mod.moe_defs(cfg), jax.random.PRNGKey(1), jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 8, cfg.d_model),
                          jnp.float32)

    def loss(params, combine):
        y, aux = moe_mod.moe_fwd(cfg.replace(moe_combine=combine), params, x)
        return jnp.sum(y ** 2) + 0.01 * aux

    g1 = jax.grad(loss)(p, "gather")
    g2 = jax.grad(loss)(p, "scatter")
    for k in g1:
        np.testing.assert_allclose(np.asarray(g1[k]), np.asarray(g2[k]),
                                   atol=2e-4, err_msg=k)


def test_rules_fingerprint_distinguishes_rule_sets():
    from repro.parallel.sharding import (
        AxisRules, axis_rules, rules_fingerprint)

    class _Mesh:
        axis_names = ("data", "model")
        shape = {"data": 4, "model": 2}

    with axis_rules(AxisRules(mesh=_Mesh(), rules={"batch": ("data",)})):
        fp1 = rules_fingerprint()
    with axis_rules(AxisRules(mesh=_Mesh(),
                              rules={"batch": ("data", "model")})):
        fp2 = rules_fingerprint()
    assert fp1 != fp2
    assert rules_fingerprint() is None  # outside any rules context
    assert hash(fp1) is not None        # must be hashable (static arg)


def test_forward_retraces_under_different_rules():
    """Regression for the jax.checkpoint trace-cache leak: the same config
    lowered under different rules must honor each rule set (different
    HLO), not silently reuse the first trace."""
    from repro.configs import get_reduced
    from repro.models import abstract_params, input_specs, loss_fn
    from repro.parallel.sharding import AxisRules, axis_rules
    from repro.configs.shapes import InputShape
    import jax

    cfg = get_reduced("qwen2-1.5b")
    shape = InputShape("t", 32, 4, "train")

    class _Mesh1:
        axis_names = ("data",)
        shape = {"data": 1}

    r1 = AxisRules(mesh=None, rules={})
    r2 = AxisRules(mesh=None, rules={"batch": ("data",)})

    def run(rules):
        def f(p, b):
            with axis_rules(rules):
                return loss_fn(cfg, p, b)[0]
        ap = abstract_params(cfg)
        return jax.jit(f).lower(ap, input_specs(cfg, shape)).as_text()

    # both trace cleanly (mesh-free rules are no-ops; the regression was a
    # crash/stale-shardings only observable on real meshes, covered by
    # tests/test_parallel.py; here we assert the fingerprint plumbing runs)
    assert run(r1) and run(r2)


def test_pipe_action_reachable_by_mcts():
    from repro.core.device import testbed
    from repro.core.strategy import Option, candidate_actions
    acts = candidate_actions(testbed(), has_grad=True)
    assert any(a.option == Option.PIPE for a in acts)
    assert any(a.option == Option.DUP for a in acts)
    # DP-all placement first => never truncated away
    assert acts[0].placement == tuple(range(testbed().m))


def test_hlo_scatter_counts_update_not_buffer():
    from repro.core.hlo_analysis import analyze_hlo

    def f(buf, upd, idx):
        return buf.at[idx].add(upd)

    buf = jnp.zeros((100_000, 64), jnp.float32)
    upd = jnp.ones((8, 64), jnp.float32)
    idx = jnp.arange(8)
    c = jax.jit(f).lower(buf, upd, idx).compile()
    stats = analyze_hlo(c.as_text())
    buf_bytes = 100_000 * 64 * 4
    # must NOT charge read+write of the full buffer
    assert stats.bytes_accessed < 1.2 * buf_bytes


def test_optimizer_bf16_state_dtype():
    """Kimi-scale mitigation: bf16 moments halve optimizer memory and
    still converge on a quadratic."""
    from repro.optim.adam import AdamW
    opt = AdamW(lr=0.05, weight_decay=0.0, state_dtype="bfloat16")
    params = {"w": jnp.asarray([4.0, -2.0])}
    state = opt.init(params)
    assert state["mu"]["w"].dtype == jnp.bfloat16
    for step in range(300):
        grads = jax.grad(lambda p: jnp.sum(p["w"] ** 2))(params)
        params, state = opt.update(params, state, grads, step)
    assert float(jnp.max(jnp.abs(params["w"]))) < 0.2


def test_pallas_attention_path_matches_jnp_path():
    """cfg.attn_impl='pallas' routes the model's attention through the
    flash kernel and must match the jnp reference path."""
    from repro.models import forward, init_params
    cfg = get_reduced("qwen2-1.5b").replace(dtype="float32")
    params = init_params(cfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 128),
                                          0, cfg.vocab_size)}
    h_jnp, _, _ = forward(cfg, params, batch, remat=False)
    h_pl, _, _ = forward(cfg.replace(attn_impl="pallas"), params, batch,
                         remat=False)
    np.testing.assert_allclose(np.asarray(h_jnp), np.asarray(h_pl),
                               atol=2e-3)
