"""Device-topology graph (paper §4.2: device nodes = homogeneous GPU/TPU
groups; edges = inter-group links).

Includes the paper's two evaluation clusters (testbed / cloud), the random
topology generator used for GNN training (§5.2), and the TPU-pod topology
of the hardware adaptation.
"""
from __future__ import annotations

from dataclasses import dataclass, field

import numpy as np

# public peak throughput (FLOP/s), memory, and the *default* utilization
# factor per device type. The utilization priors make heterogeneity RATIOS
# (what drives the search) match the paper's cluster; the runtime feedback
# subsystem (repro.runtime.calibration) refits them from measured step
# telemetry and overrides them via CalibrationProfile.apply().
GPU_PEAKS = {
    "V100": {"peak_flops": 15.7e12, "util": 0.45, "mem": 32e9},
    "V100-16": {"peak_flops": 15.7e12, "util": 0.45, "mem": 16e9},
    "1080Ti": {"peak_flops": 11.3e12, "util": 0.40, "mem": 11e9},
    "P100": {"peak_flops": 9.5e12, "util": 0.40, "mem": 16e9},
    "T4": {"peak_flops": 8.1e12, "util": 0.40, "mem": 16e9},
    "TPUv5e": {"peak_flops": 197e12, "util": 0.5, "mem": 16e9},
    "TPUv4": {"peak_flops": 275e12, "util": 0.5, "mem": 32e9},
}

# effective throughput view (peak x default utilization) — kept for
# backward compatibility with callers that only need effective FLOP/s.
GPU_SPECS = {t: {"flops": s["peak_flops"] * s["util"], "mem": s["mem"]}
             for t, s in GPU_PEAKS.items()}


def peak_flops(gpu_type: str) -> float:
    return GPU_PEAKS[gpu_type]["peak_flops"]


def default_util(gpu_type: str) -> float:
    return GPU_PEAKS[gpu_type]["util"]


@dataclass
class DeviceGroup:
    group_id: int
    gpu_type: str
    num_gpus: int
    intra_bw: float            # B/s between devices inside the group
    mem_bytes: float = 0.0
    flops: float = 0.0

    def __post_init__(self):
        spec = GPU_SPECS[self.gpu_type]
        self.mem_bytes = self.mem_bytes or spec["mem"]
        self.flops = self.flops or spec["flops"]


@dataclass
class Topology:
    groups: list                       # list[DeviceGroup]
    inter_bw: np.ndarray               # (M, M) B/s between groups
    latency: float = 50e-6             # per-transfer latency (s)
    name: str = ""
    # Effective-bandwidth factors, calibrated so the simulator matches the
    # paper's MEASURED comm regressions (§4.1.2 / Table 5: cross-machine
    # NCCL-over-TCP AllReduce on TF in-graph replication delivers well
    # under nominal link bandwidth; P2P GRPC does better). TPU topologies
    # override these (ICI is not TCP).
    coll_eff_cross: float = 0.15       # collectives spanning machines
    coll_eff_intra: float = 0.7        # collectives inside one machine
    p2p_eff: float = 0.6               # point-to-point transfers
    # Per-(gi, gj) point-to-point efficiency overrides, fitted by the
    # runtime calibration's per-link-pair tier once a pair accumulates
    # enough telemetry (repro.runtime.calibration). Falls back to the
    # per-class ``p2p_eff`` for unobserved pairs.
    pair_eff: dict = field(default_factory=dict)

    @property
    def m(self):
        return len(self.groups)

    @property
    def total_devices(self):
        return sum(g.num_gpus for g in self.groups)

    def nominal_bw(self, gi: int, gj: int) -> float:
        """Raw (spec-sheet) link bandwidth between device groups, before
        any efficiency factor. Telemetry records transfers against this
        value so calibration can fit the achieved fraction."""
        if gi == gj:
            return self.groups[gi].intra_bw
        return float(self.inter_bw[gi, gj])

    def nominal_bottleneck(self, group_ids):
        """(raw bottleneck bandwidth, link class) for a collective among
        device groups; class is "intra" (one machine) or "cross"."""
        gids = sorted(set(group_ids))
        b = min(self.groups[g].intra_bw for g in gids)
        cls = "intra"
        for i in gids:
            for j in gids:
                if i < j:
                    b = min(b, float(self.inter_bw[i, j]))
                    cls = "cross"
        return b, cls

    def bw(self, gi: int, gj: int) -> float:
        """Effective point-to-point bandwidth between device groups
        (per-pair calibrated efficiency when available)."""
        eff = self.pair_eff.get((gi, gj), self.p2p_eff)
        return self.nominal_bw(gi, gj) * eff

    def bottleneck_bw(self, group_ids) -> float:
        """Effective bottleneck bandwidth for a collective among device
        groups (SFB's tau / ring AllReduce bandwidth)."""
        b, cls = self.nominal_bottleneck(group_ids)
        return b * (self.coll_eff_cross if cls == "cross"
                    else self.coll_eff_intra)


def _full_inter(m: int, bw: float) -> np.ndarray:
    a = np.full((m, m), bw)
    np.fill_diagonal(a, 0)
    return a


def testbed() -> Topology:
    """Paper §5.2 on-premise cluster: 1x(4 V100, NVLink) + 4x(2 1080Ti,
    PCIe) + 2x(2 P100, PCIe), 100 Gbps switch."""
    gbps = 1e9 / 8
    groups = [DeviceGroup(0, "V100", 4, intra_bw=300 * gbps)]       # NVLink
    for i in range(4):
        groups.append(DeviceGroup(1 + i, "1080Ti", 2, intra_bw=64 * gbps))
    for i in range(2):
        groups.append(DeviceGroup(5 + i, "P100", 2, intra_bw=64 * gbps))
    return Topology(groups, _full_inter(7, 100 * gbps), name="testbed")


def cloud() -> Topology:
    """Paper §5.2 public cloud: 2x(8 V100-16G) + 4x(4 T4), 10 Gbps."""
    gbps = 1e9 / 8
    groups = [DeviceGroup(0, "V100-16", 8, intra_bw=300 * gbps),
              DeviceGroup(1, "V100-16", 8, intra_bw=300 * gbps)]
    for i in range(4):
        groups.append(DeviceGroup(2 + i, "T4", 4, intra_bw=64 * gbps))
    return Topology(groups, _full_inter(6, 10 * gbps), name="cloud")


def two_1080ti() -> Topology:
    """Paper §5.6 SFB experiment: two machines, one 1080Ti each."""
    gbps = 1e9 / 8
    groups = [DeviceGroup(0, "1080Ti", 1, intra_bw=64 * gbps),
              DeviceGroup(1, "1080Ti", 1, intra_bw=64 * gbps)]
    return Topology(groups, _full_inter(2, 10 * gbps), name="2x1080ti")


def homogeneous_2v100() -> Topology:
    """Paper §5.4: two V100s on one machine."""
    gbps = 1e9 / 8
    return Topology([DeviceGroup(0, "V100", 2, intra_bw=300 * gbps)],
                    _full_inter(1, 0), name="2xV100")


def random_topology(rng: np.random.Generator) -> Topology:
    """Paper §5.2 GNN-training distribution: machines in [1,6], GPUs/machine
    in [1,8] of one of 3 types, intra-bw in [64,160] Gbps, inter-bw in
    [20,50] Gbps."""
    gbps = 1e9 / 8
    m = int(rng.integers(1, 7))
    types = ["V100", "1080Ti", "P100"]
    groups = []
    for i in range(m):
        groups.append(DeviceGroup(
            i, types[int(rng.integers(0, 3))], int(rng.integers(1, 9)),
            intra_bw=float(rng.uniform(64, 160)) * gbps))
    inter = rng.uniform(20, 50) * gbps
    return Topology(groups, _full_inter(m, float(inter)),
                    name=f"random-{m}")


def tpu_pods(n_pods: int = 2, chips_per_group: int = 16,
             groups_per_pod: int = 2, gen: str = "TPUv5e") -> Topology:
    """Hardware adaptation: TPU slices as device groups; ICI intra-group,
    DCI across pods. Mixed generations model fleet heterogeneity."""
    groups, gid = [], 0
    for p in range(n_pods):
        for _ in range(groups_per_pod):
            t = gen if p == 0 else ("TPUv4" if gen == "TPUv5e" else gen)
            groups.append(DeviceGroup(gid, t, chips_per_group,
                                      intra_bw=200e9))
            gid += 1
    m = len(groups)
    inter = np.zeros((m, m))
    for i in range(m):
        for j in range(m):
            if i == j:
                continue
            same_pod = i // groups_per_pod == j // groups_per_pod
            inter[i, j] = 100e9 if same_pod else 25e9   # ICI vs DCI
    return Topology(groups, inter, name=f"tpu-{n_pods}pod",
                    coll_eff_cross=0.8, coll_eff_intra=0.9, p2p_eff=0.9)
