"""Heterogeneous GNN (paper §4.2.1, Fig. 2) in pure JAX.

Two node types (op groups, device groups), three link types (op-op,
dev-dev, op-dev/dev-op). Each of the 4 layers does GAT-style multi-head
attention aggregation per edge type:

    h_u^{l+1} = AGG_{v in N(u)} gamma_etype * sigma(W_etype [h_v ; e_uv])

with gamma = 1 for same-type edges and 0.1 for cross-type edges (paper's
balance weights). A thin decoder scores a strategy slice (P_i, O_i) from
[sum_j E_dev[j] P_ij ; E_op[i] ; onehot(O_i)] and a softmax over candidate
slices yields the MCTS priors G(s, a).
"""
from __future__ import annotations

from dataclasses import dataclass
import math

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.features import DEV_F, EDGE_F, OP_F, HetGraph
from repro.core.strategy import Option

GAMMA_SAME = 1.0
GAMMA_CROSS = 0.1
N_OPTIONS = len(Option)


@dataclass(frozen=True)
class GNNConfig:
    hidden: int = 48
    heads: int = 4
    layers: int = 4
    decoder_hidden: int = 64


def _dense_init(key, fan_in, fan_out):
    s = math.sqrt(2.0 / (fan_in + fan_out))
    return jax.random.normal(key, (fan_in, fan_out), jnp.float32) * s


def init_gnn(cfg: GNNConfig, key) -> dict:
    keys = iter(jax.random.split(key, 200))
    H = cfg.hidden
    p = {
        "enc_op": _dense_init(next(keys), OP_F, H),
        "enc_dev": _dense_init(next(keys), DEV_F, H),
    }
    for layer in range(cfg.layers):
        for et in ("oo", "dd", "od", "do"):
            p[f"W_{layer}_{et}"] = _dense_init(next(keys), H + EDGE_F, H)
            p[f"b_{layer}_{et}"] = jnp.zeros((H,), jnp.float32)
            p[f"a_{layer}_{et}"] = jax.random.normal(
                next(keys), (cfg.heads, 2 * (H // cfg.heads)),
                jnp.float32) * 0.1
        p[f"self_{layer}"] = _dense_init(next(keys), H, H)
    D = cfg.decoder_hidden
    p["dec1"] = _dense_init(next(keys), 2 * H + N_OPTIONS, D)
    p["dec1b"] = jnp.zeros((D,), jnp.float32)
    p["dec2"] = _dense_init(next(keys), D, 1)
    return p


def _gat_message(cfg: GNNConfig, W, b, a, h_dst, h_src, e, mask):
    """One edge-type aggregation. h_dst: (U, H); h_src: (V, H);
    e: (U, V, EDGE_F); mask: (U, V) -> (U, H)."""
    U, V = e.shape[0], e.shape[1]
    H = h_dst.shape[-1]
    hd = H // cfg.heads
    src_e = jnp.concatenate(
        [jnp.broadcast_to(h_src[None, :, :], (U, V, H)), e], axis=-1)
    m = jax.nn.leaky_relu(src_e @ W + b)                   # (U, V, H)
    mh = m.reshape(U, V, cfg.heads, hd)
    dh = h_dst.reshape(U, cfg.heads, hd)
    att_in = jnp.concatenate(
        [jnp.broadcast_to(dh[:, None], (U, V, cfg.heads, hd)), mh], axis=-1)
    logits = jnp.einsum("uvkd,kd->uvk", jax.nn.leaky_relu(att_in), a)
    logits = jnp.where(mask[..., None], logits, -1e30)
    alpha = jax.nn.softmax(logits, axis=1)
    alpha = jnp.where(mask[..., None], alpha, 0.0)
    out = jnp.einsum("uvk,uvkd->ukd", alpha, mh).reshape(U, H)
    return out


def gnn_forward(cfg: GNNConfig, p: dict, g: HetGraph):
    """Returns (E_op (N,H), E_dev (M,H))."""
    h_op = jnp.asarray(g.op_x) @ p["enc_op"]
    h_dev = jnp.asarray(g.dev_x) @ p["enc_dev"]
    oo_mask = jnp.asarray(g.oo_mask)
    dd_mask = jnp.asarray(g.dd_mask)
    N, M = h_op.shape[0], h_dev.shape[0]
    od_mask = jnp.ones((N, M), bool)
    oo_e, dd_e = jnp.asarray(g.oo_e), jnp.asarray(g.dd_e)
    od_e = jnp.asarray(g.od_e)
    do_e = jnp.swapaxes(od_e, 0, 1)
    for layer in range(cfg.layers):
        def msg(et, hd_, hs_, e_, m_, layer=layer):
            return _gat_message(cfg, p[f"W_{layer}_{et}"],
                                p[f"b_{layer}_{et}"], p[f"a_{layer}_{et}"],
                                hd_, hs_, e_, m_)
        new_op = h_op @ p[f"self_{layer}"] \
            + GAMMA_SAME * msg("oo", h_op, h_op, oo_e, oo_mask) \
            + GAMMA_CROSS * msg("do", h_op, h_dev, od_e, od_mask)
        new_dev = h_dev @ p[f"self_{layer}"] \
            + GAMMA_SAME * msg("dd", h_dev, h_dev, dd_e, dd_mask) \
            + GAMMA_CROSS * msg("od", h_dev, h_op, do_e,
                                jnp.swapaxes(od_mask, 0, 1))
        h_op = jax.nn.elu(new_op) + h_op
        h_dev = jax.nn.elu(new_dev) + h_dev
    return h_op, h_dev


def actions_to_arrays(actions, m: int, bucket: int = 8):
    """(P (A',M), opt (A',4), mask (A',)) padded to a bucket size so jitted
    calls hit a small number of compiled shapes."""
    A = len(actions)
    Ap = -(-A // bucket) * bucket
    P = np.zeros((Ap, m), np.float32)
    opt = np.zeros((Ap, N_OPTIONS), np.float32)
    mask = np.zeros((Ap,), np.float32)
    for k, a in enumerate(actions):
        for j in a.placement:
            P[k, j] = 1.0
        opt[k, int(a.option)] = 1.0
        mask[k] = 1.0
    return P, opt, mask


def score_actions(cfg: GNNConfig, p: dict, e_op, e_dev, gid, P, opt):
    """Thin decoder: scores for (padded) strategy slices."""
    dev_sum = P @ e_dev                                     # (A, H)
    op_e = jnp.broadcast_to(e_op[gid][None], (P.shape[0], e_op.shape[1]))
    x = jnp.concatenate([dev_sum, op_e, opt], axis=-1)
    h = jax.nn.relu(x @ p["dec1"] + p["dec1b"])
    return (h @ p["dec2"])[:, 0]


def _policy_core(cfg, p, arrays, gid, P, opt, mask):
    g = HetGraph(*arrays)
    e_op, e_dev = gnn_forward(cfg, p, g)
    logits = score_actions(cfg, p, e_op, e_dev, gid, P, opt)
    return jnp.where(mask > 0, logits, -1e30)


_policy_jit = jax.jit(_policy_core, static_argnums=(0,))


# Split forward pass: the heavy 4-layer GAT encoder and the thin decoder
# as separate jitted functions, so callers that hold an episode's
# embeddings fixed (core.trainer.CachedPolicy) only pay the decoder per
# MCTS expansion.

def _embed_core(cfg, p, arrays):
    return gnn_forward(cfg, p, HetGraph(*arrays))


_embed_jit = jax.jit(_embed_core, static_argnums=(0,))


def _score_core(cfg, p, e_op, e_dev, gid, P, opt, mask):
    logits = score_actions(cfg, p, e_op, e_dev, gid, P, opt)
    return jnp.where(mask > 0, logits, -1e30)


_score_jit = jax.jit(_score_core, static_argnums=(0,))


def embed_hetgraph(cfg: GNNConfig, p: dict, g: HetGraph):
    """Encoder half of the policy: (E_op (N,H), E_dev (M,H))."""
    return _embed_jit(cfg, p, _het_arrays(g))


def score_embedded(cfg: GNNConfig, p: dict, e_op, e_dev, gid: int, actions,
                   m: int):
    """Decoder half: logits for ``actions`` given precomputed embeddings."""
    P, opt, mask = actions_to_arrays(actions, m)
    out = _score_jit(cfg, p, e_op, e_dev, jnp.asarray(gid), P, opt, mask)
    return out[:len(actions)]


def _het_arrays(g: HetGraph):
    return (g.op_x, g.dev_x, g.oo_mask, g.oo_e, g.dd_mask, g.dd_e, g.od_e)


def policy_logits(cfg: GNNConfig, p: dict, g: HetGraph, gid: int, actions):
    P, opt, mask = actions_to_arrays(actions, g.dev_x.shape[0])
    out = _policy_jit(cfg, p, _het_arrays(g), jnp.asarray(gid), P, opt, mask)
    return out[:len(actions)]


def policy_probs(cfg: GNNConfig, p: dict, g: HetGraph, gid: int, actions):
    return jax.nn.softmax(policy_logits(cfg, p, g, gid, actions))


def record_loss_core(cfg, p, arrays, gid, P, opt, mask, pi):
    """Cross-entropy between GNN prior and (padded) MCTS visit dist."""
    g = HetGraph(*arrays)
    e_op, e_dev = gnn_forward(cfg, p, g)
    logits = score_actions(cfg, p, e_op, e_dev, gid, P, opt)
    logits = jnp.where(mask > 0, logits, -1e30)
    return -jnp.sum(pi * jax.nn.log_softmax(logits))
