"""Profiler (paper §4.1.2).

Per-op compute times per device type follow the paper's finding that time
is (piecewise) linear in batch size: we model t(op, dev, frac) =
overhead + flops*frac / dev_throughput, and provide the measure-then-
regress pipeline (LinearBatchModel / SegmentedLinear) used to fit real
measurements — exercised on CPU in tests to validate the linearity
assumption, and used to fit GRPC/AllReduce-style comm curves.
"""
from __future__ import annotations

import time
from dataclasses import dataclass

import numpy as np

OP_OVERHEAD = 5e-6     # per-op launch overhead (s)


@dataclass
class LinearBatchModel:
    """t(batch) = a + b * batch, fit on profiled batch sizes (paper: <=60)."""
    a: float
    b: float

    @classmethod
    def fit(cls, batches, times) -> "LinearBatchModel":
        x = np.asarray(batches, float)
        y = np.asarray(times, float)
        b, a = np.polyfit(x, y, 1)
        return cls(a=float(max(a, 0.0)), b=float(max(b, 0.0)))

    def __call__(self, batch: float) -> float:
        return self.a + self.b * batch


@dataclass
class SegmentedLinear:
    """Piecewise-linear size->time model (paper: GRPC/NCCL regressions fit
    on 1KB..1GB doubling sizes)."""
    knots: np.ndarray      # sizes (sorted)
    times: np.ndarray

    @classmethod
    def fit(cls, sizes, times) -> "SegmentedLinear":
        order = np.argsort(sizes)
        return cls(np.asarray(sizes, float)[order],
                   np.asarray(times, float)[order])

    def __call__(self, size: float) -> float:
        k, t = self.knots, self.times
        if size <= k[0]:
            return float(t[0] * size / k[0])
        if size >= k[-1]:
            return float(t[-1] * size / k[-1])
        i = int(np.searchsorted(k, size)) - 1
        f = (size - k[i]) / (k[i + 1] - k[i])
        return float(t[i] + f * (t[i + 1] - t[i]))


def compute_time(flops: float, dev_flops: float, frac: float = 1.0) -> float:
    return OP_OVERHEAD + flops * frac / dev_flops


def transfer_time(nbytes: float, bw: float, latency: float) -> float:
    if nbytes <= 0:
        return 0.0
    return latency + nbytes / bw


def allreduce_time(nbytes: float, n_dev: int, bw: float,
                   latency: float) -> float:
    """Ring AllReduce: 2(D-1)/D * bytes / bottleneck_bw."""
    if n_dev <= 1 or nbytes <= 0:
        return 0.0
    return 2 * (n_dev - 1) / n_dev * nbytes / bw + 2 * n_dev * latency


def ps_round_time(nbytes: float, n_dev: int, bw: float,
                  latency: float) -> float:
    """Sharded PS (round-robin owners) push+pull for one worker's share."""
    if n_dev <= 1 or nbytes <= 0:
        return 0.0
    return 2 * (n_dev - 1) / n_dev * nbytes / bw + 2 * latency


# --------------------------------------------------------- measurement

def measure_op(fn, *args, repeats: int = 5) -> float:
    """Median wall time of a jitted callable (CPU profiling mode)."""
    import jax
    fn(*args)  # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def profile_matmul_batches(batches, dim: int = 256) -> LinearBatchModel:
    """Measure matmul time vs batch size on the host device and fit the
    linear model (validates the paper's linearity assumption in tests)."""
    import jax
    import jax.numpy as jnp
    w = jnp.ones((dim, dim), jnp.float32)
    f = jax.jit(lambda x: x @ w)
    times = []
    for b in batches:
        x = jnp.ones((int(b), dim), jnp.float32)
        times.append(measure_op(f, x))
    return LinearBatchModel.fit(batches, times)
