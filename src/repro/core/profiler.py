"""Profiler (paper §4.1.2).

Per-op compute times per device type follow the paper's finding that time
is (piecewise) linear in batch size: we model t(op, dev, frac) =
overhead + flops*frac / dev_throughput, and provide the measure-then-
regress pipeline (LinearBatchModel / SegmentedLinear) used to fit real
measurements — exercised on CPU in tests to validate the linearity
assumption, and used to fit GRPC/AllReduce-style comm curves.
"""
from __future__ import annotations

from dataclasses import dataclass
import time

import numpy as np

OP_OVERHEAD = 5e-6     # per-op launch overhead (s)


@dataclass
class LinearBatchModel:
    """t(batch) = a + b * batch, fit on profiled batch sizes (paper: <=60)."""
    a: float
    b: float

    @classmethod
    def fit(cls, batches, times) -> "LinearBatchModel":
        x = np.asarray(batches, float)
        y = np.asarray(times, float)
        b, a = np.polyfit(x, y, 1)
        return cls(a=float(max(a, 0.0)), b=float(max(b, 0.0)))

    def __call__(self, batch: float) -> float:
        return self.a + self.b * batch


@dataclass
class SegmentedLinear:
    """Piecewise-linear size->time model (paper: GRPC/NCCL regressions fit
    on 1KB..1GB doubling sizes)."""
    knots: np.ndarray      # sizes (sorted)
    times: np.ndarray

    @classmethod
    def fit(cls, sizes, times) -> "SegmentedLinear":
        order = np.argsort(sizes)
        return cls(np.asarray(sizes, float)[order],
                   np.asarray(times, float)[order])

    def __call__(self, size: float) -> float:
        k, t = self.knots, self.times
        if size <= k[0]:
            return float(t[0] * size / k[0])
        if size >= k[-1]:
            return float(t[-1] * size / k[-1])
        i = int(np.searchsorted(k, size)) - 1
        f = (size - k[i]) / (k[i + 1] - k[i])
        return float(t[i] + f * (t[i + 1] - t[i]))


def compute_time(flops: float, dev_flops: float, frac: float = 1.0) -> float:
    return OP_OVERHEAD + flops * frac / dev_flops


def transfer_time(nbytes: float, bw: float, latency: float) -> float:
    if nbytes <= 0:
        return 0.0
    return latency + nbytes / bw


def allreduce_time(nbytes: float, n_dev: int, bw: float,
                   latency: float) -> float:
    """Ring AllReduce: 2(D-1)/D * bytes / bottleneck_bw."""
    if n_dev <= 1 or nbytes <= 0:
        return 0.0
    return 2 * (n_dev - 1) / n_dev * nbytes / bw + 2 * n_dev * latency


def ps_round_time(nbytes: float, n_dev: int, bw: float,
                  latency: float) -> float:
    """Sharded PS (round-robin owners) push+pull for one worker's share."""
    if n_dev <= 1 or nbytes <= 0:
        return 0.0
    return 2 * (n_dev - 1) / n_dev * nbytes / bw + 2 * latency


# ----------------------------------------------- calibration primitives
# Least-squares fits of the cost model's free parameters from runtime
# telemetry (repro.runtime.calibration orchestrates these per device type
# / link class and packages the result as a CalibrationProfile).

def fit_utilization(flops, times, peak_flops: float,
                    overhead: float = OP_OVERHEAD) -> float | None:
    """Recover a device type's compute utilization from measured op times.

    Model: t = overhead + flops / (peak_flops * u). Least squares through
    the origin on (flops, t - overhead) gives 1/(peak*u); inverted and
    clamped to (0, 1]. Returns ``None`` when the samples carry no signal
    (all times at/under the launch overhead) — the caller keeps its
    nominal prior; a fabricated util=1.0 would move the cost model the
    WRONG way for a cluster that was observed to be slow.
    """
    x = np.asarray(flops, float)
    y = np.asarray(times, float) - overhead
    denom = float(np.sum(x * x))
    if denom <= 0:
        return None
    slope = float(np.sum(x * y)) / denom
    if slope <= 0:
        return None
    return float(min(1.0 / (slope * peak_flops), 1.0))


@dataclass
class CommFit:
    """Fitted link-class parameters: t = size_term / eff + lat_mult * alpha,
    where size_term is the transfer's byte volume normalized by the NOMINAL
    link bandwidth (so ``eff`` is the achieved fraction of nominal) and
    lat_mult counts per-transfer latency hits (1 for p2p, 2n for ring
    AllReduce, 2 for sharded PS)."""
    eff: float                 # achieved fraction of nominal bandwidth
    alpha: float               # per-hit latency (s)
    n_samples: int = 0

    def to_dict(self) -> dict:
        return {"eff": self.eff, "alpha": self.alpha,
                "n_samples": self.n_samples}

    @classmethod
    def from_dict(cls, d: dict) -> "CommFit":
        return cls(eff=float(d["eff"]), alpha=float(d["alpha"]),
                   n_samples=int(d.get("n_samples", 0)))


def fit_comm(size_terms, lat_mults, times,
             prior_alpha: float = 50e-6) -> CommFit | None:
    """Fit one link class's (eff, alpha) by least squares.

    Design matrix columns are [size_term, lat_mult]; the solution's first
    coefficient is 1/eff. Falls back to the prior latency (fitting eff
    alone through the origin) when the system is rank-deficient — e.g. a
    single sample, or all samples sharing one transfer size. Returns
    ``None`` when even that carries no signal (non-positive slope): the
    caller keeps its nominal efficiency rather than adopting a fabricated
    one.
    """
    s = np.asarray(size_terms, float)
    m = np.asarray(lat_mults, float)
    y = np.asarray(times, float)
    eff, alpha = 0.0, prior_alpha
    if len(s) >= 2:
        A = np.stack([s, m], axis=1)
        coef, _, rank, _ = np.linalg.lstsq(A, y, rcond=None)
        if rank == 2 and coef[0] > 0 and coef[1] >= 0:
            eff, alpha = 1.0 / float(coef[0]), float(coef[1])
    if eff <= 0:                       # fall back: alpha pinned to prior
        resid = y - prior_alpha * m
        denom = float(np.sum(s * s))
        slope = float(np.sum(s * resid)) / denom if denom > 0 else 0.0
        if slope <= 0:
            return None
        eff = 1.0 / slope
        alpha = prior_alpha
    return CommFit(eff=float(np.clip(eff, 1e-3, 1.0)), alpha=alpha,
                   n_samples=len(s))


# --------------------------------------------------------- measurement

def measure_op(fn, *args, repeats: int = 5) -> float:
    """Median wall time of a jitted callable (CPU profiling mode)."""
    import jax
    fn(*args)  # warmup/compile
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts))


def profile_matmul_batches(batches, dim: int = 256) -> LinearBatchModel:
    """Measure matmul time vs batch size on the host device and fit the
    linear model (validates the paper's linearity assumption in tests)."""
    import jax
    import jax.numpy as jnp
    w = jnp.ones((dim, dim), jnp.float32)
    f = jax.jit(lambda x: x @ w)
    times = []
    for b in batches:
        x = jnp.ones((int(b), dim), jnp.float32)
        times.append(measure_op(f, x))
    return LinearBatchModel.fit(batches, times)
