"""Multilevel graph partitioner (METIS replacement, paper §4.1.1).

Objective: split the computation graph into <= n_groups op groups,
minimizing the tensor bytes on cut edges while keeping per-group compute
balanced within a balance factor (paper uses 60 groups, factor 2).

Pipeline (standard multilevel scheme):
  1. coarsen by repeated heavy-edge matching (merge the heaviest tensor
     edges first) until the graph is small,
  2. initial partition by balanced topological chunking,
  3. FM-style boundary refinement (gain = cut-bytes reduction) under the
     balance constraint, projected back through the levels.
"""
from __future__ import annotations

from collections import defaultdict

from repro.core.graph import CompGraph


class _CoarseGraph:
    def __init__(self, weights, edges, members):
        self.weights = weights          # node -> compute weight
        self.edges = edges              # (u, v) unordered -> bytes
        self.members = members          # node -> list of original op_ids

    @property
    def n(self):
        return len(self.weights)

    def adjacency(self):
        adj = defaultdict(dict)
        for (u, v), w in self.edges.items():
            adj[u][v] = adj[u].get(v, 0.0) + w
            adj[v][u] = adj[v].get(u, 0.0) + w
        return adj


def _from_comp_graph(g: CompGraph) -> _CoarseGraph:
    min_w = max(1.0, g.total_flops() / max(len(g.nodes), 1) * 1e-3)
    weights = {i: max(n.flops, min_w) for i, n in g.nodes.items()}
    edges: dict = {}
    for e in g.edges:
        if e.src == e.dst:
            continue
        key = (min(e.src, e.dst), max(e.src, e.dst))
        edges[key] = edges.get(key, 0.0) + e.bytes
    members = {i: [i] for i in g.nodes}
    return _CoarseGraph(weights, edges, members)


def _coarsen(cg: _CoarseGraph, max_node_w: float) -> _CoarseGraph:
    """One pass of heavy-edge matching."""
    matched = {}
    order = sorted(cg.edges.items(), key=lambda kv: -kv[1])
    used = set()
    for (u, v), _ in order:
        if u in used or v in used:
            continue
        if cg.weights[u] + cg.weights[v] > max_node_w:
            continue
        matched[u] = v
        used.add(u)
        used.add(v)
    if not matched:
        return cg
    rep = {}
    for node in cg.weights:
        rep[node] = node
    for u, v in matched.items():
        rep[v] = u
    weights, members = {}, {}
    for node, w in cg.weights.items():
        r = rep[node]
        weights[r] = weights.get(r, 0.0) + w
        members.setdefault(r, []).extend(cg.members[node])
    edges: dict = {}
    for (u, v), w in cg.edges.items():
        ru, rv = rep[u], rep[v]
        if ru == rv:
            continue
        key = (min(ru, rv), max(ru, rv))
        edges[key] = edges.get(key, 0.0) + w
    return _CoarseGraph(weights, edges, members)


def _topo_chunks(g: CompGraph, cg: _CoarseGraph, n_groups: int) -> dict:
    """Initial partition: fill groups along a topological order of the
    ORIGINAL graph (coarse nodes ordered by their first member)."""
    topo_pos = {op: i for i, op in enumerate(g.topo_order())}
    nodes = sorted(cg.weights, key=lambda nd: min(
        topo_pos.get(m, 0) for m in cg.members[nd]))
    total = sum(cg.weights.values())
    target = total / n_groups
    assign, gid, acc = {}, 0, 0.0
    for nd in nodes:
        assign[nd] = gid
        acc += cg.weights[nd]
        if acc >= target * (gid + 1) and gid < n_groups - 1:
            gid += 1
    return assign


def _refine(cg: _CoarseGraph, assign: dict, n_groups: int,
            balance: float, passes: int = 4):
    adj = cg.adjacency()
    total = sum(cg.weights.values())
    cap = balance * total / n_groups
    gw = defaultdict(float)
    for nd, gid in assign.items():
        gw[gid] += cg.weights[nd]
    for _ in range(passes):
        moved = 0
        for nd in list(assign):
            cur = assign[nd]
            # cut weight toward each neighboring group
            conn = defaultdict(float)
            for nb, w in adj.get(nd, {}).items():
                conn[assign[nb]] += w
            best_gid, best_gain = cur, 0.0
            for gid, w in conn.items():
                if gid == cur:
                    continue
                gain = w - conn.get(cur, 0.0)
                if gain > best_gain and gw[gid] + cg.weights[nd] <= cap:
                    best_gid, best_gain = gid, gain
            if best_gid != cur:
                gw[cur] -= cg.weights[nd]
                gw[best_gid] += cg.weights[nd]
                assign[nd] = best_gid
                moved += 1
        if moved == 0:
            break
    return assign


def cut_bytes(g: CompGraph, assignment: dict) -> float:
    return sum(e.bytes for e in g.edges
               if assignment[e.src] != assignment[e.dst])


def _condense_cycles(g: CompGraph, assign: dict) -> dict:
    """Merge strongly-connected components of the group graph so the
    grouped view is a DAG (groups must be executable in some order)."""
    gids = sorted(set(assign.values()))
    idx = {gid: i for i, gid in enumerate(gids)}
    n = len(gids)
    succ = [set() for _ in range(n)]
    for e in g.edges:
        a, b = idx[assign[e.src]], idx[assign[e.dst]]
        if a != b:
            succ[a].add(b)
    # iterative Tarjan SCC
    comp = [-1] * n
    low = [0] * n
    num = [0] * n
    on = [False] * n
    stack: list = []
    counter = [0]
    ncomp = [0]
    visited = [False] * n
    for root in range(n):
        if visited[root]:
            continue
        work = [(root, iter(succ[root]))]
        visited[root] = True
        num[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on[root] = True
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if not visited[w]:
                    visited[w] = True
                    num[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on[w] = True
                    work.append((w, iter(succ[w])))
                    advanced = True
                    break
                elif on[w]:
                    low[v] = min(low[v], num[w])
            if advanced:
                continue
            work.pop()
            if work:
                pv = work[-1][0]
                low[pv] = min(low[pv], low[v])
            if low[v] == num[v]:
                while True:
                    w = stack.pop()
                    on[w] = False
                    comp[w] = ncomp[0]
                    if w == v:
                        break
                ncomp[0] += 1
    return {op: comp[idx[gid]] for op, gid in assign.items()}


def _topo_renumber(g: CompGraph, assign: dict) -> dict:
    """Renumber groups in topological order of the (acyclic) group graph."""
    first_pos: dict = {}
    for i, op in enumerate(g.topo_order()):
        gid = assign[op]
        first_pos.setdefault(gid, i)
    order = sorted(first_pos, key=first_pos.get)
    remap = {gid: i for i, gid in enumerate(order)}
    return {op: remap[gid] for op, gid in assign.items()}


def _monotone_refine(g: CompGraph, assign: dict, n_groups: int,
                     balance: float, passes: int = 6):
    """FM-style refinement that PRESERVES acyclicity: a node may move to a
    neighboring group id only while every in-edge still comes from a group
    <= its own and every out-edge goes to a group >= its own."""
    g.build_adj()
    weights = {i: max(n.flops, 1.0) for i, n in g.nodes.items()}
    total = sum(weights.values())
    cap = balance * total / n_groups
    gw = defaultdict(float)
    for op, gid in assign.items():
        gw[gid] += weights[op]

    def gain(op, tgt):
        """Cut-bytes reduction from moving ``op`` assign[op] -> tgt."""
        cur = assign[op]
        d = 0.0
        for e in g._in[op] + g._out[op]:
            nb = e.src if e.dst == op else e.dst
            if nb == op:
                continue
            gnb = assign[nb]
            if gnb == tgt:
                d += e.bytes        # was cut, becomes internal
            elif gnb == cur:
                d -= e.bytes        # was internal, becomes cut
        return d

    for _ in range(passes):
        moved = 0
        for op in g.nodes:
            cur = assign[op]
            lo = max((assign[e.src] for e in g._in[op] if e.src != op),
                     default=0)
            hi = min((assign[e.dst] for e in g._out[op] if e.dst != op),
                     default=n_groups - 1)
            for tgt in {max(lo, cur - 1), min(hi, cur + 1)}:
                if tgt == cur or not (lo <= tgt <= hi):
                    continue
                if gw[tgt] + weights[op] > cap:
                    continue
                if gain(op, tgt) > 0:
                    gw[cur] -= weights[op]
                    gw[tgt] += weights[op]
                    assign[op] = tgt
                    moved += 1
                    break
        if moved == 0:
            break
    return assign


def partition(g: CompGraph, n_groups: int = 60, balance: float = 2.0) -> dict:
    """op_id -> group_id. Groups are ACYCLIC (intervals of a topological
    order, refined monotonically): required because the strategy creator
    treats each group as one schedulable unit."""
    n_groups = max(1, min(n_groups, len(g.nodes)))
    order = g.topo_order()
    weights = {i: max(g.nodes[i].flops, 1.0) for i in g.nodes}
    total = sum(weights.values())
    target = total / n_groups
    assign, gid, acc = {}, 0, 0.0
    for op in order:
        assign[op] = gid
        acc += weights[op]
        if acc >= target * (gid + 1) and gid < n_groups - 1:
            gid += 1
    assign = _monotone_refine(g, assign, n_groups, balance)
    # anchor parameter sources with their first consumer and ApplyGradient
    # sinks with their gradient producer (keeps param/grad bytes attributed
    # to the groups that actually use them; preserves monotonicity since
    # params are sources and apply nodes are sinks)
    g.build_adj()
    for op, node in g.nodes.items():
        if node.is_param and g._out[op]:
            assign[op] = min(assign[e.dst] for e in g._out[op])
        elif node.is_apply_grad and g._in[op]:
            assign[op] = max(assign[e.src] for e in g._in[op])
    out = _condense_cycles(g, assign)   # safety net (no-op when monotone)
    return _topo_renumber(g, out)
