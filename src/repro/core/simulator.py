"""Discrete-event simulator (paper §4.3.2).

FIFO queue per device (TF-default-scheduler-like): a task enters its
device queue when all inputs are ready; devices execute their queues
independently. Transfers serialize per directed link; collectives occupy
all participating devices. Reference-counted tensor lifetimes give peak
memory per device; the result carries the runtime-feedback features the
GNN consumes (makespan, idle-before-transfer, per-device idle %, per-link
idle %, peak memory) and an OOM flag.
"""
from __future__ import annotations

from dataclasses import dataclass, field
import heapq

from repro.core.compiler import TaskGraph
from repro.core.device import Topology
from repro.core.profiler import (
    allreduce_time, compute_time, ps_round_time, transfer_time)
from repro.core.strategy import device_group_of


@dataclass
class SimResult:
    makespan: float
    feasible: bool
    task_start: list
    task_finish: list
    device_busy: dict                 # device -> busy seconds
    peak_mem: dict                    # device -> bytes
    link_busy: dict                   # (gi, gj) -> busy seconds
    group_start: dict = field(default_factory=dict)
    group_finish: dict = field(default_factory=dict)
    group_idle_before_xfer: dict = field(default_factory=dict)

    def device_idle_frac(self, dev: int) -> float:
        if self.makespan <= 0:
            return 0.0
        return 1.0 - self.device_busy.get(dev, 0.0) / self.makespan

    def link_idle_frac(self, gi: int, gj: int) -> float:
        if self.makespan <= 0:
            return 0.0
        return 1.0 - self.link_busy.get((gi, gj), 0.0) / self.makespan


def _dev_speed(topo: Topology, dev: int) -> float:
    return topo.groups[device_group_of(topo, dev)].flops


def simulate(tg: TaskGraph, topo: Topology, profile=None) -> SimResult:
    """Simulate a TaskGraph on a topology.

    ``profile`` is an optional ``repro.runtime.calibration
    .CalibrationProfile``: when given, the hard-coded device utilization
    and link-efficiency constants baked into ``topo`` are replaced by the
    measurement-fitted values before timing anything (paper §4.3 runtime
    feedback refining the simulator).
    """
    if profile is not None:
        topo = profile.apply(topo)
    n = len(tg.tasks)
    indeg = [0] * n
    succs: list = [[] for _ in range(n)]
    for t in tg.tasks:
        for d in t.deps:
            succs[d].append(t.tid)
            indeg[t.tid] += 1

    dev_free: dict = {}
    link_free: dict = {}
    dev_busy: dict = {}
    link_busy: dict = {}
    start = [0.0] * n
    finish = [0.0] * n
    ready_time = [0.0] * n

    # min-heap of (ready_time, tid) — FIFO per device approximated by
    # global readiness order, matching the paper's queue-insertion rule.
    heap = [(0.0, t.tid) for t in tg.tasks if indeg[t.tid] == 0]
    heapq.heapify(heap)
    done = 0
    g_of = {d: device_group_of(topo, d)
            for d in range(topo.total_devices)}

    while heap:
        rt, tid = heapq.heappop(heap)
        t = tg.tasks[tid]
        if t.kind == "compute":
            s = max(rt, dev_free.get(t.device, 0.0))
            dur = compute_time(t.flops, _dev_speed(topo, t.device))
            dev_free[t.device] = s + dur
            dev_busy[t.device] = dev_busy.get(t.device, 0.0) + dur
        elif t.kind == "xfer":
            gi, gj = g_of[t.src], g_of[t.dst]
            key = (t.src, t.dst)
            s = max(rt, link_free.get(key, 0.0))
            dur = transfer_time(t.nbytes, topo.bw(gi, gj), topo.latency)
            link_free[key] = s + dur
            link_busy[(gi, gj)] = link_busy.get((gi, gj), 0.0) + dur
        elif t.kind == "allreduce":
            s = max([rt, *(dev_free.get(d, 0.0) for d in t.devices)])
            gids = [g_of[d] for d in t.devices]
            tau = topo.bottleneck_bw(gids)
            dur = allreduce_time(t.nbytes, len(t.devices), tau, topo.latency)
            for d in t.devices:
                dev_free[d] = s + dur
                dev_busy[d] = dev_busy.get(d, 0.0) + dur
        elif t.kind == "ps":
            # sharded PS: each worker pushes/pulls its share; the slowest
            # link bounds it, but workers are NOT barriered together.
            gids = [g_of[d] for d in t.devices]
            tau = topo.bottleneck_bw(gids)
            dur = ps_round_time(t.nbytes, len(t.devices), tau, topo.latency)
            s = rt  # overlaps with device compute of others
        else:
            s, dur = rt, 0.0
        start[tid], finish[tid] = s, s + dur
        done += 1
        for nx in succs[tid]:
            indeg[nx] -= 1
            ready_time[nx] = max(ready_time[nx], finish[tid])
            if indeg[nx] == 0:
                heapq.heappush(heap, (ready_time[nx], nx))

    makespan = max(finish) if finish else 0.0

    # reference-counted tensor lifetimes (paper §4.3.2): a replica's output
    # is allocated when its compute task finishes and freed when its last
    # consumer (compute on the same device, or outgoing transfer) finishes.
    events: dict = {d: [] for d in range(topo.total_devices)}
    last_use = [finish[t.tid] for t in tg.tasks]
    for t in tg.tasks:
        for d in t.deps:
            last_use[d] = max(last_use[d], finish[t.tid])
    for t in tg.tasks:
        if t.kind != "compute":
            continue
        gid = t.group
        grp_bytes = 0.0
        reps = tg.replicas.get(gid, [])
        rep = next((r for r in reps if r.task == t.tid), None)
        if rep is not None:
            total = tg.group_out_bytes.get(gid, 0.0)
            if tg.group_is_mp.get(gid):
                grp_bytes = total / max(len(reps), 1)   # stage slice
            else:
                grp_bytes = total * rep.frac
        if grp_bytes <= 0:
            continue
        events[t.device].append((finish[t.tid], grp_bytes))
        events[t.device].append((last_use[t.tid], -grp_bytes))

    peak_mem = {}
    feasible = done == n
    for d in range(topo.total_devices):
        resident = tg.params_on.get(d, 0.0) * 4.0  # param+grad+adam moments
        cur, peak = resident, resident
        for _, delta in sorted(events[d]):
            cur += delta
            peak = max(peak, cur)
        peak_mem[d] = peak
        if peak > topo.groups[g_of[d]].mem_bytes:
            feasible = False

    res = SimResult(
        makespan=makespan, feasible=feasible and done == n,
        task_start=start, task_finish=finish, device_busy=dev_busy,
        peak_mem=peak_mem, link_busy=link_busy)

    # per-group runtime feedback
    for gid, reps in tg.replicas.items():
        ts = [r.task for r in reps]
        res.group_start[gid] = min(start[t] for t in ts)
        res.group_finish[gid] = max(finish[t] for t in ts)
    for t in tg.tasks:
        if t.kind == "xfer" and t.group >= 0 and t.deps:
            lag = start[t.tid] - max(finish[d] for d in t.deps)
            cur = res.group_idle_before_xfer.get(t.group, 0.0)
            res.group_idle_before_xfer[t.group] = max(cur, lag)
    return res


def device_group_stats(res: SimResult, topo: Topology):
    """Aggregate per-device-group feedback (GNN features part 3)."""
    stats = []
    base = 0
    for dg in topo.groups:
        devs = range(base, base + dg.num_gpus)
        base += dg.num_gpus
        peak = max((res.peak_mem.get(d, 0.0) for d in devs), default=0.0)
        idle = sum(res.device_idle_frac(d) for d in devs) / max(dg.num_gpus, 1)
        stats.append({"peak_mem": peak, "idle_frac": idle,
                      "mem_frac": peak / dg.mem_bytes})
    return stats
