"""Computation-graph IR for TAG (paper §4.1).

Nodes are ops with per-device-type compute costs, parameter sizes and a
splittability category; edges are tensors with byte sizes. A grouped view
(op groups from the METIS-style partitioner) exposes the same interface to
the strategy creator.
"""
from __future__ import annotations

from dataclasses import dataclass, field
import enum


class Split(enum.Enum):
    CONCAT = "concat"     # splittable in batch dim, outputs concatenated
    SUM = "sum"           # splittable, outputs summed (gradient-like)
    OTHER = "other"       # not splittable (inputs must be aggregated first)


@dataclass
class OpNode:
    op_id: int
    name: str
    op_type: str                      # primitive name ("dot_general", ...)
    flops: float = 0.0
    bytes_out: float = 0.0            # total output tensor bytes
    param_bytes: float = 0.0          # trainable parameter bytes attached
    grad_bytes: float = 0.0           # gradient tensor bytes produced here
    split: Split = Split.CONCAT
    is_grad_producer: bool = False    # produces a parameter gradient
    is_apply_grad: bool = False       # optimizer update op
    is_param: bool = False            # parameter source node
    batch_dim: bool = True            # output carries the batch dimension
    grad_of: int | None = None        # op_id of the ApplyGradient consumer


@dataclass
class TensorEdge:
    src: int
    dst: int
    bytes: float


@dataclass
class CompGraph:
    nodes: dict = field(default_factory=dict)     # op_id -> OpNode
    edges: list = field(default_factory=list)     # list[TensorEdge]
    name: str = ""

    def add_node(self, node: OpNode):
        self.nodes[node.op_id] = node

    def add_edge(self, src: int, dst: int, nbytes: float):
        self.edges.append(TensorEdge(src, dst, float(nbytes)))

    # -- adjacency helpers ------------------------------------------------
    def in_edges(self, op_id: int):
        return [e for e in self.edges if e.dst == op_id]

    def out_edges(self, op_id: int):
        return [e for e in self.edges if e.src == op_id]

    def build_adj(self):
        self._in = {i: [] for i in self.nodes}
        self._out = {i: [] for i in self.nodes}
        for e in self.edges:
            self._out[e.src].append(e)
            self._in[e.dst].append(e)
        return self

    def preds(self, op_id: int):
        return [e.src for e in self._in[op_id]]

    def succs(self, op_id: int):
        return [e.dst for e in self._out[op_id]]

    def topo_order(self):
        indeg = {i: 0 for i in self.nodes}
        for e in self.edges:
            indeg[e.dst] += 1
        stack = [i for i, d in indeg.items() if d == 0]
        order = []
        self.build_adj()
        while stack:
            u = stack.pop()
            order.append(u)
            for e in self._out[u]:
                indeg[e.dst] -= 1
                if indeg[e.dst] == 0:
                    stack.append(e.dst)
        assert len(order) == len(self.nodes), "cycle in computation graph"
        return order

    def total_flops(self):
        return sum(n.flops for n in self.nodes.values())

    def simplify(self):
        """Paper §4.1.1: drop identity/no-op nodes and dangling subgraphs not
        connected to optimizer (apply-grad) ops."""
        # remove trivial ops by splicing edges through them
        trivial = {i for i, n in self.nodes.items()
                   if n.op_type in ("copy", "convert_element_type",
                                    "stop_gradient", "broadcast_in_dim")
                   and n.flops == 0 and not n.is_apply_grad
                   and not n.is_param}
        self.build_adj()
        for t in sorted(trivial):
            ins, outs = self._in[t], self._out[t]
            if len(ins) != 1:
                continue
            src = ins[0].src
            for oe in outs:
                self.edges.append(TensorEdge(src, oe.dst, oe.bytes))
            self.edges = [e for e in self.edges if e.src != t and e.dst != t]
            del self.nodes[t]
            self.build_adj()
        # keep only nodes that reach (or are reached from) an anchor
        anchors = [i for i, n in self.nodes.items()
                   if n.is_apply_grad or n.is_grad_producer]
        if not anchors:
            return self
        keep = set(anchors)
        und = {i: set() for i in self.nodes}
        for e in self.edges:
            und[e.src].add(e.dst)
            und[e.dst].add(e.src)
        frontier = list(anchors)
        while frontier:
            u = frontier.pop()
            for v in und[u]:
                if v not in keep:
                    keep.add(v)
                    frontier.append(v)
        self.nodes = {i: n for i, n in self.nodes.items() if i in keep}
        self.edges = [e for e in self.edges if e.src in keep and e.dst in keep]
        return self


@dataclass
class OpGroup:
    group_id: int
    op_ids: list
    flops: float
    param_bytes: float
    grad_bytes: float
    bytes_out: float
    has_grad: bool
    split: Split


@dataclass
class GroupedGraph:
    """Strategy-creator view: N op groups + inter-group tensor sizes."""
    base: CompGraph
    groups: list                       # list[OpGroup], index = group id
    edges: dict = field(default_factory=dict)   # (gi, gj) -> bytes

    @property
    def n(self):
        return len(self.groups)

    def group_of(self):
        m = {}
        for g in self.groups:
            for o in g.op_ids:
                m[o] = g.group_id
        return m

    def sorted_by_cost(self):
        """Paper §4.2.2: op groups in descending order of computation time."""
        return sorted(range(self.n), key=lambda g: -self.groups[g].flops)


def group_graph(graph: CompGraph, assignment: dict) -> GroupedGraph:
    """Build the grouped view given op->group assignment."""
    n = max(assignment.values()) + 1 if assignment else 0
    groups = []
    for gid in range(n):
        ids = [o for o, g in assignment.items() if g == gid]
        nodes = [graph.nodes[o] for o in ids]
        split = Split.CONCAT
        if any(x.split == Split.OTHER for x in nodes):
            split = Split.OTHER
        elif any(x.split == Split.SUM for x in nodes):
            split = Split.SUM
        groups.append(OpGroup(
            group_id=gid, op_ids=ids,
            flops=sum(x.flops for x in nodes),
            param_bytes=sum(x.param_bytes for x in nodes),
            grad_bytes=sum(x.grad_bytes for x in nodes),
            bytes_out=sum(x.bytes_out for x in nodes),
            has_grad=any(x.is_grad_producer for x in nodes),
            split=split))
    gg = GroupedGraph(base=graph, groups=groups)
    for e in graph.edges:
        gi, gj = assignment[e.src], assignment[e.dst]
        if gi != gj:
            gg.edges[(gi, gj)] = gg.edges.get((gi, gj), 0.0) + e.bytes
    return gg
