"""Graph analyzer front-end: trace a JAX training function into TAG's
CompGraph IR (paper §4.1.1).

The paper consumes TF graphs; here the "execution engine" is JAX/XLA, so
the analyzer walks jaxprs: every equation becomes an op node with a FLOP
estimate and output bytes; higher-order primitives (pjit, scan, remat,
custom_vjp) are inlined (scan bodies once, costs multiplied by length).

Splittability (paper's three categories) is derived by propagating the
batch dimension from the data inputs:
  * output keeps the batch dim            -> Split.CONCAT
  * batch dim contracted away (dW = x^T dy, reduce over batch) -> Split.SUM
  * no batch relationship                 -> Split.OTHER

Gradient producers and synthetic ApplyGradient nodes are attached by
tracing ``value_and_grad`` so the SFB solver can find its subgraphs.
"""
from __future__ import annotations

import math

import jax
from jax.extend.core import Literal
import numpy as np

from repro.core.graph import CompGraph, OpNode, Split

_ELEMWISE = {
    "add", "sub", "mul", "div", "max", "min", "pow", "exp", "log", "tanh",
    "logistic", "rsqrt", "sqrt", "neg", "sign", "abs", "floor", "ceil",
    "select_n", "clamp", "and", "or", "not", "xor", "rem", "integer_pow",
    "erf", "sin", "cos", "squeeze", "expand_dims", "convert_element_type",
    "stop_gradient", "copy", "real", "imag", "add_any", "cumsum",
    "cumlogsumexp", "cummax", "is_finite", "square",
}

_REDUCERS = {"reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
             "reduce_and", "reduce_or", "argmax", "argmin",
             "reduce_precision", "logsumexp"}


def _size_bytes(aval) -> float:
    try:
        return float(math.prod(aval.shape) * np.dtype(aval.dtype).itemsize)
    except Exception:  # tokens/abstract
        return 0.0


def _elems(aval) -> float:
    try:
        return float(math.prod(aval.shape))
    except Exception:
        return 0.0


def _eqn_flops(eqn) -> float:
    prim = eqn.primitive.name
    out = eqn.outvars[0].aval if eqn.outvars else None
    if prim == "dot_general":
        dims = eqn.params["dimension_numbers"]
        (lc, _), _ = dims
        lhs = eqn.invars[0].aval
        k = math.prod(lhs.shape[d] for d in lc) if lc else 1
        return 2.0 * _elems(out) * k
    if prim == "conv_general_dilated":
        rhs = eqn.invars[1].aval
        groups = eqn.params.get("feature_group_count", 1)
        kernel = math.prod(rhs.shape[:-1]) / max(groups, 1)
        return 2.0 * _elems(out) * kernel
    if prim in _REDUCERS or prim.startswith("reduce"):
        return _elems(eqn.invars[0].aval)
    if prim in _ELEMWISE:
        return _elems(out)
    if prim in ("softmax", "logsumexp"):
        return 5.0 * _elems(eqn.invars[0].aval)
    if prim in ("sort", "top_k"):
        n = _elems(eqn.invars[0].aval)
        return n * max(1.0, math.log2(max(n, 2)))
    return 0.0


class _Exporter:
    def __init__(self, batch_size: int):
        self.g = CompGraph()
        self.next_id = 0
        self.var_src: dict = {}      # jaxpr var -> op_id
        self.var_batch: dict = {}    # jaxpr var -> bool (carries batch dim)
        self.batch_size = batch_size

    def new_node(self, **kw) -> OpNode:
        node = OpNode(op_id=self.next_id, **kw)
        self.next_id += 1
        self.g.add_node(node)
        return node

    def _has_batch(self, aval) -> bool:
        shape = getattr(aval, "shape", ())
        return bool(shape) and shape[0] == self.batch_size

    def walk(self, jaxpr, scale: float = 1.0, prefix: str = ""):
        for eqn in jaxpr.eqns:
            prim = eqn.primitive.name
            sub = None
            mult = 1.0
            if prim == "pjit":
                sub = eqn.params["jaxpr"].jaxpr
            elif prim in ("custom_vjp_call", "custom_jvp_call",
                          "custom_vjp_call_jaxpr"):
                cj = eqn.params.get("call_jaxpr") or eqn.params.get("fun_jaxpr")
                sub = cj.jaxpr if hasattr(cj, "jaxpr") else cj
            elif prim == "remat" or prim == "checkpoint":
                sub = eqn.params["jaxpr"]
            elif prim == "scan":
                sub = eqn.params["jaxpr"].jaxpr
                mult = float(eqn.params.get("length", 1))
            elif prim == "while":
                sub = eqn.params["body_jaxpr"].jaxpr
                mult = 1.0
            elif prim == "cond":
                sub = eqn.params["branches"][0].jaxpr

            if sub is not None:
                # connect: map outer invars into sub invars
                # cond eqns carry the predicate as an extra invar, so
                # the zip truncating is the point
                for iv, sv in zip(eqn.invars, sub.invars, strict=False):
                    if hasattr(iv, "aval") and not isinstance(iv, Literal):
                        self.var_src[sv] = self.var_src.get(iv)
                        self.var_batch[sv] = self.var_batch.get(iv, False)
                    else:
                        self.var_batch[sv] = False
                self.walk(sub, scale * mult, prefix)
                for ov, sv in zip(eqn.outvars, sub.outvars,
                                  strict=False):
                    self.var_src[ov] = self.var_src.get(sv)
                    self.var_batch[ov] = self.var_batch.get(sv, False)
                continue

            in_batch = [
                self.var_batch.get(v, False) for v in eqn.invars
                if not isinstance(v, Literal)]
            out_aval = eqn.outvars[0].aval if eqn.outvars else None
            out_has_batch = self._has_batch(out_aval) if out_aval is not None \
                else False
            any_in_batch = any(in_batch)
            if any_in_batch and out_has_batch:
                split = Split.CONCAT
            elif any_in_batch and prim in ("dot_general",
                                           "conv_general_dilated") \
                    or (any_in_batch and prim in _REDUCERS):
                split = Split.SUM
            elif any_in_batch:
                split = Split.SUM if prim == "transpose" else Split.OTHER
            else:
                split = Split.OTHER

            node = self.new_node(
                name=f"{prefix}{prim}_{self.next_id}",
                op_type=prim,
                flops=_eqn_flops(eqn) * scale,
                bytes_out=sum(_size_bytes(v.aval) for v in eqn.outvars),
                split=split,
                batch_dim=out_has_batch,
            )
            for v in eqn.invars:
                if isinstance(v, Literal):
                    continue
                src = self.var_src.get(v)
                if src is not None:
                    self.g.add_edge(src, node.op_id, _size_bytes(v.aval))
            for v in eqn.outvars:
                self.var_src[v] = node.op_id
                self.var_batch[v] = self._has_batch(v.aval)


def trace_training_graph(loss_fn, params, batch, name: str = "") -> CompGraph:
    """Trace ``value_and_grad(loss_fn)(params, batch)`` into a CompGraph
    with parameter sources, gradient producers, and ApplyGradient sinks."""
    vg = jax.value_and_grad(loss_fn)
    closed = jax.make_jaxpr(vg)(params, batch)
    jaxpr = closed.jaxpr

    plist, ptree = jax.tree.flatten(params)
    blist, _ = jax.tree.flatten(batch)
    batch_size = int(blist[0].shape[0]) if blist and len(blist[0].shape) else 0

    def leaf_bytes(x) -> float:
        return float(math.prod(x.shape) * np.dtype(x.dtype).itemsize)

    ex = _Exporter(batch_size)
    n_params = len(plist)
    param_nodes = []
    for i, v in enumerate(jaxpr.invars):
        is_param = i < n_params
        arr = plist[i] if is_param else blist[i - n_params]
        node = ex.new_node(
            name=f"param_{i}" if is_param else f"input_{i - n_params}",
            op_type="parameter",
            bytes_out=leaf_bytes(arr),
            param_bytes=leaf_bytes(arr) if is_param else 0.0,
            split=Split.OTHER if is_param else Split.CONCAT,
            is_param=is_param,
            batch_dim=not is_param and ex._has_batch(arr),
        )
        if is_param:
            param_nodes.append(node)
        ex.var_src[v] = node.op_id
        ex.var_batch[v] = node.batch_dim

    ex.walk(jaxpr)

    # outputs: (loss, *grads) in tree order
    outvars = jaxpr.outvars
    grad_vars = outvars[1:1 + n_params]
    for i, gv in enumerate(grad_vars):
        src = ex.var_src.get(gv)
        if src is None:
            continue
        gnode = ex.g.nodes[src]
        gnode.is_grad_producer = True
        pb = leaf_bytes(plist[i])
        gnode.grad_bytes += pb
        apply_node = ex.new_node(
            name=f"apply_grad_{i}",
            op_type="apply_gradient",
            flops=3.0 * math.prod(plist[i].shape),   # adam-style update
            bytes_out=pb,
            split=Split.OTHER,
            is_apply_grad=True,
        )
        gnode.grad_of = apply_node.op_id
        ex.g.add_edge(src, apply_node.op_id, pb)
        ex.g.add_edge(param_nodes[i].op_id, apply_node.op_id, pb)

    ex.g.name = name
    ex.g.build_adj()
    return ex.g
