"""Strategy -> JAX execution bridge (hardware adaptation layer).

TAG strategies speak op-group placement on heterogeneous device groups; the
real execution engine here is XLA SPMD on a homogeneous TPU mesh. This
module lowers a searched Strategy into:

  * an ``AxisRules`` set (logical-axis -> mesh-axis mapping) consumed by the
    models' ``logical_shard`` constraints,
  * per-block gradient-sync modes ("allreduce" | "ps" | "sfb") consumed by
    ``parallel/sfb_dense`` style layers and the optimizer-state sharding
    choice (PS => ZeRO-style sharded moments).

Mapping rules (documented in DESIGN.md §3):
  * dominant option MP            -> tensor parallelism over "model"
  * AR / PS replication           -> data parallelism over "pod"+"data";
                                     PS additionally shards optimizer
                                     moments over "data" (ZeRO-1)
  * DUP (SFB)                     -> grad_sync "sfb" for the dense blocks
                                     whose gradients the ILP duplicated
  * partial placement (subset of
    device groups)                -> smaller data-parallel degree: batch
                                     maps to "data" only (not "pod")
"""
from __future__ import annotations

from collections import Counter
from dataclasses import dataclass

from repro.core.strategy import Option, Strategy
from repro.parallel.sharding import AxisRules


@dataclass
class ExecutionPlan:
    rules: AxisRules
    grad_sync: dict              # block/param prefix -> sync mode
    zero1: bool                  # shard optimizer moments over data axis
    summary: dict
    # Pipeline stage map when the strategy carries PIPE actions spanning
    # >= 2 device groups (repro.exec.stages.StagePlan) — the launcher
    # routes these through the pipeline execution engine instead of the
    # single-mesh axis rules above. None for pure single-mesh plans.
    stage_plan: object | None = None

    @property
    def is_pipelined(self) -> bool:
        return self.stage_plan is not None


def lower_strategy(strat: Strategy, gg, topo, mesh, *,
                   n_micro: int = 4) -> ExecutionPlan:
    opts = Counter(a.option for a in strat.actions if a is not None)
    n = max(sum(opts.values()), 1)
    placements = [a.placement for a in strat.actions if a is not None]
    full_m = topo.m
    partial = sum(1 for p in placements if len(p) < full_m) / max(
        len(placements), 1)

    multi = "pod" in mesh.axis_names
    batch_axes = ("pod", "data") if multi else ("data",)
    if partial > 0.5 and multi:
        batch_axes = ("data",)      # partial replication: keep DP inside pod

    rules = {
        "batch": batch_axes,
        "cache_seq": ("data",),
        "embed": None, "expert_embed": None, "layers": None, "seq": None,
        "q_heads": None, "kv_heads": None, "mlp": None, "experts": None,
        "vocab": None, "ssm_heads": None, "ssm_inner": None,
    }
    mp_frac = (opts.get(Option.MP, 0) + opts.get(Option.PIPE, 0)) / n
    if mp_frac > 0.1 or full_m == 1:
        for k in ("q_heads", "kv_heads", "mlp", "experts", "vocab",
                  "ssm_heads", "ssm_inner"):
            rules[k] = "model"

    grad_sync = {}
    zero1 = False
    for gid, a in enumerate(strat.actions):
        if a is None:
            continue
        if a.option == Option.PS:
            grad_sync[f"group{gid}"] = "ps"
            zero1 = True
        elif a.option == Option.DUP:
            grad_sync[f"group{gid}"] = "sfb"
        else:
            grad_sync[f"group{gid}"] = "allreduce"

    stage_plan = None
    if gg is not None and strat.has_pipeline():
        # lazy import: repro.exec sits above core in the layering
        from repro.exec.stages import build_stage_plan
        stage_plan = build_stage_plan(gg, strat, topo, n_micro=n_micro)

    ar = AxisRules(mesh=mesh, rules=rules, grad_sync=grad_sync)
    return ExecutionPlan(
        rules=ar, grad_sync=grad_sync, zero1=zero1, stage_plan=stage_plan,
        summary={
            "options": {o.name: c for o, c in opts.items()},
            "partial_placement_frac": partial,
            "mp_frac": mp_frac,
            "pipe_frac": opts.get(Option.PIPE, 0) / n,
            "batch_axes": batch_axes,
            "n_stages": stage_plan.n_stages if stage_plan else 0,
        })
