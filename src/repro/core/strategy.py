"""Deployment strategies (paper §4.2).

A strategy assigns every op group a placement row P_i over device groups
and one of the four replication options O_i:
  AR  — replicate with AllReduce gradient sync
  PS  — replicate with parameter-server sync (round-robin shard owners)
  DUP — duplicate: inputs broadcast, identical compute on every device
        (this is how SFB manifests: broadcast sufficient factors,
        recompute gradients locally — no sync op)
  MP  — model parallelism: ops split across the devices of the group
"""
from __future__ import annotations

from dataclasses import dataclass
import enum
import json

from repro.core.device import Topology


class Option(enum.IntEnum):
    AR = 0
    PS = 1
    DUP = 2
    MP = 3
    PIPE = 4   # beyond-paper: the paper's stated future work (§6) —
               # pipeline the group's stages across devices w/ microbatches


# microbatch schedules the search may attach to a PIPE action (GPipe is
# excluded: it is dominated by 1F1B on both bubble and stash, so offering
# it only widens the branching factor; it stays reachable via --pipeline)
PIPE_SEARCH_SCHEDULES = ("1f1b", "interleaved", "zb")


@dataclass(frozen=True)
class Action:
    """Deployment of one op group: device groups + replication option.

    PIPE actions additionally carry the microbatch ``schedule`` the
    pipeline should run ("gpipe" | "1f1b" | "interleaved" | "zb") — the
    schedule-aware search costs each choice with the schedule timeline
    simulator. Empty string = not applicable / legacy default (1F1B).
    """
    placement: tuple          # sorted tuple of device-group ids
    option: Option
    schedule: str = ""        # PIPE only; "" elsewhere

    def __repr__(self):
        tail = f":{self.schedule}" if self.schedule else ""
        return (f"<{self.option.name}{tail}"
                f"@{','.join(map(str, self.placement))}>")

    def to_dict(self) -> dict:
        d = {"placement": [int(g) for g in self.placement],
             "option": self.option.name}
        if self.schedule:
            d["schedule"] = self.schedule   # omitted when unset, so plans
            #                                 stored before the field keep
            #                                 a byte-identical canonical form
        return d

    @classmethod
    def from_dict(cls, d: dict) -> "Action":
        return cls(placement=tuple(int(g) for g in d["placement"]),
                   option=Option[d["option"]],
                   schedule=d.get("schedule", ""))


@dataclass
class Strategy:
    actions: list             # index = group id; None = undecided

    @classmethod
    def empty(cls, n_groups: int) -> "Strategy":
        return cls(actions=[None] * n_groups)

    def with_action(self, gid: int, action: Action) -> "Strategy":
        acts = list(self.actions)
        acts[gid] = action
        return Strategy(acts)

    @property
    def n_decided(self):
        return sum(a is not None for a in self.actions)

    def complete(self):
        return all(a is not None for a in self.actions)

    def fill_undecided(self, default: Action) -> "Strategy":
        """Paper footnote 2: undecided groups take the strategy of the most
        expensive decided group (the default here)."""
        return Strategy([a if a is not None else default
                         for a in self.actions])

    # -- serialization (plan store schema) --------------------------------
    def to_dict(self) -> dict:
        return {"actions": [a.to_dict() if a is not None else None
                            for a in self.actions]}

    @classmethod
    def from_dict(cls, d: dict) -> "Strategy":
        return cls([Action.from_dict(a) if a is not None else None
                    for a in d["actions"]])

    def canonical_json(self) -> str:
        """Deterministic byte representation (cache identity checks)."""
        return json.dumps(self.to_dict(), sort_keys=True,
                          separators=(",", ":"))

    def pipe_actions(self) -> list:
        """[(gid, action)] for groups the strategy pipelines across >= 2
        device groups — the ones ``repro.exec.stages`` cuts stages at."""
        return [(gid, a) for gid, a in enumerate(self.actions)
                if a is not None and a.option == Option.PIPE
                and len(a.placement) >= 2]

    def has_pipeline(self) -> bool:
        """True when a real multi-stage execution path exists (any PIPE
        action spanning more than one device group)."""
        return bool(self.pipe_actions())


def data_parallel_all(topo: Topology, option: Option = Option.AR) -> Action:
    """The DP baseline action: replicate on every device group."""
    return Action(tuple(range(topo.m)), option)


def candidate_actions(topo: Topology, *, has_grad: bool,
                      max_actions: int = 128) -> list:
    """Enumerate the candidate deployments for one op group.

    The raw space (2^M - 1 placements x 4 options) is intractable for MCTS
    branching; following the paper's device-group abstraction we enumerate:
    each single device group, each same-GPU-type set, the fastest-k
    prefixes, and all groups.
    """
    m = topo.m
    placements: list = []
    if m > 1:
        placements.append(tuple(range(m)))   # DP-all first (never truncated)
    for g in range(m):
        placements.append((g,))
    by_type: dict = {}
    for g, dg in enumerate(topo.groups):
        by_type.setdefault(dg.gpu_type, []).append(g)
    for gs in by_type.values():
        if len(gs) > 1:
            placements.append(tuple(sorted(gs)))
    order = sorted(range(m), key=lambda g: -(topo.groups[g].flops
                                             * topo.groups[g].num_gpus))
    for k in range(2, m):
        placements.append(tuple(sorted(order[:k])))
    if m > 1:
        placements.append(tuple(range(m)))
    # dedupe, preserve order
    seen, uniq = set(), []
    for p in placements:
        if p not in seen:
            seen.add(p)
            uniq.append(p)

    actions = []
    for p in uniq:
        n_dev = sum(topo.groups[g].num_gpus for g in p)
        opts = [Option.AR, Option.PS] if (has_grad and n_dev > 1) \
            else [Option.AR]
        if has_grad and n_dev > 1:
            opts.append(Option.DUP)
        if n_dev > 1:
            opts.append(Option.MP)
        for o in opts:
            actions.append(Action(p, o))
        if n_dev > 1 and len(p) > 1:
            # one PIPE variant per searchable schedule: the schedule-aware
            # evaluator ranks them by bubble fraction + boundary transfers
            for sched in PIPE_SEARCH_SCHEDULES:
                actions.append(Action(p, Option.PIPE, schedule=sched))
    return actions[:max_actions]


def canonical_strategies(n_groups: int, topo: Topology) -> list:
    """Well-known strategy families inside TAG's space: DP-AR/PS over all
    devices, each GPU type alone (AR/PS), and the fastest-half prefix.
    Used as warm-start candidates (benchmarks) and as re-search seeds when
    the runtime feedback loop recalibrates the cost model — a drifted
    cluster can move the optimum far from the cached plan."""
    out = [Strategy([data_parallel_all(topo, o)] * n_groups)
           for o in (Option.AR, Option.PS)]
    by_type: dict = {}
    for g, dg in enumerate(topo.groups):
        by_type.setdefault(dg.gpu_type, []).append(g)
    order = sorted(range(topo.m),
                   key=lambda g: -(topo.groups[g].flops
                                   * topo.groups[g].num_gpus))
    subsets = [tuple(sorted(v)) for v in by_type.values()]
    subsets.append(tuple(sorted(order[:max(1, topo.m // 2)])))
    for p in subsets:
        for o in (Option.AR, Option.PS):
            out.append(Strategy([Action(p, o)] * n_groups))
    return out


def devices_of(topo: Topology, placement) -> list:
    """Flat device ids for a placement (group-major)."""
    out = []
    for g in placement:
        base = sum(topo.groups[k].num_gpus for k in range(g))
        out.extend(range(base, base + topo.groups[g].num_gpus))
    return out


def device_group_of(topo: Topology, dev: int) -> int:
    acc = 0
    for g, dg in enumerate(topo.groups):
        acc += dg.num_gpus
        if dev < acc:
            return g
    raise ValueError(dev)
