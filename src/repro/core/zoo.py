"""The paper's six benchmark DNNs (Table 3), as traceable JAX functions.

These are the strategy-search *subjects*: we only need their computation
graphs (real dimensions, abstract params — nothing is allocated), so each
builder returns ``(loss_fn, abstract_params, abstract_batch)``. Parameter
sizes and compute/communication ratios match the paper's table closely
(VGG19 ~550 MB dominated by FC layers, ResNet101 compute-heavy/~170 MB,
Transformer/BERT attention stacks).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

f32 = jnp.dtype("float32")


def _sds(*shape, dtype=f32):
    return jax.ShapeDtypeStruct(tuple(int(s) for s in shape), dtype)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _pool(x, k=2):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, k, k, 1), (1, k, k, 1), "VALID")


def _softmax_ce(logits, labels):
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[:, None], axis=-1)[:, 0]
    return jnp.mean(logz - gold)


# ----------------------------------------------------------------- VGG19

_VGG_CFG = [64, 64, "M", 128, 128, "M", 256, 256, 256, 256, "M",
            512, 512, 512, 512, "M", 512, 512, 512, 512, "M"]


def vgg19(batch: int = 96):
    params, cin = {}, 3
    for i, c in enumerate(_VGG_CFG):
        if c == "M":
            continue
        params[f"conv{i}"] = _sds(3, 3, cin, c)
        cin = c
    params["fc1"] = _sds(7 * 7 * 512, 4096)
    params["fc2"] = _sds(4096, 4096)
    params["fc3"] = _sds(4096, 1000)

    def loss_fn(p, b):
        x = b["image"]
        for i, c in enumerate(_VGG_CFG):
            if c == "M":
                x = _pool(x)
            else:
                x = jax.nn.relu(_conv(x, p[f"conv{i}"]))
        x = x.reshape(x.shape[0], -1)
        x = jax.nn.relu(x @ p["fc1"])
        x = jax.nn.relu(x @ p["fc2"])
        return _softmax_ce(x @ p["fc3"], b["label"])

    batch_specs = {"image": _sds(batch, 224, 224, 3),
                   "label": _sds(batch, dtype=jnp.int32)}
    return loss_fn, params, batch_specs


# -------------------------------------------------------------- ResNet101

_RESNET_STAGES = [(64, 3), (128, 4), (256, 23), (512, 3)]


def resnet101(batch: int = 96):
    params = {"stem": _sds(7, 7, 3, 64)}
    cin = 64
    for s, (c, blocks) in enumerate(_RESNET_STAGES):
        for b in range(blocks):
            pfx = f"s{s}b{b}"
            params[pfx + "c1"] = _sds(1, 1, cin if b == 0 else 4 * c, c)
            params[pfx + "c2"] = _sds(3, 3, c, c)
            params[pfx + "c3"] = _sds(1, 1, c, 4 * c)
            if b == 0:
                params[pfx + "proj"] = _sds(1, 1, cin, 4 * c)
        cin = 4 * c
    params["fc"] = _sds(2048, 1000)

    def loss_fn(p, b):
        x = jax.nn.relu(_conv(b["image"], p["stem"], stride=2))
        x = _pool(x)
        for s, (_c, blocks) in enumerate(_RESNET_STAGES):
            for blk in range(blocks):
                pfx = f"s{s}b{blk}"
                stride = 2 if (blk == 0 and s > 0) else 1
                h = jax.nn.relu(_conv(x, p[pfx + "c1"], stride=stride))
                h = jax.nn.relu(_conv(h, p[pfx + "c2"]))
                h = _conv(h, p[pfx + "c3"])
                sc = _conv(x, p[pfx + "proj"], stride=stride) \
                    if pfx + "proj" in p else x
                x = jax.nn.relu(h + sc)
        x = jnp.mean(x, axis=(1, 2))
        return _softmax_ce(x @ p["fc"], b["label"])

    batch_specs = {"image": _sds(batch, 224, 224, 3),
                   "label": _sds(batch, dtype=jnp.int32)}
    return loss_fn, params, batch_specs


# ------------------------------------------------------------ InceptionV3

def inception_v3(batch: int = 96):
    """Simplified Inception: stem + 8 mixed blocks with parallel towers."""
    params = {"stem1": _sds(3, 3, 3, 32), "stem2": _sds(3, 3, 32, 64),
              "stem3": _sds(3, 3, 64, 192)}
    cin = 192
    widths = [256, 288, 288, 768, 768, 768, 1280, 2048]
    for i, w in enumerate(widths):
        b = w // 4
        params[f"m{i}t1"] = _sds(1, 1, cin, b)
        params[f"m{i}t2a"] = _sds(1, 1, cin, b)
        params[f"m{i}t2b"] = _sds(3, 3, b, b)
        params[f"m{i}t3a"] = _sds(1, 1, cin, b)
        params[f"m{i}t3b"] = _sds(3, 3, b, b)
        params[f"m{i}t3c"] = _sds(3, 3, b, b)
        params[f"m{i}t4"] = _sds(1, 1, cin, w - 3 * b)
        cin = w
    params["fc"] = _sds(2048, 1000)

    def loss_fn(p, b):
        x = jax.nn.relu(_conv(b["image"], p["stem1"], stride=2))
        x = jax.nn.relu(_conv(x, p["stem2"]))
        x = jax.nn.relu(_conv(x, p["stem3"]))
        x = _pool(x)
        for i, _w in enumerate(widths):
            t1 = jax.nn.relu(_conv(x, p[f"m{i}t1"]))
            t2 = jax.nn.relu(_conv(jax.nn.relu(_conv(x, p[f"m{i}t2a"])),
                                   p[f"m{i}t2b"]))
            t3 = jax.nn.relu(_conv(x, p[f"m{i}t3a"]))
            t3 = jax.nn.relu(_conv(t3, p[f"m{i}t3b"]))
            t3 = jax.nn.relu(_conv(t3, p[f"m{i}t3c"]))
            t4 = jax.nn.relu(_conv(x, p[f"m{i}t4"]))
            x = jnp.concatenate([t1, t2, t3, t4], axis=-1)
            if i in (2, 5):
                x = _pool(x)
        x = jnp.mean(x, axis=(1, 2))
        return _softmax_ce(x @ p["fc"], b["label"])

    batch_specs = {"image": _sds(batch, 149, 149, 3),
                   "label": _sds(batch, dtype=jnp.int32)}
    return loss_fn, params, batch_specs


# ------------------------------------------------- Transformer / BERT

def _attn_block_params(d: int, dff: int, pfx: str):
    return {
        pfx + "wq": _sds(d, d), pfx + "wk": _sds(d, d),
        pfx + "wv": _sds(d, d), pfx + "wo": _sds(d, d),
        pfx + "w1": _sds(d, dff), pfx + "w2": _sds(dff, d),
        pfx + "ln1": _sds(d), pfx + "ln2": _sds(d),
    }


def _attn_block(p, x, pfx, heads: int, causal: bool = False):
    B, S, d = x.shape
    hd = d // heads

    def ln(h, g):
        mu = jnp.mean(h, -1, keepdims=True)
        var = jnp.mean((h - mu) ** 2, -1, keepdims=True)
        return (h - mu) * jax.lax.rsqrt(var + 1e-5) * g

    h = ln(x, p[pfx + "ln1"])
    q = (h @ p[pfx + "wq"]).reshape(B, S, heads, hd)
    k = (h @ p[pfx + "wk"]).reshape(B, S, heads, hd)
    v = (h @ p[pfx + "wv"]).reshape(B, S, heads, hd)
    s = jnp.einsum("bqhd,bkhd->bhqk", q, k) / math.sqrt(hd)
    if causal:
        mask = jnp.tril(jnp.ones((S, S), bool))
        s = jnp.where(mask, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bkhd->bqhd", w, v).reshape(B, S, d)
    x = x + o @ p[pfx + "wo"]
    h = ln(x, p[pfx + "ln2"])
    return x + jax.nn.relu(h @ p[pfx + "w1"]) @ p[pfx + "w2"]


def _bert_like(layers: int, d: int, dff: int, heads: int, vocab: int,
               batch: int, seq: int, causal: bool = False):
    params = {"embed": _sds(vocab, d), "pos": _sds(seq, d)}
    for i in range(layers):
        params.update(_attn_block_params(d, dff, f"l{i}_"))

    def loss_fn(p, b):
        x = p["embed"][b["tokens"]] + p["pos"][None]
        for i in range(layers):
            x = _attn_block(p, x, f"l{i}_", heads, causal)
        logits = x @ p["embed"].T   # tied head
        logz = jax.scipy.special.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, b["labels"][..., None], -1)[..., 0]
        return jnp.mean(logz - gold)

    batch_specs = {"tokens": _sds(batch, seq, dtype=jnp.int32),
                   "labels": _sds(batch, seq, dtype=jnp.int32)}
    return loss_fn, params, batch_specs


def transformer(batch: int = 480):
    # paper: 407MB params — decoder-only stack, 32k vocab (tied embeddings)
    return _bert_like(6, 1024, 4096, 16, 32_000, batch, 128, causal=True)


def bert_small(batch: int = 96):
    return _bert_like(4, 512, 2048, 8, 30_522, batch, 128)


def bert_large(batch: int = 16):
    return _bert_like(24, 1024, 4096, 16, 30_522, batch, 384)


ZOO = {
    "inception_v3": inception_v3,
    "resnet101": resnet101,
    "vgg19": vgg19,
    "transformer": transformer,
    "bert_small": bert_small,
    "bert_large": bert_large,
}


def build(name: str, batch: int | None = None, scale: float = 1.0):
    """Build a zoo model; ``batch`` overrides the paper's batch size."""
    fn = ZOO[name]
    kwargs = {} if batch is None else {"batch": batch}
    return fn(**kwargs)
