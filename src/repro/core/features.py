"""GNN input featurization (paper §4.2.1, Table 1).

The unified heterogeneous graph has op-group nodes and device-group nodes;
three link types (op-op tensors, dev-dev links, op-dev placements). The
four feature parts: raw graph/device features, the strategy encoding,
runtime feedback from the simulator, and search progress. Features are
log-scaled where sizes/times appear so unseen model scales stay in range.
"""
from __future__ import annotations

from dataclasses import dataclass
import math

import numpy as np

from repro.core.device import Topology
from repro.core.graph import GroupedGraph
from repro.core.simulator import SimResult, device_group_stats
from repro.core.strategy import Strategy

OP_F = 13      # per-op-node features (5-wide option one-hot)
DEV_F = 8      # per-device-node features
EDGE_F = 2     # per-edge features (both etypes)

_AVG_FLOPS = 5e12  # normalizing device speed


def _log1p(x, scale=1.0):
    return math.log1p(max(x, 0.0) / scale)


@dataclass
class HetGraph:
    op_x: np.ndarray       # (N, OP_F)
    dev_x: np.ndarray      # (M, DEV_F)
    oo_mask: np.ndarray    # (N, N) bool
    oo_e: np.ndarray       # (N, N, EDGE_F)
    dd_mask: np.ndarray    # (M, M)
    dd_e: np.ndarray       # (M, M, EDGE_F)
    od_e: np.ndarray       # (N, M, EDGE_F) — full bipartite, placement bit


def featurize(gg: GroupedGraph, topo: Topology, strat: Strategy,
              res: SimResult | None, next_gid: int | None,
              observed: SimResult | None = None) -> HetGraph:
    """Build the heterogeneous GNN input.

    ``res`` carries the runtime-feedback feature part (Table 1 part 3).
    When ``observed`` is given — a SimResult-shaped aggregate of REAL step
    telemetry (``repro.runtime.telemetry.observed_sim_result``) — its
    measured device/link idle signals overlay the simulator's estimates
    (paper §4.3). Features real telemetry cannot attribute stay
    per-candidate from ``res``: group makespan / idle-before-transfer
    (real executions observe devices, not op groups, unless a record
    carries group data) and peak-memory fractions (reference-counted in
    the simulator only) — a wholesale replacement would make every MCTS
    candidate look identical on exactly the signals that rank them.
    """
    # overlay only what the observation actually ATTRIBUTES: a wall-time-
    # only record (empty busy maps) would otherwise read as "everything
    # 100% idle" — a fabricated constant wiping the per-candidate signals
    grp_src = observed if observed is not None and observed.group_finish \
        else res
    N, M = gg.n, topo.m
    op_x = np.zeros((N, OP_F), np.float32)
    stats = device_group_stats(res, topo) if res is not None else None
    obs_stats = device_group_stats(observed, topo) \
        if observed is not None and observed.device_busy else None
    link_src = observed if observed is not None and observed.link_busy \
        else res
    for i, grp in enumerate(gg.groups):
        a = strat.actions[i]
        t_avg = grp.flops / _AVG_FLOPS
        op_x[i, 0] = _log1p(t_avg, 1e-3)                   # computation time
        op_x[i, 1] = _log1p(grp.param_bytes, 1e6)          # parameter size
        if a is not None:
            op_x[i, 2 + int(a.option)] = 1.0               # replication plan
        if grp_src is not None:
            op_x[i, 7] = _log1p(
                grp_src.group_finish.get(i, 0.0)
                - grp_src.group_start.get(i, 0.0), 1e-3)    # makespan
            op_x[i, 8] = _log1p(
                grp_src.group_idle_before_xfer.get(i, 0.0), 1e-3)
        op_x[i, 9] = 1.0 if a is not None else 0.0          # decided
        op_x[i, 10] = 1.0 if i == next_gid else 0.0         # produced next
        op_x[i, 11] = 1.0 if grp.has_grad else 0.0
        op_x[i, 12] = _log1p(grp.bytes_out, 1e6)

    dev_x = np.zeros((M, DEV_F), np.float32)
    for j, dg in enumerate(topo.groups):
        dev_x[j, 0] = dg.num_gpus / 8.0
        dev_x[j, 1] = _log1p(dg.mem_bytes, 1e9)
        dev_x[j, 2] = _log1p(dg.intra_bw, 1e9)
        dev_x[j, 3] = dg.flops / _AVG_FLOPS
        if stats is not None:
            dev_x[j, 4] = stats[j]["mem_frac"]              # peak memory
            dev_x[j, 5] = stats[j]["idle_frac"]             # idling %
        if obs_stats is not None:
            dev_x[j, 5] = obs_stats[j]["idle_frac"]         # measured
    oo_mask = np.zeros((N, N), bool)
    oo_e = np.zeros((N, N, EDGE_F), np.float32)
    for (gi, gj), b in gg.edges.items():
        oo_mask[gi, gj] = oo_mask[gj, gi] = True
        oo_e[gi, gj, 0] = oo_e[gj, gi, 0] = _log1p(b, 1e6)  # tensor size

    dd_mask = np.ones((M, M), bool)
    dd_e = np.zeros((M, M, EDGE_F), np.float32)
    for i in range(M):
        for j in range(M):
            dd_e[i, j, 0] = _log1p(topo.bw(i, j), 1e9)      # inter-group bw
            if link_src is not None:
                dd_e[i, j, 1] = link_src.link_idle_frac(i, j)  # idling %

    od_e = np.zeros((N, M, EDGE_F), np.float32)
    for i, a in enumerate(strat.actions):
        if a is None:
            continue
        for j in a.placement:
            od_e[i, j, 0] = 1.0                             # placement bit
    return HetGraph(op_x, dev_x, oo_mask, oo_e, dd_mask, dd_e, od_e)
