"""GNN policy training (paper §4.2.2).

Each step: sample a (DNN graph, device topology) pair, run MCTS with the
current policy, collect (state, visit-distribution) records at vertices
with enough visits, and minimize cross-entropy between the GNN prior
G_theta(s, a) and the MCTS selection probability pi(s, a) = N / sum N.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.device import Topology, random_topology
from repro.core.features import HetGraph
from repro.core.graph import GroupedGraph
from repro.core.hetgnn import (
    GNNConfig, embed_hetgraph, init_gnn, policy_probs, score_embedded)
from repro.core.mcts import MCTS
from repro.optim.adam import AdamW


@dataclass
class TrainState:
    cfg: GNNConfig
    params: dict
    opt: AdamW
    opt_state: dict
    step: int = 0
    losses: list = field(default_factory=list)


class CachedPolicy:
    """GNN policy with per-(HetGraph, params) embedding memoization.

    ``gnn_forward`` (4 GAT layers over the full heterogeneous graph) is by
    far the dominant cost of a policy query, yet its inputs are fixed for
    every expansion that scores the same HetGraph — MCTS feeds the
    episode-static featurization (see ``MCTS._static_het``) precisely so
    this cache collapses the encoder to one run per search; only the thin
    ``score_actions`` decoder runs per op group. Keys are content hashes
    of the feature arrays (never ``id()`` — a GC'd graph's id can be
    reused), and the cache is LRU-bounded.
    """

    cache_embeddings = True     # advertised to MCTS (static featurization)

    def __init__(self, cfg: GNNConfig, params: dict, max_entries: int = 8):
        self.cfg = cfg
        self.params = params
        self.max_entries = max_entries
        self._cache: "OrderedDict" = OrderedDict()
        self.hits = 0
        self.misses = 0

    def _key(self, het: HetGraph):
        h = hashlib.sha1()
        for a in (het.op_x, het.dev_x, het.oo_mask, het.oo_e,
                  het.dd_mask, het.dd_e, het.od_e):
            h.update(np.ascontiguousarray(a).tobytes())
        return h.digest()

    def embeddings(self, het: HetGraph):
        key = self._key(het)
        hit = self._cache.get(key)
        if hit is not None:
            self.hits += 1
            self._cache.move_to_end(key)
            return hit
        self.misses += 1
        e = embed_hetgraph(self.cfg, self.params, het)
        self._cache[key] = e
        while len(self._cache) > self.max_entries:
            self._cache.popitem(last=False)
        return e

    def __call__(self, het: HetGraph, gid: int, actions):
        e_op, e_dev = self.embeddings(het)
        logits = score_embedded(self.cfg, self.params, e_op, e_dev, gid,
                                actions, het.dev_x.shape[0])
        return np.asarray(jax.nn.softmax(logits))


def make_policy(cfg: GNNConfig, params: dict, *,
                cache_embeddings: bool = True):
    """Build an MCTS-facing policy callable from trained GNN params.

    With ``cache_embeddings`` (default) the returned policy memoizes the
    encoder per featurized graph and MCTS feeds it the episode-static
    featurization — one ``gnn_forward`` per search instead of one per
    expansion. Pass False for the exact per-vertex featurization
    (strategy-so-far context in the encoder input, pre-memoization
    behaviour).
    """
    if cache_embeddings:
        return CachedPolicy(cfg, params)

    def policy(het: HetGraph, gid: int, actions):
        return np.asarray(policy_probs(cfg, params, het, gid, actions))
    return policy


def init_trainer(cfg: GNNConfig | None = None, seed: int = 0,
                 lr: float = 3e-4) -> TrainState:
    cfg = cfg or GNNConfig()
    params = init_gnn(cfg, jax.random.PRNGKey(seed))
    opt = AdamW(lr=lr, weight_decay=0.0, state_dtype="float32")
    return TrainState(cfg, params, opt, opt.init(params))


from repro.core.hetgnn import (  # noqa: E402 — needs TrainState above
    _het_arrays, actions_to_arrays, record_loss_core)

_loss_and_grad = jax.jit(
    jax.value_and_grad(record_loss_core, argnums=1), static_argnums=(0,))


def train_step(state: TrainState, records, *, use_feedback: bool = True):
    """One gradient step over a list of MCTS visit records (per-record
    jitted loss+grad, accumulated — shapes are padded so only a handful of
    compilations happen)."""
    if not records:
        return 0.0
    tot_loss = 0.0
    acc = None
    for (het, gid, actions, pi) in records:
        if not use_feedback:
            het = _strip_feedback(het)
        P, O, mask = actions_to_arrays(actions, het.dev_x.shape[0])
        pi_pad = np.zeros((P.shape[0],), np.float32)
        pi_pad[:len(pi)] = pi
        loss, grads = _loss_and_grad(
            state.cfg, state.params, _het_arrays(het), jnp.asarray(gid),
            P, O, mask, pi_pad)
        tot_loss += float(loss)
        acc = grads if acc is None else jax.tree.map(
            jnp.add, acc, grads)
    grads = jax.tree.map(lambda g: g / len(records), acc)
    state.params, state.opt_state = state.opt.update(
        state.params, state.opt_state, grads, state.step)
    state.step += 1
    mean = tot_loss / len(records)
    state.losses.append(mean)
    return mean


def _strip_feedback(het: HetGraph) -> HetGraph:
    """Ablation (paper §5.5): zero the runtime-feedback features."""
    op_x = het.op_x.copy()
    op_x[:, 7] = 0.0
    op_x[:, 8] = 0.0
    dev_x = het.dev_x.copy()
    dev_x[:, 4] = 0.0
    dev_x[:, 5] = 0.0
    dd_e = het.dd_e.copy()
    dd_e[:, :, 1] = 0.0
    return HetGraph(op_x, dev_x, het.oo_mask, het.oo_e, het.dd_mask,
                    dd_e, het.od_e)


def train_policy(state: TrainState, graphs: list, *, steps: int = 20,
                 mcts_iters: int = 24, seed: int = 0,
                 topologies: list | None = None,
                 use_feedback: bool = True, verbose: bool = False):
    """Paper's training loop: random (graph, topology) pairs per step."""
    rng = np.random.default_rng(seed)
    for step in range(steps):
        gg: GroupedGraph = graphs[int(rng.integers(len(graphs)))]
        topo: Topology = (topologies[int(rng.integers(len(topologies)))]
                          if topologies else random_topology(rng))
        policy = make_policy(state.cfg, state.params)
        mcts = MCTS(gg, topo, policy=policy, seed=int(rng.integers(1 << 31)),
                    record_threshold=6)
        sr = mcts.search(mcts_iters)
        loss = train_step(state, sr.visit_records, use_feedback=use_feedback)
        if verbose:
            print(f"  gnn step {step}: loss={loss:.4f} "
                  f"records={len(sr.visit_records)} "
                  f"best_speedup={sr.best_reward:.2f}", flush=True)
    return state
