"""GNN-guided Monte-Carlo tree search (paper §4.2.2).

Vertices are partial strategies; level k decides the deployment of the
k-th op group in descending computation-time order. Edge statistics
(visit count N, running-average reward Q) drive PUCT selection:

    U(s,a) = Q(s,a) + c * G(s,a) * sqrt(sum_a' N(s,a')) / (1 + N(s,a))

Rewards are simulated speed-ups over the DP-AllReduce baseline (OOM = -1,
paper's interactive OOM-rejection). Priors G come from the heterogeneous
GNN fed with the partial strategy + its simulated runtime feedback; a
uniform prior gives the "pure MCTS" ablation (Table 7).
"""
from __future__ import annotations

from dataclasses import dataclass, field
import math

import numpy as np

from repro.core.compiler import compile_strategy
from repro.core.device import Topology
from repro.core.features import featurize
from repro.core.graph import GroupedGraph
from repro.core.simulator import simulate
from repro.core.strategy import (
    Action, Option, Strategy, candidate_actions, data_parallel_all)
from repro.obs.spans import get_tracer


@dataclass
class Vertex:
    strategy: Strategy
    depth: int                       # number of decided groups
    actions: list | None = None      # candidates for the next group
    prior: np.ndarray | None = None
    N: np.ndarray | None = None
    Q: np.ndarray | None = None
    children: dict = field(default_factory=dict)
    reward: float = 0.0
    feedback: object = None          # SimResult of the filled strategy


@dataclass
class SearchResult:
    best_strategy: Strategy
    best_reward: float
    best_time: float
    baseline_time: float
    iters_to_beat_baseline: int      # -1 if never
    rewards: list
    visit_records: list              # (featurized state, gid, actions, pi)
    iterations_run: int = 0          # playouts actually executed
    warm_started: bool = False       # seeded from a prior strategy
    # best strategy among the playouts whose FILLED strategy pipelines
    # (None when no playout did) — diagnostic view of the pipe-subspace
    # decision when the overall winner is a single-mesh plan
    best_pipelined: Strategy | None = None
    best_pipelined_reward: float = float("-inf")


class MCTS:
    def __init__(self, gg: GroupedGraph, topo: Topology, *, policy=None,
                 c_puct: float = 1.5, seed: int = 0,
                 record_threshold: int = 8,
                 prior_strategy: Strategy | None = None,
                 prior_weight: float = 0.5,
                 observed_feedback=None,
                 schedule_aware: bool = True,
                 pipe_global_micro: int = 16):
        self.gg = gg
        self.topo = topo
        self.policy = policy          # callable(hetgraph, gid, actions)->probs
        self.c = c_puct
        # schedule-aware PIPE costing: pipelined strategies are ranked by
        # the schedule timeline simulator (bubble fraction + boundary
        # transfers under a memory-capped microbatch depth) instead of the
        # generic task-graph FIFO model; False = the PR-4-era FIFO ablation
        self.schedule_aware = schedule_aware
        self.pipe_global_micro = pipe_global_micro
        self._pipe_cache: dict = {}   # (partition, schedule) -> (step, res)
        # runtime feedback (paper §4.3): when a deployed plan's measured
        # step telemetry is available, its SimResult-shaped aggregate
        # overrides the simulated feedback features the GNN sees
        self.observed_feedback = observed_feedback
        self.rng = np.random.default_rng(seed)
        self.order = gg.sorted_by_cost()
        self.record_threshold = record_threshold
        # warm start (planner service): a previously-searched strategy whose
        # actions bias the priors and seed the first playout
        if prior_strategy is not None \
                and len(prior_strategy.actions) != gg.n:
            raise ValueError("prior_strategy has wrong group count")
        if prior_strategy is not None:
            # plans cached before PIPE actions carried a schedule store
            # schedule="" — candidate_actions only emits named variants
            # now, so normalize to the legacy default (1f1b) or the
            # blend/seed lookups would silently never match them
            prior_strategy = Strategy([
                Action(a.placement, a.option, schedule="1f1b")
                if a is not None and a.option == Option.PIPE
                and not a.schedule and len(a.placement) > 1 else a
                for a in prior_strategy.actions])
        self.prior_strategy = prior_strategy
        self.prior_weight = prior_weight

        base = Strategy([data_parallel_all(topo)] * gg.n)
        res = simulate(compile_strategy(gg, base, topo), self.topo)
        self.baseline_time = res.makespan
        self.default_action = data_parallel_all(topo)
        # episode-static featurization for embedding-caching policies: the
        # DP-baseline SimResult stands in as the episode's runtime-feedback
        # signal (deterministic, available before any playout)
        self._baseline_res = res
        self._static_het = None

    # ---------------------------------------------------------------- eval
    def _evaluate(self, strat: Strategy):
        filled = strat.fill_undecided(self._fill_action(strat))
        if self.schedule_aware and filled.has_pipeline():
            out = self._pipe_evaluate(filled)
            if out is not None:
                return out
        with get_tracer().span("simulate", cat="mcts"):
            tg = compile_strategy(self.gg, filled, self.topo)
            res = simulate(tg, self.topo)
        if not res.feasible:
            return -1.0, res
        return self.baseline_time / res.makespan, res

    def _pipe_evaluate(self, filled: Strategy):
        """Schedule-aware reward of a pipelined strategy: cut it into a
        StagePlan, run the voted microbatch schedule through the timeline
        simulator at the memory-capped feasible depth, and charge flushes
        plus the per-stage gradient sync. Results are memoized per
        (partition, schedule) — the timeline is episode-static, and many
        playouts land on the same cut. Returns None when the strategy has
        no multi-group spine (the FIFO model stays in charge); an
        infeasible memory cap is the paper's interactive OOM-rejection
        (-1 reward)."""
        # lazy import: repro.exec sits above core in the layering
        from repro.exec.schedule import (
            schedule_step_cost, timeline_to_simresult)
        from repro.exec.stages import build_stage_plan
        plan = build_stage_plan(self.gg, filled, self.topo,
                                n_micro=self.pipe_global_micro)
        if plan is None:
            return None
        key = (plan.placement, plan.schedule,
               tuple(tuple(s.op_group_ids) for s in plan.stages),
               tuple(s.sync for s in plan.stages))
        hit = self._pipe_cache.get(key)
        if hit is None:
            cost = schedule_step_cost(plan, self.topo, plan.schedule,
                                      global_micro=self.pipe_global_micro)
            if cost is None:
                hit = (None, None)
            else:
                res = timeline_to_simresult(
                    plan, cost["timeline"], self.topo, self.gg,
                    flushes=cost["flushes"],
                    sync_time=cost["sync_time_s"])
                hit = (cost["step_time_s"], res)
            self._pipe_cache[key] = hit
        step, res = hit
        if step is None:
            return -1.0, res
        return self.baseline_time / step, res

    def _fill_action(self, strat: Strategy):
        """Paper footnote 2: undecided groups copy the strategy of the most
        computation-expensive decided group."""
        for gid in self.order:
            if strat.actions[gid] is not None:
                return strat.actions[gid]
        return self.default_action

    def _episode_het(self):
        """Featurization shared by every expansion of this search: empty
        strategy, baseline runtime feedback, no next-group marker. Policies
        advertising ``cache_embeddings`` receive this same HetGraph at every
        vertex, so their encoder memoization collapses ``gnn_forward`` to
        one run per episode (the decoder still sees per-vertex actions)."""
        if self._static_het is None:
            self._static_het = featurize(
                self.gg, self.topo, Strategy.empty(self.gg.n),
                self._baseline_res, None, observed=self.observed_feedback)
        return self._static_het

    def _priors(self, vertex: Vertex):
        gid = self.order[vertex.depth]
        actions = candidate_actions(
            self.topo, has_grad=self.gg.groups[gid].has_grad)
        if self.policy is None:
            probs = np.full(len(actions), 1.0 / len(actions))
        else:
            tracer = get_tracer()
            if getattr(self.policy, "cache_embeddings", False):
                het = self._episode_het()
            else:
                with tracer.span("featurize", cat="mcts"):
                    het = featurize(self.gg, self.topo, vertex.strategy,
                                    vertex.feedback, gid,
                                    observed=self.observed_feedback)
            with tracer.span("gnn_forward", cat="mcts"):
                probs = np.asarray(self.policy(het, gid, actions),
                                   np.float64)
            probs = probs / max(probs.sum(), 1e-9)
        return actions, self._blend_prior(gid, actions, probs)

    def _blend_prior(self, gid: int, actions, probs):
        """Mix prior mass toward the warm-start strategy's action."""
        if self.prior_strategy is None:
            return probs
        pa = self.prior_strategy.actions[gid]
        if pa is None or pa not in actions:
            return probs
        onehot = np.zeros(len(actions))
        onehot[actions.index(pa)] = 1.0
        return (1.0 - self.prior_weight) * probs + self.prior_weight * onehot

    def _expand(self, v: Vertex):
        if v.depth < self.gg.n and v.actions is None:
            v.actions, v.prior = self._priors(v)
            v.N = np.zeros(len(v.actions))
            # First-play urgency: unvisited actions start at the vertex's
            # own evaluation instead of 0. Deciding one more group often
            # fills to the SAME complete strategy as the parent (footnote-2
            # fill), so the child's reward exactly repeats the parent's —
            # with Q(unvisited)=0 such a plateau child outranks every
            # unexplored sibling and a small-budget search marches down a
            # constant-reward chain, learning nothing per playout (the
            # schedule-aware PIPE rewards made these plateaus common
            # enough to trap the policy-training searches). At Q=v.reward
            # a plateau child ties its siblings and the prior-weighted
            # exploration term decides; the init washes out on the first
            # real visit (running average with N=1 sets Q=r).
            v.Q = np.full(len(v.actions), v.reward)

    def _backprop(self, path, r):
        for (pv, ai) in path:
            pv.N[ai] += 1
            pv.Q[ai] += (r - pv.Q[ai]) / pv.N[ai]

    def _seed_playout(self, root: Vertex):
        """Warm-start playout (planner service): descend along the prior
        strategy's actions, expanding vertices and creating children on the
        way, so the first evaluation is the full prior strategy and its path
        carries visit statistics like any other iteration. Returns None —
        charging no playout — when no prior action applies at the root."""
        v = root
        path = []
        while v.depth < self.gg.n:
            self._expand(v)
            gid = self.order[v.depth]
            pa = self.prior_strategy.actions[gid]
            if pa is None or pa not in v.actions:
                break
            a_idx = v.actions.index(pa)
            path.append((v, a_idx))
            if a_idx not in v.children:
                v.children[a_idx] = Vertex(
                    v.strategy.with_action(gid, pa), v.depth + 1)
            v = v.children[a_idx]
        if not path:
            return None
        r, res = self._evaluate(v.strategy)
        v.reward, v.feedback = r, res
        self._expand(v)
        self._backprop(path, r)
        return r, v

    # -------------------------------------------------------------- search
    def search(self, iterations: int = 100, *,
               stop_reward: float | None = None) -> SearchResult:
        root = Vertex(Strategy.empty(self.gg.n), 0)
        root.reward, root.feedback = self._evaluate(root.strategy)
        best = {"r": root.reward, "s": root.strategy, "iters": -1,
                "pipe_r": float("-inf"), "pipe_s": None}
        rewards = []
        records = []
        it_run = 0

        def note(r, v):
            nonlocal it_run
            it_run += 1
            rewards.append(r)
            if r > best["r"]:
                best["r"], best["s"] = r, v.strategy
            if best["iters"] < 0 and r > 1.0:
                best["iters"] = it_run
            if r > best["pipe_r"]:      # guard keeps the re-fill off the
                #                         common path (rarely improves)
                filled_v = v.strategy.fill_undecided(
                    self._fill_action(v.strategy))
                if filled_v.has_pipeline():
                    best["pipe_r"], best["pipe_s"] = r, filled_v

        if self.prior_strategy is not None and iterations > 0:
            seeded = self._seed_playout(root)
            if seeded is not None:
                note(*seeded)

        tracer = get_tracer()
        while it_run < iterations:
            if stop_reward is not None and best["r"] >= stop_reward:
                break
            with tracer.span("playout", cat="mcts", iter=it_run):
                # selection
                path = []
                v = root
                while True:
                    if v.depth >= self.gg.n:
                        break
                    if v.actions is None:  # unexpanded leaf
                        break
                    total_n = v.N.sum()
                    u = v.Q + self.c * v.prior \
                        * math.sqrt(total_n + 1e-9) / (1.0 + v.N)
                    a_idx = int(np.argmax(u))
                    path.append((v, a_idx))
                    if a_idx not in v.children:
                        gid = self.order[v.depth]
                        child = Vertex(
                            v.strategy.with_action(gid, v.actions[a_idx]),
                            v.depth + 1)
                        v.children[a_idx] = child
                        v = child
                        break
                    v = v.children[a_idx]

                # expansion + evaluation
                with tracer.span("evaluate", cat="mcts", depth=v.depth):
                    r, res = self._evaluate(v.strategy)
                v.reward, v.feedback = r, res
                with tracer.span("expand", cat="mcts"):
                    self._expand(v)

                # back-propagation
                self._backprop(path, r)
                note(r, v)

        # collect training records from well-visited vertices
        def visit(v):
            if v.actions is not None and v.N is not None \
                    and v.N.sum() >= self.record_threshold:
                pi = np.log(np.maximum(v.N, 1e-9))
                pi = np.exp(pi - pi.max())
                pi = pi / pi.sum()
                gid = self.order[v.depth]
                het = featurize(self.gg, self.topo, v.strategy,
                                v.feedback, gid,
                                observed=self.observed_feedback)
                records.append((het, gid, v.actions, pi))
            for ch in v.children.values():
                visit(ch)
        visit(root)

        filled = best["s"].fill_undecided(self._fill_action(best["s"]))
        return SearchResult(
            best_strategy=filled,
            best_reward=best["r"],
            best_time=self.baseline_time / max(best["r"], 1e-9)
            if best["r"] > 0 else float("inf"),
            baseline_time=self.baseline_time,
            iters_to_beat_baseline=best["iters"],
            rewards=rewards,
            visit_records=records,
            iterations_run=it_run,
            warm_started=self.prior_strategy is not None,
            best_pipelined=best["pipe_s"],
            best_pipelined_reward=best["pipe_r"])
