"""Trip-count-aware analyzer for optimized HLO text.

XLA's ``compiled.cost_analysis()`` counts each while-loop (lax.scan) body
ONCE, which under-reports FLOPs/bytes for scan-over-layers models by the
trip count. This module re-derives the three roofline inputs directly from
``compiled.as_text()``:

  * flops           — dot / convolution ops, multiplied through the call
                      graph by every enclosing while's known_trip_count
  * hbm bytes       — per top-level op: operand + result bytes, with
                      fusions counted at their boundary only (a fusion is
                      one kernel: internal traffic stays in registers/VMEM)
  * collective wire bytes — per collective opcode, with ring-algorithm
                      factors (all-reduce 2x, others 1x of the result size)

This is the profiler the §Perf hillclimb reads; it is validated against
cost_analysis on loop-free modules in tests.
"""
from __future__ import annotations

from collections import defaultdict
from dataclasses import dataclass, field
import re

_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "c128": 16, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"([a-z][a-z0-9]*)\[([0-9,]*)\]")

COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
               "collective-permute")
_WIRE_FACTOR = {"all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
                "all-to-all": 1.0, "collective-permute": 1.0}

_SKIP_BYTES = {"parameter", "constant", "get-tuple-element", "tuple",
               "bitcast", "after-all", "partition-id", "replica-id", "iota"}


def xla_cost_analysis(compiled) -> dict:
    """Version-compat wrapper: ``Compiled.cost_analysis()`` returns a dict
    on current jax but a per-partition list of dicts on older releases."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    return ca or {}


def _type_bytes(type_str: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(type_str):
        dt = m.group(1)
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def _shape_dims(type_str: str):
    """Dims of the FIRST array shape in a type string."""
    m = _SHAPE_RE.search(type_str)
    if not m:
        return ()
    return tuple(int(d) for d in m.group(2).split(",") if d)


@dataclass
class Op:
    name: str
    type_str: str
    opcode: str
    operands: list
    attrs: str

    def attr_list(self, key: str):
        m = re.search(rf"{key}={{([0-9,]*)}}", self.attrs)
        if not m:
            return []
        return [int(x) for x in m.group(1).split(",") if x]

    def called(self, key: str):
        m = re.search(rf"{key}=(%[\w.\-]+)", self.attrs)
        return m.group(1) if m else None

    @property
    def trip_count(self):
        m = re.search(r'"known_trip_count":{"n":"(\d+)"}', self.attrs)
        return int(m.group(1)) if m else None


@dataclass
class Computation:
    name: str
    is_entry: bool
    param_types: dict = field(default_factory=dict)
    ops: list = field(default_factory=list)
    types: dict = field(default_factory=dict)  # op/param name -> type str


_COMP_HDR = re.compile(
    r"^(ENTRY )?(%[\w.\-]+)\s*\((.*)\)\s*->\s*(.*?)\s*\{\s*$")
_OP_START = re.compile(r"^\s*(?:ROOT )?(%[\w.\-]+) = ")
_OPCODE_RE = re.compile(r"\s*([a-z][\w\-]*)\(")
_PARAM_RE = re.compile(r"(%?[\w.\-]+):\s*((?:\([^)]*\))|[a-z][a-z0-9]*\[[0-9,]*\])")


def parse_hlo(text: str) -> dict:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    for line in text.splitlines():
        h = _COMP_HDR.match(line)
        if h:
            cur = Computation(name=h.group(2), is_entry=bool(h.group(1)))
            for pm in _PARAM_RE.finditer(h.group(3)):
                pname = pm.group(1)
                if not pname.startswith("%"):
                    pname = "%" + pname
                cur.param_types[pname] = pm.group(2)
                cur.types[pname] = pm.group(2)
            comps[cur.name] = cur
            continue
        if cur is None:
            continue
        if line.startswith("}"):
            cur = None
            continue
        m = _OP_START.match(line)
        if not m:
            continue
        name = m.group(1)
        rest = line[m.end():]
        # type: either a balanced "(tuple, ...)" (may contain /*index=k*/
        # comments) or a single "dtype[dims]{layout}" token
        if rest.startswith("("):
            depth, i = 0, 0
            while i < len(rest):
                if rest[i] == "(":
                    depth += 1
                elif rest[i] == ")":
                    depth -= 1
                    if depth == 0:
                        i += 1
                        break
                i += 1
            type_str, rest = rest[:i], rest[i:]
        else:
            tm = re.match(r"[a-z][a-z0-9]*\[[0-9,]*\](?:{[^}]*})?", rest)
            if not tm:
                continue
            type_str, rest = tm.group(0), rest[tm.end():]
        om = _OPCODE_RE.match(rest)
        if not om:
            continue
        opcode = om.group(1)
        rest = rest[om.end():]
        # operand list: up to the matching close paren
        depth, i = 1, 0
        while i < len(rest) and depth:
            if rest[i] == "(":
                depth += 1
            elif rest[i] == ")":
                depth -= 1
            i += 1
        operand_str, attrs = rest[:i - 1], rest[i:]
        operands = re.findall(r"%[\w.\-]+", operand_str)
        op = Op(name, type_str, opcode, operands, attrs)
        cur.ops.append(op)
        cur.types[name] = type_str
    return comps


def _dot_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    lhs_type = comp.types.get(op.operands[0], "") if op.operands else ""
    lhs_dims = _shape_dims(lhs_type)
    k = 1
    for i in op.attr_list("lhs_contracting_dims"):
        if i < len(lhs_dims):
            k *= lhs_dims[i]
    return 2.0 * out_elems * k


def _conv_flops(op: Op, comp: Computation) -> float:
    out_elems = 1
    for d in _shape_dims(op.type_str):
        out_elems *= d
    rhs_type = comp.types.get(op.operands[1], "") if len(op.operands) > 1 else ""
    rhs_dims = _shape_dims(rhs_type)
    k = 1
    for d in rhs_dims[:-1]:  # kernel spatial x in-channels (approx)
        k *= d
    return 2.0 * out_elems * k


@dataclass
class HloStats:
    flops: float = 0.0
    bytes_accessed: float = 0.0
    collective_wire_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: defaultdict(float))
    collective_counts: dict = field(default_factory=lambda: defaultdict(float))
    transcendental_elems: float = 0.0
    while_trips: list = field(default_factory=list)
    # per-op attribution for the perf loop: opcode -> (bytes, flops, count)
    by_opcode: dict = field(default_factory=lambda: defaultdict(
        lambda: [0.0, 0.0, 0.0]))
    top_ops: list = field(default_factory=list)   # (bytes, name, opcode)

    def record(self, name, opcode, nbytes, nflops, mult):
        e = self.by_opcode[opcode]
        e[0] += nbytes
        e[1] += nflops
        e[2] += mult
        if nbytes > 0:
            self.top_ops.append((nbytes, name, opcode))
            if len(self.top_ops) > 4096:
                self.top_ops.sort(reverse=True)
                del self.top_ops[512:]

    def summary(self, k: int = 15) -> str:
        lines = [f"flops={self.flops:.3e} bytes={self.bytes_accessed:.3e} "
                 f"coll={self.collective_wire_bytes:.3e}"]
        lines.append("-- by opcode (bytes desc) --")
        for oc, (b, f, c) in sorted(self.by_opcode.items(),
                                    key=lambda kv: -kv[1][0])[:k]:
            lines.append(f"  {oc:28s} bytes={b:.3e} flops={f:.3e} n={c:.0f}")
        lines.append("-- top ops by bytes --")
        for b, name, oc in sorted(self.top_ops, reverse=True)[:k]:
            lines.append(f"  {b:.3e}  {oc:20s} {name}")
        return "\n".join(lines)

    def as_dict(self):
        return {
            "flops": self.flops,
            "bytes_accessed": self.bytes_accessed,
            "collective_wire_bytes": self.collective_wire_bytes,
            "collective_bytes": dict(self.collective_bytes),
            "collective_counts": dict(self.collective_counts),
            "while_trips": self.while_trips,
        }


_TRANSCENDENTAL = {"exponential", "log", "tanh", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "divide"}


def _op_bytes(op: Op, comp: Computation, oc: str) -> float:
    """HBM traffic model for one top-level op (TPU-oriented):

    * dynamic-update-slice updates in place — traffic is 2x the update
      slice, NOT the carried buffer (XLA aliases the input buffer);
    * dynamic-slice / gather read+write the slice/result only;
    * plain copies of a loop-carried buffer are CPU-lowering artifacts —
      TPU aliases the carry; charge one write;
    * everything else: operands read + result written.
    """
    res = _type_bytes(op.type_str)
    opnds = [_type_bytes(comp.types.get(o, "")) for o in op.operands]
    if oc == "dynamic-update-slice":
        upd = opnds[1] if len(opnds) > 1 else 0
        return 2.0 * upd
    if oc in ("dynamic-slice", "gather"):
        return 2.0 * res
    if oc in ("copy", "bitcast-convert", "transpose") and opnds \
            and max(opnds) == res:
        return float(res)
    return float(res + sum(opnds))


def _fusion_bytes(op: Op, comp: Computation) -> float:
    """Fusion boundary traffic with in-place-update correction: when the
    fusion both consumes and produces the same-size (large) buffer and its
    name marks a dynamic-update-slice or pure copy, the buffer pass-through
    is aliased, so only the true update traffic is charged."""
    res = _type_bytes(op.type_str)
    opnds = [_type_bytes(comp.types.get(o, "")) for o in op.operands]
    total = res + sum(opnds)
    name = op.name
    if "scatter" in name:
        big = max(opnds, default=0)
        if big and abs(big - res) <= 0.01 * max(big, res):
            # scatter updates in place: traffic = indices + updates (r/w)
            small = sum(opnds) - big
            return float(2.0 * small) if small > 0 else float(res)
    if "dynamic-update-slice" in name:
        big = max(opnds, default=0)
        if big and abs(big - res) <= 0.01 * max(big, res):
            # charge: remaining operands (the update) read + written once
            small = sum(opnds) - big
            return float(2.0 * small) if small > 0 else float(res)
    if name.startswith(("%copy_bitcast", "%bitcast_copy", "%copy_fusion")) \
            and opnds and abs(sum(opnds) - res) <= 0.01 * max(res, 1):
        # pure copy of loop-carried buffers (possibly a tuple of them):
        # TPU aliases the carry; charge one write
        return float(res)
    return float(total)


def _walk(comp: Computation, comps: dict, mult: float, stats: HloStats,
          flops_only: bool, _seen_depth: int = 0):
    if _seen_depth > 64:
        return
    for op in comp.ops:
        oc = op.opcode
        if oc == "while":
            trips = op.trip_count or 1
            stats.while_trips.append(trips)
            body = op.called("body")
            cond = op.called("condition")
            for c in (body, cond):
                if c and c in comps:
                    _walk(comps[c], comps, mult * trips, stats, flops_only,
                          _seen_depth + 1)
            continue
        if oc in ("call", "conditional", "async-start"):
            for key in ("to_apply", "true_computation", "false_computation",
                        "branch_computations", "called_computation"):
                c = op.called(key)
                if c and c in comps:
                    _walk(comps[c], comps, mult, stats, flops_only,
                          _seen_depth + 1)
            if oc == "conditional":
                continue
        if oc == "fusion":
            c = op.called("calls")
            f_before = stats.flops
            if c and c in comps:
                _walk(comps[c], comps, mult, stats, True, _seen_depth + 1)
            if not flops_only:
                b = _fusion_bytes(op, comp)
                stats.bytes_accessed += mult * b
                stats.record(op.name, "fusion", mult * b,
                             stats.flops - f_before, mult)
            continue
        if oc == "dot":
            stats.flops += mult * _dot_flops(op, comp)
        elif oc == "convolution":
            stats.flops += mult * _conv_flops(op, comp)
        elif oc in _TRANSCENDENTAL:
            n = 1
            for d in _shape_dims(op.type_str):
                n *= d
            stats.transcendental_elems += mult * n
        if oc in COLLECTIVES:
            b = _type_bytes(op.type_str)
            stats.collective_bytes[oc] += mult * b
            stats.collective_counts[oc] += mult
            stats.collective_wire_bytes += mult * b * _WIRE_FACTOR[oc]
        if not flops_only and oc not in _SKIP_BYTES:
            b = _op_bytes(op, comp, oc)
            stats.bytes_accessed += mult * b
            nflops = mult * _dot_flops(op, comp) if oc == "dot" else 0.0
            stats.record(op.name, oc, mult * b, nflops, mult)


def analyze_hlo(text: str) -> HloStats:
    comps = parse_hlo(text)
    entry = next((c for c in comps.values() if c.is_entry), None)
    stats = HloStats()
    if entry is None:
        return stats
    _walk(entry, comps, 1.0, stats, flops_only=False)
    return stats


def analyze_compiled(compiled) -> HloStats:
    return analyze_hlo(compiled.as_text())
