"""Compiler (paper §4.3.1): apply a deployment strategy to the grouped
computation graph, inserting the auxiliary ops that keep the deployed
graph mathematically equivalent to the original:

  * producer replicated, consumer not   -> Concat (CONCAT splittables) or
                                           AddN (SUM splittables) gathers
  * consumer replicated, producer not   -> Split (batch-dim scatter)
  * replica counts differ               -> Concat + Split (re-shard)
  * replicated parameter, option AR/PS  -> AllReduce / sharded-PS sync task
  * option DUP                          -> inputs broadcast to every copy,
                                           no sync (SFB semantics)

Output is a TaskGraph for the discrete-event simulator. Transfers carry
the exact byte fractions implied by the split/concat insertions, so the
simulator charges the same traffic the rewritten graph would move.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.device import Topology
from repro.core.graph import GroupedGraph, Split
from repro.core.strategy import Action, Option, Strategy, devices_of


@dataclass
class Task:
    tid: int
    kind: str                 # compute | xfer | allreduce | ps
    group: int                # op-group id (-1 for sync/aux)
    device: int = -1          # compute: flat device id
    flops: float = 0.0
    src: int = -1             # xfer: source device
    dst: int = -1
    nbytes: float = 0.0
    devices: tuple = ()       # collectives: participating devices
    deps: list = field(default_factory=list)
    label: str = ""


@dataclass
class Replica:
    device: int
    frac: float               # batch fraction processed by this replica
    task: int                 # compute task id


@dataclass
class TaskGraph:
    tasks: list = field(default_factory=list)
    replicas: dict = field(default_factory=dict)   # gid -> list[Replica]
    params_on: dict = field(default_factory=dict)  # device -> param bytes
    act_bytes: dict = field(default_factory=dict)  # device -> activ. bytes
    group_out_bytes: dict = field(default_factory=dict)  # gid -> bytes_out
    group_is_mp: dict = field(default_factory=dict)      # gid -> bool

    def add(self, **kw) -> Task:
        t = Task(tid=len(self.tasks), **kw)
        self.tasks.append(t)
        return t


N_MICRO = 4   # micro-batches for the PIPE option


def _replica_plan(topo: Topology, action: Action, proportional: bool):
    """[(device, frac)] for one op group under an action."""
    devs = devices_of(topo, action.placement)
    if action.option == Option.DUP:
        return [(d, 1.0) for d in devs]
    if action.option in (Option.MP, Option.PIPE):
        # stages, each handling the full batch for a slice of the ops
        return [(d, 1.0) for d in devs]
    if proportional:
        from repro.core.strategy import device_group_of
        speeds = [topo.groups[device_group_of(topo, d)].flops for d in devs]
        tot = sum(speeds)
        return [(d, s / tot) for d, s in zip(devs, speeds, strict=True)]
    return [(d, 1.0 / len(devs)) for d in devs]


def compile_strategy(gg: GroupedGraph, strat: Strategy, topo: Topology,
                     *, proportional: bool = False,
                     sfb_plans: dict | None = None) -> TaskGraph:
    assert strat.complete(), "strategy must cover every op group"
    tg = TaskGraph()
    tg.params_on = {}
    tg.act_bytes = {}

    # 1. compute tasks per replica
    for gid, grp in enumerate(gg.groups):
        action = strat.actions[gid]
        plan = _replica_plan(topo, action, proportional)
        n = len(plan)
        reps = []
        tg.group_out_bytes[gid] = grp.bytes_out
        tg.group_is_mp[gid] = action.option in (Option.MP, Option.PIPE)
        sfb = (sfb_plans or {}).get(gid)
        if action.option == Option.PIPE and n > 1:
            # paper §6 future work: pipeline the stages over micro-batches.
            # m independent micro-chains; device FIFO queues overlap them.
            reps = []
            stage_bytes = grp.bytes_out / max(n, 1) / N_MICRO
            first_tasks = []
            for m in range(N_MICRO):
                prev = None
                for si, (d, _) in enumerate(plan):
                    deps = [prev.tid] if prev is not None else []
                    t = tg.add(kind="compute", group=gid, device=d,
                               flops=grp.flops / n / N_MICRO, deps=deps,
                               label=f"g{gid}s{si}m{m}")
                    if prev is not None and prev.device != d:
                        x = tg.add(kind="xfer", group=gid, src=prev.device,
                                   dst=d, nbytes=stage_bytes,
                                   deps=[prev.tid], label=f"pipe{gid}")
                        t.deps.append(x.tid)
                    if si == 0:
                        first_tasks.append(t)
                    prev = t
                reps.append(Replica(plan[-1][0], 1.0 / N_MICRO, prev.tid))
            for d, _ in plan:
                tg.params_on[d] = tg.params_on.get(d, 0.0) \
                    + grp.param_bytes / n
                tg.act_bytes[d] = tg.act_bytes.get(d, 0.0) \
                    + grp.bytes_out / n
            tg.replicas[gid] = reps
            continue
        for d, frac in plan:
            if action.option == Option.MP:
                flops = grp.flops / n          # stage slice, full batch
            elif action.option == Option.DUP:
                flops = grp.flops              # full batch everywhere
            else:
                flops = grp.flops * frac
                if sfb is not None and n > 1:
                    # SFB-duplicated ops recompute the full batch locally
                    flops += sfb.extra_flops * (n - 1) / n
            t = tg.add(kind="compute", group=gid, device=d, flops=flops,
                       label=f"g{gid}@d{d}")
            reps.append(Replica(d, frac, t.tid))
            tg.params_on[d] = tg.params_on.get(d, 0.0) + grp.param_bytes \
                * (1.0 if action.option in (Option.DUP, Option.AR, Option.PS)
                   else 1.0 / n)
            tg.act_bytes[d] = tg.act_bytes.get(d, 0.0) + grp.bytes_out * (
                1.0 if action.option == Option.DUP else frac if
                action.option != Option.MP else 1.0 / n)
        if action.option == Option.MP and n > 1:
            # sequential stages with boundary transfers
            stage_bytes = grp.bytes_out / max(n, 1)
            for a, b in zip(reps[:-1], reps[1:], strict=True):
                if a.device == b.device:
                    tg.tasks[b.task].deps.append(a.task)
                    continue
                x = tg.add(kind="xfer", group=gid, src=a.device,
                           dst=b.device, nbytes=stage_bytes,
                           deps=[a.task], label=f"mp{gid}")
                tg.tasks[b.task].deps.append(x.tid)
        tg.replicas[gid] = reps

    # 2. inter-group tensors with split/concat-implied traffic
    for (gi, gj), nbytes in gg.edges.items():
        src_reps = tg.replicas[gi]
        dst_reps = tg.replicas[gj]
        src_dup = strat.actions[gi].option == Option.DUP
        consumer_split = gg.groups[gj].split != Split.OTHER \
            and strat.actions[gj].option not in (Option.DUP,)
        for rc in dst_reps:
            need = nbytes * (rc.frac if consumer_split else 1.0)
            if src_dup:
                # every producer replica holds the full tensor: read the
                # local copy when possible, else the first producer
                local = next((rp for rp in src_reps
                              if rp.device == rc.device), None)
                rp = local or src_reps[0]
                if rp.device == rc.device:
                    tg.tasks[rc.task].deps.append(rp.task)
                else:
                    x = tg.add(kind="xfer", group=gi, src=rp.device,
                               dst=rc.device, nbytes=need, deps=[rp.task])
                    tg.tasks[rc.task].deps.append(x.tid)
                continue
            for rp in src_reps:
                part = need * rp.frac
                if part <= 0:
                    continue
                if rp.device == rc.device:
                    tg.tasks[rc.task].deps.append(rp.task)
                    continue
                x = tg.add(kind="xfer", group=gi, src=rp.device,
                           dst=rc.device, nbytes=part, deps=[rp.task],
                           label=f"t{gi}->{gj}")
                tg.tasks[rc.task].deps.append(x.tid)

    # 3. DUP option: broadcast the *inputs* (sufficient factors) of the
    # duplicated group to every copy — already handled above because each
    # DUP replica pulls the full input tensor (consumer_split == False).

    # 4. gradient synchronization
    for gid, grp in enumerate(gg.groups):
        action = strat.actions[gid]
        reps = tg.replicas[gid]
        if not grp.has_grad or grp.grad_bytes <= 0 or len(reps) <= 1:
            continue
        sync_bytes = grp.grad_bytes
        sfb = (sfb_plans or {}).get(gid)
        if sfb is not None:
            sync_bytes = max(0.0, sync_bytes - sfb.saved_sync_bytes)
            per_pair = sfb.bcast_bytes / max(len(reps), 1)
            for rp in reps:
                for rc in reps:
                    if rp.device == rc.device or per_pair <= 0:
                        continue
                    tg.add(kind="xfer", group=gid, src=rp.device,
                           dst=rc.device, nbytes=per_pair,
                           deps=[rp.task], label=f"sfb{gid}")
            if sync_bytes <= 0:
                continue
        if action.option == Option.AR:
            tg.add(kind="allreduce", group=gid, nbytes=sync_bytes,
                   devices=tuple(r.device for r in reps),
                   deps=[r.task for r in reps], label=f"ar{gid}")
        elif action.option == Option.PS:
            tg.add(kind="ps", group=gid, nbytes=sync_bytes,
                   devices=tuple(r.device for r in reps),
                   deps=[r.task for r in reps], label=f"ps{gid}")
        # DUP: gradients identical on every copy — no sync (SFB), MP: no
        # replication of parameters.
    return tg
