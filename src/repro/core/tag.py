"""TAG public API (paper Fig. 1 workflow).

    result = tag.optimize(loss_fn, params, batch, topology)

runs: graph analyzer (trace + simplify) -> METIS-style grouping ->
GNN-guided MCTS over placements/replication options -> SFB post-pass ->
final simulated deployment. ``result.strategy`` is the deployment plan;
``result.sfb_plans`` the per-group SFB duplications; ``result.time`` the
simulated per-iteration time.
"""
from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass

from repro.core import sfb as sfb_mod
from repro.core.compiler import compile_strategy
from repro.core.device import Topology
from repro.core.fingerprint import fingerprint_grouped_cached
from repro.core.graph import GroupedGraph, group_graph
from repro.core.jax_export import trace_training_graph
from repro.core.mcts import MCTS, SearchResult
from repro.core.partition import partition
from repro.core.simulator import SimResult, simulate
from repro.core.strategy import Option, Strategy, data_parallel_all, devices_of


@dataclass
class TAGResult:
    strategy: Strategy
    sfb_plans: dict
    search: SearchResult
    time: float                   # simulated per-iteration seconds
    baseline_time: float          # DP-AllReduce baseline
    result: SimResult
    gg: GroupedGraph

    @property
    def speedup(self):
        return self.baseline_time / self.time if self.time > 0 else 0.0

    def strategy_stats(self, topo: Topology) -> dict:
        """Table-4-style summary: avg replicas per GPU type; PS/AR shares."""
        per_type: dict = {}
        counts: dict = {}
        ps = ar = dup = 0.0
        total_grad = 0.0
        for gid, a in enumerate(self.strategy.actions):
            grp = self.gg.groups[gid]
            for g in a.placement:
                t = topo.groups[g].gpu_type
                per_type[t] = per_type.get(t, 0.0) + topo.groups[g].num_gpus
            counts["n"] = counts.get("n", 0) + 1
            if grp.has_grad and grp.grad_bytes > 0:
                total_grad += grp.grad_bytes
                if a.option == Option.AR:
                    ar += grp.grad_bytes
                elif a.option == Option.PS:
                    ps += grp.grad_bytes
                elif a.option == Option.DUP:
                    dup += grp.grad_bytes
        n = max(counts.get("n", 1), 1)
        return {
            "avg_replicas_per_type": {t: v / n for t, v in per_type.items()},
            "ps_frac": ps / total_grad if total_grad else 0.0,
            "ar_frac": ar / total_grad if total_grad else 0.0,
            "dup_frac": dup / total_grad if total_grad else 0.0,
        }


def build_grouped(loss_fn, params, batch, name: str = "",
                  n_groups: int = 60) -> GroupedGraph:
    g = trace_training_graph(loss_fn, params, batch, name=name).simplify()
    return group_graph(g, partition(g, n_groups))


# SFB plan cache. Keyed by a CONTENT fingerprint of the graph (plus the
# per-group replica/bandwidth signature), never by id(gg): a graph's id can
# be recycled after garbage collection, and an id-keyed cache would then
# silently serve the dead graph's plans to an unrelated one. LRU-bounded so
# a long-lived PlannerService cannot grow it without limit.
_SFB_CACHE: "OrderedDict" = OrderedDict()
SFB_CACHE_MAX_ENTRIES = 4096


def _sfb_cache_key(gg: GroupedGraph, gid: int, n_devs: int, tau: float,
                   dev_flops: float):
    return (fingerprint_grouped_cached(gg), gid, n_devs,
            round(tau / 1e6), round(dev_flops / 1e9))


def sfb_post_pass(gg: GroupedGraph, strat: Strategy, topo: Topology) -> dict:
    """Paper §4.2.3: for every replicated group MCTS decided (AR/PS), solve
    the SFB ILP per gradient and collect beneficial duplications. Results
    are cached per (graph content, group, placement) — the ILP depends only
    on the replica count and bottleneck bandwidth."""
    plans = {}
    for gid, a in enumerate(strat.actions):
        grp = gg.groups[gid]
        if a.option not in (Option.AR, Option.PS) or not grp.has_grad:
            continue
        devs = devices_of(topo, a.placement)
        if len(devs) <= 1:
            continue
        tau = topo.bottleneck_bw(a.placement)
        dev_flops = min(topo.groups[g].flops for g in a.placement)
        key = _sfb_cache_key(gg, gid, len(devs), tau, dev_flops)
        plan = _SFB_CACHE.get(key)
        if plan is None:
            plan = sfb_mod.optimize_group(
                gg.base, grp.op_ids, len(devs), tau, dev_flops)
            _SFB_CACHE[key] = plan
            while len(_SFB_CACHE) > SFB_CACHE_MAX_ENTRIES:
                _SFB_CACHE.popitem(last=False)
        else:
            _SFB_CACHE.move_to_end(key)
        if plan.saved_sync_bytes > 0 or plan.extra_flops > 0:
            plans[gid] = plan
    return plans


def optimize(loss_fn, params, batch, topo: Topology, *, name: str = "",
             policy=None, iterations: int = 100, n_groups: int = 60,
             enable_sfb: bool = True, seed: int = 0,
             gg: GroupedGraph | None = None,
             prior_strategy: Strategy | None = None,
             prior_weight: float = 0.5,
             stop_reward: float | None = None,
             observed_feedback=None,
             schedule_aware: bool = True) -> TAGResult:
    if gg is None:
        gg = build_grouped(loss_fn, params, batch, name, n_groups)
    mcts = MCTS(gg, topo, policy=policy, seed=seed,
                prior_strategy=prior_strategy, prior_weight=prior_weight,
                observed_feedback=observed_feedback,
                schedule_aware=schedule_aware)
    search = mcts.search(iterations, stop_reward=stop_reward)
    strat = search.best_strategy
    plans = sfb_post_pass(gg, strat, topo) if enable_sfb else {}
    res = simulate(compile_strategy(gg, strat, topo, sfb_plans=plans), topo)
    time = res.makespan
    if schedule_aware and strat.has_pipeline():
        # report the same cost model the search ranked the winner under
        # (schedule timeline, not the FIFO task-graph estimate)
        out = mcts._pipe_evaluate(strat)
        if out is not None and out[0] > 0:
            time = search.baseline_time / out[0]
    return TAGResult(
        strategy=strat, sfb_plans=plans, search=search,
        time=time, baseline_time=search.baseline_time,
        result=res, gg=gg)


def evaluate_strategy(gg: GroupedGraph, strat: Strategy, topo: Topology,
                      *, sfb: bool = False, proportional: bool = False):
    plans = sfb_post_pass(gg, strat, topo) if sfb else {}
    tg = compile_strategy(gg, strat, topo, proportional=proportional,
                          sfb_plans=plans)
    return simulate(tg, topo), plans


def strategy_step_time(gg: GroupedGraph, strat: Strategy, topo: Topology,
                       *, sfb: bool = False,
                       global_micro: int = 16) -> float:
    """Step time of a complete strategy under the same cost model the
    schedule-aware search ranks it with: pipelined strategies go through
    the schedule timeline (memory-capped microbatch depth, flushes,
    per-stage sync — ``exec.schedule.schedule_step_cost``), everything
    else through the FIFO task-graph simulator. The runtime feedback
    loop scores stale plans and re-search seeds with this, so its
    improved/regressed verdicts compare like with like. An OOM-
    infeasible pipeline costs ``inf``."""
    if strat.has_pipeline():
        # lazy import: repro.exec sits above core in the layering
        from repro.exec.schedule import schedule_step_cost
        from repro.exec.stages import build_stage_plan
        plan = build_stage_plan(gg, strat, topo, n_micro=global_micro)
        if plan is not None:
            cost = schedule_step_cost(plan, topo, plan.schedule,
                                      global_micro=global_micro)
            if cost is None:
                return float("inf")
            return cost["step_time_s"]
    return evaluate_strategy(gg, strat, topo, sfb=sfb)[0].makespan


def dp_baseline(gg: GroupedGraph, topo: Topology,
                option: Option = Option.AR) -> Strategy:
    return Strategy([data_parallel_all(topo, option)] * gg.n)
