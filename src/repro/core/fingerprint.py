"""Canonical, process-stable content fingerprints for computation graphs.

Hashes are sha256 over a canonical JSON encoding (sorted keys, floats via
``repr``), so they are stable across processes and Python hash
randomization. Display names are deliberately excluded: the same model
traced under two labels is the same planning problem.

This lives in ``core`` (not the service layer) because core consumers —
``tag.sfb_post_pass``'s plan cache keys — need a collision-safe graph
identity too; ``repro.service.fingerprint`` re-exports everything here and
adds the topology/structural-feature fingerprints the planner uses.
"""
from __future__ import annotations

import hashlib
import json

import numpy as np

from repro.core.graph import CompGraph, GroupedGraph


def _canon(obj):
    """Convert to canonically-JSON-serializable form (numpy -> python)."""
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [_canon(v) for v in obj.tolist()]
    if isinstance(obj, (np.floating, float)):
        return repr(float(obj))
    if isinstance(obj, (np.integer, int, bool)) or obj is None:
        return obj
    return str(obj)


def canonical_json(obj) -> str:
    return json.dumps(_canon(obj), sort_keys=True, separators=(",", ":"))


def _sha(obj) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()


def fingerprint_graph(graph: CompGraph) -> str:
    """Structure + costs of a CompGraph (node names / graph name ignored)."""
    nodes = [[n.op_id, n.op_type, n.flops, n.bytes_out, n.param_bytes,
              n.grad_bytes, n.split.value, n.is_grad_producer,
              n.is_apply_grad, n.is_param, n.batch_dim, n.grad_of]
             for n in sorted(graph.nodes.values(), key=lambda x: x.op_id)]
    edges = sorted([e.src, e.dst, e.bytes] for e in graph.edges)
    return _sha({"nodes": nodes, "edges": edges})


def fingerprint_grouped(gg: GroupedGraph) -> str:
    """Grouped view: base graph + partition assignment + group costs."""
    groups = [[g.group_id, sorted(g.op_ids), g.flops, g.param_bytes,
               g.grad_bytes, g.bytes_out, g.has_grad, g.split.value]
              for g in gg.groups]
    edges = sorted([gi, gj, b] for (gi, gj), b in gg.edges.items())
    return _sha({"base": fingerprint_graph(gg.base), "groups": groups,
                 "edges": edges})


def fingerprint_grouped_cached(gg: GroupedGraph) -> str:
    """``fingerprint_grouped`` memoized on the instance itself. The cached
    digest travels — and dies — with the graph object, so unlike an
    ``id()``-keyed side table it can never alias a recycled id. Callers
    must not mutate a graph after fingerprinting it (nothing in this
    codebase does: grouped graphs are built once by ``group_graph``)."""
    fp = gg.__dict__.get("_fp_grouped")
    if fp is None:
        fp = fingerprint_grouped(gg)
        gg.__dict__["_fp_grouped"] = fp
    return fp
