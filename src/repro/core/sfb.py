"""Automatic Sufficient Factor Broadcasting (paper §4.2.3).

For every gradient tensor (g -> l, l = ApplyGradient) inside a replicated
op group, solve the min-cut-like ILP

  min (D-1) sum_i alpha_i T_i  +  D(D-1) sum_(j,i) b_ji L_ji / tau
      - 2 alpha_g (D-1)/D * L_gl / tau
  s.t. alpha_k <= sum_{(k,i) in E} alpha_i   (k != l)
       b_ji >= alpha_i - alpha_j

exactly with branch-and-bound over alpha in reverse topological order
(b is determined by alpha at optimum; consumers are fixed before
producers, so the closure constraint is checked exactly). Cbc is not
available offline — subproblems are tiny (an op group around one
gradient), and the B&B is validated against brute force in tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.graph import CompGraph

MAX_BRUTE = 18


@dataclass
class SFBProblem:
    ops: list                    # op ids (V), excluding l
    edges: list                  # (j, i, L_ji) within V + edges into l
    times: dict                  # op id -> T_i (seconds on a replica device)
    g: int                       # gradient producer op
    l: int                       # optimizer (ApplyGradient) op
    grad_bytes: float            # L_gl
    D: int                       # replica count
    tau: float                   # bottleneck bandwidth (B/s)


@dataclass
class SFBSolution:
    alpha: dict                  # op id -> 0/1
    objective: float             # seconds (negative => beneficial)
    extra_flops_time: float      # (D-1) sum alpha_i T_i
    bcast_bytes: float           # sum over cut tensors of L_ji (per pair)
    saved_sync_bytes: float      # L_gl if alpha_g else 0

    @property
    def beneficial(self):
        return self.objective < 0 and any(self.alpha.values())


def _objective_terms(prob: SFBProblem, alpha: dict):
    D, tau = prob.D, prob.tau
    t_comp = (D - 1) * sum(prob.times.get(i, 0.0) for i, a in alpha.items()
                           if a)
    cut = sum(L for (j, i, L) in prob.edges
              if alpha.get(i, 0) and not alpha.get(j, 0))
    t_comm = D * (D - 1) * cut / tau
    t_save = 2 * alpha.get(prob.g, 0) * (D - 1) / D * prob.grad_bytes / tau
    return t_comp + t_comm - t_save, t_comp, cut


def solve_brute(prob: SFBProblem) -> SFBSolution:
    """Exhaustive reference (tests only)."""
    ops = prob.ops
    assert len(ops) <= MAX_BRUTE
    cons = {k: [] for k in ops}
    for (j, i, _) in prob.edges:
        if j in cons and i in prob.ops:
            cons[j].append(i)
    best, best_alpha = 0.0, {o: 0 for o in ops}
    for mask in range(1 << len(ops)):
        alpha = {o: (mask >> k) & 1 for k, o in enumerate(ops)}
        ok = True
        for k in ops:
            if alpha[k] and not any(alpha.get(c, 0) for c in cons[k]) \
                    and k != prob.g:
                ok = False
                break
        if not ok:
            continue
        obj, _, _ = _objective_terms(prob, alpha)
        if obj < best:
            best, best_alpha = obj, alpha
    obj, tc, cut = _objective_terms(prob, best_alpha)
    return SFBSolution(best_alpha, obj, tc, cut,
                       prob.grad_bytes if best_alpha.get(prob.g) else 0.0)


def solve(prob: SFBProblem) -> SFBSolution:
    """Exact branch-and-bound in reverse topological order."""
    ops = prob.ops
    n = len(ops)
    pos = {o: k for k, o in enumerate(ops)}
    cons: dict = {o: [] for o in ops}
    in_edges: dict = {o: [] for o in ops}
    for (j, i, L) in prob.edges:
        if j in cons and i in cons:
            cons[j].append(i)
            in_edges[i].append((j, L))

    # reverse-topo order (consumers before producers): topological sort on
    # reversed edges i -> j (consumer to producer)
    radj = {o: [] for o in ops}
    rdeg = {o: 0 for o in ops}
    for j in ops:
        for i in cons[j]:
            radj[i].append(j)
            rdeg[j] += 1
    stack = [o for o in ops if rdeg[o] == 0]
    order = []
    while stack:
        u = stack.pop()
        order.append(u)
        for w in radj[u]:
            rdeg[w] -= 1
            if rdeg[w] == 0:
                stack.append(w)
    if len(order) != n:        # cyclic residue (shouldn't happen): fallback
        order = sorted(ops, key=lambda o: -pos[o])

    D, tau = prob.D, prob.tau
    save = 2 * (D - 1) / D * prob.grad_bytes / tau
    best = {"obj": 0.0, "alpha": {o: 0 for o in ops}}

    alpha: dict = {}

    def edge_cost_if_fixed(o):
        """Costs of edges whose BOTH endpoints are now fixed (consumer o's
        in-edges j->o, plus o's out-edges to already-fixed consumers)."""
        c = 0.0
        if alpha[o]:
            for (j, L) in in_edges[o]:
                if j in alpha and not alpha[j]:
                    c += D * (D - 1) * L / tau
        else:
            pass
        for i in cons[o]:
            if i in alpha and alpha[i] and not alpha[o]:
                for (j, L) in in_edges[i]:
                    if j == o:
                        c += D * (D - 1) * L / tau
        return c

    def rec(k, cost):
        nonlocal best
        # lower bound: remaining ops can only add cost; the only remaining
        # negative term is the g-saving if g unfixed
        lb = cost - (save if prob.g not in alpha else 0.0)
        if lb >= best["obj"]:
            return
        if k == n:
            if cost < best["obj"]:
                best = {"obj": cost, "alpha": dict(alpha)}
            return
        o = order[k]
        for val in (0, 1):
            if val == 1 and o != prob.g:
                # closure: some consumer inside V must be duplicated,
                # or o's only consumer is l via g (handled by g anchor)
                if not any(alpha.get(c, 0) for c in cons[o]):
                    continue
            alpha[o] = val
            delta = (D - 1) * prob.times.get(o, 0.0) if val else 0.0
            delta += edge_cost_if_fixed(o)
            if val and o == prob.g:
                delta -= save
            rec(k + 1, cost + delta)
            del alpha[o]

    rec(0, 0.0)
    sol_alpha = {o: best["alpha"].get(o, 0) for o in ops}
    obj, tc, cut = _objective_terms(prob, sol_alpha)
    return SFBSolution(sol_alpha, obj, tc, cut,
                       prob.grad_bytes if sol_alpha.get(prob.g) else 0.0)


MAX_SUBGRAPH = 24   # paper §3.3: the problem stays small — only the
                    # subgraph around one gradient is considered


def build_problem(graph: CompGraph, group_ops, g_id: int, l_id: int,
                  D: int, tau: float, dev_flops: float) -> SFBProblem:
    """Extract the SFB subproblem for gradient (g -> l) inside an op group:
    the upstream neighborhood of g within the group, capped at
    MAX_SUBGRAPH ops (BFS by producer edges)."""
    opset_all = set(group_ops) - {l_id}
    graph.build_adj()
    ops = [g_id]
    seen = {g_id}
    frontier = [g_id]
    while frontier and len(ops) < MAX_SUBGRAPH:
        nxt = []
        for o in frontier:
            for e in graph._in.get(o, []):
                if e.src in opset_all and e.src not in seen:
                    seen.add(e.src)
                    ops.append(e.src)
                    nxt.append(e.src)
                    if len(ops) >= MAX_SUBGRAPH:
                        break
            if len(ops) >= MAX_SUBGRAPH:
                break
        frontier = nxt
    opset = set(ops)
    edges = []
    grad_bytes = 0.0
    for e in graph.edges:
        if e.src == g_id and e.dst == l_id:
            grad_bytes = max(grad_bytes, e.bytes)
        if e.src in opset and e.dst in opset:
            edges.append((e.src, e.dst, e.bytes))
    times = {o: graph.nodes[o].flops / dev_flops for o in ops}
    return SFBProblem(ops, edges, times, g_id, l_id, grad_bytes, D, tau)


@dataclass
class GroupSFB:
    """Aggregate SFB plan for one op group (consumed by the compiler)."""
    extra_flops: float = 0.0           # full-batch flops of duplicated ops
    bcast_bytes: float = 0.0           # tensors broadcast between replicas
    saved_sync_bytes: float = 0.0      # gradient bytes no longer synced
    dup_op_types: list = field(default_factory=list)

    def to_dict(self) -> dict:
        return {"extra_flops": float(self.extra_flops),
                "bcast_bytes": float(self.bcast_bytes),
                "saved_sync_bytes": float(self.saved_sync_bytes),
                "dup_op_types": list(self.dup_op_types)}

    @classmethod
    def from_dict(cls, d: dict) -> "GroupSFB":
        return cls(extra_flops=float(d["extra_flops"]),
                   bcast_bytes=float(d["bcast_bytes"]),
                   saved_sync_bytes=float(d["saved_sync_bytes"]),
                   dup_op_types=list(d["dup_op_types"]))


def optimize_group(graph: CompGraph, group_ops, D: int, tau: float,
                   dev_flops: float) -> GroupSFB:
    """Paper: for every gradient tensor in a replicated op group, solve the
    ILP and apply beneficial duplications. Returns the aggregate plan."""
    plan = GroupSFB()
    opset = set(group_ops)
    for o in group_ops:
        node = graph.nodes[o]
        if not node.is_grad_producer or node.grad_of is None:
            continue
        prob = build_problem(graph, group_ops, o, node.grad_of, D, tau,
                             dev_flops)
        if prob.grad_bytes <= 0:
            continue
        sol = solve(prob)
        if sol.beneficial:
            plan.extra_flops += sum(
                graph.nodes[i].flops for i, a in sol.alpha.items() if a)
            plan.bcast_bytes += sol.bcast_bytes
            plan.saved_sync_bytes += sol.saved_sync_bytes
            plan.dup_op_types.extend(
                graph.nodes[i].op_type for i, a in sol.alpha.items() if a
                and i in opset)
    return plan
