"""XLA profiler hook: per-collective attribution for instrumented steps.

Wraps a step callable in ``jax.profiler.trace(..., create_perfetto_trace
=True)``, then parses the emitted perfetto/Chrome trace into the
per-collective sample shape ``runtime.telemetry.StepRecord.collectives``
carries ({kind, nbytes, n_dev, nominal_bw, link, time, pair?}) — the
input of ``runtime.calibration.fit_profile``'s per-link-pair tier. This
closes the ROADMAP telemetry item: real hardware feeds the calibration
the same samples the replay executors synthesize.

Everything degrades gracefully: when ``jax.profiler`` is missing, the
trace context raises, or no parseable trace file appears (CPU-only
backends sometimes emit host tracks only), ``profile_step`` still
returns the step's output with ``samples == []`` and a ``meta`` dict
saying why — callers never branch on profiler availability.
"""
from __future__ import annotations

import glob
import gzip
import json
import os
import re

# XLA op-name fragments -> StepRecord collective kinds
_COLLECTIVE_PATTERNS = (
    (re.compile(r"all[-_]?reduce", re.I), "allreduce"),
    (re.compile(r"reduce[-_]?scatter", re.I), "allreduce"),
    (re.compile(r"all[-_]?gather", re.I), "allreduce"),
    (re.compile(r"all[-_]?to[-_]?all", re.I), "xfer"),
    (re.compile(r"collective[-_]?permute", re.I), "xfer"),
    (re.compile(r"\b(send|recv)\b|copy[-_]?start|copy[-_]?done", re.I),
     "xfer"),
)
# arg keys the profiler may use for moved bytes, in preference order
_BYTES_KEYS = ("nbytes", "bytes", "bytes_accessed", "bytes accessed",
               "size", "shape_size")


def profiler_available() -> bool:
    try:
        import jax.profiler  # noqa: F401
        return True
    except Exception:
        return False


def classify_op(name: str) -> str | None:
    """Collective kind of an XLA/TSL op name, or None for non-collectives."""
    for pat, kind in _COLLECTIVE_PATTERNS:
        if pat.search(name):
            return kind
    return None


def _event_bytes(args: dict) -> float:
    for k in _BYTES_KEYS:
        v = args.get(k)
        if v is None:
            continue
        try:
            return float(v)
        except (TypeError, ValueError):
            continue
    return 0.0


def find_trace_files(log_dir: str) -> list:
    """Perfetto/Chrome trace JSONs under a profiler log dir (newest run
    first)."""
    pats = ("**/*.trace.json.gz", "**/*.trace.json",
            "**/perfetto_trace.json.gz", "**/perfetto_trace.json")
    out: list = []
    for pat in pats:
        out.extend(glob.glob(os.path.join(log_dir, pat), recursive=True))
    return sorted(set(out), key=lambda p: os.path.getmtime(p),
                  reverse=True)


def parse_trace_collectives(path: str, *, nominal_bw: float = 0.0,
                            n_dev: int = 2, link: str = "intra",
                            pair: str | None = None) -> list:
    """Collective samples from one trace-event JSON(.gz) file.

    Complete (``ph == "X"``) events whose name matches a collective
    pattern become samples; ``dur`` is microseconds per the trace-event
    contract. ``nominal_bw``/``n_dev``/``link``/``pair`` supply the
    cluster-side context the device trace cannot know.
    """
    opener = gzip.open if path.endswith(".gz") else open
    with opener(path, "rt") as f:
        doc = json.load(f)
    events = doc.get("traceEvents", doc) if isinstance(doc, dict) else doc
    samples = []
    for e in events:
        if not isinstance(e, dict) or e.get("ph") != "X":
            continue
        name = str(e.get("name", ""))
        kind = classify_op(name)
        if kind is None:
            continue
        dur_us = float(e.get("dur", 0.0))
        if dur_us <= 0:
            continue
        sample = {"kind": kind, "nbytes": _event_bytes(e.get("args", {})),
                  "n_dev": n_dev, "nominal_bw": nominal_bw, "link": link,
                  "time": dur_us / 1e6, "op": name}
        if pair:
            sample["pair"] = pair
        samples.append(sample)
    return samples


def profile_step(fn, *args, log_dir: str, nominal_bw: float = 0.0,
                 n_dev: int = 2, link: str = "intra",
                 pair: str | None = None, **kwargs) -> tuple:
    """Run ``fn(*args, **kwargs)`` under an XLA profiler trace and parse
    per-collective samples out of the result.

    Returns ``(out, samples, meta)``. ``samples`` is [] — never an
    exception — when the profiler is unavailable, the trace context
    fails, or no trace file parses; ``meta["profiler"]`` says which
    (``"ok"``, ``"unavailable"``, ``"trace_failed"``, ``"no_trace"``).
    """
    if not profiler_available():
        return fn(*args, **kwargs), [], {"profiler": "unavailable"}
    import jax
    import jax.profiler
    os.makedirs(log_dir, exist_ok=True)
    try:
        with jax.profiler.trace(log_dir, create_perfetto_trace=True):
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
    except Exception as e:          # profiler backend refused: run plain
        return fn(*args, **kwargs), [], {
            "profiler": "trace_failed", "error": str(e)}
    samples: list = []
    parsed_from = None
    for path in find_trace_files(log_dir):
        try:
            samples = parse_trace_collectives(
                path, nominal_bw=nominal_bw, n_dev=n_dev, link=link,
                pair=pair)
            parsed_from = path
            break
        except (OSError, ValueError, KeyError):
            continue
    if parsed_from is None:
        return out, [], {"profiler": "no_trace", "log_dir": log_dir}
    return out, samples, {"profiler": "ok", "trace_file": parsed_from,
                          "n_collectives": len(samples)}


def attach_collectives(record, samples: list, meta: dict | None = None):
    """Merge profiler-derived samples into a ``StepRecord`` in place (and
    stamp how they were obtained); returns the record."""
    record.collectives = list(record.collectives) + list(samples)
    record.meta = dict(record.meta, xla_profiler=(meta or {}))
    return record
