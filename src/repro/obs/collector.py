"""Cross-process span/event spool + trace collector.

PR 6's spans/metrics/traces live inside one Python process; this module
is the boundary-crossing half. Every producer — the planner's span
tracer, the pipeline engine's per-event stream, ``launch.train``, the
replay executor — appends records to its own JSONL **shard** in a shared
spool directory (``fcntl``-locked appends, the ``MeasurementStore``
pattern), and a ``TraceCollector`` incrementally merges the shards into
one Chrome trace.

Clock alignment: processes disagree on ``time.perf_counter()`` epochs
(monotonic clocks start at boot/process-dependent zeros), so each shard
opens with an **anchor** record pairing one wall-clock reading with one
monotonic reading from the same instant. Every span record carries
monotonic timestamps; the collector maps them onto the shared wall
clock via ``wall = anchor.wall + (t - anchor.mono)`` and renders all
shards relative to the earliest aligned event — one coherent timeline
regardless of which host/process produced which events.

    w = SpoolWriter(spool_dir, run_id="run7", name="train")
    w.emit_span("F0.0", t0, t1, tid=0, cat="pipeline")

    c = TraceCollector(spool_dir)
    c.poll()                        # incremental: only new bytes parsed
    doc = c.chrome("run7")          # validated Chrome trace document

Record schema (one JSON object per line):

  * ``{"type": "anchor", "run_id", "process", "pid", "wall", "mono"}``
    — first line of every shard;
  * ``{"type": "span", "name", "cat", "tid", "t0", "t1", "args"}``
    — one timed region, ``t0``/``t1`` on the producer's monotonic clock;
  * ``{"type": "track", "tid", "name"}`` — names a tid's trace track.
"""
from __future__ import annotations

import json
import os
import re
import threading
import time

from repro.obs.trace import chrome_trace, validate_chrome_trace

try:
    import fcntl
except ImportError:                       # non-posix: locking degrades
    fcntl = None

_SAFE = re.compile(r"[^A-Za-z0-9_.-]+")


def _safe(s: str) -> str:
    return _SAFE.sub("_", str(s)) or "x"


def shard_path(spool_dir: str, run_id: str, name: str, pid: int) -> str:
    return os.path.join(spool_dir,
                        f"{_safe(run_id)}--{_safe(name)}-{int(pid)}.jsonl")


class SpoolWriter:
    """Appends span/event records to this producer's spool shard.

    One writer owns one shard file ``<run_id>--<name>-<pid>.jsonl``; the
    first line written is the wall<->monotonic anchor. Appends take an
    ``fcntl`` exclusive lock so a shard shared across threads (or an
    accidentally reused (run_id, name, pid) triple) stays line-atomic.

    ``anchor=(wall, mono)`` overrides the clock pair — used by tests to
    inject deterministic cross-process clock skew, and by replay-style
    producers whose "timestamps" are simulated seconds.
    """

    def __init__(self, spool_dir: str, *, run_id: str = "run",
                 name: str = "proc", pid: int | None = None,
                 anchor: tuple | None = None, meta: dict | None = None):
        os.makedirs(spool_dir, exist_ok=True)
        self.run_id = str(run_id)
        self.name = str(name)
        self.pid = int(pid if pid is not None else os.getpid())
        self.path = shard_path(spool_dir, self.run_id, self.name, self.pid)
        if anchor is not None:
            wall, mono = float(anchor[0]), float(anchor[1])
        else:
            wall, mono = time.time(), time.perf_counter()
        self.anchor = (wall, mono)
        self._lock = threading.Lock()
        self._tracer_pos: dict = {}       # id(tracer) -> spans emitted
        self._write_lines([json.dumps({
            "type": "anchor", "run_id": self.run_id,
            "process": self.name, "pid": self.pid,
            "wall": wall, "mono": mono, "meta": dict(meta or {}),
        }, sort_keys=True)], anchor_guard=True)

    # ------------------------------------------------------------ appends
    def _write_lines(self, lines: list, *, anchor_guard: bool = False):
        if not lines:
            return
        payload = "".join(line + "\n" for line in lines)
        with self._lock, open(self.path, "a") as f:
            if fcntl is not None:
                fcntl.flock(f, fcntl.LOCK_EX)
            try:
                if anchor_guard and f.tell() > 0:
                    return                # shard already anchored
                f.write(payload)
                f.flush()
            finally:
                if fcntl is not None:
                    fcntl.flock(f, fcntl.LOCK_UN)

    def emit(self, record: dict):
        """Append one raw record (already schema-shaped)."""
        self.emit_many([record])

    def emit_many(self, records: list):
        """Append a batch of records under ONE lock/write — the cheap
        path for per-step event streams."""
        self._write_lines([json.dumps(r, sort_keys=True) for r in records])

    def emit_span(self, name: str, t0: float, t1: float, *, tid: int = 0,
                  cat: str = "span", args: dict | None = None):
        """One timed region; ``t0``/``t1`` are producer-monotonic
        (``time.perf_counter()``) seconds."""
        self.emit({"type": "span", "name": str(name), "cat": str(cat),
                   "tid": int(tid), "t0": float(t0), "t1": float(t1),
                   "args": dict(args or {})})

    def emit_track(self, tid: int, name: str):
        """Name ``tid``'s track in the merged trace."""
        self.emit({"type": "track", "tid": int(tid), "name": str(name)})

    def emit_tracer(self, tracer, *, cat: str | None = None) -> int:
        """Spool a ``repro.obs.spans.Tracer``'s finished spans.

        Incremental per tracer: repeated calls only append spans recorded
        since the previous call, so a serve loop can drain the planner's
        tracer on every scrape. Returns the number of spans spooled.
        """
        spans = tracer.spans()
        pos = self._tracer_pos.get(id(tracer), 0)
        if pos > len(spans):              # tracer.clear() underneath us
            pos = 0
        new = spans[pos:]
        if not new:
            return 0
        epoch = tracer.epoch
        self.emit_many([{
            "type": "span", "name": sp.name,
            "cat": cat if cat is not None else sp.cat, "tid": sp.tid,
            "t0": epoch + sp.start, "t1": epoch + sp.end,
            "args": dict(sp.args, depth=sp.depth),
        } for sp in new])
        self._tracer_pos[id(tracer)] = len(spans)
        return len(new)


class _Shard:
    __slots__ = ("path", "offset", "anchor", "run_id", "process", "pid",
                 "tracks", "spans", "bad")

    def __init__(self, path: str):
        self.path = path
        self.offset = 0
        self.anchor = None                # (wall, mono)
        self.run_id = ""
        self.process = os.path.basename(path)
        self.pid = 0
        self.tracks: dict = {}            # tid -> name
        self.spans: list = []             # raw span records
        self.bad = 0

    def wall(self, t_mono: float) -> float:
        """Producer-monotonic seconds -> shared wall-clock seconds via
        the shard's anchor (identity for an unanchored shard)."""
        if self.anchor is None:
            return t_mono
        w, m = self.anchor
        return w + (t_mono - m)


class TraceCollector:
    """Incrementally merge spool shards into one Chrome trace.

    ``poll()`` reads only bytes appended since the previous poll (torn
    in-flight lines stay buffered via the complete-lines-only cut, the
    ``MeasurementStore.read_new`` discipline; a truncated shard resets
    and replays). ``chrome(run_id)`` renders the merged, clock-aligned,
    schema-validated trace document with per-process ``pid`` metadata.
    """

    def __init__(self, spool_dir: str):
        self.spool_dir = spool_dir
        self._shards: dict = {}           # path -> _Shard
        self._lock = threading.Lock()

    # ------------------------------------------------------------ ingest
    def poll(self) -> int:
        """Consume newly appended spool records; returns how many."""
        with self._lock:
            n = 0
            if not os.path.isdir(self.spool_dir):
                return 0
            for fn in sorted(os.listdir(self.spool_dir)):
                if not fn.endswith(".jsonl"):
                    continue
                n += self._poll_shard(os.path.join(self.spool_dir, fn))
            return n

    def _poll_shard(self, path: str) -> int:
        sh = self._shards.get(path)
        if sh is None:
            sh = self._shards[path] = _Shard(path)
        try:
            size = os.path.getsize(path)
        except OSError:
            return 0
        if size < sh.offset:              # truncated/rewritten: replay
            self._shards[path] = sh = _Shard(path)
        if size == sh.offset:
            return 0
        with open(path, "rb") as f:
            f.seek(sh.offset)
            data = f.read()
        end = data.rfind(b"\n")
        if end < 0:
            return 0                      # only a torn line so far
        n = 0
        for line in data[:end].split(b"\n"):
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
                kind = rec["type"]
            except (ValueError, KeyError, TypeError):
                sh.bad += 1
                continue
            if kind == "anchor":
                sh.anchor = (float(rec["wall"]), float(rec["mono"]))
                sh.run_id = str(rec.get("run_id", ""))
                sh.process = str(rec.get("process", sh.process))
                sh.pid = int(rec.get("pid", 0))
            elif kind == "track":
                sh.tracks[int(rec["tid"])] = str(rec["name"])
            elif kind == "span":
                sh.spans.append(rec)
            else:
                sh.bad += 1
                continue
            n += 1
        sh.offset += end + 1
        return n

    # --------------------------------------------------------- retention
    def gc(self, max_age_s: float | None = None,
           max_bytes: int | None = None) -> dict:
        """Delete fully-drained spool shard files past a retention
        budget; the long-lived-spool half of plan-store eviction.

        A shard file is deletable only when the collector has consumed
        every byte of it (``offset == size`` — a torn trailing line
        means undrained, the file survives). ``max_age_s`` drops drained
        shards whose file mtime is older; ``max_bytes`` then drops
        oldest-mtime-first until the spool directory's total drained
        footprint fits. In-memory spans are kept, so already-collected
        traces keep rendering after their shard files are gone.

        Returns ``{"deleted", "kept", "bytes_freed"}``.
        """
        self.poll()       # drain appends first so fresh bytes never die
        deleted, freed = 0, 0
        with self._lock:
            stats: list[tuple[float, int, str]] = []   # (mtime, size, p)
            kept = 0
            for path, sh in self._shards.items():
                try:
                    st = os.stat(path)
                except OSError:
                    continue              # already gone underneath us
                if sh.offset < st.st_size:
                    kept += 1             # undrained: never delete
                    continue
                stats.append((st.st_mtime, st.st_size, path))
            doomed: set = set()
            if max_age_s is not None:
                cutoff = time.time() - float(max_age_s)
                doomed |= {p for mt, _, p in stats if mt < cutoff}
            if max_bytes is not None:
                total = sum(sz for _, sz, p in stats if p not in doomed)
                for _mt, sz, p in sorted(stats):
                    if total <= int(max_bytes):
                        break
                    if p in doomed:
                        continue
                    doomed.add(p)
                    total -= sz
            for _mt, sz, p in stats:
                if p not in doomed:
                    kept += 1
                    continue
                try:
                    os.remove(p)
                except OSError:
                    kept += 1
                    continue
                deleted += 1
                freed += sz
                # the _Shard entry (and its spans) stays: collected
                # traces keep rendering, and a recreated file replays
                # through the size < offset truncation path
        return {"deleted": deleted, "kept": kept, "bytes_freed": freed}

    # ----------------------------------------------------------- queries
    def shards(self, run_id: str | None = None) -> list:
        with self._lock:
            return [sh for sh in self._shards.values()
                    if run_id is None or sh.run_id == run_id]

    def run_ids(self) -> list:
        with self._lock:
            return sorted({sh.run_id for sh in self._shards.values()
                           if sh.spans or sh.anchor is not None})

    def span_count(self, run_id: str | None = None) -> int:
        """Merged span records for one run (all when None) — the cheap
        size probe the server uses to pick buffered vs streamed trace
        responses."""
        with self._lock:
            return sum(len(sh.spans) for sh in self._shards.values()
                       if run_id is None or sh.run_id == run_id)

    def counts(self) -> dict:
        with self._lock:
            shards = list(self._shards.values())
        return {"shards": len(shards),
                "spans": sum(len(sh.spans) for sh in shards),
                "bad_lines": sum(sh.bad for sh in shards),
                "runs": len({sh.run_id for sh in shards})}

    # ------------------------------------------------------------ render
    def trace_events(self, run_id: str | None = None) -> list:
        """Merged Chrome trace events for one run (or all shards).

        Every shard becomes one trace ``pid`` (dense, deterministic
        order) with ``process_name``/``thread_name`` metadata; span
        timestamps are aligned through each shard's wall<->monotonic
        anchor and rendered relative to the earliest event across the
        selection, so cross-process ordering is real wall-clock order.
        """
        shards = [sh for sh in self.shards(run_id) if sh.spans]
        shards.sort(key=lambda sh: (sh.run_id, sh.process, sh.pid))
        if not shards:
            return []
        base = min(sh.wall(float(sp["t0"]))
                   for sh in shards for sp in sh.spans)
        events, spans = [], []
        for pid, sh in enumerate(shards):
            events.append({
                "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
                "args": {"name": f"{sh.process} (pid {sh.pid})"}})
            tids = sorted({int(sp.get("tid", 0)) for sp in sh.spans}
                          | set(sh.tracks))
            for tid in tids:
                events.append({
                    "name": "thread_name", "ph": "M", "pid": pid,
                    "tid": tid,
                    "args": {"name": sh.tracks.get(tid, f"track {tid}")}})
            for sp in sh.spans:
                t0 = sh.wall(float(sp["t0"]))
                t1 = sh.wall(float(sp["t1"]))
                spans.append({
                    "name": str(sp.get("name", "?")),
                    "cat": str(sp.get("cat", "span")), "ph": "X",
                    "ts": (t0 - base) * 1e6,
                    "dur": max(t1 - t0, 0.0) * 1e6,
                    "pid": pid, "tid": int(sp.get("tid", 0)),
                    "args": dict(sp.get("args") or {},
                                 process=sh.process, run_id=sh.run_id),
                })
        spans.sort(key=lambda e: (e["ts"], e["pid"], e["tid"]))
        return events + spans

    def chrome(self, run_id: str | None = None, **metadata) -> dict:
        """Validated Chrome trace document for ``run_id`` (all runs when
        None); raises ``KeyError`` for a run with no spooled events."""
        events = self.trace_events(run_id)
        if not events:
            raise KeyError(f"no spooled events for run {run_id!r} in "
                           f"{self.spool_dir}")
        doc = chrome_trace(events, spool_dir=self.spool_dir,
                           run_id=run_id, **metadata)
        return validate_chrome_trace(doc)

    def chrome_stream(self, run_id: str | None = None, *,
                      chunk_events: int = 512, **metadata):
        """Incrementally-serialized Chrome trace: a generator of JSON
        text fragments that concatenate to the same document ``chrome``
        returns. ``json.dumps`` of a whole merged trace costs several
        times the span list's own footprint in one allocation; this
        serializes ``chunk_events`` events at a time so the server's
        extra memory per in-flight response is bounded by the chunk,
        not the run. Raises ``KeyError`` (before yielding anything) for
        a run with no spooled events."""
        events = self.trace_events(run_id)
        if not events:
            raise KeyError(f"no spooled events for run {run_id!r} in "
                           f"{self.spool_dir}")

        def gen():
            head = {"spool_dir": self.spool_dir, "run_id": run_id,
                    **metadata}
            yield ('{"displayTimeUnit": "ms", "otherData": '
                   + json.dumps(head, default=str)
                   + ', "traceEvents": [')
            for i in range(0, len(events), max(chunk_events, 1)):
                block = events[i:i + max(chunk_events, 1)]
                prefix = "" if i == 0 else ","
                yield prefix + ",".join(
                    json.dumps(e, default=str) for e in block)
            yield "]}"
        return gen()
