"""Run health: continuous executed-vs-predicted attribution per run.

PR 6's ``diff_report`` answers "where did my predicted step go" for ONE
executed step, offline. ``RunHealthAnalyzer`` lifts that join into a
continuously-maintained, served surface: it drains ``StepRecord``s from
a telemetry ``MeasurementStore`` (``read_new()``, the same incremental
cursor the ``RecalibrationLoop`` polls) and rolls, per run:

  * per-stage compute and per-(src,dst) transfer **residual ratios** —
    EWMA-smoothed executed busy seconds against the registered predicted
    schedule ``Timeline`` (or, for unregistered runs, against a baseline
    captured from the run's own first steps: *self-baselined* mode);
  * executed vs predicted **bubble fraction**;
  * **straggler ranking** — top-k stages/links by slowdown normalized
    against the run's median ratio (a uniform slowdown is drift, not a
    straggler), with persistence hysteresis so one noisy step neither
    flags nor clears a straggler;
  * a **step-time SLO** with multi-window burn-rate alerting
    (``repro.obs.alerts``).

The attribution feeds back into planning: ``replan_priority()`` scores
watched (graph_fp, topo_fp) keys so the ``RecalibrationLoop`` replans
the worst-drifted workload first, and ``attributed_cause()`` is stamped
into the refreshed ``PlanRecord.meta["drift_cause"]`` — a replan now
records *why* (which stage, link, or sync) it happened.

Served by ``ObsServer`` as ``/runs``, ``/runs/<run_id>/health`` and
``/alerts``; exported as ``run_health_*`` gauges on every /metrics
scrape.
"""
from __future__ import annotations

from dataclasses import dataclass
import threading
import time

from repro.obs.alerts import (
    DEFAULT_OBJECTIVE, AlertEvaluator, SLOTracker, default_rules)
from repro.obs.trace import aggregate_events, executed_events_of

DEFAULT_RUN = "default"


def _run_id_of(rec) -> str:
    rid = rec.meta.get("run_id") if isinstance(rec.meta, dict) else None
    if rid:
        return str(rid)
    if rec.graph_fp or rec.topo_fp:
        return f"{rec.graph_fp[:12]}:{rec.topo_fp[:12]}"
    return DEFAULT_RUN


@dataclass
class _KeyStat:
    """Rolling residual state for one stage or one link of one run."""
    predicted_s: float = 0.0          # per-step busy seconds expected
    ewma_s: float = 0.0               # smoothed executed busy seconds
    n: int = 0
    hi_streak: int = 0
    lo_streak: int = 0
    straggling: bool = False
    since_step: int = -1

    def update(self, executed_s: float, alpha: float):
        self.n += 1
        self.ewma_s = executed_s if self.n == 1 else (
            alpha * executed_s + (1.0 - alpha) * self.ewma_s)
        if self.predicted_s <= 0:     # self-baselined: first step anchors
            self.predicted_s = executed_s

    @property
    def ratio(self) -> float:
        return self.ewma_s / self.predicted_s if self.predicted_s > 0 \
            else 1.0

    @property
    def residual_s(self) -> float:
        return self.ewma_s - self.predicted_s


class _Run:
    """Per-run rolling state (internal)."""

    def __init__(self, run_id: str):
        self.run_id = run_id
        self.graph_fp = ""
        self.topo_fp = ""
        self.registered = False        # watch() supplied predictions
        self.predicted_step_s = 0.0    # 0 until known / baselined
        self.sync_time = 0.0
        self.bubble_predicted: float | None = None
        self.pred_stage: dict = {}     # stage -> predicted busy s
        self.pred_link: dict = {}      # "src->dst" -> predicted busy s
        self.stages: dict = {}         # stage -> _KeyStat
        self.links: dict = {}          # "src->dst" -> _KeyStat
        self.steps = 0
        self.last_step = -1
        self.last_ts = 0.0
        self.step_ewma = 0.0
        self.bubble_ewma: float | None = None
        self.tracker: SLOTracker | None = None
        self.evaluator: AlertEvaluator | None = None

    # ------------------------------------------------------- derived views
    def ratios(self) -> list:
        """[(kind, key, _KeyStat)] across stages + links."""
        out = [("stage", str(s), st) for s, st in self.stages.items()]
        out += [("link", k, st) for k, st in self.links.items()]
        return out

    def dominant(self) -> dict:
        """The dominant residual, attributed category-first: compute vs
        transfer vs sync/other totals decide WHICH subsystem is at
        fault (robust to a slowdown smearing across both directions of
        a link, or partially hiding in pipeline slack), then the worst
        key inside the winning category says WHERE."""
        resid_c = sum(st.residual_s for st in self.stages.values())
        resid_x = sum(st.residual_s for st in self.links.values())
        step_resid = self.step_ewma - self.predicted_step_s \
            if self.predicted_step_s > 0 else 0.0
        sync = step_resid - resid_c - resid_x
        cause, total, table = max(
            [("stage", resid_c, self.stages),
             ("link", resid_x, self.links)],
            key=lambda c: abs(c[1]))
        if not table or abs(sync) > abs(total):
            return {"cause": "sync", "key": "sync", "residual_s": sync}
        key, st = max(table.items(),
                      key=lambda kv: abs(kv[1].residual_s))
        return {"cause": cause, "key": str(key),
                "residual_s": st.residual_s}

    def step_ratio(self) -> float:
        return self.step_ewma / self.predicted_step_s \
            if self.predicted_step_s > 0 else 1.0


class RunHealthAnalyzer:
    """Incremental telemetry -> health joiner; see the module docstring.

    ``store`` is a telemetry dir / ``.jsonl`` path or a
    ``MeasurementStore``; the analyzer owns its OWN ``read_new`` cursor,
    so it can share a telemetry dir with a ``RecalibrationLoop`` without
    stealing its records (pass a path, not the loop's store instance).
    With ``store=None`` the analyzer is feed-only (``ingest(rec)``).
    """

    def __init__(self, store=None, *, registry=None,
                 slo_s: float | None = None,
                 slo_objective: float = DEFAULT_OBJECTIVE,
                 alert_rules=None, ewma_alpha: float = 0.35,
                 straggler_ratio: float = 1.3, hysteresis_up: int = 2,
                 hysteresis_down: int = 2, top_k: int = 5,
                 max_runs: int = 64):
        from repro.runtime.telemetry import MeasurementStore
        if isinstance(store, str):
            store = MeasurementStore(store)
        self.store = store
        self.registry = registry
        self.slo_s = slo_s                   # default for unwatched runs
        self.slo_objective = float(slo_objective)
        self.alert_rules = list(alert_rules) if alert_rules is not None \
            else default_rules()
        self.ewma_alpha = float(ewma_alpha)
        self.straggler_ratio = float(straggler_ratio)
        self.hysteresis_up = max(int(hysteresis_up), 1)
        self.hysteresis_down = max(int(hysteresis_down), 1)
        self.top_k = int(top_k)
        self.max_runs = int(max_runs)
        self._runs: dict = {}                # run_id -> _Run
        self._by_key: dict = {}              # (gfp, tfp) -> set(run_id)
        self._lock = threading.RLock()
        self.records_total = 0
        self.events_total = 0
        self.ingest_seconds = 0.0

    # ------------------------------------------------------------ register
    def watch(self, run_id: str, *, timeline=None, sync_time: float = 0.0,
              graph_fp: str = "", topo_fp: str = "",
              slo_s: float | None = None,
              slo_objective: float | None = None) -> str:
        """Register a run's predicted schedule (and optionally its SLO).

        ``timeline`` is the plan's simulated ``exec.schedule.Timeline``;
        per-stage/per-link predicted busy seconds, the predicted step
        time (makespan + ``sync_time``) and the predicted bubble
        fraction are lifted from it. Without a timeline the run is
        tracked in self-baselined mode (ratios relative to its own
        first steps).
        """
        with self._lock:
            run = self._run(run_id)
            run.graph_fp = graph_fp or run.graph_fp
            run.topo_fp = topo_fp or run.topo_fp
            if run.graph_fp or run.topo_fp:
                self._by_key.setdefault(
                    (run.graph_fp, run.topo_fp), set()).add(run_id)
            if timeline is not None:
                run.registered = True
                run.sync_time = float(sync_time)
                run.predicted_step_s = timeline.makespan + run.sync_time
                run.bubble_predicted = timeline.bubble_fraction()
                run.pred_stage, run.pred_link = {}, {}
                for e in timeline.events:
                    if e.kind == "X":
                        key = f"{e.src}->{e.stage}"
                        run.pred_link[key] = \
                            run.pred_link.get(key, 0.0) + e.dur
                    else:
                        run.pred_stage[e.stage] = \
                            run.pred_stage.get(e.stage, 0.0) + e.dur
                for s, d in run.pred_stage.items():
                    run.stages.setdefault(s, _KeyStat()).predicted_s = d
                for k, d in run.pred_link.items():
                    run.links.setdefault(k, _KeyStat()).predicted_s = d
            target = slo_s if slo_s is not None else self.slo_s
            if target is not None and run.tracker is None:
                self._arm_slo(run, target, slo_objective)
            return run_id

    def _arm_slo(self, run: _Run, target: float,
                 objective: float | None = None):
        ev = AlertEvaluator(self.alert_rules)
        run.tracker = SLOTracker(
            target,
            objective=objective if objective is not None
            else self.slo_objective,
            horizon_s=ev.horizon_s)
        run.evaluator = ev

    def _run(self, run_id: str) -> _Run:
        run = self._runs.get(run_id)
        if run is None:
            run = self._runs[run_id] = _Run(run_id)
            self._evict_lru(keep=run_id)
        return run

    def _evict_lru(self, keep: str):
        while len(self._runs) > self.max_runs:
            victim = min((r for r in self._runs.values()
                          if r.run_id != keep),
                         key=lambda r: (r.registered, r.last_ts))
            self._drop(victim.run_id)

    def _drop(self, run_id: str):
        run = self._runs.pop(run_id, None)
        if run is None:
            return
        self._by_key.get((run.graph_fp, run.topo_fp), set()).discard(
            run_id)
        if self.registry is not None:       # drop stale labeled series
            for m in self.registry.metrics():
                if m.name.startswith(("run_health_", "alert_")) \
                        and hasattr(m, "remove"):
                    m.remove(run=run_id)

    # -------------------------------------------------------------- ingest
    def poll(self) -> int:
        """Drain newly appended records from the store; returns count."""
        if self.store is None:
            return 0
        n = 0
        for rec in self.store.read_new():
            self.ingest(rec)
            n += 1
        return n

    def ingest(self, rec) -> str:
        """Fold one ``StepRecord`` into its run's rolling state; returns
        the run id it was attributed to."""
        t_in = time.perf_counter()
        with self._lock:
            run_id = _run_id_of(rec)
            run = self._run(run_id)
            if rec.graph_fp and not run.graph_fp:
                run.graph_fp, run.topo_fp = rec.graph_fp, rec.topo_fp
                self._by_key.setdefault(
                    (run.graph_fp, run.topo_fp), set()).add(run_id)
            ts = rec.ts or time.time()
            run.steps += 1
            run.last_step = rec.step
            run.last_ts = ts
            run.step_ewma = rec.wall_time if run.steps == 1 else (
                self.ewma_alpha * rec.wall_time
                + (1.0 - self.ewma_alpha) * run.step_ewma)
            if run.predicted_step_s <= 0:    # self-baselined step anchor
                run.predicted_step_s = rec.wall_time

            stage_s, link_s, n_events = self._reduce(rec, run)
            self.events_total += max(n_events, 1)
            for s, dur in stage_s.items():
                run.stages.setdefault(s, _KeyStat()).update(
                    dur, self.ewma_alpha)
            for k, dur in link_s.items():
                run.links.setdefault(k, _KeyStat()).update(
                    dur, self.ewma_alpha)
            self._rank_stragglers(run)

            if run.tracker is None and self.slo_s is not None:
                self._arm_slo(run, self.slo_s)
            if run.tracker is not None:
                run.tracker.observe(ts, rec.wall_time)
                for st in run.evaluator.evaluate(run.tracker, ts):
                    if self.registry is not None:
                        self.registry.counter(
                            "alert_transitions_total",
                            "run-health alert state transitions").inc(
                            run=run_id, rule=st.rule.name, to=st.state)
            if self.registry is not None:
                self.registry.counter(
                    "run_health_records_total",
                    "telemetry records folded into run health").inc()
            self.records_total += 1
            self.ingest_seconds += time.perf_counter() - t_in
            return run_id

    def _reduce(self, rec, run: _Run) -> tuple:
        """Per-stage / per-link executed busy seconds for one record.

        Prefers the exact per-event stream (``meta["events"]``); falls
        back to the compute/collective samples (link keys are then the
        producer's device-group ``pair``, normalized ``"gi->gj"``).
        Also rolls the executed bubble fraction.
        """
        meta = rec.meta if isinstance(rec.meta, dict) else {}
        if "events" in meta:
            agg = aggregate_events(executed_events_of(rec))
            stage_s, link_s = agg["stage"], agg["link"]
            n_events = len(meta["events"])
            bubble = meta.get("bubble_frac")
            if bubble is None and stage_s:
                t0, t1 = agg["span"]
                span = max(t1 - t0, 0.0)
                denom = span * len(stage_s)
                bubble = 1.0 - sum(stage_s.values()) / denom \
                    if denom > 0 else None
        else:
            stage_s, link_s = {}, {}
            for c in rec.compute:
                s = c.get("stage")
                if s is not None:
                    stage_s[int(s)] = stage_s.get(int(s), 0.0) \
                        + float(c.get("time", 0.0))
            for c in rec.collectives:
                pair = c.get("pair")
                if pair is not None:
                    key = str(pair).replace("-", "->", 1)
                    link_s[key] = link_s.get(key, 0.0) \
                        + float(c.get("time", 0.0))
            n_events = len(rec.compute) + len(rec.collectives)
            bubble = meta.get("bubble_frac")
        if bubble is not None:
            run.bubble_ewma = float(bubble) if run.bubble_ewma is None \
                else (self.ewma_alpha * float(bubble)
                      + (1.0 - self.ewma_alpha) * run.bubble_ewma)
        return stage_s, link_s, n_events

    def _rank_stragglers(self, run: _Run):
        """Normalized-slowdown hysteresis pass over all keys of a run.

        Each key's ratio is divided by the run-wide median ratio, so a
        uniform slowdown (all keys 2x) is drift — the feedback loop's
        job — while a localized one stands out. A key must exceed
        ``straggler_ratio`` for ``hysteresis_up`` consecutive steps to
        be flagged, and fall below it for ``hysteresis_down`` steps to
        clear.
        """
        stats = [st for _, _, st in run.ratios() if st.n > 0]
        if not stats:
            return
        ratios = sorted(st.ratio for st in stats)
        med = ratios[len(ratios) // 2] if len(ratios) % 2 else (
            ratios[len(ratios) // 2 - 1] + ratios[len(ratios) // 2]) / 2
        med = med if med > 0 else 1.0
        for st in stats:
            if st.ratio / med > self.straggler_ratio:
                st.hi_streak += 1
                st.lo_streak = 0
                if not st.straggling \
                        and st.hi_streak >= self.hysteresis_up:
                    st.straggling = True
                    st.since_step = run.last_step
            else:
                st.lo_streak += 1
                st.hi_streak = 0
                if st.straggling \
                        and st.lo_streak >= self.hysteresis_down:
                    st.straggling = False
                    st.since_step = -1

    # ------------------------------------------------------------- queries
    def run_ids(self) -> list:
        with self._lock:
            return sorted(self._runs)

    def _normalized(self, run: _Run) -> dict:
        stats = [st for _, _, st in run.ratios() if st.n > 0]
        ratios = sorted(st.ratio for st in stats)
        if not ratios:
            return {}
        med = ratios[len(ratios) // 2] if len(ratios) % 2 else (
            ratios[len(ratios) // 2 - 1] + ratios[len(ratios) // 2]) / 2
        med = med if med > 0 else 1.0
        return {id(st): st.ratio / med for st in stats}

    def _stragglers(self, run: _Run) -> list:
        norm = self._normalized(run)
        out = [{"kind": kind, "key": key, "ratio": st.ratio,
                "normalized": norm.get(id(st), 1.0),
                "since_step": st.since_step}
               for kind, key, st in run.ratios() if st.straggling]
        out.sort(key=lambda d: -d["normalized"])
        return out[:self.top_k]

    def health(self, run_id: str) -> dict:
        """Full health snapshot for one run; raises KeyError unknown."""
        with self._lock:
            run = self._runs.get(run_id)
            if run is None:
                raise KeyError(f"unknown run {run_id!r} "
                               f"(known: {sorted(self._runs)})")
            norm = self._normalized(run)

            def key_dict(st: _KeyStat) -> dict:
                return {"predicted_s": st.predicted_s,
                        "executed_s": st.ewma_s, "ratio": st.ratio,
                        "normalized": norm.get(id(st), 1.0),
                        "straggling": st.straggling,
                        "since_step": st.since_step, "steps": st.n}

            resid_c = sum(st.residual_s for st in run.stages.values())
            resid_x = sum(st.residual_s for st in run.links.values())
            step_resid = run.step_ewma - run.predicted_step_s \
                if run.predicted_step_s > 0 else 0.0
            d = {
                "run_id": run.run_id, "graph_fp": run.graph_fp,
                "topo_fp": run.topo_fp,
                "mode": "predicted" if run.registered
                        else "self_baselined",
                "steps": run.steps, "last_step": run.last_step,
                "last_ts": run.last_ts,
                "predicted_step_s": run.predicted_step_s,
                "step_ewma_s": run.step_ewma,
                "step_ratio": run.step_ratio(),
                "bubble": {"predicted": run.bubble_predicted,
                           "executed": run.bubble_ewma},
                "stages": {str(s): key_dict(st)
                           for s, st in sorted(run.stages.items())},
                "links": {k: key_dict(st)
                          for k, st in sorted(run.links.items())},
                "attribution": {
                    "compute_s": resid_c, "transfer_s": resid_x,
                    "sync_other_s": step_resid - resid_c - resid_x},
                "dominant": run.dominant(),
                "stragglers": self._stragglers(run),
            }
            if run.tracker is not None:
                windows = sorted({w for r in run.evaluator.rules
                                  for w in (r.short_window_s,
                                            r.long_window_s)})
                d["slo"] = run.tracker.to_dict(now=run.last_ts,
                                               windows=windows)
                d["alerts"] = [st.to_dict()
                               for st in run.evaluator.states()]
            else:
                d["slo"] = None
                d["alerts"] = []
            return d

    def run_summaries(self) -> list:
        """Compact per-run rows for the /runs index."""
        out = []
        with self._lock:
            ids = sorted(self._runs)
        for rid in ids:
            try:
                h = self.health(rid)
            except KeyError:
                continue
            out.append({
                "run_id": rid, "mode": h["mode"], "steps": h["steps"],
                "last_ts": h["last_ts"], "step_ratio": h["step_ratio"],
                "dominant": h["dominant"],
                "stragglers": len(h["stragglers"]),
                "alerts_firing": sum(1 for a in h["alerts"]
                                     if a["state"] == "firing")})
        return out

    def alerts(self) -> list:
        """All runs' alert states, firing first, pages before warns."""
        out = []
        with self._lock:
            for rid, run in sorted(self._runs.items()):
                if run.evaluator is None:
                    continue
                for st in run.evaluator.states():
                    out.append(dict(st.to_dict(), run_id=rid))
        out.sort(key=lambda a: (a["state"] != "firing",
                                a["severity"] != "page", a["rule"]))
        return out

    # ------------------------------------------------------- replan wiring
    def replan_priority(self) -> dict:
        """{(graph_fp, topo_fp): score} — how hard each key's worst run
        deviates from its predicted step (0 = on plan). The
        ``RecalibrationLoop`` drains drifted keys in descending order."""
        scores: dict = {}
        with self._lock:
            for key, rids in self._by_key.items():
                best = 0.0
                for rid in rids:
                    run = self._runs.get(rid)
                    if run is not None:
                        best = max(best, abs(run.step_ratio() - 1.0))
                if key[0] or key[1]:
                    scores[key] = best
        return scores

    def attributed_cause(self, graph_fp: str, topo_fp: str) -> dict | None:
        """The dominant residual for the worst run under a plan key —
        stamped into ``PlanRecord.meta["drift_cause"]`` on replan."""
        with self._lock:
            rids = self._by_key.get((graph_fp, topo_fp), ())
            runs = [self._runs[r] for r in rids if r in self._runs]
            if not runs:
                return None
            run = max(runs, key=lambda r: abs(r.step_ratio() - 1.0))
            return dict(run.dominant(), run_id=run.run_id,
                        step_ratio=run.step_ratio(), ts=run.last_ts)

    # ------------------------------------------------------------- metrics
    def export_metrics(self, registry=None):
        """Refresh the ``run_health_*`` gauge families (called by the
        served plane on every /metrics scrape)."""
        reg = registry if registry is not None else self.registry
        if reg is None:
            return
        g = reg.gauge
        with self._lock:
            g("run_health_runs", "runs tracked by the health analyzer"
              ).set(len(self._runs))
            for rid, run in self._runs.items():
                g("run_health_step_ratio",
                  "EWMA executed / predicted step time").set(
                    run.step_ratio(), run=rid)
                if run.bubble_ewma is not None:
                    g("run_health_bubble",
                      "pipeline bubble fraction by origin").set(
                        run.bubble_ewma, run=rid, origin="executed")
                if run.bubble_predicted is not None:
                    g("run_health_bubble",
                      "pipeline bubble fraction by origin").set(
                        run.bubble_predicted, run=rid, origin="predicted")
                for s, st in run.stages.items():
                    g("run_health_stage_ratio",
                      "per-stage executed/predicted compute ratio").set(
                        st.ratio, run=rid, stage=str(s))
                for k, st in run.links.items():
                    g("run_health_link_ratio",
                      "per-link executed/predicted transfer ratio").set(
                        st.ratio, run=rid, link=k)
                g("run_health_stragglers",
                  "keys currently flagged as stragglers").set(
                    sum(1 for _, _, st in run.ratios()
                        if st.straggling), run=rid)
                if run.tracker is not None:
                    for rule in run.evaluator.rules:
                        for w in {rule.short_window_s,
                                  rule.long_window_s}:
                            g("run_health_slo_burn",
                              "SLO error-budget burn rate by window").set(
                                run.tracker.burn_rate(w, run.last_ts),
                                run=rid, window=str(int(w)))
                    for st in run.evaluator.states():
                        g("run_health_alert_firing",
                          "1 while a run-health alert fires").set(
                            1.0 if st.firing else 0.0, run=rid,
                            rule=st.rule.name,
                            severity=st.rule.severity)

    def stats(self) -> dict:
        with self._lock:
            per_event = (self.ingest_seconds / self.events_total
                         if self.events_total else 0.0)
            return {"runs": len(self._runs),
                    "records": self.records_total,
                    "events": self.events_total,
                    "ingest_us_per_event": per_event * 1e6,
                    "slo_s": self.slo_s,
                    "rules": [r.to_dict() for r in self.alert_rules]}
