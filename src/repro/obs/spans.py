"""Structured spans: a low-overhead, thread-safe tracing API.

A ``Span`` is one timed region of the planning path (``PlannerService
.plan`` -> store lookup -> policy resolve -> MCTS playouts with
expand / featurize / gnn_forward / simulate sub-spans). Spans nest per
thread (each thread keeps its own open-span stack) and finished spans
are appended under a lock, so concurrent planners share one tracer.

The global tracer is DISABLED by default and ``span()`` on a disabled
tracer returns a shared no-op context manager — no allocation, no
clock read — so instrumented hot paths (one span per MCTS playout)
stay effectively free until someone opts in:

    from repro.obs import get_tracer
    tr = get_tracer()
    tr.enable()
    with tr.span("plan", cat="planner", model="bert_small"):
        ...
    events = tr.to_chrome()           # chrome://tracing JSON events

``to_chrome`` renders spans in the same Chrome trace-event format as
``obs.trace`` renders schedule timelines, so planner spans and pipeline
timelines open in one viewer.
"""
from __future__ import annotations

from dataclasses import dataclass, field
import threading
import time


@dataclass
class Span:
    """One finished timed region. Times are seconds relative to the
    tracer epoch; ``tid`` is a dense per-thread track id."""
    name: str
    cat: str
    start: float
    end: float
    tid: int
    depth: int
    args: dict = field(default_factory=dict)

    @property
    def dur(self) -> float:
        return self.end - self.start


class _NullSpan:
    """Reusable, re-entrant no-op context manager (disabled tracer)."""

    __slots__ = ()

    def __enter__(self):
        return None

    def __exit__(self, *exc):
        return False


_NULL_SPAN = _NullSpan()


class _SpanCtx:
    __slots__ = ("tracer", "name", "cat", "args", "_t0")

    def __init__(self, tracer, name, cat, args):
        self.tracer = tracer
        self.name = name
        self.cat = cat
        self.args = args

    def __enter__(self):
        self._t0 = self.tracer._push()
        return self

    def __exit__(self, *exc):
        self.tracer._pop(self.name, self.cat, self._t0, self.args)
        return False


class _ThreadState(threading.local):
    def __init__(self):
        self.depth = 0
        self.tid = None


class Tracer:
    """Thread-safe span recorder. Disabled tracers cost one attribute
    read per ``span()`` call."""

    def __init__(self, *, enabled: bool = False, max_spans: int = 200_000):
        self.enabled = enabled
        self.max_spans = max_spans
        self._epoch = time.perf_counter()
        self._spans: list = []
        self._lock = threading.Lock()
        self._local = _ThreadState()
        self._tids: dict = {}              # thread ident -> dense tid
        self.dropped = 0

    @property
    def epoch(self) -> float:
        """``time.perf_counter()`` reading that span-relative times are
        measured from (lets exporters recover monotonic timestamps)."""
        return self._epoch

    # ------------------------------------------------------------- control
    def enable(self):
        self.enabled = True
        return self

    def disable(self):
        self.enabled = False
        return self

    def clear(self):
        with self._lock:
            self._spans = []
            self.dropped = 0
            self._epoch = time.perf_counter()

    # --------------------------------------------------------------- spans
    def span(self, name: str, cat: str = "planner", **args):
        """Context manager timing one region. No-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _SpanCtx(self, name, cat, args)

    def _tid(self) -> int:
        st = self._local
        if st.tid is None:
            ident = threading.get_ident()
            with self._lock:
                st.tid = self._tids.setdefault(ident, len(self._tids))
        return st.tid

    def _push(self) -> float:
        self._local.depth += 1
        return time.perf_counter()

    def _pop(self, name, cat, t0, args):
        t1 = time.perf_counter()
        st = self._local
        depth = st.depth - 1
        st.depth = depth
        sp = Span(name=name, cat=cat, start=t0 - self._epoch,
                  end=t1 - self._epoch, tid=self._tid(), depth=depth,
                  args=args)
        with self._lock:
            if len(self._spans) < self.max_spans:
                self._spans.append(sp)
            else:
                self.dropped += 1

    def spans(self) -> list:
        with self._lock:
            return list(self._spans)

    def __len__(self):
        with self._lock:
            return len(self._spans)

    # ------------------------------------------------------------- summary
    def summary(self) -> dict:
        """Per-(cat, name) totals: count and summed seconds."""
        out: dict = {}
        for sp in self.spans():
            key = f"{sp.cat}/{sp.name}"
            agg = out.setdefault(key, {"count": 0, "total_s": 0.0})
            agg["count"] += 1
            agg["total_s"] += sp.dur
        return out

    def to_chrome(self, *, pid: int = 0, process_name: str = "planner",
                  time_scale: float = 1e6) -> list:
        """Chrome trace-event JSON events (``ph: "X"`` complete events,
        microsecond timestamps) for all finished spans."""
        events = [{
            "name": "process_name", "ph": "M", "pid": pid, "tid": 0,
            "args": {"name": process_name},
        }]
        tids = sorted({sp.tid for sp in self.spans()})
        for t in tids:
            events.append({
                "name": "thread_name", "ph": "M", "pid": pid, "tid": t,
                "args": {"name": f"thread {t}"}})
        for sp in self.spans():
            events.append({
                "name": sp.name, "cat": sp.cat, "ph": "X",
                "ts": sp.start * time_scale,
                "dur": max(sp.dur, 0.0) * time_scale,
                "pid": pid, "tid": sp.tid,
                "args": dict(sp.args, depth=sp.depth),
            })
        return events


_GLOBAL = Tracer(enabled=False)


def get_tracer() -> Tracer:
    """The process-global tracer (disabled until ``.enable()``)."""
    return _GLOBAL


def set_tracer(tracer: Tracer) -> Tracer:
    """Swap the process-global tracer (tests); returns the old one."""
    global _GLOBAL
    old, _GLOBAL = _GLOBAL, tracer
    return old


def span(name: str, cat: str = "planner", **args):
    """``get_tracer().span(...)`` shorthand for instrumented call sites."""
    return _GLOBAL.span(name, cat, **args)


def export_tracer_metrics(registry, tracer: Tracer | None = None):
    """Mirror a tracer's drop/buffer state into a metrics registry.

    ``tracer_dropped_spans_total`` counts spans silently discarded at
    the ``max_spans`` cap — the one failure mode of the span layer that
    is otherwise invisible. The counter is advanced by the delta since
    the last export (a swapped/cleared tracer resets its ``dropped``;
    the registry counter stays monotonic, as counters must). Also sets
    ``tracer_buffered_spans`` and ``tracer_enabled`` gauges. Returns the
    counter.
    """
    tr = tracer if tracer is not None else _GLOBAL
    c = registry.counter(
        "tracer_dropped_spans_total",
        "spans dropped at the tracer max_spans cap")
    delta = tr.dropped - c.value()
    if delta > 0:
        c.inc(delta)
    registry.gauge(
        "tracer_buffered_spans",
        "finished spans buffered in the tracer").set(float(len(tr)))
    registry.gauge(
        "tracer_enabled",
        "1 when the span tracer records").set(1.0 if tr.enabled else 0.0)
    return c
