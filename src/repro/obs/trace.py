"""Chrome/Perfetto trace export for pipeline timelines + the
predicted-vs-executed diff report.

Renders both sides of the §4.3 feedback loop into the Chrome trace-event
format (``chrome://tracing`` / https://ui.perfetto.dev):

  * the schedule simulator's *predicted* ``Timeline`` (``exec.schedule
    .simulate_schedule``) — one track per stage plus a transfer track,
    events named ``F0.1`` / ``B2c1.0`` / ``X0->1.3``, colored by kind;
  * the *executed* event stream — a replay ``StepRecord`` (``exec
    .replay.execute_pipeline`` puts per-event start/finish in
    ``meta["events"]``) or the real engine's ``StepStats`` events.

``diff_report`` joins the two streams per ``(stage, mb, kind, chunk)``
and attributes the step-time error to compute (F/B/W) vs transfer (X)
vs sync/other — the "where did my predicted step go" view the feedback
loop calibrates from.

All timestamps are emitted in microseconds (the trace-event contract);
``validate_chrome_trace`` is the schema check the tests and the
``repro-plan trace`` CLI both run on every exported document.
"""
from __future__ import annotations

import gzip
import json
import os

# chrome://tracing reserved color names per event kind
KIND_CNAME = {"F": "good", "B": "bad", "W": "yellow", "X": "grey"}
KIND_LABEL = {"F": "forward", "B": "backward", "W": "weight-grad",
              "X": "transfer"}
US = 1e6                      # seconds -> trace-event microseconds


def _meta_event(name: str, pid: int, tid: int, value: str) -> dict:
    return {"name": name, "ph": "M", "pid": pid, "tid": tid,
            "args": {"name": value}}


def event_name(kind: str, stage: int, mb: int, chunk: int,
               src: int = -1) -> str:
    """Canonical event label: ``F0.1`` / ``B2c1.0`` / ``X0->1.3`` —
    shared by timeline export, executed traces, and spool producers."""
    c = f"c{chunk}" if chunk else ""
    if kind == "X":
        return f"X{src}->{stage}.{mb}"
    return f"{kind}{stage}{c}.{mb}"


_event_name = event_name


def timeline_trace_events(tl, *, pid: int = 0,
                          process_name: str = "predicted") -> list:
    """Trace events for a simulated ``Timeline``: tid ``s`` is stage
    ``s``'s compute track, tid ``n_stages + s`` its incoming-transfer
    track."""
    S = tl.n_stages
    events = [_meta_event("process_name", pid, 0, process_name)]
    for s in range(S):
        events.append(_meta_event("thread_name", pid, s, f"stage {s}"))
    xfer_tids = sorted({e.stage for e in tl.events if e.kind == "X"})
    for s in xfer_tids:
        events.append(_meta_event("thread_name", pid, S + s,
                                  f"stage {s} transfers in"))
    for e in tl.events:
        tid = e.stage if e.kind != "X" else S + e.stage
        args = {"kind": KIND_LABEL.get(e.kind, e.kind), "stage": e.stage,
                "mb": e.mb, "chunk": e.chunk}
        if e.kind == "X":
            args["src_stage"] = e.src
            args["nbytes"] = e.nbytes
        events.append({
            "name": _event_name(e.kind, e.stage, e.mb, e.chunk, e.src),
            "cat": f"pipeline,{KIND_LABEL.get(e.kind, e.kind)}",
            "ph": "X", "ts": e.start * US,
            "dur": max(e.dur, 0.0) * US,
            "pid": pid, "tid": tid,
            "cname": KIND_CNAME.get(e.kind, "generic_work"),
            "args": args,
        })
    return events


def executed_events_of(source) -> list:
    """Normalize an executed event stream to
    ``[{kind, stage, mb, chunk, start, finish}, ...]``.

    Accepts a replay/engine ``StepRecord`` (events under
    ``meta["events"]``), an ``exec.engine.StepStats``, or an already
    normalized list of event dicts.
    """
    meta = getattr(source, "meta", None)
    if isinstance(meta, dict) and "events" in meta:
        source = meta["events"]
    evs = getattr(source, "events", source)
    out = []
    for e in evs:
        if isinstance(e, dict):
            out.append({"kind": e["kind"], "stage": int(e["stage"]),
                        "mb": int(e["mb"]),
                        "chunk": int(e.get("chunk", 0)),
                        "src": int(e.get("src", -1)),
                        "start": float(e["start"]),
                        "finish": float(e["finish"])})
        else:
            # engine StepStats tuple: (kind, stage, mb, dur, chunk, start)
            kind, s, m, dur, chunk = e[:5]
            start = float(e[5]) if len(e) > 5 else 0.0
            out.append({"kind": kind, "stage": int(s), "mb": int(m),
                        "chunk": int(chunk), "src": -1, "start": start,
                        "finish": start + float(dur)})
    return out


def executed_trace_events(source, *, pid: int = 1,
                          process_name: str = "executed",
                          n_stages: int | None = None) -> list:
    """Trace events for an executed step (see ``executed_events_of``)."""
    evs = executed_events_of(source)
    S = n_stages if n_stages is not None \
        else max((e["stage"] for e in evs), default=-1) + 1
    events = [_meta_event("process_name", pid, 0, process_name)]
    tids = sorted({e["stage"] for e in evs})
    for s in tids:
        events.append(_meta_event("thread_name", pid, s, f"stage {s}"))
    xfer_tids = sorted({e["stage"] for e in evs if e["kind"] == "X"})
    for s in xfer_tids:
        events.append(_meta_event("thread_name", pid, S + s,
                                  f"stage {s} transfers in"))
    for e in evs:
        tid = e["stage"] if e["kind"] != "X" else S + e["stage"]
        args = {"kind": KIND_LABEL.get(e["kind"], e["kind"]),
                "stage": e["stage"], "mb": e["mb"], "chunk": e["chunk"]}
        if e["kind"] == "X" and e["src"] >= 0:
            args["src_stage"] = e["src"]
        events.append({
            "name": _event_name(e["kind"], e["stage"], e["mb"], e["chunk"],
                                e["src"]),
            "cat": f"pipeline,{KIND_LABEL.get(e['kind'], e['kind'])}",
            "ph": "X", "ts": e["start"] * US,
            "dur": max(e["finish"] - e["start"], 0.0) * US,
            "pid": pid, "tid": tid,
            "cname": KIND_CNAME.get(e["kind"], "generic_work"),
            "args": args,
        })
    return events


def chrome_trace(events: list, **metadata) -> dict:
    """Wrap trace events as a Chrome trace-event JSON document."""
    return {"traceEvents": list(events), "displayTimeUnit": "ms",
            "otherData": dict(metadata)}


def write_chrome_trace(path: str, events_or_doc, **metadata) -> str:
    """Write (and validate) a trace document; ``.gz`` paths compress."""
    doc = events_or_doc if isinstance(events_or_doc, dict) \
        else chrome_trace(events_or_doc, **metadata)
    validate_chrome_trace(doc)
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    if path.endswith(".gz"):
        with gzip.open(path, "wt") as f:
            json.dump(doc, f)
    else:
        with open(path, "w") as f:
            json.dump(doc, f)
    return path


def validate_chrome_trace(doc) -> dict:
    """Schema check for the trace-event JSON object format; raises
    ``ValueError`` on violation, returns the (JSON-round-trippable)
    document otherwise."""
    doc = json.loads(json.dumps(doc))      # proves serializability
    if not isinstance(doc, dict) or "traceEvents" not in doc:
        raise ValueError("trace document must carry 'traceEvents'")
    evs = doc["traceEvents"]
    if not isinstance(evs, list):
        raise ValueError("'traceEvents' must be a list")
    for i, e in enumerate(evs):
        if not isinstance(e, dict):
            raise ValueError(f"event {i} is not an object")
        for k in ("name", "ph", "pid", "tid"):
            if k not in e:
                raise ValueError(f"event {i} missing required key {k!r}")
        ph = e["ph"]
        if ph == "M":
            continue
        if "ts" not in e:
            raise ValueError(f"event {i} ({e['name']}): missing 'ts'")
        if not isinstance(e["ts"], (int, float)) or e["ts"] < 0:
            raise ValueError(f"event {i} ({e['name']}): bad ts {e['ts']}")
        if ph == "X":
            dur = e.get("dur")
            if not isinstance(dur, (int, float)) or dur < 0:
                raise ValueError(
                    f"event {i} ({e['name']}): complete event needs "
                    f"dur >= 0, got {dur!r}")
    return doc


def aggregate_events(events: list) -> dict:
    """Reduce a normalized executed-event list (``executed_events_of``)
    to the per-key busy sums the run-health analyzer rolls over:

      * ``stage``: compute seconds (F/B/W) by stage id;
      * ``link``:  transfer seconds (X) by directed ``"src->dst"`` stage
        edge (``"?->dst"`` when the producer did not record a src);
      * ``span``:  (earliest start, latest finish) across all events.
    """
    stage: dict = {}
    link: dict = {}
    t0, t1 = float("inf"), float("-inf")
    for e in events:
        dur = e["finish"] - e["start"]
        t0 = min(t0, e["start"])
        t1 = max(t1, e["finish"])
        if e["kind"] == "X":
            src = e.get("src", -1)
            key = f"{src if src >= 0 else '?'}->{e['stage']}"
            link[key] = link.get(key, 0.0) + dur
        else:
            s = int(e["stage"])
            stage[s] = stage.get(s, 0.0) + dur
    if not events:
        t0 = t1 = 0.0
    return {"stage": stage, "link": link, "span": (t0, t1)}


# ------------------------------------------------------------ diff report

def _key(e) -> tuple:
    # src disambiguates the two transfers into one stage (forward
    # activation from s-1 vs backward grad from s+1, same mb/chunk)
    return (e["stage"], e["mb"], e["kind"], e["chunk"], e["src"])


def diff_report(predicted_tl, executed, *, sync_time: float = 0.0,
                executed_wall: float | None = None, top_k: int = 8) -> dict:
    """Join predicted vs executed per (stage, mb, kind, chunk) and
    attribute the step-time gap.

    ``predicted_tl`` is a simulated ``Timeline``; ``executed`` anything
    ``executed_events_of`` accepts. ``executed_wall`` (default: the
    latest executed finish) is the measured step seconds; ``sync_time``
    is the predicted post-flush gradient-sync that the timeline itself
    does not contain.

    The report's ``attribution`` splits the summed per-event error into
    ``compute_s`` (F/B/W), ``transfer_s`` (X) and ``sync_other_s`` (the
    wall-clock gap unexplained by per-event deltas — gradient sync,
    dispatch overhead, host time).
    """
    pred = {}
    for e in predicted_tl.events:
        pred[(e.stage, e.mb, e.kind, e.chunk, e.src)] = {
            "start": e.start, "finish": e.finish, "dur": e.dur}
    exe = {_key(e): {"start": e["start"], "finish": e["finish"],
                     "dur": e["finish"] - e["start"]}
           for e in executed_events_of(executed)}

    rows = []
    compute_d = transfer_d = 0.0
    matched = 0
    for key in sorted(set(pred) | set(exe)):
        stage, mb, kind, chunk, src = key
        p, x = pred.get(key), exe.get(key)
        row = {"stage": stage, "mb": mb, "kind": kind, "chunk": chunk,
               "src": src,
               "predicted_s": p["dur"] if p else None,
               "executed_s": x["dur"] if x else None,
               "delta_s": (x["dur"] - p["dur"]) if p and x else None}
        rows.append(row)
        if p and x:
            matched += 1
            if kind == "X":
                transfer_d += x["dur"] - p["dur"]
            else:
                compute_d += x["dur"] - p["dur"]

    by_kind = {}
    for row in rows:
        agg = by_kind.setdefault(row["kind"], {
            "predicted_s": 0.0, "executed_s": 0.0, "events": 0})
        agg["events"] += 1
        agg["predicted_s"] += row["predicted_s"] or 0.0
        agg["executed_s"] += row["executed_s"] or 0.0
    for agg in by_kind.values():
        agg["delta_s"] = agg["executed_s"] - agg["predicted_s"]

    predicted_step = predicted_tl.makespan + sync_time
    if executed_wall is None:
        executed_wall = max((e["finish"] for e in
                             executed_events_of(executed)), default=0.0)
    step_err = executed_wall - predicted_step
    worst = sorted((r for r in rows if r["delta_s"] is not None),
                   key=lambda r: -abs(r["delta_s"]))[:top_k]
    return {
        "predicted_step_s": predicted_step,
        "predicted_makespan_s": predicted_tl.makespan,
        "predicted_sync_s": sync_time,
        "executed_step_s": executed_wall,
        "step_error_s": step_err,
        "step_error_frac": step_err / predicted_step
        if predicted_step > 0 else 0.0,
        "events_predicted": len(pred), "events_executed": len(exe),
        "events_matched": matched,
        "unmatched": [r for r in rows if r["delta_s"] is None],
        "attribution": {
            "compute_s": compute_d,
            "transfer_s": transfer_d,
            "sync_other_s": step_err - compute_d - transfer_d,
        },
        "by_kind": by_kind,
        "worst_events": worst,
        "rows": rows,
    }


def format_diff(report: dict) -> str:
    """Human-oriented rendering of a ``diff_report``."""
    a = report["attribution"]
    lines = [
        f"predicted step {report['predicted_step_s']:.6f}s "
        f"(makespan {report['predicted_makespan_s']:.6f}s"
        f" + sync {report['predicted_sync_s']:.6f}s), "
        f"executed {report['executed_step_s']:.6f}s "
        f"-> error {report['step_error_frac']:+.2%}",
        f"attribution: compute {a['compute_s']:+.6f}s, "
        f"transfer {a['transfer_s']:+.6f}s, "
        f"sync/other {a['sync_other_s']:+.6f}s",
        f"events: {report['events_matched']} matched / "
        f"{report['events_predicted']} predicted / "
        f"{report['events_executed']} executed",
    ]
    for kind, agg in sorted(report["by_kind"].items()):
        lines.append(
            f"  {KIND_LABEL.get(kind, kind):>11}: "
            f"predicted {agg['predicted_s']:.6f}s, "
            f"executed {agg['executed_s']:.6f}s "
            f"({agg['delta_s']:+.6f}s over {agg['events']} events)")
    for r in report["worst_events"]:
        lines.append(
            f"  worst: "
            f"{_event_name(r['kind'], r['stage'], r['mb'], r['chunk'], r.get('src', -1))}"
            f" predicted {r['predicted_s']:.6f}s executed "
            f"{r['executed_s']:.6f}s ({r['delta_s']:+.6f}s)")
    return "\n".join(lines)
