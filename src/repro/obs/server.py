"""Served observability plane: /metrics, /healthz, /traces, /plans,
/runs, /alerts.

A stdlib ``http.server`` daemon that turns the in-process observability
surfaces into live endpoints — no third-party dependency, safe to embed
in the planner service or run standalone via ``repro-plan
serve-metrics``:

  * ``GET /metrics``        — Prometheus text exposition from the live
                              ``MetricsRegistry`` (planner counters,
                              calibration gauges, tracer drop counter,
                              collector spool gauges, run-health
                              series);
  * ``GET /healthz``        — liveness JSON (uptime, scrape count,
                              collector/recalibration state);
  * ``GET /traces``         — JSON list of spooled run ids;
  * ``GET /traces/<run_id>``— the merged, clock-aligned Chrome trace
                              for one run (all runs via ``/traces/all``;
                              runs past ``trace_stream_events`` stream
                              chunked with bounded memory);
  * ``GET /plans``          — plan-store stats + per-plan entries with
                              their cached verify diagnostics;
  * ``GET /plans/<fp>/verify`` — full TAGxxx diagnostics for plans
                              matching a fingerprint prefix;
  * ``GET /runs``           — run-health index (one row per run);
  * ``GET /runs/<run_id>/health`` — the full health snapshot: residual
                              ratios, stragglers, attribution, SLO;
  * ``GET /alerts``         — all runs' burn-rate alert states.

The server binds before ``start()`` returns (port 0 picks a free port,
so tests never race on a fixed one), handles requests on daemon threads,
and refreshes per-scrape state inside the request: each ``/metrics``
scrape re-exports tracer drop counts, drains this process's tracer into
the spool (when one is attached), polls the collector and the health
analyzer, and re-reads the plan-store size — a scrape always reflects
*now*, not server start.
"""
from __future__ import annotations

from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
import json
import threading
import time
from urllib.parse import urlparse

from repro.obs.metrics import MetricsRegistry
from repro.obs.spans import export_tracer_metrics, get_tracer

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class ObsServer:
    """HTTP front end over a registry + optional service/collector/loop.

    Every collaborator is optional and duck-typed: ``service`` needs
    ``.metrics``/``.store``/``.stats()`` (a ``PlannerService``),
    ``collector`` is a ``TraceCollector``, ``spool`` a ``SpoolWriter``
    this process drains its own tracer into, ``recalib`` a
    ``RecalibrationLoop`` whose lifecycle the server adopts on
    ``start()``/``stop()``.
    """

    def __init__(self, *, registry: MetricsRegistry | None = None,
                 service=None, collector=None, spool=None, recalib=None,
                 health=None, host: str = "127.0.0.1", port: int = 0,
                 spool_max_age_s: float | None = None,
                 spool_max_bytes: int | None = None,
                 trace_stream_events: int = 10_000):
        if registry is None:
            registry = service.metrics if service is not None \
                else MetricsRegistry()
        self.registry = registry
        self.service = service
        self.collector = collector
        self.spool = spool
        self.recalib = recalib
        # run-health analyzer (repro.obs.health.RunHealthAnalyzer):
        # polled + exported on every /metrics scrape, served on /runs,
        # /runs/<run_id>/health and /alerts
        self.health = health
        # traces with more merged spans than this stream chunked instead
        # of buffering the whole serialized JSON document
        self.trace_stream_events = int(trace_stream_events)
        # shard retention budgets: each /metrics scrape GCs drained
        # spool shards past these (None = keep forever)
        self.spool_max_age_s = spool_max_age_s
        self.spool_max_bytes = spool_max_bytes
        self._t0 = time.time()
        self._scrapes = registry.counter(
            "obs_http_requests_total", "requests served by the obs plane")
        self._httpd = ThreadingHTTPServer((host, port), self._handler())
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------- lifecycle
    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ObsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.1},
            name="obs-server", daemon=True)
        self._thread.start()
        if self.recalib is not None:
            self.recalib.start()
        return self

    def stop(self):
        if self.recalib is not None:
            self.recalib.stop()
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False

    # ------------------------------------------------------------- routes
    def render_metrics(self) -> str:
        """The /metrics body; refreshes live state before rendering."""
        export_tracer_metrics(self.registry)
        if self.spool is not None:
            try:
                self.spool.emit_tracer(get_tracer())
            except OSError:
                pass
        if self.service is not None:
            self.registry.gauge(
                "planner_store_size",
                "plans resident in the store").set(len(self.service.store))
        if self.collector is not None:
            self.collector.poll()
            if self.spool_max_age_s is not None \
                    or self.spool_max_bytes is not None:
                res = self.collector.gc(max_age_s=self.spool_max_age_s,
                                        max_bytes=self.spool_max_bytes)
                if res["deleted"]:
                    self.registry.counter(
                        "collector_spool_gc_deleted_total",
                        "drained spool shard files removed by retention "
                        "GC").inc(res["deleted"])
                    self.registry.counter(
                        "collector_spool_gc_bytes_total",
                        "spool bytes reclaimed by retention GC").inc(
                        res["bytes_freed"])
            c = self.collector.counts()
            g = self.registry.gauge
            g("collector_spool_shards",
              "spool shard files seen by the collector").set(c["shards"])
            g("collector_spool_spans",
              "span records merged from the spool").set(c["spans"])
            g("collector_spool_bad_lines",
              "malformed spool lines skipped").set(c["bad_lines"])
            g("collector_spool_runs",
              "distinct run ids in the spool").set(c["runs"])
        if self.health is not None:
            self.health.poll()
            self.health.export_metrics(self.registry)
        return self.registry.to_prometheus()

    def _healthz(self) -> dict:
        body = {"status": "ok", "uptime_s": time.time() - self._t0,
                "requests": self._scrapes.value(path="/metrics")}
        if self.collector is not None:
            body["collector"] = self.collector.counts()
        if self.recalib is not None:
            body["recalibration"] = self.recalib.stats()
        if self.service is not None:
            body["store_size"] = len(self.service.store)
        if self.health is not None:
            body["run_health"] = self.health.stats()
        return body

    def _plan_listing(self) -> dict:
        """The /plans body: service stats + per-plan entries carrying
        the cached verify verdict AND the full TAGxxx diagnostics."""
        body = self.service.stats()
        body["plans"] = self.service.plan_entries()
        return body

    def _plan_verify_detail(self, fp: str):
        """Plans matching a fingerprint prefix, with full diagnostics.

        ``fp`` matches a record when it prefixes the graph fingerprint,
        the topology fingerprint, or the ``<graph24>-<topo24>`` combined
        form the store names its files with.
        """
        matches = []
        for entry in self.service.plan_entries():
            combined = f"{entry['graph_fp'][:24]}-{entry['topo_fp'][:24]}"
            if (entry["graph_fp"].startswith(fp)
                    or entry["topo_fp"].startswith(fp)
                    or combined.startswith(fp)):
                matches.append(entry)
        return matches

    def _route(self, path: str):
        """Returns (status, content_type, body) — ``body`` is a str, or
        an iterator of str fragments for chunked streaming responses."""
        def as_json(obj, status=200):
            return status, "application/json", json.dumps(
                obj, indent=2, sort_keys=True, default=str) + "\n"

        if path in ("/metrics", "/metrics/"):
            self._scrapes.inc(path="/metrics")
            return 200, PROM_CONTENT_TYPE, self.render_metrics()
        if path in ("/healthz", "/healthz/", "/health"):
            self._scrapes.inc(path="/healthz")
            return as_json(self._healthz())
        if path in ("/plans", "/plans/"):
            self._scrapes.inc(path="/plans")
            if self.service is None:
                return as_json({"error": "no planner service attached"},
                               404)
            return as_json(self._plan_listing())
        if path.startswith("/plans/") and path.rstrip("/").endswith(
                "/verify"):
            self._scrapes.inc(path="/plans/<fp>/verify")
            if self.service is None:
                return as_json({"error": "no planner service attached"},
                               404)
            fp = path[len("/plans/"):].rstrip("/")
            fp = fp[:-len("/verify")].strip("/")
            matches = self._plan_verify_detail(fp)
            if not matches:
                return as_json(
                    {"error": f"no plan matching fingerprint {fp!r}",
                     "plans": [e["graph_fp"][:24] for e in
                               self.service.plan_entries()]}, 404)
            return as_json({"fingerprint": fp, "matches": matches})
        if path in ("/runs", "/runs/"):
            self._scrapes.inc(path="/runs")
            if self.health is None:
                return as_json({"error": "no health analyzer attached"},
                               404)
            self.health.poll()
            return as_json({"runs": self.health.run_summaries()})
        if path.startswith("/runs/") and path.rstrip("/").endswith(
                "/health"):
            self._scrapes.inc(path="/runs/<run_id>/health")
            if self.health is None:
                return as_json({"error": "no health analyzer attached"},
                               404)
            run_id = path[len("/runs/"):].rstrip("/")
            run_id = run_id[:-len("/health")].strip("/")
            self.health.poll()
            try:
                return as_json(self.health.health(run_id))
            except KeyError:
                return as_json({"error": f"unknown run {run_id!r}",
                                "runs": self.health.run_ids()}, 404)
        if path in ("/alerts", "/alerts/"):
            self._scrapes.inc(path="/alerts")
            if self.health is None:
                return as_json({"error": "no health analyzer attached"},
                               404)
            self.health.poll()
            return as_json({"alerts": self.health.alerts()})
        if path in ("/traces", "/traces/"):
            self._scrapes.inc(path="/traces")
            if self.collector is None:
                return as_json({"error": "no trace collector attached"},
                               404)
            self.collector.poll()
            return as_json({"runs": self.collector.run_ids()})
        if path.startswith("/traces/"):
            self._scrapes.inc(path="/traces/<run_id>")
            if self.collector is None:
                return as_json({"error": "no trace collector attached"},
                               404)
            run_id = path[len("/traces/"):].strip("/")
            rid = None if run_id in ("all", "*") else run_id
            self.collector.poll()
            try:
                if self.collector.span_count(rid) \
                        > self.trace_stream_events:
                    # large run: stream the serialized document chunked
                    # instead of buffering it whole
                    return (200, "application/json",
                            self.collector.chrome_stream(rid))
                doc = self.collector.chrome(rid)
            except KeyError as e:
                return as_json({"error": str(e),
                                "runs": self.collector.run_ids()}, 404)
            return as_json(doc)
        if path in ("", "/"):
            return as_json({"endpoints": [
                "/metrics", "/healthz", "/plans",
                "/plans/<fingerprint>/verify", "/runs",
                "/runs/<run_id>/health", "/alerts", "/traces",
                "/traces/<run_id>"]})
        return as_json({"error": f"no route {path!r}"}, 404)

    # ------------------------------------------------------------ handler
    def _handler(self):
        server = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, *args):      # keep stdout clean
                pass

            def do_GET(self):
                try:
                    status, ctype, body = server._route(
                        urlparse(self.path).path)
                except Exception as e:         # a broken route must not
                    status, ctype = 500, "text/plain; charset=utf-8"
                    body = f"internal error: {e}\n"   # kill the daemon
                if isinstance(body, str):
                    data = body.encode("utf-8")
                    self.send_response(status)
                    self.send_header("Content-Type", ctype)
                    self.send_header("Content-Length", str(len(data)))
                    self.end_headers()
                    self.wfile.write(data)
                    return
                # iterator body: HTTP/1.1 chunked transfer — memory per
                # in-flight response is one fragment, not the document
                self.send_response(status)
                self.send_header("Content-Type", ctype)
                self.send_header("Transfer-Encoding", "chunked")
                self.end_headers()
                try:
                    for part in body:
                        if not part:
                            continue
                        data = part.encode("utf-8")
                        self.wfile.write(
                            f"{len(data):x}\r\n".encode() + data
                            + b"\r\n")
                    self.wfile.write(b"0\r\n\r\n")
                except (BrokenPipeError, ConnectionResetError):
                    pass                      # client went away mid-body

        return Handler
