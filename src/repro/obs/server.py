"""Served observability plane: /metrics, /healthz, /traces, /plans.

A stdlib ``http.server`` daemon that turns the in-process observability
surfaces into live endpoints — no third-party dependency, safe to embed
in the planner service or run standalone via ``repro-plan
serve-metrics``:

  * ``GET /metrics``        — Prometheus text exposition from the live
                              ``MetricsRegistry`` (planner counters,
                              calibration gauges, tracer drop counter,
                              collector spool gauges);
  * ``GET /healthz``        — liveness JSON (uptime, scrape count,
                              collector/recalibration state);
  * ``GET /traces``         — JSON list of spooled run ids;
  * ``GET /traces/<run_id>``— the merged, clock-aligned Chrome trace
                              for one run (all runs via ``/traces/all``);
  * ``GET /plans``          — plan-store stats JSON.

The server binds before ``start()`` returns (port 0 picks a free port,
so tests never race on a fixed one), handles requests on daemon threads,
and refreshes per-scrape state inside the request: each ``/metrics``
scrape re-exports tracer drop counts, drains this process's tracer into
the spool (when one is attached), polls the collector, and re-reads the
plan-store size — a scrape always reflects *now*, not server start.
"""
from __future__ import annotations

from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
import json
import threading
import time
from urllib.parse import urlparse

from repro.obs.metrics import MetricsRegistry
from repro.obs.spans import export_tracer_metrics, get_tracer

PROM_CONTENT_TYPE = "text/plain; version=0.0.4; charset=utf-8"


class ObsServer:
    """HTTP front end over a registry + optional service/collector/loop.

    Every collaborator is optional and duck-typed: ``service`` needs
    ``.metrics``/``.store``/``.stats()`` (a ``PlannerService``),
    ``collector`` is a ``TraceCollector``, ``spool`` a ``SpoolWriter``
    this process drains its own tracer into, ``recalib`` a
    ``RecalibrationLoop`` whose lifecycle the server adopts on
    ``start()``/``stop()``.
    """

    def __init__(self, *, registry: MetricsRegistry | None = None,
                 service=None, collector=None, spool=None, recalib=None,
                 host: str = "127.0.0.1", port: int = 0,
                 spool_max_age_s: float | None = None,
                 spool_max_bytes: int | None = None):
        if registry is None:
            registry = service.metrics if service is not None \
                else MetricsRegistry()
        self.registry = registry
        self.service = service
        self.collector = collector
        self.spool = spool
        self.recalib = recalib
        # shard retention budgets: each /metrics scrape GCs drained
        # spool shards past these (None = keep forever)
        self.spool_max_age_s = spool_max_age_s
        self.spool_max_bytes = spool_max_bytes
        self._t0 = time.time()
        self._scrapes = registry.counter(
            "obs_http_requests_total", "requests served by the obs plane")
        self._httpd = ThreadingHTTPServer((host, port), self._handler())
        self._httpd.daemon_threads = True
        self.host, self.port = self._httpd.server_address[:2]
        self._thread: threading.Thread | None = None

    # ----------------------------------------------------------- lifecycle
    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ObsServer":
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, kwargs={"poll_interval": 0.1},
            name="obs-server", daemon=True)
        self._thread.start()
        if self.recalib is not None:
            self.recalib.start()
        return self

    def stop(self):
        if self.recalib is not None:
            self.recalib.stop()
        self._httpd.shutdown()
        self._httpd.server_close()
        if self._thread is not None:
            self._thread.join(timeout=5)
            self._thread = None

    def __enter__(self):
        return self.start()

    def __exit__(self, *exc):
        self.stop()
        return False

    # ------------------------------------------------------------- routes
    def render_metrics(self) -> str:
        """The /metrics body; refreshes live state before rendering."""
        export_tracer_metrics(self.registry)
        if self.spool is not None:
            try:
                self.spool.emit_tracer(get_tracer())
            except OSError:
                pass
        if self.service is not None:
            self.registry.gauge(
                "planner_store_size",
                "plans resident in the store").set(len(self.service.store))
        if self.collector is not None:
            self.collector.poll()
            if self.spool_max_age_s is not None \
                    or self.spool_max_bytes is not None:
                res = self.collector.gc(max_age_s=self.spool_max_age_s,
                                        max_bytes=self.spool_max_bytes)
                if res["deleted"]:
                    self.registry.counter(
                        "collector_spool_gc_deleted_total",
                        "drained spool shard files removed by retention "
                        "GC").inc(res["deleted"])
                    self.registry.counter(
                        "collector_spool_gc_bytes_total",
                        "spool bytes reclaimed by retention GC").inc(
                        res["bytes_freed"])
            c = self.collector.counts()
            g = self.registry.gauge
            g("collector_spool_shards",
              "spool shard files seen by the collector").set(c["shards"])
            g("collector_spool_spans",
              "span records merged from the spool").set(c["spans"])
            g("collector_spool_bad_lines",
              "malformed spool lines skipped").set(c["bad_lines"])
            g("collector_spool_runs",
              "distinct run ids in the spool").set(c["runs"])
        return self.registry.to_prometheus()

    def _healthz(self) -> dict:
        body = {"status": "ok", "uptime_s": time.time() - self._t0,
                "requests": self._scrapes.value(path="/metrics")}
        if self.collector is not None:
            body["collector"] = self.collector.counts()
        if self.recalib is not None:
            body["recalibration"] = self.recalib.stats()
        if self.service is not None:
            body["store_size"] = len(self.service.store)
        return body

    def _route(self, path: str):
        """Returns (status, content_type, body_str)."""
        def as_json(obj, status=200):
            return status, "application/json", json.dumps(
                obj, indent=2, sort_keys=True, default=str) + "\n"

        if path in ("/metrics", "/metrics/"):
            self._scrapes.inc(path="/metrics")
            return 200, PROM_CONTENT_TYPE, self.render_metrics()
        if path in ("/healthz", "/healthz/", "/health"):
            self._scrapes.inc(path="/healthz")
            return as_json(self._healthz())
        if path in ("/plans", "/plans/"):
            self._scrapes.inc(path="/plans")
            if self.service is None:
                return as_json({"error": "no planner service attached"},
                               404)
            return as_json(self.service.stats())
        if path in ("/traces", "/traces/"):
            self._scrapes.inc(path="/traces")
            if self.collector is None:
                return as_json({"error": "no trace collector attached"},
                               404)
            self.collector.poll()
            return as_json({"runs": self.collector.run_ids()})
        if path.startswith("/traces/"):
            self._scrapes.inc(path="/traces/<run_id>")
            if self.collector is None:
                return as_json({"error": "no trace collector attached"},
                               404)
            run_id = path[len("/traces/"):].strip("/")
            self.collector.poll()
            try:
                doc = self.collector.chrome(
                    None if run_id in ("all", "*") else run_id)
            except KeyError as e:
                return as_json({"error": str(e),
                                "runs": self.collector.run_ids()}, 404)
            return as_json(doc)
        if path in ("", "/"):
            return as_json({"endpoints": ["/metrics", "/healthz",
                                          "/plans", "/traces",
                                          "/traces/<run_id>"]})
        return as_json({"error": f"no route {path!r}"}, 404)

    # ------------------------------------------------------------ handler
    def _handler(self):
        server = self

        class Handler(BaseHTTPRequestHandler):
            protocol_version = "HTTP/1.1"

            def log_message(self, *args):      # keep stdout clean
                pass

            def do_GET(self):
                try:
                    status, ctype, body = server._route(
                        urlparse(self.path).path)
                except Exception as e:         # a broken route must not
                    status, ctype = 500, "text/plain; charset=utf-8"
                    body = f"internal error: {e}\n"   # kill the daemon
                data = body.encode("utf-8")
                self.send_response(status)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(data)))
                self.end_headers()
                self.wfile.write(data)

        return Handler
