"""Metrics registry: counters / gauges / histograms with Prometheus-text
and JSON dumps.

The planner service is the primary producer (hit/warm/cold rates,
plan-latency histograms, playouts-to-best, store size, drift-detector
state); the calibration layer adds per-device-type and per-op-type
utilization gauges. Everything is in-process and thread-safe — a metric
is a named family, each (sorted) label set a separate series:

    reg = MetricsRegistry()
    reg.counter("planner_requests_total", "requests").inc(source="hit")
    reg.histogram("planner_plan_latency_seconds", "latency").observe(0.2)
    print(reg.to_prometheus())        # text exposition format
    reg.to_dict()                     # JSON-able dump

No server is bundled: ``repro-plan metrics`` prints either format, and a
future planner front end can mount ``to_prometheus()`` on a /metrics
route unchanged.
"""
from __future__ import annotations

import re
import threading

# default histogram buckets: exponential, centered on plan/step latencies
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def _labelkey(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def escape_label_value(v: str) -> str:
    """Prometheus text-exposition escaping for label values: backslash,
    double-quote, and newline must be escaped inside the quotes."""
    return (str(v).replace("\\", "\\\\").replace('"', '\\"')
            .replace("\n", "\\n"))


def _labelstr(key: tuple) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{escape_label_value(v)}"' for k, v in key)
    return "{" + inner + "}"


class Metric:
    """Base: a named family of label-keyed series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: dict = {}

    def _get(self, labels: dict, default):
        key = _labelkey(labels)
        with self._lock:
            if key not in self._series:
                self._series[key] = default()
            return key, self._series[key]

    def series(self) -> dict:
        with self._lock:
            return dict(self._series)

    def remove(self, **labels) -> int:
        """Drop every series whose label set contains all the given
        pairs; returns how many were removed. Long-lived registries
        (the served plane) use this to retire series for runs the
        health analyzer has evicted, so label cardinality tracks live
        runs instead of growing forever."""
        want = {(str(k), str(v)) for k, v in labels.items()}
        with self._lock:
            doomed = [k for k in self._series if want <= set(k)]
            for k in doomed:
                del self._series[k]
        return len(doomed)


class Counter(Metric):
    """Monotonically increasing counter (per label set)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels):
        if amount < 0:
            raise ValueError("counters only go up")
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._series.get(_labelkey(labels), 0.0)

    def to_dict(self) -> dict:
        return {_labelstr(k) or "": v for k, v in self.series().items()}

    def to_prometheus(self) -> list:
        return [f"{self.name}{_labelstr(k)} {v:.10g}"
                for k, v in sorted(self.series().items())]


class Gauge(Metric):
    """Set-to-current-value gauge (per label set)."""

    kind = "gauge"

    def set(self, value: float, **labels):
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = float(value)

    def inc(self, amount: float = 1.0, **labels):
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._series.get(_labelkey(labels), 0.0)

    to_dict = Counter.to_dict
    to_prometheus = Counter.to_prometheus


class _HistSeries:
    __slots__ = ("counts", "total", "count", "vmin", "vmax")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)       # +inf bucket last
        self.total = 0.0
        self.count = 0
        self.vmin = float("inf")
        self.vmax = float("-inf")


class Histogram(Metric):
    """Cumulative-bucket histogram (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets=None):
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets or DEFAULT_BUCKETS))

    def observe(self, value: float, **labels):
        value = float(value)
        key, s = self._get(labels, lambda: _HistSeries(len(self.buckets)))
        with self._lock:
            i = 0
            while i < len(self.buckets) and value > self.buckets[i]:
                i += 1
            s.counts[i] += 1
            s.total += value
            s.count += 1
            s.vmin = min(s.vmin, value)
            s.vmax = max(s.vmax, value)

    def snapshot(self, **labels) -> dict:
        """count/sum/mean/min/max + per-bucket cumulative counts."""
        with self._lock:
            s = self._series.get(_labelkey(labels))
            if s is None or s.count == 0:
                return {"count": 0, "sum": 0.0}
            cum, cumcounts = 0, []
            for c in s.counts:
                cum += c
                cumcounts.append(cum)
            return {"count": s.count, "sum": s.total,
                    "mean": s.total / s.count, "min": s.vmin,
                    "max": s.vmax,
                    "buckets": {("+Inf" if i >= len(self.buckets)
                                 else repr(self.buckets[i])): c
                                for i, c in enumerate(cumcounts)}}

    def to_dict(self) -> dict:
        return {_labelstr(_labelkey(dict(k))) or "":
                self.snapshot(**dict(k)) for k in self.series()}

    def to_prometheus(self) -> list:
        lines = []
        for key in sorted(self.series()):
            snap = self.snapshot(**dict(key))
            base = dict(key)
            for le, c in snap.get("buckets", {}).items():
                lab = _labelstr(_labelkey(dict(base, le=le)))
                lines.append(f"{self.name}_bucket{lab} {c}")
            lab = _labelstr(key)
            lines.append(f"{self.name}_sum{lab} {snap['sum']:.10g}")
            lines.append(f"{self.name}_count{lab} {snap['count']}")
        return lines


class MetricsRegistry:
    """Get-or-create metric families; re-registering a name with a
    different kind raises."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}

    def _register(self, cls, name, help, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=None) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def metrics(self) -> list:
        with self._lock:
            return sorted(self._metrics.values(), key=lambda m: m.name)

    def to_dict(self) -> dict:
        return {m.name: {"kind": m.kind, "help": m.help,
                         "series": m.to_dict()} for m in self.metrics()}

    def to_prometheus(self) -> str:
        lines = []
        for m in self.metrics():
            if m.help:
                esc = m.help.replace("\\", "\\\\").replace("\n", "\\n")
                lines.append(f"# HELP {m.name} {esc}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            lines.extend(m.to_prometheus())
        return "\n".join(lines) + ("\n" if lines else "")


# --------------------------------------------------------------------------
# text exposition parser — the validating half of the format contract.
# The CI smoke step and `repro-plan metrics --url` run every scrape through
# this, so a registry that emits malformed HELP/TYPE lines, label escaping,
# or histogram series fails loudly instead of at Prometheus ingest time.

_METRIC_NAME = re.compile(r"^[a-zA-Z_:][a-zA-Z0-9_:]*$")
_LABEL_NAME = re.compile(r"^[a-zA-Z_][a-zA-Z0-9_]*$")
_TYPES = ("counter", "gauge", "histogram", "summary", "untyped")


def _parse_labels(s: str, lineno: int) -> dict:
    """Parse ``{k="v",...}`` with escape handling; raises ValueError."""
    labels: dict = {}
    i = 1                                  # past '{'
    while True:
        if i >= len(s):
            raise ValueError(f"line {lineno}: unterminated label set")
        if s[i] == "}":
            return labels
        j = s.find("=", i)
        if j < 0:
            raise ValueError(f"line {lineno}: label without '='")
        name = s[i:j].strip()
        if not _LABEL_NAME.match(name):
            raise ValueError(f"line {lineno}: bad label name {name!r}")
        i = j + 1
        if i >= len(s) or s[i] != '"':
            raise ValueError(f"line {lineno}: label value not quoted")
        i += 1
        out = []
        while i < len(s) and s[i] != '"':
            c = s[i]
            if c == "\\":
                if i + 1 >= len(s):
                    raise ValueError(f"line {lineno}: dangling escape")
                nxt = s[i + 1]
                if nxt == "n":
                    out.append("\n")
                elif nxt in ('"', "\\"):
                    out.append(nxt)
                else:
                    raise ValueError(
                        f"line {lineno}: bad escape \\{nxt!r}")
                i += 2
            elif c == "\n":
                raise ValueError(f"line {lineno}: raw newline in value")
            else:
                out.append(c)
                i += 1
        if i >= len(s):
            raise ValueError(f"line {lineno}: unterminated label value")
        labels[name] = "".join(out)
        i += 1                             # past closing '"'
        if i < len(s) and s[i] == ",":
            i += 1


def parse_prometheus_text(text: str) -> dict:
    """Strict parser for the Prometheus text exposition format.

    Returns ``{family: {"kind", "help", "samples": [(name, labels,
    value), ...]}}`` — histogram ``_bucket``/``_sum``/``_count`` series
    fold into their declared base family. Raises ``ValueError`` on any
    format violation: bad metric/label names, broken quoting/escaping,
    unparseable values, duplicate or unknown TYPE declarations, or a
    histogram family missing its ``le``-labelled buckets.
    """
    families: dict = {}

    def fam(name: str) -> dict:
        return families.setdefault(
            name, {"kind": None, "help": None, "samples": []})

    def base_family(name: str) -> str:
        for suffix in ("_bucket", "_sum", "_count"):
            if name.endswith(suffix):
                base = name[:-len(suffix)]
                if families.get(base, {}).get("kind") in ("histogram",
                                                          "summary"):
                    return base
        return name

    for lineno, raw in enumerate(text.split("\n"), 1):
        line = raw.rstrip("\r")
        if not line.strip():
            continue
        if line.startswith("# HELP "):
            parts = line[len("# HELP "):].split(" ", 1)
            name = parts[0]
            if not _METRIC_NAME.match(name):
                raise ValueError(f"line {lineno}: bad HELP name {name!r}")
            fam(name)["help"] = (parts[1] if len(parts) > 1 else "")
        elif line.startswith("# TYPE "):
            parts = line[len("# TYPE "):].split()
            if len(parts) != 2:
                raise ValueError(f"line {lineno}: malformed TYPE line")
            name, kind = parts
            if not _METRIC_NAME.match(name):
                raise ValueError(f"line {lineno}: bad TYPE name {name!r}")
            if kind not in _TYPES:
                raise ValueError(f"line {lineno}: unknown type {kind!r}")
            f = fam(name)
            if f["kind"] is not None:
                raise ValueError(f"line {lineno}: duplicate TYPE {name}")
            f["kind"] = kind
        elif line.startswith("#"):
            continue                       # free-form comment
        else:
            brace = line.find("{")
            if brace >= 0:
                name = line[:brace]
                close = line.rfind("}")
                if close < brace:
                    raise ValueError(f"line {lineno}: unbalanced braces")
                labels = _parse_labels(line[brace:close + 1], lineno)
                rest = line[close + 1:].strip()
            else:
                name, _, rest = line.partition(" ")
                labels, rest = {}, rest.strip()
            if not _METRIC_NAME.match(name):
                raise ValueError(f"line {lineno}: bad metric name {name!r}")
            fields = rest.split()
            if not fields:
                raise ValueError(f"line {lineno}: sample missing value")
            tok = fields[0]
            try:
                value = float("inf" if tok == "+Inf" else
                              "-inf" if tok == "-Inf" else tok)
            except ValueError:
                raise ValueError(
                    f"line {lineno}: bad sample value {tok!r}") from None
            fam(base_family(name))["samples"].append((name, labels, value))

    for name, f in families.items():
        if f["kind"] is None:
            f["kind"] = "untyped"
        if f["kind"] == "histogram" and f["samples"]:
            series = {s for s, _, _ in f["samples"]}
            if f"{name}_bucket" not in series:
                raise ValueError(f"histogram {name} has no _bucket series")
            if f"{name}_count" not in series or f"{name}_sum" not in series:
                raise ValueError(f"histogram {name} missing _sum/_count")
            if not all(lbl.get("le") for s, lbl, _ in f["samples"]
                       if s == f"{name}_bucket"):
                raise ValueError(f"histogram {name} bucket missing 'le'")
    return families
