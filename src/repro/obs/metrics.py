"""Metrics registry: counters / gauges / histograms with Prometheus-text
and JSON dumps.

The planner service is the primary producer (hit/warm/cold rates,
plan-latency histograms, playouts-to-best, store size, drift-detector
state); the calibration layer adds per-device-type and per-op-type
utilization gauges. Everything is in-process and thread-safe — a metric
is a named family, each (sorted) label set a separate series:

    reg = MetricsRegistry()
    reg.counter("planner_requests_total", "requests").inc(source="hit")
    reg.histogram("planner_plan_latency_seconds", "latency").observe(0.2)
    print(reg.to_prometheus())        # text exposition format
    reg.to_dict()                     # JSON-able dump

No server is bundled: ``repro-plan metrics`` prints either format, and a
future planner front end can mount ``to_prometheus()`` on a /metrics
route unchanged.
"""
from __future__ import annotations

import threading

# default histogram buckets: exponential, centered on plan/step latencies
DEFAULT_BUCKETS = (0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25,
                   0.5, 1.0, 2.5, 5.0, 10.0, 30.0, 60.0)


def _labelkey(labels: dict) -> tuple:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


def _labelstr(key: tuple) -> str:
    if not key:
        return ""
    inner = ",".join(f'{k}="{v}"' for k, v in key)
    return "{" + inner + "}"


class Metric:
    """Base: a named family of label-keyed series."""

    kind = "untyped"

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: dict = {}

    def _get(self, labels: dict, default):
        key = _labelkey(labels)
        with self._lock:
            if key not in self._series:
                self._series[key] = default()
            return key, self._series[key]

    def series(self) -> dict:
        with self._lock:
            return dict(self._series)


class Counter(Metric):
    """Monotonically increasing counter (per label set)."""

    kind = "counter"

    def inc(self, amount: float = 1.0, **labels):
        if amount < 0:
            raise ValueError("counters only go up")
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._series.get(_labelkey(labels), 0.0)

    def to_dict(self) -> dict:
        return {_labelstr(k) or "": v for k, v in self.series().items()}

    def to_prometheus(self) -> list:
        return [f"{self.name}{_labelstr(k)} {v:.10g}"
                for k, v in sorted(self.series().items())]


class Gauge(Metric):
    """Set-to-current-value gauge (per label set)."""

    kind = "gauge"

    def set(self, value: float, **labels):
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = float(value)

    def inc(self, amount: float = 1.0, **labels):
        key = _labelkey(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return self._series.get(_labelkey(labels), 0.0)

    to_dict = Counter.to_dict
    to_prometheus = Counter.to_prometheus


class _HistSeries:
    __slots__ = ("counts", "total", "count", "vmin", "vmax")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)       # +inf bucket last
        self.total = 0.0
        self.count = 0
        self.vmin = float("inf")
        self.vmax = float("-inf")


class Histogram(Metric):
    """Cumulative-bucket histogram (Prometheus semantics)."""

    kind = "histogram"

    def __init__(self, name: str, help: str = "", buckets=None):
        super().__init__(name, help)
        self.buckets = tuple(sorted(buckets or DEFAULT_BUCKETS))

    def observe(self, value: float, **labels):
        value = float(value)
        key, s = self._get(labels, lambda: _HistSeries(len(self.buckets)))
        with self._lock:
            i = 0
            while i < len(self.buckets) and value > self.buckets[i]:
                i += 1
            s.counts[i] += 1
            s.total += value
            s.count += 1
            s.vmin = min(s.vmin, value)
            s.vmax = max(s.vmax, value)

    def snapshot(self, **labels) -> dict:
        """count/sum/mean/min/max + per-bucket cumulative counts."""
        with self._lock:
            s = self._series.get(_labelkey(labels))
            if s is None or s.count == 0:
                return {"count": 0, "sum": 0.0}
            cum, cumcounts = 0, []
            for c in s.counts:
                cum += c
                cumcounts.append(cum)
            return {"count": s.count, "sum": s.total,
                    "mean": s.total / s.count, "min": s.vmin,
                    "max": s.vmax,
                    "buckets": {("+Inf" if i >= len(self.buckets)
                                 else repr(self.buckets[i])): c
                                for i, c in enumerate(cumcounts)}}

    def to_dict(self) -> dict:
        return {_labelstr(_labelkey(dict(k))) or "":
                self.snapshot(**dict(k)) for k in self.series()}

    def to_prometheus(self) -> list:
        lines = []
        for key in sorted(self.series()):
            snap = self.snapshot(**dict(key))
            base = dict(key)
            for le, c in snap.get("buckets", {}).items():
                lab = _labelstr(_labelkey(dict(base, le=le)))
                lines.append(f"{self.name}_bucket{lab} {c}")
            lab = _labelstr(key)
            lines.append(f"{self.name}_sum{lab} {snap['sum']:.10g}")
            lines.append(f"{self.name}_count{lab} {snap['count']}")
        return lines


class MetricsRegistry:
    """Get-or-create metric families; re-registering a name with a
    different kind raises."""

    def __init__(self):
        self._lock = threading.Lock()
        self._metrics: dict = {}

    def _register(self, cls, name, help, **kw):
        with self._lock:
            m = self._metrics.get(name)
            if m is None:
                m = cls(name, help, **kw)
                self._metrics[name] = m
            elif not isinstance(m, cls):
                raise ValueError(
                    f"metric {name!r} already registered as {m.kind}")
            return m

    def counter(self, name: str, help: str = "") -> Counter:
        return self._register(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._register(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets=None) -> Histogram:
        return self._register(Histogram, name, help, buckets=buckets)

    def get(self, name: str):
        with self._lock:
            return self._metrics.get(name)

    def metrics(self) -> list:
        with self._lock:
            return sorted(self._metrics.values(), key=lambda m: m.name)

    def to_dict(self) -> dict:
        return {m.name: {"kind": m.kind, "help": m.help,
                         "series": m.to_dict()} for m in self.metrics()}

    def to_prometheus(self) -> str:
        lines = []
        for m in self.metrics():
            if m.help:
                lines.append(f"# HELP {m.name} {m.help}")
            lines.append(f"# TYPE {m.name} {m.kind}")
            lines.extend(m.to_prometheus())
        return "\n".join(lines) + ("\n" if lines else "")
