"""Observability layer (``repro.obs``): tracing + metrics for the whole
TAG pipeline.

  * ``trace``        — Chrome/Perfetto trace export of predicted
                       schedule ``Timeline``s and executed event
                       streams, plus the per-(stage, mb, kind)
                       predicted-vs-executed ``diff_report``;
  * ``spans``        — low-overhead thread-safe span API (planner path:
                       plan -> store lookup -> policy resolve -> MCTS
                       playouts with expand/featurize/simulate
                       sub-spans), exported in the same trace format;
  * ``metrics``      — counters/gauges/histograms with Prometheus-text
                       and JSON dumps (planner hit rates, plan-latency
                       histograms, bubble fractions, drift state);
  * ``xla_profiler`` — optional ``jax.profiler`` hook parsing real
                       per-collective samples into
                       ``StepRecord.collectives`` (graceful no-op when
                       the profiler is unavailable).

The live plane (PR 7) crosses process boundaries:

  * ``collector``    — cross-process span/event spool (fcntl-locked
                       JSONL shards with wall<->monotonic anchors) and
                       the incremental merge into one Chrome trace;
  * ``server``       — stdlib HTTP daemon serving /metrics (Prometheus
                       text), /healthz, /traces/<run_id> (chunked past
                       a size threshold), /plans (+ verify detail),
                       /runs, /runs/<run_id>/health, /alerts;
  * ``health``       — ``RunHealthAnalyzer``: continuous executed-vs-
                       predicted residual attribution per stage/link,
                       straggler ranking with hysteresis, and replan
                       prioritization for the recalibration loop;
  * ``alerts``       — step-time SLO tracking with multi-window
                       burn-rate ``AlertRule`` evaluation (page/warn).

Every surface is consumed by ``repro-plan trace`` / ``repro-plan
metrics`` / ``repro-plan serve-metrics`` and ``launch.train
--trace-dir`` / ``--spool-dir``.
"""
from repro.obs.alerts import (
    AlertEvaluator, AlertRule, AlertState, SLOTracker, default_rules,
    load_rules, parse_rules)
from repro.obs.collector import SpoolWriter, TraceCollector, shard_path
from repro.obs.health import RunHealthAnalyzer
from repro.obs.metrics import (
    Counter, Gauge, Histogram, Metric, MetricsRegistry,
    escape_label_value, parse_prometheus_text)
from repro.obs.server import PROM_CONTENT_TYPE, ObsServer
from repro.obs.spans import (
    Span, Tracer, export_tracer_metrics, get_tracer, set_tracer, span)
from repro.obs.trace import (
    aggregate_events, chrome_trace, diff_report, event_name,
    executed_events_of, executed_trace_events, format_diff,
    timeline_trace_events, validate_chrome_trace, write_chrome_trace)
from repro.obs.xla_profiler import (
    attach_collectives, classify_op, find_trace_files,
    parse_trace_collectives, profile_step, profiler_available)

__all__ = [
    "Counter", "Gauge", "Histogram", "Metric", "MetricsRegistry",
    "escape_label_value", "parse_prometheus_text",
    "Span", "Tracer", "export_tracer_metrics", "get_tracer",
    "set_tracer", "span",
    "SpoolWriter", "TraceCollector", "shard_path",
    "ObsServer", "PROM_CONTENT_TYPE",
    "AlertEvaluator", "AlertRule", "AlertState", "SLOTracker",
    "default_rules", "load_rules", "parse_rules",
    "RunHealthAnalyzer",
    "aggregate_events", "chrome_trace", "diff_report", "event_name",
    "executed_events_of", "executed_trace_events", "format_diff",
    "timeline_trace_events", "validate_chrome_trace",
    "write_chrome_trace",
    "attach_collectives", "classify_op", "find_trace_files",
    "parse_trace_collectives", "profile_step", "profiler_available",
]
