"""SLO tracking + multi-window burn-rate alerting for run health.

The step-time SLO is framed the SRE way: an *objective* fraction of
steps (default 99%) must finish under a *target* wall time. Each step is
a good/bad sample; the error budget is ``1 - objective``; the **burn
rate** over a window is ``bad_fraction(window) / (1 - objective)`` — a
burn rate of 1.0 spends the budget exactly at its sustainable pace,
14.4 spends a 30-day budget in ~2 days.

An ``AlertRule`` is the classic two-window form: it fires only when the
burn rate exceeds its threshold over BOTH the long window (persistence —
one bad step cannot page) and the short window (recency — an incident
that already ended stops paging as soon as the short window drains).
``default_rules()`` ships a page/warn pair over 1h/5m windows; callers
override via ``serve-metrics --alert-rules rules.json``.

Everything is timestamp-driven (no hidden ``time.time()`` in the math):
``SLOTracker.observe(ts, value)`` buffers samples, ``burn_rate(window,
now)`` evaluates at an explicit instant — deterministic under test and
under replayed telemetry.
"""
from __future__ import annotations

from collections import deque
from dataclasses import dataclass, field
import json

SEVERITIES = ("page", "warn")

DEFAULT_OBJECTIVE = 0.99              # 99% of steps under the target


@dataclass(frozen=True)
class AlertRule:
    """One two-window burn-rate rule.

    Fires when the SLO burn rate is >= ``burn_rate`` over BOTH
    ``long_window_s`` and ``short_window_s``.
    """
    name: str
    severity: str                     # "page" | "warn"
    burn_rate: float                  # budget-consumption multiple
    long_window_s: float
    short_window_s: float

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"severity {self.severity!r} "
                             f"(use one of {SEVERITIES})")
        if self.burn_rate <= 0:
            raise ValueError("burn_rate must be > 0")
        if not (0 < self.short_window_s <= self.long_window_s):
            raise ValueError("need 0 < short_window_s <= long_window_s")

    def to_dict(self) -> dict:
        return {"name": self.name, "severity": self.severity,
                "burn_rate": self.burn_rate,
                "long_window_s": self.long_window_s,
                "short_window_s": self.short_window_s}

    @classmethod
    def from_dict(cls, d: dict) -> "AlertRule":
        return cls(name=str(d["name"]), severity=str(d["severity"]),
                   burn_rate=float(d["burn_rate"]),
                   long_window_s=float(d["long_window_s"]),
                   short_window_s=float(d["short_window_s"]))


def default_rules(*, long_window_s: float = 3600.0,
                  short_window_s: float = 300.0) -> list:
    """The stock page/warn pair over 1h/5m windows.

    With the default 99% objective: the page rule trips at >= 14.4% bad
    steps sustained across both windows, the warn rule at >= 3%.
    """
    return [
        AlertRule(name="slo_fast_burn", severity="page", burn_rate=14.4,
                  long_window_s=long_window_s,
                  short_window_s=short_window_s),
        AlertRule(name="slo_slow_burn", severity="warn", burn_rate=3.0,
                  long_window_s=long_window_s,
                  short_window_s=short_window_s),
    ]


def parse_rules(text: str) -> list:
    """Parse a JSON list of AlertRule dicts (the ``--alert-rules`` file
    format); raises ``ValueError`` on schema violations."""
    try:
        raw = json.loads(text)
    except json.JSONDecodeError as e:
        raise ValueError(f"alert rules are not valid JSON: {e}") from None
    if not isinstance(raw, list) or not raw:
        raise ValueError("alert rules must be a non-empty JSON list")
    try:
        rules = [AlertRule.from_dict(d) for d in raw]
    except (KeyError, TypeError) as e:
        raise ValueError(f"alert rule missing field: {e}") from None
    names = [r.name for r in rules]
    if len(set(names)) != len(names):
        raise ValueError(f"duplicate alert rule names in {names}")
    return rules


def load_rules(path: str) -> list:
    with open(path) as f:
        return parse_rules(f.read())


class SLOTracker:
    """Sliding-window good/bad step buffer + burn-rate queries.

    ``observe(ts, value_s)`` classifies one step against ``target_s``;
    ``burn_rate(window_s, now)`` is the bad fraction over ``(now -
    window_s, now]`` divided by the error budget. Samples older than
    ``horizon_s`` (set this to the longest rule window) are pruned on
    every observe, so memory is bounded by the window, not the run.
    """

    def __init__(self, target_s: float, *,
                 objective: float = DEFAULT_OBJECTIVE,
                 horizon_s: float = 3600.0, max_samples: int = 100_000):
        if target_s <= 0:
            raise ValueError("SLO target must be > 0 seconds")
        if not (0 < objective < 1):
            raise ValueError("objective must be in (0, 1)")
        self.target_s = float(target_s)
        self.objective = float(objective)
        self.horizon_s = float(horizon_s)
        self._samples: deque = deque(maxlen=max_samples)  # (ts, bad)
        self.total = 0                     # lifetime counters
        self.bad = 0

    @property
    def budget(self) -> float:
        return 1.0 - self.objective

    def observe(self, ts: float, value_s: float) -> bool:
        """Record one step; returns True when it violated the target."""
        bad = float(value_s) > self.target_s
        self._samples.append((float(ts), bad))
        self.total += 1
        self.bad += int(bad)
        cutoff = float(ts) - self.horizon_s
        while self._samples and self._samples[0][0] < cutoff:
            self._samples.popleft()
        return bad

    def bad_fraction(self, window_s: float, now: float) -> float:
        """Bad fraction over ``(now - window_s, now]``; 0.0 when the
        window holds no samples (no data is not an incident)."""
        lo = now - window_s
        n = nbad = 0
        for ts, bad in reversed(self._samples):
            if ts <= lo or ts > now:
                if ts <= lo:
                    break                  # deque is time-ordered
                continue
            n += 1
            nbad += int(bad)
        return nbad / n if n else 0.0

    def burn_rate(self, window_s: float, now: float) -> float:
        return self.bad_fraction(window_s, now) / self.budget

    def to_dict(self, now: float | None = None, windows=()) -> dict:
        d = {"target_s": self.target_s, "objective": self.objective,
             "total": self.total, "bad": self.bad,
             "buffered": len(self._samples)}
        if now is not None:
            d["burn"] = {str(int(w)): self.burn_rate(w, now)
                         for w in windows}
        return d


@dataclass
class AlertState:
    """Live state of one rule: ok | firing, with transition bookkeeping."""
    rule: AlertRule
    state: str = "ok"
    since: float = 0.0                 # ts of the last transition
    burn_long: float = 0.0
    burn_short: float = 0.0
    transitions: int = 0
    meta: dict = field(default_factory=dict)

    @property
    def firing(self) -> bool:
        return self.state == "firing"

    def to_dict(self) -> dict:
        return {"rule": self.rule.name, "severity": self.rule.severity,
                "state": self.state, "since": self.since,
                "burn_long": self.burn_long,
                "burn_short": self.burn_short,
                "threshold": self.rule.burn_rate,
                "long_window_s": self.rule.long_window_s,
                "short_window_s": self.rule.short_window_s,
                "transitions": self.transitions, **self.meta}


class AlertEvaluator:
    """Evaluates a rule set against one ``SLOTracker``.

    ok -> firing when both windows burn past the threshold; firing -> ok
    as soon as the SHORT window drops back under it (fast recovery: the
    long window remembers the incident, the short window proves it
    ended). Returns the states whose ``state`` changed this evaluation.
    """

    def __init__(self, rules=None):
        self.rules = list(rules if rules is not None else default_rules())
        self._states = {r.name: AlertState(rule=r) for r in self.rules}

    @property
    def horizon_s(self) -> float:
        return max((r.long_window_s for r in self.rules), default=3600.0)

    def evaluate(self, tracker: SLOTracker, now: float) -> list:
        changed = []
        for rule in self.rules:
            st = self._states[rule.name]
            st.burn_long = tracker.burn_rate(rule.long_window_s, now)
            st.burn_short = tracker.burn_rate(rule.short_window_s, now)
            should_fire = (st.burn_long >= rule.burn_rate
                           and st.burn_short >= rule.burn_rate)
            if should_fire and st.state == "ok":
                st.state, st.since = "firing", now
                st.transitions += 1
                changed.append(st)
            elif st.state == "firing" \
                    and st.burn_short < rule.burn_rate:
                st.state, st.since = "ok", now
                st.transitions += 1
                changed.append(st)
        return changed

    def states(self) -> list:
        return [self._states[r.name] for r in self.rules]

    def firing(self) -> list:
        return [st for st in self.states() if st.firing]
