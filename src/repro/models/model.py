"""Top-level LM: embeddings, decoder stack, head, loss, decode steps, and
``input_specs`` (ShapeDtypeStruct stand-ins for the dry-run).

Audio/VLM frontends are stubs per the brief: ``input_specs`` provides
precomputed frame/patch embeddings ("prefix") of shape
(B, cfg.frontend_tokens, D); the decoder consumes them as a prefix and the
loss covers token positions only.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import transformer as tf_mod
from repro.models.layers import (
    ParamDef, abstract_tree, axes_tree, cross_entropy, init_tree, rms_norm)
from repro.parallel.sharding import logical_shard

MAX_SMOKE_AUX = 0.01  # aux-loss weight


def model_defs(cfg: ModelConfig) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    defs = {
        "embed": ParamDef((V, D), ("vocab", "embed"), scale=0.02),
        "blocks": tf_mod.stacked_defs(cfg),
        "final_norm": ParamDef((D,), ("embed",), init="ones"),
    }
    if not cfg.tie_embeddings:
        defs["head"] = ParamDef((D, V), ("embed", "vocab"), scale=0.02)
    if cfg.frontend != "none":
        # learned projection applied to the (stubbed) frontend embeddings
        defs["frontend_proj"] = ParamDef((D, D), ("embed", "embed"))
    return defs


def init_params(cfg: ModelConfig, key):
    return init_tree(model_defs(cfg), key, jnp.dtype(cfg.dtype))


def abstract_params(cfg: ModelConfig):
    return abstract_tree(model_defs(cfg), jnp.dtype(cfg.dtype))


def param_axes(cfg: ModelConfig):
    return axes_tree(model_defs(cfg))


def _head(cfg, params, h):
    w = params["embed"].T if cfg.tie_embeddings else params["head"]
    return h @ w


def _embed_inputs(cfg, params, batch):
    """Token (+ prefix) embedding. Returns (x, pos, n_prefix)."""
    x = params["embed"][batch["tokens"]]
    x = x * (cfg.d_model ** 0.5)
    n_prefix = 0
    if cfg.frontend != "none":
        prefix = batch["prefix"].astype(x.dtype) @ params["frontend_proj"]
        x = jnp.concatenate([prefix, x], axis=1)
        n_prefix = prefix.shape[1]
    x = logical_shard(x, "batch", "seq", "embed")
    pos = jnp.arange(x.shape[1])
    return x, pos, n_prefix


def forward(cfg: ModelConfig, params, batch, remat: bool = True,
            remat_policy: str = "full"):
    """Full-sequence forward. Returns (hidden (B, S, D), aux_loss, n_prefix)."""
    x, pos, n_prefix = _embed_inputs(cfg, params, batch)
    x, aux = tf_mod.stack_fwd(cfg, params["blocks"], x, pos, remat=remat,
                              remat_policy=remat_policy)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    return x, aux, n_prefix


def loss_fn(cfg: ModelConfig, params, batch, remat: bool = True,
            loss_chunk: int = 0, remat_policy: str = "full"):
    """Mean next-token CE (+ MoE aux). ``loss_chunk`` > 0 computes logits
    in sequence chunks to avoid materializing (B, S, V)."""
    h, aux, n_prefix = forward(cfg, params, batch, remat=remat,
                               remat_policy=remat_policy)
    if n_prefix:
        h = h[:, n_prefix:]
    labels = batch["labels"]
    if loss_chunk and h.shape[1] % loss_chunk == 0 and h.shape[1] > loss_chunk:
        n = h.shape[1] // loss_chunk
        hc = h.reshape(h.shape[0], n, loss_chunk, -1).swapaxes(0, 1)
        lc = labels.reshape(labels.shape[0], n, loss_chunk).swapaxes(0, 1)

        def body(tot, inp):
            hb, lb = inp
            return tot + cross_entropy(_head(cfg, params, hb), lb), None

        tot, _ = jax.lax.scan(body, jnp.zeros((), jnp.float32), (hc, lc))
        ce = tot / n
    else:
        logits = _head(cfg, params, h)
        logits = logical_shard(logits, "batch", "seq", "vocab")
        ce = cross_entropy(logits, labels)
    return ce + MAX_SMOKE_AUX * aux, {"ce": ce, "aux": aux}


# ------------------------------------------------------------- decoding

def cache_len_for(cfg: ModelConfig, seq_len: int) -> int:
    if cfg.sliding_window:
        return min(cfg.sliding_window, seq_len)
    return seq_len


def init_cache(cfg: ModelConfig, batch: int, seq_len: int):
    return tf_mod.init_stacked_cache(
        cfg, batch, cache_len_for(cfg, seq_len), jnp.dtype(cfg.dtype))


def cache_specs(cfg: ModelConfig, batch: int, seq_len: int):
    return tf_mod.stacked_cache_specs(
        cfg, batch, cache_len_for(cfg, seq_len), jnp.dtype(cfg.dtype))


def cache_axes(cfg: ModelConfig):
    return tf_mod.cache_axes(cfg)


def decode_step(cfg: ModelConfig, params, cache, tokens, pos):
    """One decode step. tokens: (B, 1) int32; pos: scalar absolute position.
    Returns (logits (B, 1, V), new_cache)."""
    x = params["embed"][tokens] * (cfg.d_model ** 0.5)
    x = logical_shard(x, "batch", None, "embed")
    x, new_cache = tf_mod.stack_decode(cfg, params["blocks"], cache, x, pos)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = _head(cfg, params, x)
    return logits, new_cache


def prefill_step(cfg: ModelConfig, params, batch):
    """Inference prefill: full forward, returns last-position logits."""
    h, _, _ = forward(cfg, params, batch, remat=False)
    return _head(cfg, params, h[:, -1:])


# ----------------------------------------------------------- input specs

def input_specs(cfg: ModelConfig, shape: InputShape) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this shape
    (weak-type-correct, shardable, no device allocation)."""
    B, S = shape.global_batch, shape.seq_len
    i32 = jnp.dtype("int32")
    dt = jnp.dtype(cfg.dtype)
    if shape.kind == "decode":
        return {"tokens": jax.ShapeDtypeStruct((B, 1), i32)}
    n_tok = S - (cfg.frontend_tokens if cfg.frontend != "none" else 0)
    specs = {"tokens": jax.ShapeDtypeStruct((B, n_tok), i32)}
    if cfg.frontend != "none":
        specs["prefix"] = jax.ShapeDtypeStruct(
            (B, cfg.frontend_tokens, cfg.d_model), dt)
    if shape.kind == "train":
        specs["labels"] = jax.ShapeDtypeStruct((B, n_tok), i32)
    return specs
