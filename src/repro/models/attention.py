"""GQA attention: chunked-causal (flash-style online softmax in pure jnp,
mirrored by kernels/flash_attention.py for TPU), sliding-window variant,
and single-token decode against a KV cache.

Shapes: q (B, S, H, hd); k/v (B, S, KV, hd). GQA groups G = H // KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, rotary
from repro.parallel.sharding import logical_shard

NEG_INF = -1e30
Q_CHUNK = 1024


def attn_defs(cfg) -> dict:
    D, hd = cfg.d_model, cfg.resolved_head_dim
    H, KV = cfg.num_heads, cfg.num_kv_heads
    defs = {
        "wq": ParamDef((D, H * hd), ("embed", "q_heads")),
        "wk": ParamDef((D, KV * hd), ("embed", "kv_heads")),
        "wv": ParamDef((D, KV * hd), ("embed", "kv_heads")),
        "wo": ParamDef((H * hd, D), ("q_heads", "embed")),
    }
    if cfg.qkv_bias:
        defs["bq"] = ParamDef((H * hd,), ("q_heads",), init="zeros")
        defs["bk"] = ParamDef((KV * hd,), ("kv_heads",), init="zeros")
        defs["bv"] = ParamDef((KV * hd,), ("kv_heads",), init="zeros")
    return defs


def _project_qkv(cfg, p, x, pos):
    B, S, _ = x.shape
    hd = cfg.resolved_head_dim
    q = x @ p["wq"]
    k = x @ p["wk"]
    v = x @ p["wv"]
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, cfg.num_heads, hd)
    k = k.reshape(B, S, cfg.num_kv_heads, hd)
    v = v.reshape(B, S, cfg.num_kv_heads, hd)
    q = rotary(q, pos, cfg.rope_theta)
    k = rotary(k, pos, cfg.rope_theta)
    q = logical_shard(q, "batch", "seq", "q_heads", None)
    k = logical_shard(k, "batch", "seq", "kv_heads", None)
    v = logical_shard(v, "batch", "seq", "kv_heads", None)
    return q, k, v


def _sdpa_chunk(q, k, v, mask):
    """q: (B, qc, KV, G, hd); k/v: (B, S, KV, hd); mask: (qc, S)."""
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bqkgh,bskh->bkgqs", q, k) * scale
    s = jnp.where(mask[None, None, None], s.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(v.dtype)
    return jnp.einsum("bkgqs,bskh->bqkgh", w, v)


def attention(cfg, p, x, pos):
    """Full (or sliding-window) causal self-attention for train/prefill.

    Scans over query chunks so the (qc, S) score tile is the only softmax
    temp — the pure-jnp analogue of the Pallas flash kernel.
    Returns (out (B,S,D), (k, v) for cache use).
    """
    B, S, _ = x.shape
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = H // KV
    q, k, v = _project_qkv(cfg, p, x, pos)
    if cfg.attn_impl == "pallas":
        # Pallas flash kernel path (TPU target; interpret=True on CPU).
        from repro.kernels.ops import gqa_flash_attention
        o = gqa_flash_attention(
            q, k, v, causal=True, window=cfg.sliding_window,
            block_q=min(128, S), block_k=min(128, S))
        out = o.reshape(B, S, H * hd)
        out = logical_shard(out, "batch", "seq", "q_heads")
        return out @ p["wo"], (k, v)
    qg = q.reshape(B, S, KV, G, hd)

    qc = min(cfg.attn_chunk or Q_CHUNK, S)
    assert S % qc == 0
    n_chunks = S // qc
    kpos = jnp.asarray(pos)

    def body(carry, inputs):
        i, q_blk = inputs
        qpos = i * qc + jnp.arange(qc)
        causal = kpos[None, :] <= qpos[:, None]
        if cfg.sliding_window:
            causal &= kpos[None, :] > qpos[:, None] - cfg.sliding_window
        o = _sdpa_chunk(q_blk, k, v, causal)
        return carry, o

    q_blocks = qg.reshape(B, n_chunks, qc, KV, G, hd).transpose(1, 0, 2, 3, 4, 5)
    _, outs = jax.lax.scan(body, None, (jnp.arange(n_chunks), q_blocks))
    out = outs.transpose(1, 0, 2, 3, 4, 5).reshape(B, S, H * hd)
    out = logical_shard(out, "batch", "seq", "q_heads")
    return out @ p["wo"], (k, v)


def init_kv_cache(cfg, batch: int, cache_len: int, dtype):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    shape = (batch, cache_len, KV, hd)
    return {
        "k": jnp.zeros(shape, dtype),
        "v": jnp.zeros(shape, dtype),
    }


def kv_cache_specs(cfg, batch: int, cache_len: int, dtype):
    KV, hd = cfg.num_kv_heads, cfg.resolved_head_dim
    s = jax.ShapeDtypeStruct((batch, cache_len, KV, hd), dtype)
    return {"k": s, "v": s}


KV_CACHE_AXES = ("batch", "cache_seq", "kv_heads", None)


def decode_attention(cfg, p, x, cache, pos):
    """One-token decode. x: (B, 1, D); cache k/v: (B, Sc, KV, hd) ring buffer
    (ring only engages when sliding_window > 0). ``pos``: scalar absolute
    position of the new token. Returns (out, new_cache)."""
    B = x.shape[0]
    H, KV, hd = cfg.num_heads, cfg.num_kv_heads, cfg.resolved_head_dim
    G = H // KV
    q, k, v = _project_qkv(cfg, p, x, jnp.asarray(pos)[None])
    cache_len = cache["k"].shape[1]
    slot = pos % cache_len if cfg.sliding_window else pos
    new_k = jax.lax.dynamic_update_slice_in_dim(cache["k"], k, slot, axis=1)
    new_v = jax.lax.dynamic_update_slice_in_dim(cache["v"], v, slot, axis=1)
    new_k = logical_shard(new_k, *KV_CACHE_AXES)
    new_v = logical_shard(new_v, *KV_CACHE_AXES)

    idx = jnp.arange(cache_len)
    valid = idx <= slot if not cfg.sliding_window else (
        (idx <= slot) | (pos >= cache_len))
    qg = q.reshape(B, 1, KV, G, hd)
    scale = hd ** -0.5
    s = jnp.einsum("bqkgh,bskh->bkgqs", qg, new_k) * scale
    s = jnp.where(valid[None, None, None, None], s.astype(jnp.float32), NEG_INF)
    w = jax.nn.softmax(s, axis=-1).astype(new_v.dtype)
    o = jnp.einsum("bkgqs,bskh->bqkgh", w, new_v).reshape(B, 1, H * hd)
    return o @ p["wo"], {"k": new_k, "v": new_v}
