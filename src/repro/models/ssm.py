"""Mamba-2 block (SSD — state-space duality, arXiv:2405.21060).

Training/prefill uses the chunked SSD algorithm: intra-chunk quadratic
(attention-like) term + inter-chunk linear recurrence, scanned over chunks
(pure jnp here; kernels/ssd_scan.py is the Pallas TPU mirror of the chunk
kernel). Decode is the O(1) recurrent state update.

State layout: h (B, nheads, head_dim, d_state); conv ring (B, K-1, conv_ch).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef, rms_norm
from repro.parallel.sharding import logical_shard


def ssm_defs(cfg) -> dict:
    D = cfg.d_model
    di, ds, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_nheads
    g = cfg.ssm_ngroups
    conv_ch = di + 2 * g * ds
    in_dim = 2 * di + 2 * g * ds + nh
    return {
        "in_proj": ParamDef((D, in_dim), ("embed", "ssm_inner")),
        "conv_w": ParamDef((cfg.ssm_conv, conv_ch), (None, "ssm_inner")),
        "conv_b": ParamDef((conv_ch,), ("ssm_inner",), init="zeros"),
        "A_log": ParamDef((nh,), ("ssm_heads",), init="zeros"),
        "dt_bias": ParamDef((nh,), ("ssm_heads",), init="zeros"),
        "D_skip": ParamDef((nh,), ("ssm_heads",), init="ones"),
        "norm": ParamDef((di,), ("ssm_inner",), init="ones"),
        "out_proj": ParamDef((di, D), ("ssm_inner", "embed")),
    }


def _split_proj(cfg, zxbcdt):
    di, ds, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    z, xbc, dt = jnp.split(zxbcdt, [di, 2 * di + 2 * g * ds], axis=-1)
    return z, xbc, dt


def _causal_conv(xbc, w, b):
    """Depthwise causal conv, kernel K. xbc: (B, S, C); w: (K, C)."""
    K = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(pad[:, i:i + xbc.shape[1], :] * w[i] for i in range(K))
    return jax.nn.silu(out + b)


def _segsum_decay(a):
    """a: (..., Q) per-step log-decays -> (..., Q, Q) lower-tri decay matrix
    L[i, j] = exp(sum_{j < t <= i} a_t) for j <= i else 0."""
    Q = a.shape[-1]
    cum = jnp.cumsum(a, axis=-1)
    diff = cum[..., :, None] - cum[..., None, :]       # (..., i, j)
    mask = jnp.tril(jnp.ones((Q, Q), bool))
    return jnp.where(mask, jnp.exp(diff), 0.0)


def ssd_chunked(x, dt, A, Bm, Cm, chunk: int):
    """Chunked SSD scan.

    x:  (B, S, nh, hd)      inputs (post-conv)
    dt: (B, S, nh)          softplus'd step sizes
    A:  (nh,)               negative decay rates
    Bm: (B, S, nh, ds)      input gates (groups already broadcast to heads)
    Cm: (B, S, nh, ds)      output gates
    Returns y: (B, S, nh, hd).
    """
    Bb, S, nh, hd = x.shape
    ds = Bm.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    def reshape_c(t):
        return t.reshape(Bb, nc, Q, *t.shape[2:]).swapaxes(0, 1)

    xc, dtc, Bc, Cc = map(reshape_c, (x, dt, Bm, Cm))   # (nc, B, Q, ...)

    def chunk_body(h, inp):
        xq, dtq, Bq, Cq = inp                            # (B, Q, ...)
        a = (dtq * A).astype(jnp.float32)                # (B, Q, nh)
        a_h = a.swapaxes(1, 2)                           # (B, nh, Q)
        L = _segsum_decay(a_h)                           # (B, nh, Q, Q)
        cum = jnp.cumsum(a_h, axis=-1)                   # (B, nh, Q)
        total = jnp.exp(cum[..., -1])                    # (B, nh)
        xdt = xq * dtq[..., None]                        # (B, Q, nh, hd)

        # intra-chunk: (C B^T ⊙ L) @ (x·dt)
        scores = jnp.einsum("bqhs,bkhs->bhqk", Cq, Bq).astype(jnp.float32)
        y_intra = jnp.einsum("bhqk,bkhd->bqhd", scores * L, xdt.astype(jnp.float32))

        # inter-chunk: contribution of the carried state
        decay_in = jnp.exp(cum).swapaxes(1, 2)           # (B, Q, nh)
        y_inter = jnp.einsum(
            "bqhs,bhds->bqhd", Cq.astype(jnp.float32), h) * decay_in[..., None]

        # state update: h' = h * exp(sum a) + Σ_j exp(cum_Q - cum_j) dt_j x_j B_j
        decay_out = jnp.exp(cum[..., -1:] - cum).swapaxes(1, 2)  # (B, Q, nh)
        h_new = h * total[..., None, None] + jnp.einsum(
            "bqhd,bqhs->bhds", (xdt * decay_out[..., None]).astype(jnp.float32),
            Bq.astype(jnp.float32))
        return h_new, (y_intra + y_inter).astype(x.dtype)

    h0 = jnp.zeros((Bb, nh, hd, ds), jnp.float32)
    h_final, yc = jax.lax.scan(chunk_body, h0, (xc, dtc, Bc, Cc))
    y = yc.swapaxes(0, 1).reshape(Bb, S, nh, hd)
    return y, h_final


def mamba_fwd(cfg, p, u):
    """Full-sequence Mamba-2 mixer. u: (B, S, D) -> (y, final_state)."""
    B, S, D = u.shape
    di, ds, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    hd = cfg.ssm_head_dim
    z, xbc, dt = _split_proj(cfg, u @ p["in_proj"])
    xbc = _causal_conv(xbc, p["conv_w"], p["conv_b"])
    x, Bm, Cm = jnp.split(xbc, [di, di + g * ds], axis=-1)
    x = x.reshape(B, S, nh, hd)
    x = logical_shard(x, "batch", "seq", "ssm_heads", None)
    rep = nh // g
    Bm = jnp.repeat(Bm.reshape(B, S, g, ds), rep, axis=2)
    Cm = jnp.repeat(Cm.reshape(B, S, g, ds), rep, axis=2)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    y, h = ssd_chunked(x, dt, A, Bm, Cm, cfg.ssm_chunk)
    y = y + x * p["D_skip"][None, None, :, None]
    y = y.reshape(B, S, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    return y @ p["out_proj"], h


def init_ssm_cache(cfg, batch: int, dtype):
    di, ds, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    conv_ch = di + 2 * g * ds
    return {
        "h": jnp.zeros((batch, nh, cfg.ssm_head_dim, ds), jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


def ssm_cache_specs(cfg, batch: int, dtype):
    di, ds, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    conv_ch = di + 2 * g * ds
    return {
        "h": jax.ShapeDtypeStruct((batch, nh, cfg.ssm_head_dim, ds), jnp.float32),
        "conv": jax.ShapeDtypeStruct((batch, cfg.ssm_conv - 1, conv_ch), dtype),
    }


SSM_CACHE_AXES = {"h": ("batch", "ssm_heads", None, None),
                  "conv": ("batch", None, "ssm_inner")}


def mamba_decode(cfg, p, u, cache):
    """One-token recurrent step. u: (B, 1, D)."""
    B = u.shape[0]
    di, ds, g, nh = cfg.d_inner, cfg.ssm_state, cfg.ssm_ngroups, cfg.ssm_nheads
    hd = cfg.ssm_head_dim
    z, xbc, dt = _split_proj(cfg, u[:, 0] @ p["in_proj"])       # (B, ...)
    window = jnp.concatenate([cache["conv"], xbc[:, None]], axis=1)  # (B, K, C)
    conv_out = jax.nn.silu(
        jnp.einsum("bkc,kc->bc", window, p["conv_w"]) + p["conv_b"])
    new_conv = window[:, 1:]
    x, Bm, Cm = jnp.split(conv_out, [di, di + g * ds], axis=-1)
    x = x.reshape(B, nh, hd)
    rep = nh // g
    Bm = jnp.repeat(Bm.reshape(B, g, ds), rep, axis=1)          # (B, nh, ds)
    Cm = jnp.repeat(Cm.reshape(B, g, ds), rep, axis=1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B, nh)
    A = -jnp.exp(p["A_log"].astype(jnp.float32))
    dA = jnp.exp(dt * A)                                        # (B, nh)
    h = cache["h"] * dA[..., None, None] + jnp.einsum(
        "bhd,bhs->bhds", (x * dt[..., None]).astype(jnp.float32),
        Bm.astype(jnp.float32))
    y = jnp.einsum("bhs,bhds->bhd", Cm.astype(jnp.float32), h)
    y = y.astype(u.dtype) + x * p["D_skip"][None, :, None]
    y = y.reshape(B, di)
    y = rms_norm(y * jax.nn.silu(z), p["norm"], cfg.norm_eps)
    out = (y @ p["out_proj"])[:, None]
    return out, {"h": h, "conv": new_conv}
