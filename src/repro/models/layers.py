"""Parameter definitions and basic layers (pure-functional JAX).

Parameters live in nested dicts. Every leaf is declared via ``ParamDef``
(shape + logical sharding axes + initializer), so a single definition tree
yields: materialized params, abstract ShapeDtypeStructs (dry-run), and the
logical-axes tree used by the sharding rules.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.parallel.sharding import logical_shard


@dataclass(frozen=True)
class ParamDef:
    shape: tuple
    axes: tuple                      # logical axis name (or None) per dim
    init: str = "normal"             # normal | zeros | ones
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def _materialize(d: ParamDef, key, dtype):
    if d.init == "zeros":
        return jnp.zeros(d.shape, dtype)
    if d.init == "ones":
        return jnp.ones(d.shape, dtype)
    return (jax.random.normal(key, d.shape, jnp.float32) * d.scale).astype(dtype)


def init_tree(defs, key, dtype):
    """Materialize a nested dict of ParamDefs."""
    flat, treedef = jax.tree.flatten(defs, is_leaf=lambda x: isinstance(x, ParamDef))
    keys = jax.random.split(key, len(flat))
    return jax.tree.unflatten(
        treedef, [_materialize(d, k, dtype) for d, k in zip(flat, keys, strict=True)])


def abstract_tree(defs, dtype):
    """ShapeDtypeStructs for a nested dict of ParamDefs (no allocation)."""
    return jax.tree.map(
        lambda d: jax.ShapeDtypeStruct(d.shape, dtype), defs,
        is_leaf=lambda x: isinstance(x, ParamDef))


def axes_tree(defs):
    return jax.tree.map(
        lambda d: d.axes, defs, is_leaf=lambda x: isinstance(x, ParamDef))


def stack_defs(defs, n: int, axis_name: str = "layers"):
    """Prepend a stacking dimension (for scan-over-layers params)."""
    return jax.tree.map(
        lambda d: ParamDef((n, *d.shape), (axis_name, *d.axes), d.init, d.scale),
        defs, is_leaf=lambda x: isinstance(x, ParamDef))


# ---------------------------------------------------------------- layers

def rms_norm(x, gamma, eps: float):
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * gamma.astype(jnp.float32)).astype(dt)


def dense(x, w, b=None):
    y = x @ w
    if b is not None:
        y = y + b
    return y


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def mlp_defs(d_model: int, d_ff: int) -> dict:
    return {
        "w_gate": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "w_up": ParamDef((d_model, d_ff), ("embed", "mlp")),
        "w_down": ParamDef((d_ff, d_model), ("mlp", "embed")),
    }


def mlp_fwd(p, x):
    h = jax.nn.silu(x @ p["w_gate"]) * (x @ p["w_up"])
    h = logical_shard(h, "batch", "seq", "mlp")
    return h @ p["w_down"]


def rotary(x, pos, theta: float):
    """Apply rotary embedding. x: (..., S, H, hd); pos: (S,) or scalar."""
    hd = x.shape[-1]
    half = hd // 2
    freqs = jnp.arange(half, dtype=jnp.float32)
    inv = theta ** (-freqs / half)
    angles = jnp.asarray(pos, jnp.float32)[..., None] * inv     # (S, half)
    cos = jnp.cos(angles)[..., None, :]                          # (S, 1, half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def cross_entropy(logits, labels):
    """Mean next-token CE. logits: (B, S, V) float; labels: (B, S) int."""
    logits = logits.astype(jnp.float32)
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, labels[..., None], axis=-1)[..., 0]
    return jnp.mean(logz - gold)
