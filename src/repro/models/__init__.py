from repro.models.model import (  # noqa: F401
    init_params, param_axes, forward, loss_fn, init_cache,
    prefill_step, decode_step, input_specs, abstract_params)
