"""Decoder stack: repeating layer *periods* (cfg.pattern) scanned with
``jax.lax.scan`` so HLO size is O(period), not O(depth) — required for the
61-layer Kimi config under a CPU compile budget and the right production
choice regardless.

Each layer = mixer ('A' attention / 'M' mamba) + optional FFN
(dense SwiGLU or MoE per cfg.moe_every).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.layers import (
    ParamDef, mlp_defs, mlp_fwd, rms_norm, stack_defs)
from repro.parallel.sharding import logical_shard


def _layer_is_moe(cfg, j: int) -> bool:
    return (cfg.num_experts > 0 and cfg.d_ff > 0
            and j % cfg.moe_every == cfg.moe_every - 1)


def layer_defs(cfg, j: int, ch: str) -> dict:
    D = cfg.d_model
    defs = {"norm1": ParamDef((D,), ("embed",), init="ones")}
    if ch == "A":
        defs["mixer"] = attn.attn_defs(cfg)
    else:
        defs["mixer"] = ssm_mod.ssm_defs(cfg)
    if cfg.d_ff > 0:
        defs["norm2"] = ParamDef((D,), ("embed",), init="ones")
        if _layer_is_moe(cfg, j):
            defs["ffn"] = moe_mod.moe_defs(cfg)
        else:
            defs["ffn"] = mlp_defs(D, cfg.d_ff)
    return defs


def period_defs(cfg) -> dict:
    return {f"layer{j}": layer_defs(cfg, j, ch)
            for j, ch in enumerate(cfg.pattern)}


def stacked_defs(cfg) -> dict:
    return stack_defs(period_defs(cfg), cfg.num_periods)


# --------------------------------------------------------------- forward

def _layer_fwd(cfg, lp, x, pos, j: int, ch: str):
    """Full-sequence layer. Returns (x, aux_loss)."""
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if ch == "A":
        mix, _ = attn.attention(cfg, lp["mixer"], h, pos)
    else:
        mix, _ = ssm_mod.mamba_fwd(cfg, lp["mixer"], h)
    x = x + mix
    aux = jnp.zeros((), jnp.float32)
    if cfg.d_ff > 0:
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if _layer_is_moe(cfg, j):
            y, aux = moe_mod.moe_fwd(cfg, lp["ffn"], h)
        else:
            y = mlp_fwd(lp["ffn"], h)
        x = x + y
    return logical_shard(x, "batch", "seq", "embed"), aux


def period_fwd(cfg, rules_fp, pparams, x, pos):
    """``rules_fp`` is the static fingerprint of the active sharding rules
    (see parallel.sharding.rules_fingerprint) — it keeps jax.checkpoint's
    trace cache honest when the same config is lowered under different
    rules in one process."""
    del rules_fp
    aux = jnp.zeros((), jnp.float32)
    for j, ch in enumerate(cfg.pattern):
        x, a = _layer_fwd(cfg, pparams[f"layer{j}"], x, pos, j, ch)
        aux = aux + a
    return x, aux


REMAT_POLICIES = {
    "full": None,   # save only the scan carry (recompute everything)
    "dots": "dots_with_no_batch_dims_saveable",
    "none": "everything_saveable",
}


def stack_fwd(cfg, stacked, x, pos, remat: bool = True,
              remat_policy: str = "full"):
    """x: (B, S, D) -> (x, total_aux). ``stacked``: period params with a
    leading num_periods dim. ``remat_policy`` picks what the checkpoint
    saves (a §Perf lever: recompute-vs-HBM-traffic trade)."""
    from repro.parallel.sharding import rules_fingerprint
    fp = rules_fingerprint()
    fn = period_fwd
    if remat:
        pol_name = REMAT_POLICIES.get(remat_policy)
        policy = getattr(jax.checkpoint_policies, pol_name) \
            if pol_name else None
        fn = jax.checkpoint(period_fwd, static_argnums=(0, 1),
                            policy=policy)

    def body(carry, pparams):
        x, aux = carry
        x, a = fn(cfg, fp, pparams, x, pos)
        return (x, aux + a), None

    (x, aux), _ = jax.lax.scan(body, (x, jnp.zeros((), jnp.float32)), stacked)
    return x, aux


# --------------------------------------------------------------- decode

def layer_cache_specs(cfg, j: int, ch: str, batch: int, cache_len: int, dtype):
    if ch == "A":
        return attn.kv_cache_specs(cfg, batch, cache_len, dtype)
    return ssm_mod.ssm_cache_specs(cfg, batch, dtype)


def period_cache_specs(cfg, batch: int, cache_len: int, dtype):
    return {f"layer{j}": layer_cache_specs(cfg, j, ch, batch, cache_len, dtype)
            for j, ch in enumerate(cfg.pattern)}


def stacked_cache_specs(cfg, batch: int, cache_len: int, dtype):
    per = period_cache_specs(cfg, batch, cache_len, dtype)
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct((cfg.num_periods, *s.shape), s.dtype), per)


def init_stacked_cache(cfg, batch: int, cache_len: int, dtype):
    return jax.tree.map(lambda s: jnp.zeros(s.shape, s.dtype),
                        stacked_cache_specs(cfg, batch, cache_len, dtype))


def cache_axes(cfg):
    axes = {}
    for j, ch in enumerate(cfg.pattern):
        if ch == "A":
            axes[f"layer{j}"] = {"k": attn.KV_CACHE_AXES, "v": attn.KV_CACHE_AXES}
        else:
            axes[f"layer{j}"] = dict(ssm_mod.SSM_CACHE_AXES)
    return jax.tree.map(lambda a: ("layers", *a), axes,
                        is_leaf=lambda x: isinstance(x, tuple))


def _layer_decode(cfg, lp, lcache, x, pos, j: int, ch: str):
    h = rms_norm(x, lp["norm1"], cfg.norm_eps)
    if ch == "A":
        mix, new_cache = attn.decode_attention(cfg, lp["mixer"], h, lcache, pos)
    else:
        mix, new_cache = ssm_mod.mamba_decode(cfg, lp["mixer"], h, lcache)
    x = x + mix
    if cfg.d_ff > 0:
        h = rms_norm(x, lp["norm2"], cfg.norm_eps)
        if _layer_is_moe(cfg, j):
            y, _ = moe_mod.moe_fwd(cfg, lp["ffn"], h)
        else:
            y = mlp_fwd(lp["ffn"], h)
        x = x + y
    return x, new_cache


def period_decode(cfg, pparams, pcache, x, pos):
    new = {}
    for j, ch in enumerate(cfg.pattern):
        x, new[f"layer{j}"] = _layer_decode(
            cfg, pparams[f"layer{j}"], pcache[f"layer{j}"], x, pos, j, ch)
    return x, new


def stack_decode(cfg, stacked, cache, x, pos):
    def body(x, inp):
        pparams, pcache = inp
        x, new_pcache = period_decode(cfg, pparams, pcache, x, pos)
        return x, new_pcache

    x, new_cache = jax.lax.scan(body, x, (stacked, cache))
    return x, new_cache
