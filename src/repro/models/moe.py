"""Top-k MoE with grouped, gather/scatter-based capacity dispatch.

Tokens are reshaped to (G, T/G, D) where G tracks the data-parallel shard
count; routing + capacity ranking happen per group (local under SPMD), and
the (g, e, c, d) -> (e, g, c, d) transpose before the expert matmuls is the
canonical GSPMD all-to-all. No (T, E, C) one-hot tensor is ever
materialized — dispatch/combine are integer gathers/scatters, so HLO FLOPs
stay close to the active-expert compute (keeps the roofline "useful ratio"
honest at kimi-k2 scale).

Includes the Switch-style auxiliary load-balance loss.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import ParamDef
from repro.parallel.sharding import current_rules, logical_shard


def moe_defs(cfg) -> dict:
    D, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    return {
        "router": ParamDef((D, E), ("embed", "experts")),
        "w_gate": ParamDef((E, D, F), ("experts", "expert_embed", "mlp")),
        "w_up": ParamDef((E, D, F), ("experts", "expert_embed", "mlp")),
        "w_down": ParamDef((E, F, D), ("experts", "mlp", "expert_embed")),
    }


def capacity(cfg, tokens_per_group: int) -> int:
    c = int(cfg.capacity_factor * cfg.experts_per_token * tokens_per_group
            / cfg.num_experts)
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


def num_groups(total_tokens: int) -> int:
    """Dispatch group count = data-parallel shard count when divisible."""
    r = current_rules()
    if r is None or r.mesh is None:
        return 1
    ax = r.mesh_axes("batch")
    if ax is None:
        return 1
    g = r.axis_size(ax)
    return g if total_tokens % g == 0 else 1


def _rank_within_expert(e_idx, E: int):
    """Capacity rank per (token, k) assignment inside one group.

    e_idx: (T, K) int32. Returns pos: (T, K) — the k-major arrival rank of
    each assignment at its expert. Memory: one (T, E) int32 temp per k-slot.
    """
    T, K = e_idx.shape

    def body(base, ek):
        oh = jax.nn.one_hot(ek, E, dtype=jnp.int32)           # (T, E)
        excl = jnp.cumsum(oh, axis=0) - oh                     # exclusive
        pos_k = jnp.take_along_axis(excl + base[None], ek[:, None], axis=1)[:, 0]
        return base + oh.sum(0), pos_k

    base0 = jnp.zeros((E,), jnp.int32)
    _, pos = jax.lax.scan(body, base0, e_idx.T)                # (K, T)
    return pos.T


def moe_fwd(cfg, p, x):
    """x: (B, S, D) -> (y, aux_loss)."""
    B, S, D = x.shape
    E, K = cfg.num_experts, cfg.experts_per_token
    T = B * S
    G = num_groups(T)
    Tg = T // G
    C = capacity(cfg, Tg)
    xt = x.reshape(G, Tg, D)
    xt = logical_shard(xt, "batch", None, "embed")

    logits = (xt @ p["router"]).astype(jnp.float32)            # (G, Tg, E)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, e_idx = jax.lax.top_k(probs, K)                 # (G, Tg, K)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    density = jnp.mean(
        jax.nn.one_hot(e_idx[..., 0], E, dtype=jnp.float32), axis=(0, 1))
    aux = E * jnp.sum(density * jnp.mean(probs, axis=(0, 1)))

    pos = jax.vmap(_rank_within_expert, in_axes=(0, None))(e_idx, E)  # (G,Tg,K)
    keep = pos < C
    gate_vals = jnp.where(keep, gate_vals, 0.0)
    safe_pos = jnp.where(keep, pos, C)                         # C drops on scatter

    token_id = jnp.broadcast_to(jnp.arange(Tg)[:, None], (Tg, K))

    def build_idx(eidx_g, pos_g):
        idx = jnp.zeros((E, C), jnp.int32)
        filled = jnp.zeros((E, C), x.dtype)
        idx = idx.at[eidx_g, pos_g].set(token_id, mode="drop")
        filled = filled.at[eidx_g, pos_g].set(1.0, mode="drop")
        return idx, filled

    idx, filled = jax.vmap(build_idx)(e_idx, safe_pos)         # (G, E, C)

    # dispatch: gather token embeddings into expert slots
    xe = jnp.take_along_axis(
        xt[:, :, None, :],                                     # (G, Tg, 1, D)
        idx.reshape(G, E * C)[:, :, None, None], axis=1, mode="clip"
    ).reshape(G, E, C, D) * filled[..., None]
    xe = jnp.swapaxes(xe, 0, 1)                                # (E, G, C, D) — a2a
    xe = logical_shard(xe, "experts", "batch", None, "expert_embed")

    h = jax.nn.silu(jnp.einsum("egcd,edf->egcf", xe, p["w_gate"])) \
        * jnp.einsum("egcd,edf->egcf", xe, p["w_up"])
    h = logical_shard(h, "experts", "batch", None, "mlp")
    ye = jnp.einsum("egcf,efd->egcd", h, p["w_down"])          # (E, G, C, D)
    ye = jnp.swapaxes(ye, 0, 1)                                # (G, E, C, D) — a2a
    ye = logical_shard(ye, "batch", "experts", None, "expert_embed")

    if cfg.moe_combine == "scatter":
        # combine on the EXPERT side: weight each slot's output by its
        # token's gate and scatter-add into (G, Tg, D). Under SPMD the
        # expert axis stays local and only the (Tg, D) partial sums cross
        # the mesh (an all-reduce) — instead of all-gathering the full
        # (E, C, D) expert outputs for the token-side gather (§Perf o5).
        gate_slot = jnp.zeros((G, E, C), jnp.float32)
        gate_slot = jax.vmap(
            lambda gs, ei, sp, gv: gs.at[ei, sp].add(gv, mode="drop"))(
            gate_slot, e_idx, safe_pos, gate_vals)
        weighted = ye * gate_slot[..., None].astype(x.dtype)   # (G,E,C,D)

        def scat(idx_g, w_g):
            return jnp.zeros((Tg, D), x.dtype).at[
                idx_g.reshape(E * C)].add(w_g.reshape(E * C, D))
        y = jax.vmap(scat)(idx, weighted)                      # (G, Tg, D)
    else:
        # combine: gather each assignment's expert output, weight, sum
        # over K. Dropped assignments have slot == E*C (out of bounds):
        # clip-gather junk, their gate weight is already zeroed.
        flat_slot = e_idx * C + safe_pos                       # (G, Tg, K)
        yk = jnp.take_along_axis(
            ye.reshape(G, E * C, 1, D),
            flat_slot.reshape(G, Tg * K)[:, :, None, None], axis=1,
            mode="clip").reshape(G, Tg, K, D)
        y = jnp.sum(yk * gate_vals[..., None].astype(x.dtype), axis=2)
    y = logical_shard(y, "batch", None, "embed")
    return y.reshape(B, S, D), aux
