"""Replay executor: run a compiled TaskGraph on the *actual* cluster and
emit telemetry.

On real hardware this role is played by the instrumented launchers
(``launch.train --telemetry-dir``, ``launch.serve --observe``); here the
"actual cluster" is a ``Topology`` whose true parameters (utilization,
link efficiency, latency) may differ from the nominal one the plan was
searched under — the perturbed-cluster scenario of the feedback
benchmark. Each execution walks the simulated schedule on the TRUE
topology and records per-op compute samples and per-collective transfer
samples against the NOMINAL topology's spec-sheet numbers, exactly what a
profiler on a live cluster would log (observed time vs nominal
bandwidth). Calibration then fits the gap.
"""
from __future__ import annotations

import numpy as np

from repro.core.device import Topology
from repro.core.simulator import simulate
from repro.core.strategy import device_group_of
from repro.runtime.telemetry import MeasurementStore, StepRecord


def dominant_op(gg, group_id: int) -> str | None:
    """Flops-dominant primitive name of an op group — the ``"op"`` tag
    compute samples carry so calibration can bucket utilization per op
    type, not just per device type."""
    if gg is None or group_id is None or group_id >= len(gg.groups):
        return None
    by_op: dict = {}
    for oid in gg.groups[group_id].op_ids:
        node = gg.base.nodes.get(oid)
        if node is not None:
            by_op[node.op_type] = by_op.get(node.op_type, 0.0) + node.flops
    if not by_op:
        return None
    return max(by_op.items(), key=lambda kv: kv[1])[0]


def execute_plan(tg, true_topo: Topology, *,
                 nominal_topo: Topology | None = None,
                 graph_fp: str = "", topo_fp: str = "",
                 step: int = 0, noise: float = 0.0, seed: int = 0,
                 store: MeasurementStore | None = None,
                 gg=None, meta: dict | None = None) -> StepRecord:
    """Execute one step of ``tg`` on ``true_topo`` and record telemetry.

    ``nominal_topo`` (default: ``true_topo``) supplies the spec-sheet
    bandwidths the samples are normalized against — on a live cluster the
    profiler knows the nominal link speed, not the achieved one.
    ``noise`` adds multiplicative jitter (relative std-dev) per sample.
    ``gg`` (the GroupedGraph ``tg`` was compiled from, optional) lets
    compute samples carry their group's dominant primitive as ``"op"``
    for the per-op-type calibration tier.
    """
    nominal = nominal_topo or true_topo
    rng = np.random.default_rng(seed)
    op_of = {g: dominant_op(gg, g)
             for g in range(len(gg.groups))} if gg is not None else {}

    def jitter():
        return 1.0 + noise * float(rng.standard_normal()) if noise else 1.0

    res = simulate(tg, true_topo)
    g_of = {d: device_group_of(true_topo, d)
            for d in range(true_topo.total_devices)}

    compute, collectives = [], []
    for t in tg.tasks:
        dur = (res.task_finish[t.tid] - res.task_start[t.tid]) * jitter()
        if t.kind == "compute":
            sample = {
                "gpu_type": true_topo.groups[g_of[t.device]].gpu_type,
                "flops": t.flops, "time": dur}
            op = op_of.get(t.group)
            if op:
                sample["op"] = op
            compute.append(sample)
        elif t.kind == "xfer":
            gi, gj = g_of[t.src], g_of[t.dst]
            collectives.append({
                "kind": "xfer", "nbytes": t.nbytes, "n_dev": 2,
                "nominal_bw": nominal.nominal_bw(gi, gj),
                "link": "p2p", "pair": f"{gi}-{gj}", "time": dur})
        elif t.kind in ("allreduce", "ps"):
            gids = sorted({g_of[d] for d in t.devices})
            b_nom, cls = nominal.nominal_bottleneck(gids)
            collectives.append({
                "kind": t.kind, "nbytes": t.nbytes,
                "n_dev": len(t.devices), "nominal_bw": b_nom,
                "link": cls, "time": dur})

    rec = StepRecord(
        graph_fp=graph_fp, topo_fp=topo_fp, step=step,
        wall_time=res.makespan * jitter(),
        device_busy={str(d): b for d, b in res.device_busy.items()},
        link_busy={f"{gi}-{gj}": b
                   for (gi, gj), b in res.link_busy.items()},
        compute=compute, collectives=collectives,
        meta=dict(meta or {}, executor="replay",
                  true_topo=true_topo.name))
    if store is not None:
        store.append(rec)
    return rec
