"""Drift detection: observed step time vs a cached plan's simulated
makespan.

Transient spikes are damped two ways, both configurable (and plumbed
through ``PlannerService(drift_threshold=, drift_min_samples=,
drift_ewma_alpha=)``): observed step times are smoothed by an
exponentially-weighted moving average per (graph, topology) key, and
drift is only flagged once ``min_samples`` observations put the smoothed
value beyond ``threshold`` relative error. With the defaults
(``min_samples=1``, ``alpha=0.5``) a first-ever observation can trigger
immediately; raise ``min_samples`` to require sustained drift.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass
class DriftReport:
    graph_fp: str
    topo_fp: str
    simulated: float              # cached plan's simulated step seconds
    observed: float               # latest observed step seconds
    ewma: float                   # smoothed observed step seconds
    drift: float                  # |ewma - simulated| / simulated
    threshold: float
    n_obs: int
    drifted: bool
    # attributed root cause of a drifted verdict, stamped by the
    # recalibration path from the run-health analyzer:
    # {"cause": "stage"|"link"|"sync", "key", "residual_s", ...}.
    # None when no analyzer observed the drifting run.
    cause: dict | None = None

    def to_dict(self) -> dict:
        return {"graph_fp": self.graph_fp, "topo_fp": self.topo_fp,
                "simulated": self.simulated, "observed": self.observed,
                "ewma": self.ewma, "drift": self.drift,
                "threshold": self.threshold, "n_obs": self.n_obs,
                "drifted": self.drifted, "cause": self.cause}


@dataclass
class _KeyState:
    ewma: float = 0.0
    n: int = 0


class DriftDetector:
    def __init__(self, threshold: float = 0.25, alpha: float = 0.5,
                 min_samples: int = 1):
        self.threshold = threshold
        self.alpha = alpha
        self.min_samples = max(min_samples, 1)
        self._state: dict = {}          # (graph_fp, topo_fp) -> _KeyState

    def update(self, graph_fp: str, topo_fp: str, simulated: float,
               observed: float) -> DriftReport:
        key = (graph_fp, topo_fp)
        st = self._state.setdefault(key, _KeyState())
        st.n += 1
        st.ewma = observed if st.n == 1 else (
            self.alpha * observed + (1.0 - self.alpha) * st.ewma)
        drift = abs(st.ewma - simulated) / simulated if simulated > 0 \
            else float("inf")
        return DriftReport(
            graph_fp=graph_fp, topo_fp=topo_fp, simulated=simulated,
            observed=observed, ewma=st.ewma, drift=drift,
            threshold=self.threshold, n_obs=st.n,
            drifted=st.n >= self.min_samples and drift > self.threshold)

    def reset(self, graph_fp: str, topo_fp: str):
        self._state.pop((graph_fp, topo_fp), None)
