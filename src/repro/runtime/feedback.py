"""FeedbackLoop: the paper's §4.3 closed loop over the planner service.

    loop = FeedbackLoop(service)
    result = loop.observe(gg, topo, step_record)

Each observation is appended to the measurement log and compared — via an
EWMA drift detector — against the cached plan's simulated makespan. Past
the drift threshold the loop:

  1. fits a ``CalibrationProfile`` from this topology's accumulated
     telemetry (falling back to the triggering record alone),
  2. invalidates the stale ``PlanStore`` entry,
  3. re-searches warm-started from the stale strategy, on the CALIBRATED
     topology, with the observed runtime features routed into the GNN,
  4. stores and returns the refreshed plan.

The replacement plan's simulated time *under the calibrated cost model*
is compared against the stale plan re-simulated under the same model, so
``result.improved`` states whether replanning actually helped.
"""
from __future__ import annotations

from dataclasses import dataclass
import threading
import time

from repro.core import tag as tag_mod
from repro.core.device import Topology
from repro.core.graph import GroupedGraph
from repro.core.strategy import canonical_strategies
from repro.runtime.calibration import (
    CalibrationProfile, fit_profile, uniform_profile)
from repro.runtime.drift import DriftDetector, DriftReport
from repro.runtime.telemetry import (
    MeasurementStore, StepRecord, observed_sim_result)


@dataclass
class FeedbackResult:
    kind: str                              # no_plan | ok | replanned
    report: DriftReport | None = None
    profile: CalibrationProfile | None = None
    response: object = None                # PlanResponse of the new plan
    stale_time: float | None = None        # stale plan under calib model
    observed: float | None = None

    @property
    def improved(self) -> bool:
        return (self.kind == "replanned" and self.response is not None
                and self.stale_time is not None
                and self.response.time <= self.stale_time * (1 + 1e-9))


class FeedbackLoop:
    def __init__(self, service, *,
                 measurements: MeasurementStore | None = None,
                 drift_threshold: float = 0.25, ewma_alpha: float = 0.5,
                 min_samples: int = 1, max_history: int = 256):
        self.service = service
        self.measurements = measurements if measurements is not None \
            else MeasurementStore()
        self.detector = DriftDetector(threshold=drift_threshold,
                                      alpha=ewma_alpha,
                                      min_samples=min_samples)
        # calibration window: newest records consulted on a drift event —
        # bounds the refit cost on long-lived logs and keeps the profile
        # tracking the CURRENT cluster, not its whole history
        self.max_history = max_history

    def observe(self, gg: GroupedGraph, topo: Topology, observation,
                *, iterations: int = 20, seed: int = 0,
                enable_sfb: bool = True,
                append: bool = True) -> FeedbackResult:
        """Feed one observed step back into the planner.

        ``observation`` is a ``StepRecord`` (preferred — its samples feed
        calibration) or a bare observed step time in seconds.

        ``append=False`` skips writing the record to the measurement
        store — for callers (the ``RecalibrationLoop`` poller) whose
        observation was *read from* that same store and must not be
        duplicated back into it.
        """
        from repro.service.fingerprint import (
            fingerprint_grouped, fingerprint_topology)
        from repro.service.warmstart import adapt_strategy

        graph_fp = fingerprint_grouped(gg)
        topo_fp = fingerprint_topology(topo)
        if isinstance(observation, StepRecord):
            rec = observation
            rec.graph_fp, rec.topo_fp = graph_fp, topo_fp
        else:
            rec = StepRecord(graph_fp=graph_fp, topo_fp=topo_fp,
                             wall_time=float(observation))
        if append:
            self.measurements.append(rec)

        cached = self.service.store.get(graph_fp, topo_fp)
        if cached is None:
            return FeedbackResult(kind="no_plan", observed=rec.wall_time)

        report = self.detector.update(graph_fp, topo_fp, cached.time,
                                      rec.wall_time)
        if not report.drifted:
            return FeedbackResult(kind="ok", report=report,
                                  observed=rec.wall_time)

        # ---- drift: recalibrate, invalidate, warm re-search
        history = self.measurements.records(
            graph_fp=graph_fp, topo_fp=topo_fp,
            limit=self.max_history) or [rec]
        profile = fit_profile(history, topo)
        if not profile.util and not profile.links:
            # wall-time-only telemetry (e.g. the CLI's --observed-time):
            # fall back to a uniform slowdown matching the smoothed
            # observation
            profile = uniform_profile(topo, cached.time / report.ewma
                                      if report.ewma > 0 else 1.0,
                                      n_records=len(history))
        calib_topo = profile.apply(topo)

        stale_strat = cached.strategy_obj()
        # schedule-aware when the stale plan pipelines, FIFO otherwise —
        # the SAME model the planner reported cached.time under, so the
        # improved/regressed verdict compares like with like
        stale_time = tag_mod.strategy_step_time(
            gg, stale_strat, calib_topo, sfb=enable_sfb)

        self.service.store.evict(graph_fp=graph_fp, topo_fp=topo_fp)
        self.detector.reset(graph_fp, topo_fp)

        # Seed the re-search from the best of {stale plan, canonical
        # families} re-scored under the CALIBRATED model: a drifted
        # cluster (e.g. congested cross-machine fabric) can move the
        # optimum far from the cached plan, and MCTS warm-started from a
        # now-bad prior would stay in its basin.
        seed_strat, seed_time = adapt_strategy(stale_strat, gg.n,
                                               calib_topo), stale_time
        for cand in canonical_strategies(gg.n, calib_topo):
            t = tag_mod.strategy_step_time(gg, cand, calib_topo,
                                           sfb=enable_sfb)
            if t < seed_time:
                seed_strat, seed_time = cand, t

        # The refreshed plan is searched under the CALIBRATED topology but
        # stored under the NOMINAL (deployment) key: that is the key the
        # next launch plans with and the next observation joins against —
        # keying by the calibrated fingerprint would orphan the entry and
        # make every later observe() report "no_plan".
        resp = self.service.plan_graph(
            gg, calib_topo, iterations=iterations, seed=seed,
            enable_sfb=enable_sfb, prior_strategy=seed_strat,
            fingerprints=(graph_fp, topo_fp),
            observed_feedback=observed_sim_result(history, topo))
        return FeedbackResult(
            kind="replanned", report=report, profile=profile,
            response=resp, stale_time=stale_time,
            observed=rec.wall_time)


class RecalibrationLoop:
    """Continuous, unattended plan -> execute -> observe -> replan.

    A background thread polls the service's ``MeasurementStore`` via
    ``read_new()`` (the incremental, complete-lines-only cursor), so any
    process appending ``StepRecord``s to the shared telemetry dir —
    ``launch.train --telemetry-dir``, the replay executor, the real
    engine — feeds the drift detector with no manual ``observe`` call.

    Records only carry fingerprints; replanning needs the (graph,
    topology) objects, so workloads are registered with ``watch(gg,
    topo)``. Records for unwatched fingerprints are counted
    (``recalib_records_total{outcome="unwatched"}``) and skipped. Every
    processed record goes through ``service.observe(..., append=False)``
    — ``append=False`` because the record was *read from* the same store
    observe would write it back to. After each batch the calibration
    profile is refit from the watched workload's accumulated telemetry
    and published as gauges (``profile_metrics``), so /metrics always
    shows the currently-fitted cluster state.

    Backlog control is two-tier: the whole poll is capped at
    ``max_batch`` records (oldest dropped,
    ``recalib_records_total{outcome="dropped"}``), then each watched
    key keeps only its newest ``max_per_key`` records — older
    duplicates of the same workload only re-smooth the same EWMA, so
    shedding them (``recalib_backlog_shed_total``) bounds a flooded
    telemetry dir's poll cost without losing any key's newest signal.
    ``recalib_backlog_depth`` gauges the pre-shed backlog per poll.

    ``health`` (a ``repro.obs.health.RunHealthAnalyzer``) upgrades the
    loop from arrival-order to severity-order: watched keys drain in
    descending ``replan_priority()`` score, so when several workloads
    drift at once the worst-deviating one replans FIRST, and each
    replanned verdict is stamped with the analyzer's attributed cause
    (``DriftReport.cause`` + ``PlanRecord.meta["drift_cause"]``).
    """

    def __init__(self, service, *, interval_s: float = 5.0,
                 iterations: int = 20, seed: int = 0,
                 enable_sfb: bool = True, max_batch: int = 256,
                 max_per_key: int = 32, health=None):
        self.service = service
        self.interval_s = float(interval_s)
        self.iterations = int(iterations)
        self.seed = int(seed)
        self.enable_sfb = bool(enable_sfb)
        self.max_batch = int(max_batch)
        self.max_per_key = max(int(max_per_key), 1)
        self.health = health
        self._watched: dict = {}            # (graph_fp, topo_fp) -> (gg, t)
        self._last_order: list = []         # key drain order of last poll
        self._stop = threading.Event()
        self._thread: threading.Thread | None = None
        self._lock = threading.Lock()       # one poll at a time
        reg = service.metrics
        self._m_polls = reg.counter(
            "recalib_polls_total", "recalibration store polls")
        self._m_records = reg.counter(
            "recalib_records_total",
            "telemetry records consumed by the recalibration loop, "
            "by outcome")
        self._m_last = reg.gauge(
            "recalib_last_poll_unixtime", "wall time of the latest poll")
        self._m_running = reg.gauge(
            "recalib_running", "1 while the recalibration thread runs")
        self._m_watched = reg.gauge(
            "recalib_watched_workloads",
            "(graph, topology) pairs registered for replanning")
        self._m_backlog = reg.gauge(
            "recalib_backlog_depth",
            "records found waiting at the start of the latest poll")
        self._m_shed = reg.counter(
            "recalib_backlog_shed_total",
            "stale per-key records shed before processing (oldest "
            "first; each key keeps its newest max_per_key)")

    # ------------------------------------------------------------- control
    def watch(self, gg, topo) -> tuple:
        """Register a workload; returns its (graph_fp, topo_fp) key."""
        from repro.service.fingerprint import (
            fingerprint_grouped, fingerprint_topology)
        key = (fingerprint_grouped(gg), fingerprint_topology(topo))
        self._watched[key] = (gg, topo)
        self._m_watched.set(len(self._watched))
        return key

    def start(self) -> "RecalibrationLoop":
        if self._thread is not None:
            return self
        self._stop.clear()
        self._thread = threading.Thread(target=self._run,
                                        name="recalibration", daemon=True)
        self._thread.start()
        self._m_running.set(1)
        return self

    def stop(self):
        self._stop.set()
        if self._thread is not None:
            self._thread.join(timeout=30)
            self._thread = None
        self._m_running.set(0)

    @property
    def running(self) -> bool:
        return self._thread is not None and self._thread.is_alive()

    def _run(self):
        while not self._stop.wait(self.interval_s):
            try:
                self.poll_once()
            except Exception:               # a bad poll must not kill the
                self._m_records.inc(outcome="error")      # daemon thread
        self._m_running.set(0)

    # ------------------------------------------------------------ polling
    def poll_once(self) -> list:
        """Drain newly appended records once; returns the
        ``FeedbackResult``s of processed (watched) records, in the
        order they were processed (priority order when a health
        analyzer is attached)."""
        with self._lock:
            store = self.service.measurements
            recs = store.read_new()
            self._m_polls.inc()
            self._m_last.set(time.time())
            self._m_backlog.set(len(recs))
            results = []
            touched: set = set()
            if len(recs) > self.max_batch:   # never replay an unbounded
                self._m_records.inc(len(recs) - self.max_batch,
                                    outcome="dropped")    # backlog silently
                recs = recs[-self.max_batch:]
            if self.health is not None:
                # keep the analyzer's view fresh BEFORE ordering keys.
                # An analyzer with its own store cursor drains it; a
                # feed-only analyzer rides this poll's records.
                if getattr(self.health, "store", None) is not None:
                    self.health.poll()
                else:
                    for rec in recs:
                        try:
                            self.health.ingest(rec)
                        except Exception:
                            pass             # health is advisory, never
                                             # blocks recalibration
            by_key: dict = {}               # key -> records, oldest first
            for rec in recs:
                key = (rec.graph_fp, rec.topo_fp)
                if key not in self._watched:
                    self._m_records.inc(outcome="unwatched")
                    continue
                by_key.setdefault(key, []).append(rec)
            # per-key shedding: EWMA smoothing means only the newest
            # records of a flooded key carry signal — keep those
            for key, krecs in by_key.items():
                if len(krecs) > self.max_per_key:
                    shed = len(krecs) - self.max_per_key
                    self._m_shed.inc(shed)
                    self._m_records.inc(shed, outcome="shed")
                    by_key[key] = krecs[-self.max_per_key:]
            order = sorted(by_key, key=self._priority, reverse=True)
            self._last_order = list(order)
            for key in order:
                gg, topo = self._watched[key]
                for rec in by_key[key]:
                    try:
                        res = self.service.observe(
                            gg, topo, rec, iterations=self.iterations,
                            seed=self.seed, enable_sfb=self.enable_sfb,
                            append=False)
                    except Exception:
                        self._m_records.inc(outcome="error")
                        continue
                    self._m_records.inc(outcome=res.kind)
                    touched.add(key)
                    if res.kind == "replanned":
                        self._annotate_cause(key, res)
                    results.append(res)
            for key in touched:
                self._publish_calibration(key, store)
            return results

    def _priority(self, key: tuple) -> tuple:
        """Drain order for a watched key: health-attributed deviation
        first (worst drift replans before any un-drifted workload),
        fingerprints as a deterministic tiebreak."""
        score = 0.0
        if self.health is not None:
            try:
                score = self.health.replan_priority().get(key, 0.0)
            except Exception:
                score = 0.0
        return (score, key[0], key[1])

    def _annotate_cause(self, key: tuple, res):
        """Stamp the analyzer's attributed cause onto a replanned
        verdict: the DriftReport carries it back to the caller and the
        refreshed PlanRecord persists it in ``meta["drift_cause"]``."""
        if self.health is None:
            return
        try:
            cause = self.health.attributed_cause(*key)
        except Exception:
            return
        if not cause:
            return
        if res.report is not None:
            res.report.cause = cause
        rec = self.service.store.get(*key)
        if rec is not None:
            rec.meta["drift_cause"] = cause
            self.service.store.put(rec)

    def _publish_calibration(self, key: tuple, store: MeasurementStore):
        """Refit + publish calibration gauges for one watched workload."""
        _, topo = self._watched[key]
        history = store.records(graph_fp=key[0], topo_fp=key[1], limit=256)
        if not history:
            return
        from repro.runtime.calibration import profile_metrics
        profile = fit_profile(history, topo)
        if not profile.util and not profile.links:
            profile = uniform_profile(topo, 1.0, n_records=len(history))
        profile_metrics(profile, self.service.metrics)

    def stats(self) -> dict:
        return {"running": self.running,
                "interval_s": self.interval_s,
                "watched": len(self._watched),
                "polls": self._m_polls.value(),
                "records": {
                    k: self._m_records.value(outcome=k)
                    for k in ("ok", "replanned", "no_plan", "unwatched",
                              "error", "shed", "dropped")},
                "backlog_depth": self._m_backlog.value(),
                "shed_total": self._m_shed.value(),
                "last_order": [[k[0][:12], k[1][:12]]
                               for k in self._last_order],
                "last_poll_unixtime": self._m_last.value()}
