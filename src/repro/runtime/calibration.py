"""Cost-model calibration from step telemetry (paper §4.3).

``fit_profile`` turns a set of observed ``StepRecord``s into a
``CalibrationProfile``:

  * per-device-type compute utilization — least squares of observed op
    time against ``flops / peak_flops`` (``core.profiler.fit_utilization``)
  * per-link-class comm regressions — alpha (per-transfer latency) and
    beta (achieved fraction of nominal bandwidth) fitted jointly per
    class ``p2p`` / ``intra`` / ``cross`` (``core.profiler.fit_comm``)

``CalibrationProfile.apply(topo)`` produces a topology whose device
speeds and efficiency factors are the MEASURED ones; ``core.simulator
.simulate(tg, topo, profile=...)`` and the planner consume it in place of
the hard-coded ``GPU_PEAKS`` utilization priors and ``Topology``
effective-bandwidth constants.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
import json
import os

import numpy as np

from repro.core.device import GPU_PEAKS, Topology, peak_flops
from repro.core.profiler import CommFit, fit_comm, fit_utilization

PROFILE_VERSION = 1

# lat_mult per collective kind: how many per-transfer latency hits the
# cost model charges (see core.profiler allreduce/ps/transfer formulas)
def _lat_mult(kind: str, n_dev: int) -> float:
    if kind == "allreduce":
        return 2.0 * n_dev
    if kind == "ps":
        return 2.0
    return 1.0                       # xfer


@dataclass
class CalibrationProfile:
    """Measurement-fitted replacements for the simulator's cost constants."""
    util: dict = field(default_factory=dict)    # gpu_type -> utilization
    links: dict = field(default_factory=dict)   # p2p|intra|cross -> CommFit
    latency: float | None = None                # fitted per-transfer alpha
    n_records: int = 0
    meta: dict = field(default_factory=dict)
    # per-(gi, gj) link-pair fits: "gi-gj" -> CommFit. Fitted only once a
    # pair accumulates >= min_pair_samples transfers (fit_profile arg);
    # sparser pairs keep the per-class fit above. Pipeline boundary
    # transfers (repro.exec.replay / runtime.executor) tag their samples
    # with the pair key that feeds this tier.
    pairs: dict = field(default_factory=dict)
    # per-op-type utilization buckets: "gpu_type/op" -> utilization, where
    # ``op`` is the sample's op attribution — the pipeline event kind
    # (F/B/W) from the exec engine/replay, or the dominant traced
    # primitive ("dot_general", ...) from the task-graph executor. An
    # observability tier on top of the per-device ``util`` the cost model
    # applies: it shows WHICH phase/op family drags a device's achieved
    # utilization down (surfaced by ``profile_metrics`` and
    # ``repro-plan metrics``).
    util_by_op: dict = field(default_factory=dict)

    def device_flops(self, gpu_type: str, default: float) -> float:
        u = self.util.get(gpu_type)
        if u is None:
            return default
        return peak_flops(gpu_type) * u

    def apply(self, topo: Topology) -> Topology:
        """Calibrated copy of ``topo``: fitted utilization replaces the
        ``GPU_PEAKS`` priors, fitted per-class efficiencies replace the
        ``coll_eff_*`` / ``p2p_eff`` constants, fitted alpha replaces the
        nominal latency. Unobserved types/classes keep nominal values."""
        t2 = copy.deepcopy(topo)
        for g in t2.groups:
            g.flops = self.device_flops(g.gpu_type, g.flops)
        if "p2p" in self.links:
            t2.p2p_eff = self.links["p2p"].eff
        if "intra" in self.links:
            t2.coll_eff_intra = self.links["intra"].eff
        if "cross" in self.links:
            t2.coll_eff_cross = self.links["cross"].eff
        if self.latency is not None:
            t2.latency = self.latency
        for pair, fit in self.pairs.items():
            gi, gj = (int(x) for x in pair.split("-"))
            if gi < t2.m and gj < t2.m:
                t2.pair_eff[(gi, gj)] = fit.eff
        if topo.name:
            t2.name = f"{topo.name}+calib"
        return t2

    # ------------------------------------------------------ serialization
    def to_dict(self) -> dict:
        return {"version": PROFILE_VERSION, "util": self.util,
                "util_by_op": self.util_by_op,
                "links": {k: v.to_dict() for k, v in self.links.items()},
                "pairs": {k: v.to_dict() for k, v in self.pairs.items()},
                "latency": self.latency, "n_records": self.n_records,
                "meta": self.meta}

    @classmethod
    def from_dict(cls, d: dict) -> "CalibrationProfile":
        if d.get("version") != PROFILE_VERSION:
            raise ValueError(f"calibration profile schema "
                             f"{d.get('version')} != {PROFILE_VERSION}")
        return cls(util={k: float(v) for k, v in d.get("util", {}).items()},
                   util_by_op={k: float(v)
                               for k, v in d.get("util_by_op", {}).items()},
                   links={k: CommFit.from_dict(v)
                          for k, v in d.get("links", {}).items()},
                   pairs={k: CommFit.from_dict(v)
                          for k, v in d.get("pairs", {}).items()},
                   latency=d.get("latency"),
                   n_records=int(d.get("n_records", 0)),
                   meta=d.get("meta", {}))

    def save(self, path: str):
        os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        with open(path, "w") as f:
            json.dump(self.to_dict(), f, indent=2, sort_keys=True)

    @classmethod
    def load(cls, path: str) -> "CalibrationProfile":
        with open(path) as f:
            return cls.from_dict(json.load(f))


def load_profile(path: str) -> CalibrationProfile:
    return CalibrationProfile.load(path)


def uniform_profile(topo: Topology, scale: float,
                    n_records: int = 0) -> CalibrationProfile:
    """Time-only calibration fallback: when telemetry carries wall times
    but no per-op/per-collective samples, assume a uniform cluster
    slowdown (``scale`` < 1) or speedup — every compute rate, link
    efficiency, and (inversely) the latency scales by it, so simulated
    makespans scale by ~1/scale (modulo the fixed per-op launch
    overhead)."""
    scale = float(np.clip(scale, 1e-3, 10.0))
    util = {}
    for g in topo.groups:
        if g.gpu_type in GPU_PEAKS:
            util[g.gpu_type] = float(np.clip(
                g.flops * scale / peak_flops(g.gpu_type), 1e-3, 1.0))
    links = {
        "p2p": CommFit(eff=float(np.clip(topo.p2p_eff * scale, 1e-3, 1.0)),
                       alpha=topo.latency / scale),
        "intra": CommFit(eff=float(np.clip(topo.coll_eff_intra * scale,
                                           1e-3, 1.0)),
                         alpha=topo.latency / scale),
        "cross": CommFit(eff=float(np.clip(topo.coll_eff_cross * scale,
                                           1e-3, 1.0)),
                         alpha=topo.latency / scale),
    }
    return CalibrationProfile(
        util=util, links=links, latency=topo.latency / scale,
        n_records=n_records,
        meta={"topo": topo.name, "uniform_scale": scale,
              "compute_samples": 0, "comm_samples": 0})


def fit_profile(records: list, topo: Topology, *,
                min_pair_samples: int = 8) -> CalibrationProfile:
    """Fit a CalibrationProfile from observed StepRecords.

    ``topo`` is the NOMINAL topology the samples were recorded against —
    it supplies peak specs, the latency prior for rank-deficient comm
    fits, and names which device types exist.

    Per-link-pair tier: collective samples carrying a ``"pair"`` key
    ("gi-gj", e.g. pipeline boundary transfers) are additionally
    bucketed per pair; every pair with at least ``min_pair_samples``
    observations gets its own (eff, alpha) fit — sparser pairs fall back
    to the per-class fit.
    """
    by_type: dict = {}
    by_op: dict = {}
    for r in records:
        for s in r.compute:
            if s.get("flops", 0.0) > 0 and s.get("time", 0.0) > 0:
                sample = (float(s["flops"]), float(s["time"]))
                by_type.setdefault(s["gpu_type"], []).append(sample)
                op = s.get("op") or s.get("kind")
                if op:
                    by_op.setdefault((s["gpu_type"], str(op)),
                                     []).append(sample)
    util = {}
    for t, samples in by_type.items():
        if t not in GPU_PEAKS:
            continue
        fl, ti = zip(*samples, strict=True)
        u = fit_utilization(fl, ti, peak_flops(t))
        if u is not None:              # degenerate fit: keep nominal
            util[t] = u
    util_by_op = {}
    for (t, op), samples in by_op.items():
        if t not in GPU_PEAKS:
            continue
        fl, ti = zip(*samples, strict=True)
        u = fit_utilization(fl, ti, peak_flops(t))
        if u is not None:
            util_by_op[f"{t}/{op}"] = u

    by_class: dict = {}
    by_pair: dict = {}
    for r in records:
        for s in r.collectives:
            nb, nd = float(s.get("nbytes", 0.0)), int(s.get("n_dev", 2))
            bw, dt = float(s.get("nominal_bw", 0.0)), float(
                s.get("time", 0.0))
            if nb <= 0 or bw <= 0 or dt <= 0 or nd <= 1:
                continue
            kind = s.get("kind", "xfer")
            ring = 2.0 * (nd - 1) / nd if kind in ("allreduce", "ps") \
                else 1.0
            sample = (ring * nb / bw, _lat_mult(kind, nd), dt)
            by_class.setdefault(s.get("link", "p2p"), []).append(sample)
            if s.get("pair"):
                by_pair.setdefault(str(s["pair"]), []).append(sample)
    links = {}
    alphas = []
    for cls_name, samples in by_class.items():
        s, m, y = (list(x) for x in zip(*samples, strict=True))
        fit = fit_comm(s, m, y, prior_alpha=topo.latency)
        if fit is None:                # degenerate fit: keep nominal
            continue
        links[cls_name] = fit
        alphas.extend([fit.alpha] * fit.n_samples)
    pairs = {}
    for pair, samples in by_pair.items():
        if len(samples) < min_pair_samples:
            continue                   # sparse pair: class fit covers it
        s, m, y = (list(x) for x in zip(*samples, strict=True))
        fit = fit_comm(s, m, y, prior_alpha=topo.latency)
        if fit is not None:
            pairs[pair] = fit

    return CalibrationProfile(
        util=util, util_by_op=util_by_op, links=links, pairs=pairs,
        latency=float(np.mean(alphas)) if alphas else None,
        n_records=len(records),
        meta={"topo": topo.name,
              "compute_samples": int(sum(len(v) for v in by_type.values())),
              "comm_samples": int(sum(len(v) for v in by_class.values())),
              "pair_samples": {k: len(v) for k, v in by_pair.items()},
              "op_samples": {f"{t}/{op}": len(v)
                             for (t, op), v in by_op.items()}})


def profile_metrics(profile: CalibrationProfile, registry=None):
    """Surface a ``CalibrationProfile`` as metrics gauges
    (``repro.obs.metrics``): per-device-type and per-op-type utilization,
    per-class and per-pair link efficiency, fitted latency. Returns the
    registry (created when not given)."""
    if registry is None:
        from repro.obs.metrics import MetricsRegistry
        registry = MetricsRegistry()
    g_util = registry.gauge("calibration_utilization",
                            "fitted compute utilization per device type")
    for t, u in profile.util.items():
        g_util.set(u, gpu_type=t)
    g_op = registry.gauge(
        "calibration_utilization_by_op",
        "fitted compute utilization per (device type, op type) bucket")
    for key, u in profile.util_by_op.items():
        t, op = key.split("/", 1)
        g_op.set(u, gpu_type=t, op=op)
    g_eff = registry.gauge("calibration_link_efficiency",
                           "fitted achieved fraction of nominal bandwidth")
    for cls_name, fit in profile.links.items():
        g_eff.set(fit.eff, link=cls_name)
    for pair, fit in profile.pairs.items():
        g_eff.set(fit.eff, link="pair", pair=pair)
    if profile.latency is not None:
        registry.gauge("calibration_latency_seconds",
                       "fitted per-transfer latency alpha").set(
            profile.latency)
    registry.gauge("calibration_records",
                   "step records the profile was fitted from").set(
        profile.n_records)
    return registry
