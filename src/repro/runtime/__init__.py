"""Runtime feedback subsystem (paper §4.3).

Closes the deployment loop: instrumented executions record step telemetry
(`telemetry`), least-squares fits refine the simulator's cost model
(`calibration`), drift between simulated and observed step time triggers
plan invalidation and a warm re-search (`drift`, `feedback`), and the
observed — not simulated — runtime features are routed back into the GNN
(`telemetry.observed_sim_result` -> `core.features.featurize`).
"""
from repro.runtime.calibration import (
    CalibrationProfile, fit_profile, load_profile, uniform_profile)
from repro.runtime.drift import DriftDetector, DriftReport
from repro.runtime.executor import execute_plan
from repro.runtime.feedback import FeedbackLoop, FeedbackResult
from repro.runtime.telemetry import (
    MeasurementStore, StepRecord, StepTimer, observed_sim_result)

__all__ = [
    "CalibrationProfile", "fit_profile", "load_profile", "uniform_profile",
    "DriftDetector", "DriftReport",
    "execute_plan",
    "FeedbackLoop", "FeedbackResult",
    "MeasurementStore", "StepRecord", "StepTimer", "observed_sim_result",
]
