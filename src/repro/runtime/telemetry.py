"""Step telemetry: instrumented timers + an append-only measurement log.

A ``StepRecord`` is one observed training/serving step: wall time plus —
when the executor can attribute them — per-device busy seconds, per-link
busy seconds, per-op compute samples, and per-collective transfer
samples. Records are keyed by the service layer's graph/topology
fingerprints so the feedback loop can join observations back to cached
plans.

``MeasurementStore`` persists records as append-only JSONL (one line per
step, ``fcntl``-locked appends so concurrent launchers can share a log);
``StepTimer`` wraps a jitted step callable (``launch.steps`` /
``launch.train`` / ``launch.serve``) and records each invocation.
"""
from __future__ import annotations

from dataclasses import dataclass, field
import json
import os
import time

import numpy as np

try:
    import fcntl
except ImportError:                       # non-posix: locking degrades
    fcntl = None

TELEMETRY_FILE = "measurements.jsonl"


@dataclass
class StepRecord:
    """One observed execution step."""
    graph_fp: str = ""
    topo_fp: str = ""
    step: int = 0
    wall_time: float = 0.0                # end-to-end step seconds
    device_busy: dict = field(default_factory=dict)   # str(dev) -> busy s
    link_busy: dict = field(default_factory=dict)     # "gi-gj" -> busy s
    compute: list = field(default_factory=list)
    # compute sample: {"gpu_type", "flops", "time"}
    collectives: list = field(default_factory=list)
    # collective sample: {"kind": allreduce|ps|xfer, "nbytes", "n_dev",
    #                     "nominal_bw" (spec-sheet B/s), "link":
    #                     intra|cross|p2p, "time"}
    meta: dict = field(default_factory=dict)
    ts: float = 0.0                        # record timestamp (epoch s)

    def to_dict(self) -> dict:
        return {
            "graph_fp": self.graph_fp, "topo_fp": self.topo_fp,
            "step": self.step, "wall_time": self.wall_time,
            "device_busy": self.device_busy, "link_busy": self.link_busy,
            "compute": self.compute, "collectives": self.collectives,
            "meta": self.meta, "ts": self.ts,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "StepRecord":
        return cls(
            graph_fp=d.get("graph_fp", ""), topo_fp=d.get("topo_fp", ""),
            step=int(d.get("step", 0)),
            wall_time=float(d.get("wall_time", 0.0)),
            device_busy=d.get("device_busy", {}),
            link_busy=d.get("link_busy", {}),
            compute=d.get("compute", []),
            collectives=d.get("collectives", []),
            meta=d.get("meta", {}), ts=float(d.get("ts", 0.0)))


class MeasurementStore:
    """Append-only JSONL measurement log.

    ``path=None`` keeps records in memory only (tests, single-process
    benchmarks). With a path — a directory (a ``measurements.jsonl`` is
    created inside) or a ``.jsonl`` file — appends are atomic
    single-line writes under an ``fcntl`` exclusive lock, so multiple
    launcher processes can share one log.
    """

    def __init__(self, path: str | None = None):
        if path and not path.endswith(".jsonl"):
            os.makedirs(path, exist_ok=True)
            path = os.path.join(path, TELEMETRY_FILE)
        elif path:
            os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
        self.path = path
        self._mem: list = []
        # incremental-reader cursor (``read_new``): byte offset of the
        # first unconsumed line (memory mode: index into ``_mem``)
        self._offset = 0

    def append(self, rec: StepRecord) -> StepRecord:
        if not rec.ts:
            rec.ts = time.time()
        if self.path is None:
            self._mem.append(rec)
            return rec
        line = json.dumps(rec.to_dict(), sort_keys=True)
        with open(self.path, "a") as f:
            if fcntl is not None:
                fcntl.flock(f, fcntl.LOCK_EX)
            try:
                f.write(line + "\n")
                f.flush()
            finally:
                if fcntl is not None:
                    fcntl.flock(f, fcntl.LOCK_UN)
        return rec

    @staticmethod
    def _parse(line, graph_fp, topo_fp):
        """StepRecord of a JSONL line passing the substring pre-filter and
        the exact fingerprint match, else None."""
        if isinstance(line, bytes):
            try:
                line = line.decode()
            except UnicodeDecodeError:
                return None
        line = line.strip()
        if not line:
            return None
        if graph_fp is not None and graph_fp not in line:
            return None
        if topo_fp is not None and topo_fp not in line:
            return None
        try:
            rec = StepRecord.from_dict(json.loads(line))
        except (ValueError, KeyError):
            return None                   # torn/garbled line: skip
        if graph_fp is not None and rec.graph_fp != graph_fp:
            return None
        if topo_fp is not None and rec.topo_fp != topo_fp:
            return None
        return rec

    def records(self, *, graph_fp: str | None = None,
                topo_fp: str | None = None,
                limit: int | None = None) -> list:
        """Matching records, oldest first; ``limit`` keeps the newest N.

        Lines are pre-filtered by raw substring before JSON parsing, so
        fingerprint-keyed queries over a large log only pay full parse
        cost for matching steps. With ``limit`` the log is read BACKWARDS
        in blocks (``tail``) — a long-running observe loop polling the
        newest N records stays O(tail), not O(log).
        """
        if self.path is not None and limit is not None:
            return self.tail(limit, graph_fp=graph_fp, topo_fp=topo_fp)
        if self.path is None:
            out = [r for r in self._mem
                   if (graph_fp is None or r.graph_fp == graph_fp)
                   and (topo_fp is None or r.topo_fp == topo_fp)]
        else:
            out = []
            if os.path.exists(self.path):
                with open(self.path) as f:
                    for line in f:
                        rec = self._parse(line, graph_fp, topo_fp)
                        if rec is not None:
                            out.append(rec)
        if limit is not None:
            out = out[-limit:]
        return out

    def tail(self, limit: int, *, graph_fp: str | None = None,
             topo_fp: str | None = None,
             block_size: int = 1 << 16) -> list:
        """Newest ``limit`` matching records, oldest first, reading the
        log backwards in ``block_size`` chunks — cost is proportional to
        the tail, not the full log."""
        if limit <= 0:
            return []
        if self.path is None:
            out = [r for r in self._mem
                   if (graph_fp is None or r.graph_fp == graph_fp)
                   and (topo_fp is None or r.topo_fp == topo_fp)]
            return out[-limit:]
        if not os.path.exists(self.path):
            return []
        out = []
        with open(self.path, "rb") as f:
            f.seek(0, os.SEEK_END)
            pos = f.tell()
            buf = b""
            while pos > 0 and len(out) < limit:
                size = min(block_size, pos)
                pos -= size
                f.seek(pos)
                buf = f.read(size) + buf
                lines = buf.split(b"\n")
                # lines[0] may be a partial line continuing into the
                # previous (unread) block — keep it buffered
                buf = lines[0] if pos > 0 else b""
                start = 1 if pos > 0 else 0
                for line in reversed(lines[start:]):
                    rec = self._parse(line, graph_fp, topo_fp)
                    if rec is not None:
                        out.append(rec)
                        if len(out) >= limit:
                            break
        out.reverse()
        return out

    def read_new(self, *, graph_fp: str | None = None,
                 topo_fp: str | None = None) -> list:
        """Records appended since the previous ``read_new`` call (oldest
        first) — the O(new records) incremental reader for long-running
        observe/feedback polls. Only COMPLETE lines are consumed: a
        torn in-flight append stays buffered for the next poll. A
        truncated/rotated log resets the cursor and replays from the
        start."""
        if self.path is None:
            out = [r for r in self._mem[self._offset:]
                   if (graph_fp is None or r.graph_fp == graph_fp)
                   and (topo_fp is None or r.topo_fp == topo_fp)]
            self._offset = len(self._mem)
            return out
        if not os.path.exists(self.path):
            self._offset = 0
            return []
        size = os.path.getsize(self.path)
        if size < self._offset:          # rotated/truncated underneath us
            self._offset = 0
        out = []
        with open(self.path, "rb") as f:
            f.seek(self._offset)
            data = f.read()
        end = data.rfind(b"\n")
        if end < 0:
            return []
        for line in data[:end].split(b"\n"):
            rec = self._parse(line, graph_fp, topo_fp)
            if rec is not None:
                out.append(rec)
        self._offset += end + 1
        return out

    def __len__(self):
        """Total record count — a line count, no JSON parse."""
        if self.path is None:
            return len(self._mem)
        if not os.path.exists(self.path):
            return 0
        with open(self.path) as f:
            return sum(1 for line in f if line.strip())


class StepTimer:
    """Wrap a step callable so every invocation is timed end-to-end and
    appended to a MeasurementStore.

        timer = StepTimer(store, graph_fp=fp_g, topo_fp=fp_t)
        step_fn = timer.wrap(step_fn)      # drop-in replacement

    Outputs are blocked until ready (``jax.block_until_ready``) so the
    recorded wall time covers the actual device execution, not just
    dispatch.
    """

    def __init__(self, store: MeasurementStore | None = None,
                 graph_fp: str = "", topo_fp: str = "",
                 meta: dict | None = None):
        self.store = store if store is not None else MeasurementStore()
        self.graph_fp = graph_fp
        self.topo_fp = topo_fp
        self.meta = dict(meta or {})
        self.wall_times: list = []

    def record(self, wall_time: float, **kw) -> StepRecord:
        self.wall_times.append(wall_time)
        rec = StepRecord(graph_fp=self.graph_fp, topo_fp=self.topo_fp,
                         step=len(self.wall_times) - 1,
                         wall_time=wall_time, meta=dict(self.meta), **kw)
        return self.store.append(rec)

    def wrap(self, fn):
        def timed(*args, **kwargs):
            import jax
            t0 = time.perf_counter()
            out = fn(*args, **kwargs)
            jax.block_until_ready(out)
            self.record(time.perf_counter() - t0)
            return out
        return timed

    def summary(self) -> dict:
        w = np.asarray(self.wall_times, float)
        if w.size == 0:
            return {"steps": 0}
        return {"steps": int(w.size), "mean_s": float(w.mean()),
                "median_s": float(np.median(w)), "p90_s":
                float(np.percentile(w, 90)), "total_s": float(w.sum())}


def observed_sim_result(records: list, topo):
    """Aggregate observed StepRecords into a ``SimResult``-shaped object.

    The GNN's runtime-feedback features (makespan, per-device idle %,
    per-link idle %) are normally read off the simulator; this builds the
    same container from MEASURED telemetry so ``core.features.featurize
    (..., observed=...)`` feeds real signals to trained policies.
    Group-level features (per-group makespan, idle-before-transfer) stay
    empty unless a record carries them — real executions observe devices
    and links, not op groups.
    """
    from repro.core.simulator import SimResult
    if not records:
        raise ValueError("observed_sim_result needs at least one record")
    makespan = float(np.median([r.wall_time for r in records]))
    dev_busy: dict = {}
    link_busy: dict = {}
    n = len(records)
    for r in records:
        for d, b in r.device_busy.items():
            dev_busy[int(d)] = dev_busy.get(int(d), 0.0) + float(b) / n
        for k, b in r.link_busy.items():
            gi, gj = (int(x) for x in str(k).split("-"))
            link_busy[(gi, gj)] = link_busy.get((gi, gj), 0.0) \
                + float(b) / n
    return SimResult(
        makespan=makespan, feasible=True, task_start=[], task_finish=[],
        device_busy=dev_busy, peak_mem={}, link_busy=link_busy)
