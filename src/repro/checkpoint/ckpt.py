"""Flat-npz checkpointing with pytree structure + dtype metadata.

Tree leaves are flattened to ``path.to.leaf`` keys. bf16 arrays are stored
as uint16 views (npz has no bf16) and restored exactly.
"""
from __future__ import annotations

import json
import os
import re

import jax.numpy as jnp
import numpy as np

_BF16 = "bfloat16"


def _flatten_tree(tree):
    flat = {}

    def rec(node, path):
        if isinstance(node, dict):
            for k, v in node.items():
                rec(v, path + (k,))
        else:
            flat[".".join(path)] = node
    rec(tree, ())
    return flat


def _unflatten(flat: dict):
    tree: dict = {}
    for key, val in flat.items():
        parts = key.split(".")
        node = tree
        for p in parts[:-1]:
            node = node.setdefault(p, {})
        node[parts[-1]] = val
    return tree


def save_checkpoint(path: str, step: int, tree) -> str:
    os.makedirs(path, exist_ok=True)
    flat = _flatten_tree(tree)
    arrays, meta = {}, {}
    for k, v in flat.items():
        a = np.asarray(v)
        if a.dtype == jnp.bfloat16:
            arrays[k] = a.view(np.uint16)
            meta[k] = _BF16
        else:
            arrays[k] = a
            meta[k] = str(a.dtype)
    fn = os.path.join(path, f"ckpt_{step:08d}.npz")
    np.savez(fn, **arrays)
    with open(fn + ".meta.json", "w") as f:
        json.dump({"step": step, "dtypes": meta}, f)
    return fn


def latest_step(path: str):
    if not os.path.isdir(path):
        return None
    steps = [int(m.group(1)) for f in os.listdir(path)
             if (m := re.match(r"ckpt_(\d+)\.npz$", f))]
    return max(steps) if steps else None


def load_checkpoint(path: str, step: int | None = None):
    if step is None:
        step = latest_step(path)
        if step is None:
            raise FileNotFoundError(f"no checkpoints under {path}")
    fn = os.path.join(path, f"ckpt_{step:08d}.npz")
    with open(fn + ".meta.json") as f:
        meta = json.load(f)
    data = np.load(fn)
    flat = {}
    for k in data.files:
        a = data[k]
        if meta["dtypes"].get(k) == _BF16:
            a = a.view(jnp.bfloat16)
        flat[k] = jnp.asarray(a)
    return step, _unflatten(flat)
