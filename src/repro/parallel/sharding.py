"""Logical-axis sharding rules (MaxText-style).

Model code annotates activations/params with *logical* axis names
("batch", "seq", "heads", "mlp", "experts", "vocab", ...). An ``AxisRules``
context maps logical names to mesh axes. ``logical_shard`` applies a
``with_sharding_constraint`` only when the mapping is defined, the mesh is
active, and the dimension is divisible by the mesh-axis size — so the same
model code runs unsharded on one CPU device and fully sharded on a 512-chip
mesh.

TAG's strategy output (core/plan.py) is lowered to one of these rule-sets:
the searched choices (data-parallel degree, tensor-parallel placement,
gradient-sync mode) become the rule mapping + the sync mode consumed by the
optimizer step.
"""
from __future__ import annotations

import contextlib
from dataclasses import dataclass, field
import threading

import jax
from jax.sharding import NamedSharding, PartitionSpec as P

_STATE = threading.local()


@dataclass
class AxisRules:
    """Mapping logical axis name -> mesh axis name (or tuple of them)."""
    mesh: "jax.sharding.Mesh | None" = None
    rules: dict = field(default_factory=dict)
    # gradient sync mode per parameter-name prefix, from TAG strategies:
    #   "allreduce" (default) | "ps" | "sfb"
    grad_sync: dict = field(default_factory=dict)

    def mesh_axes(self, logical: str):
        ax = self.rules.get(logical)
        if ax is None:
            return None
        # drop mappings to axes the active mesh doesn't have (e.g. "model"
        # on a 1-D host mesh) so the same rules work on any mesh
        present = set(self.mesh.axis_names) if self.mesh is not None else set()
        if isinstance(ax, (tuple, list)):
            ax = tuple(a for a in ax if a in present)
            return ax or None
        return ax if ax in present else None

    def axis_size(self, mesh_axis) -> int:
        assert self.mesh is not None
        if isinstance(mesh_axis, (tuple, list)):
            n = 1
            for a in mesh_axis:
                n *= self.mesh.shape[a]
            return n
        return self.mesh.shape[mesh_axis]


def current_rules() -> AxisRules | None:
    return getattr(_STATE, "rules", None)


def rules_fingerprint():
    """Hashable signature of the active rules — passed as a STATIC arg
    through cached transforms (jax.checkpoint caches traces keyed on
    (fun, static args, avals); the thread-local rules are invisible to
    that key, so without this fingerprint a retrace under different rules
    would silently reuse the previous trace)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return None
    items = tuple(sorted(
        (k, tuple(v) if isinstance(v, (list, tuple)) else v)
        for k, v in r.rules.items()))
    mesh_sig = (tuple(r.mesh.axis_names),
                tuple(r.mesh.shape[a] for a in r.mesh.axis_names))
    return (items, mesh_sig)


@contextlib.contextmanager
def axis_rules(rules: AxisRules):
    prev = getattr(_STATE, "rules", None)
    _STATE.rules = rules
    try:
        yield rules
    finally:
        _STATE.rules = prev


def logical_spec(logical_axes, shape=None) -> P:
    """Build a PartitionSpec for the given logical axes under current rules.

    ``logical_axes`` is a tuple with one entry (str or None) per dim.
    When ``shape`` is given, divisibility is checked and non-divisible dims
    fall back to replication.
    """
    r = current_rules()
    if r is None or r.mesh is None:
        return P()
    spec, used = [], set()
    for i, name in enumerate(logical_axes):
        ax = r.mesh_axes(name) if name is not None else None
        if ax is None:
            spec.append(None)
            continue
        key = tuple(ax) if isinstance(ax, (list, tuple)) else (ax,)
        if used & set(key):  # a mesh axis may appear only once in a spec
            spec.append(None)
            continue
        if shape is not None and shape[i] % r.axis_size(ax) != 0:
            spec.append(None)
            continue
        used |= set(key)
        spec.append(tuple(ax) if isinstance(ax, (list, tuple)) else ax)
    while spec and spec[-1] is None:
        spec.pop()
    return P(*spec)


def logical_shard(x, *logical_axes):
    """Constrain ``x`` to the sharding implied by logical axes (no-op when
    no rules are active)."""
    r = current_rules()
    if r is None or r.mesh is None:
        return x
    spec = logical_spec(logical_axes, shape=x.shape)
    return jax.lax.with_sharding_constraint(x, NamedSharding(r.mesh, spec))


def named_sharding(logical_axes, shape=None) -> "NamedSharding | None":
    r = current_rules()
    if r is None or r.mesh is None:
        return None
    return NamedSharding(r.mesh, logical_spec(logical_axes, shape=shape))
