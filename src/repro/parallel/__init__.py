from repro.parallel.sharding import (  # noqa: F401
    AxisRules, axis_rules, current_rules, logical_shard, logical_spec)
