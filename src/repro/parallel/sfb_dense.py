"""Runtime gradient-sync variants for data-parallel dense layers — the
paper's strategy options executed for real in JAX (shard_map over the data
axis + custom_vjp):

  * "allreduce" — dW_local then psum over the data axis (DP-NCCL analogue)
  * "ps"        — reduce-scatter + all-gather (sharded parameter server /
                  ZeRO round-robin owners, the TPU-idiomatic PS)
  * "sfb"       — sufficient factor broadcasting: all-gather the factors
                  (activations x and output grads dy) and recompute
                  dW = x_gathered^T @ dy_gathered locally. Mathematically
                  identical, no gradient tensor on the wire. Wire bytes:
                  2*B*(H1+H2) vs H1*H2 — wins at small per-step batch,
                  exactly the paper's Table 5 regime.

All three produce bit-comparable gradients (tested allclose vs the
single-device reference), demonstrating the paper's losslessness claim on
the real execution engine rather than only in the simulator.
"""
from __future__ import annotations

import jax
from jax.experimental.shard_map import shard_map
import jax.numpy as jnp
from jax.sharding import Mesh, PartitionSpec as P

SYNC_MODES = ("allreduce", "ps", "sfb")


# -------------------------------------------------- grad-sync primitives
# Reusable inside any shard_map body (the dense layers below AND the
# pipeline engine's per-stage backward in repro.exec.engine).

def allreduce_grad(g, axis: str):
    """DP-NCCL analogue: one psum, every shard holds the summed grad."""
    return jax.lax.psum(g, axis)


def ps_grad(g, axis: str, n_dev: int):
    """Sharded parameter server (ZeRO round-robin owners): reduce-scatter
    one flat shard per owner, then all-gather. Pads to a multiple of the
    axis size so arbitrary leaf shapes shard evenly."""
    flat = g.reshape(-1)
    pad = (-flat.size) % n_dev
    if pad:
        flat = jnp.concatenate(
            [flat, jnp.zeros((pad,), flat.dtype)])
    shard = jax.lax.psum_scatter(flat, axis, scatter_dimension=0,
                                 tiled=True)
    full = jax.lax.all_gather(shard, axis, tiled=True)
    if pad:
        full = full[:g.size]
    return full.reshape(g.shape)


def tree_grad_sync(grads, axis: str, sync: str, n_dev: int):
    """Apply one sync mode to every leaf of a gradient pytree. ``sfb``
    is intentionally absent: SFB does not sync gradients — callers
    broadcast the sufficient factors and recompute (see
    ``repro.exec.engine``'s backward and ``sfb_dense_apply`` below)."""
    if n_dev <= 1:
        return grads
    if sync == "allreduce":
        return jax.tree.map(lambda g: allreduce_grad(g, axis), grads)
    if sync == "ps":
        return jax.tree.map(lambda g: ps_grad(g, axis, n_dev), grads)
    raise ValueError(f"tree_grad_sync cannot apply {sync!r} "
                     f"(use one of allreduce|ps)")


def sfb_dense_apply(mesh: Mesh, axis: str, sync: str):
    """Returns dense(x, w) with x batch-sharded over ``axis``, w replicated,
    and the chosen gradient synchronization executed explicitly.

    custom_vjp sits OUTSIDE shard_map so the only collectives in the
    backward pass are the ones the sync mode asks for (shard_map's own
    transpose would otherwise add a redundant psum for the replicated w).
    """
    assert sync in SYNC_MODES, sync

    fwd_sm = shard_map(lambda x, w: x @ w, mesh=mesh,
                       in_specs=(P(axis, None), P(None, None)),
                       out_specs=P(axis, None), check_rep=False)
    dx_sm = shard_map(lambda dy, w: dy @ w.T, mesh=mesh,
                      in_specs=(P(axis, None), P(None, None)),
                      out_specs=P(axis, None), check_rep=False)

    n_dev = mesh.shape[axis]

    def _dw_local(x, dy):
        if sync == "sfb":
            xg = jax.lax.all_gather(x, axis, tiled=True)
            dyg = jax.lax.all_gather(dy, axis, tiled=True)
            return xg.T @ dyg
        if sync == "ps":
            return ps_grad(x.T @ dy, axis, n_dev)
        return allreduce_grad(x.T @ dy, axis)

    # dw is identical on every shard after the sync -> replicated out_spec
    dw_sm = shard_map(_dw_local, mesh=mesh,
                      in_specs=(P(axis, None), P(axis, None)),
                      out_specs=P(None, None), check_rep=False)

    @jax.custom_vjp
    def dense(x, w):
        return fwd_sm(x, w)

    def fwd(x, w):
        return fwd_sm(x, w), (x, w)

    def bwd(res, dy):
        x, w = res
        return dx_sm(dy, w), dw_sm(x, dy)

    dense.defvjp(fwd, bwd)
    return dense


def dp_mlp_loss(mesh: Mesh, axis: str, sync: str, widths):
    """A small data-parallel MLP whose every layer syncs gradients via the
    chosen mode (used by tests + the SFB example/benchmark)."""
    dense = sfb_dense_apply(mesh, axis, sync)

    def loss_fn(params, x, y):
        h = x
        for i, w in enumerate(params):
            h = dense(h, w)
            if i < len(params) - 1:
                h = jax.nn.relu(h)
        return jnp.mean((h - y) ** 2)
    return loss_fn


def sfb_wire_bytes(batch: int, h1: int, h2: int, d: int,
                   itemsize: int = 4) -> dict:
    """Napkin model of per-step wire bytes (ring collectives)."""
    return {
        "allreduce": 2 * (d - 1) / d * h1 * h2 * itemsize,
        "ps": 2 * (d - 1) / d * h1 * h2 * itemsize,
        "sfb": (d - 1) / d * batch * (h1 + h2) * itemsize * 2,
    }
