"""Stage partitioner: cut a searched Strategy into executable pipeline
stages (paper Fig. 5/6 regime — pipelined stages spanning heterogeneous
device groups).

A ``Strategy`` marks op groups with ``Option.PIPE`` over a placement (a
tuple of device groups). This module turns that into a ``StagePlan``:

  * the **pipeline spine** is the PIPE placement carrying the most
    compute (flops-weighted vote across PIPE actions) — partial
    placements are respected, device groups outside the spine host no
    stage;
  * every op group is assigned to exactly one stage; groups are laid out
    in topological order and cut into contiguous spans whose flops are
    proportional to each stage's device-group compute capacity
    (heterogeneity-aware balance, paper §4.2);
  * each stage carries the gradient-sync mode its member groups voted
    for (AR -> "allreduce", PS -> "ps", DUP -> "sfb", by grad bytes) —
    the §4.2.3 ILP's decisions routed to the real engine;
  * stage boundaries carry the inter-group tensor bytes that cross them,
    so the schedule simulator charges the same activation traffic the
    executed pipeline moves.

``StagePlan.assign_local_devices`` maps the plan onto whatever jax
devices the host actually has (per-stage submeshes, proportional to the
topology's group sizes), raising ``PipelineInfeasible`` when there are
fewer devices than stages — the launcher catches that and falls back to
single-mesh axis rules with a clear warning.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.device import Topology
from repro.core.graph import GroupedGraph
from repro.core.strategy import Option, Strategy

# Option -> runtime gradient-sync mode (parallel/sfb_dense.SYNC_MODES)
OPTION_SYNC = {Option.AR: "allreduce", Option.PS: "ps", Option.DUP: "sfb"}


class PipelineInfeasible(RuntimeError):
    """The host cannot execute this stage map (too few devices)."""


@dataclass
class StageSpec:
    """One pipeline stage: a contiguous span of op groups mapped to one
    topology device group."""
    stage_id: int
    device_group: int            # topology device-group id hosting it
    op_group_ids: list           # op groups assigned (topological order)
    flops: float                 # summed group flops (fwd+bwd trace)
    param_bytes: float
    grad_bytes: float
    out_bytes: float             # activation bytes crossing to stage+1
    sync: str = "allreduce"      # gradient-sync mode within the stage
    n_devices: int = 1           # devices in the topology group
    gpu_type: str = ""           # device type (telemetry attribution)

    def to_dict(self) -> dict:
        return {"stage_id": self.stage_id,
                "device_group": self.device_group,
                "op_group_ids": [int(g) for g in self.op_group_ids],
                "flops": self.flops, "param_bytes": self.param_bytes,
                "grad_bytes": self.grad_bytes, "out_bytes": self.out_bytes,
                "sync": self.sync, "n_devices": self.n_devices,
                "gpu_type": self.gpu_type}

    @classmethod
    def from_dict(cls, d: dict) -> "StageSpec":
        return cls(stage_id=int(d["stage_id"]),
                   device_group=int(d["device_group"]),
                   op_group_ids=list(d["op_group_ids"]),
                   flops=float(d["flops"]),
                   param_bytes=float(d["param_bytes"]),
                   grad_bytes=float(d["grad_bytes"]),
                   out_bytes=float(d["out_bytes"]),
                   sync=d.get("sync", "allreduce"),
                   n_devices=int(d.get("n_devices", 1)),
                   gpu_type=d.get("gpu_type", ""))


@dataclass
class StagePlan:
    """Executable pipeline layout for one strategy on one topology."""
    stages: list                        # list[StageSpec]
    placement: tuple                    # device-group ids (pipeline spine)
    n_micro: int = 4
    topo_name: str = ""
    schedule: str = "1f1b"              # microbatch schedule the PIPE
    #                                     actions voted for (flops-weighted)
    meta: dict = field(default_factory=dict)

    @property
    def n_stages(self) -> int:
        return len(self.stages)

    def flops_fracs(self) -> list:
        tot = sum(s.flops for s in self.stages) or 1.0
        return [s.flops / tot for s in self.stages]

    def layer_splits(self, n_layers: int, n_chunks: int = 1) -> list:
        """Contiguous [lo, hi) layer spans per virtual stage, proportional
        to the stages' flops share (model adapter: map transformer periods
        onto stages). With ``n_chunks > 1`` (interleaved schedules) each
        physical stage hosts ``n_chunks`` model chunks; virtual stage
        ``u = chunk * S + s`` executes the u-th span at 1/n_chunks of the
        stage's flops share. Every span gets >= 0 layers; all layers are
        covered."""
        fracs = self.flops_fracs()
        if n_chunks > 1:
            fracs = [fracs[u % len(fracs)] / n_chunks
                     for u in range(len(fracs) * n_chunks)]
        splits, lo = [], 0
        acc = 0.0
        for s, f in enumerate(fracs):
            acc += f
            hi = n_layers if s == len(fracs) - 1 \
                else min(n_layers, round(acc * n_layers))
            hi = max(hi, lo)
            splits.append((lo, hi))
            lo = hi
        return splits

    def with_carry_bytes(self, nbytes: float) -> "StagePlan":
        """Copy with every interior boundary's bytes replaced by the
        EXECUTED inter-stage carry. The traced graph's cut-crossing bytes
        include tensors the engine never ships (it rematerializes the
        stage forward during backward and only moves the hidden-state
        carry — see the boundary accounting note in ``build_stage_plan``);
        callers that know the model's carry size (batch x seq x d_model x
        dtype) use this to cost schedules against real traffic."""
        import copy
        plan = copy.deepcopy(self)
        for s in plan.stages[:-1]:
            s.out_bytes = float(nbytes)
        return plan

    def assign_local_devices(self, devices) -> list:
        """Map stages onto the host's jax devices: one contiguous slice
        per stage, sized proportionally to the topology group's device
        count (>= 1 each). Raises ``PipelineInfeasible`` when the host
        has fewer devices than stages."""
        devices = list(devices)
        S = self.n_stages
        if len(devices) < S:
            raise PipelineInfeasible(
                f"stage map needs {S} stages but the host has "
                f"{len(devices)} device(s)")
        want = [max(1, s.n_devices) for s in self.stages]
        tot = sum(want)
        # proportional shares, then hand out leftovers largest-first
        share = [max(1, int(len(devices) * w / tot)) for w in want]
        while sum(share) > len(devices):
            share[share.index(max(share))] -= 1
        leftovers = len(devices) - sum(share)
        order = sorted(range(S), key=lambda i: -want[i])
        for i in range(leftovers):
            share[order[i % S]] += 1
        out, base = [], 0
        for k in share:
            out.append(devices[base:base + k])
            base += k
        return out

    def to_dict(self) -> dict:
        return {"stages": [s.to_dict() for s in self.stages],
                "placement": [int(g) for g in self.placement],
                "n_micro": self.n_micro, "topo_name": self.topo_name,
                "schedule": self.schedule, "meta": self.meta}

    @classmethod
    def from_dict(cls, d: dict) -> "StagePlan":
        return cls(stages=[StageSpec.from_dict(s) for s in d["stages"]],
                   placement=tuple(d["placement"]),
                   n_micro=int(d.get("n_micro", 4)),
                   topo_name=d.get("topo_name", ""),
                   schedule=d.get("schedule", "1f1b"),
                   meta=d.get("meta", {}))


def _group_topo_positions(gg: GroupedGraph) -> dict:
    """Mean topological position of each op group's member ops."""
    order = {op: i for i, op in enumerate(gg.base.topo_order())}
    pos = {}
    for g in gg.groups:
        ps = [order[o] for o in g.op_ids if o in order]
        pos[g.group_id] = (sum(ps) / len(ps)) if ps else 0.0
    return pos


def pipeline_spine(strat: Strategy, gg: GroupedGraph,
                   topo: Topology) -> tuple | None:
    """The flops-weighted majority PIPE placement, or None when the
    strategy pipelines nothing (or only within a single device group)."""
    votes: dict = {}
    for gid, a in enumerate(strat.actions):
        if a is None or a.option != Option.PIPE:
            continue
        if len(a.placement) < 2:
            continue                    # single-group PIPE: no real stages
        w = gg.groups[gid].flops if gid < len(gg.groups) else 1.0
        votes[a.placement] = votes.get(a.placement, 0.0) + max(w, 1.0)
    if not votes:
        return None
    return max(votes.items(), key=lambda kv: kv[1])[0]


def vote_schedule(strat: Strategy, gg: GroupedGraph,
                  spine: tuple) -> str:
    """Flops-weighted majority microbatch schedule among the PIPE
    actions on the chosen spine; "1f1b" when none names one (legacy
    strategies searched before the schedule field existed)."""
    votes: dict = {}
    fallback: dict = {}
    for gid, a in enumerate(strat.actions):
        if a is None or a.option != Option.PIPE or not a.schedule:
            continue
        w = gg.groups[gid].flops if gid < len(gg.groups) else 1.0
        fallback[a.schedule] = fallback.get(a.schedule, 0.0) + max(w, 1.0)
        if a.placement == spine:
            votes[a.schedule] = votes.get(a.schedule, 0.0) + max(w, 1.0)
    votes = votes or fallback       # truncated spine: no exact match
    if not votes:
        return "1f1b"
    return max(votes.items(), key=lambda kv: kv[1])[0]


def _refine_cuts(spans: list, order: list, gg: GroupedGraph, caps: list,
                 cap_tot: float, total_flops: float, *,
                 window: int = 4, min_share: float = 0.25,
                 passes: int = 3) -> list:
    """Shift stage boundaries toward cheap cuts (the paper's partition
    objective: minimize crossing tensor bytes under compute balance).

    The capacity-proportional fill above balances flops but is blind to
    activation sizes, so a boundary can land on a huge tensor (e.g. the
    early-conv activations of a VGG) when a few positions over the
    crossing bytes collapse. Each pass slides every cut within a window,
    keeping every stage at >= ``min_share`` of its capacity-proportional
    flops target, and keeps the move only when it lowers total crossing
    bytes.
    """
    S = len(spans)
    if S < 2:
        return spans
    flops = [max(gg.groups[g].flops, 1.0) for g in order]
    pos_of = {g: i for i, g in enumerate(order)}
    cuts = []
    acc = 0
    for span in spans[:-1]:
        acc += len(span)
        cuts.append(acc)                # stage k = order[cuts[k-1]:cuts[k]]

    def stage_of(idx: int, cuts_) -> int:
        for k, c in enumerate(cuts_):
            if idx < c:
                return k
        return S - 1

    def cut_bytes(cuts_) -> float:
        # consecutive-stage crossings only — matching what the executed
        # pipeline moves (see the boundary accounting note below)
        return sum(b for (gi, gj), b in gg.edges.items()
                   if stage_of(pos_of[gj], cuts_)
                   == stage_of(pos_of[gi], cuts_) + 1)

    def feasible(cuts_) -> bool:
        bounds = [0, *cuts_, len(order)]
        for k in range(S):
            lo, hi = bounds[k], bounds[k + 1]
            if hi <= lo:
                return False
            target = caps[k] / cap_tot * total_flops
            if sum(flops[lo:hi]) < min_share * target:
                return False
        return True

    best = cut_bytes(cuts)
    for _ in range(passes):
        improved = False
        for k in range(S - 1):
            for delta in range(-window, window + 1):
                if delta == 0:
                    continue
                cand = list(cuts)
                cand[k] += delta
                if not (0 < cand[k] <= len(order) - 1):
                    continue
                if k > 0 and cand[k] <= cand[k - 1]:
                    continue
                if k < S - 2 and cand[k] >= cand[k + 1]:
                    continue
                if not feasible(cand):
                    continue
                b = cut_bytes(cand)
                if b < best:
                    best, cuts, improved = b, cand, True
        if not improved:
            break
    bounds = [0, *cuts, len(order)]
    return [order[bounds[k]:bounds[k + 1]] for k in range(S)]


def build_stage_plan(gg: GroupedGraph, strat: Strategy, topo: Topology,
                     *, n_micro: int = 4) -> StagePlan | None:
    """Cut ``gg`` at the strategy's PIPE boundaries into a StagePlan.

    Returns ``None`` when the strategy contains no multi-group PIPE
    action — the single-mesh lowering in ``core.plan`` stays in charge.
    """
    spine = pipeline_spine(strat, gg, topo)
    if spine is None:
        return None
    if gg.n < len(spine):               # degenerate: fewer op groups than
        spine = spine[:max(gg.n, 2)]    # stages — truncate the spine
        if len(spine) < 2:
            return None
    S = len(spine)
    # capacity-proportional flops targets per stage
    caps = [topo.groups[g].flops * topo.groups[g].num_gpus for g in spine]
    cap_tot = sum(caps) or 1.0

    pos = _group_topo_positions(gg)
    order = sorted(range(gg.n), key=lambda g: (pos[g], g))
    total_flops = sum(max(gg.groups[g].flops, 1.0) for g in order)

    # contiguous spans: stage s closes once its cumulative capacity share
    # is filled (or when the remaining stages need every remaining group)
    spans: list = [[] for _ in range(S)]
    acc, s = 0.0, 0
    for idx, g in enumerate(order):
        target = sum(caps[:s + 1]) / cap_tot * total_flops
        left = len(order) - idx
        if spans[s] and s < S - 1 and (acc >= target
                                       or left <= S - s - 1):
            s += 1
        spans[s].append(g)
        acc += max(gg.groups[g].flops, 1.0)
    if any(not span for span in spans):
        # capacity targets left a stage empty (tiny graphs): fall back to
        # contiguous near-equal-count chunks, preserving topo order
        spans = [[] for _ in range(S)]
        for i, g in enumerate(order):
            spans[min(i * S // len(order), S - 1)].append(g)
    spans = _refine_cuts(spans, order, gg, caps, cap_tot, total_flops)

    gid_stage = {g: si for si, span in enumerate(spans) for g in span}
    stages = []
    for si, span in enumerate(spans):
        # Boundary bytes = edges into the NEXT stage only. The flat
        # fwd+bwd trace contains long-range activation->backward edges
        # (a forward op early in topo order feeding a grad op late in
        # it); the execution engine rematerializes the stage forward
        # on-stage during backward, so those tensors never cross a
        # boundary at runtime — only the consecutive carry does.
        out_bytes = sum(
            b for (gi, gj), b in gg.edges.items()
            if gid_stage.get(gi) == si and gid_stage.get(gj, si) == si + 1)
        sync_votes: dict = {}
        for g in span:
            a = strat.actions[g]
            if a is None:
                continue
            mode = OPTION_SYNC.get(a.option)
            if mode is not None and gg.groups[g].has_grad:
                w = max(gg.groups[g].grad_bytes, 1.0)
                sync_votes[mode] = sync_votes.get(mode, 0.0) + w
        sync = max(sync_votes.items(), key=lambda kv: kv[1])[0] \
            if sync_votes else "allreduce"
        dg = topo.groups[spine[si]]
        stages.append(StageSpec(
            stage_id=si, device_group=spine[si], op_group_ids=span,
            flops=sum(gg.groups[g].flops for g in span),
            param_bytes=sum(gg.groups[g].param_bytes for g in span),
            grad_bytes=sum(gg.groups[g].grad_bytes for g in span),
            out_bytes=out_bytes, sync=sync, n_devices=dg.num_gpus,
            gpu_type=dg.gpu_type))
    return StagePlan(stages=stages, placement=spine, n_micro=n_micro,
                     topo_name=topo.name,
                     schedule=vote_schedule(strat, gg, spine),
                     meta={"n_groups": gg.n,
                           "pipe_groups": sum(
                               1 for a in strat.actions
                               if a is not None
                               and a.option == Option.PIPE)})
