"""Pipeline execution engine: run a StagePlan as a REAL multi-stage
jax train step.

The engine executes a microbatch schedule (``exec.schedule``) eagerly:
per-stage jitted forward / backward callables, ``device_put`` boundary
transfers for activations and activation-grads, per-stage data
parallelism via ``shard_map`` submeshes, and explicit AR / PS / SFB
parameter-gradient synchronization (the §4.2.3 ILP's decisions routed
through ``parallel.sfb_dense``'s primitives).

Backward recomputes the stage forward (GPipe-style rematerialization):
each backward callable re-runs the stage on the stashed *input* and
vjp's through it, so only boundary activations are stashed — the stash
count follows the schedule's ``peak_stash`` exactly.

Gradient semantics (proved by the parity tests): the global step loss is
the mean over microbatches of the mean over stage-DP shards of the local
loss. The engine seeds the last stage's backward with ``1/ndev_last``,
syncs parameter grads with a plain sum (psum / reduce-scatter+gather /
SFB gather-recompute), accumulates over microbatches, and divides by
``n_micro`` — bit-comparable to the single-device gradient.
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.exec.schedule import flatten_schedule, make_schedule
from repro.parallel.sfb_dense import tree_grad_sync


def _batch_spec(x, ndev: int):
    shape = getattr(x, "shape", ())
    if len(shape) >= 1 and shape[0] and shape[0] % ndev == 0:
        return P("dp", *([None] * (len(shape) - 1)))
    return P()


def _specs(tree, ndev: int):
    return jax.tree.map(lambda x: _batch_spec(x, ndev), tree)


def _gather(tree, specs):
    """All-gather the batch-sharded leaves (SFB: move the sufficient
    factors, not the parameter gradients)."""
    if tree is None:
        return None

    def g(x, spec):
        if spec is not None and "dp" in [a for a in spec if a]:
            return jax.lax.all_gather(x, "dp", tiled=True)
        return x
    return jax.tree.map(g, tree, specs)


def split_microbatches(batch: dict, n_micro: int) -> list:
    """Split every batch leaf into ``n_micro`` equal chunks on dim 0."""
    sizes = {k: v.shape[0] for k, v in batch.items()}
    for k, b in sizes.items():
        if b % n_micro:
            raise ValueError(
                f"batch dim {b} of {k!r} not divisible by "
                f"n_micro={n_micro}")
    out = []
    for m in range(n_micro):
        out.append({k: v[m * (v.shape[0] // n_micro):
                         (m + 1) * (v.shape[0] // n_micro)]
                    for k, v in batch.items()})
    return out


@dataclass
class StepStats:
    loss: float
    metrics: dict
    wall_time: float
    events: list = field(default_factory=list)   # (kind, stage, mb, dur)
    peak_stash: int = 0


class PipelineRunner:
    """Execute stage functions under a microbatch schedule.

    ``stage_fns[s]`` has signature ``fn(params_s, carry, mb) -> carry``
    (``(loss, metrics)`` for the last stage); ``device_sets[s]`` lists
    the jax devices hosting stage ``s`` (>1 devices = per-stage data
    parallelism over a "dp" submesh, grad sync per ``plan.stages[s]
    .sync``). ``mb_keys[s]`` names the microbatch entries the stage
    consumes (default: all).
    """

    def __init__(self, stage_fns, plan, device_sets, *,
                 schedule: str = "1f1b", n_micro: int | None = None,
                 mb_keys=None, tied_ref=None, store=None,
                 graph_fp: str = "", topo_fp: str = "",
                 meta: dict | None = None):
        self.fns = list(stage_fns)
        self.plan = plan
        self.S = len(stage_fns)
        assert len(device_sets) == self.S, (len(device_sets), self.S)
        self.device_sets = [list(d) for d in device_sets]
        self.schedule = schedule
        self.n_micro = int(n_micro or plan.n_micro)
        self.mb_keys = mb_keys
        self.tied_ref = tied_ref
        self.store = store
        self.graph_fp, self.topo_fp = graph_fp, topo_fp
        self.meta = dict(meta or {})
        self.syncs = [plan.stages[s].sync if s < len(plan.stages)
                      else "allreduce" for s in range(self.S)]
        self.meshes = [
            Mesh(np.asarray(devs), ("dp",)) if len(devs) > 1 else None
            for devs in self.device_sets]
        order = make_schedule(schedule, self.S, self.n_micro)
        self.flat = flatten_schedule(order, self.S, self.n_micro)
        self._fwd = [None] * self.S
        self._bwd = [None] * self.S

    # ------------------------------------------------------- placement
    def _ndev(self, s: int) -> int:
        return len(self.device_sets[s])

    def place(self, s: int, tree, *, batch: bool = False):
        """Commit a pytree to stage ``s``'s devices (replicated params,
        batch-sharded activations on multi-device stages)."""
        if tree is None:
            return None
        mesh = self.meshes[s]
        if mesh is None:
            return jax.device_put(tree, self.device_sets[s][0])
        ndev = self._ndev(s)
        specs = _specs(tree, ndev) if batch \
            else jax.tree.map(lambda _: P(), tree)
        shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                                 is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(tree, shardings)

    def place_params(self, params_list) -> list:
        return [self.place(s, p) for s, p in enumerate(params_list)]

    def _mb_for(self, s: int, mb: dict) -> dict:
        if self.mb_keys is None:
            return mb
        return {k: mb[k] for k in self.mb_keys[s] if k in mb}

    # ------------------------------------------------------- compiled fns
    def _build(self, s: int, p_ex, c_ex, mb_ex):
        """Compile stage ``s``'s forward and backward callables."""
        fn = self.fns[s]
        is_last = s == self.S - 1
        ndev = self._ndev(s)
        mesh = self.meshes[s]
        sync = self.syncs[s]

        if mesh is None:
            if is_last:
                def fwd(p, c, mb):
                    loss, mets = fn(p, c, mb)
                    return loss[None], jax.tree.map(lambda v: v[None], mets)

                def bwd(p, c, mb, dout):
                    f = lambda pp, cc: fn(pp, cc, mb)[0]       # noqa: E731
                    _, vjp = jax.vjp(f, p, c)
                    return vjp(dout)
            else:
                fwd = fn

                def bwd(p, c, mb, dout):
                    f = lambda pp, cc: fn(pp, cc, mb)          # noqa: E731
                    _, vjp = jax.vjp(f, p, c)
                    return vjp(dout)
            self._fwd[s], self._bwd[s] = jax.jit(fwd), jax.jit(bwd)
            return

        p_specs = jax.tree.map(lambda _: P(), p_ex)
        c_specs = _specs(c_ex, ndev)
        mb_specs = _specs(mb_ex, ndev)

        if is_last:
            def fwd_body(p, c, mb):
                loss, mets = fn(p, c, mb)
                return loss[None], jax.tree.map(lambda v: v[None], mets)
            mets_ex = jax.eval_shape(fn, p_ex, c_ex, mb_ex)[1]
            fwd_out_specs = (P("dp"),
                             jax.tree.map(lambda _: P("dp"), mets_ex))
            dout_specs = P()
        else:
            fwd_body = fn
            out_ex = jax.eval_shape(fn, p_ex, c_ex, mb_ex)
            fwd_out_specs = _specs(out_ex, ndev)
            dout_specs = fwd_out_specs                  # cotangent of out

        def bwd_body(p, c, mb, dout):
            if is_last:
                f_loc = lambda pp, cc: fn(pp, cc, mb)[0]       # noqa: E731
            else:
                f_loc = lambda pp, cc: fn(pp, cc, mb)          # noqa: E731
            if sync == "sfb":
                # sufficient factors (inputs + output grads) on the wire,
                # parameter grads recomputed locally on the full batch
                c_g = _gather(c, c_specs)
                mb_g = _gather(mb, mb_specs)
                if is_last:
                    fg = lambda pp: fn(pp, c_g, mb_g)[0]       # noqa: E731
                    seed = dout * ndev          # 1/ndev -> 1: gathered
                    #                             loss is the global mean
                else:
                    fg = lambda pp: fn(pp, c_g, mb_g)          # noqa: E731
                    seed = _gather(dout, dout_specs)
                _, vjp_g = jax.vjp(fg, p)
                dp, = vjp_g(seed)
                _, vjp_l = jax.vjp(lambda cc: f_loc(p, cc), c)
                dc, = vjp_l(dout)
            else:
                _, vjp = jax.vjp(f_loc, p, c)
                dp, dc = vjp(dout)
                dp = tree_grad_sync(dp, "dp", sync, ndev)
            return dp, dc

        self._fwd[s] = jax.jit(shard_map(
            fwd_body, mesh=mesh, in_specs=(p_specs, c_specs, mb_specs),
            out_specs=fwd_out_specs, check_rep=False))
        self._bwd[s] = jax.jit(shard_map(
            bwd_body, mesh=mesh,
            in_specs=(p_specs, c_specs, mb_specs, dout_specs),
            out_specs=(p_specs, c_specs), check_rep=False))

    # ------------------------------------------------------------- step
    def step(self, params_list, batch, *, record: bool = False) -> tuple:
        """One pipelined train step.

        Returns ``(grads_list, StepStats)``; grads match the structure of
        ``params_list`` (tied-head gradient already folded back into the
        stage-0 embedding).
        """
        t_start = time.perf_counter()
        mbs = split_microbatches(batch, self.n_micro)
        S, M = self.S, self.n_micro

        params_eff = list(params_list)
        if self.tied_ref is not None:
            src_key, dst_key = self.tied_ref
            head = self.place(S - 1, params_list[0][src_key])
            params_eff[S - 1] = dict(params_list[S - 1], **{dst_key: head})

        mb_cache: dict = {}             # (s, m) -> placed microbatch

        def mb_at(s, m):
            if (s, m) not in mb_cache:
                mb_cache[(s, m)] = self.place(
                    s, self._mb_for(s, mbs[m]), batch=True)
            return mb_cache[(s, m)]

        outs: dict = {}                 # (s, m) -> stage output carry
        stage_in: dict = {}             # (s, m) -> placed input (stash)
        dcs: dict = {}                  # (s, m) -> d loss / d input of s
        grads: list = [None] * S
        losses, mets_acc = [], []
        events, stash, peak = [], 0, 0
        seed_last = 1.0 / self._ndev(S - 1)

        for ev in self.flat:
            s, m = ev.stage, ev.mb
            t0 = time.perf_counter()
            if ev.kind == "F":
                carry = None
                if s > 0:
                    carry = self.place(s, outs.pop((s - 1, m)), batch=True)
                stage_in[(s, m)] = carry
                stash += 1
                peak = max(peak, stash)
                mb = mb_at(s, m)
                if self._fwd[s] is None:
                    self._build(s, params_eff[s], carry, mb)
                out = self._fwd[s](params_eff[s], carry, mb)
                if s == S - 1:
                    loss, mets = out
                    losses.append(loss)
                    mets_acc.append(mets)
                else:
                    outs[(s, m)] = out
                if record:
                    jax.block_until_ready(out)
            else:
                if s == S - 1:
                    dout = jnp.asarray(seed_last, jnp.float32)
                else:
                    dout = self.place(s, dcs.pop((s + 1, m)), batch=True)
                carry = stage_in.pop((s, m))
                stash -= 1
                dp, dc = self._bwd[s](params_eff[s], carry, mb_at(s, m),
                                      dout)
                grads[s] = dp if grads[s] is None else jax.tree.map(
                    jnp.add, grads[s], dp)
                if s > 0:
                    dcs[(s, m)] = dc
                if record:
                    jax.block_until_ready(dp)
            if record:
                events.append((ev.kind, s, m, time.perf_counter() - t0))

        grads = [jax.tree.map(lambda g: g / M, g_s) for g_s in grads]
        if self.tied_ref is not None:
            src_key, dst_key = self.tied_ref
            dhead = grads[S - 1].pop(dst_key)
            dhead = self.place(0, dhead)
            grads[0] = dict(grads[0], **{
                src_key: grads[0][src_key] + dhead})

        loss = float(jnp.mean(jnp.concatenate(
            [jnp.atleast_1d(x) for x in losses])))
        metrics = {}
        for k in mets_acc[0]:
            metrics[k] = float(np.mean(
                [float(jnp.mean(mm[k])) for mm in mets_acc]))
        wall = time.perf_counter() - t_start
        stats = StepStats(loss=loss, metrics=metrics, wall_time=wall,
                          events=events, peak_stash=peak)
        if self.store is not None:
            self._record_telemetry(stats)
        return grads, stats

    # -------------------------------------------------------- telemetry
    def _record_telemetry(self, stats: StepStats):
        from repro.runtime.telemetry import StepRecord
        from repro.exec.schedule import FWD_FRAC
        compute = []
        for kind, s, m, dur in stats.events:
            spec = self.plan.stages[s] if s < len(self.plan.stages) else None
            flops_m = (spec.flops / self.n_micro) if spec else 0.0
            frac = FWD_FRAC if kind == "F" else 1.0 - FWD_FRAC
            compute.append({
                "gpu_type": getattr(spec, "gpu_type", "") or "",
                "flops": flops_m * frac, "time": dur,
                "stage": s, "mb": m, "kind": kind})
        rec = StepRecord(
            graph_fp=self.graph_fp, topo_fp=self.topo_fp,
            wall_time=stats.wall_time, compute=compute,
            meta=dict(self.meta, executor="pipeline",
                      schedule=self.schedule, n_stages=self.S,
                      n_micro=self.n_micro, loss=stats.loss,
                      peak_stash=stats.peak_stash))
        self.store.append(rec)
