"""Pipeline execution engines: run a StagePlan as a REAL multi-stage
jax train step.

Two engines share the same per-microbatch stage math (``_make_bodies``):

  * ``PipelineRunner`` executes the microbatch schedule
    (``exec.schedule``) eagerly — per-stage jitted forward / backward
    callables dispatched per event, ``device_put`` boundary transfers
    for activations and activation-grads, per-stage data parallelism
    via ``shard_map`` submeshes, and explicit AR / PS / SFB
    parameter-gradient synchronization (the §4.2.3 ILP's decisions
    routed through ``parallel.sfb_dense``'s primitives).
  * ``CompiledPipelineRunner`` rolls the same bodies into per-stage
    ``jax.lax.scan`` programs (O(stages) compiled dispatches per step,
    compile time flat in ``n_micro * n_chunks``) with bulk
    double-buffered boundary transfers; see its docstring for the
    memory/overlap trade.

Two schedule extensions execute for real here:

  * **interleaved** (virtual stages): ``n_chunks`` model chunks per
    physical stage — ``stage_fns`` has ``S * n_chunks`` entries, virtual
    stage ``u = chunk * S + s`` running on physical stage ``s``'s
    devices; chunk boundaries wrap from the last physical stage back to
    the first, exactly the extra transfers the schedule simulator
    charges.
  * **zb** (zero-bubble): the backward splits into an activation-grad
    half (``B`` events, on the cross-stage critical path) and a
    weight-grad half (``W`` events, stage-local). Each half re-runs the
    stage forward and vjp's through it, so the split costs one extra
    rematerialization — the price of freeing the B chain.

Backward recomputes the stage forward (GPipe-style rematerialization):
each backward callable re-runs the stage on the stashed *input* and
vjp's through it, so only boundary activations are stashed — the stash
count follows the schedule's ``peak_stash`` exactly (``W`` releases the
stash under zb).

Gradient semantics (proved by the parity tests): the global step loss is
the mean over microbatches of the mean over stage-DP shards of the local
loss. The engine seeds the last stage's backward with ``1/ndev_last``,
syncs parameter grads with a plain sum (psum / reduce-scatter+gather /
SFB gather-recompute), accumulates over microbatches, and divides by
``n_micro`` — bit-comparable to the single-device gradient.
"""
from __future__ import annotations

from dataclasses import dataclass, field
import time

import jax
from jax.experimental.shard_map import shard_map
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
import numpy as np

from repro.exec.schedule import flatten_schedule, make_schedule
from repro.parallel.sfb_dense import tree_grad_sync
from repro.verify.diagnostics import PlanVerificationError


def _batch_spec(x, ndev: int):
    shape = getattr(x, "shape", ())
    if len(shape) >= 1 and shape[0] and shape[0] % ndev == 0:
        return P("dp", *([None] * (len(shape) - 1)))
    return P()


def _specs(tree, ndev: int):
    return jax.tree.map(lambda x: _batch_spec(x, ndev), tree)


def _gather(tree, specs):
    """All-gather the batch-sharded leaves (SFB: move the sufficient
    factors, not the parameter gradients)."""
    if tree is None:
        return None

    def g(x, spec):
        if spec is not None and "dp" in [a for a in spec if a]:
            return jax.lax.all_gather(x, "dp", tiled=True)
        return x
    return jax.tree.map(g, tree, specs)


def stack_microbatches(batch: dict, n_micro: int) -> dict:
    """Reshape every batch leaf to ``[n_micro, per_mb, ...]`` — the scan
    engine's stacked layout; row ``m`` is exactly
    ``split_microbatches(batch, n_micro)[m]``."""
    for k, v in batch.items():
        if v.shape[0] % n_micro:
            raise ValueError(
                f"batch dim {v.shape[0]} of {k!r} not divisible by "
                f"n_micro={n_micro}")
    return {k: v.reshape(n_micro, v.shape[0] // n_micro, *v.shape[1:])
            for k, v in batch.items()}


def split_microbatches(batch: dict, n_micro: int) -> list:
    """Split every batch leaf into ``n_micro`` equal chunks on dim 0."""
    sizes = {k: v.shape[0] for k, v in batch.items()}
    for k, b in sizes.items():
        if b % n_micro:
            raise ValueError(
                f"batch dim {b} of {k!r} not divisible by "
                f"n_micro={n_micro}")
    out = []
    for m in range(n_micro):
        out.append({k: v[m * (v.shape[0] // n_micro):
                         (m + 1) * (v.shape[0] // n_micro)]
                    for k, v in batch.items()})
    return out


@dataclass
class StepStats:
    loss: float
    metrics: dict
    wall_time: float
    events: list = field(default_factory=list)  # (kind, stage, mb, dur,
    #                                              chunk, start) — start
    #                                              is seconds from step
    #                                              begin
    peak_stash: int = 0


class PipelineRunner:
    """Execute stage functions under a microbatch schedule.

    ``stage_fns[u]`` has signature ``fn(params_u, carry, mb) -> carry``
    (``(loss, metrics)`` for the last virtual stage); with
    ``n_chunks > 1`` there are ``S * n_chunks`` virtual stages, virtual
    stage ``u`` running on physical stage ``u % S``. ``device_sets[s]``
    lists the jax devices hosting physical stage ``s`` (>1 devices =
    per-stage data parallelism over a "dp" submesh, grad sync per
    ``plan.stages[s].sync``). ``mb_keys[u]`` names the microbatch
    entries virtual stage ``u`` consumes (default: all).
    """

    def __init__(self, stage_fns, plan, device_sets, *,
                 schedule: str = "1f1b", n_micro: int | None = None,
                 n_chunks: int = 1, mb_keys=None, tied_ref=None,
                 store=None, graph_fp: str = "", topo_fp: str = "",
                 meta: dict | None = None, spool=None):
        self.fns = list(stage_fns)
        self.plan = plan
        self.S = len(device_sets)
        self.V = max(1, int(n_chunks))
        if self.V > 1 and schedule != "interleaved":
            # only the interleaved generator emits chunked events; any
            # other schedule would leave virtual stages S..U-1 unscheduled
            # and fail deep inside the event loop
            raise ValueError(
                f"n_chunks={self.V} requires schedule='interleaved' "
                f"(got {schedule!r})")
        self.U = self.S * self.V
        assert len(self.fns) == self.U, (len(self.fns), self.S, self.V)
        self.device_sets = [list(d) for d in device_sets]
        self.schedule = schedule
        self.n_micro = int(n_micro or plan.n_micro)
        self.mb_keys = mb_keys
        self.tied_ref = tied_ref
        self.store = store
        # live-observability spool (obs.collector.SpoolWriter): recorded
        # step events stream into this process's shard for the
        # cross-process trace collector
        self.spool = spool
        self._spool_tracks_done = False
        self.graph_fp, self.topo_fp = graph_fp, topo_fp
        self.meta = dict(meta or {})
        self.syncs = [plan.stages[s].sync if s < len(plan.stages)
                      else "allreduce" for s in range(self.S)]
        self.meshes = [
            Mesh(np.asarray(devs), ("dp",)) if len(devs) > 1 else None
            for devs in self.device_sets]
        order = make_schedule(schedule, self.S, self.n_micro,
                              n_chunks=self.V)
        # static preflight: prove the event lists deadlock/race-free and
        # the plan's collectives well-formed for the device sets we were
        # actually handed, before any compile or transfer happens (lazy
        # import: repro.verify.verifier imports repro.exec.schedule)
        from repro.verify.verifier import (
            verify_preflight, verify_schedule)
        if getattr(plan, "n_stages", None) == self.S:
            pre = verify_preflight(
                plan, order, self.n_micro, n_chunks=self.V,
                device_counts=[len(d) for d in self.device_sets])
        else:
            pre = verify_schedule(order, self.S, self.n_micro,
                                  n_chunks=self.V)
        if pre.errors():
            raise PlanVerificationError(
                pre, context=f"pipeline preflight ({schedule}, "
                             f"S={self.S}, n_micro={self.n_micro})")
        self.flat = flatten_schedule(order, self.S, self.n_micro)
        self.has_w = any(e.kind == "W" for e in self.flat)
        self._fwd = [None] * self.U
        self._bwd = [None] * self.U          # joint (dp, dc)
        self._bwd_act = [None] * self.U      # zb: dc only
        self._bwd_wgt = [None] * self.U      # zb: dp only
        self.last_stats = None               # StepStats of the last step

    # ------------------------------------------------------- placement
    def phys(self, u: int) -> int:
        """Physical stage hosting virtual stage ``u``."""
        return u % self.S

    def _ndev(self, s: int) -> int:
        return len(self.device_sets[s])

    def place(self, s: int, tree, *, batch: bool = False):
        """Commit a pytree to physical stage ``s``'s devices (replicated
        params, batch-sharded activations on multi-device stages)."""
        if tree is None:
            return None
        mesh = self.meshes[s]
        if mesh is None:
            return jax.device_put(tree, self.device_sets[s][0])
        ndev = self._ndev(s)
        specs = _specs(tree, ndev) if batch \
            else jax.tree.map(lambda _: P(), tree)
        shardings = jax.tree.map(lambda sp: NamedSharding(mesh, sp), specs,
                                 is_leaf=lambda x: isinstance(x, P))
        return jax.device_put(tree, shardings)

    def place_params(self, params_list) -> list:
        return [self.place(self.phys(u), p)
                for u, p in enumerate(params_list)]

    def _mb_for(self, u: int, mb: dict) -> dict:
        if self.mb_keys is None:
            return mb
        return {k: mb[k] for k in self.mb_keys[u] if k in mb}

    # ------------------------------------------------------- compiled fns
    def _make_bodies(self, u: int, p_ex, c_ex, mb_ex) -> dict:
        """Un-jitted per-microbatch bodies of virtual stage ``u`` — the
        single source of the stage math both engines compile. The eager
        engine jits each body and dispatches it per event; the scan
        engine rolls the same bodies into per-stage ``lax.scan``
        programs, so gradient parity between the engines is structural.
        Multi-device stages also carry the shard_map partition specs
        (``mesh`` is None on single-device stages)."""
        fn = self.fns[u]
        is_last = u == self.U - 1
        s = self.phys(u)
        ndev = self._ndev(s)
        mesh = self.meshes[s]
        sync = self.syncs[s]

        if mesh is None:
            if is_last:
                def fwd(p, c, mb):
                    loss, mets = fn(p, c, mb)
                    return loss[None], jax.tree.map(lambda v: v[None], mets)

                def f_of(p, c, mb):
                    return fn(p, c, mb)[0]
            else:
                fwd = fn
                f_of = fn

            def bwd(p, c, mb, dout):
                _, vjp = jax.vjp(lambda pp, cc: f_of(pp, cc, mb), p, c)
                return vjp(dout)

            def bwd_act(p, c, mb, dout):
                _, vjp = jax.vjp(lambda cc: f_of(p, cc, mb), c)
                return vjp(dout)[0]

            def bwd_wgt(p, c, mb, dout):
                _, vjp = jax.vjp(lambda pp: f_of(pp, c, mb), p)
                return vjp(dout)[0]

            return {"mesh": None, "fwd": fwd, "bwd": bwd,
                    "bwd_act": bwd_act, "bwd_wgt": bwd_wgt}

        p_specs = jax.tree.map(lambda _: P(), p_ex)
        c_specs = _specs(c_ex, ndev)
        mb_specs = _specs(mb_ex, ndev)

        if is_last:
            def fwd_body(p, c, mb):
                loss, mets = fn(p, c, mb)
                return loss[None], jax.tree.map(lambda v: v[None], mets)
            mets_ex = jax.eval_shape(fn, p_ex, c_ex, mb_ex)[1]
            fwd_out_specs = (P("dp"),
                             jax.tree.map(lambda _: P("dp"), mets_ex))
            dout_specs = P()
        else:
            fwd_body = fn
            out_ex = jax.eval_shape(fn, p_ex, c_ex, mb_ex)
            fwd_out_specs = _specs(out_ex, ndev)
            dout_specs = fwd_out_specs                  # cotangent of out

        def f_loc(p, c, mb):
            return fn(p, c, mb)[0] if is_last else fn(p, c, mb)

        def dp_of(p, c, mb, dout):
            """Parameter gradient with the stage's sync mode applied."""
            if sync == "sfb":
                # sufficient factors (inputs + output grads) on the wire,
                # parameter grads recomputed locally on the full batch
                c_g = _gather(c, c_specs)
                mb_g = _gather(mb, mb_specs)
                if is_last:
                    seed = dout * ndev          # 1/ndev -> 1: gathered
                    #                             loss is the global mean
                else:
                    seed = _gather(dout, dout_specs)
                _, vjp_g = jax.vjp(lambda pp: f_loc(pp, c_g, mb_g), p)
                dp, = vjp_g(seed)
                return dp
            _, vjp = jax.vjp(lambda pp: f_loc(pp, c, mb), p)
            dp, = vjp(dout)
            return tree_grad_sync(dp, "dp", sync, ndev)

        def dc_of(p, c, mb, dout):
            _, vjp_l = jax.vjp(lambda cc: f_loc(p, cc, mb), c)
            dc, = vjp_l(dout)
            return dc

        def bwd_body(p, c, mb, dout):
            return dp_of(p, c, mb, dout), dc_of(p, c, mb, dout)

        return {"mesh": mesh, "fwd": fwd_body, "bwd": bwd_body,
                "bwd_act": dc_of, "bwd_wgt": dp_of,
                "p_specs": p_specs, "c_specs": c_specs,
                "mb_specs": mb_specs, "fwd_out_specs": fwd_out_specs,
                "dout_specs": dout_specs}

    def _build(self, u: int, p_ex, c_ex, mb_ex):
        """Compile virtual stage ``u``'s forward and backward callables
        (joint backward, plus the split activation-grad / weight-grad
        pair when the schedule zero-bubbles)."""
        B = self._make_bodies(u, p_ex, c_ex, mb_ex)
        mesh = B["mesh"]
        if mesh is None:
            self._fwd[u] = jax.jit(B["fwd"])
            if self.has_w:
                self._bwd_act[u] = jax.jit(B["bwd_act"])
                self._bwd_wgt[u] = jax.jit(B["bwd_wgt"])
            else:
                self._bwd[u] = jax.jit(B["bwd"])
            return

        self._fwd[u] = jax.jit(shard_map(
            B["fwd"], mesh=mesh,
            in_specs=(B["p_specs"], B["c_specs"], B["mb_specs"]),
            out_specs=B["fwd_out_specs"], check_rep=False))
        in_specs = (B["p_specs"], B["c_specs"], B["mb_specs"],
                    B["dout_specs"])
        if self.has_w:
            self._bwd_act[u] = jax.jit(shard_map(
                B["bwd_act"], mesh=mesh, in_specs=in_specs,
                out_specs=B["c_specs"], check_rep=False))
            self._bwd_wgt[u] = jax.jit(shard_map(
                B["bwd_wgt"], mesh=mesh, in_specs=in_specs,
                out_specs=B["p_specs"], check_rep=False))
        else:
            self._bwd[u] = jax.jit(shard_map(
                B["bwd"], mesh=mesh, in_specs=in_specs,
                out_specs=(B["p_specs"], B["c_specs"]), check_rep=False))

    # ------------------------------------------------------------- step
    def step(self, params_list, batch, *, record: bool = False) -> tuple:
        """One pipelined train step.

        Returns ``(grads_list, StepStats)``; grads match the structure of
        ``params_list`` (one entry per virtual stage; tied-head gradient
        already folded back into the stage-0 embedding).
        """
        t_start = time.perf_counter()
        record = record or self.spool is not None   # spooling needs events
        mbs = split_microbatches(batch, self.n_micro)
        S, U, M = self.S, self.U, self.n_micro

        params_eff = list(params_list)
        if self.tied_ref is not None:
            src_key, dst_key = self.tied_ref
            head = self.place(self.phys(U - 1), params_list[0][src_key])
            params_eff[U - 1] = dict(params_list[U - 1], **{dst_key: head})

        mb_cache: dict = {}             # (u, m) -> placed microbatch

        def mb_at(u, m):
            if (u, m) not in mb_cache:
                mb_cache[(u, m)] = self.place(
                    self.phys(u), self._mb_for(u, mbs[m]), batch=True)
            return mb_cache[(u, m)]

        outs: dict = {}                 # (u, m) -> stage output carry
        stage_in: dict = {}             # (u, m) -> placed input (stash)
        dcs: dict = {}                  # (u, m) -> d loss / d input of u
        w_dout: dict = {}               # (u, m) -> dout stashed for W (zb)
        grads: list = [None] * U
        losses, mets_acc = [], []
        events, stash, peak = [], 0, 0
        seed_last = 1.0 / self._ndev(self.phys(U - 1))

        for ev in self.flat:
            s, m = ev.stage, ev.mb
            u = ev.chunk * S + s
            t0 = time.perf_counter()
            if ev.kind == "F":
                carry = None
                if u > 0:
                    carry = self.place(s, outs.pop((u - 1, m)), batch=True)
                stage_in[(u, m)] = carry
                stash += 1
                peak = max(peak, stash)
                mb = mb_at(u, m)
                if self._fwd[u] is None:
                    self._build(u, params_eff[u], carry, mb)
                out = self._fwd[u](params_eff[u], carry, mb)
                if u == U - 1:
                    loss, mets = out
                    losses.append(loss)
                    mets_acc.append(mets)
                else:
                    outs[(u, m)] = out
                if record:
                    jax.block_until_ready(out)
            elif ev.kind == "B":
                if u == U - 1:
                    dout = jnp.asarray(seed_last, jnp.float32)
                else:
                    dout = self.place(s, dcs.pop((u + 1, m)), batch=True)
                if self.has_w:
                    # zero-bubble: activation grad only; the stash (and
                    # dout) stay pinned until this microbatch's W
                    carry = stage_in[(u, m)]
                    dc = self._bwd_act[u](params_eff[u], carry,
                                          mb_at(u, m), dout)
                    w_dout[(u, m)] = dout
                    if u > 0:
                        dcs[(u, m)] = dc
                    if record:
                        jax.block_until_ready(dc)
                else:
                    carry = stage_in.pop((u, m))
                    stash -= 1
                    dp, dc = self._bwd[u](params_eff[u], carry,
                                          mb_at(u, m), dout)
                    grads[u] = dp if grads[u] is None else jax.tree.map(
                        jnp.add, grads[u], dp)
                    if u > 0:
                        dcs[(u, m)] = dc
                    if record:
                        jax.block_until_ready(dp)
            else:                       # "W": weight grad, releases stash
                carry = stage_in.pop((u, m))
                stash -= 1
                dout = w_dout.pop((u, m))
                dp = self._bwd_wgt[u](params_eff[u], carry, mb_at(u, m),
                                      dout)
                grads[u] = dp if grads[u] is None else jax.tree.map(
                    jnp.add, grads[u], dp)
                if record:
                    jax.block_until_ready(dp)
            if record:
                events.append((ev.kind, s, m,
                               time.perf_counter() - t0, ev.chunk,
                               t0 - t_start))

        grads = [jax.tree.map(lambda g: g / M, g_u) for g_u in grads]
        if self.tied_ref is not None:
            src_key, dst_key = self.tied_ref
            dhead = grads[U - 1].pop(dst_key)
            dhead = self.place(0, dhead)
            grads[0] = dict(grads[0], **{
                src_key: grads[0][src_key] + dhead})

        loss = float(jnp.mean(jnp.concatenate(
            [jnp.atleast_1d(x) for x in losses])))
        metrics = {}
        for k in mets_acc[0]:
            metrics[k] = float(np.mean(
                [float(jnp.mean(mm[k])) for mm in mets_acc]))
        wall = time.perf_counter() - t_start
        stats = StepStats(loss=loss, metrics=metrics, wall_time=wall,
                          events=events, peak_stash=peak)
        self.last_stats = stats         # latest recorded step, for trace
        #                                 export (obs.trace)
        if self.store is not None:
            self._record_telemetry(stats)
        if self.spool is not None:
            self._spool_events(stats, t_start)
        return grads, stats

    # -------------------------------------------------------- telemetry
    def _record_telemetry(self, stats: StepStats):
        from repro.exec.schedule import FWD_FRAC, ZB_DGRAD_FRAC
        from repro.runtime.telemetry import StepRecord
        bwd_frac = 1.0 - FWD_FRAC
        compute, ev_meta = [], []
        for e in stats.events:
            kind, s, m, dur, chunk = e[:5]
            start = e[5] if len(e) > 5 else 0.0
            spec = self.plan.stages[s] if s < len(self.plan.stages) else None
            if spec is None:
                flops_m = 0.0
            elif m < 0:      # scan engine: one event spans all microbatches
                flops_m = spec.flops / self.V
            else:
                flops_m = spec.flops / self.n_micro / self.V
            if kind == "F":
                frac = FWD_FRAC
            elif kind == "W":
                frac = bwd_frac * (1.0 - ZB_DGRAD_FRAC)
            else:
                frac = bwd_frac * (ZB_DGRAD_FRAC if self.has_w else 1.0)
            compute.append({
                "gpu_type": getattr(spec, "gpu_type", "") or "",
                "flops": flops_m * frac, "time": dur, "op": kind,
                "stage": s, "mb": m, "kind": kind, "chunk": chunk})
            ev_meta.append({"kind": kind, "stage": s, "mb": m,
                            "chunk": chunk, "start": start,
                            "finish": start + dur})
        rec = StepRecord(
            graph_fp=self.graph_fp, topo_fp=self.topo_fp,
            wall_time=stats.wall_time, compute=compute,
            meta=dict(self.meta, executor="pipeline",
                      schedule=self.schedule, n_stages=self.S,
                      n_chunks=self.V, n_micro=self.n_micro,
                      loss=stats.loss, peak_stash=stats.peak_stash,
                      events=ev_meta))
        self.store.append(rec)

    def _spool_events(self, stats: StepStats, t_start: float):
        """Stream this step's events to the cross-process spool — one
        batched append (single lock/write) per step; event times are
        re-based from step-relative to this process's monotonic clock so
        the collector's anchor alignment applies unchanged."""
        from repro.obs.trace import KIND_LABEL, event_name
        recs = []
        if not self._spool_tracks_done:
            self._spool_tracks_done = True
            recs += [{"type": "track", "tid": s, "name": f"stage {s}"}
                     for s in range(self.S)]
        for e in stats.events:
            kind, s, m, dur, chunk = e[:5]
            start = float(e[5]) if len(e) > 5 else 0.0
            recs.append({
                "type": "span", "name": event_name(kind, s, m, chunk),
                "cat": "pipeline", "tid": int(s),
                "t0": t_start + start, "t1": t_start + start + float(dur),
                "args": {"kind": KIND_LABEL.get(kind, kind), "stage": s,
                         "mb": m, "chunk": chunk,
                         "schedule": self.schedule}})
        self.spool.emit_many(recs)


class CompiledPipelineRunner(PipelineRunner):
    """Scan-rolled pipeline engine: the same stage math as the eager
    ``PipelineRunner`` (shared un-jitted bodies, ``_make_bodies``), but
    compiled into O(U) rolled ``lax.scan`` programs instead of
    O(U * n_micro) per-event dispatches.

    Per virtual stage ``u``: one forward scan over the stacked
    microbatch axis, and one gradient-accumulating backward scan (split
    into activation-grad / weight-grad scans when the schedule
    zero-bubbles), executed in dataflow order — forwards ascending the
    virtual pipeline, backwards descending it. Gradients are
    schedule-independent (sum over microbatches / n_micro), so the
    result is parity with the eager engine under every schedule family;
    the schedule still decides validation (n_micro / chunk
    constraints), the predicted timeline, and the event program the
    verifier preflights.

    The trade the cost model and the memory prover both see:

      * boundary transfers become ONE bulk stacked ``[n_micro, ...]``
        ``device_put`` per boundary, dispatched asynchronously — the
        copy for stage u streams while jax is still executing earlier
        work (double-buffered boundaries: producer output + consumer
        copy coexist). ``exec.schedule.simulate_schedule(...,
        overlap="full")`` is this engine's timeline model.
      * every stage stashes all ``n_micro`` inputs until its backward
        (GPipe-like activation memory, whatever the schedule family);
        ``verify.memory.analyze_memory(..., engine="scan")`` proves the
        budget under that accounting.

    ``unroll`` forwards to ``lax.scan`` — the default 1 keeps the
    compiled program (and compile time) flat in ``n_micro * n_chunks``;
    larger values trade compile time for less loop overhead.
    """

    def __init__(self, *args, unroll: int = 1, **kw):
        super().__init__(*args, **kw)
        self.unroll = max(1, int(unroll))
        self._fscan = [None] * self.U
        self._bscan = [None] * self.U        # joint (dp sum, dcs)
        self._bscan_act = [None] * self.U    # zb: dcs only
        self._bscan_wgt = [None] * self.U    # zb: dp sum only

    # ------------------------------------------------------- placement
    def place_stacked(self, s: int, tree):
        """Commit stacked ``[n_micro, batch, ...]`` activations to
        physical stage ``s``: microbatch axis unsharded, per-microbatch
        batch axis sharded over the stage's "dp" submesh."""
        if tree is None:
            return None
        mesh = self.meshes[s]
        if mesh is None:
            return jax.device_put(tree, self.device_sets[s][0])
        ndev = self._ndev(s)

        def spec(x):
            shape = getattr(x, "shape", ())
            if len(shape) >= 2 and shape[1] and shape[1] % ndev == 0:
                return P(None, "dp", *([None] * (len(shape) - 2)))
            return P()
        shardings = jax.tree.map(lambda x: NamedSharding(mesh, spec(x)),
                                 tree)
        return jax.device_put(tree, shardings)

    @staticmethod
    def _stack_specs(specs):
        """Partition specs of per-microbatch values, lifted to the
        stacked layout (unsharded microbatch axis prepended)."""
        return jax.tree.map(lambda sp: P(None, *sp), specs,
                            is_leaf=lambda x: isinstance(x, P))

    # ----------------------------------------------------- compiled fns
    def _build_scan(self, u: int, p_ex, cs_ex, mbs_ex):
        """Compile virtual stage ``u``'s scan programs from the shared
        bodies: a forward scan over the microbatch axis and a backward
        scan accumulating the parameter gradient in its carry (split
        activation-grad / weight-grad scans under zero-bubble)."""
        def one(t):
            return jax.tree.map(lambda x: x[0], t)
        c_ex = one(cs_ex) if cs_ex is not None else None
        B = self._make_bodies(u, p_ex, c_ex, one(mbs_ex))
        unroll = self.unroll
        has_c = cs_ex is not None

        def xs_of(cs, mbs, douts=None):
            xs = {"mb": mbs}
            if has_c:
                xs["c"] = cs
            if douts is not None:
                xs["dout"] = douts
            return xs

        def f_scan(p, cs, mbs):
            def body(_, x):
                return 0, B["fwd"](p, x.get("c"), x["mb"])
            return jax.lax.scan(body, 0, xs_of(cs, mbs),
                                unroll=unroll)[1]

        def zeros_like_p(p):
            return jax.tree.map(jnp.zeros_like, p)

        def b_scan(p, cs, mbs, douts):
            def body(acc, x):
                dp, dc = B["bwd"](p, x.get("c"), x["mb"], x["dout"])
                return jax.tree.map(jnp.add, acc, dp), dc
            return jax.lax.scan(body, zeros_like_p(p),
                                xs_of(cs, mbs, douts), reverse=True,
                                unroll=unroll)

        def b_scan_act(p, cs, mbs, douts):
            def body(_, x):
                return 0, B["bwd_act"](p, x.get("c"), x["mb"], x["dout"])
            return jax.lax.scan(body, 0, xs_of(cs, mbs, douts),
                                reverse=True, unroll=unroll)[1]

        def b_scan_wgt(p, cs, mbs, douts):
            def body(acc, x):
                dp = B["bwd_wgt"](p, x.get("c"), x["mb"], x["dout"])
                return jax.tree.map(jnp.add, acc, dp), 0
            return jax.lax.scan(body, zeros_like_p(p),
                                xs_of(cs, mbs, douts), reverse=True,
                                unroll=unroll)[0]

        mesh = B["mesh"]
        if mesh is None:
            self._fscan[u] = jax.jit(f_scan)
            if self.has_w:
                self._bscan_act[u] = jax.jit(b_scan_act)
                self._bscan_wgt[u] = jax.jit(b_scan_wgt)
            else:
                self._bscan[u] = jax.jit(b_scan)
            return

        cs_specs = self._stack_specs(B["c_specs"])
        mbs_specs = self._stack_specs(B["mb_specs"])
        outs_specs = self._stack_specs(B["fwd_out_specs"])
        douts_specs = self._stack_specs(B["dout_specs"])
        p_specs = B["p_specs"]
        self._fscan[u] = jax.jit(shard_map(
            f_scan, mesh=mesh, in_specs=(p_specs, cs_specs, mbs_specs),
            out_specs=outs_specs, check_rep=False))
        in_specs = (p_specs, cs_specs, mbs_specs, douts_specs)
        if self.has_w:
            self._bscan_act[u] = jax.jit(shard_map(
                b_scan_act, mesh=mesh, in_specs=in_specs,
                out_specs=cs_specs, check_rep=False))
            self._bscan_wgt[u] = jax.jit(shard_map(
                b_scan_wgt, mesh=mesh, in_specs=in_specs,
                out_specs=p_specs, check_rep=False))
        else:
            self._bscan[u] = jax.jit(shard_map(
                b_scan, mesh=mesh, in_specs=in_specs,
                out_specs=(p_specs, cs_specs), check_rep=False))

    # ------------------------------------------------------------- step
    def step(self, params_list, batch, *, record: bool = False) -> tuple:
        """One pipelined train step via the scan programs.

        Returns ``(grads_list, StepStats)`` under the same gradient
        contract as the eager engine. ``StepStats.events`` holds ONE
        entry per scan program (``mb == -1``: all microbatches), so a
        step dispatches ``U * 2`` (``U * 3`` for zero-bubble) compiled
        calls instead of the eager engine's ``U * n_micro`` and up.
        """
        t_start = time.perf_counter()
        record = record or self.spool is not None   # spooling needs events
        S, U, M = self.S, self.U, self.n_micro
        stacked = stack_microbatches(batch, M)

        params_eff = list(params_list)
        if self.tied_ref is not None:
            src_key, dst_key = self.tied_ref
            head = self.place(self.phys(U - 1), params_list[0][src_key])
            params_eff[U - 1] = dict(params_list[U - 1],
                                     **{dst_key: head})

        mbs_cache: list = [None] * U

        def mb_at(u):
            if mbs_cache[u] is None:
                mbs_cache[u] = self.place_stacked(
                    self.phys(u), self._mb_for(u, stacked))
            return mbs_cache[u]

        stage_in: list = [None] * U     # stacked stashed inputs (all M)
        fouts: list = [None] * U
        losses = mets = None
        events: list = []

        for u in range(U):
            s = self.phys(u)
            t0 = time.perf_counter()
            cs = None
            if u > 0:
                # double-buffered boundary: one bulk stacked device_put,
                # dispatched asynchronously — the copy streams while jax
                # still executes the producer's scan
                cs = self.place_stacked(s, fouts[u - 1])
                fouts[u - 1] = None
            stage_in[u] = cs
            mbs = mb_at(u)
            if self._fscan[u] is None:
                self._build_scan(u, params_eff[u], cs, mbs)
            out = self._fscan[u](params_eff[u], cs, mbs)
            if u == U - 1:
                losses, mets = out
            else:
                fouts[u] = out
            if record:
                jax.block_until_ready(out)
                events.append(("F", s, -1, time.perf_counter() - t0,
                               u // S, t0 - t_start))

        grads: list = [None] * U
        seed_last = 1.0 / self._ndev(self.phys(U - 1))
        dcs = None
        for u in reversed(range(U)):
            s = self.phys(u)
            t0 = time.perf_counter()
            if u == U - 1:
                douts = self.place_stacked(
                    s, jnp.full((M,), seed_last, jnp.float32))
            else:
                douts = self.place_stacked(s, dcs)
            cs, mbs = stage_in[u], mb_at(u)
            if self.has_w:
                dcs = self._bscan_act[u](params_eff[u], cs, mbs, douts)
                if record:
                    jax.block_until_ready(dcs)
                    events.append(("B", s, -1,
                                   time.perf_counter() - t0, u // S,
                                   t0 - t_start))
                t1 = time.perf_counter()
                grads[u] = self._bscan_wgt[u](params_eff[u], cs, mbs,
                                              douts)
                if record:
                    jax.block_until_ready(grads[u])
                    events.append(("W", s, -1,
                                   time.perf_counter() - t1, u // S,
                                   t1 - t_start))
            else:
                grads[u], dcs = self._bscan[u](params_eff[u], cs, mbs,
                                               douts)
                if record:
                    jax.block_until_ready(grads[u])
                    events.append(("B", s, -1,
                                   time.perf_counter() - t0, u // S,
                                   t0 - t_start))
            stage_in[u] = None

        grads = [jax.tree.map(lambda g: g / M, g_u) for g_u in grads]
        if self.tied_ref is not None:
            src_key, dst_key = self.tied_ref
            dhead = grads[U - 1].pop(dst_key)
            dhead = self.place(0, dhead)
            grads[0] = dict(grads[0], **{
                src_key: grads[0][src_key] + dhead})

        loss = float(jnp.mean(losses))
        metrics = {k: float(jnp.mean(mets[k])) for k in mets}
        wall = time.perf_counter() - t_start
        stats = StepStats(loss=loss, metrics=metrics, wall_time=wall,
                          events=events, peak_stash=U * M)
        self.last_stats = stats
        if self.store is not None:
            self._record_telemetry(stats)
        if self.spool is not None:
            self._spool_events(stats, t_start)
        return grads, stats
