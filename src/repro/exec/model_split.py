"""Model adapter: cut a ``ModelConfig`` LM into pipeline stage functions.

The decoder stack is a scan over ``num_periods`` period-params (leading
dim of every leaf under ``params["blocks"]``), so a stage is a contiguous
period span plus the edges: stage 0 owns the embedding (+ frontend
projection), the last stage owns the final norm, head, and loss.

Stage functions share one signature the engine understands:

    fn(stage_params, carry, mb) -> carry            (stages 0..S-2)
    fn(stage_params, carry, mb) -> (loss, metrics)  (last stage)

``carry`` is ``(hidden (B, S, D), aux (B,))`` — the MoE aux loss rides
along as a per-example vector so it batch-shards with the activations
(per-stage data parallelism splits the microbatch across the stage's
submesh; a scalar aux could not be sharded, and a cross-shard mean inside
the differentiated body would force a collective the engine's explicit
AR/PS/SFB gradient sync must stay in charge of).

Tied embeddings: the head weight IS the embedding matrix, which lives on
stage 0. ``split_model`` then omits the head from the last stage's
params; the engine broadcasts the embedding to the last stage each step
(``tied_ref``) and folds the head gradient back into the embedding
gradient — the same two boundary transfers a real pipeline runtime pays
for weight tying.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models import model as model_mod
from repro.models import transformer as tf_mod
from repro.models.layers import cross_entropy, rms_norm

TIED_HEAD = "tied_head"      # engine-injected key on the last stage


def _first_stage(cfg: ModelConfig):
    def fn(p, carry, mb):
        del carry
        x, pos, n_prefix = model_mod._embed_inputs(cfg, p, mb)
        del n_prefix
        aux = jnp.zeros((x.shape[0],), jnp.float32)
        return _run_blocks(cfg, p, x, aux)
    return fn


def _mid_stage(cfg: ModelConfig):
    def fn(p, carry, mb):
        del mb
        x, aux = carry
        return _run_blocks(cfg, p, x, aux)
    return fn


def _run_blocks(cfg, p, x, aux):
    blocks = p.get("blocks")
    if blocks is not None and jax.tree.leaves(blocks):
        pos = jnp.arange(x.shape[1])
        x, a = tf_mod.stack_fwd(cfg, blocks, x, pos, remat=False)
        aux = aux + a                   # scalar broadcasts over (B,)
    return x, aux


def _last_stage(cfg: ModelConfig, tied: bool):
    def fn(p, carry, mb):
        x, aux = carry
        x, aux = _run_blocks(cfg, p, x, aux)
        h = rms_norm(x, p["final_norm"], cfg.norm_eps)
        n_prefix = h.shape[1] - mb["labels"].shape[1]
        if n_prefix:
            h = h[:, n_prefix:]
        w = p[TIED_HEAD] if tied else p["head"]
        ce = cross_entropy(h @ w.T if tied else h @ w, mb["labels"])
        loss = ce + model_mod.MAX_SMOKE_AUX * jnp.mean(aux)
        return loss, {"ce": ce, "aux": jnp.mean(aux)}
    return fn


def split_model(cfg: ModelConfig, params, n_stages: int,
                splits: list | None = None):
    """-> (stage_params, stage_fns, mb_keys, tied_ref).

    ``splits`` is the per-stage [lo, hi) period span (default: equal
    chunks; pass ``StagePlan.layer_splits(cfg.num_periods)`` for the
    capacity-aware cut). ``mb_keys[s]`` names the microbatch entries
    stage ``s`` consumes. ``tied_ref`` is ``("embed", TIED_HEAD)`` when
    the head is tied to the stage-0 embedding, else ``None``.
    """
    P = cfg.num_periods
    if splits is None:
        splits = [(s * P // n_stages, (s + 1) * P // n_stages)
                  for s in range(n_stages)]
    assert len(splits) == n_stages and splits[0][0] == 0 \
        and splits[-1][1] == P, splits

    tied = cfg.tie_embeddings
    stage_params, stage_fns, mb_keys = [], [], []
    for s, (lo, hi) in enumerate(splits):
        p = {"blocks": jax.tree.map(lambda a, lo=lo, hi=hi: a[lo:hi],
                                    params["blocks"])}
        keys: list = []
        if s == 0:
            p["embed"] = params["embed"]
            keys.append("tokens")
            if cfg.frontend != "none":
                p["frontend_proj"] = params["frontend_proj"]
                keys.append("prefix")
            fn = _first_stage(cfg)
        else:
            fn = _mid_stage(cfg)
        if s == n_stages - 1:
            p["final_norm"] = params["final_norm"]
            if not tied:
                p["head"] = params["head"]
            keys.append("labels")
            fn = _last_stage(cfg, tied) if s > 0 else \
                _single_stage(cfg, tied)
        stage_params.append(p)
        stage_fns.append(fn)
        mb_keys.append(keys)
    tied_ref = ("embed", TIED_HEAD) if tied and n_stages > 1 else None
    return stage_params, stage_fns, mb_keys, tied_ref


def _single_stage(cfg: ModelConfig, tied: bool):
    """Degenerate 1-stage pipeline (embed + blocks + head in one)."""
    first, last = _first_stage(cfg), _last_stage(cfg, tied=False)

    def fn(p, carry, mb):
        carry = first(p, carry, mb)
        # first() already ran the decoder blocks; hand last() a
        # blocks-free view so it only applies norm + head + loss
        p_last = {k: v for k, v in p.items() if k != "blocks"}
        if tied:
            p_last["head"] = p["embed"].T
        return last(p_last, carry, mb)
    return fn
