"""Heterogeneous pipeline execution engine (``repro.exec``).

Lowers a searched TAG ``Strategy`` with PIPE actions into a *running*
multi-stage train step:

  * ``stages``      — stage partitioner: cut the grouped graph at PIPE
                      boundaries, map stages to topology device groups;
  * ``schedule``    — GPipe / 1F1B microbatch schedules as explicit
                      event lists + a dependency-driven timeline
                      simulator (bubble fractions, stash bounds);
  * ``model_split`` — cut a ``ModelConfig`` LM into stage functions;
  * ``engine``      — two executors sharing the same stage math: the
                      eager ``PipelineRunner`` (per-event jitted
                      dispatch, device_put boundary transfers, shard_map
                      per-stage data parallelism with AR/PS/SFB gradient
                      sync) and the scan-rolled
                      ``CompiledPipelineRunner`` (per-stage ``lax.scan``
                      programs, bulk double-buffered boundary
                      transfers);
  * ``replay``      — replay executor emitting step telemetry (the
                      simulator cross-check + per-link-pair calibration
                      samples).
"""
from repro.exec.engine import (
    CompiledPipelineRunner, PipelineRunner, split_microbatches,
    stack_microbatches)
from repro.exec.model_split import split_model
from repro.exec.replay import execute_pipeline
from repro.exec.schedule import (
    SCHEDULES, Timeline, flatten_schedule, gpipe_schedule,
    interleaved_1f1b_schedule, make_schedule, max_feasible_micro,
    one_f_one_b_schedule, peak_stash, schedule_step_cost, simulate_schedule,
    stage_sync_time, timeline_to_simresult, validate_schedule,
    zero_bubble_schedule)
from repro.exec.stages import (
    PipelineInfeasible, StagePlan, StageSpec, build_stage_plan, pipeline_spine,
    vote_schedule)

__all__ = [
    "CompiledPipelineRunner", "PipelineRunner", "split_microbatches",
    "stack_microbatches", "split_model",
    "execute_pipeline",
    "SCHEDULES", "Timeline", "flatten_schedule", "gpipe_schedule",
    "interleaved_1f1b_schedule", "make_schedule", "max_feasible_micro",
    "one_f_one_b_schedule", "peak_stash", "schedule_step_cost",
    "simulate_schedule", "stage_sync_time", "timeline_to_simresult",
    "validate_schedule", "zero_bubble_schedule",
    "PipelineInfeasible", "StagePlan", "StageSpec", "build_stage_plan",
    "pipeline_spine", "vote_schedule",
]
