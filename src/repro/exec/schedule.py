"""Microbatch pipeline schedules as explicit event lists.

A schedule is, per physical stage, an ordered list of ``Event``s —
``F(s, m)`` (forward of microbatch ``m`` on stage ``s``), ``B(s, m)``
(backward), plus two extensions:

  * a ``chunk`` id for **interleaved (virtual-stage)** schedules: stage
    ``s`` hosts ``V`` model chunks, chunk ``v`` of stage ``s`` being
    virtual pipeline stage ``u = v * S + s`` (the Megatron-LM mapping).
    For ``V == 1`` everything degenerates to the plain schedules.
  * a ``W`` kind for **zero-bubble** schedules: the backward is split
    into the activation-gradient half ``B`` (on the cross-stage critical
    path) and the weight-gradient half ``W`` (local to the stage, free to
    slide into bubbles).

Four schedules are provided:

  * **GPipe**: all forwards, then all backwards. Stash peaks at
    ``n_micro`` per stage.
  * **1F1B** (PipeDream-flush): warm-up of ``min(S - s, M)`` forwards,
    then one-forward/one-backward, then drain. Stash peaks at
    ``min(S - s, M)``.
  * **Interleaved 1F1B** (Megatron virtual stages): each stage runs
    ``V`` chunks; warm-up ``min(2(S - s - 1) + (V - 1) S, M V)``
    virtual forwards with microbatch groups of size ``S`` (requires
    ``M % S == 0``). Warm-up/drain bubbles shrink by ``V`` at the cost
    of ``V``x the boundary transfers.
  * **Zero-bubble** (ZB-H1-style): 1F1B skeleton with the backward
    split; each drain gap is filled by a pending ``W``, and the
    cross-stage ``B`` chain is half as deep as a full backward — same
    activation stash as 1F1B (``W`` promptly releases the stash).

``simulate_schedule`` lowers a (StagePlan, schedule) pair onto a
``Topology`` as a dependency-driven timeline: per-stage serial execution
in schedule order, cross-(virtual-)stage activation / activation-grad
transfers serialized per directed link. The same timeline code is the
*predicted* side of the replay executor's cross-check (``exec.replay``),
the bubble-fraction source for the pipeline benchmark, and — via
``schedule_step_cost`` — the cost model MCTS uses to rank PIPE actions.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Sequence

from repro.core.device import Topology
from repro.core.profiler import (
    allreduce_time, compute_time, ps_round_time, transfer_time)

if TYPE_CHECKING:
    from repro.core.graph import GroupedGraph
    from repro.core.simulator import SimResult
    from repro.exec.stages import StagePlan

SCHEDULES = ("gpipe", "1f1b", "interleaved", "zb")

# fraction of a group's traced flops attributed to the forward pass (the
# training trace contains fwd+bwd; backward is ~2x forward for dense nets)
FWD_FRAC = 1.0 / 3.0

# zero-bubble split of the backward: activation-grad (B) vs weight-grad
# (W). For dense nets dgrad ~= wgrad ~= one forward each, so the split is
# even — F, B and W all cost ~1/3 of the traced fwd+bwd flops.
ZB_DGRAD_FRAC = 0.5

# default virtual-chunk count for interleaved schedules
DEFAULT_CHUNKS = 2

# a stage boundary's crossing bytes come from the fwd+bwd trace, so they
# cover BOTH directions: the F-edge carries the activation half, the
# B-edge the activation-grad half
BOUNDARY_DIR_FRAC = 0.5

# transfer/compute overlap models for the timeline simulator:
#   "link" — transfers serialize only per directed device-group link
#            (the legacy model; both stage rows keep computing)
#   "none" — the transfer ALSO occupies the destination stage row, the
#            way the eager engine's synchronous per-event ``device_put``
#            dispatch does
#   "full" — double-buffered boundaries: back-to-back transfers on the
#            same link form a stream, and only the first pays the wire
#            latency (the scan engine's bulk stacked transfer)
OVERLAP_MODES = ("link", "none", "full")


@dataclass(frozen=True)
class Event:
    """One schedule slot: kind F/B/W on (stage, microbatch, chunk)."""

    kind: str                 # "F" | "B" | "W"
    stage: int                # physical stage
    mb: int
    chunk: int = 0            # virtual chunk (interleaved); 0 otherwise

    def __repr__(self) -> str:
        c = f"c{self.chunk}" if self.chunk else ""
        return f"{self.kind}{self.stage}{c}.{self.mb}"


def gpipe_schedule(n_stages: int, n_micro: int) -> list[list[Event]]:
    """Per-stage issue order: F(0..M-1) then B(M-1..0)."""
    out: list[list[Event]] = []
    for s in range(n_stages):
        evs = [Event("F", s, m) for m in range(n_micro)]
        evs += [Event("B", s, m) for m in reversed(range(n_micro))]
        out.append(evs)
    return out


def one_f_one_b_schedule(n_stages: int,
                         n_micro: int) -> list[list[Event]]:
    """Per-stage issue order with warm-up ``min(S - s, M)`` forwards."""
    out: list[list[Event]] = []
    for s in range(n_stages):
        warm = min(n_stages - s, n_micro)
        evs = [Event("F", s, m) for m in range(warm)]
        nf, nb = warm, 0
        while nb < n_micro:
            evs.append(Event("B", s, nb))
            nb += 1
            if nf < n_micro:
                evs.append(Event("F", s, nf))
                nf += 1
        out.append(evs)
    return out


def interleaved_1f1b_schedule(
        n_stages: int, n_micro: int,
        n_chunks: int = DEFAULT_CHUNKS) -> list[list[Event]]:
    """Megatron-style interleaved 1F1B over virtual stages.

    Runs ``n_chunks`` virtual stages per physical stage.

    Virtual microbatches are issued in groups of ``S`` per chunk
    (forwards walk chunks 0..V-1, backwards V-1..0), which requires
    ``n_micro % n_stages == 0``. Warm-up is
    ``min(2 (S - s - 1) + (V - 1) S, M V)`` virtual forwards, then
    one-forward/one-backward, then drain.
    """
    S, M, V = n_stages, n_micro, n_chunks
    if V < 2:
        raise ValueError(f"interleaved needs n_chunks >= 2, got {V}")
    if S < 2:
        raise ValueError("interleaved needs n_stages >= 2")
    if M % S:
        raise ValueError(
            f"interleaved needs n_micro % n_stages == 0 "
            f"(got M={M}, S={S})")
    total = M * V

    def chunk_mb(k: int, forward: bool) -> tuple[int, int]:
        """Map virtual-microbatch index ``k`` to its (chunk, mb)."""
        c = (k % (S * V)) // S
        if not forward:
            c = V - 1 - c
        return c, (k // (S * V)) * S + k % S

    out: list[list[Event]] = []
    for s in range(S):
        warm = min(2 * (S - s - 1) + (V - 1) * S, total)
        evs: list[Event] = []
        for k in range(warm):
            c, mb = chunk_mb(k, True)
            evs.append(Event("F", s, mb, c))
        nf, nb = warm, 0
        while nf < total:
            c, mb = chunk_mb(nf, True)
            evs.append(Event("F", s, mb, c))
            nf += 1
            c, mb = chunk_mb(nb, False)
            evs.append(Event("B", s, mb, c))
            nb += 1
        while nb < total:
            c, mb = chunk_mb(nb, False)
            evs.append(Event("B", s, mb, c))
            nb += 1
        out.append(evs)
    return out


def zero_bubble_schedule(n_stages: int,
                         n_micro: int) -> list[list[Event]]:
    """ZB-H1-style split-backward schedule.

    The 1F1B skeleton with each backward split into ``B`` (activation
    grad, cross-stage dependency) and ``W`` (weight grad, stage-local).
    ``W(m)`` is issued promptly
    after ``B(m)`` — releasing the activation stash BEFORE the next
    forward acquires one, so peak stash stays exactly at 1F1B's
    ``min(S - s, M)`` bound — and in the drain phase it fills the gap
    while the stage waits for the next downstream ``B``.
    """
    S, M = n_stages, n_micro
    out: list[list[Event]] = []
    for s in range(S):
        warm = min(S - s, M)
        evs = [Event("F", s, m) for m in range(warm)]
        nf, nb, nw = warm, 0, 0
        while nb < M:
            evs.append(Event("B", s, nb))
            nb += 1
            evs.append(Event("W", s, nw))
            nw += 1
            if nf < M:
                evs.append(Event("F", s, nf))
                nf += 1
        out.append(evs)
    return out


def make_schedule(name: str, n_stages: int, n_micro: int, *,
                  n_chunks: int = DEFAULT_CHUNKS) -> list[list[Event]]:
    """Build the named schedule's per-stage event lists."""
    if name == "gpipe":
        return gpipe_schedule(n_stages, n_micro)
    if name == "1f1b":
        return one_f_one_b_schedule(n_stages, n_micro)
    if name == "interleaved":
        return interleaved_1f1b_schedule(n_stages, n_micro, n_chunks)
    if name == "zb":
        return zero_bubble_schedule(n_stages, n_micro)
    raise ValueError(f"unknown schedule {name!r} (use one of {SCHEDULES})")


def n_chunks_of(order: Sequence[Sequence[Event]]) -> int:
    """Virtual-chunk count of a schedule (1 for plain schedules)."""
    return max((e.chunk for evs in order for e in evs), default=0) + 1


def _dep_of(e: Event, n_stages: int, n_chunks: int) -> Event | None:
    """Cross-event dependency of ``e`` (None when only its own F).

    Virtual stage ``u = chunk * S + stage``: forwards chain up the
    virtual pipeline, backwards chain down it, ``W`` waits on its own
    ``B``.
    """
    S, U = n_stages, n_stages * n_chunks
    u = e.chunk * S + e.stage
    if e.kind == "F":
        if u == 0:
            return None
        p = u - 1
        return Event("F", p % S, e.mb, p // S)
    if e.kind == "B":
        if u == U - 1:
            return None                 # only its own F (checked separately)
        p = u + 1
        return Event("B", p % S, e.mb, p // S)
    return Event("B", e.stage, e.mb, e.chunk)       # "W"


def validate_schedule(order: list[list[Event]], n_stages: int,
                      n_micro: int) -> None:
    """Check schedule invariants; raises ``ValueError`` on violation.

      * every stage issues F and B of every (chunk, microbatch) exactly
        once (chunk count inferred from the events);
      * per stage, B(s, m, c) comes after F(s, m, c);
      * when a stage issues W events (zero-bubble), they cover the same
        (chunk, mb) set and W(s, m, c) comes after B(s, m, c);
      * a consistent global order exists: following per-stage order plus
        the cross-virtual-stage deps never deadlocks (no stage executes
        a microbatch before its predecessor produced it).
    """
    if len(order) != n_stages:
        raise ValueError(f"{len(order)} stage lists != {n_stages} stages")
    V = n_chunks_of(order)
    want = sorted((c, m) for c in range(V) for m in range(n_micro))
    for s, evs in enumerate(order):
        kinds = {e.kind for e in evs}
        for kind in ("F", "B") + (("W",) if "W" in kinds else ()):
            cms = sorted((e.chunk, e.mb) for e in evs if e.kind == kind)
            if cms != want:
                raise ValueError(f"stage {s}: {kind} covers {cms}")
        seen: dict[str, set[tuple[int, int]]] = {"F": set(),
                                                 "B": set()}
        for e in evs:
            if e.kind == "F":
                seen["F"].add((e.chunk, e.mb))
            elif e.kind == "B":
                if (e.chunk, e.mb) not in seen["F"]:
                    raise ValueError(
                        f"stage {s}: B before F for {(e.chunk, e.mb)}")
                seen["B"].add((e.chunk, e.mb))
            else:
                if (e.chunk, e.mb) not in seen["B"]:
                    raise ValueError(
                        f"stage {s}: W before B for {(e.chunk, e.mb)}")
    flatten_schedule(order, n_stages, n_micro)   # raises on deadlock


def flatten_schedule(order: list[list[Event]], n_stages: int,
                     n_micro: int) -> list[Event]:
    """Build a single dependency-consistent global issue order.

    The eager engine executes events in this order. Raises on deadlock.
    """
    del n_micro
    V = n_chunks_of(order)
    ptr = [0] * n_stages
    done: set[Event] = set()
    out: list[Event] = []
    total = sum(len(evs) for evs in order)
    while len(out) < total:
        progressed = False
        for s in range(n_stages):
            if ptr[s] >= len(order[s]):
                continue
            e = order[s][ptr[s]]
            dep = _dep_of(e, n_stages, V)
            need_f = Event("F", s, e.mb, e.chunk) if e.kind == "B" else None
            if (dep is None or dep in done) and \
                    (need_f is None or need_f in done):
                out.append(e)
                done.add(e)
                ptr[s] += 1
                progressed = True
        if not progressed:
            raise ValueError("schedule deadlocks: unsatisfiable order")
    return out


def peak_stash(order: "Sequence[Sequence[Event | TimedEvent]]"
               ) -> list[int]:
    """Per-stage peak number of in-flight forward activations (stash).

    The pipeline's activation-memory driver: GPipe peaks at n_micro,
    1F1B at min(S - s, M). A stash is released by the event that last
    consumes the stage input: ``W`` when the stage splits its backward
    (zero-bubble), else ``B``.
    """
    peaks: list[int] = []
    for evs in order:
        release = "W" if any(e.kind == "W" for e in evs) else "B"
        cur = peak = 0
        for e in evs:
            if e.kind == "F":
                cur += 1
            elif e.kind == release:
                cur -= 1
            peak = max(peak, cur)
        peaks.append(peak)
    return peaks


def max_feasible_micro(plan: "StagePlan", schedule: str, *,
                       mb_act_bytes: float | Sequence[float],
                       mem_budget: float | Sequence[float],
                       cap: int = 64,
                       n_chunks: int = DEFAULT_CHUNKS) -> int:
    """Largest microbatch count whose peak stash fits the memory budget.

    Evaluated per stage at a FIXED microbatch size. ``mb_act_bytes``
    and ``mem_budget`` are scalars (uniform across stages) or per-stage
    sequences. GPipe stashes all M microbatches, so its feasible M is
    memory-capped; 1F1B/zero-bubble stash is bounded by the stage depth
    regardless of M; interleaved stashes more warm-up activations (its
    M must also be a multiple of the stage count — other M are skipped
    as infeasible).
    """
    S = plan.n_stages
    acts = list(mb_act_bytes) if isinstance(mb_act_bytes, Sequence) \
        else [float(mb_act_bytes)] * S
    buds = list(mem_budget) if isinstance(mem_budget, Sequence) \
        else [float(mem_budget)] * S
    best = 0
    for m in range(1, cap + 1):
        try:
            order = make_schedule(schedule, S, m, n_chunks=n_chunks)
        except ValueError:
            continue
        peaks = peak_stash(order)
        if all(p * a <= b for p, a, b in zip(peaks, acts, buds)):
            best = m
    return best


# ----------------------------------------------------------- timeline

@dataclass
class TimedEvent:
    """A schedule event placed on the simulated clock."""

    kind: str                 # "F" | "B" | "W" | "X" (boundary transfer)
    stage: int                # executing stage (transfers: dst stage)
    mb: int
    start: float
    finish: float
    src: int = -1             # transfers: producing stage (F: stage-1,
    #                           B: stage+1); -1 for compute events
    chunk: int = 0
    nbytes: float = 0.0       # transfers: bytes on the wire

    @property
    def dur(self) -> float:
        """Event duration in simulated seconds."""
        return self.finish - self.start


@dataclass
class Timeline:
    """Simulated execution of one schedule: events plus summary stats."""

    events: list[TimedEvent]
    makespan: float
    stage_busy: list[float]              # compute seconds per stage
    n_stages: int
    n_micro: int
    n_chunks: int = 1
    meta: dict[str, object] = field(default_factory=dict)

    def bubble_fraction(self) -> float:
        """1 - busy/(S * makespan): the idle share of stage-seconds."""
        if self.makespan <= 0:
            return 0.0
        return 1.0 - sum(self.stage_busy) / (self.n_stages * self.makespan)

    def finish_of(self, kind: str, stage: int, mb: int,
                  chunk: int = 0) -> float:
        """Finish time of the matching event; raises ``KeyError``."""
        for e in self.events:
            if e.kind == kind and e.stage == stage and e.mb == mb \
                    and e.chunk == chunk:
                return e.finish
        raise KeyError((kind, stage, mb, chunk))


def _stage_speed(plan: "StagePlan", topo: Topology, s: int) -> float:
    dg = topo.groups[plan.stages[s].device_group]
    return dg.flops * max(dg.num_gpus, 1)


def boundary_bytes(plan: "StagePlan", u_lo: int,
                   n_micro: int) -> float:
    """Per-direction, per-microbatch bytes over boundary (u_lo, u_lo+1).

    Interior boundaries carry the traced stage-crossing activation;
    chunk-wrap boundaries (last physical stage back to the first,
    between chunks) are estimated as the mean interior crossing — the
    wrapped tensor is the same hidden-state carry, just not present in
    the unchunked trace.
    """
    S = plan.n_stages
    s = u_lo % S
    if s < S - 1:
        nb = plan.stages[s].out_bytes
    else:
        interior = [st.out_bytes for st in plan.stages[:-1]
                    if st.out_bytes > 0]
        nb = sum(interior) / len(interior) if interior else 0.0
    return nb * BOUNDARY_DIR_FRAC / max(n_micro, 1)


def simulate_schedule(plan: "StagePlan", topo: Topology,
                      order: list[list[Event]], *,
                      fwd_frac: float = FWD_FRAC,
                      overlap: str = "link") -> Timeline:
    """Dependency-driven timeline of a schedule on a topology.

    Per-stage compute is serial in the stage's issue order; forward of
    virtual stage u waits for virtual stage u-1's forward plus the
    boundary activation transfer; backward waits symmetrically on u+1
    plus the activation-grad transfer; W (zero-bubble weight grad) waits
    only on the stage's own B. Transfers serialize per directed
    (src, dst) device-group link, so a congested boundary link shows up
    as pipeline bubble exactly like on a real cluster. Interleaved
    chunks split each stage's compute by the chunk count and pay the
    extra chunk-boundary transfers.

    ``overlap`` picks the transfer/compute overlap model
    (``OVERLAP_MODES``): ``"link"`` (legacy) lets transfers overlap all
    compute and serialize only per directed link; ``"none"`` charges
    each transfer to the destination stage row as well, matching the
    eager engine's synchronous per-event ``device_put``; ``"full"``
    models double-buffered boundaries — a transfer departing while (or
    exactly when) its link is still streaming the previous one joins
    the stream and pays only the bandwidth term, not the wire latency.
    """
    if overlap not in OVERLAP_MODES:
        raise ValueError(
            f"unknown overlap mode {overlap!r} (use one of "
            f"{OVERLAP_MODES})")
    S = len(order)
    V = n_chunks_of(order)
    U = S * V
    M = max((e.mb for evs in order for e in evs), default=-1) + 1
    has_w = any(e.kind == "W" for evs in order for e in evs)
    fwd_t: list[float] = []
    bwd_t: list[float] = []
    for s in range(S):
        flops_m = plan.stages[s].flops / max(M, 1)
        speed = _stage_speed(plan, topo, s)
        fwd_t.append(compute_time(flops_m * fwd_frac, speed))
        bwd_t.append(compute_time(flops_m * (1.0 - fwd_frac), speed))

    def dur_of(e: Event) -> float:
        """Compute duration of one event on its stage."""
        if e.kind == "F":
            return fwd_t[e.stage] / V
        if e.kind == "W":
            return bwd_t[e.stage] / V * (1.0 - ZB_DGRAD_FRAC)
        return bwd_t[e.stage] / V * (ZB_DGRAD_FRAC if has_w else 1.0)

    def xfer_t(u_lo: int, src_stage: int, dst_stage: int,
               streamed: bool = False) -> tuple[float, float]:
        """(transfer seconds, bytes) across one virtual boundary."""
        gi = plan.stages[src_stage].device_group
        gj = plan.stages[dst_stage].device_group
        nb = boundary_bytes(plan, u_lo, M)
        if nb <= 0 or gi == gj:
            return 0.0, 0.0
        lat = 0.0 if streamed else topo.latency
        return transfer_time(nb, topo.bw(gi, gj), lat), nb

    # (kind, stage, mb, chunk) -> finish time
    finish: dict[tuple[str, int, int, int], float] = {}
    stage_free = [0.0] * S
    link_free: dict[tuple[int, int], float] = {}   # (src_g, dst_g) -> t
    busy = [0.0] * S
    events: list[TimedEvent] = []
    ptr = [0] * S

    def ready(e: Event) -> tuple[float | None, TimedEvent | None]:
        """(ready time, transfer TimedEvent|None) for event e."""
        u = e.chunk * S + e.stage
        if e.kind == "F":
            if u == 0:
                return 0.0, None
            p = u - 1
            key = ("F", p % S, e.mb, p // S)
        elif e.kind == "B":
            if u == U - 1:
                return finish.get(("F", e.stage, e.mb, e.chunk), 0.0), None
            p = u + 1
            key = ("B", p % S, e.mb, p // S)
        else:                                       # "W": own B, no transfer
            key = ("B", e.stage, e.mb, e.chunk)
            if key not in finish:
                return None, None
            return finish[key], None
        if key not in finish:
            return None, None
        t0 = finish[key]
        src = key[1]
        u_lo = min(u, p)
        gi = plan.stages[src].device_group
        gj = plan.stages[e.stage].device_group
        free = link_free.get((gi, gj), 0.0)
        # "full": joining a still-busy (or just-freed) link streams
        # behind the previous transfer — latency already paid once
        streamed = overlap == "full" and free > 0.0 and t0 <= free
        dur, nb = xfer_t(u_lo, src, e.stage, streamed=streamed)
        if dur <= 0:
            return t0, None
        s0 = max(t0, free)
        if overlap == "none":
            # eager engine: the synchronous device_put blocks the
            # destination stage's dispatch thread
            s0 = max(s0, stage_free[e.stage])
            stage_free[e.stage] = s0 + dur
        link_free[(gi, gj)] = s0 + dur
        return s0 + dur, TimedEvent("X", e.stage, e.mb, s0, s0 + dur,
                                    src=src, chunk=e.chunk, nbytes=nb)

    total = sum(len(evs) for evs in order)
    while len(finish) < total:
        progressed = False
        for s in range(S):
            if ptr[s] >= len(order[s]):
                continue
            e = order[s][ptr[s]]
            if e.kind == "B" and ("F", s, e.mb, e.chunk) not in finish:
                continue
            rt, xev = ready(e)
            if rt is None:
                continue
            if xev is not None:
                events.append(xev)
            t = dur_of(e)
            start = max(rt, stage_free[s])
            stage_free[s] = start + t
            busy[s] += t
            finish[(e.kind, s, e.mb, e.chunk)] = start + t
            events.append(TimedEvent(e.kind, s, e.mb, start, start + t,
                                     chunk=e.chunk))
            ptr[s] += 1
            progressed = True
        if not progressed:
            raise ValueError("schedule deadlocks on the timeline")
    makespan = max((e.finish for e in events), default=0.0)
    return Timeline(events=events, makespan=makespan, stage_busy=busy,
                    n_stages=S, n_micro=M, n_chunks=V,
                    meta={"fwd_t": fwd_t, "bwd_t": bwd_t,
                          "overlap": overlap})


# ------------------------------------------------ search-facing costing

def stage_sync_time(plan: "StagePlan", topo: Topology) -> float:
    """Worst per-stage gradient-sync time (collective after the flush).

    Stages sync on disjoint device groups, so they overlap — the
    slowest one bounds the step. SFB stages broadcast sufficient
    factors with the activations and recompute locally, so they add no
    post-flush sync.
    """
    worst = 0.0
    for st in plan.stages:
        if st.grad_bytes <= 0 or st.n_devices <= 1 or st.sync == "sfb":
            continue
        tau = topo.bottleneck_bw([st.device_group])
        if st.sync == "ps":
            t = ps_round_time(st.grad_bytes, st.n_devices, tau, topo.latency)
        else:
            t = allreduce_time(st.grad_bytes, st.n_devices, tau,
                               topo.latency)
        worst = max(worst, t)
    return worst


def schedule_step_cost(plan: "StagePlan", topo: Topology,
                       schedule: str, *, global_micro: int = 16,
                       n_chunks: int = DEFAULT_CHUNKS,
                       mb_act_bytes: Sequence[float] | None = None,
                       mem_budget: Sequence[float] | None = None,
                       include_sync: bool = True,
                       overlap: str = "full"
                       ) -> dict[str, object] | None:
    """Memory-capped effective per-global-batch cost of one schedule.

    The schedule runs at its largest feasible microbatch depth under the
    per-stage activation budget; shallower depths pay multiple pipeline
    flushes (``ceil(global_micro / m)``). Default budgets derive from
    the topology: per stage, group memory minus 4x resident parameters
    (param + grad + Adam moments); a stage whose parameters alone
    overflow is infeasible. Returns ``None`` when no microbatch depth
    fits, else a dict with ``n_micro/flushes/flush_time_s/step_time_s/
    bubble_frac/sync_time_s/timeline``.

    ``overlap`` is the transfer/compute overlap model the timeline runs
    under (``OVERLAP_MODES``). The default is ``"full"`` — the
    double-buffered streaming model of the compiled scan engine — so
    MCTS and the feedback loop rank strategies under the costing of the
    engine that actually executes them; pass ``"link"`` for the legacy
    per-link-serialization model.
    """
    S = plan.n_stages
    if mb_act_bytes is None:
        mb_act_bytes = [
            (plan.stages[s - 1].out_bytes if s else plan.stages[0].out_bytes)
            / max(global_micro, 1) for s in range(S)]
    if mem_budget is None:
        mem_budget = []
        for st in plan.stages:
            dg = topo.groups[st.device_group]
            free = (dg.mem_bytes - 4.0 * st.param_bytes) * max(dg.num_gpus, 1)
            mem_budget.append(free)
    if any(b <= 0 for b in mem_budget):
        return None
    m = max_feasible_micro(plan, schedule, mb_act_bytes=mb_act_bytes,
                           mem_budget=mem_budget, cap=global_micro,
                           n_chunks=n_chunks)
    if m <= 0:
        return None
    m = min(m, global_micro)
    flushes = -(-global_micro // m)
    order = make_schedule(schedule, S, m, n_chunks=n_chunks)
    tl = simulate_schedule(plan, topo, order, overlap=overlap)
    sync = stage_sync_time(plan, topo) if include_sync else 0.0
    return {"schedule": schedule, "n_micro": m, "flushes": flushes,
            "flush_time_s": tl.makespan,
            "step_time_s": flushes * tl.makespan + sync,
            "bubble_frac": tl.bubble_fraction(),
            "sync_time_s": sync, "timeline": tl}


def timeline_to_simresult(plan: "StagePlan", tl: Timeline,
                          topo: Topology,
                          gg: "GroupedGraph | None" = None, *,
                          flushes: int = 1,
                          sync_time: float = 0.0) -> "SimResult":
    """Project a schedule ``Timeline`` into the ``SimResult`` shape.

    The GNN featurization consumes it (runtime-feedback features part
    3), so schedule-aware MCTS evaluations feed the policy the same way
    FIFO evaluations do: per-device busy/idle, per-link busy, peak
    memory, and per-op-group start/finish mapped through the stage that
    hosts the group.
    """
    from repro.core.simulator import SimResult

    step = flushes * tl.makespan + sync_time
    dev_busy: dict[int, float] = {}
    peak_mem: dict[int, float] = {}
    link_busy: dict[tuple[int, int], float] = {}
    order: list[list[TimedEvent]] = [[] for _ in range(tl.n_stages)]
    for e in tl.events:
        if e.kind == "X":
            gi = plan.stages[e.src].device_group
            gj = plan.stages[e.stage].device_group
            link_busy[(gi, gj)] = link_busy.get((gi, gj), 0.0) \
                + e.dur * flushes
        else:
            order[e.stage].append(e)
    base = [sum(topo.groups[k].num_gpus for k in range(g))
            for g in range(topo.m)]
    stash = peak_stash(order) if any(order) else [0] * tl.n_stages
    for si, st in enumerate(plan.stages):
        g = st.device_group
        dg = topo.groups[g]
        act = boundary_bytes(plan, si - 1 if si else si, tl.n_micro) \
            * 2.0 * stash[si]
        per_dev = 4.0 * st.param_bytes + act / max(dg.num_gpus, 1)
        for d in range(base[g], base[g] + dg.num_gpus):
            dev_busy[d] = tl.stage_busy[si] * flushes + sync_time
            peak_mem[d] = per_dev
    res = SimResult(makespan=step, feasible=True, task_start=[],
                    task_finish=[], device_busy=dev_busy,
                    peak_mem=peak_mem, link_busy=link_busy)
    if gg is not None:
        span: dict[int, tuple[float, float]] = {}
        for e in tl.events:
            if e.kind == "X":
                continue
            lo, hi = span.get(e.stage, (e.start, e.finish))
            span[e.stage] = (min(lo, e.start), max(hi, e.finish))
        for si, st in enumerate(plan.stages):
            lo, hi = span.get(si, (0.0, 0.0))
            for gid in st.op_group_ids:
                res.group_start[gid] = lo
                res.group_finish[gid] = hi
    return res
