"""Microbatch pipeline schedules as explicit event lists.

A schedule is, per stage, an ordered list of ``Event``s — ``F(s, m)``
(forward of microbatch ``m`` on stage ``s``) and ``B(s, m)`` (backward).
Two classic schedules are provided:

  * **GPipe**: all forwards, then all backwards (backwards in reverse
    microbatch order). Activation stash peaks at ``n_micro`` per stage.
  * **1F1B** (PipeDream-flush): each stage runs a warm-up of
    ``min(S - s, M)`` forwards, then alternates one-forward/one-backward,
    then drains. Stash peaks at ``min(S - s, M)`` — bounded by the stage
    depth, so deeper microbatching is free memory-wise.

``simulate_schedule`` lowers a (StagePlan, schedule) pair onto a
``Topology`` as a dependency-driven timeline: per-stage serial execution
in schedule order, cross-stage activation / activation-grad transfers
serialized per directed link. The same timeline code is the *predicted*
side of the replay executor's cross-check (``exec.replay``) and the
bubble-fraction source for the pipeline benchmark.
"""
from __future__ import annotations

from dataclasses import dataclass, field

from repro.core.device import Topology
from repro.core.profiler import compute_time, transfer_time

SCHEDULES = ("gpipe", "1f1b")

# fraction of a group's traced flops attributed to the forward pass (the
# training trace contains fwd+bwd; backward is ~2x forward for dense nets)
FWD_FRAC = 1.0 / 3.0

# a stage boundary's crossing bytes come from the fwd+bwd trace, so they
# cover BOTH directions: the F-edge carries the activation half, the
# B-edge the activation-grad half
BOUNDARY_DIR_FRAC = 0.5


@dataclass(frozen=True)
class Event:
    kind: str                 # "F" | "B"
    stage: int
    mb: int

    def __repr__(self):
        return f"{self.kind}{self.stage}.{self.mb}"


def gpipe_schedule(n_stages: int, n_micro: int) -> list:
    """Per-stage issue order: F(0..M-1) then B(M-1..0)."""
    out = []
    for s in range(n_stages):
        evs = [Event("F", s, m) for m in range(n_micro)]
        evs += [Event("B", s, m) for m in reversed(range(n_micro))]
        out.append(evs)
    return out


def one_f_one_b_schedule(n_stages: int, n_micro: int) -> list:
    """Per-stage issue order with warm-up ``min(S - s, M)`` forwards."""
    out = []
    for s in range(n_stages):
        warm = min(n_stages - s, n_micro)
        evs = [Event("F", s, m) for m in range(warm)]
        nf, nb = warm, 0
        while nb < n_micro:
            evs.append(Event("B", s, nb))
            nb += 1
            if nf < n_micro:
                evs.append(Event("F", s, nf))
                nf += 1
        out.append(evs)
    return out


def make_schedule(name: str, n_stages: int, n_micro: int) -> list:
    if name == "gpipe":
        return gpipe_schedule(n_stages, n_micro)
    if name == "1f1b":
        return one_f_one_b_schedule(n_stages, n_micro)
    raise ValueError(f"unknown schedule {name!r} (use one of {SCHEDULES})")


def validate_schedule(order: list, n_stages: int, n_micro: int) -> None:
    """Schedule invariants; raises ``ValueError`` on violation:

      * every stage issues F and B of every microbatch exactly once;
      * per stage, B(s, m) comes after F(s, m);
      * a consistent global order exists: following per-stage order plus
        the cross-stage deps F(s,m) after F(s-1,m) and B(s,m) after
        B(s+1,m) never deadlocks (no stage executes a microbatch before
        its predecessor produced it).
    """
    if len(order) != n_stages:
        raise ValueError(f"{len(order)} stage lists != {n_stages} stages")
    for s, evs in enumerate(order):
        for kind in ("F", "B"):
            mbs = [e.mb for e in evs if e.kind == kind]
            if sorted(mbs) != list(range(n_micro)):
                raise ValueError(f"stage {s}: {kind} covers {sorted(mbs)}")
        seen_f = set()
        for e in evs:
            if e.kind == "F":
                seen_f.add(e.mb)
            elif e.mb not in seen_f:
                raise ValueError(f"stage {s}: B before F for mb {e.mb}")
    flatten_schedule(order, n_stages, n_micro)   # raises on deadlock


def flatten_schedule(order: list, n_stages: int, n_micro: int) -> list:
    """A single dependency-consistent global issue order (the eager
    engine executes events in this order). Raises on deadlock."""
    ptr = [0] * n_stages
    done: set = set()
    out = []
    total = sum(len(evs) for evs in order)
    while len(out) < total:
        progressed = False
        for s in range(n_stages):
            if ptr[s] >= len(order[s]):
                continue
            e = order[s][ptr[s]]
            if e.kind == "F":
                dep = None if s == 0 else Event("F", s - 1, e.mb)
            else:
                dep = None if s == n_stages - 1 else Event("B", s + 1, e.mb)
            need_f = Event("F", s, e.mb) if e.kind == "B" else None
            if (dep is None or dep in done) and \
                    (need_f is None or need_f in done):
                out.append(e)
                done.add(e)
                ptr[s] += 1
                progressed = True
        if not progressed:
            raise ValueError("schedule deadlocks: unsatisfiable order")
    return out


def peak_stash(order: list) -> list:
    """Per-stage peak number of in-flight forward activations (stash) —
    the pipeline's activation-memory driver: GPipe peaks at n_micro,
    1F1B at min(S - s, M)."""
    peaks = []
    for evs in order:
        cur = peak = 0
        for e in evs:
            cur += 1 if e.kind == "F" else -1
            peak = max(peak, cur)
        peaks.append(peak)
    return peaks


def max_feasible_micro(plan, schedule: str, *, mb_act_bytes: float,
                       mem_budget: float, cap: int = 64) -> int:
    """Largest microbatch count whose peak activation stash fits
    ``mem_budget`` per stage at a FIXED microbatch size (``mb_act_bytes``
    per stage per microbatch). GPipe stashes all M microbatches, so its
    feasible M is capped by memory; 1F1B's stash is bounded by the stage
    depth regardless of M — the schedule's headline advantage."""
    best = 0
    for m in range(1, cap + 1):
        order = make_schedule(schedule, plan.n_stages, m)
        if max(peak_stash(order)) * mb_act_bytes <= mem_budget:
            best = m
    return best


# ----------------------------------------------------------- timeline

@dataclass
class TimedEvent:
    kind: str                 # "F" | "B" | "X" (boundary transfer)
    stage: int                # executing stage (transfers: dst stage)
    mb: int
    start: float
    finish: float
    src: int = -1             # transfers: producing stage (F: stage-1,
    #                           B: stage+1); -1 for compute events

    @property
    def dur(self):
        return self.finish - self.start


@dataclass
class Timeline:
    events: list                         # list[TimedEvent]
    makespan: float
    stage_busy: list                     # compute seconds per stage
    n_stages: int
    n_micro: int
    meta: dict = field(default_factory=dict)

    def bubble_fraction(self) -> float:
        """1 - busy/(S * makespan): the idle share of stage-seconds."""
        if self.makespan <= 0:
            return 0.0
        return 1.0 - sum(self.stage_busy) / (self.n_stages * self.makespan)

    def finish_of(self, kind: str, stage: int, mb: int) -> float:
        for e in self.events:
            if e.kind == kind and e.stage == stage and e.mb == mb:
                return e.finish
        raise KeyError((kind, stage, mb))


def _stage_speed(plan, topo: Topology, s: int) -> float:
    dg = topo.groups[plan.stages[s].device_group]
    return dg.flops * max(dg.num_gpus, 1)


def simulate_schedule(plan, topo: Topology, order: list,
                      *, fwd_frac: float = FWD_FRAC) -> Timeline:
    """Dependency-driven timeline of a schedule on a topology.

    Per-stage compute is serial in the stage's issue order; forward of
    microbatch m on stage s waits for stage s-1's forward of m plus the
    boundary activation transfer; backward waits symmetrically on stage
    s+1 plus the activation-grad transfer. Transfers serialize per
    directed (src, dst) device-group link, so a congested boundary link
    shows up as pipeline bubble exactly like on a real cluster.
    """
    S = len(order)
    M = max((e.mb for evs in order for e in evs), default=-1) + 1
    fwd_t, bwd_t = [], []
    for s in range(S):
        flops_m = plan.stages[s].flops / max(M, 1)
        speed = _stage_speed(plan, topo, s)
        fwd_t.append(compute_time(flops_m * fwd_frac, speed))
        bwd_t.append(compute_time(flops_m * (1.0 - fwd_frac), speed))

    def xfer_t(src_stage: int, dst_stage: int) -> float:
        gi = plan.stages[src_stage].device_group
        gj = plan.stages[dst_stage].device_group
        nb = plan.stages[min(src_stage, dst_stage)].out_bytes \
            * BOUNDARY_DIR_FRAC / max(M, 1)
        if nb <= 0 or gi == gj:
            return 0.0
        return transfer_time(nb, topo.bw(gi, gj), topo.latency)

    finish: dict = {}                  # (kind, stage, mb) -> finish time
    stage_free = [0.0] * S
    link_free: dict = {}               # (src_g, dst_g) -> free time
    busy = [0.0] * S
    events: list = []
    ptr = [0] * S

    def ready(e: Event):
        """(ready time, transfer TimedEvent|None) for event e."""
        if e.kind == "F":
            if e.stage == 0:
                return 0.0, None
            src, key = e.stage - 1, ("F", e.stage - 1, e.mb)
        else:
            if e.stage == S - 1:
                return finish.get(("F", e.stage, e.mb), 0.0), None
            src, key = e.stage + 1, ("B", e.stage + 1, e.mb)
        if key not in finish:
            return None, None
        t0 = finish[key]
        dur = xfer_t(src, e.stage)
        if dur <= 0:
            return t0, None
        gi = plan.stages[src].device_group
        gj = plan.stages[e.stage].device_group
        s0 = max(t0, link_free.get((gi, gj), 0.0))
        link_free[(gi, gj)] = s0 + dur
        return s0 + dur, TimedEvent("X", e.stage, e.mb, s0, s0 + dur,
                                    src=src)

    total = sum(len(evs) for evs in order)
    while len(finish) < total:
        progressed = False
        for s in range(S):
            if ptr[s] >= len(order[s]):
                continue
            e = order[s][ptr[s]]
            if e.kind == "B" and ("F", s, e.mb) not in finish:
                continue
            rt, xev = ready(e)
            if rt is None:
                continue
            if xev is not None:
                events.append(xev)
            t = fwd_t[s] if e.kind == "F" else bwd_t[s]
            start = max(rt, stage_free[s])
            stage_free[s] = start + t
            busy[s] += t
            finish[(e.kind, s, e.mb)] = start + t
            events.append(TimedEvent(e.kind, s, e.mb, start, start + t))
            ptr[s] += 1
            progressed = True
        if not progressed:
            raise ValueError("schedule deadlocks on the timeline")
    makespan = max((e.finish for e in events), default=0.0)
    return Timeline(events=events, makespan=makespan, stage_busy=busy,
                    n_stages=S, n_micro=M,
                    meta={"fwd_t": fwd_t, "bwd_t": bwd_t})
