"""Replay executor for pipelines: run a (StagePlan, schedule) pair on a
cluster ``Topology`` and emit step telemetry.

The real engine (``exec.engine``) plays this role on actual hardware;
here the "cluster" is a ``Topology`` whose true parameters may differ
from the nominal ones the plan was searched under (the perturbed-cluster
scenario of the runtime-feedback benchmarks). One execution walks the
schedule timeline on the TRUE topology and records:

  * per-event compute samples (stage gpu_type, flops, time),
  * per-boundary transfer samples carrying the ``pair`` key
    (``"gi-gj"``) — the per-link-pair calibration tier's input
    (``runtime.calibration.fit_profile(min_pair_samples=...)``),

all normalized against the NOMINAL topology's spec-sheet numbers, exactly
what a live profiler would log. The predicted timeline and the executed
one come from the same schedule semantics, so
``simulate_schedule(plan, topo, order)`` at noise 0 must agree
event-for-event with the replay — the plan->execution cross-check the
tests assert.
"""
from __future__ import annotations

import numpy as np

from repro.core.device import Topology
from repro.exec.schedule import (
    DEFAULT_CHUNKS, FWD_FRAC, ZB_DGRAD_FRAC, Timeline, make_schedule,
    simulate_schedule)
from repro.exec.stages import StagePlan
from repro.runtime.telemetry import MeasurementStore, StepRecord


def execute_pipeline(plan: StagePlan, true_topo: Topology, *,
                     schedule: str = "1f1b",
                     n_chunks: int = DEFAULT_CHUNKS,
                     nominal_topo: Topology | None = None,
                     graph_fp: str = "", topo_fp: str = "",
                     step: int = 0, noise: float = 0.0, seed: int = 0,
                     store: MeasurementStore | None = None,
                     meta: dict | None = None, spool=None,
                     overlap: str = "link") -> tuple:
    """Execute one pipelined step on ``true_topo``; returns
    ``(StepRecord, Timeline)``. ``noise`` adds multiplicative jitter
    (relative std-dev) per recorded sample. ``n_chunks`` only applies to
    the interleaved schedule (virtual chunks per stage). ``overlap`` is
    the transfer/compute overlap model the replayed timeline runs under
    (default: the legacy link-serialization model, so predicted and
    replayed timelines agree event-for-event).

    ``spool`` (an ``obs.collector.SpoolWriter``) streams the executed
    events into the cross-process trace spool: simulated seconds are
    re-based onto this process's monotonic clock at emission time, so
    the merged trace shows the replay where it actually ran."""
    nominal = nominal_topo or true_topo
    rng = np.random.default_rng(seed)

    def jitter():
        return 1.0 + noise * float(rng.standard_normal()) if noise else 1.0

    order = make_schedule(schedule, plan.n_stages, plan.n_micro,
                          n_chunks=n_chunks)
    tl: Timeline = simulate_schedule(plan, true_topo, order,
                                     overlap=overlap)
    M = max(plan.n_micro, 1)
    has_w = any(e.kind == "W" for e in tl.events)
    bwd_frac = 1.0 - FWD_FRAC

    compute, collectives = [], []
    stage_events = []
    for e in tl.events:
        dur = e.dur * jitter()
        spec = plan.stages[e.stage]
        if e.kind != "X":
            if e.kind == "F":
                frac = FWD_FRAC
            elif e.kind == "W":
                frac = bwd_frac * (1.0 - ZB_DGRAD_FRAC)
            else:
                frac = bwd_frac * (ZB_DGRAD_FRAC if has_w else 1.0)
            compute.append({
                "gpu_type": spec.gpu_type,
                "flops": spec.flops / M / tl.n_chunks * frac,
                "time": dur, "stage": e.stage, "mb": e.mb,
                "kind": e.kind, "chunk": e.chunk})
        else:                              # "X": boundary transfer
            src = plan.stages[e.src]
            gi, gj = src.device_group, spec.device_group
            collectives.append({
                "kind": "xfer", "nbytes": e.nbytes, "n_dev": 2,
                "nominal_bw": nominal.nominal_bw(gi, gj),
                "link": "p2p", "pair": f"{gi}-{gj}", "time": dur})
        stage_events.append({"kind": e.kind, "stage": e.stage,
                             "mb": e.mb, "chunk": e.chunk, "src": e.src,
                             "start": e.start, "finish": e.start + dur})

    busy = {str(s.device_group): tl.stage_busy[i]
            for i, s in enumerate(plan.stages)}
    rec = StepRecord(
        graph_fp=graph_fp, topo_fp=topo_fp, step=step,
        wall_time=tl.makespan * jitter(),
        device_busy=busy, compute=compute, collectives=collectives,
        meta=dict(meta or {}, executor="pipeline-replay",
                  schedule=schedule, n_stages=plan.n_stages,
                  n_chunks=tl.n_chunks, n_micro=plan.n_micro,
                  bubble_frac=tl.bubble_fraction(),
                  true_topo=true_topo.name, events=stage_events))
    if store is not None:
        store.append(rec)
    if spool is not None:
        _spool_replay(spool, stage_events, plan.n_stages, schedule, step)
    return rec, tl


def _spool_replay(spool, stage_events: list, n_stages: int,
                  schedule: str, step: int):
    import time

    from repro.obs.trace import KIND_LABEL, event_name

    t0 = time.perf_counter()
    recs = [{"type": "track", "tid": s, "name": f"stage {s}"}
            for s in range(n_stages)]
    recs += [{"type": "track", "tid": n_stages + s,
              "name": f"stage {s} transfers in"}
             for s in sorted({e["stage"] for e in stage_events
                              if e["kind"] == "X"})]
    for e in stage_events:
        tid = e["stage"] if e["kind"] != "X" else n_stages + e["stage"]
        recs.append({
            "type": "span",
            "name": event_name(e["kind"], e["stage"], e["mb"], e["chunk"],
                               e["src"]),
            "cat": "pipeline", "tid": tid,
            "t0": t0 + e["start"], "t1": t0 + e["finish"],
            "args": {"kind": KIND_LABEL.get(e["kind"], e["kind"]),
                     "stage": e["stage"], "mb": e["mb"],
                     "chunk": e["chunk"], "schedule": schedule,
                     "step": step}})
    spool.emit_many(recs)
