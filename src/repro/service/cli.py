"""Planner-service CLI (installed as ``repro-plan``).

    python -m repro.service.cli plan --model vgg19 \
        --topo testbed --iterations 40 --cache-dir .plans
    python -m repro.service.cli inspect --cache-dir .plans
    python -m repro.service.cli evict --cache-dir .plans --max-age 86400
    python -m repro.service.cli observe --model vgg19 --topo testbed \
        --observed-time 0.31 --cache-dir .plans --telemetry-dir .telemetry
    python -m repro.service.cli calibrate --topo testbed \
        --telemetry-dir .telemetry --save profile.json
    python -m repro.service.cli drift --model vgg19 --topo testbed \
        --observed-time 0.31 --cache-dir .plans
    python -m repro.service.cli health --telemetry-dir .telemetry \
        --slo-ms 350
    python -m repro.service.cli policy train --models bert_small vgg19 \
        --name corpus-a --steps 16 --cache-dir .plans
    python -m repro.service.cli policy list --cache-dir .plans
    python -m repro.service.cli policy use --name corpus-a --cache-dir .plans
"""
from __future__ import annotations

import argparse
import json
import os

from repro.core import device as device_mod
from repro.core.graph import group_graph
from repro.core.jax_export import trace_training_graph
from repro.core.partition import partition
from repro.core.zoo import ZOO, build
from repro.service.planner import POLICY_SUBDIR, PlannerService
from repro.service.registry import PolicyRegistry
from repro.service.store import PlanStore

TOPOLOGIES = {
    "testbed": device_mod.testbed,
    "cloud": device_mod.cloud,
    "2x1080ti": device_mod.two_1080ti,
    "2xv100": device_mod.homogeneous_2v100,
    "tpu": device_mod.tpu_pods,
}


def _build_topology(name: str):
    return TOPOLOGIES[name]()


def _build_grouped(args):
    loss_fn, params, batch = build(args.model, batch=args.batch)
    g = trace_training_graph(loss_fn, params, batch, args.model).simplify()
    return group_graph(g, partition(g, args.n_groups))


def cmd_plan(args) -> int:
    gg = _build_grouped(args)
    svc = PlannerService(cache_dir=args.cache_dir)
    resp = svc.plan_graph(gg, _build_topology(args.topo),
                          iterations=args.iterations, seed=args.seed,
                          enable_sfb=not args.no_sfb)
    print(json.dumps({
        "model": args.model, "topo": args.topo, "source": resp.source,
        "iterations_run": resp.iterations_run,
        "time_s": resp.time, "baseline_s": resp.baseline_time,
        "speedup": round(resp.speedup, 4),
        "policy": resp.policy, "verify": resp.verify,
        "graph_fp": resp.graph_fp[:16], "topo_fp": resp.topo_fp[:16],
        "stats": svc.stats(),
    }, indent=2))
    return 0


# -------------------------------------------------------- policy registry

def _registry(args) -> PolicyRegistry:
    return PolicyRegistry(os.path.join(args.cache_dir, POLICY_SUBDIR))


def cmd_policy_train(args) -> int:
    """Train a GNN policy on a corpus of zoo graphs and register it."""
    from repro.core.trainer import init_trainer, train_policy
    from repro.service.fingerprint import (
        fingerprint_grouped_cached, structural_features)

    graphs = []
    for model in args.models:
        loss_fn, params, batch = build(model)
        g = trace_training_graph(loss_fn, params, batch, model).simplify()
        graphs.append(group_graph(g, partition(g, args.n_groups)))
    topologies = [_build_topology(t) for t in args.topos] or None

    state = init_trainer(seed=args.seed, lr=args.lr)
    state = train_policy(state, graphs, steps=args.steps,
                         mcts_iters=args.mcts_iters, seed=args.seed,
                         topologies=topologies, verbose=args.verbose)

    reg = _registry(args)
    rec = reg.save(
        args.name, state.cfg, state.params,
        corpus=[fingerprint_grouped_cached(g) for g in graphs],
        corpus_features=[structural_features(g) for g in graphs],
        meta={"models": list(args.models), "topos": list(args.topos),
              "steps": args.steps, "mcts_iters": args.mcts_iters,
              "seed": args.seed, "n_groups": args.n_groups,
              "final_loss": state.losses[-1] if state.losses else None})
    print(json.dumps({
        "registered": rec.name, "models": args.models,
        "steps": args.steps, "mcts_iters": args.mcts_iters,
        "final_loss": rec.meta["final_loss"],
        "registry": reg.path, "policies": len(reg),
    }, indent=2))
    return 0


def cmd_policy_list(args) -> int:
    reg = _registry(args)
    default = reg.default_name()
    rows = [{
        "name": r.name, "default": r.name == default,
        "corpus": [fp[:16] for fp in r.corpus],
        "models": r.meta.get("models"), "steps": r.meta.get("steps"),
        "final_loss": r.meta.get("final_loss"), "created": r.created,
    } for r in reg.records()]
    print(json.dumps({"policies": rows, "count": len(rows),
                      "default": default}, indent=2))
    return 0


def cmd_policy_use(args) -> int:
    """Pin a registered policy: the planner serves every request with it
    (overrides corpus / structural matching) until re-pinned."""
    reg = _registry(args)
    try:
        reg.set_default(args.name)
    except (OSError, ValueError, KeyError) as e:
        print(json.dumps({"error": f"cannot pin {args.name!r}: {e}"}))
        return 1
    print(json.dumps({"default": args.name, "registry": reg.path}))
    return 0


def cmd_policy_evict(args) -> int:
    """Registry-level eviction: drop a named checkpoint and/or apply
    age / size / count budgets (pinned default is never evicted)."""
    reg = _registry(args)
    n = 0
    if args.name:
        n += int(reg.remove(args.name))
    if args.max_age is not None or args.max_bytes is not None \
            or args.max_count is not None:
        n += reg.evict_expired(max_age_s=args.max_age,
                               max_bytes=args.max_bytes,
                               max_count=args.max_count)
    print(json.dumps({"evicted": n, "remaining": len(reg),
                      "default": reg.default_name()}))
    return 0


def cmd_inspect(args) -> int:
    store = PlanStore(path=args.cache_dir)
    rows = [{
        "graph_fp": r.graph_fp[:16], "topo_fp": r.topo_fp[:16],
        "n_groups": r.n_groups, "topo_m": r.topo_m,
        "time_s": r.time, "speedup": round(r.speedup, 4),
        "meta": r.meta,
    } for r in store.records()]
    print(json.dumps({"records": rows, "count": len(rows)}, indent=2))
    return 0


def cmd_evict(args) -> int:
    store = PlanStore(path=args.cache_dir)
    n = 0
    if args.graph_fp or args.topo_fp or args.all:
        n += store.evict(graph_fp=args.graph_fp, topo_fp=args.topo_fp,
                         all=args.all)
    if args.max_age is not None or args.max_bytes is not None \
            or args.per_topo_quota is not None:
        n += store.evict_expired(max_age_s=args.max_age,
                                 max_bytes=args.max_bytes,
                                 per_topo_quota=args.per_topo_quota)
    print(json.dumps({"evicted": n, "remaining": len(store)}))
    return 0


def cmd_observe(args) -> int:
    """Feed an observed step time back: logs telemetry, and past the drift
    threshold invalidates + replans under a recalibrated cost model."""
    gg = _build_grouped(args)
    svc = PlannerService(cache_dir=args.cache_dir,
                         telemetry_dir=args.telemetry_dir,
                         drift_threshold=args.threshold)
    res = svc.observe(gg, _build_topology(args.topo), args.observed_time,
                      iterations=args.iterations, seed=args.seed)
    out = {"model": args.model, "topo": args.topo, "kind": res.kind,
           "observed_s": res.observed}
    if res.report is not None:
        out["drift"] = res.report.to_dict()
    if res.kind == "replanned":
        out["stale_time_s"] = res.stale_time
        out["new_time_s"] = res.response.time
        out["improved"] = res.improved
        if res.profile is not None:
            out["profile"] = res.profile.to_dict()
    print(json.dumps(out, indent=2))
    return 0


def cmd_calibrate(args) -> int:
    """Fit a CalibrationProfile from accumulated step telemetry."""
    from repro.runtime.calibration import fit_profile
    from repro.runtime.telemetry import MeasurementStore
    from repro.service.fingerprint import fingerprint_topology
    topo = _build_topology(args.topo)
    store = MeasurementStore(args.telemetry_dir)
    recs = store.records(
        topo_fp=fingerprint_topology(topo) if args.match_topo else None)
    if not recs:
        print(json.dumps({"error": "no matching measurements",
                          "telemetry_dir": args.telemetry_dir}))
        return 1
    profile = fit_profile(recs, topo)
    if args.save:
        profile.save(args.save)
    print(json.dumps({"topo": args.topo, "records": len(recs),
                      "profile": profile.to_dict(),
                      "saved": args.save or None}, indent=2))
    return 0


def cmd_drift(args) -> int:
    """Report-only drift check of an observed time vs the cached plan."""
    from repro.service.fingerprint import (
        fingerprint_grouped, fingerprint_topology)
    gg = _build_grouped(args)
    topo = _build_topology(args.topo)
    store = PlanStore(path=args.cache_dir)
    rec = store.get(fingerprint_grouped(gg), fingerprint_topology(topo))
    if rec is None:
        print(json.dumps({"error": "no cached plan for (model, topo)"}))
        return 1
    drift = abs(args.observed_time - rec.time) / rec.time \
        if rec.time > 0 else float("inf")
    print(json.dumps({
        "model": args.model, "topo": args.topo,
        "simulated_s": rec.time, "observed_s": args.observed_time,
        "drift": round(drift, 4), "threshold": args.threshold,
        "drifted": drift > args.threshold,
    }, indent=2))
    return 0


SCHEDULES = ("gpipe", "1f1b", "interleaved", "zb")


def cmd_trace(args) -> int:
    """Export predicted + executed Chrome traces per schedule, plus the
    per-(stage, mb, kind) diff report attributing step-time error."""
    from repro.core.strategy import Action, Option, Strategy
    from repro.exec.replay import execute_pipeline
    from repro.exec.schedule import make_schedule, simulate_schedule
    from repro.exec.stages import build_stage_plan
    from repro.obs import (
        chrome_trace, diff_report, executed_trace_events, format_diff,
        timeline_trace_events, write_chrome_trace)
    from repro.obs.metrics import MetricsRegistry

    gg = _build_grouped(args)
    topo = _build_topology(args.topo)
    placement = tuple(range(topo.m))
    strat = Strategy([
        Action(placement, Option.PIPE) if i % 2 == 0
        else Action(placement, Option.PS) for i in range(gg.n)])
    plan = build_stage_plan(gg, strat, topo, n_micro=args.n_micro)
    if plan is None or plan.n_stages < 2:
        print(json.dumps(
            {"error": "no multi-stage pipeline spine for this "
                      "(model, topo)", "model": args.model,
             "topo": args.topo}))
        return 1
    S = plan.n_stages
    m = max(S, (args.n_micro // S) * S)   # interleaved needs m % S == 0
    plan.n_micro = m
    os.makedirs(args.out_dir, exist_ok=True)

    registry = MetricsRegistry()
    g_bubble = registry.gauge(
        "pipeline_bubble_fraction",
        "executed idle fraction of the pipeline flush per schedule")
    g_err = registry.gauge(
        "pipeline_step_error_frac",
        "(executed - predicted) / predicted step seconds per schedule")
    out = {"model": args.model, "topo": args.topo, "n_stages": S,
           "n_micro": m, "out_dir": args.out_dir, "schedules": {}}
    for name in (args.schedules or SCHEDULES):
        predicted = simulate_schedule(plan, topo,
                                      make_schedule(name, S, m))
        rec, tl = execute_pipeline(plan, topo, schedule=name,
                                   noise=args.noise, seed=args.seed)
        events = timeline_trace_events(
            predicted, pid=0, process_name=f"predicted [{name}]")
        events += executed_trace_events(
            rec, pid=1, process_name=f"executed [{name}]", n_stages=S)
        trace_path = write_chrome_trace(
            os.path.join(args.out_dir, f"trace_{args.model}_{name}.json"),
            chrome_trace(events, model=args.model, topo=args.topo,
                         schedule=name, n_micro=m))
        report = diff_report(predicted, rec,
                             executed_wall=rec.wall_time)
        diff_path = os.path.join(args.out_dir,
                                 f"diff_{args.model}_{name}.json")
        with open(diff_path, "w") as f:
            json.dump(report, f, indent=2, sort_keys=True)
        g_bubble.set(tl.bubble_fraction(), schedule=name)
        g_err.set(report["step_error_frac"], schedule=name)
        out["schedules"][name] = {
            "trace": trace_path, "diff": diff_path,
            "predicted_step_s": report["predicted_step_s"],
            "executed_step_s": report["executed_step_s"],
            "step_error_frac": report["step_error_frac"],
            "bubble_frac": tl.bubble_fraction(),
            "events_matched": report["events_matched"]}
        if args.verbose:
            print(f"--- {name} ---")
            print(format_diff(report))
    metrics_path = os.path.join(args.out_dir, "trace_metrics.prom")
    with open(metrics_path, "w") as f:
        f.write(registry.to_prometheus())
    out["metrics"] = metrics_path
    print(json.dumps(out, indent=2))
    return 0


def cmd_verify(args) -> int:
    """Static plan verification. ``--selftest`` runs the mutation
    harness (every injected violation class must be caught — the CI
    soundness gate); otherwise searches (or loads) a plan for
    (model, topo) and renders its diagnostics, exit 1 on errors."""
    from repro.verify import run_selftest, verify_deployment

    if args.selftest:
        res = run_selftest()
        print(json.dumps(res, indent=2))
        return 0 if res["ok"] else 1
    if not args.model:
        print(json.dumps({"error": "verify needs --model (or "
                                   "--selftest)"}))
        return 2
    gg = _build_grouped(args)
    topo = _build_topology(args.topo)
    # verify="off": this command IS the verification — run it once,
    # below, with the full report instead of the cached summary
    svc = PlannerService(cache_dir=args.cache_dir, verify="off")
    resp = svc.plan_graph(gg, topo, iterations=args.iterations,
                          seed=args.seed, enable_sfb=not args.no_sfb)
    rep = verify_deployment(gg, resp.strategy, topo,
                            n_micro=args.n_micro)
    if args.json:
        print(json.dumps({
            "model": args.model, "topo": args.topo,
            "source": resp.source, "verdict": rep.verdict,
            "summary": rep.summary(), "diagnostics": rep.to_dict(),
        }, indent=2))
    else:
        print(f"{args.model} on {args.topo} "
              f"(plan source: {resp.source}): {rep.verdict}")
        text = rep.format()
        if text:
            print(text)
    return 1 if rep.errors() else 0


def _metrics_once(args) -> None:
    """One metrics dump: from a running server (``--url``, validated
    through the exposition parser so the served text can't silently
    diverge from the format contract) or assembled locally."""
    if args.url:
        import urllib.request

        from repro.obs.metrics import parse_prometheus_text
        base = args.url.rstrip("/")
        with urllib.request.urlopen(base + "/metrics", timeout=30) as r:
            text = r.read().decode("utf-8")
        parse_prometheus_text(text)
        if args.format == "prometheus":
            print(text, end="" if text.endswith("\n") else "\n")
        else:
            with urllib.request.urlopen(base + "/plans", timeout=30) as r:
                print(r.read().decode("utf-8"), end="")
        return
    from repro.obs.spans import export_tracer_metrics
    svc = PlannerService(cache_dir=args.cache_dir)
    registry = svc.metrics
    registry.gauge("planner_store_size",
                   "plans resident in the store").set(len(svc.store))
    export_tracer_metrics(registry)
    fitted = 0
    if args.telemetry_dir:
        from repro.runtime.calibration import fit_profile, profile_metrics
        from repro.runtime.telemetry import MeasurementStore
        recs = MeasurementStore(args.telemetry_dir).records()
        if recs:
            profile = fit_profile(recs, _build_topology(args.topo))
            profile_metrics(profile, registry)
            fitted = len(recs)
    if args.format == "prometheus":
        print(registry.to_prometheus())
    else:
        print(json.dumps({"stats": svc.stats(),
                          "telemetry_records": fitted}, indent=2))


def cmd_metrics(args) -> int:
    """Operational metrics snapshot: planner store gauges plus — given a
    telemetry dir — the calibration fit (per-device-type AND per-op-type
    utilization, link efficiencies) as gauges. ``--watch S`` re-dumps
    every S seconds; ``--url`` reads a running ``serve-metrics`` server
    instead of assembling metrics locally."""
    import time as time_mod
    n = 0
    while True:
        _metrics_once(args)
        n += 1
        if not args.watch or (args.watch_count and n >= args.watch_count):
            return 0
        time_mod.sleep(args.watch)


def cmd_serve_metrics(args) -> int:
    """Run the live observability plane: /metrics, /healthz,
    /traces/<run_id>, /plans, /runs, /alerts — plus (unless
    ``--no-recalibrate``) the background recalibration loop polling the
    telemetry dir and replanning watched workloads on drift, ordered by
    the health analyzer's attribution."""
    import time as time_mod

    from repro.obs.alerts import load_rules
    from repro.obs.collector import SpoolWriter, TraceCollector
    from repro.obs.health import RunHealthAnalyzer
    from repro.obs.server import ObsServer
    from repro.runtime.telemetry import MeasurementStore

    svc = PlannerService(cache_dir=args.cache_dir,
                         telemetry_dir=args.telemetry_dir or None,
                         drift_threshold=args.threshold)
    spool = collector = loop = analyzer = None
    if args.spool_dir:
        spool = SpoolWriter(args.spool_dir, run_id=args.run_id,
                            name="planner")
        collector = TraceCollector(args.spool_dir)
        # serving a spool implies the planner's own spans are wanted in
        # the merged trace
        from repro.obs.spans import get_tracer
        get_tracer().enable()
    if not args.no_health:
        rules = load_rules(args.alert_rules) if args.alert_rules else None
        hstore = MeasurementStore(args.telemetry_dir) \
            if args.telemetry_dir else None
        analyzer = RunHealthAnalyzer(
            hstore, registry=svc.metrics,
            slo_s=args.slo_ms / 1000.0 if args.slo_ms else None,
            slo_objective=args.slo_objective, alert_rules=rules)
    watched = None
    if not args.no_recalibrate:
        from repro.runtime.feedback import RecalibrationLoop
        loop = RecalibrationLoop(svc, interval_s=args.interval,
                                 iterations=args.iterations,
                                 health=analyzer)
        if args.model:
            watched = loop.watch(_build_grouped(args),
                                 _build_topology(args.topo))
    server = ObsServer(registry=svc.metrics, service=svc,
                       collector=collector, spool=spool, recalib=loop,
                       health=analyzer, host=args.host, port=args.port,
                       spool_max_age_s=args.spool_max_age,
                       spool_max_bytes=args.spool_max_bytes)
    server.start()
    print(json.dumps({
        "url": server.url,
        "endpoints": ["/metrics", "/healthz", "/plans",
                      "/plans/<fingerprint>/verify", "/traces",
                      "/traces/<run_id>", "/runs",
                      "/runs/<run_id>/health", "/alerts"],
        "cache_dir": args.cache_dir,
        "telemetry_dir": args.telemetry_dir or None,
        "spool_dir": args.spool_dir or None,
        "recalibrate": loop is not None,
        "health": analyzer is not None,
        "slo_ms": args.slo_ms or None,
        "watched": list(watched) if watched else None,
    }, indent=2), flush=True)
    try:
        if args.duration > 0:
            time_mod.sleep(args.duration)
        else:
            while True:
                time_mod.sleep(3600)
    except KeyboardInterrupt:
        pass
    finally:
        server.stop()
    return 0


def cmd_health(args) -> int:
    """Run-health snapshot: per-run residual attribution, straggler
    ranking, SLO burn rates and alert states. ``--url`` reads a running
    ``serve-metrics`` server (/runs, /runs/<id>/health, /alerts);
    otherwise a local ``RunHealthAnalyzer`` drains the telemetry dir
    once and renders the same view."""
    if args.url:
        import urllib.request
        base = args.url.rstrip("/")

        def _get(path):
            with urllib.request.urlopen(base + path, timeout=30) as r:
                return json.loads(r.read().decode("utf-8"))

        runs = _get("/runs")
        out = {"url": base, "runs": runs, "alerts": _get("/alerts")}
        if args.run_id:
            out["health"] = _get(f"/runs/{args.run_id}/health")
        else:
            out["health"] = {
                r["run_id"]: _get(f"/runs/{r['run_id']}/health")
                for r in runs.get("runs", [])}
        print(json.dumps(out, indent=2))
        return 0

    from repro.obs.alerts import load_rules
    from repro.obs.health import RunHealthAnalyzer
    from repro.runtime.telemetry import MeasurementStore
    rules = load_rules(args.alert_rules) if args.alert_rules else None
    analyzer = RunHealthAnalyzer(
        MeasurementStore(args.telemetry_dir),
        slo_s=args.slo_ms / 1000.0 if args.slo_ms else None,
        slo_objective=args.slo_objective, alert_rules=rules)
    n = analyzer.poll()
    if n == 0:
        print(json.dumps({"error": "no telemetry records",
                          "telemetry_dir": args.telemetry_dir}))
        return 1
    out = {"telemetry_dir": args.telemetry_dir, "ingested": n,
           "runs": analyzer.run_summaries(),
           "alerts": analyzer.alerts(),
           "stats": analyzer.stats()}
    ids = [args.run_id] if args.run_id else analyzer.run_ids()
    try:
        out["health"] = {rid: analyzer.health(rid) for rid in ids}
    except KeyError:
        print(json.dumps({"error": f"unknown run {args.run_id!r}",
                          "runs": analyzer.run_ids()}))
        return 1
    print(json.dumps(out, indent=2))
    return 0


def _add_model_args(p):
    p.add_argument("--model", choices=sorted(ZOO), required=True)
    p.add_argument("--topo", choices=sorted(TOPOLOGIES), default="testbed")
    p.add_argument("--n-groups", type=int, default=30)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--cache-dir", default=".plans")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.service.cli")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="plan a zoo model on a topology")
    _add_model_args(p)
    p.add_argument("--iterations", type=int, default=40)
    p.add_argument("--no-sfb", action="store_true")
    p.set_defaults(fn=cmd_plan)

    p = sub.add_parser("inspect", help="list cached plan records")
    p.add_argument("--cache-dir", default=".plans")
    p.set_defaults(fn=cmd_inspect)

    p = sub.add_parser("evict", help="remove cached plan records")
    p.add_argument("--cache-dir", default=".plans")
    p.add_argument("--graph-fp", default=None,
                   help="graph fingerprint (prefix) to evict")
    p.add_argument("--topo-fp", default=None,
                   help="topology fingerprint (prefix) to evict")
    p.add_argument("--all", action="store_true")
    p.add_argument("--max-age", type=float, default=None,
                   help="evict disk records older than SECONDS")
    p.add_argument("--max-bytes", type=int, default=None,
                   help="shrink the disk tier to this many bytes")
    p.add_argument("--per-topo-quota", type=int, default=None,
                   help="keep at most N records per topology")
    p.set_defaults(fn=cmd_evict)

    p = sub.add_parser("observe",
                       help="feed an observed step time into the "
                            "runtime feedback loop")
    _add_model_args(p)
    p.add_argument("--observed-time", type=float, required=True,
                   help="measured per-step wall time (s)")
    p.add_argument("--telemetry-dir", default=".telemetry")
    p.add_argument("--threshold", type=float, default=0.25)
    p.add_argument("--iterations", type=int, default=20,
                   help="re-search budget on drift")
    p.set_defaults(fn=cmd_observe)

    p = sub.add_parser("calibrate",
                       help="fit a calibration profile from telemetry")
    p.add_argument("--topo", choices=sorted(TOPOLOGIES), default="testbed")
    p.add_argument("--telemetry-dir", default=".telemetry")
    p.add_argument("--match-topo", action="store_true",
                   help="only use records whose topo fingerprint matches")
    p.add_argument("--save", default=None,
                   help="write the fitted profile JSON here")
    p.set_defaults(fn=cmd_calibrate)

    p = sub.add_parser("drift",
                       help="report observed-vs-simulated drift "
                            "(no invalidation)")
    _add_model_args(p)
    p.add_argument("--observed-time", type=float, required=True)
    p.add_argument("--threshold", type=float, default=0.25)
    p.set_defaults(fn=cmd_drift)

    p = sub.add_parser("trace",
                       help="export predicted + executed Chrome traces "
                            "and the predicted-vs-executed diff report")
    _add_model_args(p)
    p.add_argument("--schedules", nargs="*", choices=SCHEDULES,
                   default=None,
                   help="schedules to trace (default: all four)")
    p.add_argument("--n-micro", type=int, default=8,
                   help="microbatches per step (rounded to a multiple "
                        "of the stage count)")
    p.add_argument("--noise", type=float, default=0.0,
                   help="relative jitter on executed samples (makes the "
                        "diff report non-trivial)")
    p.add_argument("--out-dir", default="traces")
    p.add_argument("--verbose", action="store_true",
                   help="print the human diff per schedule")
    p.set_defaults(fn=cmd_trace)

    p = sub.add_parser("verify",
                       help="static plan verification: lint a searched "
                            "deployment (or --selftest the verifier's "
                            "mutation harness)")
    p.add_argument("--selftest", action="store_true",
                   help="run the mutation self-test across all four "
                        "schedule families; exit 1 on any miss")
    p.add_argument("--model", choices=sorted(ZOO), default=None)
    p.add_argument("--topo", choices=sorted(TOPOLOGIES), default="testbed")
    p.add_argument("--n-groups", type=int, default=30)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--cache-dir", default=".plans")
    p.add_argument("--iterations", type=int, default=40,
                   help="search budget when the plan is not cached")
    p.add_argument("--n-micro", type=int, default=None,
                   help="verify at this microbatch count (default: the "
                        "plan's own)")
    p.add_argument("--no-sfb", action="store_true")
    p.add_argument("--json", action="store_true",
                   help="machine-readable diagnostics")
    p.set_defaults(fn=cmd_verify)

    p = sub.add_parser("metrics",
                       help="dump planner + calibration metrics "
                            "(Prometheus text or JSON)")
    p.add_argument("--cache-dir", default=".plans")
    p.add_argument("--telemetry-dir", default=None,
                   help="fit a calibration profile from this telemetry "
                        "and surface it as gauges")
    p.add_argument("--topo", choices=sorted(TOPOLOGIES), default="testbed")
    p.add_argument("--format", choices=("prometheus", "json"),
                   default="prometheus")
    p.add_argument("--watch", type=float, default=0.0, metavar="SECONDS",
                   help="re-dump every SECONDS (0: once)")
    p.add_argument("--watch-count", type=int, default=0,
                   help="with --watch: stop after N dumps (0: forever)")
    p.add_argument("--url", default="",
                   help="read /metrics from a running serve-metrics "
                        "server (validated through the exposition "
                        "parser) instead of assembling locally")
    p.set_defaults(fn=cmd_metrics)

    p = sub.add_parser("serve-metrics",
                       help="serve /metrics, /healthz, /traces/<run_id>, "
                            "/plans; optionally run the continuous "
                            "recalibration loop")
    p.add_argument("--host", default="127.0.0.1")
    p.add_argument("--port", type=int, default=9464,
                   help="bind port (0: pick a free one; printed as JSON "
                        "on startup)")
    p.add_argument("--cache-dir", default=".plans")
    p.add_argument("--telemetry-dir", default=".telemetry",
                   help="measurement log the recalibration loop polls "
                        "via read_new()")
    p.add_argument("--spool-dir", default="",
                   help="cross-process trace spool to collect and serve "
                        "under /traces/<run_id>")
    p.add_argument("--run-id", default="planner",
                   help="run id for this process's own spool shard")
    p.add_argument("--spool-max-age", type=float, default=None,
                   metavar="SECONDS",
                   help="retention GC: delete fully-drained spool "
                        "shards older than SECONDS on each scrape")
    p.add_argument("--spool-max-bytes", type=int, default=None,
                   help="retention GC: shrink drained spool shards to "
                        "this many bytes (oldest deleted first)")
    p.add_argument("--interval", type=float, default=5.0,
                   help="recalibration poll interval (s)")
    p.add_argument("--iterations", type=int, default=20,
                   help="re-search budget when drift trips")
    p.add_argument("--threshold", type=float, default=0.25,
                   help="EWMA drift threshold")
    p.add_argument("--no-recalibrate", action="store_true",
                   help="serve only; no background feedback loop")
    p.add_argument("--model", choices=sorted(ZOO), default=None,
                   help="watch this zoo model for unattended replanning "
                        "(with --topo/--n-groups/--batch)")
    p.add_argument("--topo", choices=sorted(TOPOLOGIES), default="testbed")
    p.add_argument("--n-groups", type=int, default=30)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--duration", type=float, default=0.0,
                   help="serve for SECONDS then exit (0: until "
                        "interrupted) — CI smoke uses this")
    p.add_argument("--slo-ms", type=float, default=None,
                   help="step-time SLO target in milliseconds; arms "
                        "burn-rate alerting on /alerts")
    p.add_argument("--slo-objective", type=float, default=0.99,
                   help="fraction of steps that must meet the target "
                        "(error budget = 1 - objective)")
    p.add_argument("--alert-rules", default=None, metavar="PATH",
                   help="JSON AlertRule list overriding the default "
                        "page/warn burn-rate pair")
    p.add_argument("--no-health", action="store_true",
                   help="disable the run-health analyzer (/runs, "
                        "/alerts return 404)")
    p.set_defaults(fn=cmd_serve_metrics)

    p = sub.add_parser("health",
                       help="run-health snapshot: residual attribution, "
                            "stragglers, SLO burn rates, alert states")
    p.add_argument("--telemetry-dir", default=".telemetry",
                   help="measurement log to drain (local mode)")
    p.add_argument("--run-id", default=None,
                   help="restrict the detail view to one run")
    p.add_argument("--slo-ms", type=float, default=None)
    p.add_argument("--slo-objective", type=float, default=0.99)
    p.add_argument("--alert-rules", default=None, metavar="PATH")
    p.add_argument("--url", default="",
                   help="read /runs + /runs/<id>/health + /alerts from "
                        "a running serve-metrics server instead of "
                        "draining telemetry locally")
    p.set_defaults(fn=cmd_health)

    p = sub.add_parser("policy",
                       help="train / list / pin registered GNN policies")
    psub = p.add_subparsers(dest="policy_cmd", required=True)

    pp = psub.add_parser("train",
                         help="train a policy on zoo graphs + register it")
    pp.add_argument("--models", nargs="+", choices=sorted(ZOO),
                    required=True)
    pp.add_argument("--name", required=True,
                    help="registry name for the checkpoint")
    pp.add_argument("--topos", nargs="*", choices=sorted(TOPOLOGIES),
                    default=[],
                    help="training topologies (default: random per step)")
    pp.add_argument("--steps", type=int, default=16)
    pp.add_argument("--mcts-iters", type=int, default=16)
    pp.add_argument("--n-groups", type=int, default=30)
    pp.add_argument("--lr", type=float, default=3e-4)
    pp.add_argument("--seed", type=int, default=0)
    pp.add_argument("--cache-dir", default=".plans")
    pp.add_argument("--verbose", action="store_true")
    pp.set_defaults(fn=cmd_policy_train)

    pp = psub.add_parser("list", help="list registered policies")
    pp.add_argument("--cache-dir", default=".plans")
    pp.set_defaults(fn=cmd_policy_list)

    pp = psub.add_parser("use", help="pin the policy served by default")
    pp.add_argument("--name", required=True)
    pp.add_argument("--cache-dir", default=".plans")
    pp.set_defaults(fn=cmd_policy_use)

    pp = psub.add_parser("evict",
                         help="drop checkpoints by name or budget "
                              "(age/bytes/count; pinned default kept)")
    pp.add_argument("--name", default=None,
                    help="remove this checkpoint")
    pp.add_argument("--max-age", type=float, default=None,
                    help="evict checkpoints older than SECONDS")
    pp.add_argument("--max-bytes", type=int, default=None,
                    help="shrink the registry to this many bytes")
    pp.add_argument("--max-count", type=int, default=None,
                    help="keep at most N checkpoints (newest win)")
    pp.add_argument("--cache-dir", default=".plans")
    pp.set_defaults(fn=cmd_policy_evict)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
