"""Planner-service CLI.

    PYTHONPATH=src python -m repro.service.cli plan --model vgg19 \
        --topo testbed --iterations 40 --cache-dir .plans
    PYTHONPATH=src python -m repro.service.cli inspect --cache-dir .plans
    PYTHONPATH=src python -m repro.service.cli evict --cache-dir .plans --all
"""
from __future__ import annotations

import argparse
import json

from repro.core import device as device_mod
from repro.core.graph import group_graph
from repro.core.jax_export import trace_training_graph
from repro.core.partition import partition
from repro.core.zoo import ZOO, build
from repro.service.planner import PlannerService
from repro.service.store import PlanStore

TOPOLOGIES = {
    "testbed": device_mod.testbed,
    "cloud": device_mod.cloud,
    "2x1080ti": device_mod.two_1080ti,
    "2xv100": device_mod.homogeneous_2v100,
    "tpu": device_mod.tpu_pods,
}


def _build_topology(name: str):
    return TOPOLOGIES[name]()


def cmd_plan(args) -> int:
    loss_fn, params, batch = build(args.model, batch=args.batch)
    g = trace_training_graph(loss_fn, params, batch, args.model).simplify()
    gg = group_graph(g, partition(g, args.n_groups))
    svc = PlannerService(cache_dir=args.cache_dir)
    resp = svc.plan_graph(gg, _build_topology(args.topo),
                          iterations=args.iterations, seed=args.seed,
                          enable_sfb=not args.no_sfb)
    print(json.dumps({
        "model": args.model, "topo": args.topo, "source": resp.source,
        "iterations_run": resp.iterations_run,
        "time_s": resp.time, "baseline_s": resp.baseline_time,
        "speedup": round(resp.speedup, 4),
        "graph_fp": resp.graph_fp[:16], "topo_fp": resp.topo_fp[:16],
        "stats": svc.stats(),
    }, indent=2))
    return 0


def cmd_inspect(args) -> int:
    store = PlanStore(path=args.cache_dir)
    rows = [{
        "graph_fp": r.graph_fp[:16], "topo_fp": r.topo_fp[:16],
        "n_groups": r.n_groups, "topo_m": r.topo_m,
        "time_s": r.time, "speedup": round(r.speedup, 4),
        "meta": r.meta,
    } for r in store.records()]
    print(json.dumps({"records": rows, "count": len(rows)}, indent=2))
    return 0


def cmd_evict(args) -> int:
    store = PlanStore(path=args.cache_dir)
    n = store.evict(graph_fp=args.graph_fp, topo_fp=args.topo_fp,
                    all=args.all)
    print(json.dumps({"evicted": n, "remaining": len(store)}))
    return 0


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(prog="repro.service.cli")
    sub = ap.add_subparsers(dest="cmd", required=True)

    p = sub.add_parser("plan", help="plan a zoo model on a topology")
    p.add_argument("--model", choices=sorted(ZOO), required=True)
    p.add_argument("--topo", choices=sorted(TOPOLOGIES), default="testbed")
    p.add_argument("--iterations", type=int, default=40)
    p.add_argument("--n-groups", type=int, default=30)
    p.add_argument("--batch", type=int, default=None)
    p.add_argument("--seed", type=int, default=0)
    p.add_argument("--cache-dir", default=".plans")
    p.add_argument("--no-sfb", action="store_true")
    p.set_defaults(fn=cmd_plan)

    p = sub.add_parser("inspect", help="list cached plan records")
    p.add_argument("--cache-dir", default=".plans")
    p.set_defaults(fn=cmd_inspect)

    p = sub.add_parser("evict", help="remove cached plan records")
    p.add_argument("--cache-dir", default=".plans")
    p.add_argument("--graph-fp", default=None,
                   help="full graph fingerprint to evict")
    p.add_argument("--topo-fp", default=None,
                   help="full topology fingerprint to evict")
    p.add_argument("--all", action="store_true")
    p.set_defaults(fn=cmd_evict)

    args = ap.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    raise SystemExit(main())
