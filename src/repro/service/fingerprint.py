"""Canonical, process-stable fingerprints for plan-cache keys.

Two requests dedupe iff their computation graph and device topology hash
identically. Hashes are sha256 over a canonical JSON encoding (sorted
keys, floats via ``repr``), so they are stable across processes and
Python hash randomization. Display names are deliberately excluded: the
same model traced under two labels is the same planning problem.
"""
from __future__ import annotations

import hashlib
import json

import numpy as np

from repro.core.device import Topology
from repro.core.graph import CompGraph, GroupedGraph


def _canon(obj):
    """Convert to canonically-JSON-serializable form (numpy -> python)."""
    if isinstance(obj, dict):
        return {str(k): _canon(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_canon(v) for v in obj]
    if isinstance(obj, np.ndarray):
        return [_canon(v) for v in obj.tolist()]
    if isinstance(obj, (np.floating, float)):
        return repr(float(obj))
    if isinstance(obj, (np.integer, int, bool)) or obj is None:
        return obj
    return str(obj)


def canonical_json(obj) -> str:
    return json.dumps(_canon(obj), sort_keys=True, separators=(",", ":"))


def _sha(obj) -> str:
    return hashlib.sha256(canonical_json(obj).encode()).hexdigest()


def fingerprint_graph(graph: CompGraph) -> str:
    """Structure + costs of a CompGraph (node names / graph name ignored)."""
    nodes = [[n.op_id, n.op_type, n.flops, n.bytes_out, n.param_bytes,
              n.grad_bytes, n.split.value, n.is_grad_producer,
              n.is_apply_grad, n.is_param, n.batch_dim, n.grad_of]
             for n in sorted(graph.nodes.values(), key=lambda x: x.op_id)]
    edges = sorted([e.src, e.dst, e.bytes] for e in graph.edges)
    return _sha({"nodes": nodes, "edges": edges})


def fingerprint_grouped(gg: GroupedGraph) -> str:
    """Grouped view: base graph + partition assignment + group costs."""
    groups = [[g.group_id, sorted(g.op_ids), g.flops, g.param_bytes,
               g.grad_bytes, g.bytes_out, g.has_grad, g.split.value]
              for g in gg.groups]
    edges = sorted([gi, gj, b] for (gi, gj), b in gg.edges.items())
    return _sha({"base": fingerprint_graph(gg.base), "groups": groups,
                 "edges": edges})


def fingerprint_topology(topo: Topology) -> str:
    """Full topology identity: device specs + link matrix + efficiency
    factors (everything the simulator reads)."""
    groups = [[g.group_id, g.gpu_type, g.num_gpus, g.intra_bw, g.mem_bytes,
               g.flops] for g in topo.groups]
    return _sha({"groups": groups, "inter_bw": topo.inter_bw,
                 "latency": topo.latency,
                 "eff": [topo.coll_eff_cross, topo.coll_eff_intra,
                         topo.p2p_eff]})


def topology_structure_fingerprint(topo: Topology) -> str:
    """Bandwidth-blind structure (device groups + types + counts): two
    topologies with equal structure but perturbed links are prime
    warm-start donors for each other."""
    return _sha({"groups": [[g.group_id, g.gpu_type, g.num_gpus]
                            for g in topo.groups]})
