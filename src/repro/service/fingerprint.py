"""Canonical, process-stable fingerprints for plan-cache keys.

Two requests dedupe iff their computation graph and device topology hash
identically. Graph-content fingerprints live in ``repro.core.fingerprint``
(core consumers need them too) and are re-exported here; this module adds
the topology fingerprints and the structural feature vectors the planner's
cross-model transfer tier ranks donors with.
"""
from __future__ import annotations

import hashlib
import math

import numpy as np

from repro.core.device import Topology
from repro.core.fingerprint import (  # noqa: F401  (re-exports)
    _sha, canonical_json, fingerprint_graph, fingerprint_grouped,
    fingerprint_grouped_cached)
from repro.core.graph import GroupedGraph


def fingerprint_topology(topo: Topology) -> str:
    """Full topology identity: device specs + link matrix + efficiency
    factors (everything the simulator reads)."""
    groups = [[g.group_id, g.gpu_type, g.num_gpus, g.intra_bw, g.mem_bytes,
               g.flops] for g in topo.groups]
    return _sha({"groups": groups, "inter_bw": topo.inter_bw,
                 "latency": topo.latency,
                 "eff": [topo.coll_eff_cross, topo.coll_eff_intra,
                         topo.p2p_eff],
                 # per-pair calibrated overrides (Topology.bw reads them;
                 # two calibrations differing only per-pair must not
                 # dedupe to one cached plan)
                 "pair_eff": sorted(
                     (f"{gi}-{gj}", eff)
                     for (gi, gj), eff in topo.pair_eff.items())})


def topology_structure_fingerprint(topo: Topology) -> str:
    """Bandwidth-blind structure (device groups + types + counts): two
    topologies with equal structure but perturbed links are prime
    warm-start donors for each other."""
    return _sha({"groups": [[g.group_id, g.gpu_type, g.num_gpus]
                            for g in topo.groups]})


# -------------------------------------------------- structural features
#
# Where the hashes above answer "is this the SAME planning problem?", the
# feature vector answers "how NEAR is this problem to one we solved?" —
# the cross-model transfer tier (paper §5.2 / Table 8): an unseen model
# seeds its search from the cached plan of the structurally closest known
# graph, and the policy registry picks the checkpoint whose training
# corpus sits nearest.

STRUCT_HIST_BUCKETS = 16
STRUCT_SCALARS = 13
STRUCT_F = STRUCT_SCALARS + STRUCT_HIST_BUCKETS  # stats + op-type histogram


def _type_bucket(op_type: str) -> int:
    """Stable op-type -> histogram bucket (independent of hash seed)."""
    h = hashlib.sha256(str(op_type).encode()).digest()
    return h[0] % STRUCT_HIST_BUCKETS


def structural_features(gg: GroupedGraph) -> list:
    """Scale-normalized structural descriptor of a grouped graph.

    Entries: log-scaled node/group/edge counts, total and per-group
    compute/parameter/activation statistics, gradient-producing fraction,
    and a hashed op-type histogram (fractions). Log scaling keeps unseen
    model scales in range (same rationale as ``features.featurize``);
    fractions make the histogram batch-size independent.
    """
    nodes = list(gg.base.nodes.values())
    n_nodes = max(len(nodes), 1)
    per_group_pb = [math.log1p(g.param_bytes / 1e6) for g in gg.groups]
    per_group_fl = [math.log1p(g.flops / 1e9) for g in gg.groups]
    edge_bytes = list(gg.edges.values())

    def _mean(xs):
        return sum(xs) / len(xs) if xs else 0.0

    def _std(xs):
        if not xs:
            return 0.0
        m = _mean(xs)
        return math.sqrt(_mean([(x - m) ** 2 for x in xs]))

    vec = [
        math.log1p(len(nodes)),
        math.log1p(gg.n),
        math.log1p(len(gg.edges)),
        math.log1p(sum(g.flops for g in gg.groups) / 1e9),
        math.log1p(sum(g.param_bytes for g in gg.groups) / 1e6),
        math.log1p(sum(g.grad_bytes for g in gg.groups) / 1e6),
        math.log1p(sum(g.bytes_out for g in gg.groups) / 1e6),
        sum(g.has_grad for g in gg.groups) / max(gg.n, 1),
        _mean(per_group_pb), _std(per_group_pb),
        _mean(per_group_fl), _std(per_group_fl),
        math.log1p(_mean(edge_bytes) / 1e6),
    ]
    hist = [0.0] * STRUCT_HIST_BUCKETS
    for n in nodes:
        hist[_type_bucket(n.op_type)] += 1.0
    vec.extend(h / n_nodes for h in hist)
    return [float(v) for v in vec]


def _block_normalize(v: np.ndarray) -> np.ndarray | None:
    """Unit-normalize the scalar-stats and op-histogram blocks separately
    before comparing: raw log-scale stats are an order of magnitude larger
    than histogram fractions and strongly correlated across ALL DNNs, so
    an unweighted cosine would rank a conv net "nearest" an attention
    stack just for having similar parameter volume. Block-normalized,
    model families separate cleanly (attention<->attention ~0.006,
    conv<->conv ~0.02, cross-family ~0.3)."""
    s, h = v[:STRUCT_SCALARS], v[STRUCT_SCALARS:]
    ns, nh = float(np.linalg.norm(s)), float(np.linalg.norm(h))
    if ns == 0.0 and nh == 0.0:
        return None
    return np.concatenate([s / ns if ns else s, h / nh if nh else h])


def structural_features_cached(gg: GroupedGraph) -> list:
    """``structural_features`` memoized on the instance (same contract as
    ``fingerprint_grouped_cached``: graphs are never mutated after
    grouping). The planner computes this per request — including exact
    cache hits, which never read it — so the walk must not repeat."""
    feats = gg.__dict__.get("_struct_features")
    if feats is None:
        feats = structural_features(gg)
        gg.__dict__["_struct_features"] = feats
    return feats


def structural_distance(a, b) -> float:
    """Cosine distance between structural feature vectors (0 = identical
    direction, 1 = orthogonal), computed on block-normalized vectors.
    Length mismatches (schema drift) are treated as maximally distant."""
    if a is None or b is None or len(a) == 0 or len(b) == 0 \
            or len(a) != len(b) or len(a) != STRUCT_F:
        return float("inf")
    va = _block_normalize(np.asarray(a, float))
    vb = _block_normalize(np.asarray(b, float))
    if va is None or vb is None:
        return float("inf")
    na, nb = float(np.linalg.norm(va)), float(np.linalg.norm(vb))
    return float(1.0 - float(va @ vb) / (na * nb))
