"""PlannerService: the traffic-facing front end of the TAG pipeline.

    svc = PlannerService(cache_dir=".plans")
    resp = svc.plan(loss_fn, params, batch, topo)       # traces + searches
    resp = svc.plan_graph(gg, topo, iterations=60)      # pre-grouped graph

Responses record their provenance: "hit" (served from the store, zero
MCTS playouts, byte-identical strategy), "warm" (search seeded from a
near-hit donor), or "cold" (full search). ``plan_many`` batches requests
with per-request budgets and within-batch dedup.
"""
from __future__ import annotations

from dataclasses import dataclass
import os
import time

from repro.core import tag as tag_mod
from repro.core.device import Topology
from repro.core.graph import GroupedGraph
from repro.core.strategy import Strategy
from repro.obs.metrics import MetricsRegistry
from repro.obs.spans import get_tracer
from repro.service.fingerprint import (
    fingerprint_grouped_cached, fingerprint_topology,
    structural_features_cached, topology_structure_fingerprint)
from repro.service.registry import PolicyRegistry
from repro.service.store import PlanRecord, PlanStore
from repro.service.warmstart import adapt_strategy, find_prior
from repro.verify import PlanVerificationError, verify_deployment

POLICY_SUBDIR = "policies"

VERIFY_MODES = ("off", "warn", "reject")


@dataclass
class PlanRequest:
    """One planning request with its own search budget."""
    gg: GroupedGraph
    topo: Topology
    iterations: int = 60
    seed: int = 0
    enable_sfb: bool = True
    stop_reward: float | None = None


@dataclass
class PlanResponse:
    strategy: Strategy
    sfb_plans: dict                  # {gid: GroupSFB}
    time: float                      # simulated per-iteration seconds
    baseline_time: float
    source: str                      # "hit" | "warm" | "cold"
    iterations_run: int              # MCTS playouts spent on this request
    graph_fp: str
    topo_fp: str
    best_reward: float = 0.0         # MCTS-level reward (pre-SFB speedup);
                                     # stop_reward targets compare to this
    policy: str | None = None        # registry checkpoint that guided the
                                     # search (None: unguided / cache hit)
    verify: dict | None = None       # static-verifier verdict summary
                                     # (repro.verify Report.summary());
                                     # None when verification is off

    @property
    def speedup(self):
        return self.baseline_time / self.time if self.time > 0 else 0.0


class PlannerService:
    def __init__(self, *, store: PlanStore | None = None,
                 cache_dir: str | None = None, capacity: int = 256,
                 policy=None, warm_start: bool = True,
                 prior_weight: float = 0.6,
                 registry: PolicyRegistry | None = None,
                 policy_dir: str | None = None,
                 use_registry: bool = True,
                 measurements=None, drift_threshold: float = 0.25,
                 drift_min_samples: int = 1,
                 drift_ewma_alpha: float = 0.5,
                 telemetry_dir: str | None = None,
                 verify: str = "warn"):
        self.store = store if store is not None \
            else PlanStore(capacity=capacity, path=cache_dir)
        self.policy = policy
        # trained-prior source (paper §5.2): an explicit ``policy``
        # callable wins; otherwise the registry living next to the plan
        # store (``<cache_dir>/policies``, or ``policy_dir``) supplies the
        # best-matching trained checkpoint per request. An empty/missing
        # registry degrades to unguided search.
        if registry is None and use_registry:
            rdir = policy_dir or (os.path.join(cache_dir, POLICY_SUBDIR)
                                  if cache_dir else None)
            registry = PolicyRegistry(rdir) if rdir else None
        self.registry = registry if use_registry else None
        self.warm_start = warm_start
        self.prior_weight = prior_weight
        # static plan verification (repro.verify) of every fresh search
        # result: "off" skips it, "warn" annotates the response and
        # refuses to cache error-carrying plans, "reject" additionally
        # raises PlanVerificationError instead of returning them
        if verify not in VERIFY_MODES:
            raise ValueError(f"verify={verify!r} (use one of "
                             f"{VERIFY_MODES})")
        self.verify_mode = verify
        self._stats = {"requests": 0, "hits": 0, "warm": 0, "cold": 0,
                       "batch_dedup": 0, "iterations": 0,
                       "policy_guided": 0,
                       "observations": 0, "replans": 0,
                       "verify_clean": 0, "verify_warn": 0,
                       "verify_error": 0}
        # structured metrics mirror of _stats (+ latency/playout
        # distributions), dumped by ``repro-plan metrics`` and merged
        # into ``stats()``
        self.metrics = MetricsRegistry()
        self._m_requests = self.metrics.counter(
            "planner_requests_total", "plan requests by provenance")
        self._m_latency = self.metrics.histogram(
            "planner_plan_seconds", "plan_graph wall seconds by provenance")
        self._m_playouts = self.metrics.histogram(
            "planner_playouts", "MCTS playouts spent per request",
            buckets=[0, 5, 10, 20, 40, 80, 160, 320, 640])
        self._m_playouts_to_best = self.metrics.histogram(
            "planner_playouts_to_best",
            "playouts until the search first beat the DP baseline",
            buckets=[0, 5, 10, 20, 40, 80, 160, 320, 640])
        self._m_store = self.metrics.gauge(
            "planner_store_size", "plans resident in the store")
        self._m_verify = self.metrics.counter(
            "planner_verify_total",
            "static plan verifications by verdict")
        self._m_verify_rejected = self.metrics.counter(
            "planner_verify_rejected_total",
            "plans refused store entry over error diagnostics")
        self._m_verify_seconds = self.metrics.histogram(
            "planner_verify_seconds",
            "static verification wall seconds",
            buckets=[0.001, 0.005, 0.02, 0.1, 0.5, 2.0])
        self._m_observe = self.metrics.counter(
            "planner_observations_total",
            "feedback observations by outcome")
        self._m_drift = self.metrics.gauge(
            "planner_drift_ratio",
            "latest observed |ewma - simulated| / simulated")
        # runtime feedback loop (repro.runtime): created lazily so the
        # service stays import-light when feedback is unused
        self._drift_threshold = drift_threshold
        self._drift_min_samples = drift_min_samples
        self._drift_ewma_alpha = drift_ewma_alpha
        self._measurements = measurements
        self._telemetry_dir = telemetry_dir
        self._feedback = None

    # ----------------------------------------------------------------- API
    def plan(self, loss_fn, params, batch, topo: Topology, *,
             name: str = "", n_groups: int = 60, **kw) -> PlanResponse:
        """Trace a training function and plan its deployment."""
        gg = tag_mod.build_grouped(loss_fn, params, batch, name, n_groups)
        return self.plan_graph(gg, topo, **kw)

    def plan_graph(self, gg: GroupedGraph, topo: Topology, *,
                   iterations: int = 60, seed: int = 0,
                   enable_sfb: bool = True,
                   stop_reward: float | None = None,
                   fingerprints: tuple | None = None,
                   prior_strategy=None,
                   observed_feedback=None) -> PlanResponse:
        """Plan a grouped graph's deployment on a topology.

        ``prior_strategy`` forces a warm start from the given strategy
        (the feedback loop seeds re-searches from the invalidated plan
        this way); ``observed_feedback`` is a SimResult-shaped aggregate
        of measured telemetry routed into the GNN features in place of
        the simulated runtime feedback.
        """
        t_plan = time.perf_counter()
        tracer = get_tracer()
        with tracer.span("plan", cat="planner", iterations=iterations):
            with tracer.span("fingerprint", cat="planner"):
                graph_fp, topo_fp = fingerprints or (
                    fingerprint_grouped_cached(gg),
                    fingerprint_topology(topo))
                struct_fp = topology_structure_fingerprint(topo)
                graph_feat = structural_features_cached(gg)
            self._stats["requests"] += 1

            with tracer.span("store_lookup", cat="planner"):
                if prior_strategy is not None:
                    kind, rec = "forced", None
                elif self.warm_start:
                    kind, rec = find_prior(self.store, graph_fp, topo_fp,
                                           struct_fp,
                                           graph_features=graph_feat)
                else:
                    rec = self.store.get(graph_fp, topo_fp)
                    kind = "hit" if rec is not None else "miss"
            if kind == "hit" and not (
                    rec.meta.get("enable_sfb", True) == enable_sfb
                    and rec.meta.get("iterations", 0) >= iterations):
                # cached under a smaller budget or different SFB setting:
                # don't let it shadow the request — re-search, seeded
                # from it
                kind = "stale_hit"
            if kind == "hit":
                self._stats["hits"] += 1
                self._finish_metrics("hit", t_plan, playouts=0)
                return PlanResponse(
                    strategy=rec.strategy_obj(), sfb_plans=rec.sfb_objs(),
                    time=rec.time, baseline_time=rec.baseline_time,
                    source="hit", iterations_run=0,
                    graph_fp=graph_fp, topo_fp=topo_fp,
                    best_reward=float(rec.meta.get("best_reward", 0.0)),
                    verify=rec.meta.get("verify"))

            prior = None
            if kind == "forced":
                prior = prior_strategy
                self._stats["warm"] += 1
            elif kind in ("warm_topo", "warm_graph", "warm_struct",
                          "stale_hit"):
                prior = adapt_strategy(rec.strategy_obj(), gg.n, topo)
                self._stats["warm"] += 1
            else:
                self._stats["cold"] += 1

            with tracer.span("policy_resolve", cat="planner"):
                policy_name, policy = self._resolve_policy(graph_fp,
                                                           graph_feat)
            with tracer.span("search", cat="planner",
                             iterations=iterations):
                res = tag_mod.optimize(
                    None, None, None, topo, gg=gg, policy=policy,
                    iterations=iterations, seed=seed,
                    enable_sfb=enable_sfb,
                    prior_strategy=prior, prior_weight=self.prior_weight,
                    stop_reward=stop_reward,
                    observed_feedback=observed_feedback)
            self._stats["iterations"] += res.search.iterations_run

            verify_summary = None
            verify_diagnostics = None
            verify_ok = True
            if self.verify_mode != "off":
                t_verify = time.perf_counter()
                with tracer.span("verify", cat="planner"):
                    report = verify_deployment(gg, res.strategy, topo)
                self._m_verify_seconds.observe(
                    time.perf_counter() - t_verify)
                verify_summary = report.summary()
                # the full TAGxxx diagnostic list rides along in the
                # cached record so the served plane (/plans,
                # /plans/<fp>/verify) can show WHAT was flagged, not
                # just how many
                verify_diagnostics = report.to_dict()["diagnostics"]
                verify_ok = report.ok
                self._m_verify.inc(verdict=report.verdict)
                self._stats["verify_" + report.verdict] += 1
                if not verify_ok:
                    # an error-carrying plan is never cached: a bad plan
                    # served from the store would be a fleet incident,
                    # not a local traceback
                    self._m_verify_rejected.inc()
                    if self.verify_mode == "reject":
                        raise PlanVerificationError(
                            report, context=f"graph {graph_fp[:12]} on "
                                            f"topo {topo_fp[:12]}")
            if verify_ok:
                with tracer.span("store_put", cat="planner"):
                    self.store.put(PlanRecord(
                        graph_fp=graph_fp, topo_fp=topo_fp,
                        topo_struct_fp=struct_fp,
                        n_groups=gg.n, topo_m=topo.m,
                        strategy=res.strategy.to_dict(),
                        sfb_plans={str(g): p.to_dict()
                                   for g, p in res.sfb_plans.items()},
                        time=res.time, baseline_time=res.baseline_time,
                        graph_features=graph_feat,
                        meta={"iterations": iterations, "seed": seed,
                              "enable_sfb": enable_sfb,
                              "iterations_run": res.search.iterations_run,
                              "best_reward": res.search.best_reward,
                              "policy": policy_name,
                              "verify": verify_summary,
                              "verify_diagnostics": verify_diagnostics,
                              "source": "warm" if prior is not None
                              else "cold"}))
            source = "warm" if prior is not None else "cold"
            self._finish_metrics(
                source, t_plan, playouts=res.search.iterations_run,
                to_best=res.search.iters_to_beat_baseline)
            return PlanResponse(
                strategy=res.strategy, sfb_plans=res.sfb_plans,
                time=res.time, baseline_time=res.baseline_time,
                source=source,
                iterations_run=res.search.iterations_run,
                graph_fp=graph_fp, topo_fp=topo_fp,
                best_reward=res.search.best_reward,
                policy=policy_name, verify=verify_summary)

    def _finish_metrics(self, source: str, t_start: float, *,
                        playouts: int, to_best: int | None = None):
        self._m_requests.inc(source=source)
        self._m_latency.observe(time.perf_counter() - t_start,
                                source=source)
        self._m_playouts.observe(playouts, source=source)
        if to_best is not None and to_best >= 0:
            self._m_playouts_to_best.observe(to_best)
        self._m_store.set(len(self.store))

    def _resolve_policy(self, graph_fp: str, graph_feat):
        """Trained priors for a search: an explicit ``policy=`` callable
        wins (name None); otherwise the registry's best-matching
        checkpoint; otherwise unguided."""
        if self.policy is not None:
            return None, self.policy
        if self.registry is None:
            return None, None
        name, policy = self.registry.resolve(graph_fp=graph_fp,
                                             graph_features=graph_feat)
        if policy is not None:
            self._stats["policy_guided"] += 1
        return name, policy

    def plan_many(self, requests: list) -> list:
        """Plan a batch of PlanRequests. Identical (graph, topology) pairs
        inside the batch are planned once; repeats are store hits."""
        out = []
        seen: set = set()
        for req in requests:
            key = (fingerprint_grouped_cached(req.gg),
                   fingerprint_topology(req.topo))
            if key in seen:
                self._stats["batch_dedup"] += 1
            seen.add(key)
            out.append(self.plan_graph(
                req.gg, req.topo, iterations=req.iterations, seed=req.seed,
                enable_sfb=req.enable_sfb, stop_reward=req.stop_reward,
                fingerprints=key))
        return out

    # ------------------------------------------------- runtime feedback
    def feedback_loop(self):
        """The lazily-created runtime FeedbackLoop bound to this service
        (drift detection, cost-model calibration, replanning)."""
        if self._feedback is None:
            from repro.runtime.feedback import FeedbackLoop
            from repro.runtime.telemetry import MeasurementStore
            meas = self._measurements
            if meas is None:
                meas = MeasurementStore(self._telemetry_dir)
            self._feedback = FeedbackLoop(
                self, measurements=meas,
                drift_threshold=self._drift_threshold,
                ewma_alpha=self._drift_ewma_alpha,
                min_samples=self._drift_min_samples)
        return self._feedback

    @property
    def measurements(self):
        return self.feedback_loop().measurements

    def observe(self, gg: GroupedGraph, topo: Topology, observation, *,
                iterations: int = 20, seed: int = 0,
                enable_sfb: bool = True, append: bool = True):
        """Feed an observed step (a ``repro.runtime.telemetry.StepRecord``
        or a bare step time in seconds) back into the planner: below the
        drift threshold this only logs telemetry; past it, the cached plan
        is invalidated and re-searched warm under a recalibrated cost
        model. Returns a ``repro.runtime.feedback.FeedbackResult``.

        ``append=False`` when the observation was itself read from this
        service's measurement store (the recalibration poller), so it is
        not written back as a duplicate."""
        with get_tracer().span("observe", cat="planner"):
            res = self.feedback_loop().observe(
                gg, topo, observation, iterations=iterations, seed=seed,
                enable_sfb=enable_sfb, append=append)
        self._stats["observations"] += 1
        if res.kind == "replanned":
            self._stats["replans"] += 1
        self._m_observe.inc(outcome=res.kind)
        if res.report is not None:
            self._m_drift.set(res.report.drift,
                              graph=res.report.graph_fp[:8],
                              topo=res.report.topo_fp[:8])
        return res

    def stats(self) -> dict:
        s = dict(self._stats)
        s["store_size"] = len(self.store)
        s["hit_rate"] = s["hits"] / s["requests"] if s["requests"] else 0.0
        s["metrics"] = self.metrics.to_dict()
        return s

    def plan_entries(self) -> list:
        """Per-plan rows for the served plane: fingerprints, timings,
        the cached verify verdict summary AND the full TAGxxx
        diagnostic list, plus the attributed drift cause when the
        recalibration path has replanned the entry."""
        out = []
        for rec in self.store.records():
            out.append({
                "graph_fp": rec.graph_fp, "topo_fp": rec.topo_fp,
                "n_groups": rec.n_groups, "topo_m": rec.topo_m,
                "time_s": rec.time, "baseline_time_s": rec.baseline_time,
                "speedup": rec.speedup,
                "source": rec.meta.get("source"),
                "policy": rec.meta.get("policy"),
                "verify": rec.meta.get("verify"),
                "verify_diagnostics":
                    rec.meta.get("verify_diagnostics"),
                "drift_cause": rec.meta.get("drift_cause"),
            })
        out.sort(key=lambda e: (e["graph_fp"], e["topo_fp"]))
        return out

    # ------------------------------------------------- served observability
    def serve_metrics(self, *, host: str = "127.0.0.1", port: int = 0,
                      spool_dir: str | None = None,
                      run_id: str = "planner", recalibrate: bool = True,
                      interval_s: float = 5.0, iterations: int = 20,
                      spool_max_age_s: float | None = None,
                      spool_max_bytes: int | None = None,
                      slo_s: float | None = None,
                      alert_rules=None, health: bool = True,
                      start: bool = True):
        """Embed the live observability plane in this service.

        Returns a started ``repro.obs.server.ObsServer`` exposing this
        service's registry on /metrics, per-plan verify diagnostics on
        /plans, run health on /runs + /alerts, and — when ``spool_dir``
        is given — the cross-process trace collector on
        /traces/<run_id>, with this process's planner spans drained into
        its own spool shard on every scrape. ``recalibrate=True`` also
        attaches a ``RecalibrationLoop`` (its lifecycle follows the
        server's); register workloads for unattended replanning via
        ``server.recalib.watch(gg, topo)``.

        ``health=True`` attaches a ``RunHealthAnalyzer`` draining the
        service's telemetry dir with its OWN cursor (it never steals
        the recalibration loop's records); ``slo_s``/``alert_rules``
        arm step-time SLO burn-rate alerting, and the recalibration
        loop replans drifted workloads in the analyzer's severity
        order. Register predicted schedules via
        ``server.health.watch(run_id, timeline=...)``.
        """
        from repro.obs.collector import SpoolWriter, TraceCollector
        from repro.obs.server import ObsServer
        spool = collector = loop = analyzer = None
        if spool_dir:
            spool = SpoolWriter(spool_dir, run_id=run_id, name="planner")
            collector = TraceCollector(spool_dir)
        if health:
            from repro.obs.health import RunHealthAnalyzer
            from repro.runtime.telemetry import MeasurementStore
            hstore = MeasurementStore(self._telemetry_dir) \
                if self._telemetry_dir else None
            analyzer = RunHealthAnalyzer(
                hstore, registry=self.metrics, slo_s=slo_s,
                alert_rules=alert_rules)
        if recalibrate:
            from repro.runtime.feedback import RecalibrationLoop
            loop = RecalibrationLoop(self, interval_s=interval_s,
                                     iterations=iterations,
                                     health=analyzer)
        server = ObsServer(registry=self.metrics, service=self,
                           collector=collector, spool=spool, recalib=loop,
                           health=analyzer, host=host, port=port,
                           spool_max_age_s=spool_max_age_s,
                           spool_max_bytes=spool_max_bytes)
        return server.start() if start else server
