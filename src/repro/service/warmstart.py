"""Warm-start selection and strategy adaptation.

On a cache miss with a *near* hit — the same graph on a perturbed
topology, a new graph on a known topology, or (the Table 8 transfer
tier) a structurally similar graph on any topology — the cached strategy
seeds MCTS (``prior_strategy=`` in ``core.mcts``) instead of a cold
root: the first playout replays the prior actions and the search priors
are biased toward them, so the search re-converges in far fewer playouts
(the Placeto-style generalization TAG claims in §5.2).
"""
from __future__ import annotations

from repro.core.device import Topology
from repro.core.strategy import Action, Option, Strategy
from repro.service.fingerprint import structural_distance
from repro.service.store import PlanRecord, PlanStore

# Structural-similarity acceptance bound (block-normalized cosine
# distance, see fingerprint._block_normalize): same-family donors land
# around 0.006-0.02 and are accepted; cross-family pairs (a conv net vs
# an attention stack) land around 0.3 and are deliberately REJECTED — a
# dissimilar donor's replayed actions would bias the search priors toward
# the wrong region, which is worse than a cold start.
MAX_STRUCT_DISTANCE = 0.25


def adapt_strategy(prior: Strategy, n_groups: int,
                   topo: Topology) -> Strategy:
    """Remap a cached strategy onto a (possibly different) request shape:
    placements are clipped to the new topology's device groups; actions
    that no longer place anywhere — or groups the prior never decided —
    become undecided (MCTS fills them).

    Replication options are re-validated against the *clipped* placement:
    a sync option (AR/PS/DUP) left on a single surviving device, or a
    split option (MP/PIPE) with nothing to split across, is NOT a legal
    candidate action — the SFB pass and the simulator treat such actions
    inconsistently — so those degenerate to undecided too and MCTS refills
    them. (AR on a single device is kept only when the prior already
    placed it there: it is the legal "no sync" candidate.)
    """
    acts = []
    for gid in range(n_groups):
        a = prior.actions[gid] if gid < len(prior.actions) else None
        if a is None:
            acts.append(None)
            continue
        placement = tuple(g for g in a.placement if g < topo.m)
        if not placement:
            acts.append(None)
            continue
        n_dev = sum(topo.groups[g].num_gpus for g in placement)
        clipped = len(placement) < len(a.placement)
        if n_dev <= 1 and (clipped or a.option != Option.AR):
            acts.append(None)
            continue
        acts.append(Action(placement, a.option, schedule=a.schedule))
    return Strategy(acts)


def _best(records: list) -> PlanRecord:
    return max(records, key=lambda r: r.speedup)


def find_prior(store: PlanStore, graph_fp: str, topo_fp: str,
               topo_struct_fp: str | None = None,
               graph_features=None,
               max_struct_distance: float = MAX_STRUCT_DISTANCE):
    """Resolve a request against the store.

    Returns ``(kind, record)`` with kind one of:
      "hit"         exact (graph, topology) match — reuse verbatim
      "warm_topo"   same graph, different topology (prefer equal structure)
      "warm_graph"  same topology, different graph
      "warm_struct" unseen graph AND topology: nearest stored graph by
                    structural features (cross-model transfer, Table 8)
      "miss"        nothing usable — cold search
    """
    rec = store.get(graph_fp, topo_fp)
    if rec is not None:
        return "hit", rec
    same_graph = store.find(graph_fp=graph_fp)
    if same_graph:
        structural = [r for r in same_graph
                      if topo_struct_fp and r.topo_struct_fp == topo_struct_fp]
        return "warm_topo", _best(structural or same_graph)
    same_topo = store.find(topo_fp=topo_fp)
    if graph_features:
        # a same-topology donor is still a DIFFERENT graph: apply the
        # same structural guard as the struct tier, or a cross-family
        # donor (distance ~0.3) would bias priors toward the wrong
        # region. Records without features (pre-feature schema) keep the
        # legacy accept-any behaviour.
        same_topo = [r for r in same_topo
                     if not r.graph_features
                     or structural_distance(graph_features,
                                            r.graph_features)
                     <= max_struct_distance]
    if same_topo:
        return "warm_graph", _best(same_topo)
    if graph_features:
        scored = []
        for key, feats, speedup in store.feature_entries():
            d = structural_distance(graph_features, feats)
            if d <= max_struct_distance:
                scored.append((d, -speedup, key))
        if scored:
            key = min(scored, key=lambda x: x[:2])[2]
            rec = store.get(*key)       # promote only the chosen donor
            if rec is not None:
                return "warm_struct", rec
    return "miss", None
