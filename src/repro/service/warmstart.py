"""Warm-start selection and strategy adaptation.

On a cache miss with a *near* hit — the same graph on a perturbed
topology, or a new graph on a known topology — the cached strategy seeds
MCTS (``prior_strategy=`` in ``core.mcts``) instead of a cold root: the
first playout replays the prior actions and the search priors are biased
toward them, so the search re-converges in far fewer playouts (the
Placeto-style generalization TAG claims in §5.2).
"""
from __future__ import annotations

from repro.core.device import Topology
from repro.core.strategy import Action, Strategy
from repro.service.store import PlanRecord, PlanStore


def adapt_strategy(prior: Strategy, n_groups: int,
                   topo: Topology) -> Strategy:
    """Remap a cached strategy onto a (possibly different) request shape:
    placements are clipped to the new topology's device groups; actions
    that no longer place anywhere — or groups the prior never decided —
    become undecided (MCTS fills them)."""
    acts = []
    for gid in range(n_groups):
        a = prior.actions[gid] if gid < len(prior.actions) else None
        if a is None:
            acts.append(None)
            continue
        placement = tuple(g for g in a.placement if g < topo.m)
        acts.append(Action(placement, a.option) if placement else None)
    return Strategy(acts)


def _best(records: list) -> PlanRecord:
    return max(records, key=lambda r: r.speedup)


def find_prior(store: PlanStore, graph_fp: str, topo_fp: str,
               topo_struct_fp: str | None = None):
    """Resolve a request against the store.

    Returns ``(kind, record)`` with kind one of:
      "hit"        exact (graph, topology) match — reuse verbatim
      "warm_topo"  same graph, different topology (prefer equal structure)
      "warm_graph" same topology, different graph
      "miss"       nothing usable — cold search
    """
    rec = store.get(graph_fp, topo_fp)
    if rec is not None:
        return "hit", rec
    same_graph = store.find(graph_fp=graph_fp)
    if same_graph:
        structural = [r for r in same_graph
                      if topo_struct_fp and r.topo_struct_fp == topo_struct_fp]
        return "warm_topo", _best(structural or same_graph)
    same_topo = store.find(topo_fp=topo_fp)
    if same_topo:
        return "warm_graph", _best(same_topo)
    return "miss", None
