"""Policy registry: persistent trained-GNN checkpoints for the planner.

The paper's generalization claim (§5.2, Table 8) is that one trained
policy transfers to unseen models and topologies without fine-tuning.
This module makes that a *service* property: checkpoints trained via
``core.trainer.train_policy`` are persisted on disk next to the plan
store (JSON metadata + npz params, fcntl-locked like ``service.store``),
and ``PlannerService`` loads the best-matching checkpoint so cold and
warm searches run with trained priors by default.

Checkpoint selection, most- to least-specific:

  1. the pinned default (``repro-plan policy use NAME``) — absolute;
  2. a checkpoint whose training corpus contains the request's graph
     fingerprint (the model was trained on);
  3. the checkpoint whose corpus is structurally nearest the request
     (``fingerprint.structural_features`` cosine distance — the Table 8
     unseen-model transfer tier);
  4. the newest checkpoint.
"""
from __future__ import annotations

from dataclasses import dataclass, field
import json
import os
import re
import tempfile
import time

import numpy as np

from repro.core.hetgnn import GNNConfig
from repro.service.fingerprint import structural_distance
from repro.service.store import flock_dir

POLICY_SCHEMA_VERSION = 1
DEFAULT_FILE = "default.json"
_NAME_RE = re.compile(r"^[A-Za-z0-9][A-Za-z0-9._-]{0,63}$")


def _check_name(name: str) -> str:
    if not _NAME_RE.match(name or ""):
        raise ValueError(f"bad policy name {name!r} (use letters, digits, "
                         f". _ -; max 64 chars)")
    if f"{name}.json" == DEFAULT_FILE:
        # would overwrite the pin file: the checkpoint's metadata becomes
        # invisible to records() and reads back as a phantom pin
        raise ValueError(f"policy name {name!r} is reserved")
    return name


@dataclass
class PolicyRecord:
    """One registered checkpoint's metadata (params live in ``<name>.npz``
    beside the ``<name>.json`` this serializes to)."""
    name: str
    cfg: dict                      # GNNConfig fields
    corpus: list                   # graph fingerprints trained on
    corpus_features: list          # structural feature vectors, ∥ corpus
    meta: dict = field(default_factory=dict)   # steps, mcts_iters, seed...
    created: float = 0.0
    version: int = POLICY_SCHEMA_VERSION

    def gnn_config(self) -> GNNConfig:
        return GNNConfig(**self.cfg)

    def distance_to(self, graph_features) -> float:
        """Distance from a request's structural features to the nearest
        graph in this checkpoint's training corpus."""
        ds = [structural_distance(graph_features, f)
              for f in self.corpus_features]
        return min(ds) if ds else float("inf")

    def to_dict(self) -> dict:
        return {"version": self.version, "name": self.name,
                "cfg": self.cfg, "corpus": list(self.corpus),
                "corpus_features": [list(map(float, f))
                                    for f in self.corpus_features],
                "meta": self.meta, "created": self.created}

    @classmethod
    def from_dict(cls, d: dict) -> "PolicyRecord":
        if d.get("version") != POLICY_SCHEMA_VERSION:
            raise ValueError(f"policy record schema {d.get('version')} != "
                             f"{POLICY_SCHEMA_VERSION}")
        return cls(name=d["name"], cfg=d["cfg"],
                   corpus=list(d.get("corpus", [])),
                   corpus_features=list(d.get("corpus_features", [])),
                   meta=d.get("meta", {}),
                   created=float(d.get("created", 0.0)),
                   version=d["version"])


class PolicyRegistry:
    """Disk-backed registry of trained GNN policies.

    All disk mutations take an fcntl lock on ``.lock`` in the registry
    directory (shared for reads), mirroring ``PlanStore`` — many launcher
    processes can train into / serve from one registry.

    Eviction budgets (mirroring the plan store's disk-tier budgets):
    ``max_age_s`` drops checkpoints older than this, ``max_bytes`` caps
    the registry's on-disk size (json + npz, oldest evicted first),
    ``max_count`` caps the checkpoint count (newest win). Budgets are
    enforced on every ``save`` and on demand via ``evict_expired`` /
    the ``repro-plan policy evict`` CLI. The pinned default is never a
    victim — an operator's explicit pin outranks any budget.
    """

    def __init__(self, path: str, *, max_age_s: float | None = None,
                 max_bytes: int | None = None,
                 max_count: int | None = None):
        self.path = path
        self.max_age_s = max_age_s
        self.max_bytes = max_bytes
        self.max_count = max_count
        self._policies: dict = {}      # name -> (PolicyRecord, policy)

    # ------------------------------------------------------------- locking
    def _lock(self, shared: bool = False):
        return flock_dir(self.path, shared=shared, require_dir=True)

    # --------------------------------------------------------------- paths
    def _meta_path(self, name: str) -> str:
        return os.path.join(self.path, f"{_check_name(name)}.json")

    def _params_path(self, name: str) -> str:
        return os.path.join(self.path, f"{_check_name(name)}.npz")

    # ------------------------------------------------------------ save/load
    def save(self, name: str, cfg: GNNConfig, params: dict, *,
             corpus=(), corpus_features=(), meta: dict | None = None,
             created: float | None = None) -> PolicyRecord:
        """Register a trained checkpoint (atomic npz + JSON writes)."""
        _check_name(name)
        os.makedirs(self.path, exist_ok=True)
        rec = PolicyRecord(
            name=name,
            cfg={"hidden": cfg.hidden, "heads": cfg.heads,
                 "layers": cfg.layers, "decoder_hidden": cfg.decoder_hidden},
            corpus=list(corpus),
            corpus_features=[list(map(float, f)) for f in corpus_features],
            meta=dict(meta or {}),
            created=time.time() if created is None else created)
        with self._lock():
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".npz.tmp")
            with os.fdopen(fd, "wb") as f:
                np.savez(f, **{k: np.asarray(v) for k, v in params.items()})
            os.replace(tmp, self._params_path(name))
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".json.tmp")
            with os.fdopen(fd, "w") as f:
                json.dump(rec.to_dict(), f, sort_keys=True)
            os.replace(tmp, self._meta_path(name))
            self._enforce_budgets()
        self._policies.pop(name, None)       # invalidate any cached build
        return rec

    def load(self, name: str):
        """-> (PolicyRecord, params dict). Raises if absent/corrupt."""
        with self._lock(shared=True):
            with open(self._meta_path(name)) as f:
                rec = PolicyRecord.from_dict(json.load(f))
            with np.load(self._params_path(name)) as z:
                params = {k: z[k] for k in z.files}
        return rec, params

    def records(self) -> list:
        """All readable checkpoints, newest first."""
        if not os.path.isdir(self.path):
            return []
        out = []
        with self._lock(shared=True):
            for fn in sorted(os.listdir(self.path)):
                if not fn.endswith(".json") or fn == DEFAULT_FILE:
                    continue
                try:
                    with open(os.path.join(self.path, fn)) as f:
                        rec = PolicyRecord.from_dict(json.load(f))
                except (ValueError, KeyError, json.JSONDecodeError,
                        OSError):
                    continue
                if os.path.exists(self._params_path(rec.name)):
                    out.append(rec)
        out.sort(key=lambda r: -r.created)
        return out

    def remove(self, name: str) -> bool:
        hit = False
        with self._lock():
            for p in (self._meta_path(name), self._params_path(name)):
                try:
                    os.remove(p)
                    hit = True
                except OSError:
                    pass
            if self.default_name() == name:
                try:
                    os.remove(os.path.join(self.path, DEFAULT_FILE))
                except OSError:
                    pass
        self._policies.pop(name, None)
        return hit

    # -------------------------------------------------------------- default
    def set_default(self, name: str):
        """Pin a checkpoint (``repro-plan policy use``): selection returns
        it unconditionally until unpinned."""
        self.load(name)                      # validate it exists + loads
        os.makedirs(self.path, exist_ok=True)
        with self._lock():
            fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".json.tmp")
            with os.fdopen(fd, "w") as f:
                json.dump({"name": name}, f)
            os.replace(tmp, os.path.join(self.path, DEFAULT_FILE))

    def default_name(self) -> str | None:
        try:
            with open(os.path.join(self.path, DEFAULT_FILE)) as f:
                return json.load(f).get("name")
        except (OSError, json.JSONDecodeError):
            return None

    # ------------------------------------------------------------- budgets
    def _entries(self):
        """[(name, mtime, bytes)] per checkpoint, newest first (caller
        holds the lock)."""
        out = []
        for fn in os.listdir(self.path):
            if not fn.endswith(".json") or fn == DEFAULT_FILE:
                continue
            name = fn[:-len(".json")]
            try:
                st = os.stat(os.path.join(self.path, fn))
            except (OSError, ValueError):
                continue
            mtime, size = st.st_mtime, st.st_size
            try:
                size += os.stat(self._params_path(name)).st_size
            except (OSError, ValueError):
                pass       # orphaned meta (npz gone): still budget-
                #            visible so eviction can clean it up
            out.append((name, mtime, size))
        out.sort(key=lambda e: -e[1])
        return out

    def _remove_files(self, name: str) -> bool:
        hit = False
        for p in (self._meta_path(name), self._params_path(name)):
            try:
                os.remove(p)
                hit = True
            except OSError:
                pass
        self._policies.pop(name, None)
        return hit

    def _enforce_budgets(self, now: float | None = None) -> int:
        """Apply age/size/count budgets (caller holds the lock). Newest
        checkpoints win; the pinned default is never evicted."""
        if self.max_age_s is None and self.max_bytes is None \
                and self.max_count is None:
            return 0
        now = time.time() if now is None else now
        pinned = self.default_name()
        entries = [e for e in self._entries()]
        victims = set()
        if self.max_age_s is not None:
            victims |= {n for n, mtime, _ in entries
                        if now - mtime > self.max_age_s and n != pinned}
        if self.max_count is not None:
            # the pinned checkpoint always survives and fills a slot
            kept = sum(1 for n, _, _ in entries
                       if n == pinned and n not in victims)
            for n, _, _ in entries:             # newest first
                if n in victims or n == pinned:
                    continue
                kept += 1
                if kept > self.max_count:
                    victims.add(n)
        if self.max_bytes is not None:
            total = sum(s for n, _, s in entries if n not in victims)
            for n, _, s in reversed(entries):   # oldest first
                if total <= self.max_bytes:
                    break
                if n in victims or n == pinned:
                    continue
                victims.add(n)
                total -= s
        return sum(self._remove_files(n) for n in victims)

    def evict_expired(self, *, max_age_s: float | None = None,
                      max_bytes: int | None = None,
                      max_count: int | None = None,
                      now: float | None = None) -> int:
        """One-shot cleanup under explicit budgets (the CLI's ``policy
        evict``). Arguments default to the registry's standing budgets."""
        saved = (self.max_age_s, self.max_bytes, self.max_count)
        if max_age_s is not None:
            self.max_age_s = max_age_s
        if max_bytes is not None:
            self.max_bytes = max_bytes
        if max_count is not None:
            self.max_count = max_count
        try:
            if not os.path.isdir(self.path):
                return 0
            with self._lock():
                return self._enforce_budgets(now=now)
        finally:
            (self.max_age_s, self.max_bytes, self.max_count) = saved

    # ------------------------------------------------------------ selection
    def select(self, graph_fp: str | None = None,
               graph_features=None) -> PolicyRecord | None:
        """Best-matching checkpoint for a request (see module docstring
        for the tier order). Returns None when the registry is empty."""
        recs = self.records()
        if not recs:
            return None
        default = self.default_name()
        if default is not None:
            for r in recs:
                if r.name == default:
                    return r
        if graph_fp is not None:
            exact = [r for r in recs if graph_fp in r.corpus]
            if exact:
                return exact[0]              # newest among exact matches
        if graph_features:
            scored = [(r.distance_to(graph_features), r) for r in recs]
            scored = [(d, r) for d, r in scored if d != float("inf")]
            if scored:
                return min(scored, key=lambda x: x[0])[1]
        return recs[0]                       # newest overall

    def resolve(self, graph_fp: str | None = None, graph_features=None):
        """-> (name, policy callable) for the best-matching checkpoint, or
        (None, None). Built policies are cached per name, so the npz load
        and GNN setup happen once per registry instance."""
        rec = self.select(graph_fp=graph_fp, graph_features=graph_features)
        if rec is None:
            return None, None
        cached = self._policies.get(rec.name)
        if cached is not None and cached[0].created != rec.created:
            cached = None      # re-registered (possibly by another
            #                    process) since we built it: reload
        if cached is None:
            from repro.core.trainer import make_policy
            try:
                rec, params = self.load(rec.name)
            except (OSError, ValueError, KeyError):
                return None, None
            cached = (rec, make_policy(rec.gnn_config(), params))
            self._policies[rec.name] = cached
        return cached[0].name, cached[1]

    def __len__(self):
        return len(self.records())
