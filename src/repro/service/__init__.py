"""Planner service: persistent strategy cache + warm-started search.

Wraps the TAG pipeline (trace -> group -> MCTS -> SFB -> simulate) as a
long-lived planner that amortizes search cost across requests:

  * exact (graph, topology) repeats are served from a versioned plan
    store without re-running MCTS;
  * near repeats (same graph on a perturbed topology, or a new graph on
    a known topology) warm-start MCTS from the cached strategy.

    from repro.service import PlannerService
    svc = PlannerService(cache_dir=".plans")
    resp = svc.plan(loss_fn, params, batch, topo, iterations=60)
"""
from repro.service.fingerprint import (  # noqa: F401
    fingerprint_graph, fingerprint_grouped, fingerprint_grouped_cached,
    fingerprint_topology, structural_distance, structural_features,
    topology_structure_fingerprint)
from repro.service.planner import (  # noqa: F401
    PlannerService, PlanRequest, PlanResponse)
from repro.service.registry import (  # noqa: F401
    PolicyRecord, PolicyRegistry)
from repro.service.store import PlanRecord, PlanStore  # noqa: F401
from repro.service.warmstart import adapt_strategy, find_prior  # noqa: F401
