"""Versioned plan store: in-memory LRU over a JSON-on-disk tier.

Records are keyed by (graph_fp, topo_fp). The memory tier is a bounded
LRU; the disk tier (optional ``path=``) holds one JSON file per record
and survives process restarts — a warm planner re-serves yesterday's
strategies without a single MCTS playout.

The disk tier is bounded too: age/size budgets and per-topology quotas
(constructor arguments, enforced on every put, or on demand via
``evict_expired`` / the CLI's ``evict --max-age/--max-bytes``), and all
disk mutations take an ``fcntl`` lock on ``.lock`` in the cache
directory so multiple launcher processes can share one cache.
"""
from __future__ import annotations

from collections import OrderedDict
from contextlib import contextmanager
from dataclasses import dataclass, field
import json
import os
import tempfile
import time

from repro.core.sfb import GroupSFB
from repro.core.strategy import Strategy

try:
    import fcntl
except ImportError:                        # non-posix: locking degrades
    fcntl = None

SCHEMA_VERSION = 1
LOCK_FILE = ".lock"


@contextmanager
def flock_dir(path: str | None, *, shared: bool = False,
              require_dir: bool = False):
    """fcntl file lock over a cache directory (shared with the policy
    registry); no-op when there is no directory to lock or fcntl is
    unavailable. ``require_dir`` skips locking until the directory exists
    (registries are created lazily on first save)."""
    if not path or fcntl is None or (require_dir and not os.path.isdir(path)):
        yield
        return
    with open(os.path.join(path, LOCK_FILE), "a+") as lf:
        fcntl.flock(lf, fcntl.LOCK_SH if shared else fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(lf, fcntl.LOCK_UN)


@dataclass
class PlanRecord:
    graph_fp: str
    topo_fp: str
    topo_struct_fp: str
    n_groups: int
    topo_m: int
    strategy: dict                     # Strategy.to_dict()
    sfb_plans: dict                    # {str(gid): GroupSFB.to_dict()}
    time: float                        # simulated per-iteration seconds
    baseline_time: float
    # structural feature vector of the planned graph
    # (service.fingerprint.structural_features) — the cross-model
    # warm-start tier ranks donor records by distance to it. Optional:
    # records written before the field existed load as [] and are simply
    # never structural donors.
    graph_features: list = field(default_factory=list)
    meta: dict = field(default_factory=dict)   # iterations, seed, source...
    version: int = SCHEMA_VERSION

    @property
    def key(self):
        return (self.graph_fp, self.topo_fp)

    @property
    def speedup(self):
        return self.baseline_time / self.time if self.time > 0 else 0.0

    def strategy_obj(self) -> Strategy:
        return Strategy.from_dict(self.strategy)

    def sfb_objs(self) -> dict:
        return {int(gid): GroupSFB.from_dict(d)
                for gid, d in self.sfb_plans.items()}

    def to_dict(self) -> dict:
        return {
            "version": self.version,
            "graph_fp": self.graph_fp, "topo_fp": self.topo_fp,
            "topo_struct_fp": self.topo_struct_fp,
            "n_groups": self.n_groups, "topo_m": self.topo_m,
            "strategy": self.strategy, "sfb_plans": self.sfb_plans,
            "time": self.time, "baseline_time": self.baseline_time,
            "graph_features": [float(v) for v in self.graph_features],
            "meta": self.meta,
        }

    @classmethod
    def from_dict(cls, d: dict) -> "PlanRecord":
        if d.get("version") != SCHEMA_VERSION:
            raise ValueError(f"plan record schema {d.get('version')} != "
                             f"{SCHEMA_VERSION}")
        return cls(
            graph_fp=d["graph_fp"], topo_fp=d["topo_fp"],
            topo_struct_fp=d["topo_struct_fp"],
            n_groups=int(d["n_groups"]), topo_m=int(d["topo_m"]),
            strategy=d["strategy"], sfb_plans=d["sfb_plans"],
            time=float(d["time"]), baseline_time=float(d["baseline_time"]),
            graph_features=list(d.get("graph_features", [])),
            meta=d.get("meta", {}), version=d["version"])


def _fname(graph_fp: str, topo_fp: str) -> str:
    return f"{graph_fp[:24]}-{topo_fp[:24]}.json"


class PlanStore:
    def __init__(self, capacity: int = 256, path: str | None = None,
                 max_age_s: float | None = None,
                 max_bytes: int | None = None,
                 per_topo_quota: int | None = None):
        self.capacity = capacity
        self.path = path
        self.max_age_s = max_age_s
        self.max_bytes = max_bytes
        self.per_topo_quota = per_topo_quota
        self._mem: OrderedDict = OrderedDict()   # key -> PlanRecord
        self._disk: dict = {}                    # key -> filename
        self._feat_cache: dict = {}              # key -> (mtime, feats, sp)
        if path:
            os.makedirs(path, exist_ok=True)
            with self._lock():
                self._scan_disk()

    # ------------------------------------------------------------- locking
    def _lock(self, shared: bool = False):
        """fcntl file lock over the cache directory; no-op for the pure
        memory tier or where fcntl is unavailable."""
        return flock_dir(self.path, shared=shared)

    # ---------------------------------------------------------------- disk
    def _scan_disk(self):
        for fn in os.listdir(self.path):
            if not fn.endswith(".json"):
                continue
            try:
                rec = self._load_file(fn)
            except (ValueError, KeyError, json.JSONDecodeError, OSError):
                continue                         # unreadable/stale schema
            self._disk[rec.key] = fn

    def _load_file(self, fn: str) -> PlanRecord:
        with open(os.path.join(self.path, fn)) as f:
            return PlanRecord.from_dict(json.load(f))

    def _write_file(self, rec: PlanRecord):
        fn = _fname(*rec.key)
        fd, tmp = tempfile.mkstemp(dir=self.path, suffix=".tmp")
        with os.fdopen(fd, "w") as f:
            json.dump(rec.to_dict(), f, sort_keys=True)
        os.replace(tmp, os.path.join(self.path, fn))
        self._disk[rec.key] = fn

    # ------------------------------------------------------------- get/put
    def _insert_mem(self, rec: PlanRecord):
        self._mem[rec.key] = rec
        self._mem.move_to_end(rec.key)
        while len(self._mem) > self.capacity:
            self._mem.popitem(last=False)        # LRU; disk tier keeps it

    def put(self, rec: PlanRecord):
        self._insert_mem(rec)
        if self.path:
            with self._lock():
                self._write_file(rec)
                self._enforce_budgets()

    def get(self, graph_fp: str, topo_fp: str) -> PlanRecord | None:
        key = (graph_fp, topo_fp)
        rec = self._mem.get(key)
        if rec is not None:
            self._mem.move_to_end(key)
            return rec
        fn = self._disk.get(key)
        if fn is None and self.path:
            # another process may have written it since our last scan
            cand = _fname(*key)
            if os.path.exists(os.path.join(self.path, cand)):
                fn = cand
        if fn is not None:
            try:
                with self._lock(shared=True):
                    rec = self._load_file(fn)
            except (ValueError, KeyError, json.JSONDecodeError, OSError):
                self._disk.pop(key, None)
                return None
            if rec.key != key:                   # filename prefix collision
                return None
            self._disk[key] = fn
            self._insert_mem(rec)                # promote; no disk rewrite
            return rec
        return None

    def find(self, *, graph_fp: str | None = None,
             topo_fp: str | None = None) -> list:
        """Records matching one side of the key (warm-start donors)."""
        out, seen = [], set()
        for key in list(self._mem) + list(self._disk):
            if key in seen:
                continue
            seen.add(key)
            if graph_fp is not None and key[0] != graph_fp:
                continue
            if topo_fp is not None and key[1] != topo_fp:
                continue
            rec = self.get(*key)
            if rec is not None:
                out.append(rec)
        return out

    def records(self) -> list:
        return self.find()

    def feature_entries(self) -> list:
        """[(key, graph_features, speedup)] across both tiers WITHOUT
        promoting disk records into the memory LRU. The structural
        warm-start tier scans every stored plan on a cache miss; routing
        that scan through ``get()`` would evict hot memory-tier entries
        in favor of arbitrary donors and rewrite LRU order on every novel
        request. Disk-tier reads are memoized per (file, mtime), so
        repeated misses cost one stat per record instead of a full JSON
        parse — while still observing records other processes rewrite."""
        out = []
        for key, rec in self._mem.items():
            out.append((key, rec.graph_features, rec.speedup))
        seen = set(self._mem)
        for key, fn in list(self._disk.items()):
            if key in seen:
                continue
            path = os.path.join(self.path, fn)
            try:
                mtime = os.stat(path).st_mtime
            except OSError:
                continue
            cached = self._feat_cache.get(key)
            if cached is not None and cached[0] == mtime:
                out.append((key, cached[1], cached[2]))
                continue
            try:
                with self._lock(shared=True):
                    rec = self._load_file(fn)
            except (ValueError, KeyError, json.JSONDecodeError, OSError):
                continue
            self._feat_cache[key] = (mtime, rec.graph_features, rec.speedup)
            out.append((key, rec.graph_features, rec.speedup))
        return out

    # -------------------------------------------------------------- evict
    def _remove_key(self, key) -> bool:
        """Drop one key from both tiers (caller holds the lock)."""
        hit = False
        if key in self._mem:
            del self._mem[key]
            hit = True
        fn = self._disk.pop(key, None)
        if fn is not None:
            try:
                os.remove(os.path.join(self.path, fn))
            except OSError:
                pass
            hit = True
        return hit

    def evict(self, *, graph_fp: str | None = None,
              topo_fp: str | None = None, all: bool = False) -> int:
        """Remove matching records from both tiers. Fingerprints may be
        prefixes (the CLI prints truncated fps)."""
        n = 0
        with self._lock():
            self._scan_disk()      # see records other processes wrote
            for key in list(self._mem) + list(self._disk):
                if not all:
                    if graph_fp is not None \
                            and not key[0].startswith(graph_fp):
                        continue
                    if topo_fp is not None \
                            and not key[1].startswith(topo_fp):
                        continue
                    if graph_fp is None and topo_fp is None:
                        continue
                n += self._remove_key(key)
        return n

    # ------------------------------------------------- disk-tier budgets
    def _disk_entries(self):
        """[(key, fn, mtime, size)] for the disk tier, newest first."""
        out = []
        for key, fn in list(self._disk.items()):
            p = os.path.join(self.path, fn)
            try:
                st = os.stat(p)
            except OSError:
                self._disk.pop(key, None)
                continue
            out.append((key, fn, st.st_mtime, st.st_size))
        out.sort(key=lambda e: -e[2])
        return out

    def _enforce_budgets(self, now: float | None = None) -> int:
        """Apply age/size/per-topology budgets to the disk tier (caller
        holds the lock). Victims leave both tiers; newest records win.
        The directory is rescanned first so budgets cover records other
        processes sharing the cache wrote since our last scan."""
        if not self.path:
            return 0
        if self.max_age_s is None and self.max_bytes is None \
                and self.per_topo_quota is None:
            return 0
        self._scan_disk()
        now = time.time() if now is None else now
        entries = self._disk_entries()
        victims = set()
        if self.max_age_s is not None:
            victims |= {key for key, _, mtime, _ in entries
                        if now - mtime > self.max_age_s}
        if self.per_topo_quota is not None:
            seen: dict = {}
            for key, _, _, _ in entries:        # newest first
                if key in victims:
                    continue
                seen[key[1]] = seen.get(key[1], 0) + 1
                if seen[key[1]] > self.per_topo_quota:
                    victims.add(key)
        if self.max_bytes is not None:
            total = sum(size for key, _, _, size in entries
                        if key not in victims)
            for key, _, _, size in reversed(entries):   # oldest first
                if total <= self.max_bytes:
                    break
                if key in victims:
                    continue
                victims.add(key)
                total -= size
        n = 0
        for key in victims:
            n += self._remove_key(key)
        return n

    def evict_expired(self, *, max_age_s: float | None = None,
                      max_bytes: int | None = None,
                      per_topo_quota: int | None = None,
                      now: float | None = None) -> int:
        """One-shot disk-tier cleanup under explicit budgets (the CLI's
        ``evict --max-age/--max-bytes/--per-topo-quota``). Arguments
        default to the store's standing budgets."""
        saved = (self.max_age_s, self.max_bytes, self.per_topo_quota)
        if max_age_s is not None:
            self.max_age_s = max_age_s
        if max_bytes is not None:
            self.max_bytes = max_bytes
        if per_topo_quota is not None:
            self.per_topo_quota = per_topo_quota
        try:
            with self._lock():
                return self._enforce_budgets(now=now)
        finally:
            (self.max_age_s, self.max_bytes,
             self.per_topo_quota) = saved

    def __len__(self):
        return len(set(self._mem) | set(self._disk))

    def keys(self):
        return sorted(set(self._mem) | set(self._disk))
