"""deepseek-7b — llama-architecture dense decoder (MHA kv=32) [arXiv:2401.02954]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="deepseek-7b",
    family="dense",
    num_layers=30,
    d_model=4096,
    num_heads=32,
    num_kv_heads=32,
    d_ff=11008,
    vocab_size=102_400,
    source="arXiv:2401.02954 (DeepSeek LLM)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="deepseek-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, d_ff=512, vocab_size=512)
