"""yi-6b — llama-architecture dense decoder with GQA (kv=4) [arXiv:2403.04652]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="yi-6b",
    family="dense",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    d_ff=11008,
    vocab_size=64_000,
    rope_theta=5e6,
    source="arXiv:2403.04652 (Yi)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="yi-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=2, d_ff=512, vocab_size=512)
