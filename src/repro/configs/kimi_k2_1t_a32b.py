"""kimi-k2-1t-a32b — trillion-parameter MoE, 384 experts top-8 (paper-table
scale) [arXiv:2501.kimi2]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=2048,              # per-expert FFN width
    vocab_size=163_840,
    num_experts=384,
    experts_per_token=8,
    moe_every=1,
    source="arXiv:2501.kimi2 (Kimi K2)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="kimi-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=64, vocab_size=512, num_experts=4,
        experts_per_token=2)
