"""mamba2-130m — SSD (state-space duality), attention-free [arXiv:2405.21060]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,                 # pure Mamba blocks, no FFN sublayer
    vocab_size=50_280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_ngroups=1,
    layer_pattern="M",
    source="arXiv:2405.21060 (Mamba-2 / SSD)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="mamba2-smoke", num_layers=2, d_model=128, ssm_state=16,
        ssm_head_dim=32, vocab_size=512)
