"""Model configuration dataclass shared by every architecture.

Each assigned architecture gets one module in this package defining
``CONFIG`` (the exact assignment) plus ``reduced()`` (a tiny same-family
variant for CPU smoke tests).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                 # dense | moe | ssm | hybrid | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0           # 0 -> d_model // num_heads
    qkv_bias: bool = False
    tie_embeddings: bool = False
    # --- MoE ---
    num_experts: int = 0
    experts_per_token: int = 0
    moe_every: int = 1          # FFN is MoE on layers where idx % moe_every == moe_every-1
    capacity_factor: float = 1.25
    moe_combine: str = "gather"   # "gather" | "scatter" (§Perf lever)
    # --- SSM (Mamba2/SSD) ---
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv: int = 4
    ssm_ngroups: int = 1
    ssm_chunk: int = 128
    # --- layer pattern: one char per layer in a repeating period.
    # 'A' = attention mixer, 'M' = mamba mixer. "" means all-'A' (or all-'M'
    # for family == "ssm").
    layer_pattern: str = ""
    # --- attention variant ---
    sliding_window: int = 0     # 0 = full causal attention
    rope_theta: float = 1e4
    attn_chunk: int = 1024      # query-chunk size of the flash-style scan
    attn_impl: str = "jnp"      # "jnp" (shardable reference) | "pallas"
                                # (kernels/flash_attention, interpret on CPU)
    # --- modality frontend stub (audio/vlm): number of precomputed
    # frame/patch embeddings prepended to the token sequence.
    frontend: str = "none"      # none | audio | vision
    frontend_tokens: int = 0
    # --- numerics ---
    dtype: str = "bfloat16"
    norm_eps: float = 1e-5
    # provenance
    source: str = ""

    @property
    def resolved_head_dim(self) -> int:
        if self.head_dim:
            return self.head_dim
        return self.d_model // self.num_heads if self.num_heads else 0

    @property
    def pattern(self) -> str:
        if self.layer_pattern:
            return self.layer_pattern
        return "M" if self.family == "ssm" else "A"

    @property
    def num_periods(self) -> int:
        p = self.pattern
        assert self.num_layers % len(p) == 0, (self.name, self.num_layers, p)
        return self.num_layers // len(p)

    @property
    def d_inner(self) -> int:
        """Mamba inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.d_inner // self.ssm_head_dim

    def replace(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)

    # ---- parameter counting (used for roofline MODEL_FLOPS = 6*N*D) ----
    def param_count(self, active_only: bool = False) -> int:
        D, F, V = self.d_model, self.d_ff, self.vocab_size
        hd = self.resolved_head_dim
        n = V * D  # embedding
        if not self.tie_embeddings:
            n += V * D  # lm head
        per = self.pattern
        for ch in list(per) * self.num_periods:
            n += 2 * D  # norms
            if ch == "A":
                n += D * (self.num_heads * hd)          # q
                n += 2 * D * (self.num_kv_heads * hd)   # k, v
                n += (self.num_heads * hd) * D          # o
                if self.qkv_bias:
                    n += (self.num_heads + 2 * self.num_kv_heads) * hd
            else:  # mamba mixer
                di, ds, nh = self.d_inner, self.ssm_state, self.ssm_nheads
                n += D * (2 * di + 2 * self.ssm_ngroups * ds + nh)  # in_proj
                n += di * self.ssm_conv + di                        # conv + norm-ish
                n += 2 * nh                                         # A_log, dt_bias
                n += di * D                                         # out_proj
        # FFNs (attention/mamba mixers both may carry an FFN when d_ff > 0)
        if F > 0:
            layers_with_ffn = self.num_layers
            moe_layers = 0
            if self.num_experts > 0:
                moe_layers = sum(
                    1 for i in range(self.num_layers)
                    if i % self.moe_every == self.moe_every - 1)
            dense_layers = layers_with_ffn - moe_layers
            n += dense_layers * 3 * D * F
            if self.num_experts > 0:
                e = self.experts_per_token if active_only else self.num_experts
                n += moe_layers * (e * 3 * D * F + D * self.num_experts)
        return n
