"""jamba-v0.1-52b — hybrid Mamba+attention 1:7 interleave with 16-expert
top-2 MoE on every other layer [arXiv:2403.19887]."""
from repro.configs.base import ModelConfig

# Jamba block: 8 layers, attention at position 4 (index 3), MoE FFN on every
# second layer. 32 layers = 4 periods of the pattern.
CONFIG = ModelConfig(
    name="jamba-v0.1-52b",
    family="hybrid",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=14336,
    vocab_size=65_536,
    num_experts=16,
    experts_per_token=2,
    moe_every=2,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_conv=4,
    ssm_ngroups=1,
    layer_pattern="MMMAMMMM",
    source="arXiv:2403.19887 (Jamba)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="jamba-smoke", num_layers=2, d_model=128, num_heads=4,
        num_kv_heads=2, d_ff=128, vocab_size=512, num_experts=4,
        experts_per_token=2, ssm_state=16, ssm_head_dim=32,
        layer_pattern="MA")
