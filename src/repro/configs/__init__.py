"""Architecture registry: ``get_config(arch)`` / ``get_reduced(arch)``.

Ten assigned architectures (public-literature pool) plus the paper's own
benchmark models (Table 3 of TAG) used by the strategy-search benchmarks.
"""
from __future__ import annotations

import importlib

from repro.configs.base import ModelConfig
from repro.configs.shapes import (  # noqa: F401
    SHAPES, InputShape, TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K,
    LONG_CONTEXT_WINDOW)

_MODULES = {
    "mamba2-130m": "repro.configs.mamba2_130m",
    "yi-6b": "repro.configs.yi_6b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "musicgen-large": "repro.configs.musicgen_large",
    "qwen2-1.5b": "repro.configs.qwen2_1_5b",
    "deepseek-7b": "repro.configs.deepseek_7b",
    "kimi-k2-1t-a32b": "repro.configs.kimi_k2_1t_a32b",
    "jamba-v0.1-52b": "repro.configs.jamba_v0_1_52b",
    "internvl2-26b": "repro.configs.internvl2_26b",
    "minitron-4b": "repro.configs.minitron_4b",
}

ARCH_IDS = tuple(_MODULES)


def get_config(arch: str) -> ModelConfig:
    if arch not in _MODULES:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(_MODULES)}")
    return importlib.import_module(_MODULES[arch]).CONFIG


def get_reduced(arch: str) -> ModelConfig:
    return importlib.import_module(_MODULES[arch]).reduced()


def config_for_shape(arch: str, shape_name: str) -> ModelConfig:
    """Config adjusted for an input shape (sliding-window for long_500k on
    pure-attention archs — the sub-quadratic variant the brief requires)."""
    cfg = get_config(arch)
    if shape_name == "long_500k" and cfg.family not in ("ssm",) \
            and "A" in cfg.pattern and cfg.sliding_window == 0:
        cfg = cfg.replace(sliding_window=LONG_CONTEXT_WINDOW)
    return cfg
