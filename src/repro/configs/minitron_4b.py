"""minitron-4b — pruned Nemotron dense decoder, GQA kv=8 [arXiv:2407.14679]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256_000,
    source="arXiv:2407.14679 (Minitron)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="minitron-smoke", num_layers=2, d_model=192, num_heads=6,
        num_kv_heads=2, d_ff=384, vocab_size=512)
