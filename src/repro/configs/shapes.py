"""The four assigned input shapes.

``train_*`` shapes lower ``train_step``; ``decode_*`` shapes lower
``serve_step`` (one new token against a KV/SSM cache of ``seq_len``).
``long_500k`` requires sub-quadratic attention: SSM/hybrid archs run it
natively; pure-attention archs run a sliding-window variant (window 8192)
so the KV cache stays bounded.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


TRAIN_4K = InputShape("train_4k", 4_096, 256, "train")
PREFILL_32K = InputShape("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = InputShape("decode_32k", 32_768, 128, "decode")
LONG_500K = InputShape("long_500k", 524_288, 1, "decode")

SHAPES = {s.name: s for s in (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)}

# Sliding window applied to pure-attention architectures for long_500k.
LONG_CONTEXT_WINDOW = 8_192
