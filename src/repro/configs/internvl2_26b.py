"""internvl2-26b — InternViT + InternLM2 VLM; we implement the InternLM2-style
language backbone; the ViT encoder + projector is a stub supplying
precomputed patch embeddings via ``input_specs`` [arXiv:2404.16821]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-26b",
    family="vlm",
    num_layers=48,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    d_ff=16384,
    vocab_size=92_553,
    frontend="vision",
    frontend_tokens=256,    # ViT patch embeddings per image
    source="arXiv:2404.16821 (InternVL2)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="internvl2-smoke", num_layers=2, d_model=192, num_heads=6,
        num_kv_heads=2, d_ff=384, vocab_size=512, frontend_tokens=8)
