"""musicgen-large — decoder-only transformer over EnCodec tokens
[arXiv:2306.05284]. The EnCodec/conv frontend is a stub: ``input_specs``
provides precomputed conditioning frame embeddings (brief carve-out)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-large",
    family="audio",
    num_layers=48,
    d_model=2048,
    num_heads=32,
    num_kv_heads=32,
    d_ff=8192,
    vocab_size=2048,
    frontend="audio",
    frontend_tokens=256,    # precomputed conditioning frames
    source="arXiv:2306.05284 (MusicGen)",
)


def reduced() -> ModelConfig:
    return CONFIG.replace(
        name="musicgen-smoke", num_layers=2, d_model=256, num_heads=4,
        num_kv_heads=4, d_ff=512, vocab_size=512, frontend_tokens=8)
