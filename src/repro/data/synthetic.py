"""Deterministic synthetic token pipeline.

Generates Zipf-distributed token streams with a planted bigram structure so
a real model trained on it shows a decreasing loss (used by the e2e
training example and tests). Batches are produced on host as numpy and
placed with the sharding the launcher requests.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class SyntheticDataset:
    vocab_size: int
    seq_len: int
    batch_size: int
    seed: int = 0
    frontend_tokens: int = 0
    d_model: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        # planted bigram table: each token has a likely successor
        self._succ = rng.integers(0, self.vocab_size, size=(self.vocab_size,))
        ranks = np.arange(1, self.vocab_size + 1, dtype=np.float64)
        p = 1.0 / ranks ** 1.1
        self._zipf = p / p.sum()

    def batch(self, step: int) -> dict:
        rng = np.random.default_rng((self.seed, step))
        B, S = self.batch_size, self.seq_len
        toks = np.empty((B, S + 1), np.int32)
        toks[:, 0] = rng.choice(self.vocab_size, size=B, p=self._zipf)
        for t in range(S):
            follow = rng.random(B) < 0.8
            rand = rng.choice(self.vocab_size, size=B, p=self._zipf)
            toks[:, t + 1] = np.where(follow, self._succ[toks[:, t]], rand)
        out = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}
        if self.frontend_tokens:
            out["prefix"] = rng.standard_normal(
                (B, self.frontend_tokens, self.d_model)).astype(np.float32)
        return out

    def device_batch(self, step: int, shardings=None):
        b = self.batch(step)
        if shardings is None:
            return jax.tree.map(jnp.asarray, b)
        return {k: jax.device_put(v, shardings.get(k)) for k, v in b.items()}


def make_batch_specs(cfg, shape) -> dict:
    from repro.models import input_specs
    return input_specs(cfg, shape)
