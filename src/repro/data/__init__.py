from repro.data.synthetic import SyntheticDataset, make_batch_specs  # noqa: F401
