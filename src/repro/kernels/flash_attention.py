"""Flash attention Pallas TPU kernel (online softmax, causal + optional
sliding window).

Grid: (B*H, nQ, nK) — the K dimension is the minor (sequential) grid axis,
so the f32 accumulator / running max / denominator scratch in VMEM carries
across K blocks for a fixed Q block. BlockSpecs tile Q/K/V as
(block, head_dim) VMEM tiles; block sizes default to 128 (MXU-aligned).
The TPU memory hierarchy shapes the design: K/V stream HBM->VMEM block by
block, the (bq, bk) score tile lives entirely in VMEM/VREGs, and only the
(bq, hd) output tile is written back.
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
                  bq: int, bk: int, nk: int, causal: bool, window: int):
    j = pl.program_id(2)
    i = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0].astype(jnp.float32)            # (bq, hd)
    k = k_ref[0].astype(jnp.float32)            # (bk, hd)
    v = v_ref[0].astype(jnp.float32)
    scale = q.shape[-1] ** -0.5
    s = (q @ k.T) * scale                        # (bq, bk)

    qpos = i * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    kpos = j * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    if causal:
        mask = kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                          # (bq,)
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=1))
    p = jnp.exp(s - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_new = corr * l_ref[...] + jnp.sum(p, axis=1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + p @ v
    m_ref[...] = m_new
    l_ref[...] = l_new

    @pl.when(j == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_ref[...], 1e-30)[:, None]
        o_ref[0] = (acc_ref[...] / denom).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("causal", "window", "block_q", "block_k", "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    block_q: int = 128, block_k: int = 128,
                    interpret: bool = True):
    """q/k/v: (B, H, S, hd) -> (B, H, S, hd). MHA-level (GQA expansion in
    ops.py). interpret=True validates on CPU; False targets real TPUs."""
    B, H, S, hd = q.shape
    bq = min(block_q, S)
    bk = min(block_k, S)
    assert S % bq == 0 and S % bk == 0, (S, bq, bk)
    nq, nk = S // bq, S // bk
    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * H, S, hd)
    vf = v.reshape(B * H, S, hd)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, nk=nk, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, nq, nk),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, bk, hd), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, hd), jnp.float32),   # output accumulator
            pltpu.VMEM((bq,), jnp.float32),      # running max
            pltpu.VMEM((bq,), jnp.float32),      # running denominator
        ],
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
