"""Mamba-2 SSD chunked-scan Pallas TPU kernel.

Grid: (B*nh, n_chunks) — chunks are the minor (sequential) axis, so the
(hd, ds) f32 state scratch in VMEM carries the inter-chunk recurrence.
Per chunk the kernel computes the intra-chunk quadratic term
(C B^T ⊙ decay) @ (x·dt) on the MXU plus the carried-state contribution,
then updates the state — the SSD algorithm of arXiv:2405.21060 §6 laid
out for VMEM tiles (chunk=128 keeps every operand MXU-aligned).
"""
from __future__ import annotations

import functools

import jax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu
import jax.numpy as jnp


def _ssd_kernel(x_ref, dt_ref, a_ref, b_ref, c_ref, y_ref, h_ref, *,
                chunk: int):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0].astype(jnp.float32)             # (Q, hd)
    dt = dt_ref[0].astype(jnp.float32)           # (Q,)
    A = a_ref[0].astype(jnp.float32)             # scalar decay rate (<0)
    Bm = b_ref[0].astype(jnp.float32)            # (Q, ds)
    Cm = c_ref[0].astype(jnp.float32)            # (Q, ds)

    a = dt * A                                   # (Q,) log-decays
    cum = jnp.cumsum(a)                          # inclusive
    # L[i, t] = exp(cum_i - cum_t) for t <= i
    diff = cum[:, None] - cum[None, :]
    iota_i = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
    iota_t = jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1)
    L = jnp.where(iota_t <= iota_i, jnp.exp(diff), 0.0)

    xdt = x * dt[:, None]                        # (Q, hd)
    scores = (Cm @ Bm.T) * L                     # (Q, Q)
    y_intra = scores @ xdt                       # (Q, hd)

    h = h_ref[...]                               # (hd, ds)
    y_inter = (Cm @ h.T) * jnp.exp(cum)[:, None]  # (Q, hd)... via transpose

    total = jnp.exp(cum[-1])
    decay_out = jnp.exp(cum[-1] - cum)           # (Q,)
    h_new = h * total + (xdt * decay_out[:, None]).T @ Bm   # (hd, ds)

    y_ref[0] = (y_intra + y_inter).astype(y_ref.dtype)
    h_ref[...] = h_new


@functools.partial(
    jax.jit, static_argnames=("chunk", "interpret"))
def ssd_scan(x, dt, A, B, C, *, chunk: int = 128, interpret: bool = True):
    """Chunked SSD scan.

    x: (Bb, S, nh, hd); dt: (Bb, S, nh) (already softplus'd);
    A: (nh,) negative decay rates; B, C: (Bb, S, nh, ds) (groups already
    broadcast to heads). Returns y: (Bb, S, nh, hd).
    """
    Bb, S, nh, hd = x.shape
    ds = B.shape[-1]
    Q = min(chunk, S)
    assert S % Q == 0
    nc = S // Q

    # (B*nh, S, ...) layout, head-major
    xf = x.transpose(0, 2, 1, 3).reshape(Bb * nh, S, hd)
    dtf = dt.transpose(0, 2, 1).reshape(Bb * nh, S)
    bf = B.transpose(0, 2, 1, 3).reshape(Bb * nh, S, ds)
    cf = C.transpose(0, 2, 1, 3).reshape(Bb * nh, S, ds)
    af = jnp.tile(A, Bb)                          # (B*nh,)

    kernel = functools.partial(_ssd_kernel, chunk=Q)
    y = pl.pallas_call(
        kernel,
        grid=(Bb * nh, nc),
        in_specs=[
            pl.BlockSpec((1, Q, hd), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, Q), lambda b, j: (b, j)),
            pl.BlockSpec((1,), lambda b, j: (b,)),
            pl.BlockSpec((1, Q, ds), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, Q, ds), lambda b, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, Q, hd), lambda b, j: (b, j, 0)),
        out_shape=jax.ShapeDtypeStruct((Bb * nh, S, hd), x.dtype),
        scratch_shapes=[pltpu.VMEM((hd, ds), jnp.float32)],
        interpret=interpret,
    )(xf, dtf, af, bf, cf)
    return y.reshape(Bb, nh, S, hd).transpose(0, 2, 1, 3)
