"""Jit'd public wrappers around the Pallas kernels.

These are the drop-in entry points the model layers can route through
(GQA head expansion, D-skip/gating composition, interpret-mode selection).
On this CPU container ``interpret=True`` executes the kernel bodies in
Python for correctness validation; on a real TPU pass interpret=False.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ssd_scan import ssd_scan


def gqa_flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                        block_q: int = 128, block_k: int = 128,
                        interpret: bool = True):
    """q: (B, S, H, hd); k/v: (B, S, KV, hd) -> (B, S, H, hd).
    Expands GQA KV heads and routes through the flash kernel."""
    B, S, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qh = q.transpose(0, 2, 1, 3)                       # (B, H, S, hd)
    kh = jnp.repeat(k.transpose(0, 2, 1, 3), G, axis=1)
    vh = jnp.repeat(v.transpose(0, 2, 1, 3), G, axis=1)
    o = flash_attention(qh, kh, vh, causal=causal, window=window,
                        block_q=block_q, block_k=block_k,
                        interpret=interpret)
    return o.transpose(0, 2, 1, 3)


def mamba_ssd(x, dt, A, B, C, D_skip=None, *, chunk: int = 128,
              interpret: bool = True):
    """SSD scan + optional D-skip. Shapes as in kernels.ssd_scan."""
    y = ssd_scan(x, dt, A, B, C, chunk=chunk, interpret=interpret)
    if D_skip is not None:
        y = y + x * D_skip[None, None, :, None]
    return y
