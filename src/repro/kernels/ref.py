"""Pure-jnp oracles for the Pallas kernels (the allclose references)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def ref_attention(q, k, v, *, causal: bool = True, window: int = 0):
    """Naive full-softmax attention. q/k/v: (B, H, S, hd) (same H — GQA
    expansion happens in ops.py). Returns (B, H, S, hd)."""
    S = q.shape[2]
    scale = q.shape[-1] ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(S)[:, None]
        kpos = jnp.arange(S)[None, :]
        mask = kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w, v.astype(jnp.float32)
                      ).astype(q.dtype)


def ref_ssd(x, dt, A, B, C):
    """Naive sequential SSD recurrence.

    x: (Bb, S, nh, hd); dt: (Bb, S, nh); A: (nh,);
    B, C: (Bb, S, nh, ds). Returns y (Bb, S, nh, hd), h (Bb, nh, hd, ds).
    """
    Bb, S, nh, hd = x.shape
    ds = B.shape[-1]

    def step(h, inp):
        xt, dtt, Bt, Ct = inp                      # (Bb, nh, ...)
        dA = jnp.exp(dtt * A)                      # (Bb, nh)
        h = h * dA[..., None, None] + jnp.einsum(
            "bhd,bhs->bhds", (xt * dtt[..., None]).astype(jnp.float32),
            Bt.astype(jnp.float32))
        y = jnp.einsum("bhs,bhds->bhd", Ct.astype(jnp.float32), h)
        return h, y

    h0 = jnp.zeros((Bb, nh, hd, ds), jnp.float32)
    xs = (x.swapaxes(0, 1), dt.astype(jnp.float32).swapaxes(0, 1),
          B.swapaxes(0, 1), C.swapaxes(0, 1))
    h, ys = jax.lax.scan(step, h0, xs)
    return ys.swapaxes(0, 1).astype(x.dtype), h
