"""End-to-end training driver.

    python -m repro.launch.train --arch yi-6b --smoke \
        --steps 20 --batch 8 --seq 128

Runs the full loop on whatever devices exist (CPU smoke by default):
synthetic data pipeline -> jitted train step (sharded when a mesh is
requested) -> checkpointing -> metrics log. ``--tag-search`` runs the TAG
strategy search on a reduced trace of the model first and applies the
resulting execution plan's axis rules.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp

from repro.checkpoint import latest_step, load_checkpoint, save_checkpoint
from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.data import SyntheticDataset
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.models import init_params
from repro.optim.adam import AdamW


def resolve_pipeline(plan, mode: str):
    """Decide whether a lowered TAG plan's PIPE stages can really run.

    Returns the ``StagePlan`` to execute, or ``None`` for the single-mesh
    path — emitting an explicit log line either way, so a strategy with
    PIPE actions is never *silently* degraded to pure-DP axis rules.
    """
    sp = plan.stage_plan
    if sp is None:
        if plan.summary.get("options", {}).get("PIPE"):
            print("TAG pipeline: strategy has PIPE actions but no "
                  "multi-group pipeline spine; using single-mesh axis "
                  "rules", flush=True)
        return None
    if mode == "off":
        print(f"TAG pipeline: --pipeline off; degrading "
              f"{sp.n_stages}-stage plan to single-mesh axis rules",
              flush=True)
        return None
    from repro.exec.stages import PipelineInfeasible
    try:
        mesh_mod.stage_device_sets(sp)
    except PipelineInfeasible as e:
        print(f"WARNING: TAG pipeline fallback — {e}; degrading to "
              f"single-mesh DP axis rules", flush=True)
        return None
    sched = sp.schedule if mode == "auto" else mode
    print(f"TAG pipeline: executing {sp.n_stages} stages "
          f"(schedule={sched}, placement={list(sp.placement)}, "
          f"sync={[s.sync for s in sp.stages]})", flush=True)
    return sp


def _stage_key(s: int) -> str:
    return f"stage{s}"


def _export_spans(args):
    """Write the session's planner/search spans as a Chrome trace
    (``--trace-dir``); no-op when tracing is off or nothing was
    recorded."""
    if not getattr(args, "trace_dir", ""):
        return
    from repro.obs import chrome_trace, write_chrome_trace
    from repro.obs.spans import get_tracer
    tracer = get_tracer()
    if not tracer.spans():
        return
    path = write_chrome_trace(
        os.path.join(args.trace_dir, "trace_spans.json"),
        chrome_trace(tracer.to_chrome(process_name="train"),
                     arch=args.arch, kind="spans"))
    print(f"trace: wrote {path} ({len(tracer.spans())} spans)",
          flush=True)


def _run_id(args) -> str:
    """One run id for the whole job: groups the spool shard under
    /traces/<run_id> AND the telemetry records under /runs/<run_id> on
    the health analyzer side."""
    return getattr(args, "run_id", "") or f"train-{args.arch}"


def _make_spool(args):
    """``--spool-dir``: a ``SpoolWriter`` shard for this training
    process, feeding the cross-process trace collector (``repro-plan
    serve-metrics --spool-dir`` on the other end). None when unset —
    tests drive these entry points with hand-built Namespaces."""
    spool_dir = getattr(args, "spool_dir", "")
    if not spool_dir:
        return None
    from repro.obs.collector import SpoolWriter
    return SpoolWriter(spool_dir, run_id=_run_id(args), name="train",
                       meta={"arch": args.arch})


def _drain_tracer_to_spool(spool):
    """Ship this process's recorded planner/search spans (if any) into
    its spool shard alongside the step/stage events."""
    if spool is None:
        return
    from repro.obs.spans import get_tracer
    tracer = get_tracer()
    if tracer.spans():
        spool.emit_tracer(tracer)


def run_pipeline(args, cfg, stage_plan):
    """Train via a pipeline execution engine (repro.exec): the eager
    per-event engine, or the scan-rolled compiled engine
    (``--engine scan``)."""
    from repro.exec import (
        CompiledPipelineRunner, PipelineRunner, split_model)
    from repro.optim.adam import AdamW

    # tests drive run_pipeline with hand-built Namespaces — default the
    # newer knobs instead of requiring them
    engine = getattr(args, "engine", "eager")
    if engine not in ("eager", "scan"):
        raise ValueError(f"unknown engine {engine!r} (eager|scan)")
    schedule = stage_plan.schedule if args.pipeline == "auto" \
        else args.pipeline
    n_chunks = max(2, args.n_chunks) if schedule == "interleaved" else 1
    n_micro = max(1, args.n_micro)
    while n_micro > 1 and (args.batch % n_micro
                           or (schedule == "interleaved"
                               and n_micro % stage_plan.n_stages)):
        n_micro -= 1
    if schedule == "interleaved" and n_micro % stage_plan.n_stages:
        raise ValueError(
            f"interleaved needs n_micro divisible by "
            f"{stage_plan.n_stages} stages (and by batch {args.batch}); "
            f"none <= {args.n_micro} works — pick --n-micro/--batch "
            f"accordingly or another --pipeline schedule")
    if n_micro != args.n_micro:
        print(f"pipeline: n_micro {args.n_micro} -> {n_micro} "
              f"(must divide batch {args.batch}"
              + (f" and be a multiple of {stage_plan.n_stages} stages"
                 if schedule == "interleaved" else "") + ")", flush=True)

    device_sets = mesh_mod.stage_device_sets(stage_plan)

    # static preflight before any parameter gets allocated: the resolved
    # (schedule, n_micro, n_chunks) triple and the actual device sets,
    # verified device-free — errors abort here, warnings print
    from repro.exec.schedule import make_schedule
    from repro.verify import PlanVerificationError, verify_preflight
    pre = verify_preflight(
        stage_plan,
        make_schedule(schedule, stage_plan.n_stages, n_micro,
                      n_chunks=n_chunks),
        n_micro, n_chunks=n_chunks,
        device_counts=[len(d) for d in device_sets])
    if pre.errors():
        raise PlanVerificationError(
            pre, context=f"launch preflight ({schedule}, "
                         f"S={stage_plan.n_stages}, n_micro={n_micro})")
    for d in pre.warnings():
        print(f"preflight: {d.format()}", flush=True)

    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    splits = stage_plan.layer_splits(cfg.num_periods, n_chunks=n_chunks)
    stage_params, fns, mb_keys, tied = split_model(
        cfg, params, stage_plan.n_stages * n_chunks, splits=splits)

    store = None
    if args.telemetry_dir:
        from repro.runtime.telemetry import MeasurementStore
        store = MeasurementStore(args.telemetry_dir)
    spool = _make_spool(args)
    runner_kw = dict(
        schedule=schedule, n_micro=n_micro, n_chunks=n_chunks,
        mb_keys=mb_keys, tied_ref=tied, store=store, spool=spool,
        meta={"arch": args.arch, "batch": args.batch, "seq": args.seq,
              "launcher": "train", "engine": engine,
              "run_id": _run_id(args)})
    if engine == "scan":
        runner = CompiledPipelineRunner(
            fns, stage_plan, device_sets,
            unroll=max(1, getattr(args, "scan_unroll", 1)), **runner_kw)
        print(f"pipeline engine: scan (rolled lax.scan programs, "
              f"unroll={runner.unroll})", flush=True)
    else:
        runner = PipelineRunner(fns, stage_plan, device_sets, **runner_kw)

    opt = AdamW(lr=args.lr)
    params_list = runner.place_params(stage_params)
    n_virtual = len(params_list)
    opt_state_list = [runner.place(runner.phys(u), opt.init(p))
                      for u, p in enumerate(params_list)]
    start_step = 0
    if getattr(args, "resume", False) and args.ckpt_dir \
            and latest_step(args.ckpt_dir) is not None:
        start_step, tree = load_checkpoint(args.ckpt_dir)
        keys = [_stage_key(u) for u in range(n_virtual)]
        if sorted(tree["params"]) != sorted(keys):
            raise ValueError(
                f"checkpoint in {args.ckpt_dir} is not a "
                f"{n_virtual}-stage pipeline checkpoint — "
                f"resume it with the matching stage map and schedule "
                f"(or without --tag-search for single-mesh checkpoints)")
        params_list = [runner.place(runner.phys(u), tree["params"][k])
                       for u, k in enumerate(keys)]
        opt_state_list = [runner.place(runner.phys(u),
                                       tree["opt_state"][k])
                          for u, k in enumerate(keys)]
        print(f"resumed pipelined run from step {start_step}", flush=True)
    step_fn = steps_mod.make_pipeline_train_step(opt, runner)

    ds = SyntheticDataset(
        cfg.vocab_size, args.seq, args.batch, seed=args.seed,
        frontend_tokens=cfg.frontend_tokens if cfg.frontend != "none" else 0,
        d_model=cfg.d_model)

    # tests drive run_pipeline with hand-built Namespaces: default, don't
    # assume the full CLI surface
    trace_dir = getattr(args, "trace_dir", None)
    record_steps = store is not None or bool(trace_dir)
    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        batch = jax.tree.map(jnp.asarray, ds.batch(step))
        params_list, opt_state_list, metrics = step_fn(
            params_list, opt_state_list, jnp.asarray(step, jnp.int32),
            batch, record=record_steps)
        losses.append(metrics["loss"])
        if step % args.log_every == 0:
            chunks = f"x{n_chunks}v" if n_chunks > 1 else ""
            print(f"step {step:5d} loss={metrics['loss']:.4f} "
                  f"ce={metrics['ce']:.4f} "
                  f"gnorm={metrics['grad_norm']:.3f} "
                  f"[pipeline {schedule} x{stage_plan.n_stages}{chunks}]",
                  flush=True)
        if args.ckpt_dir and args.ckpt_every and \
                (step + 1) % args.ckpt_every == 0:
            # per-stage trees keyed by stage (the flat-npz checkpointer
            # walks dicts, not lists)
            save_checkpoint(
                args.ckpt_dir, step + 1,
                {"params": {_stage_key(s): p
                            for s, p in enumerate(params_list)},
                 "opt_state": {_stage_key(s): o
                               for s, o in enumerate(opt_state_list)}})
    dt = time.time() - t_start
    n = max(args.steps - start_step, 1)
    tail = f"; loss {losses[0]:.4f} -> {losses[-1]:.4f}" if losses else ""
    print(f"done: {n} pipelined steps in {dt:.1f}s "
          f"({dt/n*1e3:.0f} ms/step, schedule={schedule}, "
          f"stages={stage_plan.n_stages}, n_micro={n_micro})"
          f"{tail}", flush=True)
    if trace_dir and runner.last_stats is not None:
        from repro.obs import (
            chrome_trace, executed_trace_events, write_chrome_trace)
        events = executed_trace_events(
            runner.last_stats, pid=0,
            process_name=f"executed [{schedule}]",
            n_stages=stage_plan.n_stages)
        path = write_chrome_trace(
            os.path.join(trace_dir, "trace_executed.json"),
            chrome_trace(events, arch=args.arch, schedule=schedule,
                         n_micro=n_micro,
                         n_stages=stage_plan.n_stages))
        print(f"trace: wrote {path} "
              f"({len(runner.last_stats.events)} events)", flush=True)
    _drain_tracer_to_spool(spool)
    return losses


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="qwen2-1.5b")
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--ckpt-dir", default="")
    ap.add_argument("--ckpt-every", type=int, default=0)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--log-every", type=int, default=1)
    ap.add_argument("--tag-search", action="store_true",
                    help="run TAG strategy search and apply its plan")
    ap.add_argument("--pipeline",
                    choices=["auto", "off", "gpipe", "1f1b",
                             "interleaved", "zb"],
                    default="auto",
                    help="how to execute PIPE actions in a TAG plan: "
                         "a schedule name runs the pipeline engine, "
                         "auto uses the schedule the searched strategy "
                         "voted for (legacy plans: 1f1b), off forces "
                         "single-mesh rules")
    ap.add_argument("--engine", choices=["eager", "scan"],
                    default="eager",
                    help="pipeline execution engine: eager dispatches "
                         "every schedule event from Python; scan runs "
                         "the compiled scan-rolled engine (per-stage "
                         "lax.scan programs, bulk double-buffered "
                         "boundary transfers, GPipe-like stash)")
    ap.add_argument("--scan-unroll", type=int, default=1,
                    help="lax.scan unroll factor for --engine scan "
                         "(1 keeps compile time flat in n_micro)")
    ap.add_argument("--n-micro", type=int, default=4,
                    help="microbatches per pipelined step")
    ap.add_argument("--n-chunks", type=int, default=2,
                    help="virtual model chunks per stage for the "
                         "interleaved schedule")
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--telemetry-dir", default="",
                    help="record per-step telemetry (runtime feedback "
                         "subsystem) to this measurement log")
    ap.add_argument("--trace-dir", default="",
                    help="export Chrome traces here: the executed "
                         "pipeline timeline of the last step plus the "
                         "planner/search span timeline")
    ap.add_argument("--spool-dir", default="",
                    help="append this process's step/stage events and "
                         "spans to a shard in this live-observability "
                         "spool directory (merged across processes by "
                         "the trace collector / served by repro-plan "
                         "serve-metrics)")
    ap.add_argument("--run-id", default="",
                    help="run id grouping this job's spool shard with "
                         "other processes' shards in /traces/<run_id> "
                         "and its telemetry under /runs/<run_id> "
                         "(default: train-<arch>)")
    ap.add_argument("--xla-profile", action="store_true",
                    help="wrap one post-warmup step in a jax.profiler "
                         "trace and record per-collective samples into "
                         "the telemetry log (no-op if the profiler "
                         "backend is unavailable)")
    args = ap.parse_args(argv)

    if args.trace_dir:
        from repro.obs.spans import Tracer, set_tracer
        set_tracer(Tracer(enabled=True))

    cfg = get_reduced(args.arch) if args.smoke else get_config(args.arch)
    mesh = mesh_mod.make_host_mesh()
    rules = steps_mod.baseline_rules(mesh)

    if args.tag_search:
        from repro.core import tag as tag_mod
        from repro.core.plan import lower_strategy
        from repro.core.device import tpu_pods
        from repro.models import loss_fn as model_loss
        red = get_reduced(args.arch)
        rp = init_params(red, jax.random.PRNGKey(0))
        ds0 = SyntheticDataset(red.vocab_size, 32, 4,
                               frontend_tokens=red.frontend_tokens
                               if red.frontend != "none" else 0,
                               d_model=red.d_model)
        rb = jax.tree.map(jnp.asarray, ds0.batch(0))
        topo = tpu_pods()
        result = tag_mod.optimize(
            lambda p, b: model_loss(red, p, b, remat=False)[0],
            rp, rb, topo, name=args.arch, iterations=24, n_groups=24)
        plan = lower_strategy(result.strategy, result.gg, topo, mesh,
                              n_micro=args.n_micro)
        print(f"TAG plan: speedup={result.speedup:.2f}x "
              f"summary={json.dumps(plan.summary)}", flush=True)
        stage_plan = resolve_pipeline(plan, args.pipeline)
        if stage_plan is not None:
            losses = run_pipeline(args, cfg, stage_plan)
            _export_spans(args)
            return losses

    opt = AdamW(lr=args.lr)
    key = jax.random.PRNGKey(args.seed)
    params = init_params(cfg, key)
    opt_state = opt.init(params)
    start_step = 0
    if args.resume and args.ckpt_dir and latest_step(args.ckpt_dir) is not None:
        start_step, tree = load_checkpoint(args.ckpt_dir)
        if _stage_key(0) in tree.get("params", {}):
            raise ValueError(
                f"checkpoint in {args.ckpt_dir} is a per-stage pipeline "
                f"checkpoint — resume it through the pipeline path "
                f"(--tag-search with the same stage map)")
        params, opt_state = tree["params"], tree["opt_state"]
        print(f"resumed from step {start_step}", flush=True)

    ds = SyntheticDataset(
        cfg.vocab_size, args.seq, args.batch, seed=args.seed,
        frontend_tokens=cfg.frontend_tokens if cfg.frontend != "none" else 0,
        d_model=cfg.d_model)

    options = steps_mod.StepOptions(loss_chunk=args.loss_chunk)
    step_fn = jax.jit(steps_mod.make_train_step(cfg, opt, rules, options))

    raw_step_fn = step_fn
    timer = None
    if args.telemetry_dir:
        from repro.runtime.telemetry import MeasurementStore, StepTimer
        timer = StepTimer(MeasurementStore(args.telemetry_dir),
                          meta={"arch": args.arch, "batch": args.batch,
                                "seq": args.seq, "launcher": "train",
                                "run_id": _run_id(args)})
        step_fn = steps_mod.instrument_step(step_fn, timer)

    # profile one post-warmup step (the first is compile-dominated)
    profile_at = -1
    if args.xla_profile:
        profile_at = min(start_step + 1, args.steps - 1)

    spool = _make_spool(args)
    losses = []
    t_start = time.time()
    for step in range(start_step, args.steps):
        t_step = time.perf_counter()
        batch = jax.tree.map(jnp.asarray, ds.batch(step))
        if step == profile_at:
            from repro.obs.xla_profiler import profile_step
            log_dir = os.path.join(
                args.trace_dir or args.telemetry_dir or ".",
                "xla_profile")
            t0 = time.perf_counter()
            out, samples, pmeta = profile_step(
                raw_step_fn, params, opt_state,
                jnp.asarray(step, jnp.int32), batch, log_dir=log_dir)
            wall = time.perf_counter() - t0
            params, opt_state, metrics = out
            print(f"xla-profile: {json.dumps(pmeta)} "
                  f"({len(samples)} collective samples)", flush=True)
            if timer is not None:
                timer.record(wall, collectives=samples)
        else:
            params, opt_state, metrics = step_fn(
                params, opt_state, jnp.asarray(step, jnp.int32), batch)
        loss = float(metrics["loss"])
        if spool is not None:
            spool.emit_span(f"step {step}", t_step, time.perf_counter(),
                            tid=0, cat="train",
                            args={"step": step, "loss": loss})
        losses.append(loss)
        if step % args.log_every == 0:
            print(f"step {step:5d} loss={loss:.4f} "
                  f"ce={float(metrics['ce']):.4f} "
                  f"gnorm={float(metrics['grad_norm']):.3f}", flush=True)
        if args.ckpt_dir and args.ckpt_every and \
                (step + 1) % args.ckpt_every == 0:
            save_checkpoint(args.ckpt_dir, step + 1,
                            {"params": params, "opt_state": opt_state})
    dt = time.time() - t_start
    n = max(args.steps - start_step, 1)
    print(f"done: {n} steps in {dt:.1f}s ({dt/n*1e3:.0f} ms/step); "
          f"loss {losses[0]:.4f} -> {losses[-1]:.4f}", flush=True)
    if timer is not None:
        print(f"telemetry[{args.telemetry_dir}]: "
              f"{json.dumps(timer.summary())}", flush=True)
    _drain_tracer_to_spool(spool)
    _export_spans(args)
    return losses


if __name__ == "__main__":
    main()
