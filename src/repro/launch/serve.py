"""Batched serving driver: prefill a batch of prompts, then decode tokens
step by step against the KV/SSM cache.

    python -m repro.launch.serve --arch mamba2-130m --smoke \
        --batch 4 --prompt-len 32 --gen 16

With ``--plan-topo`` deployment planning routes through the planner
service; adding ``--observe`` closes the paper's §4.3 loop: measured
decode-step wall times are logged to ``--telemetry-dir`` and fed to
``PlannerService.observe`` — past the drift threshold the cached plan is
invalidated and re-searched under a recalibrated cost model.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config, get_reduced
from repro.configs.shapes import InputShape
from repro.launch import mesh as mesh_mod
from repro.launch import steps as steps_mod
from repro.models import (
    abstract_params, decode_step, init_cache, init_params, input_specs,
    loss_fn)
from repro.parallel.sharding import axis_rules


def plan_deployment(cfg, topo_name: str, *, cache_dir=None,
                    iterations: int = 20, n_groups: int = 20,
                    batch: int = 4, seq: int = 32, name: str = "",
                    telemetry_dir: str | None = None,
                    drift_threshold: float = 0.25):
    """Route deployment planning through the planner service: repeated
    launches on the same (model, topology) are served from the plan cache
    without re-running MCTS; perturbed topologies warm-start the search.
    Returns (response, service, grouped_graph, topology) so callers can
    feed observed step times back via ``service.observe``."""
    from repro.core import tag as tag_mod
    from repro.service import PlannerService
    from repro.service.cli import TOPOLOGIES
    if topo_name not in TOPOLOGIES:
        raise SystemExit(f"unknown --plan-topo {topo_name!r}; "
                         f"choose from {sorted(TOPOLOGIES)}")
    # input_specs handles frontend archs (prefix inputs, token budget)
    specs = input_specs(cfg, InputShape(f"plan_{batch}x{seq}", seq, batch,
                                        "train"))
    topo = TOPOLOGIES[topo_name]()
    gg = tag_mod.build_grouped(
        lambda p, b: loss_fn(cfg, p, b, remat=False)[0],
        abstract_params(cfg), specs, name, n_groups)
    svc = PlannerService(cache_dir=cache_dir, telemetry_dir=telemetry_dir,
                         drift_threshold=drift_threshold)
    resp = svc.plan_graph(gg, topo, iterations=iterations)
    return resp, svc, gg, topo


def generate(cfg, params, prompts, gen_tokens: int, rules,
             prefix=None, stats: dict | None = None):
    """prompts: (B, P) int32. Returns (B, gen_tokens) int32.

    When ``stats`` is given it is filled with per-phase wall times
    (``prefill_s``, ``decode_s``, ``decode_steps``): the prefill phase
    absorbs the one-off JIT compile, so ``decode_s / decode_steps`` is a
    steady-state per-step time usable as an observed step measurement.
    """
    B, P = prompts.shape
    total = P + gen_tokens + (cfg.frontend_tokens
                              if cfg.frontend != "none" else 0)
    cache = init_cache(cfg, B, total)

    @jax.jit
    def step(params, cache, tok, pos):
        with axis_rules(rules):
            logits, cache = decode_step(cfg, params, cache, tok, pos)
        nxt = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return nxt[:, None], cache

    # prefill by stepping the prompt (cache-building path is the decode
    # path; a fused prefill exists as launch.steps.make_prefill_step)
    t0 = time.time()
    tok = prompts[:, :1]
    pos = 0
    for i in range(P):
        nxt, cache = step(params, cache, prompts[:, i:i + 1],
                          jnp.asarray(pos, jnp.int32))
        pos += 1
    jax.block_until_ready(nxt)
    t_prefill = time.time() - t0

    t0 = time.time()
    out = []
    cur = nxt
    for _ in range(gen_tokens):
        out.append(cur)
        cur, cache = step(params, cache, cur, jnp.asarray(pos, jnp.int32))
        pos += 1
    res = jnp.concatenate(out, axis=1)
    jax.block_until_ready(res)
    if stats is not None:
        stats.update(prefill_s=t_prefill, decode_s=time.time() - t0,
                     decode_steps=gen_tokens)
    return res


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS), default="mamba2-130m")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--plan-topo", default=None,
                    help="plan deployment on this topology via the planner "
                         "service before serving (testbed/cloud/tpu/...)")
    ap.add_argument("--plan-cache", default=".plans",
                    help="plan-store directory for --plan-topo")
    ap.add_argument("--plan-iters", type=int, default=20)
    ap.add_argument("--observe", action="store_true",
                    help="with --plan-topo: log measured step times and "
                         "feed them back through PlannerService.observe "
                         "(drift -> recalibrate -> replan)")
    ap.add_argument("--telemetry-dir", default=".telemetry",
                    help="measurement log for --observe")
    ap.add_argument("--drift-threshold", type=float, default=0.25)
    args = ap.parse_args(argv)

    cfg = get_reduced(args.arch) if args.smoke else get_config(args.arch)
    plan = None
    if args.plan_topo:
        resp, svc, gg, topo = plan_deployment(
            cfg, args.plan_topo, cache_dir=args.plan_cache,
            iterations=args.plan_iters, batch=args.batch,
            seq=args.prompt_len, name=args.arch,
            telemetry_dir=args.telemetry_dir if args.observe else None,
            drift_threshold=args.drift_threshold)
        plan = (resp, svc, gg, topo)
        print(f"plan[{args.plan_topo}] source={resp.source} "
              f"iters={resp.iterations_run} "
              f"time={resp.time:.4f}s speedup={resp.speedup:.3f} "
              f"stats={svc.stats()}")
    mesh = mesh_mod.make_host_mesh()
    rules = steps_mod.baseline_rules(mesh)
    params = init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, (args.batch, args.prompt_len)),
        jnp.int32)
    t0 = time.time()
    stats: dict = {}
    out = generate(cfg, params, prompts, args.gen, rules, stats=stats)
    dt = time.time() - t0
    print(f"generated {out.shape} tokens in {dt:.1f}s "
          f"({args.batch * args.gen / dt:.1f} tok/s; "
          f"prefill {stats['prefill_s']:.1f}s incl. compile, "
          f"decode {stats['decode_s']:.1f}s)")
    print("sample:", np.asarray(out[0])[:16])

    if args.observe and plan is not None:
        # paper §4.3: feed the measured steady-state per-step wall time
        # (decode phase only — prefill absorbs the one-off JIT compile)
        # back into the planner: telemetry always, invalidation + warm
        # replanning under a recalibrated cost model past the threshold.
        # On CPU hosts this observed time is far from the simulated
        # cluster step, so expect an immediate drift -> replan.
        resp, svc, gg, topo = plan
        step_time = stats["decode_s"] / max(stats["decode_steps"], 1)
        fb = svc.observe(gg, topo, step_time, iterations=args.plan_iters)
        msg = f"observe[{args.plan_topo}] step={step_time:.4f}s kind={fb.kind}"
        if fb.report is not None:
            msg += f" drift={fb.report.drift:.3f}"
        if fb.kind == "replanned":
            msg += (f" stale={fb.stale_time:.4f}s "
                    f"new={fb.response.time:.4f}s improved={fb.improved}")
        print(msg, flush=True)
    return out


if __name__ == "__main__":
    main()
