"""Builders for the jitted train / prefill / serve steps, plus the logical
axis-rule sets that TAG strategies lower into.

The returned step functions enter the ``axis_rules`` context *inside* the
jitted body, so model-level ``logical_shard`` constraints are applied at
trace time under whatever mesh the launcher chose.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig
from repro.configs.shapes import InputShape
from repro.models import model as model_mod
from repro.optim.adam import AdamW, clip_by_global_norm
from repro.parallel.sharding import AxisRules, axis_rules, logical_spec


def baseline_rules(mesh, *, overrides: dict | None = None,
                   grad_sync: dict | None = None) -> AxisRules:
    """Paper-faithful DP(+TP) baseline: batch over pod+data, tensor dims over
    model. TAG strategies produce ``overrides``/``grad_sync`` on top."""
    multi = "pod" in mesh.axis_names
    rules = {
        "batch": ("pod", "data") if multi else ("data",),
        "cache_seq": ("data",),
        "q_heads": "model",
        "kv_heads": "model",
        "mlp": "model",
        "experts": "model",
        "vocab": "model",
        "ssm_heads": "model",
        "ssm_inner": "model",
        "embed": None,
        "expert_embed": None,
        "layers": None,
        "seq": None,
    }
    if overrides:
        rules.update(overrides)
    return AxisRules(mesh=mesh, rules=rules, grad_sync=dict(grad_sync or {}))


def param_shardings(cfg: ModelConfig, rules: AxisRules):
    """NamedSharding tree matching abstract_params(cfg)."""
    axes = model_mod.param_axes(cfg)
    aparams = model_mod.abstract_params(cfg)

    def mk(ax, spec):
        with axis_rules(rules):
            return NamedSharding(rules.mesh, logical_spec(ax, shape=spec.shape))
    return jax.tree.map(mk, axes, aparams,
                        is_leaf=lambda x: isinstance(x, tuple))


def batch_shardings(cfg: ModelConfig, shape: InputShape, rules: AxisRules):
    specs = model_mod.input_specs(cfg, shape)
    out = {}
    with axis_rules(rules):
        for k, v in specs.items():
            ax = ("batch",) + (None,) * (len(v.shape) - 1)
            out[k] = NamedSharding(rules.mesh, logical_spec(ax, shape=v.shape))
    return out


def cache_shardings(cfg: ModelConfig, shape: InputShape, rules: AxisRules):
    specs = model_mod.cache_specs(cfg, shape.global_batch, shape.seq_len)
    axes = model_mod.cache_axes(cfg)

    def mk(ax, spec):
        with axis_rules(rules):
            return NamedSharding(rules.mesh, logical_spec(ax, shape=spec.shape))
    return jax.tree.map(mk, axes, specs, is_leaf=lambda x: isinstance(x, tuple))


def instrument_step(step_fn, timer):
    """Telemetry seam for the step builders: drop-in wrap of a jitted
    step with a ``repro.runtime.telemetry.StepTimer`` (see its docs)."""
    return timer.wrap(step_fn)


@dataclass(frozen=True)
class StepOptions:
    remat: bool = True
    loss_chunk: int = 0
    clip_norm: float = 1.0
    remat_policy: str = "full"


def make_train_step(cfg: ModelConfig, opt: AdamW, rules: AxisRules,
                    options: StepOptions | None = None):
    options = options if options is not None else StepOptions()

    def train_step(params, opt_state, step, batch):
        with axis_rules(rules):
            def loss(p):
                l, m = model_mod.loss_fn(
                    cfg, p, batch, remat=options.remat,
                    loss_chunk=options.loss_chunk,
                    remat_policy=options.remat_policy)
                return l, m
            (l, metrics), grads = jax.value_and_grad(loss, has_aux=True)(params)
            grads, gnorm = clip_by_global_norm(grads, options.clip_norm)
            params, opt_state = opt.update(params, opt_state, grads, step)
            metrics = dict(metrics, loss=l, grad_norm=gnorm)
        return params, opt_state, metrics
    return train_step


def make_pipeline_train_step(opt: AdamW, runner,
                             options: StepOptions | None = None):
    """Train-step builder for the pipeline execution engines.

    ``runner`` is a ``repro.exec.engine.PipelineRunner`` or
    ``CompiledPipelineRunner`` — both satisfy the same
    ``step() -> (grads_list, StepStats)`` contract; params/opt
    state are per-stage lists committed to the stage devices. The
    optimizer update runs per stage (jitted once per stage, computation
    stays on the stage's devices); gradient clipping is by the GLOBAL
    norm across stages — per-stage squared norms are tiny scalars, so
    the cross-stage reduction happens on host like a real multi-host
    trainer's scalar allreduce.
    """
    import jax.numpy as jnp
    from repro.optim.adam import global_norm

    options = options if options is not None else StepOptions()
    sq = jax.jit(lambda g: global_norm(g) ** 2)

    upd = jax.jit(
        lambda p, s, g, step, scale: opt.update(
            p, s,
            jax.tree.map(lambda gg: (gg.astype(jnp.float32)
                                     * scale).astype(gg.dtype), g),
            step))

    def step_fn(params_list, opt_state_list, step, batch, *,
                record: bool = False):
        grads, stats = runner.step(params_list, batch, record=record)
        gnorm = float(sum(float(sq(g)) for g in grads)) ** 0.5
        scale = jnp.asarray(min(1.0, options.clip_norm / max(gnorm, 1e-9)),
                            jnp.float32)
        new_p, new_s = [], []
        for p, s, g in zip(params_list, opt_state_list, grads,
                           strict=True):
            p2, s2 = upd(p, s, g, step, scale)
            new_p.append(p2)
            new_s.append(s2)
        metrics = dict(stats.metrics, loss=stats.loss, grad_norm=gnorm,
                       wall_time=stats.wall_time,
                       peak_stash=stats.peak_stash)
        return new_p, new_s, metrics
    return step_fn


def make_prefill_step(cfg: ModelConfig, rules: AxisRules):
    def prefill(params, batch):
        with axis_rules(rules):
            return model_mod.prefill_step(cfg, params, batch)
    return prefill


def make_serve_step(cfg: ModelConfig, rules: AxisRules):
    """One decode step: greedy next token + updated cache."""
    def serve(params, cache, tokens, pos):
        with axis_rules(rules):
            logits, cache = model_mod.decode_step(cfg, params, cache, tokens, pos)
            next_tok = jnp.argmax(logits[:, -1], axis=-1).astype(jnp.int32)
        return next_tok[:, None], cache
    return serve


def jit_train_step(cfg, opt, rules, shape,
                   options: StepOptions | None = None):
    options = options if options is not None else StepOptions()
    ps = param_shardings(cfg, rules)
    bs = batch_shardings(cfg, shape, rules)
    os_ = jax.tree.map(lambda s: s, ps)  # opt moments follow params
    opt_sh = {"mu": os_, "nu": os_}
    fn = make_train_step(cfg, opt, rules, options)
    return jax.jit(
        fn,
        in_shardings=(ps, opt_sh, NamedSharding(rules.mesh, P()), bs),
        out_shardings=(ps, opt_sh, NamedSharding(rules.mesh, P())),
    ), ps, opt_sh, bs


def jit_serve_step(cfg, rules, shape):
    ps = param_shardings(cfg, rules)
    cs = cache_shardings(cfg, shape, rules)
    bs = batch_shardings(cfg, shape, rules)
    fn = make_serve_step(cfg, rules)
    rep = NamedSharding(rules.mesh, P())
    return jax.jit(
        fn,
        in_shardings=(ps, cs, bs["tokens"], rep),
        out_shardings=(bs["tokens"], cs),
    ), ps, cs, bs


def jit_prefill_step(cfg, rules, shape):
    ps = param_shardings(cfg, rules)
    bs = batch_shardings(cfg, shape, rules)
    fn = make_prefill_step(cfg, rules)
    return jax.jit(fn, in_shardings=(ps, bs)), ps, None, bs
