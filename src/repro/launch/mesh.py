"""Production meshes (TPU v5e target).

Functions, not module-level constants, so importing this module never
touches jax device state.
"""
from __future__ import annotations

import jax

try:  # jax >= 0.5: explicit/auto axis types on meshes
    from jax.sharding import AxisType
except ImportError:  # older jax: make_mesh has no axis_types kwarg
    AxisType = None


def _make_mesh(shape, axes):
    if AxisType is not None:
        return jax.make_mesh(shape, axes,
                             axis_types=(AxisType.Auto,) * len(axes))
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod; 2 pods = 512 chips multi-pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _make_mesh(shape, axes)


def make_mesh(shape, axes):
    return _make_mesh(tuple(shape), tuple(axes))


def make_host_mesh():
    """Whatever devices exist locally, as a ("data",) mesh (tests/smoke)."""
    n = len(jax.devices())
    return _make_mesh((n,), ("data",))


def stage_device_sets(stage_plan, devices=None) -> list:
    """Per-stage device slices for a ``repro.exec.stages.StagePlan`` on
    the local host (proportional to the topology's group sizes).
    Raises ``repro.exec.stages.PipelineInfeasible`` when the host has
    fewer devices than stages — callers fall back to single-mesh rules."""
    return stage_plan.assign_local_devices(
        jax.devices() if devices is None else devices)


# TPU v5e hardware constants (per chip) used by the roofline analysis.
HW = {
    "peak_flops_bf16": 197e12,   # FLOP/s
    "hbm_bw": 819e9,             # B/s
    "ici_bw": 50e9,              # B/s per link
    "hbm_bytes": 16e9,
}
