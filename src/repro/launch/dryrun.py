import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: lower + compile every (arch x input-shape) combination
on the production meshes, without allocating real arrays (ShapeDtypeStruct
stand-ins only), and extract the roofline terms from the compiled artifact.

Run:  python -m repro.launch.dryrun --arch yi-6b --shape train_4k
      python -m repro.launch.dryrun --all --out results/dryrun.json
"""
import argparse  # noqa: E402
import json  # noqa: E402
import time  # noqa: E402

import jax  # noqa: E402
import jax.numpy as jnp  # noqa: E402

from repro.configs import ARCH_IDS, SHAPES, config_for_shape  # noqa: E402
from repro.core.hlo_analysis import analyze_hlo, xla_cost_analysis  # noqa: E402
from repro.launch import mesh as mesh_mod  # noqa: E402
from repro.launch import steps as steps_mod  # noqa: E402
from repro.optim.adam import AdamW  # noqa: E402


def roofline_terms(flops, hbm_bytes, coll_bytes, n_chips, links_per_chip=4):
    hw = mesh_mod.HW
    return {
        # cost_analysis is per-partition (per-chip) on SPMD modules
        "compute_s": flops / hw["peak_flops_bf16"],
        "memory_s": hbm_bytes / hw["hbm_bw"],
        "collective_s": coll_bytes / (hw["ici_bw"] * links_per_chip),
    }


def lower_one(arch: str, shape_name: str, mesh, *, overrides=None,
              grad_sync=None, options=None, cfg_overrides=None,
              profile: bool = False):
    """Lower + compile one (arch, shape) on a mesh; return stats dict."""
    shape = SHAPES[shape_name]
    cfg = config_for_shape(arch, shape_name)
    if cfg_overrides:
        cfg = cfg.replace(**cfg_overrides)
    rules = steps_mod.baseline_rules(mesh, overrides=overrides,
                                     grad_sync=grad_sync)
    opts = options or steps_mod.StepOptions()
    opt = AdamW()

    aparams = __import__("repro.models", fromlist=["abstract_params"])\
        .abstract_params(cfg)
    t0 = time.time()
    if shape.kind == "train":
        jitted, ps, opt_sh, bs = steps_mod.jit_train_step(
            cfg, opt, rules, shape, opts)
        aopt = {"mu": aparams, "nu": aparams}
        astep = jax.ShapeDtypeStruct((), jnp.int32)
        abatch = __import__("repro.models", fromlist=["input_specs"])\
            .input_specs(cfg, shape)
        lowered = jitted.lower(aparams, aopt, astep, abatch)
    elif shape.kind == "prefill":
        jitted, ps, _, bs = steps_mod.jit_prefill_step(cfg, rules, shape)
        abatch = __import__("repro.models", fromlist=["input_specs"])\
            .input_specs(cfg, shape)
        lowered = jitted.lower(aparams, abatch)
    else:  # decode
        from repro.models import model as model_mod
        jitted, ps, cs, bs = steps_mod.jit_serve_step(cfg, rules, shape)
        acache = model_mod.cache_specs(cfg, shape.global_batch, shape.seq_len)
        atok = jax.ShapeDtypeStruct((shape.global_batch, 1), jnp.int32)
        apos = jax.ShapeDtypeStruct((), jnp.int32)
        lowered = jitted.lower(aparams, acache, atok, apos)
    t_lower = time.time() - t0

    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    ca = xla_cost_analysis(compiled)
    # cost_analysis counts while bodies once; analyze_hlo multiplies by the
    # known_trip_count along the call graph (see core/hlo_analysis.py).
    stats = analyze_hlo(compiled.as_text())
    if profile:
        print(stats.summary(18), flush=True)
    n_chips = mesh.devices.size
    terms = roofline_terms(stats.flops, stats.bytes_accessed,
                           stats.collective_wire_bytes, n_chips)
    dominant = max(terms, key=terms.get)

    return {
        "arch": arch,
        "shape": shape_name,
        "mesh": "x".join(str(s) for s in mesh.devices.shape),
        "n_chips": int(n_chips),
        "kind": shape.kind,
        "lower_s": round(t_lower, 2),
        "compile_s": round(t_compile, 2),
        "hlo_flops": stats.flops,
        "hlo_bytes": stats.bytes_accessed,
        "xla_cost_analysis": {"flops": float(ca.get("flops", 0.0)),
                              "bytes": float(ca.get("bytes accessed", 0.0))},
        "collectives": {"bytes": dict(stats.collective_bytes),
                        "counts": dict(stats.collective_counts),
                        "total_bytes": stats.collective_wire_bytes},
        "while_trips": stats.while_trips,
        "memory": {
            "argument_bytes": mem.argument_size_in_bytes,
            "output_bytes": mem.output_size_in_bytes,
            "temp_bytes": mem.temp_size_in_bytes,
            "code_bytes": mem.generated_code_size_in_bytes,
        },
        "roofline": terms,
        "dominant": dominant,
        "params": config_for_shape(arch, shape_name).param_count(),
        "active_params": config_for_shape(arch, shape_name).param_count(
            active_only=True),
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list(ARCH_IDS))
    ap.add_argument("--shape", choices=list(SHAPES))
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--out", default="")
    ap.add_argument("--loss-chunk", type=int, default=0)
    ap.add_argument("--remat-policy", default="full",
                    choices=["full", "dots", "none"])
    ap.add_argument("--attn-chunk", type=int, default=0)
    ap.add_argument("--capacity-factor", type=float, default=0.0)
    ap.add_argument("--profile", action="store_true",
                    help="print per-op byte/flop attribution")
    ap.add_argument("--telemetry-dir", default="",
                    help="append each combo's roofline-estimated step "
                         "record to this measurement log")
    ap.add_argument("--override", action="append", default=[],
                    help="logical=mesh_axis rule override, e.g. embed=data")
    args = ap.parse_args()

    overrides = {}
    for ov in args.override:
        k, _, v = ov.partition("=")
        overrides[k] = None if v in ("", "none", "None") else (
            tuple(v.split("+")) if "+" in v else v)

    meshes = []
    if args.both_meshes:
        meshes = [mesh_mod.make_production_mesh(multi_pod=False),
                  mesh_mod.make_production_mesh(multi_pod=True)]
    else:
        meshes = [mesh_mod.make_production_mesh(multi_pod=args.multi_pod)]

    combos = []
    if args.all:
        combos = [(a, s) for a in ARCH_IDS for s in SHAPES]
    else:
        assert args.arch and args.shape, "--arch/--shape or --all required"
        combos = [(args.arch, args.shape)]

    opts = steps_mod.StepOptions(loss_chunk=args.loss_chunk,
                                 remat_policy=args.remat_policy)
    cfg_overrides = {}
    if args.attn_chunk:
        cfg_overrides["attn_chunk"] = args.attn_chunk
    if args.capacity_factor:
        cfg_overrides["capacity_factor"] = args.capacity_factor
    telemetry = None
    if args.telemetry_dir:
        from repro.runtime.telemetry import MeasurementStore, StepRecord
        telemetry = MeasurementStore(args.telemetry_dir)

    results = []
    for mesh in meshes:
        for arch, shape in combos:
            tag = f"{arch} x {shape} @ {mesh.devices.shape}"
            try:
                r = lower_one(arch, shape, mesh, overrides=overrides or None,
                              options=opts, cfg_overrides=cfg_overrides or None,
                              profile=args.profile)
                r["ok"] = True
                if telemetry is not None:
                    # wall-time-only log of roofline step estimates +
                    # compile costs per (arch, shape, mesh) — inspectable
                    # history via MeasurementStore; carries no per-op
                    # samples, so it does not feed fit_profile. The step
                    # estimate is the dominant term, matching the
                    # overlap model behind `dominant`.
                    telemetry.append(StepRecord(
                        wall_time=max(r["roofline"].values()),
                        meta={"arch": arch, "shape": shape,
                              "mesh": r["mesh"], "launcher": "dryrun",
                              "dominant": r["dominant"],
                              "compile_s": r["compile_s"],
                              "lower_s": r["lower_s"]}))
                terms = r["roofline"]
                print(f"OK  {tag}: compile={r['compile_s']}s "
                      f"flops={r['hlo_flops']:.3e} bytes={r['hlo_bytes']:.3e} "
                      f"coll={r['collectives']['total_bytes']:.3e} "
                      f"dominant={r['dominant']} "
                      f"terms=({terms['compute_s']:.4f},"
                      f"{terms['memory_s']:.4f},{terms['collective_s']:.4f})s",
                      flush=True)
            except Exception as e:  # noqa: BLE001 — report per-combo failures
                r = {"arch": arch, "shape": shape,
                     "mesh": "x".join(str(s) for s in mesh.devices.shape),
                     "ok": False, "error": f"{type(e).__name__}: {e}"}
                print(f"FAIL {tag}: {r['error']}", flush=True)
            results.append(r)
            if args.out:
                with open(args.out, "w") as f:
                    json.dump(results, f, indent=1)
    n_ok = sum(r["ok"] for r in results)
    print(f"\n{n_ok}/{len(results)} combinations lowered + compiled")
    return 0 if n_ok == len(results) else 1


if __name__ == "__main__":
    raise SystemExit(main())
