"""Diagnostic model for the static plan verifier.

Every finding the verifier emits is a ``Diagnostic``: a stable code
(``TAGxxx``), a severity, a human message, and a source location inside
the deployment (stage, microbatch, chunk, event index). Codes are
API — tests, CI gates and the mutation self-test match on them, so a
code never changes meaning once shipped. The full table lives in
``CODES`` (and is rendered into the README's diagnostic-code table).

Severity semantics:

  * ``error`` — the deployment is unsound: it deadlocks, races, OOMs or
    references devices/links that cannot serve it. ``PlannerService``
    refuses to cache such a plan; preflight refuses to run it.
  * ``warn``  — legal but suspicious (mixed sync votes, >90% memory
    pressure, sync participants drifting from the searched placement).
  * ``info``  — lint-grade observations (degenerate collectives,
    microbatch normalization applied before verification).
"""
from __future__ import annotations

from dataclasses import dataclass, field
import enum
from typing import Iterable, Iterator


class Severity(enum.Enum):
    """Diagnostic severity: ``error`` is unsound, the rest is lint."""

    ERROR = "error"
    WARN = "warn"
    INFO = "info"

    def __str__(self) -> str:
        return self.value


# code -> (severity, short title). The message on each Diagnostic adds
# the instance-specific detail (exact overshoot bytes, cycle, ...).
CODES: dict[str, tuple[Severity, str]] = {
    # --- plan / input structure -------------------------------------
    "TAG001": (Severity.ERROR, "malformed schedule or plan structure"),
    "TAG002": (Severity.INFO, "microbatch count normalized for "
                              "verification"),
    # --- happens-before analysis ------------------------------------
    "TAG101": (Severity.ERROR, "schedule deadlock: happens-before cycle"),
    "TAG102": (Severity.ERROR, "backward issued before its forward"),
    "TAG103": (Severity.ERROR, "weight-grad issued before its backward"),
    "TAG104": (Severity.ERROR, "event coverage hole (missing event)"),
    "TAG105": (Severity.ERROR, "duplicate schedule event"),
    "TAG106": (Severity.ERROR, "unmatched send/recv at stage boundary"),
    "TAG107": (Severity.ERROR, "cross-stage transfer ordering race"),
    # --- memory-budget prover ---------------------------------------
    "TAG201": (Severity.ERROR, "device memory budget exceeded (OOM)"),
    "TAG202": (Severity.WARN, "memory pressure above 90% of capacity"),
    # --- collective matching ----------------------------------------
    "TAG301": (Severity.ERROR, "unknown gradient-sync mode"),
    "TAG302": (Severity.ERROR, "SFB sync on a single-device group"),
    "TAG303": (Severity.WARN, "mixed sync votes within one stage"),
    "TAG304": (Severity.INFO, "degenerate collective (1 participant)"),
    "TAG305": (Severity.WARN, "sync participants drift from searched "
                              "placement"),
    "TAG306": (Severity.INFO, "degenerate split: tiny per-device shard"),
    # --- placement feasibility --------------------------------------
    "TAG401": (Severity.ERROR, "stage spans not contiguous in "
                               "topological order"),
    "TAG402": (Severity.ERROR, "invalid device-group reference"),
    "TAG403": (Severity.ERROR, "stage capacity mismatch vs topology"),
    "TAG404": (Severity.ERROR, "scheduled transfer over unreachable "
                               "link"),
    "TAG405": (Severity.ERROR, "empty stage span"),
    "TAG406": (Severity.ERROR, "op group assigned to multiple stages"),
}


@dataclass(frozen=True)
class Loc:
    """Source location of a diagnostic inside a deployment."""

    stage: int | None = None
    mb: int | None = None
    chunk: int | None = None
    event_index: int | None = None

    def __str__(self) -> str:
        parts: list[str] = []
        if self.stage is not None:
            parts.append(f"stage {self.stage}")
        if self.chunk is not None:
            parts.append(f"chunk {self.chunk}")
        if self.mb is not None:
            parts.append(f"mb {self.mb}")
        if self.event_index is not None:
            parts.append(f"event #{self.event_index}")
        return ", ".join(parts)

    def to_dict(self) -> dict[str, int]:
        """JSON-safe dict with only the populated location fields."""
        out: dict[str, int] = {}
        for k in ("stage", "mb", "chunk", "event_index"):
            v = getattr(self, k)
            if v is not None:
                out[k] = int(v)
        return out


@dataclass(frozen=True)
class Diagnostic:
    """One finding: a stable ``TAGxxx`` code, severity, message, location."""

    code: str
    severity: Severity
    message: str
    loc: Loc = Loc()

    @property
    def title(self) -> str:
        """Short title the code table assigns to this code."""
        return CODES[self.code][1] if self.code in CODES else self.code

    def format(self) -> str:
        """One human-readable ``CODE severity: [loc] message`` line."""
        where = str(self.loc)
        at = f" [{where}]" if where else ""
        return f"{self.code} {self.severity}:{at} {self.message}"

    def to_dict(self) -> dict[str, object]:
        """JSON-safe dict form (code, severity, title, message, loc)."""
        return {"code": self.code, "severity": str(self.severity),
                "title": self.title, "message": self.message,
                "loc": self.loc.to_dict()}


def make(code: str, message: str, *, stage: int | None = None,
         mb: int | None = None, chunk: int | None = None,
         event_index: int | None = None) -> Diagnostic:
    """Build a diagnostic with the severity the code table mandates."""
    sev, _title = CODES[code]
    return Diagnostic(code=code, severity=sev, message=message,
                      loc=Loc(stage=stage, mb=mb, chunk=chunk,
                              event_index=event_index))


@dataclass
class Report:
    """An ordered collection of diagnostics plus convenience views."""

    diagnostics: list[Diagnostic] = field(default_factory=list)

    def add(self, code: str, message: str, *, stage: int | None = None,
            mb: int | None = None, chunk: int | None = None,
            event_index: int | None = None) -> Diagnostic:
        """Append (and return) a diagnostic built from the code table."""
        d = make(code, message, stage=stage, mb=mb, chunk=chunk,
                 event_index=event_index)
        self.diagnostics.append(d)
        return d

    def extend(self, other: "Report") -> "Report":
        """Absorb another report's diagnostics; returns ``self``."""
        self.diagnostics.extend(other.diagnostics)
        return self

    def __len__(self) -> int:
        return len(self.diagnostics)

    def __iter__(self) -> Iterator[Diagnostic]:
        return iter(self.diagnostics)

    def errors(self) -> list[Diagnostic]:
        """Error-severity diagnostics, in report order."""
        return [d for d in self.diagnostics
                if d.severity is Severity.ERROR]

    def warnings(self) -> list[Diagnostic]:
        """Warn-severity diagnostics, in report order."""
        return [d for d in self.diagnostics if d.severity is Severity.WARN]

    def infos(self) -> list[Diagnostic]:
        """Info-severity diagnostics, in report order."""
        return [d for d in self.diagnostics if d.severity is Severity.INFO]

    @property
    def ok(self) -> bool:
        """True when no error-severity diagnostic is present."""
        return not self.errors()

    @property
    def verdict(self) -> str:
        """Worst severity present: ``error`` | ``warn`` | ``clean``."""
        if self.errors():
            return "error"
        if self.warnings():
            return "warn"
        return "clean"

    def codes(self) -> set[str]:
        """The set of distinct codes present in the report."""
        return {d.code for d in self.diagnostics}

    def has(self, *codes: str) -> bool:
        """True when every given code appears in the report."""
        got = self.codes()
        return all(c in got for c in codes)

    def summary(self) -> dict[str, object]:
        """Compact verdict dict (persisted into ``PlanRecord.meta``)."""
        return {"verdict": self.verdict,
                "errors": len(self.errors()),
                "warnings": len(self.warnings()),
                "infos": len(self.infos()),
                "codes": sorted(self.codes())}

    def to_dict(self) -> dict[str, object]:
        """JSON-safe dict: the summary plus every diagnostic."""
        return {"summary": self.summary(),
                "diagnostics": [d.to_dict() for d in self.diagnostics]}

    def format(self, *, max_lines: int = 0) -> str:
        """Multi-line human rendering; truncated past ``max_lines``."""
        lines = [d.format() for d in self.diagnostics]
        if max_lines and len(lines) > max_lines:
            lines = [*lines[:max_lines],
                     f"... {len(self.diagnostics) - max_lines} more"]
        s = self.summary()
        head = (f"verify: {s['verdict']} ({s['errors']} error(s), "
                f"{s['warnings']} warning(s), {s['infos']} info)")
        return "\n".join([head, *lines])


class PlanVerificationError(RuntimeError):
    """Raised when a caller demands a clean plan and got errors."""

    def __init__(self, report: Report, context: str = ""):
        self.report = report
        head = f"plan verification failed ({context})" if context \
            else "plan verification failed"
        super().__init__(head + "\n" + report.format(max_lines=20))


def merge(reports: Iterable[Report]) -> Report:
    """Concatenate reports into one, preserving order."""
    out = Report()
    for r in reports:
        out.extend(r)
    return out
