"""Mutation-based self-test of the static plan verifier.

Soundness gate: inject one known violation at a time into an otherwise
clean deployment — dropped/duplicated events, swapped dependencies that
deadlock, consumer-side reorders that race, shrunk device memory,
mismatched or degenerate collectives, broken placements — and require
the verifier to flag every injected class with its designated
``TAGxxx`` code, across all four schedule families. ``run_selftest``
is wired into CI (``repro-plan verify --selftest``) so the analyses
cannot silently rot as schedules evolve.

Every mutation is a pure function on a deep-copied ``MutationContext``,
so the harness is deterministic and order-independent.
"""
from __future__ import annotations

import copy
from dataclasses import dataclass, field
from typing import Callable

from repro.core.device import DeviceGroup, Topology, _full_inter
from repro.exec.schedule import SCHEDULES, Event, make_schedule
from repro.exec.stages import StagePlan, StageSpec
from repro.verify import collectives as collectives_mod
from repro.verify import hb as hb_mod
from repro.verify import memory as memory_mod
from repro.verify import placement as placement_mod
from repro.verify.diagnostics import Report


@dataclass
class MutationContext:
    """Everything one verification pass consumes, mutable in place."""

    plan: StagePlan
    topo: Topology
    order: list[list[Event]]
    schedule: str
    n_micro: int
    n_chunks: int
    # synthetic topological positions (stand-in for a traced graph's
    # ``group_positions``) so the contiguity analysis runs without jax
    positions: dict[int, float] = field(default_factory=dict)

    @property
    def n_stages(self) -> int:
        """Stage count of the context's plan."""
        return self.plan.n_stages


@dataclass(frozen=True)
class Mutation:
    """One seeded violation: a mutator plus the codes it must trigger."""

    name: str
    klass: str                 # violation class (acceptance taxonomy)
    expect: tuple[str, ...]    # every listed code must be reported
    apply: Callable[[MutationContext], bool]   # False: not applicable


def make_context(schedule: str, *, n_stages: int = 4, n_micro: int = 8,
                 n_chunks: int = 2) -> MutationContext:
    """Build a small, clean, fully synthetic deployment.

    ``n_stages`` stages over a homogeneous V100 topology, modest
    tensors, well inside every budget — the verifier must report zero
    errors on it.
    """
    gbps = 1e9 / 8
    groups = [DeviceGroup(g, "V100", 2, intra_bw=300 * gbps)
              for g in range(n_stages)]
    topo = Topology(groups, _full_inter(n_stages, 100 * gbps),
                    name="selftest")
    stages = []
    for s in range(n_stages):
        stages.append(StageSpec(
            stage_id=s, device_group=s, op_group_ids=[2 * s, 2 * s + 1],
            flops=1e12, param_bytes=64e6, grad_bytes=64e6,
            out_bytes=8e6 if s < n_stages - 1 else 0.0,
            sync="allreduce", n_devices=2, gpu_type="V100"))
    plan = StagePlan(stages=stages, placement=tuple(range(n_stages)),
                     n_micro=n_micro, topo_name="selftest",
                     schedule=schedule)
    V = n_chunks if schedule == "interleaved" else 1
    order = make_schedule(schedule, n_stages, n_micro, n_chunks=V)
    positions = {gid: float(gid) for st in stages
                 for gid in st.op_group_ids}
    return MutationContext(plan=plan, topo=topo, order=order,
                           schedule=schedule, n_micro=n_micro,
                           n_chunks=V, positions=positions)


def verify_context(ctx: MutationContext) -> Report:
    """The full four-analysis pass over a (possibly mutated) context."""
    rep = hb_mod.analyze_schedule(ctx.order, ctx.n_stages, ctx.n_micro,
                                  n_chunks=ctx.n_chunks)
    rep.extend(placement_mod.analyze_placement(
        ctx.plan, ctx.topo, positions=ctx.positions or None,
        n_chunks=ctx.n_chunks))
    rep.extend(collectives_mod.analyze_collectives(ctx.plan, ctx.topo))
    rep.extend(memory_mod.analyze_memory(ctx.plan, ctx.topo, ctx.order,
                                         ctx.n_micro))
    return rep


# ------------------------------------------------------------ mutators

def _mid_stage(ctx: MutationContext) -> int:
    return ctx.n_stages // 2


def _drop_event(ctx: MutationContext) -> bool:
    """Remove one backward from a middle stage.

    Creates a coverage hole and an unmatched boundary recv downstream.
    """
    s = _mid_stage(ctx)
    evs = ctx.order[s]
    idx = next((i for i, e in enumerate(evs) if e.kind == "B"), None)
    if idx is None:
        return False
    del evs[idx]
    return True


def _duplicate_event(ctx: MutationContext) -> bool:
    """Issue one forward twice on the same stage."""
    s = _mid_stage(ctx)
    evs = ctx.order[s]
    idx = next((i for i, e in enumerate(evs) if e.kind == "F"), None)
    if idx is None:
        return False
    evs.insert(idx + 1, evs[idx])
    return True


def _swap_dependency_deadlock(ctx: MutationContext) -> bool:
    """Move stage 0's last forward behind its own backward chain.

    The downstream stages' forwards now wait on an event that waits
    (through the backward chain) on them — a pure happens-before cycle.
    """
    if ctx.n_stages < 2:
        return False
    evs = ctx.order[0]
    idx = max(i for i, e in enumerate(evs) if e.kind == "F")
    evs.append(evs.pop(idx))
    return True


def _reorder_transfer_race(ctx: MutationContext) -> bool:
    """Swap the last stage's first two forward arrivals (chunk 0).

    The producer still emits mb 0 then 1, the consumer now awaits 1
    then 0 — reordered traffic on a FIFO boundary link.
    """
    if ctx.n_stages < 2:
        return False
    evs = ctx.order[ctx.n_stages - 1]
    f_idx = [i for i, e in enumerate(evs)
             if e.kind == "F" and e.chunk == 0]
    if len(f_idx) < 2:
        return False
    i, j = f_idx[0], f_idx[1]
    evs[i], evs[j] = evs[j], evs[i]
    return True


def _w_before_b(ctx: MutationContext) -> bool:
    """Hoist a weight-grad above the backward it consumes (zb only)."""
    for evs in ctx.order:
        wi = next((i for i, e in enumerate(evs) if e.kind == "W"), None)
        if wi is None:
            continue
        w = evs.pop(wi)
        bi = next(i for i, e in enumerate(evs)
                  if e.kind == "B" and e.mb == w.mb
                  and e.chunk == w.chunk)
        evs.insert(bi, w)
        return True
    return False


def _shrink_memory(ctx: MutationContext) -> bool:
    """Shrink a stage's device memory below its parameter residents."""
    g = ctx.plan.stages[0].device_group
    ctx.topo.groups[g].mem_bytes = 2.0 * ctx.plan.stages[0].param_bytes
    return True


def _sfb_on_singleton(ctx: MutationContext) -> bool:
    """Demand SFB sync on a group shrunk to one device."""
    st = ctx.plan.stages[0]
    ctx.topo.groups[st.device_group].num_gpus = 1
    st.n_devices = 1
    st.sync = "sfb"
    return True


def _unknown_sync(ctx: MutationContext) -> bool:
    ctx.plan.stages[_mid_stage(ctx)].sync = "ring-exchange"
    return True


def _invalid_device_group(ctx: MutationContext) -> bool:
    ctx.plan.stages[_mid_stage(ctx)].device_group = ctx.topo.m + 7
    return True


def _capacity_mismatch(ctx: MutationContext) -> bool:
    ctx.plan.stages[_mid_stage(ctx)].n_devices += 5
    return True


def _non_contiguous_span(ctx: MutationContext) -> bool:
    """Swap an op group between the first and last stages.

    Both spans now straddle each other in topological order.
    """
    if ctx.n_stages < 2:
        return False
    a, b = ctx.plan.stages[0], ctx.plan.stages[-1]
    if not a.op_group_ids or not b.op_group_ids:
        return False
    a.op_group_ids[0], b.op_group_ids[-1] = (b.op_group_ids[-1],
                                             a.op_group_ids[0])
    return True


def _unreachable_link(ctx: MutationContext) -> bool:
    """Calibrate the first boundary's link down to zero bandwidth."""
    if ctx.n_stages < 2 or ctx.plan.stages[0].out_bytes <= 0:
        return False
    gi = ctx.plan.stages[0].device_group
    gj = ctx.plan.stages[1].device_group
    ctx.topo.pair_eff[(gi, gj)] = 0.0
    ctx.topo.pair_eff[(gj, gi)] = 0.0
    return True


MUTATIONS: tuple[Mutation, ...] = (
    Mutation("drop_event", "dropped", ("TAG104", "TAG106"), _drop_event),
    Mutation("duplicate_event", "dropped", ("TAG105",),
             _duplicate_event),
    Mutation("swap_dependency_deadlock", "deadlock", ("TAG101",),
             _swap_dependency_deadlock),
    Mutation("reorder_transfer_race", "race", ("TAG107",),
             _reorder_transfer_race),
    Mutation("w_before_b", "deadlock", ("TAG103",), _w_before_b),
    Mutation("shrink_memory", "oom", ("TAG201",), _shrink_memory),
    Mutation("sfb_on_singleton", "collective", ("TAG302",),
             _sfb_on_singleton),
    Mutation("unknown_sync", "collective", ("TAG301",), _unknown_sync),
    Mutation("invalid_device_group", "placement", ("TAG402",),
             _invalid_device_group),
    Mutation("capacity_mismatch", "placement", ("TAG403",),
             _capacity_mismatch),
    Mutation("non_contiguous_span", "placement", ("TAG401",),
             _non_contiguous_span),
    Mutation("unreachable_link", "placement", ("TAG404",),
             _unreachable_link),
)


def run_selftest(*, schedules: tuple[str, ...] = SCHEDULES,
                 n_stages: int = 4, n_micro: int = 8
                 ) -> dict[str, object]:
    """Run every mutation against every schedule family.

    Returns a summary dict; ``ok`` is True iff every clean baseline
    verified with zero errors AND every applicable mutation was caught
    with every expected code. ``missed`` lists failures as
    ``{schedule, mutation, expected, got}``.
    """
    missed: list[dict[str, object]] = []
    ran = caught = 0
    clean_ok = True
    for sched in schedules:
        base = make_context(sched, n_stages=n_stages, n_micro=n_micro)
        base_rep = verify_context(base)
        if not base_rep.ok:
            clean_ok = False
            missed.append({"schedule": sched, "mutation": "<clean>",
                           "expected": [],
                           "got": sorted(base_rep.codes())})
        for mut in MUTATIONS:
            ctx = copy.deepcopy(base)
            if not mut.apply(ctx):
                continue                  # not applicable to this family
            ran += 1
            rep = verify_context(ctx)
            if rep.has(*mut.expect):
                caught += 1
            else:
                missed.append({"schedule": sched, "mutation": mut.name,
                               "expected": list(mut.expect),
                               "got": sorted(rep.codes())})
    return {"schedules": list(schedules), "mutations_run": ran,
            "caught": caught, "missed": missed,
            "clean_baselines_ok": clean_ok,
            "ok": clean_ok and not missed}
